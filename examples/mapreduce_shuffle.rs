//! MapReduce shuffle-stage sort — the paper's second motivating
//! application (§II.A): keys emitted by mappers must be sorted before the
//! reduce stage. Each mapper's spill buffer becomes one in-memory sort;
//! the example runs a batch of spills through the multi-bank sorter and
//! groups the sorted stream by key for the reducers.
//!
//! Run: `cargo run --release --example mapreduce_shuffle`

use memsort::datasets::mapreduce::{record_stream, MapReduceProfile};
use memsort::datasets::rng::Rng;
use memsort::prelude::*;
use memsort::sorter::SortStats;

fn main() {
    let mappers = 8;
    let spill = 1024; // records per mapper spill buffer
    let profile = MapReduceProfile::default();
    let mut rng = Rng::new(99);

    let mut agg = SortStats::default();
    let mut reduce_groups: std::collections::BTreeMap<u32, u64> = Default::default();

    for m in 0..mappers {
        let records = record_stream(spill, &profile, &mut rng);
        let keys: Vec<u32> = records.iter().map(|r| r.key).collect();
        // Each spill is striped over a 16-bank sorter (Ns = 64), the
        // paper's best multibank configuration (Fig. 8b).
        let mut sorter = MultiBankSorter::new(MultiBankConfig {
            banks: 16,
            k: 2,
            ..Default::default()
        });
        let out = sorter.sort_with_stats(&keys);
        agg.merge_from(&out.stats);

        // Reducer-side grouping consumes the sorted run.
        for i in &out.order {
            let r = &records[*i];
            *reduce_groups.entry(r.key).or_default() += r.payload_len as u64;
        }
        println!(
            "mapper {m}: {spill} records sorted in {} cycles ({:.2} cyc/num)",
            out.stats.cycles(),
            out.stats.cycles_per_number(spill)
        );
    }

    let total = mappers * spill;
    println!();
    println!("shuffle summary:");
    println!("  records        : {total}");
    println!("  reduce groups  : {}", reduce_groups.len());
    println!("  cycles/number  : {:.2} (baseline 32.00)", agg.cycles() as f64 / total as f64);
    println!("  speedup        : {:.2}x vs [18]", 32.0 * total as f64 / agg.cycles() as f64);
    println!(
        "  est. sort time : {:.1} µs @500MHz across {mappers} banks-groups",
        agg.cycles() as f64 / memsort::params::CLOCK_HZ * 1e6
    );

    // Sanity: group payload mass conservation.
    let mass: u64 = reduce_groups.values().sum();
    assert!(mass > 0);
}

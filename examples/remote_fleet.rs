//! Remote fleet demo: shard hosts served over loopback TCP, a
//! coordinator that dials them through `RemoteTransport`, hedged
//! requests armed, and the wire output checked against an in-process
//! fleet — the zero-to-distributed walkthrough of `rust/OPERATIONS.md`.
//!
//! Run: `cargo run --release --example remote_fleet`
//!
//! Sandboxes without loopback sockets skip gracefully (exit 0 with a
//! note), so CI can always run this example.

use std::net::TcpListener;

use anyhow::Result;
use memsort::coordinator::shard_server::serve_tcp;
use memsort::prelude::*;

fn main() -> Result<()> {
    let svc = ServiceConfig { workers: 2, ..Default::default() };

    // Two shard hosts on OS-assigned loopback ports. In production
    // these are separate processes (`memsort serve --shard --port ...`);
    // here they are threads running the same accept loop.
    let mut addrs = Vec::new();
    for _ in 0..2 {
        let listener = match TcpListener::bind(("127.0.0.1", 0)) {
            Ok(l) => l,
            Err(e) => {
                println!("skipping remote fleet demo: loopback sockets unavailable ({e})");
                return Ok(());
            }
        };
        addrs.push(listener.local_addr()?.to_string());
        let config = svc.clone();
        std::thread::spawn(move || {
            if let Err(e) = serve_tcp(listener, config) {
                eprintln!("shard host exited: {e:#}");
            }
        });
    }
    println!("shard hosts listening on {addrs:?}");

    // Dial the fleet: hedging on (model-derived straggler deadline),
    // the default retry budget bounding failover hops.
    let resilience = ResilienceConfig {
        retry_budget: RetryBudgetConfig::default(),
        hedge: Some(HedgeConfig::default()),
    };
    let transports = addrs
        .iter()
        .map(|a| Ok(Box::new(RemoteTransport::connect_tcp(a)?) as Box<dyn ShardTransport>))
        .collect::<Result<Vec<_>>>()?;
    let fleet = ShardedSortService::with_transports_resilient(
        RoutePolicy::LeastOutstanding,
        resilience,
        transports,
    )?;

    // The same sort on an in-process fleet: the wire must not change a
    // byte (values, argsort, stats — pinned repo-wide by tests).
    let local = ShardedSortService::start(ShardedConfig {
        route: RoutePolicy::LeastOutstanding,
        services: vec![svc.clone(); 2],
        ..Default::default()
    })?;

    let n = 100_000usize;
    let d = Dataset::generate32(DatasetKind::MapReduce, n, 42);
    let cfg = HierarchicalConfig::fixed(1024, 4);
    let t0 = std::time::Instant::now();
    let remote_out = fleet.sort_hierarchical(&d.values, &cfg)?;
    let remote_wall = t0.elapsed();
    let t0 = std::time::Instant::now();
    let local_out = local.sort_hierarchical(&d.values, &cfg)?;
    let local_wall = t0.elapsed();

    assert_eq!(remote_out.hier.output.sorted, local_out.hier.output.sorted);
    assert_eq!(remote_out.hier.output.order, local_out.hier.output.order);
    assert_eq!(remote_out.hier.output.stats, local_out.hier.output.stats);
    println!("byte-identical    : remote == in-process fleet ({n} elements, 98 chunks)");
    println!("chunks/shard      : {:?}", remote_out.shard_chunks);
    println!(
        "host wall         : {:.1} ms over TCP vs {:.1} ms in-process \
         (wire overhead on this machine)",
        remote_wall.as_secs_f64() * 1e3,
        local_wall.as_secs_f64() * 1e3
    );

    let m = fleet.fleet_metrics();
    println!(
        "fleet metrics     : {} jobs, {} errors, imbalance {:.2} \
         (the host's own counters, fetched over the wire)",
        m.completed, m.errors, m.imbalance
    );
    println!(
        "resilience        : {} retries, {} hedges won / {} lost, \
         {} budget-denied, {:.1} tokens left",
        m.retries, m.hedges_won, m.hedges_lost, m.budget_exhausted, m.retry_tokens
    );

    local.shutdown();
    fleet.shutdown(); // sends Shutdown over each link; the hosts exit
    Ok(())
}

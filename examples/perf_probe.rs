//! Profiling probe for the §Perf pass: a tight loop of column-skipping
//! sorts on uniform data (the simulator's worst case — most CRs per
//! element), suitable as a `perf record` / flamegraph target.
//!
//! Run: `cargo build --release --example perf_probe &&
//!       perf record -o perf.data ./target/release/examples/perf_probe`

use memsort::datasets::{Dataset, DatasetKind};
use memsort::sorter::colskip::ColSkipSorter;
use memsort::sorter::InMemorySorter;

fn main() {
    let d = Dataset::generate32(DatasetKind::Uniform, 1024, 42);
    let mut acc = 0u64;
    for _ in 0..2000 {
        let mut s = ColSkipSorter::with_k(2);
        acc += s.sort_with_stats(&d.values).stats.crs;
    }
    println!("{acc}");
}

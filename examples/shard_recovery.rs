//! Shard recovery demo: a heterogeneous fleet loses a host mid-traffic,
//! the cost-aware router isolates it and fails its work over to the
//! survivors, then `recover_shard` restarts the host through its
//! transport and the router warms it back into the rotation.
//!
//! Run: `cargo run --release --example shard_recovery`

use anyhow::Result;
use memsort::prelude::*;

fn fleet_line(fleet: &ShardedSortService, label: &str) {
    let m = fleet.fleet_metrics();
    let served: Vec<u64> = m.shards.iter().map(|s| s.completed).collect();
    println!(
        "  {label:<18}: healthy {}/{}, jobs/shard {:?}, rerouted {}, recovered {}",
        m.healthy.iter().filter(|&&h| h).count(),
        fleet.shard_count(),
        served,
        m.rerouted,
        m.recovered
    );
}

fn main() -> Result<()> {
    let n = 100_000usize;
    let d = Dataset::generate32(DatasetKind::MapReduce, n, 42);
    let mut expect = d.values.clone();
    expect.sort_unstable();

    // A heterogeneous fleet: two full-height hosts and one whose
    // tallest bank is 512 rows — the cost router knows 1024-row chunks
    // are more expensive there (oversize assembly) and deals it fewer.
    let host = |spec: &str| -> anyhow::Result<ServiceConfig> {
        Ok(ServiceConfig { workers: 2, geometry: Geometry::from_spec(spec)?, ..Default::default() })
    };
    let services = vec![host("1024x32")?, host("1024x32")?, host("512x32")?];
    let fleet = ShardedSortService::start(ShardedConfig {
        route: RoutePolicy::Cost,
        services,
        ..Default::default()
    })?;
    let cfg = HierarchicalConfig::fixed(1024, 4);

    println!("heterogeneous fleet (2x 1024-bank + 1x 512-bank, cost routing):");
    let out = fleet.sort_hierarchical(&d.values, &cfg)?;
    assert_eq!(out.hier.output.sorted, expect);
    println!("  chunks/shard      : {:?} (the undersized host carries less)", out.shard_chunks);
    fleet_line(&fleet, "after sort");

    // Crash shard 1. The router isolates it; its share fails over.
    fleet.fail_shard(1)?;
    let out = fleet.sort_hierarchical(&d.values, &cfg)?;
    assert_eq!(out.hier.output.sorted, expect, "degraded fleet still byte-identical");
    println!("after failing shard 1:");
    println!("  chunks/shard      : {:?} (survivors absorb the share)", out.shard_chunks);
    fleet_line(&fleet, "degraded");

    // Recover it: the transport restarts the host (it comes back with
    // empty metrics, like a real restarted process) and the router
    // immediately starts offering it work again.
    fleet.recover_shard(1)?;
    let out = fleet.sort_hierarchical(&d.values, &cfg)?;
    assert_eq!(out.hier.output.sorted, expect, "recovered fleet still byte-identical");
    assert!(out.shard_chunks[1] > 0, "the recovered shard must receive work");
    println!("after recover_shard(1):");
    println!("  chunks/shard      : {:?} (warmed back into rotation)", out.shard_chunks);
    fleet_line(&fleet, "recovered");

    fleet.shutdown();
    Ok(())
}

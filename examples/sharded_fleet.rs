//! Sharded fleet demo: the hierarchical pipeline routed across four
//! independent sort-service hosts, with live fleet metrics and a
//! mid-flight shard failure that the router survives.
//!
//! Run: `cargo run --release --example sharded_fleet`

use anyhow::Result;
use memsort::prelude::*;

fn main() -> Result<()> {
    let n = 200_000usize;
    let d = Dataset::generate32(DatasetKind::MapReduce, n, 42);

    let fleet = ShardedSortService::start(ShardedConfig::uniform(
        4,
        RoutePolicy::RoundRobin,
        ServiceConfig { workers: 2, ..Default::default() },
    ))?;
    let cfg = HierarchicalConfig::fixed(1024, 4);

    let out = fleet.sort_hierarchical(&d.values, &cfg)?;
    let mut expect = d.values.clone();
    expect.sort_unstable();
    assert_eq!(out.hier.output.sorted, expect, "fleet must match std sort");

    println!("sharded sort of {n} MapReduce keys (4 shards, round-robin):");
    println!("  chunks/shard    : {:?}", out.shard_chunks);
    println!(
        "  fleet latency   : {} cycles vs {} single-engine streamed \
         ({:.1}% saved by parallel shard merges)",
        out.sharded_latency_cycles,
        out.hier.streamed_latency_cycles,
        out.fleet_saving() * 100.0
    );
    println!(
        "  barrier model   : {} cycles (one engine, no overlap)",
        out.hier.barrier_latency_cycles
    );

    let m = fleet.fleet_metrics();
    println!(
        "  fleet metrics   : {} jobs over {} shards, imbalance {:.2}, worst p99 {} µs",
        m.completed,
        m.shards.len(),
        m.imbalance,
        m.p99_us
    );

    // Retire a shard the way a crashed host would and sort again: the
    // router isolates it and the survivors absorb its share.
    fleet.fail_shard(2)?;
    let out = fleet.sort_hierarchical(&d.values, &cfg)?;
    assert_eq!(out.hier.output.sorted, expect, "degraded fleet still sorts");
    println!("after failing shard 2:");
    println!("  chunks/shard    : {:?} (shard 2 isolated)", out.shard_chunks);
    println!(
        "  healthy shards  : {}/{}",
        fleet.fleet_metrics().healthy.iter().filter(|&&h| h).count(),
        fleet.shard_count()
    );

    fleet.shutdown();
    Ok(())
}

//! Quickstart: sort an array on the column-skipping in-memory sorter and
//! compare against the HPCA'21 baseline — the paper's Fig. 1/Fig. 3
//! worked example, then a realistic workload.
//!
//! Run: `cargo run --release --example quickstart`

use memsort::prelude::*;

fn main() {
    // --- The paper's worked example: {8, 9, 10}, w = 4, k = 2. ---
    let data = vec![8u32, 9, 10];

    let mut baseline = BaselineSorter::with_width(4);
    let b = baseline.sort_with_stats(&data);
    println!("baseline [18]  : sorted={:?} column reads={}", b.sorted, b.stats.crs);

    let mut colskip = ColSkipSorter::new(ColSkipConfig { width: 4, k: 2, ..Default::default() });
    let c = colskip.sort_with_stats(&data);
    println!("column-skipping: sorted={:?} column reads={}", c.sorted, c.stats.crs);
    assert_eq!(b.stats.crs, 12, "Fig. 1: baseline takes N*w = 12 CRs");
    assert_eq!(c.stats.crs, 7, "Fig. 3: column skipping takes 7 CRs");

    // --- A realistic workload: MapReduce shuffle keys at paper scale. ---
    let d = Dataset::generate32(DatasetKind::MapReduce, 1024, 42);
    let mut sorter = ColSkipSorter::with_k(2);
    let out = sorter.sort_with_stats(&d.values);
    let n = d.values.len();
    println!();
    println!("MapReduce n={n}, w=32, k=2:");
    println!("  cycles/number : {:.2} (baseline: 32.00)", out.stats.cycles_per_number(n));
    println!(
        "  speedup       : {:.2}x (paper reports up to 4.16x)",
        32.0 / out.stats.cycles_per_number(n)
    );
    println!("  throughput    : {:.1} Mnum/s @500MHz", out.stats.throughput(n) / 1e6);

    // --- Cost model: the paper's Fig. 8(a) metrics for this sorter. ---
    let model = CostModel::calibrated();
    let arch = SorterArch::ColSkip { n, w: 32, k: 2 };
    let act = memsort::cost::Activity::from_stats(&out.stats);
    println!("  area          : {:.1} Kµm² (40nm model)", model.area_kum2(arch));
    println!("  power         : {:.1} mW (measured activity)", model.power_mw(arch, act));
    println!(
        "  area eff      : {:.2} Num/ns/mm²",
        model.area_efficiency(arch, out.stats.cycles_per_number(n))
    );
    println!(
        "  energy eff    : {:.1} Num/µJ",
        model.energy_efficiency(arch, out.stats.cycles_per_number(n), act)
    );
}

//! Kruskal's minimum spanning tree with the edge sort running on the
//! in-memory column-skipping sorter — the first motivating application in
//! the paper's §II.A ("all the graph edges need to be sorted from low
//! weight to high weight; majority of the weights are small numbers with
//! frequent repetitions").
//!
//! The argsort output of the sorter (its `order` vector) drives the
//! union–find pass directly, exactly how an accelerator-attached host
//! would consume the sorted index stream.
//!
//! Run: `cargo run --release --example kruskal_mst`

use memsort::datasets::kruskal::{mst_from_sorted, random_graph};
use memsort::datasets::rng::Rng;
use memsort::prelude::*;

fn main() {
    let nodes = 2048;
    let extra = 6144;
    let mut rng = Rng::new(7);
    let edges = random_graph(nodes, extra, &mut rng);
    println!("graph: {} nodes, {} edges", nodes, edges.len());

    // Pad to the sorter bank size (in-memory arrays are fixed-length;
    // real deployments pad with MAX sentinels that sort to the end).
    let mut weights: Vec<u32> = edges.iter().map(|e| e.weight).collect();
    let n_bank = weights.len().next_power_of_two();
    weights.resize(n_bank, u32::MAX);

    let mut sorter = ColSkipSorter::with_k(2);
    let out = sorter.sort_with_stats(&weights);
    println!(
        "in-memory edge sort: {} cycles ({:.2} cycles/number, speedup {:.2}x vs [18])",
        out.stats.cycles(),
        out.stats.cycles_per_number(n_bank),
        32.0 / out.stats.cycles_per_number(n_bank),
    );

    // Drop the sentinel rows, keep the argsort over real edges.
    let order: Vec<usize> = out.order.into_iter().filter(|&r| r < edges.len()).collect();
    let (total, chosen) = mst_from_sorted(nodes, &edges, &order);
    println!("MST: {} edges, total weight {}", chosen.len(), total);
    assert_eq!(chosen.len(), nodes - 1, "spanning tree must have V-1 edges");

    // Cross-check against a conventional CPU sort.
    let mut ref_order: Vec<usize> = (0..edges.len()).collect();
    ref_order.sort_by_key(|&i| edges[i].weight);
    let (ref_total, _) = mst_from_sorted(nodes, &edges, &ref_order);
    assert_eq!(total, ref_total, "in-memory argsort must give the same MST weight");
    println!("cross-check vs std sort: OK (identical MST weight)");
}

//! End-to-end driver (the EXPERIMENTS.md §E2E run): the full three-layer
//! stack serving batched sort requests.
//!
//!   L3  Rust sort service — worker pool, routing, backpressure, metrics
//!   L2  AOT JAX rank pass (scan of the L1 kernel), loaded from
//!       `artifacts/*.hlo.txt` via the PJRT C API
//!   L1  Pallas min-search kernel (interpret-lowered into the artifact)
//!
//! Each request is served by the **hybrid** engine: the PJRT executable
//! computes the sort, the native bit-accurate simulator re-derives it for
//! cross-checking and cycle metering. The run reports service latency and
//! throughput plus the paper's simulated cycles/number — proving all
//! layers compose on a real workload.
//!
//! Requires `make artifacts` (falls back to native engine otherwise).
//!
//! Run: `cargo run --release --example sort_service_e2e`

use memsort::coordinator::{EngineKind, ServiceConfig, SortService};
use memsort::datasets::{Dataset, DatasetKind};

fn main() -> anyhow::Result<()> {
    let n = 1024; // paper-scale arrays (the n=1024 AOT artifact)
    let requests = 48;
    let workers = 4;

    let have_artifacts =
        memsort::runtime::pjrt_ready(memsort::runtime::PjrtEngine::default_dir());
    let engine = if have_artifacts { EngineKind::Hybrid } else { EngineKind::Native };
    if !have_artifacts {
        eprintln!(
            "warning: PJRT unavailable (needs the xla dep + --features pjrt, and \
             `make artifacts`); using native engine"
        );
    }

    let svc = SortService::start(ServiceConfig {
        workers,
        engine,
        ..Default::default()
    })?;

    // Mixed tenant traffic: every dataset family in rotation.
    let batch: Vec<Vec<u32>> = (0..requests)
        .map(|i| {
            let kind = DatasetKind::ALL[i % DatasetKind::ALL.len()];
            Dataset::generate32(kind, n, 1000 + i as u64).values
        })
        .collect();
    let expected: Vec<Vec<u32>> = batch
        .iter()
        .map(|v| {
            let mut s = v.clone();
            s.sort_unstable();
            s
        })
        .collect();

    let t0 = std::time::Instant::now();
    let resps = svc.submit_batch(batch)?;
    let wall = t0.elapsed();

    for (r, e) in resps.iter().zip(&expected) {
        assert_eq!(&r.sorted, e, "request {} returned wrong order", r.id);
    }

    let m = svc.metrics();
    println!("=== sort service e2e ({} engine) ===", engine.name());
    println!("requests        : {} ok / {} errors", m.completed, m.errors);
    println!("elements sorted : {}", m.elements);
    println!("wall time       : {:.1} ms", wall.as_secs_f64() * 1e3);
    println!(
        "service rate    : {:.2} Mnum/s on {workers} workers",
        m.elements as f64 / wall.as_secs_f64() / 1e6
    );
    println!("latency p50     : {} µs", m.p50_us);
    println!("latency p99     : {} µs (first requests pay AOT compile)", m.p99_us);
    println!("sim cyc/num     : {:.2} (baseline 32.00 — mixed datasets)", m.cycles_per_number);
    println!(
        "sim speedup     : {:.2}x vs [18] across the mix",
        32.0 / m.cycles_per_number
    );
    assert_eq!(m.errors, 0);
    svc.shutdown();
    println!("all {requests} responses verified against std sort — stack OK");
    Ok(())
}

//! Hierarchical out-of-bank sorting: a dataset ~100× larger than the
//! paper's length-1024 array, split into bank-sized chunks, sorted
//! concurrently by the service's column-skipping workers, and combined
//! through the 4-way loser-tree merge network.
//!
//! Run: `cargo run --release --example hierarchical_sort`

use anyhow::Result;
use memsort::prelude::*;

fn main() -> Result<()> {
    let n = 100_000usize;
    let d = Dataset::generate32(DatasetKind::MapReduce, n, 42);

    let svc = SortService::start(ServiceConfig { workers: 4, ..Default::default() })?;
    let cfg = HierarchicalConfig::fixed(1024, 4);

    let t0 = std::time::Instant::now();
    let out = svc.sort_hierarchical(&d.values, &cfg)?;
    let wall = t0.elapsed();

    let mut expect = d.values.clone();
    expect.sort_unstable();
    assert_eq!(out.output.sorted, expect, "pipeline must match std sort");

    println!("hierarchical sort of {} MapReduce keys (bank capacity 1024):", n);
    println!("  chunks          : {}", out.chunks());
    println!(
        "  chunk work      : {} CRs + {} drains across all banks",
        out.output.stats.crs, out.output.stats.drains
    );
    println!(
        "  merge stage     : {} passes, {} comparisons, {} cycles (fanout {})",
        out.merge.passes, out.merge.comparisons, out.merge.cycles, out.merge.fanout
    );
    println!(
        "  latency (model) : {} cycles = {:.2} cyc/num ({:.1}% exposed merge)",
        out.latency_cycles,
        out.latency_cycles as f64 / n as f64,
        out.merge_fraction() * 100.0
    );
    println!(
        "  overlap         : streamed {} vs barrier {} cycles ({:.1}% hidden)",
        out.streamed_latency_cycles,
        out.barrier_latency_cycles,
        out.overlap_saving() * 100.0
    );
    println!("  throughput      : {:.1} Mnum/s @500MHz", out.throughput() / 1e6);
    println!("  silicon (model) : {:.0} Kµm², {:.0} mW", out.area_kum2, out.power_mw);
    println!("  host wall       : {:.1} ms", wall.as_secs_f64() * 1e3);

    // The global argsort survives chunking: recover the first few ranks.
    let first: Vec<(usize, u32)> = out
        .output
        .order
        .iter()
        .take(3)
        .map(|&row| (row, d.values[row]))
        .collect();
    println!("  first ranks     : {first:?} (original row, value)");

    svc.shutdown();
    Ok(())
}

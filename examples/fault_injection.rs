//! Device-yield experiment: how stuck-at cell faults in the 1T1R array
//! translate into sorting errors, and what the sense-margin model says
//! about the paper's 10MΩ/100kΩ devices.
//!
//! The paper assumes a pristine array; a deployable in-memory sorter
//! needs a yield story. This example sweeps the cell fault rate, sorts
//! through the faulty banks, and reports (a) how many output positions
//! are wrong and (b) the Kendall-style pairwise disorder those faults
//! induce — plus the analytic sense-amp bit-error rate.
//!
//! Run: `cargo run --release --example fault_injection`

use memsort::datasets::rng::Rng;
use memsort::datasets::{Dataset, DatasetKind};
use memsort::memory::fault::FaultMap;
use memsort::memory::sense::SenseModel;
use memsort::memory::Bank;
use memsort::prelude::*;

fn main() {
    // --- Sense margin of the paper's devices. ---
    let sense = SenseModel::default();
    println!("sense model (paper devices, 10MΩ/100kΩ):");
    println!("  margin         : {:.1} decades of current", sense.margin_decades());
    println!("  per-read BER   : {:.2e} (log-normal σ=25%)", sense.bit_error_rate());
    println!();

    // --- Stuck-at fault sweep. ---
    let n = 1024;
    let d = Dataset::generate32(DatasetKind::Clustered, n, 5);
    let sorter = ColSkipSorter::with_k(2);
    println!("stuck-at sweep on clustered n={n} (w=32), k=2:");
    println!("{:>10} {:>8} {:>12} {:>14}", "fault rate", "faults", "wrong slots", "pair inversions");
    for ber in [0.0, 1e-6, 1e-5, 1e-4, 1e-3] {
        let mut rng = Rng::new(1234);
        let faults = FaultMap::random(n, 32, ber, &mut rng);
        let nfaults = faults.len();
        let mut bank = Bank::load_with_faults(&d.values, 32, faults);
        let out = sorter.sort_bank(&mut bank);

        // The sorter orders the *stored* (faulty) values correctly; the
        // damage is what the faults did to the data. Compare against the
        // pristine sort.
        let mut expect = d.values.clone();
        expect.sort_unstable();
        let wrong = out.sorted.iter().zip(&expect).filter(|(a, b)| a != b).count();
        // Output must still be internally sorted (the circuit is exact
        // over whatever the cells hold).
        assert!(out.sorted.windows(2).all(|w| w[0] <= w[1]), "output not sorted");
        let inversions = count_inversions(&out.sorted, &expect);
        println!("{ber:>10.0e} {nfaults:>8} {wrong:>12} {inversions:>14}");
    }
    println!();
    println!("note: the near-memory circuit sorts the stored bits exactly; every");
    println!("error above is data corruption from stuck cells, bounding the array");
    println!("yield a deployment needs (ECC or remapping below ~1e-5 per cell).");
}

/// Count pairwise disorder between the faulty output and pristine values
/// (both sorted): how many of the faulty entries changed rank bucket.
fn count_inversions(got: &[u32], expect: &[u32]) -> usize {
    // Both are sorted; count multiset symmetric difference / 2 as a rank
    // perturbation proxy.
    let mut i = 0;
    let mut j = 0;
    let mut diff = 0;
    while i < got.len() && j < expect.len() {
        match got[i].cmp(&expect[j]) {
            std::cmp::Ordering::Equal => {
                i += 1;
                j += 1;
            }
            std::cmp::Ordering::Less => {
                i += 1;
                diff += 1;
            }
            std::cmp::Ordering::Greater => {
                j += 1;
                diff += 1;
            }
        }
    }
    (diff + (got.len() - i) + (expect.len() - j)) / 2
}

"""L2 JAX model: the full iterative in-memory sort as a scan over the L1
Pallas min-search kernel.

This is the compute graph the Rust runtime executes through PJRT: given
the stored array, run N min-search iterations (the paper's Fig. 2 outer
loop), retiring the emitted row each time. Outputs per iteration feed the
Rust coordinator's cycle accounting:

  sorted[N]   — the values in ascending order (functional result);
  top_cols[N] — highest informative column of each iteration (what the
                lead register / state controller would latch);
  infos[N]    — number of informative columns (= RE count) per iteration.

The paper's system has no fwd/bwd pair — the "model" is this rank pass;
see DESIGN.md §3 for the adaptation note. Lowered once by `aot.py` to HLO
text; Python never runs at request time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.minsearch import min_search


@functools.partial(jax.jit, static_argnames=("width",))
def minsort(x: jnp.ndarray, width: int = 32):
    """Full in-memory sort of `x` (uint32[N]) via iterative min search.

    Returns (sorted u32[N], top_cols i32[N], infos i32[N]).
    """
    n = x.shape[0]
    x = x.astype(jnp.uint32)

    def body(alive, _):
        onehot, value, stats = min_search(x, alive, width=width)
        alive = alive * (jnp.uint32(1) - onehot)
        return alive, (value[0], stats[1], stats[0])

    alive0 = jnp.ones((n,), jnp.uint32)
    _, (vals, tops, infos) = jax.lax.scan(body, alive0, None, length=n)
    return vals, tops, infos


def example_args(n: int, width: int = 32):
    """Shape-only example arguments for AOT lowering."""
    del width
    return (jax.ShapeDtypeStruct((n,), jnp.uint32),)

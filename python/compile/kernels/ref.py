"""Pure-jnp oracle for the in-memory min-search compute (L1 reference).

This is the *digital contract* of the 1T1R array + sense amps during one
min-search iteration of the paper (§II.B): traverse bit columns MSB→LSB;
a column restricted to the active rows that is neither all-0s nor all-1s
("informative") excludes the rows that read 1; after the full traversal
the surviving rows hold the minimum of the active set.

The Pallas kernel (`minsearch.py`) must match these functions bit-exactly
for every shape/width the tests sweep (pytest + hypothesis). The Rust
simulator implements the same contract over real bank state; the
integration tests close the triangle rust == pallas == ref.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def min_search_ref(x: jnp.ndarray, alive: jnp.ndarray, width: int):
    """One min-search iteration over the active rows (pure jnp).

    Args:
      x: uint32[N] stored values.
      alive: uint32[N] 0/1 mask of rows still in the array.
      width: bit width w of the stored values.

    Returns:
      (min_onehot, min_value, informative_count, top_informative_col)
      - min_onehot: uint32[N], 1 only at the first (lowest-index) row
        holding the minimum among alive rows (the hardware priority
        encoder's pick); all-zero if no row is alive.
      - min_value: uint32[] the minimum value (0 if none alive).
      - informative_count: int32[] number of informative columns seen.
      - top_informative_col: int32[] highest informative column (-1 if
        none) — the quantity the lead register latches.
    """
    x = x.astype(jnp.uint32)
    active = alive.astype(jnp.uint32)
    n = x.shape[0]
    info_count = jnp.int32(0)
    top_col = jnp.int32(-1)
    for j in range(width - 1, -1, -1):
        col = (x >> jnp.uint32(j)) & jnp.uint32(1)
        ones = active * col
        zeros = active * (jnp.uint32(1) - col)
        informative = (ones.sum() > 0) & (zeros.sum() > 0)
        active = jnp.where(informative, zeros, active)
        info_count = info_count + informative.astype(jnp.int32)
        top_col = jnp.where(informative & (top_col < 0), jnp.int32(j), top_col)
    # Priority encode the first surviving row.
    idx = jnp.arange(n)
    any_alive = (active.sum() > 0).astype(jnp.uint32)
    first = jnp.min(jnp.where(active > 0, idx, n))
    min_onehot = (idx == first).astype(jnp.uint32) * any_alive
    min_value = (x * min_onehot).sum().astype(jnp.uint32)
    return min_onehot, min_value, info_count, top_col


def sort_ref(x: jnp.ndarray, width: int):
    """Full iterative in-memory sort (pure jnp, python loop).

    Returns (sorted_values, top_cols, info_counts) — the same outputs as
    the AOT model in `model.py`.
    """
    n = x.shape[0]
    alive = jnp.ones((n,), jnp.uint32)
    out_vals, out_tops, out_infos = [], [], []
    for _ in range(n):
        onehot, val, info, top = min_search_ref(x, alive, width)
        out_vals.append(val)
        out_tops.append(top)
        out_infos.append(info)
        alive = alive * (jnp.uint32(1) - onehot)
    return jnp.stack(out_vals), jnp.stack(out_tops), jnp.stack(out_infos)


def min_search_numpy(x: np.ndarray, alive: np.ndarray, width: int):
    """Plain-numpy double check of `min_search_ref` (no jax at all)."""
    active = alive.astype(np.uint64).copy()
    xs = x.astype(np.uint64)
    info_count = 0
    top_col = -1
    for j in range(width - 1, -1, -1):
        col = (xs >> j) & 1
        ones = active * col
        zeros = active * (1 - col)
        if ones.sum() > 0 and zeros.sum() > 0:
            active = zeros
            info_count += 1
            if top_col < 0:
                top_col = j
    onehot = np.zeros_like(active)
    nz = np.nonzero(active)[0]
    min_value = 0
    if len(nz) > 0:
        onehot[nz[0]] = 1
        min_value = int(xs[nz[0]])
    return onehot.astype(np.uint32), np.uint32(min_value), info_count, top_col

"""L1 Pallas kernel: one in-memory min-search iteration.

The 1T1R crossbar's analog compute — sense every select line of one bit
column at once, judge all-0s/all-1s, exclude — is a column-parallel
reduction. On TPU terms (see DESIGN.md §Hardware-Adaptation): each column
read is a width-N elementwise mask op on the VPU; the w-step MSB→LSB
traversal is a sequential `fori_loop` whose carry (the active mask) is
the wordline register. Rows are tiled into VMEM via the BlockSpec below;
the bit-plane dimension stays inside the kernel, mirroring how the sense
amps + row controller iterate columns against a resident array.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret-mode lowers the kernel into plain HLO ops so
the AOT artifact runs on the Rust `xla`-crate client (see
/opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _min_search_kernel(x_ref, alive_ref, onehot_ref, value_ref, stats_ref, *, width: int):
    """Pallas kernel body: bit traversal over the resident block.

    Outputs:
      onehot_ref: uint32[N] one-hot of the emitted (first) min row.
      value_ref: uint32[1] the min value.
      stats_ref: int32[2] = [informative_count, top_informative_col].
    """
    x = x_ref[...]
    alive = alive_ref[...]
    n = x.shape[0]

    def step(i, carry):
        active, info_count, top_col = carry
        j = jnp.uint32(width - 1) - jnp.uint32(i)
        col = (x >> j) & jnp.uint32(1)
        ones = active * col
        zeros = active * (jnp.uint32(1) - col)
        informative = (jnp.sum(ones) > 0) & (jnp.sum(zeros) > 0)
        active = jnp.where(informative, zeros, active)
        info_count = info_count + informative.astype(jnp.int32)
        top_col = jnp.where(
            informative & (top_col < 0), j.astype(jnp.int32), top_col
        )
        return active, info_count, top_col

    active0 = alive.astype(jnp.uint32)
    active, info_count, top_col = jax.lax.fori_loop(
        0, width, step, (active0, jnp.int32(0), jnp.int32(-1))
    )

    # Priority encoder: first surviving row wins (hardware row mux).
    idx = jax.lax.iota(jnp.int32, n)
    any_alive = (jnp.sum(active) > 0).astype(jnp.uint32)
    first = jnp.min(jnp.where(active > 0, idx, jnp.int32(n)))
    onehot = (idx == first).astype(jnp.uint32) * any_alive
    onehot_ref[...] = onehot
    value_ref[...] = jnp.sum(x * onehot, keepdims=True).astype(jnp.uint32)
    stats_ref[...] = jnp.stack([info_count, top_col])


@functools.partial(jax.jit, static_argnames=("width",))
def min_search(x: jnp.ndarray, alive: jnp.ndarray, width: int = 32):
    """One min-search iteration as a Pallas call (interpret mode).

    Returns (min_onehot u32[N], min_value u32[1], stats i32[2]).
    """
    n = x.shape[0]
    kernel = functools.partial(_min_search_kernel, width=width)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.uint32),
            jax.ShapeDtypeStruct((2,), jnp.int32),
        ),
        interpret=True,
    )(x.astype(jnp.uint32), alive.astype(jnp.uint32))

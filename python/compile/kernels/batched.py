"""L1 Pallas kernel, batched variant: min-search over a batch of arrays.

This is the compute-path analogue of the paper's multi-bank operation: a
`(B, N)` block of stored arrays is tiled over a Pallas **grid** along the
batch dimension — one program instance per bank — with `BlockSpec`
carving the `(1, N)` VMEM-resident row block each instance works on.
On TPU this is exactly the HBM→VMEM schedule the multi-bank manager
implements spatially; under `interpret=True` it lowers to plain HLO that
the Rust PJRT client can run.

Used by `model.minsort_batched` (the batched rank pass) and swept by
hypothesis in `tests/test_batched.py`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _batched_kernel(x_ref, alive_ref, onehot_ref, value_ref, *, width: int):
    """One grid instance = one bank's min search (block shapes (1, N))."""
    x = x_ref[0, :]
    alive = alive_ref[0, :]
    n = x.shape[0]

    def step(i, active):
        j = jnp.uint32(width - 1) - jnp.uint32(i)
        col = (x >> j) & jnp.uint32(1)
        ones = active * col
        zeros = active * (jnp.uint32(1) - col)
        informative = (jnp.sum(ones) > 0) & (jnp.sum(zeros) > 0)
        return jnp.where(informative, zeros, active)

    active = jax.lax.fori_loop(0, width, step, alive.astype(jnp.uint32))
    idx = jax.lax.iota(jnp.int32, n)
    any_alive = (jnp.sum(active) > 0).astype(jnp.uint32)
    first = jnp.min(jnp.where(active > 0, idx, jnp.int32(n)))
    onehot = (idx == first).astype(jnp.uint32) * any_alive
    onehot_ref[0, :] = onehot
    value_ref[0, 0] = jnp.sum(x * onehot).astype(jnp.uint32)


@functools.partial(jax.jit, static_argnames=("width",))
def batched_min_search(x: jnp.ndarray, alive: jnp.ndarray, width: int = 32):
    """Min search over a batch: x, alive are uint32[B, N].

    Returns (onehot u32[B, N], values u32[B, 1]).
    """
    b, n = x.shape
    kernel = functools.partial(_batched_kernel, width=width)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=(
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (i, 0)),
        ),
        out_specs=(
            pl.BlockSpec((1, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((b, n), jnp.uint32),
            jax.ShapeDtypeStruct((b, 1), jnp.uint32),
        ),
        interpret=True,
    )(x.astype(jnp.uint32), alive.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("width",))
def minsort_batched(x: jnp.ndarray, width: int = 32):
    """Full rank pass over a batch of arrays: x uint32[B, N] → sorted[B, N]."""
    b, n = x.shape
    x = x.astype(jnp.uint32)

    def body(alive, _):
        onehot, values = batched_min_search(x, alive, width=width)
        alive = alive * (jnp.uint32(1) - onehot)
        return alive, values[:, 0]

    alive0 = jnp.ones((b, n), jnp.uint32)
    _, vals = jax.lax.scan(body, alive0, None, length=n)
    return vals.T  # [B, N]

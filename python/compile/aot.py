"""AOT bridge: lower the L2 model to HLO *text* for the Rust runtime.

HLO text — not `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids, which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot [--out-dir ../artifacts] [--sizes 16,64,256,1024]

Emits one artifact per array-size variant:
    artifacts/minsort_n{N}_w{W}.hlo.txt
plus a manifest (artifacts/manifest.txt) the Rust runtime consults.

Python runs ONCE at build time (`make artifacts`); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import example_args, minsort

DEFAULT_SIZES = (16, 64, 256, 1024)
DEFAULT_WIDTH = 32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_minsort(n: int, width: int = DEFAULT_WIDTH) -> str:
    """Lower the length-`n` sort variant to HLO text."""
    lowered = jax.jit(lambda x: minsort(x, width=width)).lower(*example_args(n, width))
    return to_hlo_text(lowered)


def artifact_name(n: int, width: int = DEFAULT_WIDTH) -> str:
    return f"minsort_n{n}_w{width}.hlo.txt"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--sizes", default=",".join(str(s) for s in DEFAULT_SIZES))
    ap.add_argument("--width", type=int, default=DEFAULT_WIDTH)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    sizes = [int(s) for s in args.sizes.split(",") if s]

    manifest_lines = []
    for n in sizes:
        text = lower_minsort(n, args.width)
        name = artifact_name(n, args.width)
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} n={n} w={args.width}")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(sizes)} variants")


if __name__ == "__main__":
    main()

"""L1 correctness: Pallas min-search kernel vs the pure-jnp/numpy oracles.

This is the CORE correctness signal of the compile path: hypothesis
sweeps array lengths, bit widths, value distributions and alive-mask
patterns; every output (one-hot, value, informative count, top column)
must match the reference bit-exactly.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.minsearch import min_search
from compile.kernels.ref import min_search_numpy, min_search_ref


def _check_case(values, alive, width):
    x = jnp.asarray(values, jnp.uint32)
    a = jnp.asarray(alive, jnp.uint32)
    oh_k, val_k, stats_k = min_search(x, a, width=width)
    oh_r, val_r, info_r, top_r = min_search_ref(x, a, width)
    np.testing.assert_array_equal(np.asarray(oh_k), np.asarray(oh_r))
    assert int(val_k[0]) == int(val_r)
    assert int(stats_k[0]) == int(info_r)
    assert int(stats_k[1]) == int(top_r)
    # Triangle check: jnp ref vs plain numpy ref.
    oh_n, val_n, info_n, top_n = min_search_numpy(
        np.asarray(values, np.uint32), np.asarray(alive, np.uint32), width
    )
    np.testing.assert_array_equal(np.asarray(oh_r), oh_n)
    assert int(val_r) == int(val_n)
    assert int(info_r) == info_n
    assert int(top_r) == top_n


@st.composite
def cases(draw):
    width = draw(st.integers(min_value=1, max_value=32))
    n = draw(st.integers(min_value=1, max_value=48))
    max_val = (1 << width) - 1
    mode = draw(st.integers(min_value=0, max_value=2))
    if mode == 0:  # uniform over the width
        values = draw(
            st.lists(st.integers(0, max_val), min_size=n, max_size=n)
        )
    elif mode == 1:  # heavy duplicates from a small pool
        pool = draw(st.lists(st.integers(0, max_val), min_size=1, max_size=4))
        values = [pool[draw(st.integers(0, len(pool) - 1))] for _ in range(n)]
    else:  # small values (leading zeros)
        values = draw(
            st.lists(st.integers(0, min(15, max_val)), min_size=n, max_size=n)
        )
    alive = draw(st.lists(st.integers(0, 1), min_size=n, max_size=n))
    return values, alive, width


@settings(max_examples=150, deadline=None)
@given(cases())
def test_kernel_matches_ref_hypothesis(case):
    values, alive, width = case
    _check_case(values, alive, width)


def test_paper_fig1_first_iteration():
    # {8,9,10} at w=4: min is 8 (row 0); columns 1 and 0 are informative,
    # the top informative column is 1.
    _check_case([8, 9, 10], [1, 1, 1], 4)
    oh, val, stats = min_search(
        jnp.array([8, 9, 10], jnp.uint32), jnp.ones(3, jnp.uint32), width=4
    )
    assert list(np.asarray(oh)) == [1, 0, 0]
    assert int(val[0]) == 8
    assert int(stats[0]) == 2 and int(stats[1]) == 1


def test_no_alive_rows():
    oh, val, stats = min_search(
        jnp.array([5, 6], jnp.uint32), jnp.zeros(2, jnp.uint32), width=8
    )
    assert list(np.asarray(oh)) == [0, 0]
    assert int(val[0]) == 0
    assert int(stats[0]) == 0 and int(stats[1]) == -1


def test_single_alive_row():
    oh, val, stats = min_search(
        jnp.array([123, 45, 67], jnp.uint32),
        jnp.array([0, 0, 1], jnp.uint32),
        width=8,
    )
    assert list(np.asarray(oh)) == [0, 0, 1]
    assert int(val[0]) == 67
    assert int(stats[0]) == 0  # nothing informative with one row


def test_all_equal_rows_pick_first():
    oh, val, stats = min_search(
        jnp.full((8,), 42, jnp.uint32), jnp.ones(8, jnp.uint32), width=8
    )
    assert list(np.asarray(oh)) == [1, 0, 0, 0, 0, 0, 0, 0]
    assert int(val[0]) == 42
    assert int(stats[0]) == 0


def test_full_width_extremes():
    _check_case([0xFFFFFFFF, 0, 0x80000000, 1], [1, 1, 1, 1], 32)


@pytest.mark.parametrize("width", [1, 2, 7, 8, 16, 31, 32])
def test_width_sweep_duplicate_min(width):
    max_val = (1 << width) - 1
    values = [max_val, 0, max_val // 2, 0]
    _check_case(values, [1, 1, 1, 1], width)
    # Duplicate minimum: priority encoder must pick row 1 (first zero).
    oh, _, _ = min_search(
        jnp.asarray(values, jnp.uint32), jnp.ones(4, jnp.uint32), width=width
    )
    assert list(np.asarray(oh)) == [0, 1, 0, 0]

"""AOT path: lowering must produce loadable HLO text whose executable
reproduces the model's numerics through the same PJRT stack the Rust
runtime uses."""

import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile.aot import artifact_name, lower_minsort, to_hlo_text
from compile.model import minsort


def test_hlo_text_structure():
    text = lower_minsort(8, 16)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Input parameter is a u32[8]; tuple output (return_tuple=True).
    assert "u32[8]" in text
    assert "(u32[8]" in text or "tuple" in text.lower()


def test_artifact_naming():
    assert artifact_name(1024, 32) == "minsort_n1024_w32.hlo.txt"
    assert artifact_name(64, 16) == "minsort_n64_w16.hlo.txt"


def test_hlo_text_parses_back():
    """The emitted text must be parseable as an HloModule — the same
    parser family the Rust runtime's `HloModuleProto::from_text_file`
    uses. (The full text → compile → execute round-trip is covered on the
    Rust side in `rust/tests/pjrt_roundtrip.rs`, since jaxlib 0.8's
    Client.compile no longer accepts XlaComputation directly.)"""
    n, width = 8, 16
    text = lower_minsort(n, width)
    mod = xc._xla.hlo_module_from_text(text)
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 100


def test_stablehlo_executes_and_matches_model():
    """Compile the same lowered module through PJRT and compare numerics
    with the jit path — proves the AOT artifact computes the rank pass."""
    import jax

    from compile.model import example_args

    n, width = 8, 16
    lowered = jax.jit(lambda x: minsort(x, width=width)).lower(*example_args(n, width))
    compiled = lowered.compile()
    x = np.array([300, 5, 5, 0, 65535, 77, 1024, 2], np.uint32)
    got_sorted, got_tops, got_infos = compiled(jnp.asarray(x))
    vals, tops, infos = minsort(jnp.asarray(x), width=width)
    np.testing.assert_array_equal(np.asarray(got_sorted), np.asarray(vals))
    np.testing.assert_array_equal(np.asarray(got_tops), np.asarray(tops))
    np.testing.assert_array_equal(np.asarray(got_infos), np.asarray(infos))


def test_cli_writes_artifacts(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--sizes", "4,8"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr
    assert (tmp_path / "minsort_n4_w32.hlo.txt").exists()
    assert (tmp_path / "minsort_n8_w32.hlo.txt").exists()
    manifest = (tmp_path / "manifest.txt").read_text()
    assert "minsort_n4_w32.hlo.txt n=4 w=32" in manifest


@pytest.mark.parametrize("n", [4, 16])
def test_lowering_is_deterministic(n):
    a = lower_minsort(n, 32)
    b = lower_minsort(n, 32)
    assert a == b

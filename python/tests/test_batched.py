"""Batched L1 kernel: grid/BlockSpec variant vs the single-array kernel
and numpy."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.batched import batched_min_search, minsort_batched
from compile.kernels.minsearch import min_search


@st.composite
def batches(draw):
    width = draw(st.sampled_from([4, 8, 16, 32]))
    b = draw(st.integers(min_value=1, max_value=4))
    n = draw(st.integers(min_value=1, max_value=16))
    max_val = (1 << width) - 1
    vals = [
        draw(st.lists(st.integers(0, max_val), min_size=n, max_size=n))
        for _ in range(b)
    ]
    return vals, width


@settings(max_examples=40, deadline=None)
@given(batches())
def test_batched_matches_single_kernel(case):
    vals, width = case
    x = jnp.asarray(vals, jnp.uint32)
    alive = jnp.ones_like(x)
    oh_b, val_b = batched_min_search(x, alive, width=width)
    for i in range(x.shape[0]):
        oh_s, val_s, _ = min_search(x[i], alive[i], width=width)
        np.testing.assert_array_equal(np.asarray(oh_b[i]), np.asarray(oh_s))
        assert int(val_b[i, 0]) == int(val_s[0])


@settings(max_examples=15, deadline=None)
@given(batches())
def test_minsort_batched_matches_numpy(case):
    vals, width = case
    x = jnp.asarray(vals, jnp.uint32)
    got = minsort_batched(x, width=width)
    np.testing.assert_array_equal(
        np.asarray(got), np.sort(np.asarray(vals, np.uint32), axis=1)
    )


def test_batched_respects_alive_masks_per_bank():
    x = jnp.asarray([[9, 1, 5], [3, 7, 2]], jnp.uint32)
    alive = jnp.asarray([[1, 0, 1], [0, 1, 1]], jnp.uint32)
    oh, vals = batched_min_search(x, alive, width=4)
    # Bank 0: min over {9, 5} = 5 (row 2); bank 1: min over {7, 2} = 2.
    assert list(np.asarray(oh[0])) == [0, 0, 1]
    assert list(np.asarray(oh[1])) == [0, 0, 1]
    assert int(vals[0, 0]) == 5
    assert int(vals[1, 0]) == 2


def test_batched_grid_of_one():
    x = jnp.asarray([[4, 4, 4, 0]], jnp.uint32)
    oh, vals = batched_min_search(x, jnp.ones_like(x), width=4)
    assert list(np.asarray(oh[0])) == [0, 0, 0, 1]
    assert int(vals[0, 0]) == 0

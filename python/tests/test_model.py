"""L2 correctness: the scan-based minsort model vs numpy sort and the
pure-jnp reference sorter."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import sort_ref
from compile.model import minsort


@st.composite
def arrays(draw):
    width = draw(st.sampled_from([4, 8, 16, 32]))
    n = draw(st.integers(min_value=1, max_value=24))
    max_val = (1 << width) - 1
    values = draw(st.lists(st.integers(0, max_val), min_size=n, max_size=n))
    return values, width


@settings(max_examples=40, deadline=None)
@given(arrays())
def test_minsort_matches_numpy(case):
    values, width = case
    vals, _, _ = minsort(jnp.asarray(values, jnp.uint32), width=width)
    np.testing.assert_array_equal(
        np.asarray(vals), np.sort(np.asarray(values, np.uint32))
    )


@settings(max_examples=15, deadline=None)
@given(arrays())
def test_minsort_matches_ref_sorter_exactly(case):
    values, width = case
    x = jnp.asarray(values, jnp.uint32)
    vals_m, tops_m, infos_m = minsort(x, width=width)
    vals_r, tops_r, infos_r = sort_ref(x, width)
    np.testing.assert_array_equal(np.asarray(vals_m), np.asarray(vals_r))
    np.testing.assert_array_equal(np.asarray(tops_m), np.asarray(tops_r))
    np.testing.assert_array_equal(np.asarray(infos_m), np.asarray(infos_r))


def test_paper_example_sort_and_traces():
    vals, tops, infos = minsort(jnp.array([8, 9, 10], jnp.uint32), width=4)
    assert list(np.asarray(vals)) == [8, 9, 10]
    # Iteration traces: {8,9,10} → top informative col 1, 2 REs;
    # {9,10} → top col 1, 1 RE; {10} → nothing informative.
    assert list(np.asarray(tops)) == [1, 1, -1]
    assert list(np.asarray(infos)) == [2, 1, 0]


def test_duplicates_all_emitted():
    x = jnp.array([7, 7, 7, 3, 3], jnp.uint32)
    vals, _, infos = minsort(x, width=4)
    assert list(np.asarray(vals)) == [3, 3, 7, 7, 7]
    # Once only duplicates remain, no column is informative.
    assert int(np.asarray(infos)[-1]) == 0


def test_full_width_values():
    x = jnp.array([0xFFFFFFFF, 0, 0x80000000], jnp.uint32)
    vals, _, _ = minsort(x, width=32)
    assert list(np.asarray(vals)) == [0, 0x80000000, 0xFFFFFFFF]

"""memlint fixture corpus: one deliberately broken snippet per rule
family proves each rule actually fires (a linter whose rules can't fail
is decoration), and the clean-repo test proves the gate is green on the
tree as committed — the same invocation CI runs.

Runnable standalone (`python3 python/tests/test_memlint.py`) or under
pytest; no jax/hypothesis needed.
"""

import sys
import tempfile
from pathlib import Path

REPO = Path(__file__).resolve().parents[2]
sys.path.insert(0, str(REPO / "python"))

from memlint import (  # noqa: E402
    run_all,
    rules_docs,
    rules_locks,
    rules_mirror,
    rules_panic,
    rules_wire,
)
from memlint.findings import Allowlist, Finding, apply_allowlist  # noqa: E402
from memlint.rustlex import index_tree  # noqa: E402


def write(root: Path, rel: str, text: str) -> None:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text, encoding="utf-8")


def keys(findings):
    return {f.key for f in findings}


# -- rule family 2: a forbidden unwrap on a serving path ---------------

PANICKY_SERVER = """
impl ShardServer {
    fn serve_conn(&self) {
        let job = self.queue.pop().unwrap();
    }

    fn helper_off_path(&self) {
        let fine = self.queue.pop().unwrap();
    }
}
"""


def test_panic_rule_fires_on_a_serving_path_unwrap(tmp_path):
    write(tmp_path, "rust/src/coordinator/shard_server.rs", PANICKY_SERVER)
    findings, inventory = rules_panic.run(tmp_path, index_tree(tmp_path))
    assert "serve_conn:unwrap@0" in keys(findings)
    # The off-path helper is inventory, never a finding.
    assert not any(f.key.startswith("helper_off_path") for f in findings)
    assert inventory["total"] == 2 and inventory["serving"] == 1


# -- rule family 2, spill scope: the whole spill tier is serving path --

PANICKY_SPILL = """
impl RunReader {
    fn advance(&mut self) {
        let block = self.blocks.pop().unwrap();
    }
}

fn write_run(store: &dyn RunStore) {
    let total = store.run_len(0).expect("run exists");
}
"""


def test_panic_rule_covers_the_whole_spill_module(tmp_path):
    write(tmp_path, "rust/src/sorter/spill.rs", PANICKY_SPILL)
    findings, inventory = rules_panic.run(tmp_path, index_tree(tmp_path))
    # "*" scope: every non-test fn in spill.rs is a serving path.
    assert "advance:unwrap@0" in keys(findings)
    assert "write_run:expect@0" in keys(findings)
    assert inventory["serving"] == 2


# -- rule family 3: an out-of-order nested lock pair -------------------

LOCK_DESIGN = """# fixture

<!-- memlint:lock-order
alpha
beta
-->
"""

TANGLED = """
fn tangle(s: &S) {
    let gb = s.beta.lock().unwrap();
    let ga = s.alpha.lock().unwrap();
    drop(ga);
    drop(gb);
}
"""


def test_lock_rule_fires_on_an_out_of_order_pair(tmp_path):
    write(tmp_path, "rust/DESIGN.md", LOCK_DESIGN)
    write(tmp_path, "rust/src/coordinator/tangle.rs", TANGLED)
    findings, _ = rules_locks.run(
        tmp_path, index_tree(tmp_path), tmp_path / "rust/DESIGN.md"
    )
    assert "tangle:beta->alpha" in keys(findings)


# -- rule family 3, spill scope: the run-store lock is in scope too ----

SPILL_LOCK_DESIGN = """# fixture

<!-- memlint:lock-order
spill_runs
-->
"""

GUARDED_SPILL_IO = """
impl TempDirRunStore {
    fn append(&self, bytes: &[u8]) {
        let runs = self.spill_runs.lock().unwrap();
        self.file.write_all(bytes);
    }

    fn rotate(&self) {
        let g = self.undeclared_map.lock().unwrap();
        drop(g);
    }
}
"""


def test_lock_rule_scans_the_spill_tier(tmp_path):
    write(tmp_path, "rust/DESIGN.md", SPILL_LOCK_DESIGN)
    write(tmp_path, "rust/src/sorter/spill.rs", GUARDED_SPILL_IO)
    findings, summary = rules_locks.run(
        tmp_path, index_tree(tmp_path), tmp_path / "rust/DESIGN.md"
    )
    # A run-map guard held across file I/O stalls every spilling sort.
    assert "append:spill_runs->write_all" in keys(findings)
    # And spill locks must be declared in the canonical order.
    assert "undeclared:undeclared_map" in keys(findings)
    assert summary["sites"] == 2


def test_lock_rule_still_skips_non_coordinator_non_spill_files(tmp_path):
    write(tmp_path, "rust/DESIGN.md", SPILL_LOCK_DESIGN)
    write(tmp_path, "rust/src/sorter/merge.rs", GUARDED_SPILL_IO)
    findings, summary = rules_locks.run(
        tmp_path, index_tree(tmp_path), tmp_path / "rust/DESIGN.md"
    )
    assert findings == []
    assert summary["sites"] == 0


# -- rule family 1: a min-version stamp that drifted from the doc ------

FIXTURE_WIRE = """
pub const WIRE_VERSION: u8 = 2;
pub const MIN_WIRE_VERSION: u8 = 1;

pub enum Frame {
    Hello,
    SortJob(Vec<u32>),
    SortJobTagged(JobTag, Vec<u32>),
}

impl Frame {
    pub fn kind(&self) -> u8 {
        match self {
            Frame::Hello => 0,
            Frame::SortJob(_) => 1,
            Frame::SortJobTagged(..) => 2,
        }
    }

    pub fn wire_version(&self) -> u8 {
        match self {
            Frame::Hello => WIRE_VERSION,
            Frame::SortJobTagged(..) => 2,
            _ => MIN_WIRE_VERSION,
        }
    }
}

fn decode(k: u8) -> Frame {
    match k {
        0 => Frame::Hello,
        1 => Frame::SortJob(v),
        2 => Frame::SortJobTagged(t, v),
        _ => unknown,
    }
}
"""

# SortJobTagged is stamped min ver 1 here, but wire_version() above
# says 2 — the exact drift the rule exists to catch.
FIXTURE_OPS = """# fixture wire doc

Version `2` (minimum accepted: `1`).

<!-- memlint:wire-table -->

| kind | frame | min ver |
|------|-------|---------|
| 0 | Hello | cur |
| 1 | SortJob | 1 |
| 2 | SortJobTagged | 1 |
"""


def test_wire_rule_fires_on_a_wrong_min_version_stamp(tmp_path):
    write(tmp_path, "rust/src/coordinator/wire.rs", FIXTURE_WIRE)
    write(tmp_path, "rust/OPERATIONS.md", FIXTURE_OPS)
    findings, _ = rules_wire.run(tmp_path, index_tree(tmp_path))
    assert "table-minver:SortJobTagged" in keys(findings)
    # The correctly-stamped rows don't fire.
    assert "table-minver:Hello" not in keys(findings)
    assert "table-minver:SortJob" not in keys(findings)


# -- rule family 4: a doc citing a symbol that doesn't exist -----------


def test_doc_rule_fires_on_a_dangling_symbol(tmp_path):
    write(
        tmp_path,
        "rust/DESIGN.md",
        "The loop calls `definitely_not_a_fn()`, then `real_fn()`.\n",
    )
    write(tmp_path, "rust/src/lib.rs", "pub fn real_fn() {}\n")
    write(tmp_path, "python/placeholder.py", "")
    findings, _ = rules_docs.run(tmp_path, index_tree(tmp_path))
    assert "definitely_not_a_fn()" in keys(findings)
    assert "real_fn()" not in keys(findings)


# -- rule family 5: a model fn with no pinned python mirror ------------


def test_mirror_rule_fires_on_an_unmapped_model_fn(tmp_path):
    write(
        tmp_path,
        "rust/src/coordinator/planner/schedule.rs",
        "pub fn stray_model(x: f64) -> f64 {\n    x * 2.0\n}\n",
    )
    write(tmp_path, "python/fleet_model.py", "def pin(g, w, t):\n    pass\n")
    map_path = tmp_path / "mirror_map.json"
    map_path.write_text("{}", encoding="utf-8")
    findings, _ = rules_mirror.run(tmp_path, index_tree(tmp_path), map_path)
    assert "unmapped:stray_model" in keys(findings)


# -- allowlist hygiene: stale entries are failures, not silence --------


def test_stale_allowlist_entry_is_a_note(tmp_path):
    allow_path = tmp_path / "allow.json"
    allow_path.write_text(
        '[{"rule": "panic-path", "file": "gone.rs", "key": "x:unwrap@0",'
        ' "justification": "used to matter"}]',
        encoding="utf-8",
    )
    allow = Allowlist.load(allow_path)
    kept, notes = apply_allowlist([], allow)
    assert kept == []
    assert notes, "an entry that suppresses nothing must surface as stale"


def test_allowlist_suppresses_exactly_its_key(tmp_path):
    allow_path = tmp_path / "allow.json"
    allow_path.write_text(
        '[{"rule": "panic-path", "file": "a.rs", "key": "f:unwrap@0",'
        ' "justification": "proven"}]',
        encoding="utf-8",
    )
    allow = Allowlist.load(allow_path)
    hit = Finding("panic-path", "a.rs", 3, "f:unwrap@0", "m")
    miss = Finding("panic-path", "a.rs", 9, "f:unwrap@1", "m")
    kept, notes = apply_allowlist([hit, miss], allow)
    assert kept == [miss] and notes == []


# -- the repo itself: the gate is green as committed -------------------


def test_clean_repo_has_zero_findings():
    findings, notes, summaries = run_all(REPO)
    assert findings == [], [f.render() for f in findings]
    assert notes == [], notes
    # The rules did real work, not vacuous passes.
    assert summaries["wire-registry"]["kinds"] >= 15
    assert summaries["panic-path"]["total"] > 0
    assert summaries["lock-order"]["sites"] > 0
    assert summaries["mirror-coverage"]["rust_fns"] >= 10


if __name__ == "__main__":
    failures = 0
    for name, fn in sorted(globals().items()):
        if not name.startswith("test_") or not callable(fn):
            continue
        try:
            if fn.__code__.co_argcount:
                with tempfile.TemporaryDirectory() as td:
                    fn(Path(td))
            else:
                fn()
            print(f"ok   {name}")
        except AssertionError as exc:
            failures += 1
            print(f"FAIL {name}: {exc}")
    sys.exit(1 if failures else 0)

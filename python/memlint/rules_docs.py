"""Rule family 4 — doc-symbol drift.

Every code symbol named in DESIGN.md / OPERATIONS.md / EXPERIMENTS.md
must resolve to something that still exists: a Rust item (fn, struct,
enum, variant, field, const, trait, mod, macro), a Python def/class in
`python/`, or a file in the repo. Docs that cite `frontend::try_admit`
or `MAX_SORT_ELEMS` keep readers honest only while those names are
real; after a rename the stale reference is drift exactly like a wrong
wire table.

What counts as a symbol reference (inline code spans only; fenced
blocks are stripped first):

* a `::`-path (`coordinator::wire::read_frame`) — every segment must
  resolve (std/core/alloc paths are exempt);
* a call form `name()`;
* a SCREAMING_CASE constant of length ≥ 4;
* a snake_case identifier with ≥ 2 underscores (long enough to be a
  deliberate code name, not prose);
* a path-looking span ending in `.rs` / `.py` / `.toml` / `.md` — must
  be the suffix of some real file path in the repo (docs cite
  `sense.rs` or `planner/schedule.rs` from whatever tree they are
  describing; drift means no file of that name exists anywhere).
"""

from __future__ import annotations

import ast
import re
from pathlib import Path

from memlint.findings import Finding
from memlint.rustlex import FileIndex, index_tree

RULE = "doc-symbol"

DOCS = ("rust/DESIGN.md", "rust/OPERATIONS.md", "rust/EXPERIMENTS.md")

STD_ROOTS = {"std", "core", "alloc", "self", "super", "crate", "Self", "io", "python"}

# std/core method names the docs may cite in call form without there
# being (or needing) a local definition.
STD_METHODS = {"unwrap", "expect", "clone", "drop", "len", "lock", "read", "write", "recv"}

FENCE = re.compile(r"^```.*?^```", re.M | re.S)
SPAN = re.compile(r"`([^`\n]+)`")
CALL = re.compile(r"^([A-Za-z_][A-Za-z0-9_]*)\(\)$")
CONST = re.compile(r"^[A-Z][A-Z0-9_]{3,}$")
SNAKE = re.compile(r"^[a-z_][a-z0-9_]*$")
FILEISH = re.compile(r"^[\w./-]+\.(rs|py|toml|md|json|yml)$")


def rust_symbols(indexes: list[FileIndex]) -> set[str]:
    syms: set[str] = set()
    for idx in indexes:
        for it in idx.items:
            syms.add(it.name)
        # Module path segments: src/coordinator/wire.rs -> coordinator, wire
        for part in idx.path.parts:
            name = part[:-3] if part.endswith(".rs") else part
            if name and name != "mod":
                syms.add(name)
    return syms


def python_symbols(py_root: Path) -> set[str]:
    syms: set[str] = set()
    for path in sorted(py_root.rglob("*.py")):
        syms.add(path.stem)
        try:
            tree = ast.parse(path.read_text(encoding="utf-8"))
        except SyntaxError:
            continue
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
                syms.add(node.name)
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        syms.add(tgt.id)
    return syms


def _spans(text: str):
    """Yield (line, span_text) for inline code spans outside fences."""
    stripped = FENCE.sub(lambda m: "\n" * m.group(0).count("\n"), text)
    for ln, line in enumerate(stripped.splitlines(), 1):
        for m in SPAN.finditer(line):
            yield ln, m.group(1).strip()


def _segments(path_span: str) -> list[str]:
    out = []
    for seg in path_span.split("::"):
        seg = seg.strip()
        seg = re.sub(r"\(.*\)$", "", seg)  # call parens / arg lists
        seg = re.sub(r"<.*>$", "", seg)  # generics
        seg = seg.rstrip("!?")  # macro bang, try operator
        # `ServiceConfig::banks > 1` — the symbol is the first word;
        # the rest is a prose comparison, not a path segment.
        seg = seg.split()[0] if seg.split() else ""
        if seg:
            out.append(seg)
    return out


def check_doc(
    root: Path, rel: str, symbols: set[str], repo_files: set[str]
) -> list[Finding]:
    doc = root / rel
    if not doc.exists():
        return []
    findings: list[Finding] = []
    seen: set[str] = set()  # report each dangling span once per doc
    for ln, span in _spans(doc.read_text(encoding="utf-8")):
        if span in seen:
            continue
        missing: str | None = None

        if FILEISH.match(span) and ("/" in span or span.endswith((".rs", ".py"))):
            if not any(p == span or p.endswith("/" + span) for p in repo_files):
                missing = f"no file named `{span}` exists anywhere in the repo"
        elif "::" in span and re.fullmatch(r"[\w:!<>()&,\s]+", span):
            segs = _segments(span)
            if segs and segs[0] in STD_ROOTS:
                continue
            for seg in segs:
                if seg in STD_ROOTS or seg in symbols:
                    continue
                missing = f"`{span}`: segment `{seg}` resolves to no known item"
                break
        elif m := CALL.match(span):
            if m.group(1) not in symbols and m.group(1) not in STD_METHODS:
                missing = f"`{span}` names no known function"
        elif CONST.match(span):
            if span not in symbols:
                missing = f"`{span}` names no known constant"
        elif SNAKE.match(span) and span.count("_") >= 2:
            if span not in symbols:
                missing = f"`{span}` names no known item"

        if missing:
            seen.add(span)
            findings.append(Finding(RULE, rel, ln, span, missing))
    return findings


def run(root: Path, indexes: list[FileIndex]) -> tuple[list[Finding], dict]:
    # Docs also cite integration tests, benches and examples — index
    # those trees here (the other rules only care about rust/src).
    extra = index_tree(root, subdirs=("rust/tests", "rust/benches", "rust/examples"))
    symbols = rust_symbols(indexes + extra) | python_symbols(root / "python")
    # Workflow/CI step names and cargo targets count as citable too.
    symbols |= {"memlint", "fleet_model", "check_links", "memsort"}
    repo_files = {
        p.relative_to(root).as_posix()
        for p in root.rglob("*")
        if p.is_file() and ".git" not in p.parts and "target" not in p.parts
    }
    findings: list[Finding] = []
    checked = 0
    for rel in DOCS:
        fs = check_doc(root, rel, symbols, repo_files)
        findings.extend(fs)
        checked += 1
    return findings, {"docs": checked, "symbols": len(symbols)}

"""Rule family 1 — wire-registry consistency.

The wire protocol is specified three times: in `coordinator/wire.rs`
(the `kind()` / `decode()` / `wire_version()` match arms plus the
pinned size-formula test), in OPERATIONS.md's wire table, and in
`python/fleet_model.py`'s `frame_bytes_*` formulas. Nothing compiles
the three against each other, so this rule does:

* every `Frame` variant has exactly one kind id, `kind()` and
  `decode()` agree on it, and the OPERATIONS.md table (under the
  `<!-- memlint:wire-table -->` anchor) lists the same id for the same
  frame name — no extras, no omissions on either side;
* the per-kind minimum-version stamps from `wire_version()` match the
  table's `min ver` column (`cur` meaning `WIRE_VERSION`, for the
  handshake frame that always advertises the build's version);
* the `Version N (minimum accepted: M)` doc line matches
  `WIRE_VERSION` / `MIN_WIRE_VERSION`;
* the three size formulas — job `24 + 4n`, tagged job `33 + t + 4n`,
  full response `112 + 12n` — agree numerically between the wire.rs
  pinned test, the OPERATIONS.md prose, and
  `fleet_model.frame_bytes_job/_job_tagged/_ok`, evaluated at several
  (n, t) sample points.
"""

from __future__ import annotations

import re
from pathlib import Path

from memlint.findings import Finding
from memlint.rustlex import FileIndex, Token

RULE = "wire-registry"

DOC_REL = "rust/OPERATIONS.md"
WIRE_REL = "rust/src/coordinator/wire.rs"

TABLE_ANCHOR = "<!-- memlint:wire-table -->"
VERSION_LINE = re.compile(r"Version `(\d+)` \(minimum accepted: `(\d+)`\)")
TABLE_ROW = re.compile(r"^\|\s*(\d+)\s*\|\s*(\w+)\s*\|\s*(cur|\d+)\s*\|")

# OPERATIONS.md prose formulas, anchored by their role words.
DOC_JOB = re.compile(r"`([0-9tn +*]+)` per job frame")
DOC_TAGGED = re.compile(r"`([0-9tn +*]+)` for a tagged job")
DOC_RESP = re.compile(r"`([0-9tn +*]+)` per full response frame")

SAMPLES = [(0, 0), (1, 1), (1024, 7), (100_000, 32)]


def _eval_formula(expr: str, n: int, t: int) -> int | None:
    """Evaluate a doc formula like `33 + t + 4n` at (n, t)."""
    py = re.sub(r"(\d)\s*([nt])\b", r"\1*\2", expr)
    if not re.fullmatch(r"[0-9nt +*()]+", py):
        return None
    try:
        return int(eval(py, {"__builtins__": {}}, {"n": n, "t": t}))  # noqa: S307
    except Exception:
        return None


def _fn_tokens(idx: FileIndex, name: str) -> list[Token]:
    for fn in idx.fns:
        if fn.name == name:
            return fn.tokens
    return []


def _consts(idx: FileIndex) -> dict[str, int]:
    """`pub const NAME: ty = <int>;` bindings, by token scan."""
    toks = idx.tokens
    out: dict[str, int] = {}
    for i, t in enumerate(toks):
        if t.kind == "ident" and t.text == "const" and i + 1 < len(toks):
            name_tok = toks[i + 1]
            j = i + 2
            while j < len(toks) and toks[j].text != "=" and toks[j].text != ";":
                j += 1
            if j + 1 < len(toks) and toks[j].text == "=" and toks[j + 1].kind == "num":
                try:
                    out[name_tok.text] = int(toks[j + 1].text.replace("_", ""), 0)
                except ValueError:
                    pass
    return out


def parse_kind_map(idx: FileIndex) -> dict[str, int]:
    """`Frame::Name ... => <num>` arms inside fn kind()."""
    toks = _fn_tokens(idx, "kind")
    out: dict[str, int] = {}
    i = 0
    while i < len(toks):
        if toks[i].text == "Frame" and i + 2 < len(toks) and toks[i + 1].text == "::":
            name = toks[i + 2].text
            j = i + 3
            while j < len(toks) and toks[j].text != "=>":
                j += 1
            if j + 1 < len(toks) and toks[j + 1].kind == "num":
                out[name] = int(toks[j + 1].text)
            i = j
        i += 1
    return out


def parse_decode_map(idx: FileIndex) -> dict[str, int]:
    """`<num> => ... Frame::Name` arms inside fn decode()."""
    toks = _fn_tokens(idx, "decode")
    out: dict[str, int] = {}
    pending: int | None = None
    i = 0
    while i < len(toks):
        t = toks[i]
        if t.kind == "num" and i + 1 < len(toks) and toks[i + 1].text == "=>":
            pending = int(t.text)
        elif (
            pending is not None
            and t.text == "Frame"
            and i + 2 < len(toks)
            and toks[i + 1].text == "::"
        ):
            out[toks[i + 2].text] = pending
            pending = None
        i += 1
    return out


def parse_version_map(idx: FileIndex, consts: dict[str, int]) -> dict[str, int | str]:
    """`Frame::A | Frame::B => <num|CONST>` arms inside wire_version().
    Returns per-variant stamps; the `_ =>` arm's value under key `"_"`."""
    toks = _fn_tokens(idx, "wire_version")
    out: dict[str, int | str] = {}
    i = 0
    names: list[str] = []
    while i < len(toks):
        t = toks[i]
        if t.text == "Frame" and i + 2 < len(toks) and toks[i + 1].text == "::":
            names.append(toks[i + 2].text)
        elif t.text == "_" and t.kind == "ident":
            names.append("_")
        elif t.text == "=>":
            j = i + 1
            val: int | str | None = None
            if j < len(toks):
                if toks[j].kind == "num":
                    val = int(toks[j].text)
                elif toks[j].kind == "ident" and toks[j].text in consts:
                    val = consts[toks[j].text]
                elif toks[j].kind == "ident":
                    val = toks[j].text
            if val is not None:
                for name in names:
                    out[name] = val
            names = []
        i += 1
    return out


def parse_rust_formulas(idx: FileIndex) -> dict[str, tuple[int, ...]]:
    """Extract (base, per_elem[, tagged]) coefficient tuples from the
    pinned `frame_sizes_match_the_documented_overhead_model` test, by
    token shape: `A + B * n` -> job (B==4) or resp (B==12);
    `A + t + B * n` -> tagged."""
    toks = _fn_tokens(idx, "frame_sizes_match_the_documented_overhead_model")
    out: dict[str, tuple[int, ...]] = {}
    n = len(toks)
    for i in range(n - 4):
        a, p1, b = toks[i], toks[i + 1], toks[i + 2]
        if a.kind == "num" and p1.text == "+":
            # `A + t + B * n` (tagged job)
            if (
                b.kind == "ident"
                and b.text == "t"
                and i + 6 < n
                and toks[i + 3].text == "+"
                and toks[i + 4].kind == "num"
                and toks[i + 5].text == "*"
                and toks[i + 6].text == "n"
            ):
                out.setdefault("tagged", (int(a.text), int(toks[i + 4].text)))
            # `A + B * n`
            elif (
                b.kind == "num"
                and i + 4 < n
                and toks[i + 3].text == "*"
                and toks[i + 4].text == "n"
            ):
                base, per = int(a.text), int(b.text)
                role = {4: "job", 12: "resp"}.get(per)
                if role:
                    out.setdefault(role, (base, per))
    return out


def parse_doc(ops_md: Path):
    """Returns (version_pair, rows, formulas, anchor_line, problems)."""
    problems: list[str] = []
    if not ops_md.exists():
        return None, {}, {}, 0, [f"{ops_md} does not exist"]
    text = ops_md.read_text(encoding="utf-8")
    lines = text.splitlines()

    vm = VERSION_LINE.search(text)
    version_pair = (int(vm.group(1)), int(vm.group(2))) if vm else None
    if not vm:
        problems.append("no `Version `N` (minimum accepted: `M`)` line found")

    anchor_line = 0
    rows: dict[str, tuple[int, int | str, int]] = {}  # name -> (id, minver, line)
    for ln, line in enumerate(lines, 1):
        if TABLE_ANCHOR in line:
            anchor_line = ln
        elif anchor_line and ln > anchor_line:
            m = TABLE_ROW.match(line.strip())
            if m:
                minv: int | str = m.group(3) if m.group(3) == "cur" else int(m.group(3))
                rows[m.group(2)] = (int(m.group(1)), minv, ln)
            elif rows and not line.strip().startswith("|"):
                break  # table ended
    if not anchor_line:
        problems.append(
            f"no `{TABLE_ANCHOR}` anchor — the kind table must stay machine-parseable"
        )

    formulas: dict[str, str] = {}
    for role, rx in (("job", DOC_JOB), ("tagged", DOC_TAGGED), ("resp", DOC_RESP)):
        m = rx.search(text)
        if m:
            formulas[role] = m.group(1)
        else:
            problems.append(f"no `{role}` size formula found in the prose")
    return version_pair, rows, formulas, anchor_line, problems


def run(root: Path, indexes: list[FileIndex]) -> tuple[list[Finding], dict]:
    findings: list[Finding] = []
    wire_idx = next(
        (i for i in indexes if i.path.relative_to(root).as_posix() == WIRE_REL), None
    )
    if wire_idx is None:
        return [Finding(RULE, WIRE_REL, 1, "missing", "wire.rs not found")], {}

    consts = _consts(wire_idx)
    wire_version = consts.get("WIRE_VERSION")
    min_version = consts.get("MIN_WIRE_VERSION")
    kind_map = parse_kind_map(wire_idx)
    decode_map = parse_decode_map(wire_idx)
    version_map = parse_version_map(wire_idx, consts)
    rust_formulas = parse_rust_formulas(wire_idx)
    # Scope to the `Frame` enum: wire.rs also defines borrowed view enums
    # (`FrameView` et al.) whose variants are not wire kinds.
    variants = {
        it.name
        for it in wire_idx.items
        if it.kind == "variant" and not it.in_test and it.context == "Frame"
    }

    def flag(file, line, key, msg):
        findings.append(Finding(RULE, file, line, key, msg))

    # -- internal wire.rs consistency ---------------------------------
    for name in sorted(variants):
        if name not in kind_map:
            flag(WIRE_REL, 1, f"kind-missing:{name}", f"Frame::{name} has no kind() arm")
        if name not in decode_map:
            flag(
                WIRE_REL, 1, f"decode-missing:{name}", f"Frame::{name} has no decode() arm"
            )
    for name, kid in sorted(kind_map.items()):
        if name in decode_map and decode_map[name] != kid:
            flag(
                WIRE_REL,
                1,
                f"kind-decode:{name}",
                f"Frame::{name}: kind() says {kid} but decode() maps {decode_map[name]}",
            )
    ids = sorted(kind_map.values())
    if len(set(ids)) != len(ids):
        flag(WIRE_REL, 1, "kind-dup", f"duplicate kind ids in kind(): {ids}")

    # -- doc table vs wire.rs -----------------------------------------
    version_pair, rows, doc_formulas, anchor_line, problems = parse_doc(
        root / DOC_REL
    )
    for p in problems:
        flag(DOC_REL, anchor_line or 1, f"doc:{p[:40]}", p)

    if version_pair and wire_version is not None and min_version is not None:
        if version_pair != (wire_version, min_version):
            flag(
                DOC_REL,
                1,
                "version-line",
                f"doc says version {version_pair[0]} (min {version_pair[1]}) but "
                f"wire.rs has WIRE_VERSION={wire_version}, "
                f"MIN_WIRE_VERSION={min_version}",
            )

    default_stamp = version_map.get("_", min_version)
    for name, kid in sorted(kind_map.items()):
        if name not in rows:
            flag(
                DOC_REL,
                anchor_line or 1,
                f"table-missing:{name}",
                f"frame {name} (kind {kid}) is absent from the OPERATIONS.md kind table",
            )
            continue
        doc_id, doc_min, ln = rows[name]
        if doc_id != kid:
            flag(
                DOC_REL,
                ln,
                f"table-id:{name}",
                f"table says {name} is kind {doc_id}; kind() says {kid}",
            )
        rust_min = version_map.get(name, default_stamp)
        doc_min_val = wire_version if doc_min == "cur" else doc_min
        if rust_min is not None and doc_min_val != rust_min:
            flag(
                DOC_REL,
                ln,
                f"table-minver:{name}",
                f"table stamps {name} at min version {doc_min}; wire_version() "
                f"says {rust_min}",
            )
    for name, (doc_id, _, ln) in sorted(rows.items()):
        if name not in kind_map:
            flag(
                DOC_REL,
                ln,
                f"table-extra:{name}",
                f"table lists frame {name} (kind {doc_id}) but wire.rs has no such "
                "variant",
            )

    # -- size formulas: rust test pin vs doc prose vs fleet_model -----
    try:
        import fleet_model  # noqa: PLC0415  (lives in python/, sys.path[0])

        model = {
            "job": lambda n, t: fleet_model.frame_bytes_job(n),
            "tagged": lambda n, t: fleet_model.frame_bytes_job_tagged(n, t),
            "resp": lambda n, t: fleet_model.frame_bytes_ok(n),
        }
    except Exception as exc:  # pragma: no cover — model must import
        model = {}
        flag("python/fleet_model.py", 1, "model-import", f"cannot import fleet_model: {exc}")

    for role in ("job", "tagged", "resp"):
        coeffs = rust_formulas.get(role)
        if coeffs is None:
            flag(
                WIRE_REL,
                1,
                f"formula-missing:{role}",
                f"no pinned `{role}` size formula found in "
                "frame_sizes_match_the_documented_overhead_model",
            )
            continue

        def rust_eval(n, t, coeffs=coeffs, role=role):
            base, per = coeffs
            return base + per * n + (t if role == "tagged" else 0)

        for n, t in SAMPLES:
            want = rust_eval(n, t)
            if role in doc_formulas:
                got = _eval_formula(doc_formulas[role], n, t)
                if got != want:
                    flag(
                        DOC_REL,
                        1,
                        f"formula-doc:{role}",
                        f"doc formula `{doc_formulas[role]}` gives {got} at "
                        f"(n={n}, t={t}); wire.rs pins {want}",
                    )
                    break
        for n, t in SAMPLES:
            want = rust_eval(n, t)
            if role in model:
                got = model[role](n, t)
                if got != want:
                    flag(
                        "python/fleet_model.py",
                        1,
                        f"formula-model:{role}",
                        f"fleet_model frame_bytes for `{role}` gives {got} at "
                        f"(n={n}, t={t}); wire.rs pins {want}",
                    )
                    break

    summary = {
        "variants": len(variants),
        "kinds": len(kind_map),
        "doc_rows": len(rows),
        "formulas": sorted(rust_formulas),
    }
    return findings, summary

"""Rule family 3 — lock discipline across the coordinator.

Extracts every lock acquisition in `rust/src/coordinator/` — native
`.lock()` / `.read()` / `.write()` calls (empty argument lists, so
`io::Read::read(buf)` never matches) and the poison-recovering helpers
`lock_recover(&x)` / `read_recover(&x)` / `write_recover(&x)` — and
checks, per function:

* **Ordering** — a nested acquisition `A` held while taking `B` must
  respect the canonical order declared in DESIGN.md's
  `<!-- memlint:lock-order -->` block (outermost first). A reversed
  pair in one thread plus the straight pair in another is the classic
  ABBA deadlock; a same-lock nested pair is a self-deadlock.
* **Blocking under a guard** — a guard held across a channel `recv` /
  `recv_timeout` or socket I/O (`read_frame`, `write_frame`,
  `read_exact`, `write_all`, `accept`, `connect`, `join`) stalls every
  thread queued on that lock for as long as the peer takes.
  Intentional cases (the writer mutex that exists precisely to
  serialize whole-frame writes) carry allowlist entries.

Guard lifetimes are tracked heuristically: a `let`-bound acquisition
lives to the end of its block (or an explicit `drop(name)`); an
acquisition whose method chain continues past the guard (e.g.
`x.lock()?.remove(..)`) is statement-scoped; a scrutinee acquisition
(`match *x.lock() {`, `if let Some(g) = x.read() {`) lives through the
braced body, matching Rust's temporary-lifetime rules.
"""

from __future__ import annotations

import re
from pathlib import Path

from memlint.findings import Finding
from memlint.rustlex import FileIndex, FnSpan

RULE = "lock-order"

NATIVE = {"lock", "read", "write"}
HELPERS = {"lock_recover", "read_recover", "write_recover"}
GUARD_SUFFIX = {"expect", "unwrap", "unwrap_or_else"}
BLOCKING = {
    "recv",
    "recv_timeout",
    "read_frame",
    "read_frame_view",
    "read_hello",
    "write_frame",
    "read_exact",
    "write_all",
    "accept",
    "connect",
    "join",
}

ANCHOR = re.compile(r"<!--\s*memlint:lock-order\s*\n(.*?)-->", re.S)


def parse_order(design_md: Path) -> tuple[list[str], str | None]:
    """The canonical order: one lock name per line, outermost first,
    inside the DESIGN.md anchor block. `#`-prefixed lines are comments."""
    if not design_md.exists():
        return [], f"{design_md} does not exist — no canonical lock order to check against"
    m = ANCHOR.search(design_md.read_text(encoding="utf-8"))
    if not m:
        return [], (
            "DESIGN.md has no `<!-- memlint:lock-order -->` block — "
            "declare the canonical order (outermost first)"
        )
    names = []
    for raw in m.group(1).splitlines():
        name = raw.strip()
        if name and not name.startswith("#"):
            names.append(name)
    return names, None


class _Acq:
    __slots__ = ("name", "line", "depth", "bound", "let_name")

    def __init__(self, name, line, depth, bound, let_name):
        self.name = name
        self.line = line
        self.depth = depth  # brace depth the guard lives at
        self.bound = bound  # False: dies at the next `;` at this depth
        self.let_name = let_name


def _recv_name(toks, i) -> str | None:
    """Receiver of `recv.method()`: the ident right before the `.`."""
    if i >= 2 and toks[i - 1].text == "." and toks[i - 2].kind == "ident":
        return toks[i - 2].text
    return None


def _helper_arg_name(toks, i) -> str | None:
    """Last ident inside `helper(&a.b.c)` — the lock's field name."""
    j = i + 1
    if j >= len(toks) or toks[j].text != "(":
        return None
    depth, name = 0, None
    while j < len(toks):
        t = toks[j]
        if t.text == "(":
            depth += 1
        elif t.text == ")":
            depth -= 1
            if depth == 0:
                break
        elif t.kind == "ident" and t.text not in ("mut", "self"):
            name = t.text
        j += 1
    return name


def _acquisitions(fn: FnSpan):
    """Yield (token_index, lock_name, line, suffix_end) for each
    acquisition site. `suffix_end` is the index just past the guard
    expression (past `.expect(..)` etc.) used for lifetime guessing."""
    toks = fn.tokens
    n = len(toks)
    for i, t in enumerate(toks):
        if t.kind != "ident":
            continue
        name = None
        if t.text in NATIVE:
            # `.lock()` / `.read()` / `.write()` with NO arguments.
            if (
                i + 2 < n
                and toks[i + 1].text == "("
                and toks[i + 2].text == ")"
                and i >= 1
                and toks[i - 1].text == "."
            ):
                name = _recv_name(toks, i)
                end = i + 3
            else:
                continue
        elif t.text in HELPERS:
            name = _helper_arg_name(toks, i)
            end = i + 1
            depth = 0
            while end < n:
                if toks[end].text == "(":
                    depth += 1
                elif toks[end].text == ")":
                    depth -= 1
                    if depth == 0:
                        end += 1
                        break
                end += 1
        else:
            continue
        if name is None:
            continue
        # Swallow a poison-handling suffix: `.expect("..")`, `.unwrap()`,
        # `.unwrap_or_else(..)` — still the same guard expression.
        while end + 1 < n and toks[end].text == "." and toks[end + 1].text in GUARD_SUFFIX:
            end += 2
            depth = 0
            while end < n:
                if toks[end].text == "(":
                    depth += 1
                elif toks[end].text == ")":
                    depth -= 1
                    if depth == 0:
                        end += 1
                        break
                end += 1
        yield i, name, t.line, end


def _stmt_has_let(toks, i) -> str | None:
    """If the statement containing token `i` starts with `let`, return
    the bound name (last ident before `=`, skipping `mut`)."""
    j = i
    while j >= 0 and toks[j].text not in (";", "{", "}"):
        j -= 1
    j += 1
    if j < len(toks) and toks[j].kind == "ident" and toks[j].text == "let":
        name = None
        k = j + 1
        while k < i and toks[k].text != "=":
            if toks[k].kind == "ident" and toks[k].text != "mut":
                name = toks[k].text
            k += 1
        return name or "_"
    return None


def check_fn(fn: FnSpan, order: list[str], rel: str) -> list[Finding]:
    toks = fn.tokens
    n = len(toks)
    rank = {name: i for i, name in enumerate(order)}
    acq_at: dict[int, tuple[str, int, int]] = {}
    for i, name, line, end in _acquisitions(fn):
        acq_at[i] = (name, line, end)
    findings: list[Finding] = []
    live: list[_Acq] = []
    depth = 0
    i = 0
    while i < n:
        t = toks[i]
        if t.text == "{":
            depth += 1
        elif t.text == "}":
            depth -= 1
            live = [g for g in live if g.depth <= depth]
        elif t.text == ";":
            live = [g for g in live if g.bound or g.depth < depth or g.depth > depth]
            live = [g for g in live if not (not g.bound and g.depth == depth)]
        elif i in acq_at:
            name, line, end = acq_at[i]
            if name not in rank:
                findings.append(
                    Finding(
                        RULE,
                        rel,
                        line,
                        f"undeclared:{name}",
                        f"lock `{name}` (fn `{fn.name}`) is not in DESIGN.md's "
                        "canonical lock order declaration",
                    )
                )
            for g in live:
                if g.name == name:
                    findings.append(
                        Finding(
                            RULE,
                            rel,
                            line,
                            f"{fn.name}:{name}->{name}",
                            f"`{name}` acquired while already held in fn `{fn.name}` "
                            "— self-deadlock",
                        )
                    )
                elif g.name in rank and name in rank and rank[g.name] > rank[name]:
                    findings.append(
                        Finding(
                            RULE,
                            rel,
                            line,
                            f"{fn.name}:{g.name}->{name}",
                            f"`{name}` acquired while `{g.name}` is held in fn "
                            f"`{fn.name}`, but the canonical order is "
                            f"`{name}` before `{g.name}` — ABBA deadlock shape",
                        )
                    )
            # Lifetime: chain continues -> statement temp; `{` before `;`
            # -> scrutinee/if-let guard living through the braced body;
            # plain `let` -> block-bound.
            let_name = _stmt_has_let(toks, i)
            chained = end < n and toks[end].text == "."
            j = end
            d = 0
            brace_first = False
            while j < n:
                tj = toks[j]
                if tj.text in "([":
                    d += 1
                elif tj.text in ")]":
                    d -= 1
                elif d == 0 and tj.text == "{":
                    brace_first = True
                    break
                elif d == 0 and tj.text == ";":
                    break
                j += 1
            if brace_first:
                live.append(_Acq(name, line, depth + 1, True, let_name))
            elif chained or let_name is None:
                live.append(_Acq(name, line, depth, False, let_name))
            else:
                live.append(_Acq(name, line, depth, True, let_name))
        elif t.kind == "ident" and t.text == "drop" and i + 1 < n and toks[i + 1].text == "(":
            if i + 2 < n and toks[i + 2].kind == "ident":
                victim = toks[i + 2].text
                live = [g for g in live if g.let_name != victim]
        elif t.kind == "ident" and t.text in BLOCKING:
            if i + 1 < n and toks[i + 1].text == "(" and not (i > 0 and toks[i - 1].text == "fn"):
                for g in live:
                    findings.append(
                        Finding(
                            RULE,
                            rel,
                            t.line,
                            f"{fn.name}:{g.name}->{t.text}",
                            f"guard `{g.name}` held across blocking `{t.text}(..)` in "
                            f"fn `{fn.name}` — every thread queued on the lock stalls "
                            "for as long as the peer takes",
                        )
                    )
        i += 1
    return findings


def run(
    root: Path, indexes: list[FileIndex], design_md: Path
) -> tuple[list[Finding], dict]:
    order, err = parse_order(design_md)
    findings: list[Finding] = []
    if err:
        findings.append(Finding(RULE, "rust/DESIGN.md", 1, "missing-order", err))
    sites = 0
    for idx in indexes:
        rel = idx.path.relative_to(root).as_posix()
        # The spill tier's run stores guard shared run maps the same way
        # the coordinator guards its scoreboards — and their readers run
        # on serving threads — so they are held to the same discipline.
        if "coordinator" not in rel and rel != "rust/src/sorter/spill.rs":
            continue
        # locks.rs *is* the acquisition primitive: its helpers lock
        # generic parameters, which by construction have no place in a
        # canonical order over named shared fields.
        if rel.endswith("/locks.rs"):
            continue
        for fn in idx.fns:
            if fn.in_test:
                continue
            sites += sum(1 for _ in _acquisitions(fn))
            findings.extend(check_fn(fn, order, rel))
    return findings, {"sites": sites, "order": order}

"""CLI for memlint. Usage, from the repo root:

    python python/memlint            # full gate (rules + doc links)
    python python/memlint -q        # findings only, no summary table

Exit status 0 means clean; 1 means drift (findings, allowlist
problems, or broken doc links). This is the single lint gate CI runs —
it folds in ``check_links.py`` so one named step covers every
toolchain-independent check.
"""

from __future__ import annotations

import sys
from pathlib import Path

PKG_DIR = Path(__file__).resolve().parent
# `python python/memlint` puts python/memlint/ (not python/) on
# sys.path; make the package and its python/ siblings importable.
sys.path.insert(0, str(PKG_DIR.parent))

from memlint import run_all  # noqa: E402

import check_links  # noqa: E402  (python/check_links.py — folded into this gate)


def main(argv: list[str]) -> int:
    quiet = "-q" in argv or "--quiet" in argv
    root = PKG_DIR.parent.parent

    findings, notes, summaries = run_all(root)
    link_errors = check_links.check(root)

    for f in findings:
        print(f.render())
    for note in notes:
        print(f"allowlist: {note}")

    if not quiet:
        print()
        print("memlint summary")
        wire = summaries.get("wire-registry", {})
        print(
            f"  wire-registry   : {wire.get('kinds', 0)} kinds, "
            f"{wire.get('doc_rows', 0)} doc rows, formulas {wire.get('formulas', [])}"
        )
        panic = summaries.get("panic-path", {})
        print(
            f"  panic-path      : {panic.get('total', 0)} non-test sites across "
            f"{panic.get('files', 0)} files, {panic.get('serving', 0)} on serving paths"
        )
        locks = summaries.get("lock-order", {})
        print(
            f"  lock-order      : {locks.get('sites', 0)} acquisition sites, "
            f"order of {len(locks.get('order', []))} locks"
        )
        docs = summaries.get("doc-symbol", {})
        print(
            f"  doc-symbol      : {docs.get('docs', 0)} docs vs "
            f"{docs.get('symbols', 0)} known symbols"
        )
        mirror = summaries.get("mirror-coverage", {})
        print(
            f"  mirror-coverage : {mirror.get('mapped', 0)}/{mirror.get('rust_fns', 0)} "
            f"model fns mirrored across {mirror.get('files', 0)} files"
        )
        allow = summaries.get("allowlist", {})
        print(
            f"  allowlist       : {allow.get('entries', 0)} entries, "
            f"{allow.get('suppressed', 0)} findings suppressed"
        )
        print(f"  doc links       : {'ok' if link_errors == 0 else 'BROKEN'}")

    failed = bool(findings) or bool(notes) or link_errors != 0
    if failed:
        print(
            f"\nmemlint: FAIL ({len(findings)} finding(s), {len(notes)} allowlist "
            f"problem(s), doc links {'ok' if link_errors == 0 else 'broken'})"
        )
    else:
        print("\nmemlint: ok")
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))

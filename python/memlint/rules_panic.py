"""Rule family 2 — panic-path audit.

Inventories every `unwrap()` / `expect(...)` / `panic!` /
`unreachable!` / `todo!` / `unimplemented!` / raw-index site in the
Rust tree, and *forbids* them on the request-serving paths: the shard
server's session loops, the frontend's admission, the remote
transport's reader threads, and the wire decode path. A panic on any
of those threads either kills a session another tenant shares or
poisons a lock every sibling session needs — the multi-connection
server's whole contract is that one bad frame degrades one session,
not the process.

Sites in `#[cfg(test)]` / `#[test]` code never count. Sites outside
the serving scope are inventory only (reported in the summary, never
findings). A serving-path site survives only through the allowlist,
keyed `"<fn>:<pattern>@<occurrence>"` so entries pin one proven-safe
site each and go stale when the code around them moves.
"""

from __future__ import annotations

from pathlib import Path

from memlint.findings import Finding
from memlint.rustlex import FileIndex, FnSpan

RULE = "panic-path"

# The serving scope: file suffix -> enforced function names, or "*" for
# every non-test function in the file. These are the loops and helpers
# that run on session, collector, reader or admission threads.
SERVING_SCOPE: dict[str, set[str] | str] = {
    "rust/src/coordinator/shard_server.rs": {
        "serve_conn",
        "dispatch_job",
        "serve_tcp",
        "reject_over_cap",
    },
    "rust/src/coordinator/transport.rs": "*",
    "rust/src/coordinator/frontend.rs": {
        "try_admit",
        "try_admit_sized",
        "release",
        "saturated",
        "sort",
        "sort_batch",
        "sort_hierarchical",
        "hierarchical_admission_bytes",
        "admission",
        "fleet_metrics",
    },
    # The spill tier: every run-store append/read, the run codec and the
    # external merge run while a request is being served (and, on the
    # fleet path, while shard collection holds the assembly) — a panic
    # there loses the caller's sort and any spilled state with it. The
    # whole module is serving scope; its error contract is typed
    # `SpillError`s, never panics or silent resident fallback.
    "rust/src/sorter/spill.rs": "*",
    # The wire decode path: a malformed or hostile frame must surface as
    # an Err, never a panic, because the reader that hits it is shared.
    # The borrowed-view layer (read_raw_into / decode_view / the *Le
    # views) and the reusable encoders (encode_frame_into / FrameSink)
    # run on the same session and reader threads, so they are held to
    # the same zero-panic contract.
    "rust/src/coordinator/wire.rs": {
        "read_frame",
        "read_hello",
        "read_raw",
        "read_raw_into",
        "read_frame_view",
        "decode",
        "decode_view",
        "take",
        "u8",
        "bool",
        "u32",
        "u64",
        "usize",
        "f64",
        "len_prefix",
        "str",
        "finish",
        "get_priority",
        "get_tag",
        "get_u32_vec",
        "get_stats",
        "get_response",
        "get_config",
        "get_snapshot",
        "take_u32s",
        "take_u64s",
        "take_response_view",
        "to_vec",
        "to_usize_vec",
        "into_response",
        "into_frame",
        "encode_frame",
        "encode_frame_into",
        "write_frame",
    },
}

PANIC_MACROS = {"panic", "unreachable", "todo", "unimplemented"}
PANIC_METHODS = {"unwrap", "expect"}


def _relpath(path: Path, root: Path) -> str:
    return path.relative_to(root).as_posix()


def _in_scope(rel: str, fn: FnSpan) -> bool:
    scope = SERVING_SCOPE.get(rel)
    if scope is None or fn.in_test:
        return False
    return scope == "*" or fn.name in scope


def _sites(fn: FnSpan):
    """Yield (line, pattern) for every panic-capable site in a body."""
    toks = fn.tokens
    n = len(toks)
    for i, t in enumerate(toks):
        if t.kind != "ident" and not (t.kind == "punct" and t.text == "["):
            continue
        prev = toks[i - 1] if i > 0 else None
        nxt = toks[i + 1] if i + 1 < n else None
        if t.kind == "ident" and t.text in PANIC_METHODS:
            if prev is not None and prev.text == "." and nxt is not None and nxt.text == "(":
                if t.text == "unwrap":
                    # `.unwrap()` exactly — unwrap_or etc. are distinct idents.
                    close = toks[i + 2] if i + 2 < n else None
                    if close is None or close.text != ")":
                        continue
                yield t.line, t.text
        elif t.kind == "ident" and t.text in PANIC_MACROS:
            if nxt is not None and nxt.text == "!":
                # debug_assert-style call sites don't route here; the
                # macro ident itself is the site.
                yield t.line, f"{t.text}!"
        elif t.text == "[" and t.kind == "punct":
            # Raw index: `expr[...]` where expr ends in an ident, `)`,
            # `]` or `?`. Excludes attributes (`#[`), macro brackets
            # (`vec![`) and array/slice type or literal positions.
            if prev is None or prev.text in ("#", "!"):
                continue
            if prev.kind in ("ident", "num") or prev.text in (")", "]", "?"):
                if prev.kind == "ident" and prev.text in ("mut", "ref", "dyn", "as", "return"):
                    continue
                yield t.line, "raw-index"


def run(root: Path, indexes: list[FileIndex]) -> tuple[list[Finding], dict]:
    findings: list[Finding] = []
    inventory = {"total": 0, "serving": 0, "files": 0}
    for idx in indexes:
        rel = _relpath(idx.path, root)
        file_count = 0
        per_fn_seen: dict[tuple[str, str], int] = {}
        for fn in idx.fns:
            for line, pattern in _sites(fn):
                file_count += 1
                if fn.in_test:
                    continue
                inventory["total"] += 1
                if not _in_scope(rel, fn):
                    continue
                inventory["serving"] += 1
                occ = per_fn_seen.get((fn.name, pattern), 0)
                per_fn_seen[(fn.name, pattern)] = occ + 1
                findings.append(
                    Finding(
                        RULE,
                        rel,
                        line,
                        f"{fn.name}:{pattern}@{occ}",
                        f"`{pattern}` on the request-serving path in fn `{fn.name}` "
                        "— a panic here kills a shared session thread or poisons a "
                        "lock every sibling needs; return an Err / Frame::Dropped "
                        "instead, or allowlist with a proof of infallibility",
                    )
                )
        if file_count:
            inventory["files"] += 1
    return findings, inventory

"""Finding records and the machine-readable allowlist.

A finding is `(rule, file, line, key, message)`. The allowlist
(`allowlist.json`, next to this module) is a list of entries:

    {"rule": "...", "file": "...", "key": "...", "justification": "..."}

An entry suppresses every finding with the same `(rule, file, key)`.
The `key` is a *stable* identifier — for a panic site it is
`"<fn>:<pattern>"`, for a lock edge `"<outer><inner>"` — so allowlist
entries survive unrelated line churn. Entries must carry a non-empty
`justification`; memlint refuses an allowlist that waves findings
through silently, and reports entries that no longer match anything
(stale suppressions are drift too).
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path


@dataclass(frozen=True)
class Finding:
    rule: str
    file: str  # repo-relative, "/" separators
    line: int
    key: str
    message: str

    def render(self) -> str:
        return f"{self.file}:{self.line}: [{self.rule}] {self.message} (key: {self.key})"


class Allowlist:
    def __init__(self, entries: list[dict]):
        self.entries = entries
        self.errors: list[str] = []
        self.used: set[int] = set()
        for i, e in enumerate(entries):
            for required in ("rule", "file", "key", "justification"):
                if not str(e.get(required, "")).strip():
                    self.errors.append(
                        f"allowlist entry {i} is missing a non-empty {required!r}"
                    )

    @classmethod
    def load(cls, path: Path) -> "Allowlist":
        if not path.exists():
            return cls([])
        data = json.loads(path.read_text(encoding="utf-8"))
        if not isinstance(data, list):
            raise ValueError(f"{path}: allowlist must be a JSON list")
        return cls(data)

    def suppresses(self, f: Finding) -> bool:
        for i, e in enumerate(self.entries):
            if (
                e.get("rule") == f.rule
                and e.get("file") == f.file
                and e.get("key") == f.key
            ):
                self.used.add(i)
                return True
        return False

    def stale(self) -> list[str]:
        return [
            f"stale allowlist entry {i}: {e.get('rule')}/{e.get('file')}/{e.get('key')}"
            " matches no current finding"
            for i, e in enumerate(self.entries)
            if i not in self.used
        ]


def apply_allowlist(
    findings: list[Finding], allow: Allowlist
) -> tuple[list[Finding], list[str]]:
    """Split findings into surviving ones; return (kept, notes). Stale
    allowlist entries and malformed entries are *errors*, reported as
    synthetic notes the caller treats as failures."""
    kept = [f for f in findings if not allow.suppresses(f)]
    notes = list(allow.errors) + allow.stale()
    return kept, notes

"""Lightweight Rust lexer and item walker for memlint.

No rustc, no syn: a hand-rolled scanner good enough to answer the
questions the lint rules ask — where the comments and strings are (so
pattern rules never fire inside them), where each `fn` body starts and
ends, which items exist (functions, types, enum variants, struct
fields, consts, modules), and which regions are `#[cfg(test)]` /
`#[test]` code.

The contract is *deliberately* shallow: memlint's rules only need
token streams with line numbers and a per-function attribution, and a
shallow lexer survives language evolution far better than a grammar.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path

IDENT_START = set("abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ_")
IDENT_CONT = IDENT_START | set("0123456789")

# Keywords that introduce a named item; the next identifier is its name.
ITEM_KEYWORDS = {"fn", "struct", "enum", "trait", "mod", "const", "static", "type", "union"}


@dataclass
class Token:
    kind: str  # "ident" | "punct" | "num" | "str" | "char" | "lifetime"
    text: str
    line: int


@dataclass
class Item:
    kind: str  # "fn" | "struct" | "enum" | ... | "variant" | "field"
    name: str
    line: int
    in_test: bool
    context: str = ""  # owning enum/struct name for "variant"/"field" items


@dataclass
class FnSpan:
    """One function body: its name, impl/mod context and token slice."""

    name: str
    context: str  # enclosing impl type or module chain, "" at top level
    start_line: int
    end_line: int
    tokens: list  # the body tokens (between the braces, exclusive)
    in_test: bool
    depth: int  # brace depth the `fn` keyword appeared at


def tokenize(src: str) -> list[Token]:
    """Tokenize Rust source, dropping comments and string *contents*
    (strings become a single `str` token so rules cannot fire inside
    them). Handles nested block comments, raw strings and the
    char-vs-lifetime ambiguity."""
    toks: list[Token] = []
    i, n, line = 0, len(src), 1
    while i < n:
        c = src[i]
        if c == "\n":
            line += 1
            i += 1
            continue
        if c in " \t\r":
            i += 1
            continue
        # Line comment.
        if src.startswith("//", i):
            j = src.find("\n", i)
            i = n if j == -1 else j
            continue
        # Block comment (nested).
        if src.startswith("/*", i):
            depth, i = 1, i + 2
            while i < n and depth:
                if src.startswith("/*", i):
                    depth, i = depth + 1, i + 2
                elif src.startswith("*/", i):
                    depth, i = depth - 1, i + 2
                else:
                    if src[i] == "\n":
                        line += 1
                    i += 1
            continue
        # Raw string r"..." / r#"..."# (any # depth).
        if c == "r" and i + 1 < n and src[i + 1] in "\"#":
            j = i + 1
            hashes = 0
            while j < n and src[j] == "#":
                hashes, j = hashes + 1, j + 1
            if j < n and src[j] == '"':
                close = '"' + "#" * hashes
                k = src.find(close, j + 1)
                k = n if k == -1 else k + len(close)
                start = line
                line += src.count("\n", i, k)
                toks.append(Token("str", "", start))
                i = k
                continue
        # Plain string.
        if c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                    continue
                if src[j] == '"':
                    break
                j += 1
            start = line
            line += src.count("\n", i, j)
            toks.append(Token("str", "", start))
            i = j + 1
            continue
        # Char literal vs lifetime.
        if c == "'":
            if i + 1 < n and (src[i + 1] in IDENT_START) and not (
                i + 2 < n and src[i + 2] == "'"
            ):
                j = i + 1
                while j < n and src[j] in IDENT_CONT:
                    j += 1
                toks.append(Token("lifetime", src[i:j], line))
                i = j
                continue
            # Char literal: 'x', '\n', '\u{..}'.
            j = i + 1
            if j < n and src[j] == "\\":
                j += 2
                while j < n and src[j] != "'":
                    j += 1
            else:
                j += 1
            toks.append(Token("char", "", line))
            i = j + 1
            continue
        if c in IDENT_START:
            j = i
            while j < n and src[j] in IDENT_CONT:
                j += 1
            toks.append(Token("ident", src[i:j], line))
            i = j
            continue
        if c.isdigit():
            j = i
            while j < n and (src[j] in IDENT_CONT or src[j] == "."):
                # Stop a range `0..n` from being eaten as one number.
                if src.startswith("..", j):
                    break
                j += 1
            toks.append(Token("num", src[i:j], line))
            i = j
            continue
        # `::` / `=>` / `->` as one token — rules key on paths and
        # match arms, and a lone `>` from an arrow would unbalance
        # angle-bracket depth tracking.
        if src.startswith(("::", "=>", "->"), i):
            toks.append(Token("punct", src[i : i + 2], line))
            i += 2
            continue
        toks.append(Token("punct", c, line))
        i += 1
    return toks


def _attr_is_test(toks: list[Token], close: int) -> bool:
    """Whether the attribute ending at `]` index `close` marks test code
    (`#[test]` or `#[cfg(test)]` / `#[cfg(all(test, ...))]`)."""
    j = close
    depth = 0
    while j >= 0:
        t = toks[j]
        if t.text == "]":
            depth += 1
        elif t.text == "[":
            depth -= 1
            if depth == 0:
                break
        j -= 1
    inner = [t.text for t in toks[j + 1 : close] if t.kind == "ident"]
    if inner == ["test"]:
        return True
    return bool(inner) and inner[0] == "cfg" and "test" in inner


@dataclass
class FileIndex:
    """Everything memlint knows about one Rust file."""

    path: Path
    tokens: list = field(default_factory=list)
    items: list = field(default_factory=list)  # Item
    fns: list = field(default_factory=list)  # FnSpan


def index_file(path: Path, src: str | None = None) -> FileIndex:
    """Walk one file: collect named items (with test attribution) and
    function spans with their impl/mod context."""
    text = src if src is not None else path.read_text(encoding="utf-8")
    toks = tokenize(text)
    idx = FileIndex(path=path, tokens=toks)
    # Stack of (kind, name, depth, is_test) for blocks that carry
    # context: mod / impl / enum / struct / trait / fn.
    stack: list[tuple[str, str, int, bool]] = []
    depth = 0
    pending: tuple[str, str, bool] | None = None  # block waiting for its `{`
    test_attr = False  # a #[test]/#[cfg(test)] attribute is pending
    i = 0
    n = len(toks)
    while i < n:
        t = toks[i]
        if t.text == "{" and t.kind == "punct":
            depth += 1
            if pending:
                stack.append((pending[0], pending[1], depth, pending[2]))
                pending = None
            i += 1
            continue
        if t.text == "}" and t.kind == "punct":
            while stack and stack[-1][2] == depth:
                closed = stack.pop()
                if closed[0] == "fn":
                    # Find the matching FnSpan (the last unclosed one).
                    for fs in reversed(idx.fns):
                        if fs.end_line == -1 and fs.name == closed[1]:
                            fs.end_line = t.line
                            break
            depth -= 1
            i += 1
            continue
        if t.text == ";" and pending:
            pending = None  # e.g. `mod foo;`, `struct Unit;`
            i += 1
            continue
        # Attributes: scan to the matching `]`, note test markers.
        if t.text == "#" and i + 1 < n and toks[i + 1].text == "[":
            j = i + 1
            d = 0
            while j < n:
                if toks[j].text == "[":
                    d += 1
                elif toks[j].text == "]":
                    d -= 1
                    if d == 0:
                        break
                j += 1
            if _attr_is_test(toks, j):
                test_attr = True
            i = j + 1
            continue
        in_test = test_attr or any(s[3] for s in stack)
        if t.kind == "ident" and t.text in ITEM_KEYWORDS and not _is_path_member(toks, i):
            kw = t.text
            # Name = next ident (skipping generics is unnecessary: the
            # name comes first).
            j = i + 1
            while j < n and toks[j].kind != "ident":
                # `impl<T> Foo` style never hits here (impl handled below)
                if toks[j].text in "({;":
                    break
                j += 1
            if j < n and toks[j].kind == "ident":
                name = toks[j].text
                idx.items.append(Item(kw, name, toks[j].line, in_test))
                if kw == "fn":
                    context = "::".join(s[1] for s in stack if s[0] in ("mod", "impl"))
                    idx.fns.append(
                        FnSpan(name, context, toks[j].line, -1, [], in_test, depth)
                    )
                    pending = ("fn", name, in_test)
                elif kw in ("mod", "enum", "struct", "trait", "union"):
                    pending = (kw, name, in_test or (kw == "mod" and test_attr))
                test_attr = False
                i = j + 1
                continue
            # Nameless form (the `const { ... }` block expression): leave
            # the stopping token for the main loop so brace depth stays
            # balanced — consuming a `{` here skews depth for the whole
            # rest of the file.
            test_attr = False
            i = j if j < n else n
            continue
        if t.kind == "ident" and t.text == "impl" and _is_stmt_start(toks, i):
            # impl [<...>] Type [for Trait] { ... } — take the last path
            # ident before `{` or `for` as the context name. The
            # statement-context guard keeps `impl Trait` in argument or
            # return position (`fn new(t: impl Into<String>)`) from
            # being taken for an impl block.
            j = i + 1
            name = ""
            d = 0
            while j < n:
                tj = toks[j]
                if tj.text in "<([" :
                    d += 1
                elif tj.text in ">)]":
                    d -= 1
                elif d == 0 and tj.text == "{":
                    break
                elif d == 0 and tj.kind == "ident" and tj.text != "for":
                    name = tj.text
                j += 1
            pending = ("impl", name, test_attr)
            test_attr = False
            i = j
            continue
        if t.kind == "ident":
            test_attr = False
        i += 1
    # Second pass: enum variants and struct fields, plus fn body slices.
    _collect_members(idx)
    _slice_fn_bodies(idx)
    return idx


def _is_path_member(toks: list[Token], i: int) -> bool:
    """`x.fn_like` or `a::type` — keyword-looking idents after `.`/`::`
    are member accesses, not item starts."""
    return i > 0 and toks[i - 1].text in (".", "::")


def _is_stmt_start(toks: list[Token], i: int) -> bool:
    """True when token i sits where an item can begin: file start, after
    a block/statement boundary, after an attribute's `]`, or after an
    `unsafe` qualifier."""
    if i == 0:
        return True
    prev = toks[i - 1]
    return prev.text in ("{", "}", ";", "]") or (
        prev.kind == "ident" and prev.text == "unsafe"
    )


def _collect_members(idx: FileIndex) -> None:
    """Enum variants and struct fields: idents at depth+1 of an
    enum/struct body (variants start a segment; fields precede `:`)."""
    toks = idx.tokens
    n = len(toks)
    i = 0
    depth = 0
    paren = 0  # tuple-variant payloads: `SortJobTagged(JobTag, Vec<u32>)`
    pending: tuple[str, bool] | None = None
    bodies: list[tuple[str, int, bool]] = []  # (kind, body_depth, in_test)
    test_depths: list[int] = []
    while i < n:
        t = toks[i]
        if t.text == "#" and i + 1 < n and toks[i + 1].text == "[":
            j, d = i + 1, 0
            while j < n:
                if toks[j].text == "[":
                    d += 1
                elif toks[j].text == "]":
                    d -= 1
                    if d == 0:
                        break
                j += 1
            if _attr_is_test(toks, j) and j + 1 < n and toks[j + 1].text in ("mod",):
                pass  # handled through stack below
            i = j + 1
            continue
        if t.kind == "ident" and t.text in ("enum", "struct") and not _is_path_member(toks, i):
            owner = toks[i + 1].text if i + 1 < n and toks[i + 1].kind == "ident" else ""
            pending = (t.text, owner)
        elif t.text == "{":
            depth += 1
            if pending:
                bodies.append((pending[0], depth, pending[1]))
                pending = None
        elif t.text == "}":
            if bodies and bodies[-1][1] == depth:
                bodies.pop()
            depth -= 1
        elif t.text == ";":
            pending = None
        elif t.text == "(":
            paren += 1
        elif t.text == ")":
            paren -= 1
        elif t.kind == "ident" and bodies and depth == bodies[-1][1] and paren == 0:
            kind = bodies[-1][0]
            prev = toks[i - 1].text if i > 0 else "{"
            nxt = toks[i + 1].text if i + 1 < n else ""
            if kind == "enum" and prev in ("{", ","):
                idx.items.append(Item("variant", t.text, t.line, False, bodies[-1][2]))
            elif kind == "struct" and nxt == ":" and prev in ("{", ",", "pub", ")"):
                idx.items.append(Item("field", t.text, t.line, False, bodies[-1][2]))
        i += 1


def _slice_fn_bodies(idx: FileIndex) -> None:
    """Attach to every FnSpan the token slice of its body (between the
    opening brace after the signature and the matching close)."""
    toks = idx.tokens
    n = len(toks)
    for fs in idx.fns:
        # Find the `fn` name token at fs.start_line, then its body `{`.
        i = 0
        while i < n and not (
            toks[i].kind == "ident" and toks[i].text == fs.name and toks[i].line == fs.start_line
        ):
            i += 1
        d = 0
        while i < n:
            if toks[i].text == "{":
                break
            if toks[i].text == ";" and d == 0:
                break  # trait method without body
            if toks[i].text in "<([":
                d += 1
            elif toks[i].text in ">)]":
                d -= 1
            i += 1
        if i >= n or toks[i].text != "{":
            continue
        start = i
        d = 0
        while i < n:
            if toks[i].text == "{":
                d += 1
            elif toks[i].text == "}":
                d -= 1
                if d == 0:
                    break
            i += 1
        fs.tokens = toks[start + 1 : i]
        if fs.end_line == -1:
            fs.end_line = toks[i].line if i < n else toks[-1].line


def index_tree(root: Path, subdirs: tuple[str, ...] = ("rust/src",)) -> list[FileIndex]:
    """Index every `*.rs` file under the given subdirectories."""
    out = []
    for sub in subdirs:
        base = root / sub
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.rs")):
            out.append(index_file(path))
    return out

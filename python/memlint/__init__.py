"""memlint — the cross-layer invariant analyzer for the desk-checked fleet.

No container this repo grows in has ever had a Rust toolchain
(ROADMAP "Standing: run tier-1"), so every invariant the compiler or
`cargo test` would enforce has to be enforced some other way. memlint
is that other way: a lightweight Rust tokenizer + item walker (no
rustc, no syn) plus five rule families that cross-check the layers
that must agree:

1. ``wire-registry``    wire.rs kind ids / min-version stamps / size
                        formulas vs OPERATIONS.md vs fleet_model.py
2. ``panic-path``       no unwrap/expect/panic!/raw-index on
                        request-serving paths outside the allowlist
3. ``lock-order``       nested lock acquisitions against the declared
                        canonical order; no guard held across
                        recv/socket I/O
4. ``doc-symbol``       every symbol cited in DESIGN/OPERATIONS/
                        EXPERIMENTS resolves to a real item
5. ``mirror-coverage``  every schedule.rs model fn has a pinned
                        fleet_model.py mirror

Run it as ``python python/memlint`` from the repo root (or
``python -m memlint`` from ``python/``). Exit 0 means every rule
passed with an empty-or-justified allowlist; any drift is exit 1.
"""

from __future__ import annotations

from pathlib import Path

from memlint import rules_docs, rules_locks, rules_mirror, rules_panic, rules_wire
from memlint.findings import Allowlist, Finding, apply_allowlist
from memlint.rustlex import index_tree

PKG_DIR = Path(__file__).resolve().parent

__all__ = ["run_all", "Finding"]


def run_all(root: Path, allowlist_path: Path | None = None):
    """Run every rule family over the repo at ``root``.

    Returns ``(findings, notes, summaries)``: surviving findings after
    the allowlist, allowlist hygiene notes (stale/malformed entries —
    failures too), and per-rule summary dicts for the report.
    """
    root = Path(root).resolve()
    indexes = index_tree(root)

    findings: list[Finding] = []
    summaries: dict[str, dict] = {}

    fs, summaries["wire-registry"] = rules_wire.run(root, indexes)
    findings += fs
    fs, summaries["panic-path"] = rules_panic.run(root, indexes)
    findings += fs
    fs, summaries["lock-order"] = rules_locks.run(root, indexes, root / "rust/DESIGN.md")
    findings += fs
    fs, summaries["doc-symbol"] = rules_docs.run(root, indexes)
    findings += fs
    fs, summaries["mirror-coverage"] = rules_mirror.run(
        root, indexes, PKG_DIR / "mirror_map.json"
    )
    findings += fs

    allow = Allowlist.load(allowlist_path or PKG_DIR / "allowlist.json")
    kept, notes = apply_allowlist(findings, allow)
    summaries["allowlist"] = {
        "entries": len(allow.entries),
        "suppressed": len(findings) - len(kept),
    }
    kept.sort(key=lambda f: (f.file, f.line, f.rule, f.key))
    return kept, notes, summaries

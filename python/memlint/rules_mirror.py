"""Rule family 5 — mirror coverage.

Every top-level model function in the Rust model files (the planner's
`schedule.rs` and the hot-path accounting in `traffic.rs`) must have a
`fleet_model.py` mirror that is exercised under a hard `pin()`. The
mapping lives in `mirror_map.json` next to this module, keyed by the
Rust file's repo-relative path:

    {
      "rust/src/coordinator/planner/schedule.rs": {
        "sharded_completion": {
          "python": "model_sharded_completion",
          "pins": ["hetero uniform"]
        },
        "helper_fn": {"skip": "pure plumbing, no closed-form model"}
      },
      "rust/src/traffic.rs": { ... }
    }

Checks:

* every top-level non-test fn in each model file appears in its map
  (mapped or explicitly skipped with a reason);
* every mapped `python` function is defined in fleet_model.py AND
  called there (a mirror that exists but never runs pins nothing);
* every listed pin tag appears verbatim in fleet_model.py — tags are
  the third argument of `pin(got, want, tag)`, so a missing tag means
  the pin was deleted or renamed;
* stale map entries (Rust fn gone, or a mapped file the rule no longer
  tracks) are findings too — the map must shrink with the code.
"""

from __future__ import annotations

import ast
import json
from pathlib import Path

from memlint.findings import Finding
from memlint.rustlex import FileIndex

RULE = "mirror-coverage"

# The Rust files whose top-level fns ARE the latency/traffic models.
MODEL_RELS = [
    "rust/src/coordinator/planner/schedule.rs",
    "rust/src/traffic.rs",
]
MODEL_REL = "python/fleet_model.py"


def model_fns(idx: FileIndex) -> dict[str, int]:
    """Top-level (not impl-method, not test) fns in one model file."""
    return {
        fn.name: fn.start_line
        for fn in idx.fns
        if fn.depth == 0 and fn.context == "" and not fn.in_test
    }


def model_defs_and_calls(model_py: Path) -> tuple[set[str], set[str], str]:
    src = model_py.read_text(encoding="utf-8")
    tree = ast.parse(src)
    defs = {
        n.name for n in ast.walk(tree) if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    calls = {
        n.func.id
        for n in ast.walk(tree)
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
    }
    return defs, calls, src


def run(root: Path, indexes: list[FileIndex], map_path: Path) -> tuple[list[Finding], dict]:
    findings: list[Finding] = []

    def flag(file, line, key, msg):
        findings.append(Finding(RULE, file, line, key, msg))

    if not map_path.exists():
        return (
            [Finding(RULE, "python/memlint/mirror_map.json", 1, "missing", "mirror_map.json not found")],
            {},
        )
    mapping: dict[str, dict] = json.loads(map_path.read_text(encoding="utf-8"))

    model_py = root / MODEL_REL
    if not model_py.exists():
        return [Finding(RULE, MODEL_REL, 1, "missing", "fleet_model.py not found")], {}
    defs, calls, model_src = model_defs_and_calls(model_py)

    by_rel = {i.path.relative_to(root).as_posix(): i for i in indexes}
    total_fns = 0
    mapped = 0
    for rel in MODEL_RELS:
        idx = by_rel.get(rel)
        if idx is None:
            flag(rel, 1, f"missing:{rel}", f"model file {rel} not found")
            continue
        fns = model_fns(idx)
        total_fns += len(fns)
        file_map = mapping.get(rel, {})
        if not isinstance(file_map, dict):
            flag(
                "python/memlint/mirror_map.json",
                1,
                f"bad-map:{rel}",
                f"mirror_map.json entry for {rel} must be an object of "
                "fn-name -> mirror entries",
            )
            continue
        for name, line in sorted(fns.items()):
            entry = file_map.get(name)
            if entry is None:
                flag(
                    rel,
                    line,
                    f"unmapped:{name}",
                    f"{rel} model fn `{name}` has no fleet_model.py mirror entry "
                    "in mirror_map.json (map it, or skip it with a reason)",
                )
                continue
            if "skip" in entry:
                if not str(entry["skip"]).strip():
                    flag(
                        rel,
                        line,
                        f"skip-empty:{name}",
                        f"mirror_map.json skips `{name}` without a reason",
                    )
                continue
            mapped += 1
            py = entry.get("python", "")
            pins = entry.get("pins", [])
            if py not in defs:
                flag(
                    MODEL_REL,
                    1,
                    f"no-def:{name}",
                    f"mirror_map.json maps `{name}` to `{py}`, which is not defined in "
                    "fleet_model.py",
                )
                continue
            if py not in calls:
                flag(
                    MODEL_REL,
                    1,
                    f"no-call:{name}",
                    f"mirror `{py}` (for `{name}`) is defined but never called in "
                    "fleet_model.py — a mirror that never runs pins nothing",
                )
            if not pins:
                flag(
                    rel,
                    line,
                    f"no-pins:{name}",
                    f"mirror_map.json entry for `{name}` lists no pin tags",
                )
            for tag in pins:
                if tag not in model_src:
                    flag(
                        MODEL_REL,
                        1,
                        f"pin-gone:{name}:{tag}",
                        f"pin tag {tag!r} (for `{name}` -> `{py}`) no longer appears in "
                        "fleet_model.py",
                    )
        for name in sorted(file_map):
            if name not in fns:
                flag(
                    rel,
                    1,
                    f"stale-map:{name}",
                    f"mirror_map.json maps `{name}`, but {rel} has no such "
                    "top-level fn — prune the entry",
                )

    for rel in sorted(mapping):
        if rel not in MODEL_RELS:
            flag(
                "python/memlint/mirror_map.json",
                1,
                f"stale-file:{rel}",
                f"mirror_map.json has a section for {rel}, which this rule does "
                "not track — prune it or add the file to MODEL_RELS",
            )

    return findings, {"rust_fns": total_fns, "mapped": mapped, "files": len(MODEL_RELS)}

"""Rule family 5 — mirror coverage.

Every top-level model function in `planner/schedule.rs` must have a
`fleet_model.py` mirror that is exercised under a hard `pin()`. The
mapping lives in `mirror_map.json` next to this module:

    {
      "sharded_completion": {
        "python": "model_sharded_completion",
        "pins": ["hetero uniform"]
      },
      "helper_fn": {"skip": "pure plumbing, no closed-form model"}
    }

Checks:

* every top-level non-test fn in schedule.rs appears in the map
  (mapped or explicitly skipped with a reason);
* every mapped `python` function is defined in fleet_model.py AND
  called there (a mirror that exists but never runs pins nothing);
* every listed pin tag appears verbatim in fleet_model.py — tags are
  the third argument of `pin(got, want, tag)`, so a missing tag means
  the pin was deleted or renamed;
* stale map entries (schedule.rs fn gone) are findings too — the map
  must shrink with the code.
"""

from __future__ import annotations

import ast
import json
from pathlib import Path

from memlint.findings import Finding
from memlint.rustlex import FileIndex

RULE = "mirror-coverage"

SCHED_REL = "rust/src/coordinator/planner/schedule.rs"
MODEL_REL = "python/fleet_model.py"


def schedule_fns(idx: FileIndex) -> dict[str, int]:
    """Top-level (not impl-method, not test) fns in schedule.rs."""
    return {
        fn.name: fn.start_line
        for fn in idx.fns
        if fn.depth == 0 and fn.context == "" and not fn.in_test
    }


def model_defs_and_calls(model_py: Path) -> tuple[set[str], set[str], str]:
    src = model_py.read_text(encoding="utf-8")
    tree = ast.parse(src)
    defs = {
        n.name for n in ast.walk(tree) if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
    }
    calls = {
        n.func.id
        for n in ast.walk(tree)
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
    }
    return defs, calls, src


def run(root: Path, indexes: list[FileIndex], map_path: Path) -> tuple[list[Finding], dict]:
    findings: list[Finding] = []

    def flag(file, line, key, msg):
        findings.append(Finding(RULE, file, line, key, msg))

    sched_idx = next(
        (i for i in indexes if i.path.relative_to(root).as_posix() == SCHED_REL), None
    )
    if sched_idx is None:
        return [Finding(RULE, SCHED_REL, 1, "missing", "schedule.rs not found")], {}
    fns = schedule_fns(sched_idx)

    if not map_path.exists():
        return (
            [Finding(RULE, "python/memlint/mirror_map.json", 1, "missing", "mirror_map.json not found")],
            {"rust_fns": len(fns)},
        )
    mapping: dict[str, dict] = json.loads(map_path.read_text(encoding="utf-8"))

    model_py = root / MODEL_REL
    if not model_py.exists():
        return [Finding(RULE, MODEL_REL, 1, "missing", "fleet_model.py not found")], {}
    defs, calls, model_src = model_defs_and_calls(model_py)

    mapped = 0
    for name, line in sorted(fns.items()):
        entry = mapping.get(name)
        if entry is None:
            flag(
                SCHED_REL,
                line,
                f"unmapped:{name}",
                f"schedule.rs model fn `{name}` has no fleet_model.py mirror entry "
                "in mirror_map.json (map it, or skip it with a reason)",
            )
            continue
        if "skip" in entry:
            if not str(entry["skip"]).strip():
                flag(
                    SCHED_REL,
                    line,
                    f"skip-empty:{name}",
                    f"mirror_map.json skips `{name}` without a reason",
                )
            continue
        mapped += 1
        py = entry.get("python", "")
        pins = entry.get("pins", [])
        if py not in defs:
            flag(
                MODEL_REL,
                1,
                f"no-def:{name}",
                f"mirror_map.json maps `{name}` to `{py}`, which is not defined in "
                "fleet_model.py",
            )
            continue
        if py not in calls:
            flag(
                MODEL_REL,
                1,
                f"no-call:{name}",
                f"mirror `{py}` (for `{name}`) is defined but never called in "
                "fleet_model.py — a mirror that never runs pins nothing",
            )
        if not pins:
            flag(
                SCHED_REL,
                line,
                f"no-pins:{name}",
                f"mirror_map.json entry for `{name}` lists no pin tags",
            )
        for tag in pins:
            if tag not in model_src:
                flag(
                    MODEL_REL,
                    1,
                    f"pin-gone:{name}:{tag}",
                    f"pin tag {tag!r} (for `{name}` -> `{py}`) no longer appears in "
                    "fleet_model.py",
                )

    for name in sorted(mapping):
        if name not in fns:
            flag(
                SCHED_REL,
                1,
                f"stale-map:{name}",
                f"mirror_map.json maps `{name}`, but schedule.rs has no such "
                "top-level fn — prune the entry",
            )

    return findings, {"rust_fns": len(fns), "mapped": mapped}

#!/usr/bin/env python3
"""Relative-link checker for the repo's Markdown docs (CI's docs step).

Walks every ``*.md`` file under the repo root, extracts inline Markdown
links and image references, and fails (exit 1) when a *relative* target
does not exist on disk. External links (``http(s)://``, ``mailto:``)
and pure in-page anchors (``#...``) are skipped — this guards the
cross-file wiring (README → rust/OPERATIONS.md → DESIGN.md → ...), not
the internet. Anchors on existing files (``file.md#section``) are
checked for the file part only.

Usage: ``python3 python/check_links.py [repo_root]``
"""

import re
import sys
from pathlib import Path

# Inline links/images: [text](target) — tolerates titles ("...") and
# angle-bracketed targets; reference-style links are rare here and the
# repo does not use them.
LINK = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\s*\)")

SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
SKIP_DIRS = {".git", "target", ".github"}


def iter_markdown(root: Path):
    for path in sorted(root.rglob("*.md")):
        if not any(part in SKIP_DIRS for part in path.parts):
            yield path


def check(root: Path) -> int:
    broken = []
    checked = 0
    for md in iter_markdown(root):
        text = md.read_text(encoding="utf-8")
        for match in LINK.finditer(text):
            target = match.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            checked += 1
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (md.parent / rel).resolve()
            if not resolved.exists():
                line = text[: match.start()].count("\n") + 1
                broken.append(f"{md.relative_to(root)}:{line}: broken link -> {target}")
    for b in broken:
        print(b)
    print(f"checked {checked} relative links across the repo's *.md files: "
          f"{len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(check(Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()))

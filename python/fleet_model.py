#!/usr/bin/env python3
"""Independent mirror of the Rust fleet latency models, for cross-checking.

Re-implements, from the written model definitions only (not the Rust
source), the closed forms and the event scheduler behind:

* ``sorter::merge::model_streamed_completion`` (greedy earliest-ready
  single-engine schedule over the fixed fanout-f merge tree),
* ``model_streamed_completion_uniform`` (closed form, equal arrivals),
* ``model_sharded_completion`` / ``model_sharded_completion_hetero``
  (per-shard engines draining in parallel + one cross-shard merge),
* ``apportion_chunks`` (largest-remainder deal, degenerate weights
  clamped),
* ``planner::schedule`` (the unified fleet-schedule layer: W(c, f)
  merge work, per-lane ready/drain times, the lexicographic deal score
  and the completion-balanced steepest-descent search),
* ``planner::shard_model`` + ``Plan::estimated_cycles_hetero``
  (completion-balanced streaming side) and its arrival-balanced legacy
  form.

Running this file prints the pinned numbers used by the Rust tests and
the EXPERIMENTS.md §Heterogeneous shard scaling table, and hard-asserts
every pin, so a reviewer without a Rust toolchain can still validate
the models — and CI fails on any Rust-vs-mirror drift:

    python3 python/fleet_model.py
"""

from fractions import Fraction
from math import floor, isfinite


def model_merge_passes(runs: int, fanout: int) -> int:
    assert fanout >= 2
    passes = 0
    while runs > 1:
        runs = -(-runs // fanout)  # ceil div
        passes += 1
    return passes


def model_merge_cycles(n: int, runs: int, fanout: int) -> int:
    return n * model_merge_passes(runs, fanout)


def model_streamed_completion(leaves, fanout: int) -> int:
    """Greedy earliest-ready schedule of one merge engine over the fixed
    fanout-`fanout` tree; `leaves` are (arrival, len) in chunk order."""
    assert fanout >= 2
    if not leaves:
        return 0
    lens = [[l for (_, l) in leaves]]
    ready = [[a for (a, _) in leaves]]
    while len(lens[-1]) > 1:
        prev = lens[-1]
        lens.append([sum(prev[i:i + fanout]) for i in range(0, len(prev), fanout)])
        ready.append([None] * len(lens[-1]))
    depth = len(lens)
    engine_free = 0
    while True:
        changed = True
        while changed:  # single-run groups pass through for free
            changed = False
            for lev in range(1, depth):
                for g in range(len(lens[lev])):
                    lo, hi = g * fanout, min(g * fanout + fanout, len(lens[lev - 1]))
                    if ready[lev][g] is None and hi - lo == 1:
                        if ready[lev - 1][lo] is not None:
                            ready[lev][g] = ready[lev - 1][lo]
                            changed = True
        if ready[depth - 1][0] is not None:
            return ready[depth - 1][0]
        pick = None
        for lev in range(1, depth):
            for g in range(len(lens[lev])):
                if ready[lev][g] is not None:
                    continue
                lo, hi = g * fanout, min(g * fanout + fanout, len(lens[lev - 1]))
                ins = ready[lev - 1][lo:hi]
                if any(r is None for r in ins):
                    continue
                key = (max(ins, default=0), lev, g)
                if pick is None or key < pick:
                    pick = key
        inputs_ready, lev, g = pick
        done = max(engine_free, inputs_ready) + lens[lev][g]
        ready[lev][g] = done
        engine_free = done


def model_streamed_completion_uniform(chunks: int, length: int, arrival: int,
                                      fanout: int) -> int:
    assert fanout >= 2
    if chunks == 0:
        return 0
    counts = [1] * chunks
    work = 0
    while len(counts) > 1:
        nxt = []
        for i in range(0, len(counts), fanout):
            g = counts[i:i + fanout]
            c = sum(g)
            if len(g) > 1:
                work += c * length
            nxt.append(c)
        counts = nxt
    return arrival + work


def model_sharded_completion_hetero(length: int, deal, fanout: int) -> int:
    leaves = [(model_streamed_completion_uniform(c, length, a, fanout), c * length)
              for (c, a) in deal if c > 0]
    return model_streamed_completion(leaves, fanout)


def model_sharded_completion(chunks: int, length: int, arrival: int, shards: int,
                             fanout: int) -> int:
    assert shards >= 1
    if chunks == 0:
        return 0
    shards = min(shards, chunks)
    base, extra = divmod(chunks, shards)
    deal = [(base + (1 if s < extra else 0), arrival) for s in range(shards)]
    return model_sharded_completion_hetero(length, deal, fanout)


def apportion_chunks(chunks: int, weights) -> list:
    """Largest-remainder deal; ties go to the lower shard id. Uses exact
    rational quotas so the mirror has no float-tie ambiguity.

    Degenerate weights (NaN, infinities, zero, negative) are clamped to
    zero exactly as in the Rust model (``is_finite() && w > 0``); an
    all-degenerate vector falls back to uniform, so every chunk is
    always dealt. (An earlier revision let ``+inf`` through the filter,
    which raised on ``Fraction(inf)`` instead of clamping.)"""
    sane = [Fraction(w).limit_denominator(10**12) if (isfinite(w) and w > 0) else Fraction(0)
            for w in weights]
    if sum(sane) == 0:
        sane = [Fraction(1)] * len(weights)
    total = sum(sane)
    quotas = [Fraction(chunks) * w / total for w in sane]
    deal = [floor(q) for q in quotas]
    rem = chunks - sum(deal)
    order = sorted(range(len(sane)), key=lambda s: (-(quotas[s] - floor(quotas[s])), s))
    for s in order[:rem]:
        deal[s] += 1
    return deal


def round_half_away(x: float) -> int:
    """Rust's f64::round (half away from zero, for non-negative x here);
    Python's built-in round() is banker's rounding and would diverge
    from the Rust model on exact .5 products."""
    return floor(x + 0.5)


def model_hedge_deadline(length: int, cyc: float, mult: float, floor: int) -> int:
    """Mirror of ``sorter::merge::model_hedge_deadline``: the straggler
    bound is `mult` times the modelled leaf arrival ``round(len*cyc)``,
    floored."""
    return max(round_half_away(length * cyc * mult), floor)


def hedge_completion(primary: float, deadline: int, fresh: float):
    """Hedge-once semantics for one request: a primary reply slower
    than `deadline` triggers one speculative copy that completes a
    `fresh` draw after the deadline; first completion wins. Returns
    (completion, fired, won)."""
    if primary <= deadline:
        return primary, False, False
    hedged = deadline + fresh
    return min(primary, hedged), True, hedged < primary


def hedge_mixture(slow_fraction: float, slow_factor: float, length: int = 1024,
                  cyc: float = 7.84, mult: float = 4.0):
    """Closed-form hedging outcome for the slow-shard mixture used in
    EXPERIMENTS.md §Remote transport: a `slow_fraction` of chunks land
    on a shard `slow_factor` times slower (inf = stalled); the rest
    arrive at the nominal ``round(len*cyc)``. Returns (deadline,
    fired fraction, win rate among fired, mean cycles without hedging,
    mean cycles with hedging)."""
    normal = round_half_away(length * cyc)
    slow = float("inf") if slow_factor == float("inf") else slow_factor * normal
    deadline = model_hedge_deadline(length, cyc, mult, 0)
    base = (1 - slow_fraction) * normal + slow_fraction * slow
    n_done, n_fired, n_won = hedge_completion(normal, deadline, normal)
    s_done, s_fired, s_won = hedge_completion(slow, deadline, normal)
    hedged = (1 - slow_fraction) * n_done + slow_fraction * s_done
    fired = (1 - slow_fraction) * n_fired + slow_fraction * s_fired
    won = (1 - slow_fraction) * (n_fired and n_won) + slow_fraction * (s_fired and s_won)
    win_rate = won / fired if fired else 0.0
    return deadline, fired, win_rate, base, hedged


def frame_bytes_job(n: int) -> int:
    """Wire bytes of a SortJob frame: 16-byte header + 8-byte count +
    4 bytes per element (coordinator::wire)."""
    return 16 + 8 + 4 * n


def frame_bytes_ok(n: int) -> int:
    """Wire bytes of a full SortOk frame (argsort present): header +
    id + sorted (8 + 4n) + order (8 + 8n) + 7x8 stats + latency +
    worker."""
    return 16 + 8 + (8 + 4 * n) + (8 + 8 * n) + 7 * 8 + 8 + 8


def frame_bytes_job_tagged(n: int, tenant_len: int) -> int:
    """Wire bytes of a v2 SortJobTagged frame: header + tenant string
    (8-byte length + bytes) + 1 priority byte + 8-byte count + 4 bytes
    per element = 33 + t + 4n."""
    return 16 + (8 + tenant_len) + 1 + 8 + 4 * n


def model_coalescing(lens, tenant_len: int):
    """Mirror of ``planner::model_coalescing``: a request's round-trip
    envelope (tagged job + full response, minus the per-element 16 B)
    is a fixed ``145 + t`` bytes, so folding k same-class requests into
    one carrier job saves exactly ``(k-1) * (145 + t)``. Returns
    (solo_bytes, coalesced_bytes)."""
    fixed = 145 + tenant_len
    solo = sum(fixed + 16 * n for n in lens)
    coalesced = 0 if not lens else fixed + 16 * sum(lens)
    return solo, coalesced


def concurrent_makespan(clients: int, jobs: int, n: int, workers: int,
                        cyc: float) -> int:
    """Makespan of `clients` connections each pipelining `jobs`
    bank-sized sorts into ONE shard host with `workers` workers: every
    job is in flight up front (the sessions share the worker pool, not
    a per-connection lock), so the pool drains ceil(total / workers)
    rounds of ``round(n * cyc)`` cycles. Aggregate throughput is flat
    in C at ``workers / cyc`` elem/cycle; per-client latency grows
    linearly in C."""
    total = clients * jobs
    return -(-total // workers) * round_half_away(n * cyc)


def shard_model(bank: int, fanout: int, largest_bank: int, cyc: float):
    """(arrival, weight, oversize) for one shard at a (bank, fanout)
    candidate. `arrival` is when the shard's FIRST chunk run exists
    (one sort plus one assembly pass on an undersized host); the
    scoring charges one further `oversize` per additional dealt chunk,
    since the assembly shares the shard's serialized merge engine."""
    oversize = (model_merge_cycles(bank, -(-bank // largest_bank), fanout)
                if bank > largest_bank else 0)
    arrival = round_half_away(bank * cyc) + oversize
    return arrival, 1.0 / max(arrival, 1), oversize


def hetero_streamed(n: int, bank: int, fanout: int, shards, cyc=7.84) -> int:
    """Streaming ``Plan::estimated_cycles_hetero_arrival_balanced`` for a
    ChunkMerge plan — the legacy weight-proportional deal. `shards` is a
    list of (largest_bank, cyc_per_num)."""
    chunks = -(-n // bank)
    models = [shard_model(bank, fanout, lb, c) for (lb, c) in shards]
    deal = apportion_chunks(chunks, [w for (_, w, _) in models])
    # Effective readiness: arrival covers the first chunk's assembly;
    # each further dealt chunk adds one oversize pass on the engine.
    return model_sharded_completion_hetero(
        bank,
        [(c, a + (c - 1) * o) if c > 0 else (c, a)
         for c, (a, _, o) in zip(deal, models)],
        fanout)


# --- planner::schedule mirror --------------------------------------------
#
# The Rust schedule layer derives every fleet number from one timeline:
#
#     dispatch ──► colskip ──► arrival ──► merge-drain ──► fleet completion
#
# These functions mirror `planner::schedule` exactly: `uniform_merge_work`
# is W(c, f), `lane_drains` prices each shard's serialized engine, and
# `completion_balanced_deal` is the steepest-descent search behind the
# new `Plan::estimated_cycles_hetero` streaming arm.


def uniform_merge_work(chunks: int, fanout: int) -> int:
    """W(c, f): per-unit-length real-merge stream work of the fixed
    fanout-f tree over `chunks` equal runs (schedule::uniform_merge_work)."""
    if chunks == 0:
        return 0
    counts = [1] * chunks
    work = 0
    while len(counts) > 1:
        nxt = []
        for i in range(0, len(counts), fanout):
            g = counts[i:i + fanout]
            c = sum(g)
            if len(g) > 1:
                work += c
            nxt.append(c)
        counts = nxt
    return work


def lane_ready(c: int, a: int, o: int) -> int:
    """When a shard dealt `c` chunks has its LAST run ready: arrival plus
    one oversize assembly pass per further chunk (schedule::Lane)."""
    return a + (c - 1) * o if c > 0 else a


def lane_drains(length, deal, models, fanout, wmemo):
    """Per-shard merge-drain times (0 for empty lanes); `wmemo` memoizes
    W(c, f) across scoring calls."""
    drains = []
    for c, (a, w, o) in zip(deal, models):
        if c == 0:
            drains.append(0)
            continue
        if c not in wmemo:
            wmemo[c] = uniform_merge_work(c, fanout)
        drains.append(lane_ready(c, a, o) + wmemo[c] * length)
    return drains


def fleet_completion(length, deal, models, fanout, wmemo):
    """Fleet completion of a deal: each non-empty lane contributes a
    (drain, c*length) leaf to the cross-shard merge engine
    (schedule::FleetSchedule::from_deal)."""
    drains = lane_drains(length, deal, models, fanout, wmemo)
    leaves = [(d, c * length) for (d, c) in zip(drains, deal) if c > 0]
    return model_streamed_completion(leaves, fanout)


def deal_score(length, deal, models, fanout, wmemo):
    """(fleet completion, per-lane drains sorted descending).

    The secondary key lets descent walk across completion plateaus
    (two tied-max lanes: moving a chunk off one leaves the max on its
    twin, so completion alone never strictly improves)."""
    drains = lane_drains(length, deal, models, fanout, wmemo)
    leaves = [(d, c * length) for (d, c) in zip(drains, deal) if c > 0]
    return (model_streamed_completion(leaves, fanout),
            tuple(sorted(drains, reverse=True)))


def completion_balanced_deal(chunks, models, length, fanout):
    """Mirror of ``schedule::completion_balanced_deal``: seed with the
    arrival-proportional deal, then steepest descent over single-chunk
    moves scored lexicographically by `deal_score`. Identical fleets
    return the seed untouched (the uniform-reduction guard)."""
    deal = apportion_chunks(chunks, [w for (_, w, _) in models])
    if chunks == 0 or all(m == models[0] for m in models):
        return deal
    wmemo = {}
    best = deal_score(length, deal, models, fanout, wmemo)
    n = len(models)
    for _ in range(2 * chunks * n):
        move = None
        for i in range(n):
            if deal[i] == 0:
                continue
            for j in range(n):
                if i == j:
                    continue
                deal[i] -= 1
                deal[j] += 1
                s = deal_score(length, deal, models, fanout, wmemo)
                deal[i] += 1
                deal[j] -= 1
                if s < best and (move is None or s < move[0]):
                    move = (s, i, j)
        if move is None:
            break
        best = move[0]
        i, j = move[1], move[2]
        deal[i] -= 1
        deal[j] += 1
    return deal


def hetero_arrival(n: int, bank: int, fanout: int, shards, cyc_ignored=None):
    """(deal, completion) of the legacy arrival-balanced schedule —
    FleetSchedule::arrival_balanced. `shards` is (largest_bank, cyc)."""
    chunks = -(-n // bank)
    models = [shard_model(bank, fanout, lb, c) for (lb, c) in shards]
    deal = apportion_chunks(chunks, [w for (_, w, _) in models])
    return deal, fleet_completion(bank, deal, models, fanout, {})


def hetero_completion(n: int, bank: int, fanout: int, shards, cyc_ignored=None):
    """(deal, completion) of the completion-balanced schedule — the new
    streaming ``Plan::estimated_cycles_hetero`` path
    (FleetSchedule::completion_balanced)."""
    chunks = -(-n // bank)
    models = [shard_model(bank, fanout, lb, c) for (lb, c) in shards]
    deal = completion_balanced_deal(chunks, models, bank, fanout)
    return deal, fleet_completion(bank, deal, models, fanout, {})


def pin(got, want, tag):
    """Hard pin: any drift between this mirror and the Rust models is a
    CI failure, not a warning."""
    assert got == want, f"{tag}: mirror {got} != pinned {want}"
    return got


def main():
    print("== cross-checks for the Rust unit tests ==")
    print("merge::hetero_model_penalizes_slow_shards (len=1024, fanout=4):")
    print("  uniform 8x2@8028 :",
          pin(model_sharded_completion(8, 1024, 8028, 2, 4), 20_316, "hetero uniform"))
    print("  even (4,8028)(4,16056):",
          pin(model_sharded_completion_hetero(1024, [(4, 8028), (4, 16056)], 4),
              28_344, "hetero even"))
    print("  skew (5,8028)(3,16056):",
          pin(model_sharded_completion_hetero(1024, [(5, 8028), (3, 16056)], 4),
              27_320, "hetero skew"))

    print("merge::degenerate_weight_deals_account_for_every_chunk:")
    pin(apportion_chunks(4, [float("inf"), 2.0]), [0, 4], "deal inf")
    pin(apportion_chunks(4, [-3.0, 2.0]), [0, 4], "deal negative")
    pin(apportion_chunks(5, [float("nan"), float("inf"), -1.0]), [2, 2, 1],
        "deal all-degenerate")
    pin(apportion_chunks(6, [float("-inf"), -0.0, 0.0]), [2, 2, 2], "deal zeros")
    pin(apportion_chunks(0, [float("nan")] * 2), [0, 0], "deal empty")
    print("  degenerate weights clamp as in Rust: OK")

    print("planner::hetero_fleet_scores_worse_with_a_slow_shard "
          "(n=50k, bank=1024, fanout=4):")
    uniform = [(1024, 7.84)] * 2
    mixed = [(1024, 7.84), (1024, 15.68)]
    all_slow = [(1024, 15.68)] * 2
    print("  uniform  :", pin(hetero_streamed(50_000, 1024, 4, uniform),
                              133_980, "50k uniform"))
    print("  mixed (legacy arrival-balanced):",
          pin(hetero_streamed(50_000, 1024, 4, mixed), 157_532, "50k mixed legacy"))
    print("  all-slow :", pin(hetero_streamed(50_000, 1024, 4, all_slow),
                              142_008, "50k all-slow"))
    deal, cycles = hetero_completion(50_000, 1024, 4, mixed)
    pin(cycles, 138_076, "50k mixed balanced")
    pin(deal, [26, 23], "50k mixed balanced deal")
    print(f"  mixed (completion-balanced)    : {cycles} (deal {deal})")

    print("uniform reduction spot-check (n=1M, bank=1024, fanout=4, cyc=7.84):")
    chunks = -(-1_000_000 // 1024)
    arrival = round_half_away(1024 * 7.84)
    sharded_pins = {1: 5_008_220, 2: 3_511_132, 3: 2_671_452, 4: 2_010_972}
    for s in [1, 2, 3, 4, 8, 16]:
        uni = model_sharded_completion(chunks, 1024, arrival, s, 4)
        het = hetero_streamed(1_000_000, 1024, 4, [(1024, 7.84)] * s)
        assert uni == het, (s, uni, het)
        _, bal = hetero_completion(1_000_000, 1024, 4, [(1024, 7.84)] * s)
        assert uni == bal, (s, uni, bal)
        if s in sharded_pins:
            pin(uni, sharded_pins[s], f"sharded s={s}")
        print(f"  shards={s:2d}: {uni}")

    print()
    print("== EXPERIMENTS.md §Heterogeneous shard scaling "
          "(n=1M, bank=1024, fanout=4) ==")
    # Each row pins BOTH generations: the legacy arrival-balanced deal
    # (kept in EXPERIMENTS.md for comparison) and the completion-balanced
    # schedule the planner now routes on. The acceptance criterion —
    # completion-balanced never loses — is asserted per row.
    fleets = [
        ("4x nominal (7.84)", [(1024, 7.84)] * 4,
         2_010_972, 2_010_972, [245, 244, 244, 244]),
        ("2x nominal + 2x half-speed (15.68)",
         [(1024, 7.84)] * 2 + [(1024, 15.68)] * 2,
         2_671_452, 2_011_832, [245, 245, 244, 243]),
        ("4x half-speed (15.68)", [(1024, 15.68)] * 4,
         2_019_000, 2_019_000, [245, 244, 244, 244]),
        ("2x 1024-bank + 2x 512-bank (7.84)",
         [(1024, 7.84)] * 2 + [(512, 7.84)] * 2,
         2_325_340, 2_200_412, [256, 256, 233, 232]),
        ("1x nominal + 3x half-speed", [(1024, 7.84)] + [(1024, 15.68)] * 3,
         3_003_228, 2_011_832, [245, 244, 244, 244]),
    ]
    for name, shards, want_arr, want_bal, want_deal in fleets:
        legacy_deal, legacy = hetero_arrival(1_000_000, 1024, 4, shards)
        deal, balanced = hetero_completion(1_000_000, 1024, 4, shards)
        pin(hetero_streamed(1_000_000, 1024, 4, shards), legacy, f"{name} legacy path")
        pin(legacy, want_arr, f"{name} arrival-balanced")
        pin(balanced, want_bal, f"{name} completion-balanced")
        pin(deal, want_deal, f"{name} deal")
        assert balanced <= legacy, (name, balanced, legacy)
        saved = 100 * (legacy - balanced) / legacy
        print(f"  {name:38s}: arrival {legacy:>9d} (deal {legacy_deal}) -> "
              f"completion {balanced:>9d} (deal {deal}, saved {saved:.1f}%)")

    print()
    print("== EXPERIMENTS.md §Remote transport ==")
    print("wire overhead (coordinator::wire, pinned by "
          "frame_sizes_match_the_documented_overhead_model):")
    for n in [1024, 512]:
        print(f"  n={n:4d}: SortJob {frame_bytes_job(n)} B "
              f"({frame_bytes_job(n) / n:.2f} B/elem), "
              f"SortOk {frame_bytes_ok(n)} B ({frame_bytes_ok(n) / n:.2f} B/elem)")
    print("hedge deadline (merge::model_hedge_deadline, bank=1024, cyc=7.84):")
    for mult, want in [(1.0, 8_028), (2.0, 16_056), (4.0, 32_113)]:
        print(f"  mult={mult}: "
              f"{pin(model_hedge_deadline(1024, 7.84, mult, 0), want, f'hedge x{mult}')}"
              " cycles")
    print("hedging under a 25% slow-shard mixture (mult=4, hedge-once, "
          "fresh draw = nominal):")
    for factor in [2.0, 4.0, 8.0, float("inf")]:
        deadline, fired, win, base, hedged = hedge_mixture(0.25, factor)
        gain = "inf" if base == float("inf") else f"{100 * (1 - hedged / base):.1f}%"
        base_s = "inf" if base == float("inf") else f"{base:.0f}"
        print(f"  slow x{factor:<4}: fired {100 * fired:.0f}%, win rate "
              f"{100 * win:.0f}%, mean {base_s} -> {hedged:.0f} cycles ({gain} saved, "
              f"deadline {deadline})")

    print()
    print("== EXPERIMENTS.md §Concurrent request plane ==")
    t = len("acme")
    # The fixed envelope is the whole round trip minus the 16 B/elem.
    assert frame_bytes_job_tagged(64, t) + frame_bytes_ok(64) == (145 + t) + 16 * 64
    print(f"tagged job frame (tenant 'acme', t={t}): n=64 -> "
          f"{frame_bytes_job_tagged(64, t)} B; round-trip envelope "
          f"145+t = {145 + t} B/request + 16 B/elem")
    print("coalescing (planner::model_coalescing, tenant 'acme'):")
    packs = [("8 x 64", [64] * 8), ("4 x 64", [64] * 4), ("8 x 16", [16] * 8),
             ("17+13+30 (uneven)", [17, 13, 30])]
    for name, lens in packs:
        solo, coalesced = model_coalescing(lens, t)
        saved = solo - coalesced
        assert saved == (len(lens) - 1) * (145 + t), (name, saved)
        print(f"  {name:18s}: solo {solo:5d} B -> carrier {coalesced:5d} B "
              f"(saved {saved} = {len(lens) - 1}*{145 + t}, "
              f"{100 * saved / solo:.1f}%)")
    print("concurrent makespan (one host, workers=4, 32 jobs/client, "
          "bank=1024, cyc=7.84):")
    makespan_pins = {1: 64_224, 2: 128_448, 4: 256_896, 8: 513_792}
    for c in [1, 2, 4, 8]:
        m = pin(concurrent_makespan(c, 32, 1024, 4, 7.84), makespan_pins[c],
                f"makespan C={c}")
        agg = c * 32 * 1024 / m
        print(f"  C={c}: makespan {m:>7d} cycles, aggregate {agg:.3f} elem/cyc, "
              f"per-client {agg / c:.3f}")
    pin(concurrent_makespan(1, 3, 1024, 2, 7.84), 16_056, "makespan 3-job/2-worker")


if __name__ == "__main__":
    main()

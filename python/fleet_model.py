#!/usr/bin/env python3
"""Independent mirror of the Rust fleet latency models, for cross-checking.

Re-implements, from the written model definitions only (not the Rust
source), the closed forms and the event scheduler behind:

* ``sorter::merge::model_streamed_completion`` (greedy earliest-ready
  single-engine schedule over the fixed fanout-f merge tree),
* ``model_streamed_completion_uniform`` (closed form, equal arrivals),
* ``model_sharded_completion`` / ``model_sharded_completion_hetero``
  (per-shard engines draining in parallel + one cross-shard merge),
* ``apportion_chunks`` (largest-remainder deal, degenerate weights
  clamped),
* ``planner::schedule`` (the unified fleet-schedule layer: W(c, f)
  merge work, per-lane ready/drain times, the lexicographic deal score
  and the completion-balanced steepest-descent search),
* ``planner::shard_model`` + ``Plan::estimated_cycles_hetero``
  (completion-balanced streaming side) and its arrival-balanced legacy
  form,
* ``schedule::spill_io_cycles`` / ``schedule::spill_completion`` (the
  out-of-core spill tier's I/O surcharge — 12 B/elem over an
  8 B/cycle device, 2·passes crossings — and the spilled completion it
  prices into the budgeted auto-tuner),
* ``traffic`` (the hot-path word-traffic accounting: mask words per
  column step for the reference vs fused colskip kernels, and bytes
  copied per SortJob→SortOk round trip for the owned vs reusable-buffer
  wire paths) — backed by a bit-exact colskip simulator over the same
  dataset generators as ``datasets``, so the per-kind reductions in
  EXPERIMENTS.md §Hot-path word traffic are *recomputed* here, not
  transcribed.

Running this file prints the pinned numbers used by the Rust tests and
the EXPERIMENTS.md §Heterogeneous shard scaling table, and hard-asserts
every pin, so a reviewer without a Rust toolchain can still validate
the models — and CI fails on any Rust-vs-mirror drift:

    python3 python/fleet_model.py
"""

import math
from fractions import Fraction
from math import floor, isfinite


def model_merge_passes(runs: int, fanout: int) -> int:
    assert fanout >= 2
    passes = 0
    while runs > 1:
        runs = -(-runs // fanout)  # ceil div
        passes += 1
    return passes


def model_merge_cycles(n: int, runs: int, fanout: int) -> int:
    return n * model_merge_passes(runs, fanout)


def model_streamed_completion(leaves, fanout: int) -> int:
    """Greedy earliest-ready schedule of one merge engine over the fixed
    fanout-`fanout` tree; `leaves` are (arrival, len) in chunk order."""
    assert fanout >= 2
    if not leaves:
        return 0
    lens = [[l for (_, l) in leaves]]
    ready = [[a for (a, _) in leaves]]
    while len(lens[-1]) > 1:
        prev = lens[-1]
        lens.append([sum(prev[i:i + fanout]) for i in range(0, len(prev), fanout)])
        ready.append([None] * len(lens[-1]))
    depth = len(lens)
    engine_free = 0
    while True:
        changed = True
        while changed:  # single-run groups pass through for free
            changed = False
            for lev in range(1, depth):
                for g in range(len(lens[lev])):
                    lo, hi = g * fanout, min(g * fanout + fanout, len(lens[lev - 1]))
                    if ready[lev][g] is None and hi - lo == 1:
                        if ready[lev - 1][lo] is not None:
                            ready[lev][g] = ready[lev - 1][lo]
                            changed = True
        if ready[depth - 1][0] is not None:
            return ready[depth - 1][0]
        pick = None
        for lev in range(1, depth):
            for g in range(len(lens[lev])):
                if ready[lev][g] is not None:
                    continue
                lo, hi = g * fanout, min(g * fanout + fanout, len(lens[lev - 1]))
                ins = ready[lev - 1][lo:hi]
                if any(r is None for r in ins):
                    continue
                key = (max(ins, default=0), lev, g)
                if pick is None or key < pick:
                    pick = key
        inputs_ready, lev, g = pick
        done = max(engine_free, inputs_ready) + lens[lev][g]
        ready[lev][g] = done
        engine_free = done


def model_streamed_completion_uniform(chunks: int, length: int, arrival: int,
                                      fanout: int) -> int:
    assert fanout >= 2
    if chunks == 0:
        return 0
    counts = [1] * chunks
    work = 0
    while len(counts) > 1:
        nxt = []
        for i in range(0, len(counts), fanout):
            g = counts[i:i + fanout]
            c = sum(g)
            if len(g) > 1:
                work += c * length
            nxt.append(c)
        counts = nxt
    return arrival + work


def model_sharded_completion_hetero(length: int, deal, fanout: int) -> int:
    leaves = [(model_streamed_completion_uniform(c, length, a, fanout), c * length)
              for (c, a) in deal if c > 0]
    return model_streamed_completion(leaves, fanout)


def model_sharded_completion(chunks: int, length: int, arrival: int, shards: int,
                             fanout: int) -> int:
    assert shards >= 1
    if chunks == 0:
        return 0
    shards = min(shards, chunks)
    base, extra = divmod(chunks, shards)
    deal = [(base + (1 if s < extra else 0), arrival) for s in range(shards)]
    return model_sharded_completion_hetero(length, deal, fanout)


SPILL_BYTES_PER_ELEM = 12   # schedule::SPILL_BYTES_PER_ELEM (u32 value + u64 row)
SPILL_BYTES_PER_CYC = 8     # schedule::SPILL_BYTES_PER_CYC (64-bit channel @500MHz)


def spill_io_cycles(n: int, chunks: int, fanout: int) -> int:
    """Mirror of ``schedule::spill_io_cycles``: extra device I/O cycles
    the out-of-core merge pays over the resident merge. Every element
    crosses the spill device ``2*passes`` times (write + read per merge
    pass; ``2`` for the degenerate single-run case), at 12 B/elem over
    an 8 B/cycle device, ceil-divided so the cost never rounds to 0."""
    assert fanout >= 2
    if n == 0:
        return 0
    passes, r = 0, chunks
    while r > 1:
        passes += 1
        r = -(-r // fanout)
    crossings = 2 * max(passes, 1)
    return -(-(n * SPILL_BYTES_PER_ELEM * crossings) // SPILL_BYTES_PER_CYC)


def model_spill_completion(chunks: int, length: int, arrival: int,
                           fanout: int) -> int:
    """Mirror of ``schedule::spill_completion`` (surfaced in Rust as
    ``planner::model_spill_completion``): the resident uniform streamed
    completion plus the spill I/O surcharge. Strictly above the
    resident completion for any non-empty input, which is why the
    budgeted auto-tuner picks spill only when the memory budget forces
    it."""
    if chunks == 0:
        assert fanout >= 2
        return 0
    return (model_streamed_completion_uniform(chunks, length, arrival, fanout)
            + spill_io_cycles(chunks * length, chunks, fanout))


def apportion_chunks(chunks: int, weights) -> list:
    """Largest-remainder deal; ties go to the lower shard id. Uses exact
    rational quotas so the mirror has no float-tie ambiguity.

    Degenerate weights (NaN, infinities, zero, negative) are clamped to
    zero exactly as in the Rust model (``is_finite() && w > 0``); an
    all-degenerate vector falls back to uniform, so every chunk is
    always dealt. (An earlier revision let ``+inf`` through the filter,
    which raised on ``Fraction(inf)`` instead of clamping.)"""
    sane = [Fraction(w).limit_denominator(10**12) if (isfinite(w) and w > 0) else Fraction(0)
            for w in weights]
    if sum(sane) == 0:
        sane = [Fraction(1)] * len(weights)
    total = sum(sane)
    quotas = [Fraction(chunks) * w / total for w in sane]
    deal = [floor(q) for q in quotas]
    rem = chunks - sum(deal)
    order = sorted(range(len(sane)), key=lambda s: (-(quotas[s] - floor(quotas[s])), s))
    for s in order[:rem]:
        deal[s] += 1
    return deal


def round_half_away(x: float) -> int:
    """Rust's f64::round (half away from zero, for non-negative x here);
    Python's built-in round() is banker's rounding and would diverge
    from the Rust model on exact .5 products."""
    return floor(x + 0.5)


def model_hedge_deadline(length: int, cyc: float, mult: float, floor: int) -> int:
    """Mirror of ``sorter::merge::model_hedge_deadline``: the straggler
    bound is `mult` times the modelled leaf arrival ``round(len*cyc)``,
    floored."""
    return max(round_half_away(length * cyc * mult), floor)


def hedge_completion(primary: float, deadline: int, fresh: float):
    """Hedge-once semantics for one request: a primary reply slower
    than `deadline` triggers one speculative copy that completes a
    `fresh` draw after the deadline; first completion wins. Returns
    (completion, fired, won)."""
    if primary <= deadline:
        return primary, False, False
    hedged = deadline + fresh
    return min(primary, hedged), True, hedged < primary


def hedge_mixture(slow_fraction: float, slow_factor: float, length: int = 1024,
                  cyc: float = 7.84, mult: float = 4.0):
    """Closed-form hedging outcome for the slow-shard mixture used in
    EXPERIMENTS.md §Remote transport: a `slow_fraction` of chunks land
    on a shard `slow_factor` times slower (inf = stalled); the rest
    arrive at the nominal ``round(len*cyc)``. Returns (deadline,
    fired fraction, win rate among fired, mean cycles without hedging,
    mean cycles with hedging)."""
    normal = round_half_away(length * cyc)
    slow = float("inf") if slow_factor == float("inf") else slow_factor * normal
    deadline = model_hedge_deadline(length, cyc, mult, 0)
    base = (1 - slow_fraction) * normal + slow_fraction * slow
    n_done, n_fired, n_won = hedge_completion(normal, deadline, normal)
    s_done, s_fired, s_won = hedge_completion(slow, deadline, normal)
    hedged = (1 - slow_fraction) * n_done + slow_fraction * s_done
    fired = (1 - slow_fraction) * n_fired + slow_fraction * s_fired
    won = (1 - slow_fraction) * (n_fired and n_won) + slow_fraction * (s_fired and s_won)
    win_rate = won / fired if fired else 0.0
    return deadline, fired, win_rate, base, hedged


def frame_bytes_job(n: int) -> int:
    """Wire bytes of a SortJob frame: 16-byte header + 8-byte count +
    4 bytes per element (coordinator::wire)."""
    return 16 + 8 + 4 * n


def frame_bytes_ok(n: int) -> int:
    """Wire bytes of a full SortOk frame (argsort present): header +
    id + sorted (8 + 4n) + order (8 + 8n) + 7x8 stats + latency +
    worker."""
    return 16 + 8 + (8 + 4 * n) + (8 + 8 * n) + 7 * 8 + 8 + 8


def frame_bytes_job_tagged(n: int, tenant_len: int) -> int:
    """Wire bytes of a v2 SortJobTagged frame: header + tenant string
    (8-byte length + bytes) + 1 priority byte + 8-byte count + 4 bytes
    per element = 33 + t + 4n."""
    return 16 + (8 + tenant_len) + 1 + 8 + 4 * n


def model_coalescing(lens, tenant_len: int):
    """Mirror of ``planner::model_coalescing``: a request's round-trip
    envelope (tagged job + full response, minus the per-element 16 B)
    is a fixed ``145 + t`` bytes, so folding k same-class requests into
    one carrier job saves exactly ``(k-1) * (145 + t)``. Returns
    (solo_bytes, coalesced_bytes)."""
    fixed = 145 + tenant_len
    solo = sum(fixed + 16 * n for n in lens)
    coalesced = 0 if not lens else fixed + 16 * sum(lens)
    return solo, coalesced


def concurrent_makespan(clients: int, jobs: int, n: int, workers: int,
                        cyc: float) -> int:
    """Makespan of `clients` connections each pipelining `jobs`
    bank-sized sorts into ONE shard host with `workers` workers: every
    job is in flight up front (the sessions share the worker pool, not
    a per-connection lock), so the pool drains ceil(total / workers)
    rounds of ``round(n * cyc)`` cycles. Aggregate throughput is flat
    in C at ``workers / cyc`` elem/cycle; per-client latency grows
    linearly in C."""
    total = clients * jobs
    return -(-total // workers) * round_half_away(n * cyc)


def shard_model(bank: int, fanout: int, largest_bank: int, cyc: float):
    """(arrival, weight, oversize) for one shard at a (bank, fanout)
    candidate. `arrival` is when the shard's FIRST chunk run exists
    (one sort plus one assembly pass on an undersized host); the
    scoring charges one further `oversize` per additional dealt chunk,
    since the assembly shares the shard's serialized merge engine."""
    oversize = (model_merge_cycles(bank, -(-bank // largest_bank), fanout)
                if bank > largest_bank else 0)
    arrival = round_half_away(bank * cyc) + oversize
    return arrival, 1.0 / max(arrival, 1), oversize


def hetero_streamed(n: int, bank: int, fanout: int, shards, cyc=7.84) -> int:
    """Streaming ``Plan::estimated_cycles_hetero_arrival_balanced`` for a
    ChunkMerge plan — the legacy weight-proportional deal. `shards` is a
    list of (largest_bank, cyc_per_num)."""
    chunks = -(-n // bank)
    models = [shard_model(bank, fanout, lb, c) for (lb, c) in shards]
    deal = apportion_chunks(chunks, [w for (_, w, _) in models])
    # Effective readiness: arrival covers the first chunk's assembly;
    # each further dealt chunk adds one oversize pass on the engine.
    return model_sharded_completion_hetero(
        bank,
        [(c, a + (c - 1) * o) if c > 0 else (c, a)
         for c, (a, _, o) in zip(deal, models)],
        fanout)


# --- planner::schedule mirror --------------------------------------------
#
# The Rust schedule layer derives every fleet number from one timeline:
#
#     dispatch ──► colskip ──► arrival ──► merge-drain ──► fleet completion
#
# These functions mirror `planner::schedule` exactly: `uniform_merge_work`
# is W(c, f), `lane_drains` prices each shard's serialized engine, and
# `completion_balanced_deal` is the steepest-descent search behind the
# new `Plan::estimated_cycles_hetero` streaming arm.


def uniform_merge_work(chunks: int, fanout: int) -> int:
    """W(c, f): per-unit-length real-merge stream work of the fixed
    fanout-f tree over `chunks` equal runs (schedule::uniform_merge_work)."""
    if chunks == 0:
        return 0
    counts = [1] * chunks
    work = 0
    while len(counts) > 1:
        nxt = []
        for i in range(0, len(counts), fanout):
            g = counts[i:i + fanout]
            c = sum(g)
            if len(g) > 1:
                work += c
            nxt.append(c)
        counts = nxt
    return work


def lane_ready(c: int, a: int, o: int) -> int:
    """When a shard dealt `c` chunks has its LAST run ready: arrival plus
    one oversize assembly pass per further chunk (schedule::Lane)."""
    return a + (c - 1) * o if c > 0 else a


def lane_drains(length, deal, models, fanout, wmemo):
    """Per-shard merge-drain times (0 for empty lanes); `wmemo` memoizes
    W(c, f) across scoring calls."""
    drains = []
    for c, (a, w, o) in zip(deal, models):
        if c == 0:
            drains.append(0)
            continue
        if c not in wmemo:
            wmemo[c] = uniform_merge_work(c, fanout)
        drains.append(lane_ready(c, a, o) + wmemo[c] * length)
    return drains


def fleet_completion(length, deal, models, fanout, wmemo):
    """Fleet completion of a deal: each non-empty lane contributes a
    (drain, c*length) leaf to the cross-shard merge engine
    (schedule::FleetSchedule::from_deal)."""
    drains = lane_drains(length, deal, models, fanout, wmemo)
    leaves = [(d, c * length) for (d, c) in zip(drains, deal) if c > 0]
    return model_streamed_completion(leaves, fanout)


def deal_score(length, deal, models, fanout, wmemo):
    """(fleet completion, per-lane drains sorted descending).

    The secondary key lets descent walk across completion plateaus
    (two tied-max lanes: moving a chunk off one leaves the max on its
    twin, so completion alone never strictly improves)."""
    drains = lane_drains(length, deal, models, fanout, wmemo)
    leaves = [(d, c * length) for (d, c) in zip(drains, deal) if c > 0]
    return (model_streamed_completion(leaves, fanout),
            tuple(sorted(drains, reverse=True)))


def completion_balanced_deal(chunks, models, length, fanout):
    """Mirror of ``schedule::completion_balanced_deal``: seed with the
    arrival-proportional deal, then steepest descent over single-chunk
    moves scored lexicographically by `deal_score`. Identical fleets
    return the seed untouched (the uniform-reduction guard)."""
    deal = apportion_chunks(chunks, [w for (_, w, _) in models])
    if chunks == 0 or all(m == models[0] for m in models):
        return deal
    wmemo = {}
    best = deal_score(length, deal, models, fanout, wmemo)
    n = len(models)
    for _ in range(2 * chunks * n):
        move = None
        for i in range(n):
            if deal[i] == 0:
                continue
            for j in range(n):
                if i == j:
                    continue
                deal[i] -= 1
                deal[j] += 1
                s = deal_score(length, deal, models, fanout, wmemo)
                deal[i] += 1
                deal[j] -= 1
                if s < best and (move is None or s < move[0]):
                    move = (s, i, j)
        if move is None:
            break
        best = move[0]
        i, j = move[1], move[2]
        deal[i] -= 1
        deal[j] += 1
    return deal


def hetero_arrival(n: int, bank: int, fanout: int, shards, cyc_ignored=None):
    """(deal, completion) of the legacy arrival-balanced schedule —
    FleetSchedule::arrival_balanced. `shards` is (largest_bank, cyc)."""
    chunks = -(-n // bank)
    models = [shard_model(bank, fanout, lb, c) for (lb, c) in shards]
    deal = apportion_chunks(chunks, [w for (_, w, _) in models])
    return deal, fleet_completion(bank, deal, models, fanout, {})


def hetero_completion(n: int, bank: int, fanout: int, shards, cyc_ignored=None):
    """(deal, completion) of the completion-balanced schedule — the new
    streaming ``Plan::estimated_cycles_hetero`` path
    (FleetSchedule::completion_balanced)."""
    chunks = -(-n // bank)
    models = [shard_model(bank, fanout, lb, c) for (lb, c) in shards]
    deal = completion_balanced_deal(chunks, models, bank, fanout)
    return deal, fleet_completion(bank, deal, models, fanout, {})


# --- traffic mirror -------------------------------------------------------
#
# Mirrors `rust/src/traffic.rs`: the closed-form word/byte costs of the
# hot paths. The per-kind operation counts they are applied to are NOT
# transcribed from Rust output — `colskip_sim` below re-derives them
# from scratch (same RNG, same dataset generators, same column-skipping
# control flow), so a drifted kernel fails these pins even without a
# Rust toolchain.


def mask_words(n: int) -> int:
    """Words per row mask: ceil(n / 64) (traffic::mask_words)."""
    return -(-n // 64)


def reference_traversal_words(n: int, crs: int, res: int, srs: int) -> int:
    """Mask words the pre-fusion kernel scans: 2W judge per CR, 3W
    exclude per informative column (RE), 2W snapshot per SR
    (traffic::reference_traversal_words)."""
    return mask_words(n) * (2 * crs + 3 * res + 2 * srs)


def fused_traversal_words(n: int, executed_crs: int) -> int:
    """Mask words the fused single-pass kernel scans: 3W per *executed*
    CR — plane, active, scratch — and zero for singleton-skipped
    columns (traffic::fused_traversal_words)."""
    return 3 * mask_words(n) * executed_crs


def roundtrip_bytes_before(n: int) -> int:
    """Bytes copied per SortJob→SortOk round trip on the owned wire
    path (traffic::roundtrip_bytes_before): each leg builds a payload
    vec, copies it into a fresh frame vec, copies the received payload
    into a fresh scratch, then copies the arrays out in decode."""
    job, ok = frame_bytes_job(n), frame_bytes_ok(n)
    return (3 * job - 32 + 4 * n) + (3 * ok - 32 + 12 * n)


def roundtrip_bytes_after(n: int) -> int:
    """Bytes copied per warm round trip on the reusable-buffer path
    (traffic::roundtrip_bytes_after): one encode into a warm buffer and
    one borrowed-view copy-out per leg; warm scratches zero-fill
    nothing."""
    return frame_bytes_job(n) + 4 * n + frame_bytes_ok(n) + 12 * n


# --- bit-exact colskip simulator (datasets:: + sorter::colskip) -----------

M64 = (1 << 64) - 1
U32_MAX = 4294967295
DATASET_KINDS = ["uniform", "normal", "clustered", "kruskal", "mapreduce"]


class _SplitMix64:
    """datasets::rng::SplitMix64 — seeds the xoshiro state."""

    def __init__(self, seed):
        self.state = seed & M64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & M64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return z ^ (z >> 31)


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class _Rng:
    """datasets::rng::Rng — xoshiro256** plus the Box-Muller normal,
    Lemire bounded draw and truncated-exponential helpers."""

    def __init__(self, seed):
        sm = _SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]
        self.spare_normal = None

    def next_u64(self):
        s = self.s
        result = (_rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result

    def next_u32(self):
        return self.next_u64() >> 32

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def normal(self):
        if self.spare_normal is not None:
            z, self.spare_normal = self.spare_normal, None
            return z
        u1 = self.f64()
        while u1 <= 0.0:
            u1 = self.f64()
        u2 = self.f64()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.spare_normal = r * math.sin(theta)
        return r * math.cos(theta)

    def exp_small(self, scale, maxv):
        u = self.f64()
        while u <= 0.0:
            u = self.f64()
        return min(int(-math.log(u) * scale), maxv)


def _clamp_u32(x: float) -> int:
    if x <= 0.0:
        return 0
    if x >= float(U32_MAX):
        return U32_MAX
    return int(x)  # trunc toward zero == Rust `as u32` for in-range


def _mapreduce_keys(n, rng):
    groups, spread, zipf_s = 8, 1100.0, 1.1
    hi, lo = math.log(float(1 << 20)), math.log(256.0)
    centers = [int(math.exp(lo + ((g + rng.f64()) / groups) * (hi - lo)))
               for g in range(groups)]
    weights = [1.0 / (r ** zipf_s) for r in range(1, groups + 1)]
    total = sum(weights)
    cdf, acc = [], 0.0
    for w in weights:
        acc += w / total
        cdf.append(acc)
    out = []
    for _ in range(n):
        u = rng.f64()
        g = next((i for i, c in enumerate(cdf) if u <= c), groups - 1)
        v = round((float(centers[g]) + spread * rng.normal()) / 8.0) * 8.0
        out.append(_clamp_u32(v))
    return out


def generate_dataset(kind: str, n: int, width: int, seed: int) -> list:
    """datasets::Dataset::generate32 truncated to `width` bits."""
    ki = DATASET_KINDS.index(kind)
    rng = _Rng((seed ^ ((ki * 0x9E3779B97F4A7C15) & M64)) & M64)
    if kind == "uniform":
        raw = [rng.next_u32() for _ in range(n)]
    elif kind == "normal":
        mean, std = 2.0 ** 31, 2.0 ** 31 / 3.0
        raw = [_clamp_u32(mean + std * rng.normal()) for _ in range(n)]
    elif kind == "clustered":
        std = 2.0 ** 13
        raw = [_clamp_u32((2.0 ** 15 if rng.f64() < 0.5 else 2.0 ** 25)
                          + std * rng.normal()) for _ in range(n)]
    elif kind == "kruskal":
        raw = [min(7 * rng.exp_small(1600.0, 1 << 22), U32_MAX) for _ in range(n)]
    else:
        raw = _mapreduce_keys(n, rng)
    shift = 32 - width
    return raw if shift == 0 else [v >> shift for v in raw]


def colskip_sim(values, width: int, k: int):
    """Bit-exact mirror of sorter::colskip with the k-entry state table,
    leading-zero skip, stall drain and the singleton fast path. Row
    masks are Python ints (bit r == row r active). Returns (sorted,
    order, stats) where stats carries the wire-visible counts plus the
    executed/skipped CR split the fused kernel's traffic depends on."""
    n = len(values)
    full = (1 << n) - 1
    planes = [0] * width
    for r, v in enumerate(values):
        for j in range(width):
            if (v >> j) & 1:
                planes[j] |= 1 << r
    stats = dict(crs=0, res=0, srs=0, sls=0, invalidations=0, drains=0,
                 iterations=0, executed=0, skipped=0)
    alive = full
    lead = None
    entries = []  # state table, oldest first: [snapshot, col]
    sorted_out, order = [], []

    def first_row(active):
        return (active & -active).bit_length() - 1

    while len(sorted_out) < n:
        stats["iterations"] += 1
        entry = None
        while entries:  # SL: discard dead entries, newest first
            if entries[-1][0] & alive:
                entry = entries[-1]
                break
            entries.pop()
            stats["invalidations"] += 1
        if entry is not None:
            stats["sls"] += 1
            active, start_col, from_msb = entry[0] & alive, entry[1], False
        else:
            active = alive
            start_col = lead if lead is not None else width - 1
            from_msb = True
        active_count = bin(active).count("1")

        first_informative = None
        col = start_col
        while col >= 0:
            if active_count == 1:
                # Singleton fast path: no remaining column can split a
                # one-row active set; charge the CRs, scan nothing.
                stats["crs"] += col + 1
                stats["skipped"] += col + 1
                break
            stats["crs"] += 1
            stats["executed"] += 1
            ones = active & planes[col]
            zeros = active & ~planes[col] & full
            if ones and zeros:
                if from_msb:
                    if first_informative is None:
                        first_informative = col
                    if k > 0:
                        if len(entries) == k:
                            entries.pop(0)
                        entries.append([active, col])
                    stats["srs"] += 1
                active = zeros
                active_count = bin(active).count("1")
                stats["res"] += 1
            col -= 1
        if from_msb and first_informative is not None:
            lead = first_informative

        row = first_row(active)
        while True:
            sorted_out.append(values[row])
            order.append(row)
            active &= ~(1 << row)
            alive &= ~(1 << row)
            if not active or len(sorted_out) == n:
                break
            stats["drains"] += 1
            row = first_row(active)
    return sorted_out, order, stats


def pin(got, want, tag):
    """Hard pin: any drift between this mirror and the Rust models is a
    CI failure, not a warning."""
    assert got == want, f"{tag}: mirror {got} != pinned {want}"
    return got


def main():
    print("== cross-checks for the Rust unit tests ==")
    print("merge::hetero_model_penalizes_slow_shards (len=1024, fanout=4):")
    print("  uniform 8x2@8028 :",
          pin(model_sharded_completion(8, 1024, 8028, 2, 4), 20_316, "hetero uniform"))
    print("  even (4,8028)(4,16056):",
          pin(model_sharded_completion_hetero(1024, [(4, 8028), (4, 16056)], 4),
              28_344, "hetero even"))
    print("  skew (5,8028)(3,16056):",
          pin(model_sharded_completion_hetero(1024, [(5, 8028), (3, 16056)], 4),
              27_320, "hetero skew"))

    print("merge::degenerate_weight_deals_account_for_every_chunk:")
    pin(apportion_chunks(4, [float("inf"), 2.0]), [0, 4], "deal inf")
    pin(apportion_chunks(4, [-3.0, 2.0]), [0, 4], "deal negative")
    pin(apportion_chunks(5, [float("nan"), float("inf"), -1.0]), [2, 2, 1],
        "deal all-degenerate")
    pin(apportion_chunks(6, [float("-inf"), -0.0, 0.0]), [2, 2, 2], "deal zeros")
    pin(apportion_chunks(0, [float("nan")] * 2), [0, 0], "deal empty")
    print("  degenerate weights clamp as in Rust: OK")

    print("planner::hetero_fleet_scores_worse_with_a_slow_shard "
          "(n=50k, bank=1024, fanout=4):")
    uniform = [(1024, 7.84)] * 2
    mixed = [(1024, 7.84), (1024, 15.68)]
    all_slow = [(1024, 15.68)] * 2
    print("  uniform  :", pin(hetero_streamed(50_000, 1024, 4, uniform),
                              133_980, "50k uniform"))
    print("  mixed (legacy arrival-balanced):",
          pin(hetero_streamed(50_000, 1024, 4, mixed), 157_532, "50k mixed legacy"))
    print("  all-slow :", pin(hetero_streamed(50_000, 1024, 4, all_slow),
                              142_008, "50k all-slow"))
    deal, cycles = hetero_completion(50_000, 1024, 4, mixed)
    pin(cycles, 138_076, "50k mixed balanced")
    pin(deal, [26, 23], "50k mixed balanced deal")
    print(f"  mixed (completion-balanced)    : {cycles} (deal {deal})")

    print("uniform reduction spot-check (n=1M, bank=1024, fanout=4, cyc=7.84):")
    chunks = -(-1_000_000 // 1024)
    arrival = round_half_away(1024 * 7.84)
    sharded_pins = {1: 5_008_220, 2: 3_511_132, 3: 2_671_452, 4: 2_010_972}
    for s in [1, 2, 3, 4, 8, 16]:
        uni = model_sharded_completion(chunks, 1024, arrival, s, 4)
        het = hetero_streamed(1_000_000, 1024, 4, [(1024, 7.84)] * s)
        assert uni == het, (s, uni, het)
        _, bal = hetero_completion(1_000_000, 1024, 4, [(1024, 7.84)] * s)
        assert uni == bal, (s, uni, bal)
        if s in sharded_pins:
            pin(uni, sharded_pins[s], f"sharded s={s}")
        print(f"  shards={s:2d}: {uni}")

    print()
    print("== EXPERIMENTS.md §Heterogeneous shard scaling "
          "(n=1M, bank=1024, fanout=4) ==")
    # Each row pins BOTH generations: the legacy arrival-balanced deal
    # (kept in EXPERIMENTS.md for comparison) and the completion-balanced
    # schedule the planner now routes on. The acceptance criterion —
    # completion-balanced never loses — is asserted per row.
    fleets = [
        ("4x nominal (7.84)", [(1024, 7.84)] * 4,
         2_010_972, 2_010_972, [245, 244, 244, 244]),
        ("2x nominal + 2x half-speed (15.68)",
         [(1024, 7.84)] * 2 + [(1024, 15.68)] * 2,
         2_671_452, 2_011_832, [245, 245, 244, 243]),
        ("4x half-speed (15.68)", [(1024, 15.68)] * 4,
         2_019_000, 2_019_000, [245, 244, 244, 244]),
        ("2x 1024-bank + 2x 512-bank (7.84)",
         [(1024, 7.84)] * 2 + [(512, 7.84)] * 2,
         2_325_340, 2_200_412, [256, 256, 233, 232]),
        ("1x nominal + 3x half-speed", [(1024, 7.84)] + [(1024, 15.68)] * 3,
         3_003_228, 2_011_832, [245, 244, 244, 244]),
    ]
    for name, shards, want_arr, want_bal, want_deal in fleets:
        legacy_deal, legacy = hetero_arrival(1_000_000, 1024, 4, shards)
        deal, balanced = hetero_completion(1_000_000, 1024, 4, shards)
        pin(hetero_streamed(1_000_000, 1024, 4, shards), legacy, f"{name} legacy path")
        pin(legacy, want_arr, f"{name} arrival-balanced")
        pin(balanced, want_bal, f"{name} completion-balanced")
        pin(deal, want_deal, f"{name} deal")
        assert balanced <= legacy, (name, balanced, legacy)
        saved = 100 * (legacy - balanced) / legacy
        print(f"  {name:38s}: arrival {legacy:>9d} (deal {legacy_deal}) -> "
              f"completion {balanced:>9d} (deal {deal}, saved {saved:.1f}%)")

    print()
    print("== EXPERIMENTS.md §Remote transport ==")
    print("wire overhead (coordinator::wire, pinned by "
          "frame_sizes_match_the_documented_overhead_model):")
    for n in [1024, 512]:
        print(f"  n={n:4d}: SortJob {frame_bytes_job(n)} B "
              f"({frame_bytes_job(n) / n:.2f} B/elem), "
              f"SortOk {frame_bytes_ok(n)} B ({frame_bytes_ok(n) / n:.2f} B/elem)")
    print("hedge deadline (merge::model_hedge_deadline, bank=1024, cyc=7.84):")
    for mult, want in [(1.0, 8_028), (2.0, 16_056), (4.0, 32_113)]:
        print(f"  mult={mult}: "
              f"{pin(model_hedge_deadline(1024, 7.84, mult, 0), want, f'hedge x{mult}')}"
              " cycles")
    print("hedging under a 25% slow-shard mixture (mult=4, hedge-once, "
          "fresh draw = nominal):")
    for factor in [2.0, 4.0, 8.0, float("inf")]:
        deadline, fired, win, base, hedged = hedge_mixture(0.25, factor)
        gain = "inf" if base == float("inf") else f"{100 * (1 - hedged / base):.1f}%"
        base_s = "inf" if base == float("inf") else f"{base:.0f}"
        print(f"  slow x{factor:<4}: fired {100 * fired:.0f}%, win rate "
              f"{100 * win:.0f}%, mean {base_s} -> {hedged:.0f} cycles ({gain} saved, "
              f"deadline {deadline})")

    print()
    print("== EXPERIMENTS.md §Concurrent request plane ==")
    t = len("acme")
    # The fixed envelope is the whole round trip minus the 16 B/elem.
    assert frame_bytes_job_tagged(64, t) + frame_bytes_ok(64) == (145 + t) + 16 * 64
    print(f"tagged job frame (tenant 'acme', t={t}): n=64 -> "
          f"{frame_bytes_job_tagged(64, t)} B; round-trip envelope "
          f"145+t = {145 + t} B/request + 16 B/elem")
    print("coalescing (planner::model_coalescing, tenant 'acme'):")
    packs = [("8 x 64", [64] * 8), ("4 x 64", [64] * 4), ("8 x 16", [16] * 8),
             ("17+13+30 (uneven)", [17, 13, 30])]
    for name, lens in packs:
        solo, coalesced = model_coalescing(lens, t)
        saved = solo - coalesced
        assert saved == (len(lens) - 1) * (145 + t), (name, saved)
        print(f"  {name:18s}: solo {solo:5d} B -> carrier {coalesced:5d} B "
              f"(saved {saved} = {len(lens) - 1}*{145 + t}, "
              f"{100 * saved / solo:.1f}%)")
    print("concurrent makespan (one host, workers=4, 32 jobs/client, "
          "bank=1024, cyc=7.84):")
    makespan_pins = {1: 64_224, 2: 128_448, 4: 256_896, 8: 513_792}
    for c in [1, 2, 4, 8]:
        m = pin(concurrent_makespan(c, 32, 1024, 4, 7.84), makespan_pins[c],
                f"makespan C={c}")
        agg = c * 32 * 1024 / m
        print(f"  C={c}: makespan {m:>7d} cycles, aggregate {agg:.3f} elem/cyc, "
              f"per-client {agg / c:.3f}")
    pin(concurrent_makespan(1, 3, 1024, 2, 7.84), 16_056, "makespan 3-job/2-worker")

    print()
    print("== EXPERIMENTS.md §Out-of-core spill (bank=1024, fanout=4, "
          "arrival=8028) ==")
    # Named CI step: the spill cost model behind the budgeted
    # auto-tuner, pinned against schedule::spill_io_cycles /
    # spill_completion (the Rust tests pin the same numbers). The
    # surcharge is strictly positive, so spill is never the tuner's
    # free choice — only the memory budget forces it; the crossover
    # table below is what EXPERIMENTS.md reprints.
    print("surcharge unit pins (schedule::spill_io_surcharge_matches_"
          "the_experiments_table):")
    pin(spill_io_cycles(1024, 1, 4), 3_072, "spill io 1chunk")
    pin(spill_io_cycles(1, 1, 2), 3, "spill io single elem")
    pin(spill_io_cycles(0, 0, 4), 0, "spill io empty")
    pin(model_spill_completion(1, 1024, 8028, 4), 11_100,
        "spill 1chunk completion")
    pin(model_spill_completion(0, 1024, 8028, 4), 0, "spill empty completion")
    print("  1 chunk of 1024: +3072 cycles (write + read back); "
          "1 elem: +3; empty: 0")
    print("spill-vs-resident crossover (resident footprint is 16 B/elem "
          "of merge working set):")
    rows = [
        # chunks, resident completion, io surcharge, spilled completion
        (1, 8_028, 3_072, 11_100),
        (4, 12_124, 12_288, 24_412),
        (16, 40_796, 98_304, 139_100),
        (64, 204_636, 589_824, 794_460),
        (977, 5_008_220, 15_006_720, 20_014_940),   # the 1M-element run
    ]
    for chunks, want_res, want_io, want_tot in rows:
        res = pin(model_streamed_completion_uniform(chunks, 1024, 8028, 4),
                  want_res, f"spill crossover resident c={chunks}")
        io = pin(spill_io_cycles(chunks * 1024, chunks, 4), want_io,
                 f"spill crossover io c={chunks}")
        tot = pin(model_spill_completion(chunks, 1024, 8028, 4), want_tot,
                  f"spill crossover total c={chunks}")
        assert tot == res + io and tot > res, (chunks, res, io, tot)
        footprint = chunks * 1024 * 16
        print(f"  chunks={chunks:4d} (n={chunks * 1024:>8d}): resident "
              f"{res:>10d} cyc ({footprint:>9d} B working set) -> spilled "
              f"{tot:>10d} cyc (+{io} I/O, {tot / res:.2f}x)")
    print("  tuner contract: spilled > resident at every size -> "
          "auto_tune_budgeted spills only when the budget forces it")

    print()
    print("== EXPERIMENTS.md §Hot-path word traffic ==")
    # Named CI step: recompute the counted reductions from scratch and
    # hard-pin them. The operation counts come from `colskip_sim`, not
    # from transcribed Rust output; the Rust side pins the same numbers
    # through SortStats + KernelCounters, so kernel drift on EITHER side
    # breaks the build.
    print("colskip sanity (pinned against sorter::colskip unit tests):")
    s, _, st = colskip_sim([8, 9, 10], 4, 2)
    pin(s, [8, 9, 10], "fig3 sorted")
    pin((st["crs"], st["srs"], st["sls"], st["invalidations"], st["iterations"]),
        (7, 2, 2, 1, 3), "fig3 stats")
    pin(st["executed"], 4, "fig3 executed CRs")
    pin(reference_traversal_words(3, st["crs"], st["res"], st["srs"]), 24,
        "fig3 reference words")
    pin(fused_traversal_words(3, st["executed"]), 12, "fig3 fused words")
    print(f"  fig3 {{8,9,10}} w=4 k=2: ref 24 words -> fused 12 words (2.00x)")
    s, _, st = colskip_sim([7] * 64, 8, 2)
    pin((st["iterations"], st["drains"], st["crs"]), (1, 63, 8), "dup64 stats")
    print("  64 duplicates w=8: 1 iteration, 8 CRs, 63 drains")

    print("ref vs fused traversal words (n=1024, w=32, k=2, seed=42):")
    word_pins = {
        # kind: (crs, res, srs, executed)
        "uniform": (28_224, 2_731, 503, 5_621),
        "normal": (27_613, 2_714, 510, 5_608),
        "clustered": (15_739, 3_094, 490, 9_593),
        "kruskal": (9_336, 2_514, 723, 5_272),
        "mapreduce": (7_189, 1_878, 836, 4_324),
    }
    n = 1024
    tot_ref = tot_fused = 0
    for kind in DATASET_KINDS:
        vals = generate_dataset(kind, n, 32, 42)
        s, _, st = colskip_sim(vals, 32, 2)
        assert s == sorted(vals), f"{kind}: simulator failed to sort"
        pin((st["crs"], st["res"], st["srs"], st["executed"]), word_pins[kind],
            f"word traffic {kind}")
        ref = reference_traversal_words(n, st["crs"], st["res"], st["srs"])
        fused = fused_traversal_words(n, st["executed"])
        tot_ref += ref
        tot_fused += fused
        print(f"  {kind:10s}: crs={st['crs']:6d} exec={st['executed']:6d} "
              f"ref={ref:9d} fused={fused:9d} words ({ref / fused:.3f}x)")
    pin((tot_ref, tot_fused), (3_537_904, 1_460_064), "word traffic aggregate")
    assert tot_ref >= 2 * tot_fused, "aggregate traversal reduction fell below 2x"
    print(f"  {'aggregate':10s}: ref={tot_ref} fused={tot_fused} "
          f"({tot_ref / tot_fused:.3f}x, pinned >= 2x)")

    print("wire bytes copied per SortJob->SortOk round trip "
          "(traffic::roundtrip_bytes_*):")
    for rn in [1024, 512]:
        before, after = roundtrip_bytes_before(rn), roundtrip_bytes_after(rn)
        assert before == 344 + 64 * rn and after == 136 + 32 * rn, rn
        print(f"  n={rn:4d}: owned {before:6d} B -> reusable {after:6d} B "
              f"({before / after:.3f}x)")
    pin((roundtrip_bytes_before(1024), roundtrip_bytes_after(1024)),
        (65_880, 32_904), "roundtrip n=1024")
    assert roundtrip_bytes_before(1024) >= 2 * roundtrip_bytes_after(1024), \
        "round-trip byte reduction fell below 2x"


if __name__ == "__main__":
    main()

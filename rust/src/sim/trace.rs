//! Trace event model + Fig. 3-style schedule rendering.

/// The operation classes of the near-memory circuit (paper Fig. 2).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Column read (sense one bit column over the active rows).
    ColumnRead,
    /// Row exclusion (wordline update after an informative column).
    RowExclude,
    /// State recording into the k-entry table.
    StateRecord,
    /// State load from the table (iteration resume).
    StateLoad,
    /// A dead table entry discarded.
    Invalidate,
    /// Min row emitted.
    Emit,
    /// Duplicate row drained under column-processor stall.
    Drain,
}

/// One recorded operation.
#[derive(Copy, Clone, Debug)]
pub struct TraceEvent {
    pub kind: TraceKind,
    /// Bit column involved (CR/RE/SR/SL), otherwise 0.
    pub col: u32,
    /// Active-row count (CR/SR/SL), excluded count (RE), or row (Emit).
    pub rows: usize,
    /// Emitted value (Emit/Drain), otherwise 0.
    pub value: u32,
    /// Whether a CR was informative.
    pub informative: bool,
    /// Iteration index this event belongs to.
    pub iteration: usize,
}

impl TraceEvent {
    pub fn cr(col: u32, rows: usize, informative: bool) -> Self {
        TraceEvent { kind: TraceKind::ColumnRead, col, rows, value: 0, informative, iteration: 0 }
    }
    pub fn re(col: u32, excluded: usize) -> Self {
        TraceEvent {
            kind: TraceKind::RowExclude,
            col,
            rows: excluded,
            value: 0,
            informative: true,
            iteration: 0,
        }
    }
    pub fn sr(col: u32, rows: usize) -> Self {
        TraceEvent { kind: TraceKind::StateRecord, col, rows, value: 0, informative: true, iteration: 0 }
    }
    pub fn sl(col: u32, rows: usize) -> Self {
        TraceEvent { kind: TraceKind::StateLoad, col, rows, value: 0, informative: false, iteration: 0 }
    }
    pub fn invalidate() -> Self {
        TraceEvent {
            kind: TraceKind::Invalidate,
            col: 0,
            rows: 0,
            value: 0,
            informative: false,
            iteration: 0,
        }
    }
    pub fn emit(row: usize, value: u32) -> Self {
        TraceEvent { kind: TraceKind::Emit, col: 0, rows: row, value, informative: false, iteration: 0 }
    }
    pub fn drain(row: usize, value: u32) -> Self {
        TraceEvent { kind: TraceKind::Drain, col: 0, rows: row, value, informative: false, iteration: 0 }
    }
}

/// A complete traced sort.
#[derive(Clone, Debug)]
pub struct TracedRun {
    events: Vec<TraceEvent>,
    n: usize,
    width: u32,
    current_iteration: usize,
}

impl TracedRun {
    pub fn new(n: usize, width: u32) -> Self {
        TracedRun { events: Vec::new(), n, width, current_iteration: 0 }
    }

    pub fn begin_iteration(&mut self, emitted_so_far: usize) {
        let _ = emitted_so_far;
        self.current_iteration = self.current_iteration.saturating_add(1);
    }

    pub fn push(&mut self, mut e: TraceEvent) {
        e.iteration = self.current_iteration.saturating_sub(1);
        self.events.push(e);
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    /// Events of iteration `i`.
    pub fn iteration(&self, i: usize) -> impl Iterator<Item = &TraceEvent> {
        self.events.iter().filter(move |e| e.iteration == i)
    }

    pub fn iterations(&self) -> usize {
        self.current_iteration
    }
}

/// Render the first `max_iters` iterations as a Fig. 3-style schedule:
///
/// ```text
/// iter 1 (full traversal)
///   CR c3 [3 rows]        all-1s
///   CR c2 [3 rows]        all-0s
///   CR c1 [3 rows]  SR RE(1 excluded)
///   ...
///   => emit 8 (row 0)
/// ```
pub fn render_schedule(run: &TracedRun, max_iters: usize) -> String {
    let mut out = String::new();
    for it in 0..run.iterations().min(max_iters) {
        let events: Vec<&TraceEvent> = run.iteration(it).collect();
        let resumed = events.iter().any(|e| e.kind == TraceKind::StateLoad);
        out.push_str(&format!(
            "iter {} ({})\n",
            it + 1,
            if resumed { "resumed from state" } else { "full traversal" }
        ));
        let mut i = 0;
        while i < events.len() {
            let e = events[i];
            match e.kind {
                TraceKind::Invalidate => out.push_str("  state entry invalidated\n"),
                TraceKind::StateLoad => out.push_str(&format!(
                    "  SL c{} [{} snapshot rows] -> resume at c{}\n",
                    e.col, e.rows, e.col
                )),
                TraceKind::ColumnRead => {
                    // Fold the SR/RE that follow this CR onto one line.
                    let mut suffix = String::new();
                    let mut j = i + 1;
                    while j < events.len()
                        && matches!(
                            events[j].kind,
                            TraceKind::StateRecord | TraceKind::RowExclude
                        )
                    {
                        match events[j].kind {
                            TraceKind::StateRecord => suffix.push_str("  SR"),
                            TraceKind::RowExclude => {
                                suffix.push_str(&format!("  RE({} excluded)", events[j].rows))
                            }
                            _ => unreachable!(),
                        }
                        j += 1;
                    }
                    if !e.informative {
                        suffix.push_str("  (uninformative: skip RE)");
                    }
                    out.push_str(&format!("  CR c{} [{} rows]{}\n", e.col, e.rows, suffix));
                    i = j;
                    continue;
                }
                TraceKind::Emit => {
                    out.push_str(&format!("  => emit {} (row {})\n", e.value, e.rows))
                }
                TraceKind::Drain => {
                    out.push_str(&format!("  => drain {} (row {}, stalled)\n", e.value, e.rows))
                }
                TraceKind::StateRecord | TraceKind::RowExclude => {
                    // Only reached if not folded (defensive).
                    out.push_str(&format!("  {:?} c{}\n", e.kind, e.col));
                }
            }
            i += 1;
        }
    }
    if run.iterations() > max_iters {
        out.push_str(&format!("... ({} more iterations)\n", run.iterations() - max_iters));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::trace_sort;
    use crate::sorter::colskip::ColSkipConfig;

    #[test]
    fn render_fig3_example() {
        let (_, run) =
            trace_sort(&[8, 9, 10], &ColSkipConfig { width: 4, k: 2, ..Default::default() });
        let text = render_schedule(&run, 10);
        assert!(text.contains("iter 1 (full traversal)"));
        assert!(text.contains("iter 2 (resumed from state)"));
        assert!(text.contains("=> emit 8"));
        assert!(text.contains("=> emit 10"));
        assert!(text.contains("SR"), "{text}");
        assert!(text.contains("RE(1 excluded)"), "{text}");
        // 7 CR lines in total (the paper's count).
        assert_eq!(text.matches("  CR c").count(), 7, "{text}");
    }

    #[test]
    fn render_truncates() {
        let data: Vec<u32> = (0..32).rev().collect();
        let (_, run) =
            trace_sort(&data, &ColSkipConfig { width: 8, k: 2, ..Default::default() });
        let text = render_schedule(&run, 2);
        assert!(text.contains("more iterations"), "{text}");
    }

    #[test]
    fn drain_renders_as_stalled() {
        let (_, run) =
            trace_sort(&[5, 5, 5], &ColSkipConfig { width: 4, k: 2, ..Default::default() });
        let text = render_schedule(&run, 5);
        assert!(text.contains("stalled"), "{text}");
    }
}

//! Cycle-accurate operation tracing: record the near-memory circuit's
//! schedule (which operation touched which column/rows on every cycle)
//! and render it in the style of the paper's Fig. 1 / Fig. 3 walkthroughs.
//!
//! The traced sorter wraps the same near-memory modules as
//! [`crate::sorter::colskip::ColSkipSorter`] but emits a [`TraceEvent`]
//! per operation. Used by the `memsort trace` CLI command, the Fig. 3
//! regression test (the trace must reproduce the paper's published
//! schedule exactly), and by users debugging their own datasets.

pub mod trace;

pub use trace::{render_schedule, TraceEvent, TraceKind, TracedRun};

use crate::bits::RowMask;
use crate::memory::Bank;
use crate::sorter::colskip::ColSkipConfig;
use crate::sorter::column::ColumnProcessor;
use crate::sorter::row::RowProcessor;
use crate::sorter::state::StateTable;
use crate::sorter::{SortOutput, SortStats};

/// Run a column-skipping sort while recording every operation.
pub fn trace_sort(data: &[u32], config: &ColSkipConfig) -> (SortOutput, TracedRun) {
    let n = data.len();
    let w = config.width;
    let mut bank = Bank::load(data, w);
    let mut stats = SortStats::default();
    let mut cp = ColumnProcessor::new(w, config.skip_leading);
    let mut rp = RowProcessor::new(n);
    let mut table = StateTable::new(config.k);
    let mut ones = RowMask::new_empty(n);
    let mut sorted = Vec::with_capacity(n);
    let mut order = Vec::with_capacity(n);
    let mut run = TracedRun::new(n, w);

    while sorted.len() < n {
        stats.iterations += 1;
        run.begin_iteration(sorted.len());

        let (entry, invalidated) = table.load_most_recent(rp.alive());
        stats.invalidations += invalidated;
        for _ in 0..invalidated {
            run.push(TraceEvent::invalidate());
        }
        let (start_col, from_msb) = match entry {
            Some(e) => {
                stats.sls += 1;
                run.push(TraceEvent::sl(e.col, e.snapshot.count()));
                rp.begin_from_snapshot(&e.snapshot);
                (e.col, false)
            }
            None => {
                rp.begin_full();
                (cp.full_start(), true)
            }
        };

        let mut first_informative: Option<u32> = None;
        for col in (0..=start_col).rev() {
            stats.crs += 1;
            let (any_one, any_zero) = bank.column_read_into(col, rp.active(), &mut ones);
            let informative = any_one && any_zero;
            run.push(TraceEvent::cr(col, rp.active().count(), informative));
            if informative {
                if from_msb {
                    if first_informative.is_none() {
                        first_informative = Some(col);
                    }
                    table.record(rp.active(), col);
                    stats.srs += 1;
                    run.push(TraceEvent::sr(col, rp.active().count()));
                }
                let excluded = ones.count();
                rp.exclude(&ones);
                bank.note_wordline_update();
                stats.res += 1;
                run.push(TraceEvent::re(col, excluded));
            }
        }
        if from_msb {
            if let Some(col) = first_informative {
                cp.observe_first_informative(col);
            }
        }

        let row = rp.emit_first();
        sorted.push(bank.read_row(row));
        order.push(row);
        run.push(TraceEvent::emit(row, *sorted.last().expect("pushed")));
        if config.stall_on_duplicates {
            while rp.has_pending_duplicates() && sorted.len() < n {
                stats.drains += 1;
                let row = rp.emit_first();
                sorted.push(bank.read_row(row));
                order.push(row);
                run.push(TraceEvent::drain(row, *sorted.last().expect("pushed")));
            }
        }
    }
    (SortOutput { sorted, order, stats, counters: Default::default() }, run)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sorter::colskip::ColSkipSorter;
    use crate::sorter::InMemorySorter;

    fn cfg(width: u32, k: usize) -> ColSkipConfig {
        ColSkipConfig { width, k, ..Default::default() }
    }

    #[test]
    fn traced_run_matches_untraced_sorter() {
        use crate::datasets::{Dataset, DatasetKind};
        for kind in DatasetKind::ALL {
            let d = Dataset::generate32(kind, 128, 77);
            let (out, _) = trace_sort(&d.values, &cfg(32, 2));
            let mut plain = ColSkipSorter::with_k(2);
            let expect = plain.sort_with_stats(&d.values);
            assert_eq!(out.sorted, expect.sorted, "{kind:?}");
            assert_eq!(out.stats, expect.stats, "{kind:?}");
        }
    }

    #[test]
    fn fig3_schedule_is_reproduced() {
        // The paper's Fig. 3 walkthrough for {8,9,10}, w=4, k=2:
        // iteration 1: CR c3, CR c2, CR c1 (+SR,RE), CR c0 (+SR,RE) → emit 8
        // iteration 2: SL(c0), CR c0 → emit 9
        // iteration 3: invalidate, SL(c1), CR c1, CR c0 → emit 10
        let (out, run) = trace_sort(&[8, 9, 10], &cfg(4, 2));
        assert_eq!(out.stats.crs, 7);
        let crs_per_iter: Vec<usize> = (0..3)
            .map(|i| run.iteration(i).filter(|e| e.kind == TraceKind::ColumnRead).count())
            .collect();
        assert_eq!(crs_per_iter, vec![4, 1, 2], "Fig. 3's per-iteration CR split");
        // Iteration 2 resumes at column 0; iteration 3 at column 1.
        let sl_cols: Vec<u32> = run
            .events()
            .iter()
            .filter(|e| e.kind == TraceKind::StateLoad)
            .map(|e| e.col)
            .collect();
        assert_eq!(sl_cols, vec![0, 1]);
        // Emitted mins in order.
        let emitted: Vec<u32> = run
            .events()
            .iter()
            .filter(|e| matches!(e.kind, TraceKind::Emit | TraceKind::Drain))
            .map(|e| e.value)
            .collect();
        assert_eq!(emitted, vec![8, 9, 10]);
    }

    #[test]
    fn trace_counts_match_stats() {
        let d = crate::datasets::Dataset::generate32(
            crate::datasets::DatasetKind::Kruskal,
            256,
            3,
        );
        let (out, run) = trace_sort(&d.values, &cfg(32, 2));
        let count = |k: TraceKind| run.events().iter().filter(|e| e.kind == k).count() as u64;
        assert_eq!(count(TraceKind::ColumnRead), out.stats.crs);
        assert_eq!(count(TraceKind::RowExclude), out.stats.res);
        assert_eq!(count(TraceKind::StateRecord), out.stats.srs);
        assert_eq!(count(TraceKind::StateLoad), out.stats.sls);
        assert_eq!(count(TraceKind::Drain), out.stats.drains);
        assert_eq!(count(TraceKind::Invalidate), out.stats.invalidations);
    }
}

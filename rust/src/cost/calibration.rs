//! Calibration of the cost model against the paper's published
//! implementation points (Fig. 8a).
//!
//! Strategy: the small structural constants (column processor, control,
//! manager, cell) are fixed at standard-cell-scale assumptions; the three
//! dominant coefficients on each axis — row processor, sense amps, state
//! table — are solved *exactly* from the three in-memory anchor rows:
//!
//! * area: baseline 77.8 Kµm²; col-skip k=2 101.1 Kµm²; k=2 with 16×Ns=64
//!   banks 86.9 Kµm²;
//! * power (MapReduce activity): 319.7 / 385.2 / 349.3 mW, using the
//!   nominal activity profile ([`Activity::nominal_colskip`]) as the
//!   stand-in for PowerArtist's switching annotation;
//! * merge sorter: its own `N·log2 N` coefficient from 246.1 Kµm² /
//!   825.9 mW at N=1024.
//!
//! The solved coefficients are asserted positive (physical) and the
//! anchors are asserted to reproduce to 1e-6 relative in `cost::tests`.

use super::{Activity, CostModel};
use crate::params::{DEFAULT_N, DEFAULT_WIDTH};

/// Fixed small-structure assumptions (Kµm² / mW). These are *inputs* to
/// the calibration, chosen at standard-cell scale; the anchors then
/// determine the dominant terms exactly.
pub mod fixed {
    /// Column processor area per bit of width.
    pub const A_COLP: f64 = 0.003;
    /// Per-bank controller area.
    pub const A_CTL: f64 = 0.1;
    /// Column-skipping control area (skip decision + stall gating).
    pub const A_SKIP: f64 = 0.1;
    /// Multi-bank manager area per connected bank (OR-tree + mux slice).
    pub const A_MGR: f64 = 0.05;
    /// 1T1R cell area per bit — orders of magnitude below the circuit
    /// (paper §V.B).
    pub const A_CELL: f64 = 1.0e-5;
    /// Column processor power per bit. (The per-bank fixed powers are
    /// kept small so the banked totals stay monotone in Ns, matching the
    /// paper's §V.C observation that the near-memory circuit power
    /// decreases super-linearly with sub-sorter length.)
    pub const P_COLP: f64 = 0.01;
    /// Per-bank controller power.
    pub const P_CTL: f64 = 0.2;
    /// Column-skipping control power.
    pub const P_SKIP: f64 = 0.3;
    /// Manager power per connected bank.
    pub const P_MGR: f64 = 0.1;
    /// Global clock/IO power.
    pub const P_GLOB: f64 = 10.0;
}

/// Anchor values from Fig. 8(a).
pub mod anchors {
    pub const AREA_BASELINE: f64 = 77.8;
    pub const AREA_COLSKIP_K2: f64 = 101.1;
    pub const AREA_MULTIBANK_64: f64 = 86.9;
    pub const AREA_MERGE: f64 = 246.1;
    pub const POWER_BASELINE: f64 = 319.7;
    pub const POWER_COLSKIP_K2: f64 = 385.2;
    pub const POWER_MULTIBANK_64: f64 = 349.3;
    pub const POWER_MERGE: f64 = 825.9;
    /// The paper's measured speed for the two headline rows (cyc/num).
    pub const CYC_BASELINE: f64 = 32.0;
    pub const CYC_COLSKIP_K2: f64 = 7.84;
    pub const CYC_MERGE: f64 = 10.0;
}

/// Solve `A·x = b` for a 3×3 system by Gaussian elimination with partial
/// pivoting. Panics on a singular system (calibration inputs guarantee
/// non-singularity).
pub fn solve3(a: [[f64; 3]; 3], b: [f64; 3]) -> [f64; 3] {
    let mut m = [[0.0f64; 4]; 3];
    for i in 0..3 {
        m[i][..3].copy_from_slice(&a[i]);
        m[i][3] = b[i];
    }
    for col in 0..3 {
        // Pivot.
        let piv = (col..3)
            .max_by(|&i, &j| m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap())
            .unwrap();
        m.swap(col, piv);
        assert!(m[col][col].abs() > 1e-12, "singular calibration system");
        for row in col + 1..3 {
            let f = m[row][col] / m[col][col];
            for k in col..4 {
                m[row][k] -= f * m[col][k];
            }
        }
    }
    let mut x = [0.0f64; 3];
    for row in (0..3).rev() {
        let mut acc = m[row][3];
        for k in row + 1..3 {
            acc -= m[row][k] * x[k];
        }
        x[row] = acc / m[row][row];
    }
    x
}

/// Solve the calibrated [`CostModel`]. See the module docs for the setup.
pub fn calibrate() -> CostModel {
    let n = DEFAULT_N as f64; // 1024
    let w = DEFAULT_WIDTH as f64; // 32
    let idx = w.log2().ceil(); // 5 index bits per state entry
    let nlog = n * n.log2(); // 10240
    let banks = 16.0;
    let ns = n / banks; // 64
    let nslog = ns * ns.log2(); // 384
    let cell = fixed::A_CELL * n * w;

    // ---- Area: unknowns [a_row, a_sa, a_st] ----
    // (1) baseline (k=0, C=1):
    //     a_row·nlog + a_sa·n = AREA_BASELINE − (a_colp·w + a_ctl + cell)
    // (2) col-skip k=2 − baseline:
    //     2·a_st·(n+idx) = ΔA − a_skip
    // (3) 16 banks of Ns=64, k=2.
    let area_base_fixed = fixed::A_COLP * w + fixed::A_CTL + cell;
    let a_st = (anchors::AREA_COLSKIP_K2 - anchors::AREA_BASELINE - fixed::A_SKIP)
        / (2.0 * (n + idx));
    // (3): banks·[a_row·nslog + a_sa·ns + per_bank_fixed] + mgr + cell = anchor
    let per_bank_fixed =
        fixed::A_COLP * w + fixed::A_CTL + fixed::A_SKIP + 2.0 * a_st * (ns + idx);
    let rhs3 = anchors::AREA_MULTIBANK_64
        - fixed::A_MGR * banks
        - cell
        - banks * per_bank_fixed;
    // eq1: a_row·nlog + a_sa·n = rhs1 ; eq3: a_row·banks·nslog + a_sa·n = rhs3
    let rhs1 = anchors::AREA_BASELINE - area_base_fixed;
    let a_row = (rhs1 - rhs3) / (nlog - banks * nslog);
    let a_sa = (rhs1 - a_row * nlog) / n;
    let a_merge = anchors::AREA_MERGE / nlog;

    // ---- Power: unknowns [p_row, p_sa, p_st] under nominal activity ----
    let act_b = Activity::nominal_baseline();
    let act_c = Activity::nominal_colskip();
    // (1) baseline: p_row·nlog + p_sa·n·u_cr_b = P1 − (p_colp·w+p_ctl+p_glob)
    // (2) col-skip: p_row·nlog + p_sa·n·u_cr_c + p_st·u_tbl·2(n+idx) = P2 − ...
    // (3) multibank: p_row·banks·nslog + p_sa·n·u_cr_c
    //                + p_st·u_tbl·2·banks·(ns+idx) = P3 − ...
    let rhs = [
        anchors::POWER_BASELINE - (fixed::P_COLP * w + fixed::P_CTL + fixed::P_GLOB),
        anchors::POWER_COLSKIP_K2
            - (fixed::P_COLP * w + fixed::P_CTL + fixed::P_SKIP + fixed::P_GLOB),
        anchors::POWER_MULTIBANK_64
            - (banks * (fixed::P_COLP * w + fixed::P_CTL + fixed::P_SKIP)
                + fixed::P_MGR * banks
                + fixed::P_GLOB),
    ];
    let coeffs = [
        [nlog, n * act_b.u_cr, 0.0],
        [nlog, n * act_c.u_cr, act_c.u_tbl * 2.0 * (n + idx)],
        [banks * nslog, n * act_c.u_cr, act_c.u_tbl * 2.0 * banks * (ns + idx)],
    ];
    let [p_row, p_sa, p_st] = solve3(coeffs, rhs);
    let p_merge = anchors::POWER_MERGE / nlog;

    CostModel {
        a_row,
        a_sa,
        a_colp: fixed::A_COLP,
        a_ctl: fixed::A_CTL,
        a_skip: fixed::A_SKIP,
        a_st,
        a_mgr: fixed::A_MGR,
        a_cell: fixed::A_CELL,
        a_merge,
        p_row,
        p_sa,
        p_st,
        p_colp: fixed::P_COLP,
        p_ctl: fixed::P_CTL,
        p_skip: fixed::P_SKIP,
        p_mgr: fixed::P_MGR,
        p_glob: fixed::P_GLOB,
        p_merge,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve3_identity() {
        let x = solve3([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]], [3.0, -2.0, 0.5]);
        assert_eq!(x, [3.0, -2.0, 0.5]);
    }

    #[test]
    fn solve3_general() {
        // x=1, y=2, z=3 under a dense matrix.
        let a = [[2.0, 1.0, -1.0], [1.0, 3.0, 2.0], [3.0, -1.0, 1.0]];
        let b = [2.0 + 2.0 - 3.0, 1.0 + 6.0 + 6.0, 3.0 - 2.0 + 3.0];
        let x = solve3(a, b);
        for (xi, ti) in x.iter().zip([1.0, 2.0, 3.0]) {
            assert!((xi - ti).abs() < 1e-12, "{x:?}");
        }
    }

    #[test]
    fn solve3_needs_pivoting() {
        // Leading zero forces a row swap.
        let a = [[0.0, 1.0, 0.0], [1.0, 0.0, 0.0], [0.0, 0.0, 1.0]];
        let x = solve3(a, [5.0, 7.0, 9.0]);
        assert_eq!(x, [7.0, 5.0, 9.0]);
    }

    #[test]
    #[should_panic(expected = "singular")]
    fn solve3_rejects_singular() {
        solve3([[1.0, 1.0, 0.0], [2.0, 2.0, 0.0], [0.0, 0.0, 1.0]], [1.0, 2.0, 3.0]);
    }

    #[test]
    fn calibration_is_stable() {
        let a = calibrate();
        let b = calibrate();
        assert_eq!(a.a_row, b.a_row);
        assert_eq!(a.p_st, b.p_st);
    }
}

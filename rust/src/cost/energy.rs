//! Per-operation energy accounting: connects the bank's [`OpMeter`] and
//! the analog [`SenseModel`] to joules, giving an energy-per-sort
//! breakdown the aggregate power model (Fig. 7/8) can be sanity-checked
//! against.
//!
//! Sources:
//! * array energy — sense currents through the 1T1R cells during CRs
//!   (computed from the paper's device resistances, §V);
//! * circuit energy — CV² switching of the near-memory registers, at
//!   per-op charges calibrated so the aggregate matches the power model's
//!   baseline anchor at 500 MHz.

use crate::memory::sense::SenseModel;
use crate::memory::OpMeter;
use crate::params::CLOCK_HZ;
use crate::sorter::SortStats;

/// Per-op energy coefficients (joules).
#[derive(Clone, Debug)]
pub struct EnergyModel {
    /// Sense time per column read (s).
    pub t_sense: f64,
    /// Analog model for cell/sense-amp currents.
    pub sense: SenseModel,
    /// Circuit energy per sensed row per CR (register + SA digital side).
    pub e_cr_row: f64,
    /// Energy per wordline register update (RE), per row of the bank.
    pub e_re_row: f64,
    /// Energy per state-table row-bit accessed (SR/SL).
    pub e_st_bit: f64,
    /// Energy per cell write (array load).
    pub e_write_cell: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Circuit charges chosen so a baseline N=1024 sorter dissipates
        // ~320 mW at 500 MHz (the Fig. 8a anchor). Note the meter counts
        // only *active* select lines per CR; over a full baseline sort the
        // average active count is well below N (exclusions shrink it every
        // step), so the per-row charge is several pJ — consistent with a
        // 40nm SA + routing toggling at speed.
        EnergyModel {
            t_sense: 1.0e-9,
            sense: SenseModel::default(),
            e_cr_row: 1.6e-12,
            e_re_row: 0.45e-12,
            e_st_bit: 0.18e-12,
            e_write_cell: 1.0e-12,
        }
    }
}

/// Energy breakdown of one sort (joules).
#[derive(Clone, Debug, PartialEq)]
pub struct EnergyBreakdown {
    pub array_sense_j: f64,
    pub circuit_cr_j: f64,
    pub circuit_re_j: f64,
    pub state_table_j: f64,
    pub write_j: f64,
}

impl EnergyBreakdown {
    pub fn total_j(&self) -> f64 {
        self.array_sense_j + self.circuit_cr_j + self.circuit_re_j + self.state_table_j
        // (write_j is array programming, reported separately: the paper's
        // sorters never rewrite cells during sorting)
    }

    /// Energy per sorted element (J).
    pub fn per_element_j(&self, n: usize) -> f64 {
        self.total_j() / n.max(1) as f64
    }

    /// Average power if the sort ran in `cycles` at the paper's clock (W).
    pub fn average_power_w(&self, cycles: u64) -> f64 {
        if cycles == 0 {
            0.0
        } else {
            self.total_j() / (cycles as f64 / CLOCK_HZ)
        }
    }
}

impl EnergyModel {
    /// Energy of a metered run. `rows` is the bank height, `k` the state
    /// depth, `width` the bit width.
    pub fn breakdown(
        &self,
        meter: &OpMeter,
        stats: &SortStats,
        rows: usize,
        width: u32,
        k: usize,
    ) -> EnergyBreakdown {
        let idx_bits = (width as f64).log2().ceil();
        let st_bits_per_access = rows as f64 + idx_bits;
        let _ = k;
        EnergyBreakdown {
            // Analog: every sensed select line draws cell current for
            // t_sense. rows_sensed already counts only active rows.
            array_sense_j: self.sense.column_read_energy(1, self.t_sense)
                * meter.rows_sensed as f64,
            circuit_cr_j: self.e_cr_row * meter.rows_sensed as f64,
            circuit_re_j: self.e_re_row * rows as f64 * meter.wordline_updates as f64,
            state_table_j: self.e_st_bit
                * st_bits_per_access
                * (stats.srs + stats.sls) as f64,
            write_j: self.e_write_cell * meter.cell_writes as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, DatasetKind};
    use crate::memory::Bank;
    use crate::sorter::baseline::BaselineSorter;
    use crate::sorter::colskip::ColSkipSorter;

    fn run_colskip(n: usize, kind: DatasetKind) -> (EnergyBreakdown, SortStats, usize) {
        let d = Dataset::generate32(kind, n, 42);
        let mut bank = Bank::load(&d.values, 32);
        let sorter = ColSkipSorter::with_k(2);
        let out = sorter.sort_bank(&mut bank);
        let em = EnergyModel::default();
        (em.breakdown(bank.meter(), &out.stats, n, 32, 2), out.stats, n)
    }

    #[test]
    fn baseline_power_lands_near_anchor() {
        // The default coefficients should put the baseline sorter's
        // average power in the neighbourhood of the Fig. 8a anchor
        // (319.7 mW) — within 2x, since this is an independent bottom-up
        // estimate, not the calibrated top-down model.
        let d = Dataset::generate32(DatasetKind::MapReduce, 1024, 42);
        let mut bank = Bank::load(&d.values, 32);
        let sorter = BaselineSorter::with_width(32);
        let out = sorter.sort_bank(&mut bank);
        let em = EnergyModel::default();
        let b = em.breakdown(bank.meter(), &out.stats, 1024, 32, 0);
        let p = b.average_power_w(out.stats.cycles());
        assert!(p > 0.15 && p < 0.7, "baseline bottom-up power {p} W");
    }

    #[test]
    fn colskip_uses_less_energy_than_baseline() {
        let d = Dataset::generate32(DatasetKind::MapReduce, 1024, 42);
        let em = EnergyModel::default();
        let mut bank_b = Bank::load(&d.values, 32);
        let out_b = BaselineSorter::with_width(32).sort_bank(&mut bank_b);
        let e_b = em.breakdown(bank_b.meter(), &out_b.stats, 1024, 32, 0);
        let mut bank_c = Bank::load(&d.values, 32);
        let out_c = ColSkipSorter::with_k(2).sort_bank(&mut bank_c);
        let e_c = em.breakdown(bank_c.meter(), &out_c.stats, 1024, 32, 2);
        assert!(
            e_c.total_j() < e_b.total_j() / 2.0,
            "colskip {} J vs baseline {} J",
            e_c.total_j(),
            e_b.total_j()
        );
    }

    #[test]
    fn breakdown_components_positive_and_sum() {
        let (b, _, n) = run_colskip(256, DatasetKind::Clustered);
        assert!(b.array_sense_j > 0.0);
        assert!(b.circuit_cr_j > 0.0);
        assert!(b.state_table_j > 0.0);
        assert!(b.per_element_j(n) > 0.0);
        let sum = b.array_sense_j + b.circuit_cr_j + b.circuit_re_j + b.state_table_j;
        assert!((b.total_j() - sum).abs() < 1e-18);
    }

    #[test]
    fn write_energy_counted_separately() {
        let (b, _, _) = run_colskip(64, DatasetKind::Uniform);
        assert!(b.write_j > 0.0);
        assert!(b.total_j() < b.total_j() + b.write_j);
    }

    #[test]
    fn zero_cycles_zero_power() {
        let b = EnergyBreakdown {
            array_sense_j: 0.0,
            circuit_cr_j: 0.0,
            circuit_re_j: 0.0,
            state_table_j: 0.0,
            write_j: 0.0,
        };
        assert_eq!(b.average_power_w(0), 0.0);
    }
}

//! 40nm area/power/energy cost model (paper §V.B, Fig. 7, Fig. 8).
//!
//! The paper measures silicon area from a 40nm implementation and power
//! with Ansys PowerArtist under MapReduce switching activity. We have no
//! fab and no PowerArtist, so we substitute a **component-level analytical
//! model calibrated to the paper's four published implementation points**
//! (Fig. 8a):
//!
//! | sorter                | area (Kµm²) | power (mW) |
//! |-----------------------|-------------|------------|
//! | baseline [18]         | 77.8        | 319.7      |
//! | merge (digital)       | 246.1       | 825.9      |
//! | col-skip k=2          | 101.1       | 385.2      |
//! | col-skip k=2, Ns=64   | 86.9        | 349.3      |
//!
//! Components (per bank of `Ns` rows, `w` bits, `k` state entries):
//! * **row processor** — wordline registers + the priority/exclusion
//!   network; scales as `Ns·log2(Ns)` (the super-linear term behind the
//!   paper's Fig. 8(b) observation that sub-banking shrinks the circuit);
//! * **sense amplifiers** — one per select line, `∝ Ns`;
//! * **column processor + controller** — `∝ w` plus a constant;
//! * **state controller** — `k` entries of `Ns` snapshot bits + a
//!   `log2(w)` column index;
//! * **multi-bank manager** — `∝ C` (OR-trees and the output mux);
//! * **1T1R array** — `∝ Ns·w`, orders of magnitude below the circuit
//!   (paper §V.B), included for completeness.
//!
//! Power mirrors the same components with activity factors taken from the
//! *measured* operation counts of a simulated run (the analogue of
//! PowerArtist's switching activity): the CR duty cycle scales the sense
//! amp term and the state-table access rate scales the state term. The
//! calibration (see [`calibration`]) solves the three structural
//! coefficients exactly from the three in-memory anchor rows; the merge
//! sorter has its own `N·log2 N` comparator-tree model anchored to its row.

pub mod calibration;
pub mod energy;

use crate::params::CLOCK_HZ;
use crate::sorter::SortStats;

/// Which sorter implementation a cost query refers to.
#[derive(Copy, Clone, Debug, PartialEq)]
pub enum SorterArch {
    /// HPCA'21 bit-traversal baseline (no state controller).
    Baseline { n: usize, w: u32 },
    /// Column-skipping sorter, single bank.
    ColSkip { n: usize, w: u32, k: usize },
    /// Column-skipping sorter over `banks` sub-sorters.
    MultiBank { n: usize, w: u32, k: usize, banks: usize },
    /// Conventional digital merge sorter.
    Merge { n: usize },
    /// Hierarchical out-of-bank pipeline: `chunks` column-skipping banks
    /// of `bank_n` rows each (each possibly striped over
    /// `banks_per_chunk` sub-banks, §IV), feeding a fanout-`fanout`
    /// digital merge network that combines the per-bank sorted runs.
    Hierarchical {
        bank_n: usize,
        w: u32,
        k: usize,
        chunks: usize,
        banks_per_chunk: usize,
        fanout: usize,
    },
}

/// Switching-activity factors extracted from a (simulated) run — the
/// model's stand-in for PowerArtist activity annotation.
#[derive(Copy, Clone, Debug, PartialEq)]
pub struct Activity {
    /// Fraction of cycles issuing a CR (sense-amp duty cycle).
    pub u_cr: f64,
    /// State-table accesses (SR + SL) per cycle.
    pub u_tbl: f64,
}

impl Activity {
    /// The baseline issues a CR every cycle and has no table.
    pub fn nominal_baseline() -> Self {
        Activity { u_cr: 1.0, u_tbl: 0.0 }
    }

    /// Nominal column-skipping activity on MapReduce-class data — the
    /// profile the calibration anchors assume (see `calibration`).
    pub fn nominal_colskip() -> Self {
        Activity { u_cr: 0.9, u_tbl: 0.15 }
    }

    /// Extract measured activity from a run's operation counts.
    pub fn from_stats(stats: &SortStats) -> Self {
        let cycles = stats.cycles().max(1) as f64;
        Activity {
            u_cr: stats.crs as f64 / cycles,
            u_tbl: (stats.srs + stats.sls) as f64 / cycles,
        }
    }
}

/// The calibrated component model. Construct via [`CostModel::calibrated`].
#[derive(Clone, Debug)]
pub struct CostModel {
    // --- area coefficients (Kµm²) ---
    /// Row processor per `Ns·log2(Ns)` unit.
    pub a_row: f64,
    /// Sense amplifier per row.
    pub a_sa: f64,
    /// Column processor per bit of width.
    pub a_colp: f64,
    /// Per-bank controller constant.
    pub a_ctl: f64,
    /// Column-skipping control overhead (constant per bank).
    pub a_skip: f64,
    /// State table per (snapshot bit + index bit) per entry.
    pub a_st: f64,
    /// Multi-bank manager per connected bank.
    pub a_mgr: f64,
    /// 1T1R cell area per bit.
    pub a_cell: f64,
    /// Merge sorter per `N·log2 N` unit.
    pub a_merge: f64,
    // --- power coefficients (mW) ---
    /// Row processor per `Ns·log2(Ns)` unit.
    pub p_row: f64,
    /// Sense amp per row at CR duty 1.0.
    pub p_sa: f64,
    /// State table per entry-bit at table duty 1.0.
    pub p_st: f64,
    /// Column processor per bit of width.
    pub p_colp: f64,
    /// Per-bank controller constant.
    pub p_ctl: f64,
    /// Column-skipping control overhead per bank.
    pub p_skip: f64,
    /// Multi-bank manager per connected bank.
    pub p_mgr: f64,
    /// Global (clock tree, IO) constant.
    pub p_glob: f64,
    /// Merge sorter per `N·log2 N` unit.
    pub p_merge: f64,
}

/// `log2` of the index width for a `w`-bit sorter (state-entry index bits).
fn index_bits(w: u32) -> f64 {
    (w as f64).log2().ceil()
}

fn nlog2n(n: usize) -> f64 {
    if n <= 1 {
        n as f64
    } else {
        n as f64 * (n as f64).log2()
    }
}

/// Fanout-`f` merge units needed to reduce `runs` sorted runs to one
/// (levels of `ceil(r/f)` groups until a single run remains). A
/// remainder group of a single run passes through without a merge unit,
/// so it is not counted. Each unit is modelled as an `f·log2 f`
/// comparator tree, extrapolating the calibrated binary merge-sorter
/// coefficient.
fn merge_units(runs: usize, fanout: usize) -> f64 {
    if runs <= 1 || fanout < 2 {
        return 0.0;
    }
    let mut units = 0usize;
    let mut r = runs;
    while r > 1 {
        let groups = r.div_ceil(fanout);
        units += groups - usize::from(r % fanout == 1);
        r = groups;
    }
    units as f64
}

impl CostModel {
    /// The model calibrated against the paper's Fig. 8(a) (see module docs
    /// and [`calibration::calibrate`]).
    pub fn calibrated() -> Self {
        calibration::calibrate()
    }

    /// Silicon area in Kµm².
    pub fn area_kum2(&self, arch: SorterArch) -> f64 {
        match arch {
            SorterArch::Merge { n } => self.a_merge * nlog2n(n),
            SorterArch::Baseline { n, w } => {
                self.bank_area(n, w, 0, false) + self.a_cell * n as f64 * w as f64
            }
            SorterArch::ColSkip { n, w, k } => {
                self.bank_area(n, w, k, true) + self.a_cell * n as f64 * w as f64
            }
            SorterArch::MultiBank { n, w, k, banks } => {
                let ns = n / banks;
                let mgr = if banks > 1 { self.a_mgr * banks as f64 } else { 0.0 };
                banks as f64 * self.bank_area(ns, w, k, true)
                    + mgr
                    + self.a_cell * n as f64 * w as f64
            }
            SorterArch::Hierarchical { bank_n, w, k, chunks, banks_per_chunk, fanout } => {
                let per_chunk = if banks_per_chunk > 1 {
                    let ns = bank_n / banks_per_chunk;
                    banks_per_chunk as f64 * self.bank_area(ns, w, k, true)
                        + self.a_mgr * banks_per_chunk as f64
                } else {
                    self.bank_area(bank_n, w, k, true)
                };
                chunks as f64 * per_chunk
                    + self.a_merge * merge_units(chunks, fanout) * nlog2n(fanout)
                    + self.a_cell * (chunks * bank_n) as f64 * w as f64
            }
        }
    }

    fn bank_area(&self, ns: usize, w: u32, k: usize, skip: bool) -> f64 {
        self.a_row * nlog2n(ns)
            + self.a_sa * ns as f64
            + self.a_colp * w as f64
            + self.a_ctl
            + if skip { self.a_skip } else { 0.0 }
            + k as f64 * self.a_st * (ns as f64 + index_bits(w))
    }

    /// Power in mW under the given switching activity.
    pub fn power_mw(&self, arch: SorterArch, act: Activity) -> f64 {
        match arch {
            SorterArch::Merge { n } => self.p_merge * nlog2n(n),
            SorterArch::Baseline { n, w } => {
                self.bank_power(n, w, 0, false, act) + self.p_glob
            }
            SorterArch::ColSkip { n, w, k } => {
                self.bank_power(n, w, k, true, act) + self.p_glob
            }
            SorterArch::MultiBank { n, w, k, banks } => {
                let ns = n / banks;
                let mgr = if banks > 1 { self.p_mgr * banks as f64 } else { 0.0 };
                banks as f64 * self.bank_power(ns, w, k, true, act) + mgr + self.p_glob
            }
            SorterArch::Hierarchical { bank_n, w, k, chunks, banks_per_chunk, fanout } => {
                // Chunks sort simultaneously (parallel banks), so their
                // power sums; the merge tree mirrors its area term.
                let per_chunk = if banks_per_chunk > 1 {
                    let ns = bank_n / banks_per_chunk;
                    banks_per_chunk as f64 * self.bank_power(ns, w, k, true, act)
                        + self.p_mgr * banks_per_chunk as f64
                } else {
                    self.bank_power(bank_n, w, k, true, act)
                };
                chunks as f64 * per_chunk
                    + self.p_merge * merge_units(chunks, fanout) * nlog2n(fanout)
                    + self.p_glob
            }
        }
    }

    fn bank_power(&self, ns: usize, w: u32, k: usize, skip: bool, act: Activity) -> f64 {
        self.p_row * nlog2n(ns)
            + self.p_sa * ns as f64 * act.u_cr
            + self.p_colp * w as f64
            + self.p_ctl
            + if skip { self.p_skip } else { 0.0 }
            + k as f64 * self.p_st * (ns as f64 + index_bits(w)) * act.u_tbl
    }

    /// Throughput in numbers/ns given cycles/number at the paper's clock.
    pub fn throughput_num_per_ns(cycles_per_number: f64) -> f64 {
        if cycles_per_number <= 0.0 {
            0.0
        } else {
            CLOCK_HZ / cycles_per_number / 1e9
        }
    }

    /// Area efficiency in Num/ns/mm² (the paper's Fig. 8(a) metric).
    pub fn area_efficiency(&self, arch: SorterArch, cycles_per_number: f64) -> f64 {
        let area_mm2 = self.area_kum2(arch) * 1e-3; // Kµm² -> mm² is /1e3
        Self::throughput_num_per_ns(cycles_per_number) / area_mm2
    }

    /// Energy efficiency in Num/µJ (the paper's Fig. 8(a) metric).
    pub fn energy_efficiency(
        &self,
        arch: SorterArch,
        cycles_per_number: f64,
        act: Activity,
    ) -> f64 {
        let power_w = self.power_mw(arch, act) * 1e-3;
        let num_per_s = Self::throughput_num_per_ns(cycles_per_number) * 1e9;
        num_per_s / power_w * 1e-6
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{DEFAULT_N, DEFAULT_WIDTH};

    const N: usize = DEFAULT_N;
    const W: u32 = DEFAULT_WIDTH;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * b.abs().max(1.0)
    }

    #[test]
    fn anchors_reproduce_fig8a_areas() {
        let m = CostModel::calibrated();
        assert!(close(m.area_kum2(SorterArch::Baseline { n: N, w: W }), 77.8, 1e-6));
        assert!(close(m.area_kum2(SorterArch::ColSkip { n: N, w: W, k: 2 }), 101.1, 1e-6));
        assert!(close(
            m.area_kum2(SorterArch::MultiBank { n: N, w: W, k: 2, banks: 16 }),
            86.9,
            1e-6
        ));
        assert!(close(m.area_kum2(SorterArch::Merge { n: N }), 246.1, 1e-6));
    }

    #[test]
    fn anchors_reproduce_fig8a_powers() {
        let m = CostModel::calibrated();
        let base = m.power_mw(SorterArch::Baseline { n: N, w: W }, Activity::nominal_baseline());
        assert!(close(base, 319.7, 1e-6), "{base}");
        let cs = m.power_mw(SorterArch::ColSkip { n: N, w: W, k: 2 }, Activity::nominal_colskip());
        assert!(close(cs, 385.2, 1e-6), "{cs}");
        let mb = m.power_mw(
            SorterArch::MultiBank { n: N, w: W, k: 2, banks: 16 },
            Activity::nominal_colskip(),
        );
        assert!(close(mb, 349.3, 1e-6), "{mb}");
        let mg = m.power_mw(SorterArch::Merge { n: N }, Activity::nominal_baseline());
        assert!(close(mg, 825.9, 1e-6), "{mg}");
    }

    #[test]
    fn coefficients_are_physical() {
        let m = CostModel::calibrated();
        for (name, v) in [
            ("a_row", m.a_row),
            ("a_sa", m.a_sa),
            ("a_st", m.a_st),
            ("p_row", m.p_row),
            ("p_sa", m.p_sa),
            ("p_st", m.p_st),
        ] {
            assert!(v > 0.0, "{name} = {v} must be positive");
        }
    }

    #[test]
    fn area_monotone_in_k() {
        // Fig. 7: sorter area grows with k (bigger state controller).
        let m = CostModel::calibrated();
        let areas: Vec<f64> =
            (0..=8).map(|k| m.area_kum2(SorterArch::ColSkip { n: N, w: W, k })).collect();
        assert!(areas.windows(2).all(|p| p[1] > p[0]), "{areas:?}");
    }

    #[test]
    fn multibank_area_and_power_decrease_with_smaller_ns() {
        // Fig. 8(b): both drop monotonically toward Ns=64 and save about
        // 14% (area) / 9% (power) at Ns=64.
        let m = CostModel::calibrated();
        let single = SorterArch::ColSkip { n: N, w: W, k: 2 };
        let a0 = m.area_kum2(single);
        let p0 = m.power_mw(single, Activity::nominal_colskip());
        let mut prev_a = a0;
        let mut prev_p = p0;
        for banks in [2usize, 4, 8, 16] {
            let arch = SorterArch::MultiBank { n: N, w: W, k: 2, banks };
            let a = m.area_kum2(arch);
            let p = m.power_mw(arch, Activity::nominal_colskip());
            assert!(a < prev_a, "area must fall: C={banks}: {a} vs {prev_a}");
            assert!(p < prev_p, "power must fall: C={banks}: {p} vs {prev_p}");
            prev_a = a;
            prev_p = p;
        }
        assert!(close(prev_a / a0, 0.86, 0.02), "Ns=64 area ratio {}", prev_a / a0);
        assert!(close(prev_p / p0, 0.91, 0.02), "Ns=64 power ratio {}", prev_p / p0);
    }

    #[test]
    fn fig8a_efficiency_metrics_reproduce() {
        // With the paper's cycles/number, the derived metrics must match
        // Fig. 8(a): baseline 0.20 Num/ns/mm² and 48.9 Num/µJ; col-skip
        // k=2 0.63 and 165.6; multibank 0.73 and 182.6; merge 0.20 / 60.5.
        let m = CostModel::calibrated();
        let base = SorterArch::Baseline { n: N, w: W };
        assert!(close(m.area_efficiency(base, 32.0), 0.20, 0.02));
        assert!(
            close(m.energy_efficiency(base, 32.0, Activity::nominal_baseline()), 48.9, 0.01),
            "{}",
            m.energy_efficiency(base, 32.0, Activity::nominal_baseline())
        );
        let cs = SorterArch::ColSkip { n: N, w: W, k: 2 };
        assert!(close(m.area_efficiency(cs, 7.84), 0.63, 0.01));
        assert!(close(m.energy_efficiency(cs, 7.84, Activity::nominal_colskip()), 165.6, 0.01));
        let mb = SorterArch::MultiBank { n: N, w: W, k: 2, banks: 16 };
        assert!(close(m.area_efficiency(mb, 7.84), 0.73, 0.01));
        assert!(close(m.energy_efficiency(mb, 7.84, Activity::nominal_colskip()), 182.6, 0.01));
        let mg = SorterArch::Merge { n: N };
        assert!(close(m.area_efficiency(mg, 10.0), 0.20, 0.02));
        assert!(close(m.energy_efficiency(mg, 10.0, Activity::nominal_baseline()), 60.5, 0.01));
    }

    #[test]
    fn headline_ratios_hold() {
        // Abstract: 4.08× speedup, 3.14× area efficiency, 3.39× energy
        // efficiency over the baseline at k=2 (7.84 vs 32 cyc/num).
        let m = CostModel::calibrated();
        let base = SorterArch::Baseline { n: N, w: W };
        let cs = SorterArch::ColSkip { n: N, w: W, k: 2 };
        let speedup = 32.0 / 7.84;
        assert!(close(speedup, 4.08, 0.01));
        let ae = m.area_efficiency(cs, 7.84) / m.area_efficiency(base, 32.0);
        assert!(close(ae, 3.14, 0.01), "area-eff ratio {ae}");
        let ee = m.energy_efficiency(cs, 7.84, Activity::nominal_colskip())
            / m.energy_efficiency(base, 32.0, Activity::nominal_baseline());
        assert!(close(ee, 3.39, 0.01), "energy-eff ratio {ee}");
    }

    #[test]
    fn hierarchical_with_one_chunk_is_a_colskip_bank() {
        // chunks=1 has no merge tree, so the pipeline degenerates to the
        // plain column-skipping sorter's area/power exactly.
        let m = CostModel::calibrated();
        let hier = SorterArch::Hierarchical {
            bank_n: N,
            w: W,
            k: 2,
            chunks: 1,
            banks_per_chunk: 1,
            fanout: 4,
        };
        let cs = SorterArch::ColSkip { n: N, w: W, k: 2 };
        assert!((m.area_kum2(hier) - m.area_kum2(cs)).abs() < 1e-9);
        let act = Activity::nominal_colskip();
        assert!((m.power_mw(hier, act) - m.power_mw(cs, act)).abs() < 1e-9);
    }

    #[test]
    fn hierarchical_cost_grows_with_chunks_and_shrinks_with_fanout() {
        let m = CostModel::calibrated();
        let arch = |chunks: usize, fanout: usize| SorterArch::Hierarchical {
            bank_n: N,
            w: W,
            k: 2,
            chunks,
            banks_per_chunk: 1,
            fanout,
        };
        let act = Activity::nominal_colskip();
        // More chunks: strictly more silicon and more parallel power.
        let areas: Vec<f64> = [1usize, 4, 16, 64].map(|c| m.area_kum2(arch(c, 4))).to_vec();
        assert!(areas.windows(2).all(|p| p[1] > p[0]), "{areas:?}");
        let powers: Vec<f64> = [1usize, 4, 16, 64].map(|c| m.power_mw(arch(c, 4), act)).to_vec();
        assert!(powers.windows(2).all(|p| p[1] > p[0]), "{powers:?}");
        // Wider fanout buys fewer merge passes (latency/energy) at the
        // price of richer merge units: slightly more merge silicon.
        assert!(m.area_kum2(arch(64, 8)) > m.area_kum2(arch(64, 2)));
        // The merge tree stays a small fraction of the bank silicon.
        let with_merge = m.area_kum2(arch(64, 4));
        let banks_only = 64.0 * (m.area_kum2(arch(1, 4)) - m.a_cell * N as f64 * W as f64)
            + 64.0 * m.a_cell * N as f64 * W as f64;
        assert!((with_merge - banks_only) / banks_only < 0.01, "merge tree dominates?");
    }

    #[test]
    fn hierarchical_sub_banked_chunks_are_cheaper() {
        // Fig. 8(b) carries over: striping each chunk over 16 sub-banks
        // shrinks the per-chunk circuit.
        let m = CostModel::calibrated();
        let flat = SorterArch::Hierarchical {
            bank_n: N,
            w: W,
            k: 2,
            chunks: 8,
            banks_per_chunk: 1,
            fanout: 4,
        };
        let banked = SorterArch::Hierarchical {
            bank_n: N,
            w: W,
            k: 2,
            chunks: 8,
            banks_per_chunk: 16,
            fanout: 4,
        };
        assert!(m.area_kum2(banked) < m.area_kum2(flat));
    }

    #[test]
    fn activity_from_stats() {
        // cycles = crs + drains = 100; table accesses = srs + sls = 15.
        let s = SortStats { crs: 90, sls: 5, drains: 10, srs: 10, ..Default::default() };
        let a = Activity::from_stats(&s);
        assert!(close(a.u_cr, 0.9, 1e-9));
        assert!(close(a.u_tbl, 0.15, 1e-9));
    }

    #[test]
    fn area_efficiency_peaks_at_small_k_under_saturating_speedup() {
        // Fig. 7's shape: if speedup saturates by k=2, area efficiency
        // peaks at k=1 and declines after.
        let m = CostModel::calibrated();
        // Stylized MapReduce speedup curve (saturating at k=2).
        let cyc = [32.0, 8.5, 7.84, 7.8, 7.9, 8.0];
        let eff: Vec<f64> = (1..=5)
            .map(|k| m.area_efficiency(SorterArch::ColSkip { n: N, w: W, k }, cyc[k]))
            .collect();
        assert!(eff[0] > eff[1] && eff[1] > eff[2], "{eff:?}");
    }
}

//! Bit-level data structures shared by the memory model and the sorters.
//!
//! The 1T1R crossbar stores one bit per cell; a length-`N` array of `w`-bit
//! numbers occupies an `N × w` cell grid with the MSB in the leftmost
//! column (paper §III.B). Two views are provided:
//!
//! * [`RowMask`] — a dense bitset over rows (wordline / RE state, sense-amp
//!   outputs). All hot-path set algebra is word-parallel over `u64` limbs.
//! * [`BitPlanes`] — the column-major (bit-plane) view of the stored
//!   array: `plane[j]` is the [`RowMask`] of rows whose j-th bit is 1.
//!   A column read is then two `AND`s against the active mask.

/// Transpose a 64×64 bit matrix in place.
///
/// `a[i]` is row `i` of the matrix with bit `j` (LSB-first) holding
/// cell `(i, j)`; on return, cell `(i, j)` has moved to `(63-j, 63-i)`
/// — a transpose along the anti-diagonal. That orientation is free
/// (the classic mask-and-shift network — Hacker's Delight §7-3 —
/// produces it without any extra bit-reversal passes) and is what
/// [`BitPlanes::new`] wants: loading value `i` of a 64-row block into
/// `a[63-i]` makes bit-plane `j` of the block come out in `a[63-j]`.
/// The recurrence swaps progressively smaller off-diagonal sub-blocks
/// (32×32 down to 1×1), so the whole transpose is `6·64` word
/// operations instead of the 4096 single-bit scatters of a per-bit
/// build. Applying it twice is the identity (each sub-block swap is an
/// involution), which the round-trip tests pin.
pub fn transpose(a: &mut [u64; 64]) {
    let mut j: u32 = 32;
    let mut m: u64 = 0x0000_0000_FFFF_FFFF;
    while j != 0 {
        let mut k: usize = 0;
        while k < 64 {
            let t = (a[k] ^ (a[k + j as usize] >> j)) & m;
            a[k] ^= t;
            a[k + j as usize] ^= t << j;
            k = (k + j as usize + 1) & !(j as usize);
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// Dense bitset over the rows of a memory bank.
///
/// Used for wordline (row-exclusion) state, sense-amp column images and
/// state-controller snapshots. Operations are word-parallel; the hot loop
/// never allocates (see [`RowMask::and_not_assign`] and friends).
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct RowMask {
    words: Vec<u64>,
    n: usize,
}

impl RowMask {
    /// Mask with all `n` rows cleared.
    pub fn new_empty(n: usize) -> Self {
        RowMask { words: vec![0; n.div_ceil(64)], n }
    }

    /// Mask with all `n` rows set.
    pub fn new_full(n: usize) -> Self {
        let mut m = Self::new_empty(n);
        for w in m.words.iter_mut() {
            *w = u64::MAX;
        }
        m.trim();
        m
    }

    /// Build from an iterator of row indexes.
    pub fn from_rows(n: usize, rows: impl IntoIterator<Item = usize>) -> Self {
        let mut m = Self::new_empty(n);
        for r in rows {
            m.set(r);
        }
        m
    }

    /// Number of rows the mask covers (bank height, not popcount).
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    /// True if the mask covers zero rows.
    #[inline]
    pub fn is_len_zero(&self) -> bool {
        self.n == 0
    }

    #[inline]
    fn trim(&mut self) {
        let tail = self.n % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    /// Set row `r`.
    #[inline]
    pub fn set(&mut self, r: usize) {
        debug_assert!(r < self.n);
        self.words[r / 64] |= 1u64 << (r % 64);
    }

    /// Clear row `r`.
    #[inline]
    pub fn clear(&mut self, r: usize) {
        debug_assert!(r < self.n);
        self.words[r / 64] &= !(1u64 << (r % 64));
    }

    /// Read row `r`.
    #[inline]
    pub fn get(&self, r: usize) -> bool {
        debug_assert!(r < self.n);
        (self.words[r / 64] >> (r % 64)) & 1 == 1
    }

    /// Number of set rows.
    #[inline]
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True if no row is set.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.words.iter().all(|&w| w == 0)
    }

    /// Index of the lowest set row, if any. Models the hardware priority
    /// encoder that selects the emitted min row.
    #[inline]
    pub fn first_set(&self) -> Option<usize> {
        for (i, &w) in self.words.iter().enumerate() {
            if w != 0 {
                return Some(i * 64 + w.trailing_zeros() as usize);
            }
        }
        None
    }

    /// `self &= other`.
    #[inline]
    pub fn and_assign(&mut self, other: &RowMask) {
        debug_assert_eq!(self.n, other.n);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= b;
        }
    }

    /// `self &= !other` — the row-exclusion update (RE).
    #[inline]
    pub fn and_not_assign(&mut self, other: &RowMask) {
        debug_assert_eq!(self.n, other.n);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a &= !b;
        }
    }

    /// `self |= other`.
    #[inline]
    pub fn or_assign(&mut self, other: &RowMask) {
        debug_assert_eq!(self.n, other.n);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Write `a & b` into `self` without allocating, returning the
    /// popcount of the result. The count is free (the limbs are already
    /// in hand) and lets `RowProcessor::begin_from_snapshot` report the
    /// resumed candidate count without a second pass — the singleton
    /// fast path in `sorter/colskip.rs` keys off it.
    #[inline]
    pub fn assign_and(&mut self, a: &RowMask, b: &RowMask) -> usize {
        debug_assert_eq!(a.n, b.n);
        debug_assert_eq!(self.n, a.n);
        let mut count = 0usize;
        for ((d, x), y) in self.words.iter_mut().zip(&a.words).zip(&b.words) {
            let v = x & y;
            *d = v;
            count += v.count_ones() as usize;
        }
        count
    }

    /// Clear every row.
    #[inline]
    pub fn clear_all(&mut self) {
        self.words.fill(0);
    }

    /// Copy `other` into `self` without allocating.
    #[inline]
    pub fn copy_from(&mut self, other: &RowMask) {
        debug_assert_eq!(self.n, other.n);
        self.words.copy_from_slice(&other.words);
    }

    /// True if `self & other` is non-empty (no temporary allocated).
    #[inline]
    pub fn intersects(&self, other: &RowMask) -> bool {
        debug_assert_eq!(self.n, other.n);
        self.words.iter().zip(&other.words).any(|(a, b)| a & b != 0)
    }

    /// Popcount of `self & other` without a temporary.
    #[inline]
    pub fn intersect_count(&self, other: &RowMask) -> usize {
        debug_assert_eq!(self.n, other.n);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a & b).count_ones() as usize)
            .sum()
    }

    /// True if `self & !other` is non-empty.
    #[inline]
    pub fn has_bit_outside(&self, other: &RowMask) -> bool {
        debug_assert_eq!(self.n, other.n);
        self.words.iter().zip(&other.words).any(|(a, b)| a & !b != 0)
    }

    /// Iterate the indexes of set rows, ascending.
    pub fn iter_set(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(|(i, &w0)| {
            let mut w = w0;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros() as usize;
                    w &= w - 1;
                    Some(i * 64 + b)
                }
            })
        })
    }

    /// Raw limb view (used by the PJRT bridge and tests).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Mutable limb view (hot-path fused kernels in `memory::Bank`).
    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }
}

/// Column-major (bit-plane) image of an array stored in a bank.
///
/// `plane(j)` is the set of rows whose bit `j` is 1 — exactly the pattern
/// of cell conductances along bit column `j` of the 1T1R crossbar.
#[derive(Clone, Debug)]
pub struct BitPlanes {
    planes: Vec<RowMask>,
    n: usize,
    width: u32,
}

impl BitPlanes {
    /// Build the planes for `values`, keeping the `width` low bits of each.
    ///
    /// Panics if any value needs more than `width` bits (a real crossbar
    /// would silently truncate; truncation here would mis-sort, so we fail
    /// loudly instead).
    pub fn new(values: &[u32], width: u32) -> Self {
        assert!((1..=32).contains(&width), "width must be in 1..=32");
        if width < 32 {
            if let Some(&v) = values.iter().find(|&&v| v >> width != 0) {
                panic!("value {v:#x} does not fit in {width} bits");
            }
        }
        let n = values.len();
        let mut planes = vec![RowMask::new_empty(n); width as usize];
        // Word-blocked build: each 64-row chunk is a 64×64 bit matrix
        // with value `i` loaded into block row `63-i`; one [`transpose`]
        // then yields bit-plane `j` of the whole chunk in `block[63-j]`,
        // which lands directly in limb `b` of plane `j`. Rows past the
        // end of a short tail chunk stay zero, preserving the `RowMask`
        // trimmed-tail invariant. Equivalence with the one-bit-at-a-time
        // scatter build is pinned by `blocked_build_matches_scatter_*`.
        let mut block = [0u64; 64];
        for (b, chunk) in values.chunks(64).enumerate() {
            block.fill(0);
            for (i, &v) in chunk.iter().enumerate() {
                block[63 - i] = v as u64;
            }
            transpose(&mut block);
            for (j, plane) in planes.iter_mut().enumerate() {
                plane.words_mut()[b] = block[63 - j];
            }
        }
        BitPlanes { planes, n, width }
    }

    /// Pre-blocking reference build: scatter each set bit individually.
    /// Kept only as the equivalence oracle for the transpose-based
    /// [`BitPlanes::new`].
    #[cfg(test)]
    pub(crate) fn new_scatter_reference(values: &[u32], width: u32) -> Self {
        assert!((1..=32).contains(&width), "width must be in 1..=32");
        if width < 32 {
            if let Some(&v) = values.iter().find(|&&v| v >> width != 0) {
                panic!("value {v:#x} does not fit in {width} bits");
            }
        }
        let n = values.len();
        let mut planes = vec![RowMask::new_empty(n); width as usize];
        for (r, &v) in values.iter().enumerate() {
            let mut bits = v;
            while bits != 0 {
                let j = bits.trailing_zeros();
                planes[j as usize].set(r);
                bits &= bits - 1;
            }
        }
        BitPlanes { planes, n, width }
    }

    /// Number of rows.
    #[inline]
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Word width in bits.
    #[inline]
    pub fn width(&self) -> u32 {
        self.width
    }

    /// The rows whose bit `j` is 1.
    #[inline]
    pub fn plane(&self, j: u32) -> &RowMask {
        &self.planes[j as usize]
    }

    /// Flip the stored bit at (`row`, `col`) — used by fault injection.
    pub fn flip_bit(&mut self, row: usize, col: u32) {
        let p = &mut self.planes[col as usize];
        if p.get(row) {
            p.clear(row);
        } else {
            p.set(row);
        }
    }

    /// Force the stored bit at (`row`, `col`) — used by fault injection.
    pub fn set_bit(&mut self, row: usize, col: u32, v: bool) {
        let p = &mut self.planes[col as usize];
        if v {
            p.set(row);
        } else {
            p.clear(row);
        }
    }

    /// Reconstruct the value stored in `row` (a full row read).
    pub fn read_row(&self, row: usize) -> u32 {
        let mut v = 0u32;
        for j in 0..self.width {
            if self.planes[j as usize].get(row) {
                v |= 1 << j;
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rowmask_basic_set_clear_get() {
        let mut m = RowMask::new_empty(130);
        assert_eq!(m.count(), 0);
        m.set(0);
        m.set(64);
        m.set(129);
        assert!(m.get(0) && m.get(64) && m.get(129));
        assert!(!m.get(1));
        assert_eq!(m.count(), 3);
        m.clear(64);
        assert!(!m.get(64));
        assert_eq!(m.count(), 2);
    }

    #[test]
    fn rowmask_full_trims_tail() {
        let m = RowMask::new_full(70);
        assert_eq!(m.count(), 70);
        assert_eq!(m.words().len(), 2);
        assert_eq!(m.words()[1], (1u64 << 6) - 1);
    }

    #[test]
    fn rowmask_full_exact_word_boundary() {
        let m = RowMask::new_full(128);
        assert_eq!(m.count(), 128);
        assert_eq!(m.words()[1], u64::MAX);
    }

    #[test]
    fn rowmask_first_set_and_iter() {
        let m = RowMask::from_rows(200, [5, 77, 199]);
        assert_eq!(m.first_set(), Some(5));
        assert_eq!(m.iter_set().collect::<Vec<_>>(), vec![5, 77, 199]);
        assert_eq!(RowMask::new_empty(10).first_set(), None);
    }

    #[test]
    fn rowmask_set_algebra() {
        let a = RowMask::from_rows(100, [1, 2, 3, 70]);
        let b = RowMask::from_rows(100, [2, 3, 4, 99]);
        let mut and = a.clone();
        and.and_assign(&b);
        assert_eq!(and.iter_set().collect::<Vec<_>>(), vec![2, 3]);
        let mut andnot = a.clone();
        andnot.and_not_assign(&b);
        assert_eq!(andnot.iter_set().collect::<Vec<_>>(), vec![1, 70]);
        let mut or = a.clone();
        or.or_assign(&b);
        assert_eq!(or.count(), 6);
        assert!(a.intersects(&b));
        assert_eq!(a.intersect_count(&b), 2);
        assert!(a.has_bit_outside(&b));
        let sub = RowMask::from_rows(100, [2, 3]);
        assert!(!sub.has_bit_outside(&b));
    }

    #[test]
    fn rowmask_assign_and_no_alloc_path() {
        let a = RowMask::from_rows(64, [0, 1, 2]);
        let b = RowMask::from_rows(64, [1, 2, 3]);
        let mut d = RowMask::new_empty(64);
        assert_eq!(d.assign_and(&a, &b), 2);
        assert_eq!(d.iter_set().collect::<Vec<_>>(), vec![1, 2]);
        d.copy_from(&a);
        assert_eq!(d, a);
    }

    #[test]
    fn rowmask_assign_and_counts_across_words() {
        let a = RowMask::from_rows(200, [0, 63, 64, 130, 199]);
        let b = RowMask::from_rows(200, [63, 64, 130, 131]);
        let mut d = RowMask::new_empty(200);
        assert_eq!(d.assign_and(&a, &b), 3);
        assert_eq!(d.iter_set().collect::<Vec<_>>(), vec![63, 64, 130]);
        let empty = RowMask::new_empty(200);
        assert_eq!(d.assign_and(&a, &empty), 0);
        assert!(d.is_empty());
    }

    #[test]
    fn transpose_maps_cells_to_the_anti_diagonal() {
        // A single set bit at (r, c) must land at (63-c, 63-r).
        for (r, c) in [(0, 0), (0, 63), (63, 0), (17, 42), (42, 17), (31, 31)] {
            let mut a = [0u64; 64];
            a[r] = 1u64 << c;
            transpose(&mut a);
            for (i, &w) in a.iter().enumerate() {
                let want = if i == 63 - c { 1u64 << (63 - r) } else { 0 };
                assert_eq!(w, want, "bit ({r},{c}) row {i}");
            }
        }
    }

    #[test]
    fn transpose_twice_is_identity_on_random_blocks() {
        let mut rng = crate::datasets::rng::Rng::new(0xB17_B10C);
        for _ in 0..32 {
            let mut a = [0u64; 64];
            for w in a.iter_mut() {
                *w = rng.next_u64();
            }
            let orig = a;
            transpose(&mut a);
            transpose(&mut a);
            assert_eq!(a, orig);
        }
    }

    #[test]
    fn blocked_build_matches_scatter_on_random_inputs() {
        // n deliberately spans <64, ==64, non-multiples of 64, and >128
        // so tail chunks and multi-limb planes are all exercised.
        let mut rng = crate::datasets::rng::Rng::new(0x5CA7_7E12);
        for &n in &[0usize, 1, 3, 63, 64, 65, 100, 128, 129, 200, 321] {
            for &width in &[1u32, 4, 13, 32] {
                let values: Vec<u32> = (0..n)
                    .map(|_| {
                        let v = rng.next_u32();
                        if width < 32 { v >> (32 - width) } else { v }
                    })
                    .collect();
                let blocked = BitPlanes::new(&values, width);
                let reference = BitPlanes::new_scatter_reference(&values, width);
                assert_eq!(blocked.rows(), reference.rows());
                for j in 0..width {
                    assert_eq!(
                        blocked.plane(j),
                        reference.plane(j),
                        "n={n} width={width} plane {j}"
                    );
                }
            }
        }
    }

    #[test]
    fn bitplanes_roundtrip() {
        let vals = [8u32, 9, 10, 0, 15];
        let bp = BitPlanes::new(&vals, 4);
        for (r, &v) in vals.iter().enumerate() {
            assert_eq!(bp.read_row(r), v, "row {r}");
        }
    }

    #[test]
    fn bitplanes_plane_contents() {
        // 8=1000 9=1001 10=1010 (paper's Fig. 1 example)
        let bp = BitPlanes::new(&[8, 9, 10], 4);
        assert_eq!(bp.plane(3).iter_set().collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(bp.plane(2).count(), 0);
        assert_eq!(bp.plane(1).iter_set().collect::<Vec<_>>(), vec![2]);
        assert_eq!(bp.plane(0).iter_set().collect::<Vec<_>>(), vec![1]);
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn bitplanes_rejects_overflow() {
        BitPlanes::new(&[16], 4);
    }

    #[test]
    fn bitplanes_fault_flip() {
        let mut bp = BitPlanes::new(&[8, 9, 10], 4);
        bp.flip_bit(0, 0); // 8 -> 9
        assert_eq!(bp.read_row(0), 9);
        bp.set_bit(0, 0, false); // back to 8
        assert_eq!(bp.read_row(0), 8);
        bp.set_bit(0, 0, false); // idempotent
        assert_eq!(bp.read_row(0), 8);
    }

    #[test]
    fn bitplanes_width_32_full_range() {
        let vals = [u32::MAX, 0, 0x8000_0000, 1];
        let bp = BitPlanes::new(&vals, 32);
        for (r, &v) in vals.iter().enumerate() {
            assert_eq!(bp.read_row(r), v);
        }
    }
}

//! Minimal JSON emitter (no serde offline): enough to serialize the
//! figure harness outputs for EXPERIMENTS.md and external plotting.

/// A JSON value builder.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Serialize compactly.
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        out.push_str(&format!("{}", *x as i64));
                    } else {
                        out.push_str(&format!("{x}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}

impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}

impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let j = Json::obj([
            ("name", "fig6".into()),
            ("points", Json::arr([Json::obj([("k", 1usize.into()), ("x", 1.5f64.into())])])),
        ]);
        assert_eq!(j.render(), r#"{"name":"fig6","points":[{"k":1,"x":1.5}]}"#);
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_decimal() {
        assert_eq!(Json::Num(32.0).render(), "32");
        assert_eq!(Json::Num(7.84).render(), "7.84");
    }

    #[test]
    fn non_finite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).render(), "null");
        assert_eq!(Json::Num(f64::INFINITY).render(), "null");
    }
}

//! Figure/table emitters: regenerate every evaluation artifact of the
//! paper (§V) from simulator runs + the calibrated cost model, in both
//! human-readable table form and machine-readable JSON (hand-rolled —
//! no serde offline).

pub mod json;

use crate::cost::{Activity, CostModel, SorterArch};
use crate::datasets::{Dataset, DatasetKind};
use crate::multibank::{MultiBankConfig, MultiBankSorter};
use crate::params::{DEFAULT_N, DEFAULT_WIDTH};
use crate::sorter::baseline::BaselineSorter;
use crate::sorter::colskip::ColSkipSorter;
use crate::sorter::merge::MergeSorter;
use crate::sorter::{InMemorySorter, SortStats};

/// One measured point of Fig. 6: normalized speedup over the baseline.
#[derive(Clone, Debug)]
pub struct Fig6Point {
    pub dataset: DatasetKind,
    pub k: usize,
    pub cycles_per_number: f64,
    pub speedup: f64,
}

/// Regenerate Fig. 6: speedup vs k for every dataset
/// (N=1024, w=32, k = 1..=k_max), averaged over `trials` seeds.
pub fn fig6(n: usize, width: u32, k_max: usize, trials: u64, seed: u64) -> Vec<Fig6Point> {
    let mut out = Vec::new();
    for kind in DatasetKind::ALL {
        for k in 1..=k_max {
            let mut cyc_sum = 0.0;
            for t in 0..trials {
                let d = Dataset::generate(kind, n, width, seed + t);
                let mut s = ColSkipSorter::new(crate::sorter::colskip::ColSkipConfig {
                    width,
                    k,
                    ..Default::default()
                });
                cyc_sum += s.sort_with_stats(&d.values).stats.cycles_per_number(n);
            }
            let cycles_per_number = cyc_sum / trials as f64;
            out.push(Fig6Point {
                dataset: kind,
                k,
                cycles_per_number,
                speedup: width as f64 / cycles_per_number,
            });
        }
    }
    out
}

/// One measured point of Fig. 7: normalized area/power and efficiencies
/// vs k on the MapReduce dataset.
#[derive(Clone, Debug)]
pub struct Fig7Point {
    pub k: usize,
    pub cycles_per_number: f64,
    pub area_kum2: f64,
    pub power_mw: f64,
    pub norm_area: f64,
    pub norm_power: f64,
    pub area_eff_ratio: f64,
    pub energy_eff_ratio: f64,
}

/// Regenerate Fig. 7 (MapReduce, N=1024, w=32, k sweep).
pub fn fig7(n: usize, width: u32, k_max: usize, trials: u64, seed: u64) -> Vec<Fig7Point> {
    let model = CostModel::calibrated();
    let base_arch = SorterArch::Baseline { n, w: width };
    let base_area = model.area_kum2(base_arch);
    let base_power = model.power_mw(base_arch, Activity::nominal_baseline());
    let base_ae = model.area_efficiency(base_arch, width as f64);
    let base_ee =
        model.energy_efficiency(base_arch, width as f64, Activity::nominal_baseline());
    (1..=k_max)
        .map(|k| {
            let mut cyc = 0.0;
            let mut agg = SortStats::default();
            for t in 0..trials {
                let d = Dataset::generate(DatasetKind::MapReduce, n, width, seed + t);
                let mut s = ColSkipSorter::new(crate::sorter::colskip::ColSkipConfig {
                    width,
                    k,
                    ..Default::default()
                });
                let out = s.sort_with_stats(&d.values);
                cyc += out.stats.cycles_per_number(n);
                agg.merge_from(&out.stats);
            }
            let cyc = cyc / trials as f64;
            let act = Activity::from_stats(&agg);
            let arch = SorterArch::ColSkip { n, w: width, k };
            let area = model.area_kum2(arch);
            let power = model.power_mw(arch, act);
            Fig7Point {
                k,
                cycles_per_number: cyc,
                area_kum2: area,
                power_mw: power,
                norm_area: area / base_area,
                norm_power: power / base_power,
                area_eff_ratio: model.area_efficiency(arch, cyc) / base_ae,
                energy_eff_ratio: model.energy_efficiency(arch, cyc, act) / base_ee,
            }
        })
        .collect()
}

/// One row of the Fig. 8(a) implementation summary.
#[derive(Clone, Debug)]
pub struct Fig8aRow {
    pub name: &'static str,
    pub cycles_per_number: f64,
    pub area_kum2: f64,
    pub area_eff: f64,
    pub power_mw: f64,
    pub energy_eff: f64,
}

/// Regenerate Fig. 8(a): baseline / merge / col-skip k=2 / k=2 Ns=64 on
/// the MapReduce dataset.
pub fn fig8a(n: usize, width: u32, trials: u64, seed: u64) -> Vec<Fig8aRow> {
    let model = CostModel::calibrated();
    let mut rows = Vec::new();

    let mut run = |name: &'static str,
                   arch: SorterArch,
                   sorter: &mut dyn InMemorySorter,
                   nominal: Option<Activity>| {
        let mut cyc = 0.0;
        let mut agg = SortStats::default();
        for t in 0..trials {
            let d = Dataset::generate(DatasetKind::MapReduce, n, width, seed + t);
            let out = sorter.sort_with_stats(&d.values);
            cyc += out.stats.cycles_per_number(n);
            agg.merge_from(&out.stats);
        }
        let cyc = cyc / trials as f64;
        let act = nominal.unwrap_or_else(|| Activity::from_stats(&agg));
        rows.push(Fig8aRow {
            name,
            cycles_per_number: cyc,
            area_kum2: model.area_kum2(arch),
            area_eff: model.area_efficiency(arch, cyc),
            power_mw: model.power_mw(arch, act),
            energy_eff: model.energy_efficiency(arch, cyc, act),
        });
    };

    run(
        "baseline",
        SorterArch::Baseline { n, w: width },
        &mut BaselineSorter::with_width(width),
        Some(Activity::nominal_baseline()),
    );
    run(
        "merge",
        SorterArch::Merge { n },
        &mut MergeSorter::new(),
        Some(Activity::nominal_baseline()),
    );
    run(
        "col-skip k=2",
        SorterArch::ColSkip { n, w: width, k: 2 },
        &mut ColSkipSorter::new(crate::sorter::colskip::ColSkipConfig {
            width,
            k: 2,
            ..Default::default()
        }),
        None,
    );
    run(
        "col-skip k=2 Ns=64",
        SorterArch::MultiBank { n, w: width, k: 2, banks: (n / 64).max(1) },
        &mut MultiBankSorter::new(MultiBankConfig {
            width,
            k: 2,
            banks: (n / 64).max(1),
            ..Default::default()
        }),
        None,
    );
    rows
}

/// One point of Fig. 8(b): normalized area/power vs sub-sorter length.
#[derive(Clone, Debug)]
pub struct Fig8bPoint {
    pub sub_len: usize,
    pub banks: usize,
    pub norm_area: f64,
    pub norm_power: f64,
}

/// Regenerate Fig. 8(b): Ns ∈ {64, 256, 512, 1024} at N=1024, k=2.
pub fn fig8b(n: usize, width: u32) -> Vec<Fig8bPoint> {
    let model = CostModel::calibrated();
    let act = Activity::nominal_colskip();
    let single = SorterArch::ColSkip { n, w: width, k: 2 };
    let a0 = model.area_kum2(single);
    let p0 = model.power_mw(single, act);
    [64usize, 256, 512, n]
        .into_iter()
        .map(|ns| {
            let banks = n / ns;
            let arch = if banks == 1 {
                single
            } else {
                SorterArch::MultiBank { n, w: width, k: 2, banks }
            };
            Fig8bPoint {
                sub_len: ns,
                banks,
                norm_area: model.area_kum2(arch) / a0,
                norm_power: model.power_mw(arch, act) / p0,
            }
        })
        .collect()
}

/// One measured point of the out-of-bank scaling sweep: a dataset of
/// `n` elements sorted through the chunk → column-skip → k-way-merge
/// pipeline on `chunks` banks of `capacity` rows.
#[derive(Clone, Debug)]
pub struct ScalePoint {
    pub n: usize,
    pub capacity: usize,
    pub chunks: usize,
    pub fanout: usize,
    /// Whether the point ran the streaming merge frontier.
    pub streaming: bool,
    /// Critical-path latency of the mode that ran, cycles.
    pub latency_cycles: u64,
    /// Barrier-model latency (max chunk + merge passes), cycles.
    pub barrier_cycles: u64,
    /// Overlap-model latency (streamed completion), cycles.
    pub streamed_cycles: u64,
    /// Shards the point ran on (1 = the single-service pipeline).
    pub shards: usize,
    /// Fleet-model latency (per-shard merge engines draining in
    /// parallel + cross-shard merge); equals `streamed_cycles` at one
    /// shard.
    pub sharded_cycles: u64,
    /// Fraction of the barrier latency the streaming overlap hides.
    pub overlap_saving: f64,
    /// Latency per element — the hierarchical analogue of Fig. 6's
    /// cycles/number (chunks sort in parallel banks).
    pub cycles_per_number: f64,
    /// Fraction of the critical path spent in the merge network.
    pub merge_fraction: f64,
    /// Sorted elements per second at the paper's 500 MHz clock, Mnum/s.
    pub throughput_mnum_s: f64,
    /// Calibrated silicon area of the whole ensemble (Kµm²).
    pub area_kum2: f64,
    /// Calibrated power under measured activity (mW).
    pub power_mw: f64,
}

/// Sweep the hierarchical pipeline over dataset sizes `ns` (MapReduce
/// traffic) at a fixed bank `capacity` and merge `fanout`. One service
/// instance serves the whole sweep, so per-point cost is chunk sorting
/// plus the merge, not thread spin-up. A thin wrapper over the 1-shard
/// fleet sweep: the pipelines are byte-identical (pinned), and at one
/// shard every latency view comes from the single-engine models.
pub fn scaling(
    ns: &[usize],
    capacity: usize,
    fanout: usize,
    width: u32,
    k: usize,
    seed: u64,
    streaming: bool,
) -> Vec<ScalePoint> {
    scaling_sharded(
        ns,
        capacity,
        fanout,
        seed,
        streaming,
        vec![sweep_service(width, k, 1)],
        crate::coordinator::shard::RoutePolicy::RoundRobin,
    )
    .0
}

/// The per-shard service configuration the scaling sweeps run with:
/// host parallelism split across `shards`, the requested engine
/// width/k, defaults elsewhere. The CLI overrides the geometry per
/// shard for `--shard-geometry` sweeps.
pub fn sweep_service(width: u32, k: usize, shards: usize) -> crate::coordinator::ServiceConfig {
    crate::coordinator::ServiceConfig {
        workers: std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .div_ceil(shards.max(1))
            .min(8),
        colskip: crate::sorter::colskip::ColSkipConfig { width, k, ..Default::default() },
        ..Default::default()
    }
}

/// [`scaling`] across a fleet: the sweep runs on a
/// [`crate::coordinator::shard::ShardedSortService`] with one host per
/// `services` entry (a heterogeneous fleet when the entries differ —
/// e.g. per-shard geometries from `--shard-geometry`) under `route`,
/// and the fleet's metric snapshot is returned alongside the points
/// (totals, per-shard percentiles, imbalance) so the CLI can surface
/// it. With one shard the per-element rates derive from the mode-run
/// latency (exactly [`scaling`]'s historical numbers); above one they
/// derive from the fleet model, so each row stays internally
/// consistent (`Mnum/s == 500 / cyc_per_num`). The dataset width comes
/// from the first shard's engine config.
pub fn scaling_sharded(
    ns: &[usize],
    capacity: usize,
    fanout: usize,
    seed: u64,
    streaming: bool,
    services: Vec<crate::coordinator::ServiceConfig>,
    route: crate::coordinator::shard::RoutePolicy,
) -> (Vec<ScalePoint>, crate::coordinator::shard::FleetSnapshot) {
    use crate::coordinator::hierarchical::{Capacity, HierarchicalConfig};
    use crate::coordinator::shard::{ShardedConfig, ShardedSortService};

    let shards = services.len();
    let width = services.first().map_or(32, |s| s.colskip.width);
    let fleet =
        ShardedSortService::start(ShardedConfig { route, services, ..Default::default() })
            .expect("fleet start");
    let cfg = HierarchicalConfig {
        capacity: Capacity::Fixed(capacity),
        fanout,
        streaming,
        ..Default::default()
    };
    let pts = ns
        .iter()
        .map(|&n| {
            let d = Dataset::generate(DatasetKind::MapReduce, n, width, seed);
            let out = fleet.sort_hierarchical(&d.values, &cfg).expect("sharded sort");
            debug_assert!(out.hier.output.sorted.windows(2).all(|w| w[0] <= w[1]));
            // Fleet-model basis for the per-element rates; at one shard
            // this IS the mode-run latency (`scaling`'s historical
            // numbers), at more it is the same schedule run by the
            // fleet, so each row stays internally consistent.
            let rate_cycles = out.sharded_latency_cycles;
            let throughput = if rate_cycles == 0 {
                0.0
            } else {
                n as f64 * crate::params::CLOCK_HZ / rate_cycles as f64
            };
            ScalePoint {
                n,
                capacity,
                chunks: out.hier.chunks(),
                fanout,
                streaming,
                latency_cycles: out.hier.latency_cycles,
                barrier_cycles: out.hier.barrier_latency_cycles,
                streamed_cycles: out.hier.streamed_latency_cycles,
                shards,
                sharded_cycles: out.sharded_latency_cycles,
                overlap_saving: out.hier.overlap_saving(),
                cycles_per_number: rate_cycles as f64 / n.max(1) as f64,
                merge_fraction: out.hier.merge_fraction(),
                throughput_mnum_s: throughput / 1e6,
                area_kum2: out.hier.area_kum2,
                power_mw: out.hier.power_mw,
            }
        })
        .collect();
    let snap = fleet.fleet_metrics();
    fleet.shutdown();
    (pts, snap)
}

/// Render a text table with aligned columns.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, &w)| format!("{c:>w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    out.push_str(&fmt_row(
        &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>(),
        &widths,
    ));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Paper defaults for the figure harnesses.
pub fn paper_defaults() -> (usize, u32) {
    (DEFAULT_N, DEFAULT_WIDTH)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_small_run_shapes() {
        // Small N for test speed; shape checks only.
        let pts = fig6(128, 32, 3, 1, 7);
        assert_eq!(pts.len(), 5 * 3);
        for p in &pts {
            // Large k on prefix-poor data can dip slightly below 1×
            // (paper: speedup "goes down" past k=2–3).
            assert!(p.speedup >= 0.9, "{:?} k={} speedup {}", p.dataset, p.k, p.speedup);
        }
        // MapReduce at k=2 beats uniform at k=2 (the paper's ordering).
        let get = |kind, k| {
            pts.iter().find(|p| p.dataset == kind && p.k == k).unwrap().speedup
        };
        assert!(get(DatasetKind::MapReduce, 2) > get(DatasetKind::Uniform, 2));
        assert!(get(DatasetKind::Clustered, 2) > get(DatasetKind::Normal, 2));
    }

    #[test]
    fn fig7_small_run_shapes() {
        let pts = fig7(128, 32, 4, 1, 7);
        assert_eq!(pts.len(), 4);
        // Area strictly grows with k.
        assert!(pts.windows(2).all(|w| w[1].norm_area > w[0].norm_area));
        // Area efficiency beats baseline at k=1 (paper: >3.2× at N=1024).
        assert!(pts[0].area_eff_ratio > 1.5, "{}", pts[0].area_eff_ratio);
    }

    #[test]
    fn fig8a_rows_present_and_ordered() {
        let rows = fig8a(256, 32, 1, 7);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].name, "baseline");
        assert!((rows[0].cycles_per_number - 32.0).abs() < 1e-9);
        // col-skip beats baseline on cycles; multibank matches col-skip.
        assert!(rows[2].cycles_per_number < rows[0].cycles_per_number);
        assert!((rows[3].cycles_per_number - rows[2].cycles_per_number).abs() < 1e-9);
    }

    #[test]
    fn fig8b_normalized_monotone() {
        let pts = fig8b(1024, 32);
        assert_eq!(pts.len(), 4);
        assert_eq!(pts.last().unwrap().sub_len, 1024);
        assert!((pts.last().unwrap().norm_area - 1.0).abs() < 1e-12);
        // Smaller Ns ⇒ smaller area and power (Fig. 8b).
        assert!(pts.windows(2).all(|w| w[0].norm_area < w[1].norm_area));
        assert!(pts.windows(2).all(|w| w[0].norm_power < w[1].norm_power));
    }

    #[test]
    fn scaling_sweep_shapes() {
        let pts = scaling(&[512, 2048, 8192], 256, 4, 32, 2, 7, false);
        assert_eq!(pts.len(), 3);
        assert_eq!(pts[0].chunks, 2);
        assert_eq!(pts[1].chunks, 8);
        assert_eq!(pts[2].chunks, 32);
        for p in &pts {
            assert!(p.latency_cycles > 0, "n={}", p.n);
            assert_eq!(p.latency_cycles, p.barrier_cycles, "barrier sweep");
            assert!(p.streamed_cycles <= p.barrier_cycles, "n={}", p.n);
            assert!((0.0..1.0).contains(&p.overlap_saving), "n={}", p.n);
            assert!(p.throughput_mnum_s > 0.0);
            assert!(p.area_kum2 > 0.0 && p.power_mw > 0.0);
            assert!((0.0..1.0).contains(&p.merge_fraction), "n={}", p.n);
        }
        // Deeper merge trees: the merge share of the critical path grows
        // with the chunk count.
        assert!(pts[2].merge_fraction > pts[0].merge_fraction);
        // Column skipping keeps per-element latency under the baseline's
        // 32 cycles even with the merge passes on top.
        assert!(pts[2].cycles_per_number < 32.0, "{}", pts[2].cycles_per_number);
        // The streaming sweep produces identical results with a latency
        // never above the barrier's.
        let spts = scaling(&[512, 2048, 8192], 256, 4, 32, 2, 7, true);
        for (s, b) in spts.iter().zip(&pts) {
            assert!(s.streaming);
            assert_eq!(s.latency_cycles, s.streamed_cycles);
            assert_eq!(s.barrier_cycles, b.barrier_cycles, "same model numbers");
            assert!(s.latency_cycles <= b.latency_cycles, "n={}", s.n);
        }
    }

    #[test]
    fn sharded_scaling_matches_single_service_points() {
        use crate::coordinator::shard::RoutePolicy;
        let single = scaling(&[2048, 8192], 256, 4, 32, 2, 7, true);
        let (one, snap1) = scaling_sharded(
            &[2048, 8192],
            256,
            4,
            7,
            true,
            vec![sweep_service(32, 2, 1)],
            RoutePolicy::RoundRobin,
        );
        for (a, b) in one.iter().zip(&single) {
            assert_eq!(a.latency_cycles, b.latency_cycles);
            assert_eq!(a.sharded_cycles, b.streamed_cycles, "1 shard = single engine");
            assert_eq!(a.chunks, b.chunks);
        }
        assert_eq!(snap1.hier_completed, 2);
        let (four, snap4) = scaling_sharded(
            &[2048, 8192],
            256,
            4,
            7,
            true,
            vec![sweep_service(32, 2, 4); 4],
            RoutePolicy::RoundRobin,
        );
        for (a, b) in four.iter().zip(&single) {
            assert_eq!(a.shards, 4);
            // Byte-identical pipeline: same chunks, same flat models.
            assert_eq!(a.chunks, b.chunks);
            assert_eq!(a.streamed_cycles, b.streamed_cycles);
            assert_eq!(a.barrier_cycles, b.barrier_cycles);
            assert!(a.sharded_cycles > 0);
        }
        assert_eq!(snap4.shards.len(), 4);
        assert!(snap4.shards.iter().all(|s| s.completed > 0), "round-robin spreads chunks");
        assert_eq!(snap4.hier_chunks, 8 + 32);
    }

    #[test]
    fn heterogeneous_scaling_sweep_stays_correct() {
        use crate::coordinator::planner::Geometry;
        use crate::coordinator::shard::RoutePolicy;
        // A mixed-geometry fleet under the cost router: the sweep's
        // points stay byte-identical to the single-service models
        // (routing never changes the pipeline), and the fleet snapshot
        // carries per-shard views for every host.
        let single = scaling(&[2048, 8192], 256, 4, 32, 2, 7, true);
        let mut services = vec![sweep_service(32, 2, 2); 2];
        services[1].geometry = Geometry::from_spec("512x32").unwrap();
        let (pts, snap) =
            scaling_sharded(&[2048, 8192], 256, 4, 7, true, services, RoutePolicy::Cost);
        for (a, b) in pts.iter().zip(&single) {
            assert_eq!(a.chunks, b.chunks);
            assert_eq!(a.streamed_cycles, b.streamed_cycles);
            assert_eq!(a.barrier_cycles, b.barrier_cycles);
            assert!(a.sharded_cycles > 0);
        }
        assert_eq!(snap.shards.len(), 2);
        assert_eq!(snap.hier_completed, 2);
        assert_eq!(snap.recovered, 0);
    }

    #[test]
    fn render_table_aligns() {
        let t = render_table(
            &["name", "v"],
            &[vec!["a".into(), "1.00".into()], vec!["longer".into(), "2".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert!(lines[2].ends_with("1.00"));
    }
}

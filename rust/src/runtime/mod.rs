//! PJRT runtime: load and execute the AOT-compiled in-memory rank pass.
//!
//! The compile path (`python/compile/aot.py`, run once by `make
//! artifacts`) lowers the L2 JAX model — a scan of the L1 Pallas
//! min-search kernel — to HLO *text*. This module wraps the `xla` crate's
//! PJRT CPU client to load those artifacts, compile them once per array
//! size, and execute them from the request path with zero Python.
//!
//! The engine is the "memristive array compute" backend of the sort
//! service: the functional result (sorted values) plus the per-iteration
//! traces (`top_cols`, `infos`) the coordinator's cycle accounting can
//! consume. Integration tests assert the PJRT engine agrees bit-exactly
//! with the native bit-accurate simulator on every dataset family.
//!
//! ## Feature gating
//!
//! The `xla` crate needs a local XLA/PJRT toolchain, which offline and CI
//! builds do not have — it is not even a registry dependency (a
//! non-resolvable dependency line would break every build). The real
//! engine compiles only when the `xla` dependency is added to Cargo.toml
//! (vendored or via git) *and* the crate is built with `--features pjrt`;
//! the default build substitutes an API-compatible stub whose constructor
//! fails, so every caller (service workers, the hybrid engine, benches)
//! falls back to the native simulator cleanly.

use std::path::{Path, PathBuf};

/// Result of one AOT rank-pass execution.
#[derive(Clone, Debug)]
pub struct RankPass {
    /// Values ascending (functional sort result).
    pub sorted: Vec<u32>,
    /// Highest informative column per iteration (-1 when none).
    pub top_cols: Vec<i32>,
    /// Informative-column (= RE) count per iteration.
    pub infos: Vec<i32>,
}

/// Default artifacts location relative to the repo root, overridable
/// with `MEMSORT_ARTIFACTS`.
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var_os("MEMSORT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(feature = "pjrt")]
mod engine {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    use anyhow::{anyhow, bail, Context, Result};

    use super::RankPass;

    /// A compiled artifact for one array-size variant.
    struct Variant {
        exe: xla::PjRtLoadedExecutable,
        n: usize,
    }

    /// PJRT CPU engine holding one compiled executable per artifact variant.
    pub struct PjrtEngine {
        client: xla::PjRtClient,
        variants: HashMap<usize, Variant>,
        artifacts_dir: PathBuf,
        width: u32,
    }

    impl PjrtEngine {
        /// Create a CPU engine rooted at an artifacts directory (as produced
        /// by `make artifacts`). Variants are compiled lazily per size.
        pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
            Ok(PjrtEngine {
                client,
                variants: HashMap::new(),
                artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
                width: crate::params::DEFAULT_WIDTH,
            })
        }

        /// True when the crate was built with the PJRT runtime compiled in.
        pub fn runtime_available() -> bool {
            true
        }

        /// Default artifacts location (see [`super::default_artifacts_dir`]).
        pub fn default_dir() -> PathBuf {
            super::default_artifacts_dir()
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Array sizes with an available artifact, per the manifest.
        pub fn available_sizes(&self) -> Result<Vec<usize>> {
            let manifest = self.artifacts_dir.join("manifest.txt");
            let text = std::fs::read_to_string(&manifest)
                .with_context(|| format!("reading {manifest:?} — run `make artifacts` first"))?;
            let mut sizes = Vec::new();
            for line in text.lines() {
                if let Some(n) = line
                    .split_whitespace()
                    .find_map(|tok| tok.strip_prefix("n=").and_then(|v| v.parse::<usize>().ok()))
                {
                    sizes.push(n);
                }
            }
            sizes.sort_unstable();
            Ok(sizes)
        }

        fn artifact_path(&self, n: usize) -> PathBuf {
            self.artifacts_dir.join(format!("minsort_n{n}_w{}.hlo.txt", self.width))
        }

        /// Compile (once) and cache the variant for array size `n`.
        pub fn ensure_variant(&mut self, n: usize) -> Result<()> {
            if self.variants.contains_key(&n) {
                return Ok(());
            }
            let path = self.artifact_path(n);
            if !path.exists() {
                bail!(
                    "no AOT artifact for n={n} at {path:?}; run `make artifacts` \
                     (available: {:?})",
                    self.available_sizes().unwrap_or_default()
                );
            }
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe =
                self.client.compile(&comp).map_err(|e| anyhow!("compiling n={n}: {e:?}"))?;
            self.variants.insert(n, Variant { exe, n });
            Ok(())
        }

        /// Execute the rank pass for `data` (length must match a variant).
        pub fn rank(&mut self, data: &[u32]) -> Result<RankPass> {
            let n = data.len();
            self.ensure_variant(n)?;
            let variant = self.variants.get(&n).expect("ensured above");
            debug_assert_eq!(variant.n, n);
            let x = xla::Literal::vec1(data);
            let result = variant.exe.execute::<xla::Literal>(&[x]).map_err(|e| {
                anyhow!("execute n={n}: {e:?}")
            })?[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("fetch n={n}: {e:?}"))?;
            // aot.py lowers with return_tuple=True: (sorted, top_cols, infos).
            let elems = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
            if elems.len() != 3 {
                bail!("expected 3 outputs, got {}", elems.len());
            }
            let sorted = elems[0].to_vec::<u32>().map_err(|e| anyhow!("sorted: {e:?}"))?;
            let top_cols = elems[1].to_vec::<i32>().map_err(|e| anyhow!("top_cols: {e:?}"))?;
            let infos = elems[2].to_vec::<i32>().map_err(|e| anyhow!("infos: {e:?}"))?;
            Ok(RankPass { sorted, top_cols, infos })
        }

        /// Sizes currently compiled into this engine.
        pub fn compiled_sizes(&self) -> Vec<usize> {
            let mut v: Vec<usize> = self.variants.keys().copied().collect();
            v.sort_unstable();
            v
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine {
    use std::path::{Path, PathBuf};

    use anyhow::{bail, Result};

    use super::RankPass;

    /// Stub engine compiled when the `pjrt` feature is off. Construction
    /// always fails, so callers fall back to the native simulator.
    pub struct PjrtEngine {
        _private: (),
    }

    impl PjrtEngine {
        /// Always fails: the crate was built without `--features pjrt`.
        pub fn new(_artifacts_dir: impl AsRef<Path>) -> Result<Self> {
            bail!(
                "built without the `pjrt` feature; add the `xla` dependency to \
                 Cargo.toml (see runtime docs) and rebuild with --features pjrt"
            )
        }

        /// True when the crate was built with the PJRT runtime compiled in.
        pub fn runtime_available() -> bool {
            false
        }

        /// Default artifacts location (see [`super::default_artifacts_dir`]).
        pub fn default_dir() -> PathBuf {
            super::default_artifacts_dir()
        }

        /// PJRT platform name (diagnostics).
        pub fn platform(&self) -> String {
            "unavailable".to_string()
        }

        /// Array sizes with an available artifact, per the manifest.
        pub fn available_sizes(&self) -> Result<Vec<usize>> {
            bail!("built without the `pjrt` feature")
        }

        /// Compile (once) and cache the variant for array size `n`.
        pub fn ensure_variant(&mut self, _n: usize) -> Result<()> {
            bail!("built without the `pjrt` feature")
        }

        /// Execute the rank pass for `data` (length must match a variant).
        pub fn rank(&mut self, _data: &[u32]) -> Result<RankPass> {
            bail!("built without the `pjrt` feature")
        }

        /// Sizes currently compiled into this engine.
        pub fn compiled_sizes(&self) -> Vec<usize> {
            Vec::new()
        }
    }
}

pub use engine::PjrtEngine;

/// True when AOT artifacts exist *and* the runtime can execute them —
/// the gate every PJRT-dependent test and bench checks before running.
pub fn pjrt_ready(artifacts_dir: impl AsRef<Path>) -> bool {
    PjrtEngine::runtime_available() && artifacts_dir.as_ref().join("manifest.txt").exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_exist() -> bool {
        pjrt_ready(PjrtEngine::default_dir())
    }

    #[test]
    fn engine_loads_and_ranks_small_artifact() {
        if !artifacts_exist() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let mut eng = PjrtEngine::new(PjrtEngine::default_dir()).unwrap();
        let data: Vec<u32> =
            vec![300, 5, 5, 0, 65535, 77, 1024, 2, 9, 9, 1, 8, 4, 3, 2, 1];
        let pass = eng.rank(&data).unwrap();
        let mut expect = data.clone();
        expect.sort_unstable();
        assert_eq!(pass.sorted, expect);
        assert_eq!(pass.top_cols.len(), 16);
        assert_eq!(pass.infos.len(), 16);
        // Last iteration has one row left: nothing informative.
        assert_eq!(*pass.infos.last().unwrap(), 0);
        assert_eq!(*pass.top_cols.last().unwrap(), -1);
    }

    #[test]
    fn missing_size_reports_helpfully() {
        if !artifacts_exist() {
            return;
        }
        let mut eng = PjrtEngine::new(PjrtEngine::default_dir()).unwrap();
        let err = eng.rank(&[1, 2, 3]).unwrap_err().to_string();
        assert!(err.contains("no AOT artifact for n=3"), "{err}");
    }

    #[test]
    fn manifest_lists_sizes() {
        if !artifacts_exist() {
            return;
        }
        let eng = PjrtEngine::new(PjrtEngine::default_dir()).unwrap();
        let sizes = eng.available_sizes().unwrap();
        assert!(sizes.contains(&16), "{sizes:?}");
        assert!(sizes.contains(&1024), "{sizes:?}");
    }

    #[test]
    fn stub_or_engine_constructor_is_consistent() {
        // Without the feature the constructor must fail with guidance;
        // with it, construction succeeds on any directory (lazy compile).
        let r = PjrtEngine::new("does-not-exist");
        if PjrtEngine::runtime_available() {
            assert!(r.is_ok());
        } else {
            let msg = r.err().expect("stub must fail").to_string();
            assert!(msg.contains("pjrt"), "{msg}");
        }
    }
}

//! In-tree micro-benchmark harness (the offline registry has no
//! criterion): warmup + timed runs with median / mean / MAD reporting,
//! plus figure-style table output for the paper harnesses.
//!
//! Used by the `rust/benches/*.rs` targets (all `harness = false`).

use std::time::Instant;

/// Result of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    /// Median absolute deviation (robust spread).
    pub mad_ns: f64,
}

impl BenchResult {
    /// Criterion-style one-liner.
    pub fn line(&self) -> String {
        format!(
            "{:<40} time: [{} median, {} mean ± {} MAD] ({} iters)",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.mad_ns),
            self.iters
        )
    }

    /// Throughput helper: elements per second given elements per iter.
    pub fn throughput(&self, elems_per_iter: usize) -> f64 {
        elems_per_iter as f64 / (self.median_ns / 1e9)
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, auto-scaling iteration count to `target_ms` per batch.
pub fn bench<T>(name: &str, target_ms: u64, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let target = target_ms as f64 * 1e6;
    let samples = 15usize;
    let per_sample = ((target / samples as f64 / once).ceil() as usize).clamp(1, 1_000_000);

    let mut times: Vec<f64> = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..per_sample {
            std::hint::black_box(f());
        }
        times.push(t.elapsed().as_nanos() as f64 / per_sample as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let mut devs: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    BenchResult {
        name: name.to_string(),
        iters: samples * per_sample,
        median_ns: median,
        mean_ns: mean,
        mad_ns: mad,
    }
}

/// Run and print a benchmark.
pub fn run<T>(name: &str, target_ms: u64, f: impl FnMut() -> T) -> BenchResult {
    let r = bench(name, target_ms, f);
    println!("{}", r.line());
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 5, || {
            let mut s = 0u64;
            for i in 0..100u64 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median_ns > 0.0);
        assert!(r.iters >= 15);
        assert!(r.mad_ns <= r.median_ns);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).ends_with("ns"));
        assert!(fmt_ns(12_000.0).ends_with("µs"));
        assert!(fmt_ns(12_000_000.0).ends_with("ms"));
        assert!(fmt_ns(2.0e9).ends_with(" s"));
    }

    #[test]
    fn throughput_inverse_of_time() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            median_ns: 1e9,
            mean_ns: 1e9,
            mad_ns: 0.0,
        };
        assert!((r.throughput(1000) - 1000.0).abs() < 1e-9);
    }
}

//! `memsort` — CLI for the column-skipping memristive in-memory sorting
//! reproduction. Subcommands:
//!
//! * `sort`   — sort a generated dataset on a chosen sorter, print stats;
//!   datasets longer than `--capacity` automatically run through the
//!   hierarchical chunk → column-skip → k-way-merge pipeline
//! * `gen`    — emit a dataset (one value per line)
//! * `stats`  — workload statistics (leading zeros, repetitions, prefixes)
//! * `fig`    — regenerate a paper figure (6, 7, 8a, 8b) as table/JSON
//! * `scale`  — out-of-bank scaling sweep of the hierarchical pipeline
//! * `report` — headline paper-vs-measured summary (abstract numbers)
//! * `serve`  — run the sort service demo (native/pjrt/hybrid engines)
//! * `stress` — concurrent clients through the fair-share admission plane

use anyhow::{anyhow, bail, Result};

use std::sync::Arc;

use memsort::cli::Args;
use memsort::coordinator::frontend::{AdmitError, Frontend, FrontendConfig, JobTag, Priority};
use memsort::coordinator::hierarchical::{Capacity, HierarchicalConfig};
use memsort::coordinator::planner::{schedule::FleetSchedule, shard_model, Geometry};
use memsort::coordinator::shard::{
    HedgeConfig, ResilienceConfig, RetryBudgetConfig, RoutePolicy, ShardedConfig,
    ShardedSortService,
};
use memsort::coordinator::transport::{RemoteTransport, ShardTransport};
use memsort::coordinator::{EngineKind, ServiceConfig, SortService};
use memsort::cost::{Activity, CostModel, SorterArch};
use memsort::datasets::{stats::analyze, Dataset, DatasetKind};
use memsort::multibank::{MultiBankConfig, MultiBankSorter};
use memsort::report::{self, json::Json};
use memsort::sorter::baseline::BaselineSorter;
use memsort::sorter::colskip::{ColSkipConfig, ColSkipSorter};
use memsort::sorter::merge::MergeSorter;
use memsort::sorter::spill::MemoryBudget;
use memsort::sorter::InMemorySorter;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            usage();
            std::process::exit(2);
        }
    };
    let r = match args.command.as_deref() {
        Some("sort") => cmd_sort(&args),
        Some("gen") => cmd_gen(&args),
        Some("stats") => cmd_stats(&args),
        Some("fig") => cmd_fig(&args),
        Some("scale") => cmd_scale(&args),
        Some("report") => cmd_report(&args),
        Some("serve") => cmd_serve(&args),
        Some("stress") => cmd_stress(&args),
        Some("trace") => cmd_trace(&args),
        Some("energy") => cmd_energy(&args),
        Some(other) => {
            eprintln!("unknown command `{other}`\n");
            usage();
            std::process::exit(2);
        }
        None => {
            usage();
            Ok(())
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    println!(
        "memsort — column-skipping memristive in-memory sorting (cs.AR 2022)\n\
         \n\
         USAGE: memsort <command> [--key value ...]\n\
         \n\
         COMMANDS\n\
           sort    --dataset <uniform|normal|clustered|kruskal|mapreduce>\n\
                   --sorter <colskip|baseline|merge|multibank> --n 1024\n\
                   --width 32 --k 2 --banks 16 --seed 42\n\
                   (--n above --capacity, default 1024, runs the\n\
                    hierarchical pipeline: --n 1m --capacity 1024\n\
                    --fanout 4 --workers 4; sizes accept k/m/g;\n\
                    --capacity auto picks the cheapest bank/fanout,\n\
                    --barrier disables the streaming merge overlap,\n\
                    --memory-budget BYTES caps the coordinator merge\n\
                    working set — an over-budget sort spills runs to\n\
                    temp files and merges externally, byte-identical;\n\
                    --shards N --route <round|least|class|cost> runs\n\
                    the pipeline across a fleet of N service hosts;\n\
                    --shard-geometry 1024x32,512x32 makes the fleet\n\
                    heterogeneous — one shard per HxW entry, with the\n\
                    cost router and tuner aware of each host's banks;\n\
                    --connect host:port,... uses remote shard hosts\n\
                    (serve --shard) instead of in-process ones;\n\
                    --retry-budget T bounds failover hops (default 10\n\
                    tokens, +0.1/success), --hedge re-issues stragglers\n\
                    to the next-best shard after the model-derived\n\
                    deadline [--hedge-mult 4 --hedge-floor-us 20000];\n\
                    --tenant NAME --priority <interactive|batch> on a\n\
                    fleet submits one tagged request through the\n\
                    fair-share admission plane instead of the\n\
                    hierarchical fan-out)\n\
           gen     --dataset <kind> --n 1024 --seed 42\n\
           stats   --dataset <kind> --n 1024 --seed 42\n\
           fig     --id <6|7|8a|8b> [--trials 5] [--n 1024] [--json]\n\
           scale   --max 1m --capacity 1024 --fanout 4 [--json]\n\
                   [--streaming] [--shards N | --shard-geometry ...]\n\
                   [--route <round|least|class|cost>]\n\
                   (hierarchical sweep: chunks, latency, merge share,\n\
                   streamed-vs-barrier overlap saving; with a fleet\n\
                   also the fleet latency model + fleet metrics)\n\
           report  [--trials 5] [--seed 42]\n\
           serve   --engine <native|pjrt|hybrid> --workers 4\n\
                   --requests 64 --n 1024 [--artifacts artifacts]\n\
                   (--shard [--host 127.0.0.1] [--port 7600]\n\
                   [--geometry 1024x32] [--max-conns 8] runs a wire\n\
                   shard host serving the RPC protocol to up to\n\
                   --max-conns concurrent coordinators instead of the\n\
                   local demo — see rust/OPERATIONS.md for the wire\n\
                   format)\n\
           stress  --clients 8 --requests 32 --n 1024 [--shards 2]\n\
                   [--workers 2] [--max-outstanding 64]\n\
                   [--tenant-cap 16] [--seed 42]\n\
                   (concurrent clients through one shared admission\n\
                   plane: interactive/batch mix, prints admitted/shed\n\
                   counters and throughput)\n\
           trace   --dataset <kind> --n 8 --width 8 --k 2 [--iters 6]\n\
                   (Fig. 2/3-style near-memory circuit schedule)\n\
           energy  --dataset <kind> --n 1024 --k 2\n\
                   (per-op energy breakdown from the metered run)\n"
    );
}

/// Build the fleet's per-shard service configs from `--shards` /
/// `--shard-geometry`. A geometry list (`1024x32,512x32`) defines one
/// shard per entry — a heterogeneous fleet; a bare `--shards N` clones
/// the template. The spec widths must match the engine `--width`: the
/// geometry is the planner's view of the same banks the engine
/// simulates, and silently sorting 32-bit data on a 16-bit host would
/// corrupt the result rather than model it.
fn shard_services(args: &Args, template: &ServiceConfig) -> Result<Vec<ServiceConfig>> {
    let shards = args.parse_num("shards", 1usize)?;
    if shards == 0 {
        bail!("--shards must be at least 1");
    }
    let Some(spec) = args.get("shard-geometry") else {
        return Ok(vec![template.clone(); shards]);
    };
    let geos = spec.split(',').map(Geometry::from_spec).collect::<Result<Vec<_>>>()?;
    if args.get("shards").is_some() && shards != geos.len() {
        bail!("--shards {shards} disagrees with --shard-geometry ({} entries)", geos.len());
    }
    for g in &geos {
        if g.width != template.colskip.width {
            bail!(
                "--shard-geometry width {} conflicts with engine --width {}",
                g.width,
                template.colskip.width
            );
        }
    }
    Ok(geos
        .into_iter()
        .map(|geometry| ServiceConfig { geometry, ..template.clone() })
        .collect())
}

/// Fleet resilience from the CLI: `--retry-budget T` sizes the token
/// bucket (deposit stays at the default 0.1/success), `--hedge` turns
/// hedged requests on with `--hedge-mult` / `--hedge-floor-us` tuning
/// the straggler deadline. See `rust/OPERATIONS.md` for how to pick
/// these.
fn resilience_from(args: &Args) -> Result<ResilienceConfig> {
    let defaults = RetryBudgetConfig::default();
    let capacity = args.parse_num("retry-budget", defaults.capacity)?;
    let hedge = if args.flag("hedge")
        || args.get("hedge-mult").is_some()
        || args.get("hedge-floor-us").is_some()
    {
        let h = HedgeConfig::default();
        Some(HedgeConfig {
            straggler_mult: args.parse_num("hedge-mult", h.straggler_mult)?,
            floor_us: args.parse_num("hedge-floor-us", h.floor_us)?,
        })
    } else {
        None
    };
    Ok(ResilienceConfig { retry_budget: RetryBudgetConfig { capacity, ..defaults }, hedge })
}

fn dataset_from(args: &Args) -> Result<Dataset> {
    if let Some(path) = args.get("file") {
        // Real-data path: one unsigned decimal value per line.
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading --file {path}: {e}"))?;
        let values = text
            .lines()
            .map(str::trim)
            .filter(|l| !l.is_empty() && !l.starts_with('#'))
            .map(|l| l.parse::<u32>().map_err(|e| anyhow!("--file {path}: `{l}`: {e}")))
            .collect::<Result<Vec<u32>>>()?;
        if values.is_empty() {
            bail!("--file {path} contains no values");
        }
        return Ok(Dataset { kind: DatasetKind::Uniform, seed: 0, values });
    }
    let kind = DatasetKind::parse(args.get_or("dataset", "mapreduce"))
        .ok_or_else(|| anyhow!("unknown dataset (see usage)"))?;
    let n = args.parse_size("n", 1024)?;
    let width = args.parse_num("width", 32u32)?;
    let seed = args.parse_num("seed", 42u64)?;
    Ok(Dataset::generate(kind, n, width, seed))
}

fn cmd_sort(args: &Args) -> Result<()> {
    let d = dataset_from(args)?;
    let width = args.parse_num("width", 32u32)?;
    let k = args.parse_num("k", 2usize)?;
    let banks = args.parse_num("banks", 16usize)?;
    let name = args.get_or("sorter", "colskip");
    // `--capacity auto` asks the service to pick the chunking itself.
    let auto = matches!(args.get("capacity"), Some("auto"));
    let capacity = if auto {
        Capacity::Auto
    } else {
        Capacity::Fixed(args.parse_size("capacity", memsort::params::DEFAULT_N)?)
    };
    // Datasets beyond one bank go hierarchical (auto mode always does:
    // resolving the capacity is the point). A multibank ensemble has
    // no fixed capacity of its own (it stripes whatever it is given), so
    // it is rerouted only when the user states the bank capacity
    // explicitly — `--sorter multibank --n 4096` alone keeps sorting one
    // 4096-row ensemble as before.
    let exceeds = match capacity {
        Capacity::Auto => true,
        Capacity::Fixed(c) => d.values.len() > c,
    };
    // A tagged request always goes through the service stack (the tag
    // rides the request plane, which an inline sorter does not have).
    let tagged = args.get("tenant").is_some() || args.get("priority").is_some();
    let hier = tagged
        || match name {
            "colskip" => exceeds,
            "multibank" => args.get("capacity").is_some() && exceeds,
            _ => false,
        };
    if hier {
        return cmd_sort_hierarchical(args, &d, width, k, banks, capacity);
    }
    let mut sorter: Box<dyn InMemorySorter> = match name {
        "colskip" => Box::new(ColSkipSorter::new(ColSkipConfig { width, k, ..Default::default() })),
        "baseline" => Box::new(BaselineSorter::with_width(width)),
        "merge" => Box::new(MergeSorter::new()),
        "multibank" => Box::new(MultiBankSorter::new(MultiBankConfig {
            width,
            k,
            banks,
            ..Default::default()
        })),
        other => bail!("unknown sorter `{other}`"),
    };
    let out = sorter.sort_with_stats(&d.values);
    let n = d.values.len();
    let mut check = d.values.clone();
    check.sort_unstable();
    println!("sorter        : {}", sorter.name());
    println!("dataset       : {} (n={n}, w={width}, seed={})", d.kind.name(), d.seed);
    println!("correct       : {}", out.sorted == check);
    println!("column reads  : {}", out.stats.crs);
    println!("state loads   : {}", out.stats.sls);
    println!("drains        : {}", out.stats.drains);
    println!("cycles        : {}", out.stats.cycles());
    println!("cycles/number : {:.3}", out.stats.cycles_per_number(n));
    println!(
        "speedup vs [18]: {:.2}x",
        (n as u64 * width as u64) as f64 / out.stats.cycles() as f64
    );
    println!("throughput    : {:.2} Mnum/s @500MHz", out.stats.throughput(n) / 1e6);
    Ok(())
}

/// `sort` for datasets longer than the bank capacity: partition into
/// bank-sized chunks, sort them on the worker pool, k-way merge.
fn cmd_sort_hierarchical(
    args: &Args,
    d: &Dataset,
    width: u32,
    k: usize,
    banks: usize,
    capacity: Capacity,
) -> Result<()> {
    let fanout = args.parse_num("fanout", 4usize)?;
    let workers = args.parse_num("workers", 4usize)?;
    // `FromStr` impls make fleet flags parse through the same typed
    // accessor as every numeric option.
    let route = args.parse_num("route", RoutePolicy::RoundRobin)?;
    let streaming = !args.flag("barrier");
    if capacity == Capacity::Fixed(0) {
        bail!("--capacity must be at least 1 (or `auto`)");
    }
    if fanout < 2 {
        bail!("--fanout must be at least 2");
    }
    if workers == 0 {
        bail!("--workers must be at least 1");
    }
    let sub_banks = if args.get_or("sorter", "colskip") == "multibank" { banks } else { 1 };
    let service_cfg = ServiceConfig {
        workers,
        banks: sub_banks,
        colskip: ColSkipConfig { width, k, ..Default::default() },
        ..Default::default()
    };
    let services = shard_services(args, &service_cfg)?;
    let resilience = resilience_from(args)?;
    // `--connect host:port,...` swaps the in-process hosts for remote
    // shard servers behind `RemoteTransport`s — same routing, same
    // byte-identical pipeline, the coordinator just dials instead of
    // spawning.
    let remote: Option<Vec<String>> = args
        .get("connect")
        .map(|s| s.split(',').map(|a| a.trim().to_string()).collect());
    if let Some(addrs) = &remote {
        if args.get("shards").is_some() || args.get("shard-geometry").is_some() {
            bail!("--connect defines the fleet; drop --shards/--shard-geometry");
        }
        if addrs.iter().any(String::is_empty) {
            bail!("--connect needs a comma-separated host:port list");
        }
    }
    let shards = remote.as_ref().map_or(services.len(), Vec::len);
    let auto = capacity == Capacity::Auto;
    // `--memory-budget BYTES` caps the coordinator's merge working set;
    // an over-budget sort spills sorted runs to temp files and merges
    // them externally (byte-identical output, modelled I/O surcharge).
    let budget = match args.get("memory-budget") {
        Some(_) => MemoryBudget::Bytes(args.parse_size("memory-budget", 0)?),
        None => MemoryBudget::Unbounded,
    };
    let cfg = HierarchicalConfig { capacity, fanout, streaming, budget };
    // One host below, a routed fleet of hosts above one shard (always a
    // fleet when remote); the pipeline output is byte-identical either
    // way (pinned by tests) — the fleet adds routing, failure
    // isolation, retry budgets/hedging and the fleet latency model.
    let (out, fleet_view, wall) = if shards > 1 || remote.is_some() {
        let fleet = match &remote {
            Some(addrs) => {
                let transports = addrs
                    .iter()
                    .map(|a| {
                        Ok(Box::new(RemoteTransport::connect_tcp(a)?)
                            as Box<dyn ShardTransport>)
                    })
                    .collect::<Result<Vec<_>>>()?;
                ShardedSortService::with_transports_resilient(route, resilience, transports)?
            }
            None => ShardedSortService::start(ShardedConfig { route, services, resilience })?,
        };
        // `--tenant` / `--priority` reroute the request through the
        // fair-share admission plane as one tagged job — the
        // request-plane path a multi-tenant client of `serve --shard`
        // takes — instead of the hierarchical fan-out.
        if args.get("tenant").is_some() || args.get("priority").is_some() {
            return cmd_sort_tagged(args, d, fleet, remote.is_some());
        }
        let t0 = std::time::Instant::now();
        let sharded = fleet.sort_hierarchical(&d.values, &cfg)?;
        let wall = t0.elapsed();
        let snap = fleet.fleet_metrics();
        if remote.is_some() {
            // Operator-started shard hosts outlive the sort: close the
            // links, don't send the wire Shutdown.
            fleet.disconnect();
        } else {
            fleet.shutdown();
        }
        let extras = (sharded.sharded_latency_cycles, sharded.shard_chunks.clone(), snap);
        (sharded.hier, Some(extras), wall)
    } else {
        if args.get("tenant").is_some() || args.get("priority").is_some() {
            bail!("--tenant/--priority ride the request plane over a fleet: add --shards N or --connect");
        }
        let svc = SortService::start(services.into_iter().next().expect("one shard"))?;
        let t0 = std::time::Instant::now();
        let out = svc.sort_hierarchical(&d.values, &cfg)?;
        let wall = t0.elapsed();
        svc.shutdown();
        (out, None, wall)
    };
    let n = d.values.len();
    let mut check = d.values.clone();
    check.sort_unstable();
    println!(
        "pipeline      : chunk({}{}) -> column-skip -> {}-way {} merge{}",
        out.capacity,
        if auto { ", auto" } else { "" },
        out.merge.fanout,
        if streaming { "streaming" } else { "barrier" },
        if shards > 1 || remote.is_some() {
            format!(
                " across {shards}{} shard{} ({})",
                if remote.is_some() { " remote" } else { "" },
                if shards == 1 { "" } else { "s" },
                route.name()
            )
        } else {
            String::new()
        }
    );
    println!("dataset       : {} (n={n}, w={width}, seed={})", d.kind.name(), d.seed);
    println!("correct       : {}", out.output.sorted == check);
    println!("chunks        : {} ({workers} workers, {sub_banks} banks/chunk)", out.chunks());
    println!(
        "chunk work    : {} CRs, {} SLs, {} drains (all banks)",
        out.output.stats.crs, out.output.stats.sls, out.output.stats.drains
    );
    println!(
        "merge         : {} passes, {} comparisons, {} cycles",
        out.merge.passes, out.merge.comparisons, out.merge.cycles
    );
    println!(
        "latency       : {} cycles ({:.3} ms @500MHz, {:.1}% exposed merge)",
        out.latency_cycles,
        out.latency_seconds() * 1e3,
        out.merge_fraction() * 100.0
    );
    println!(
        "overlap       : streamed {} vs barrier {} cycles ({:.1}% hidden)",
        out.streamed_latency_cycles,
        out.barrier_latency_cycles,
        out.overlap_saving() * 100.0
    );
    if cfg.budget.is_bounded() {
        println!(
            "spill         : {} (budget {}, {} B written to runs)",
            if out.spilled { "external merge" } else { "resident" },
            cfg.budget,
            out.spilled_bytes
        );
    }
    if let Some((sharded_cycles, shard_chunks, snap)) = &fleet_view {
        println!(
            "fleet         : {} cycles with per-shard merge engines \
             ({:.2}x vs one engine), chunks/shard {:?}",
            sharded_cycles,
            out.latency_cycles as f64 / (*sharded_cycles).max(1) as f64,
            shard_chunks
        );
        println!(
            "fleet metrics : {} jobs, {} errors, imbalance {:.2}, \
             worst p50/p99 {}/{} µs, {} rerouted",
            snap.completed, snap.errors, snap.imbalance, snap.p50_us, snap.p99_us, snap.rerouted
        );
        println!(
            "resilience    : {} retries, {} hedges won / {} lost, \
             {} budget-denied, {:.1} tokens left",
            snap.retries,
            snap.hedges_won,
            snap.hedges_lost,
            snap.budget_exhausted,
            snap.retry_tokens
        );
    }
    println!("cycles/number : {:.3}", out.latency_cycles as f64 / n as f64);
    println!("throughput    : {:.2} Mnum/s @500MHz", out.throughput() / 1e6);
    println!("area (model)  : {:.1} Kµm²", out.area_kum2);
    println!("power (model) : {:.1} mW", out.power_mw);
    println!("host wall     : {:.1} ms", wall.as_secs_f64() * 1e3);
    Ok(())
}

/// `sort --tenant/--priority` on a fleet: one tagged request through
/// the fair-share admission plane ([`Frontend`]), the path a
/// multi-tenant client takes, instead of the hierarchical fan-out.
fn cmd_sort_tagged(
    args: &Args,
    d: &Dataset,
    fleet: ShardedSortService,
    remote: bool,
) -> Result<()> {
    let tenant = args.get_or("tenant", "anon").to_string();
    let priority = args.parse_num("priority", Priority::Batch)?;
    let tag = JobTag::new(tenant, priority);
    let fe = Frontend::new(fleet, FrontendConfig::default())?;
    let t0 = std::time::Instant::now();
    let resp = fe.sort(&tag, d.values.clone())?;
    let wall = t0.elapsed();
    let n = d.values.len();
    let mut check = d.values.clone();
    check.sort_unstable();
    println!(
        "request plane : tagged sort as tenant `{}`, {} class",
        tag.tenant,
        tag.priority.name()
    );
    println!("dataset       : {} (n={n}, seed={})", d.kind.name(), d.seed);
    println!("correct       : {}", resp.sorted == check);
    println!(
        "served by     : worker {} in {} µs ({} simulated cycles)",
        resp.worker,
        resp.latency_us,
        resp.stats.cycles()
    );
    let adm = fe.admission();
    println!(
        "admission     : {} admitted, {} shed saturated, {} tenant-capped",
        adm.admitted,
        adm.shed_batch + adm.shed_interactive,
        adm.shed_tenant_cap
    );
    println!("host wall     : {:.1} ms", wall.as_secs_f64() * 1e3);
    if remote {
        // Operator-started shard hosts outlive the sort.
        fe.into_fleet().disconnect();
    } else {
        fe.shutdown();
    }
    Ok(())
}

/// Concurrency stress: `--clients` threads each push `--requests`
/// tagged sorts through one shared [`Frontend`] over an in-process
/// fleet. Even-numbered clients run interactive, odd batch, so a
/// saturated run shows the shed ordering live.
fn cmd_stress(args: &Args) -> Result<()> {
    let clients = args.parse_num("clients", 8usize)?;
    let requests = args.parse_num("requests", 32usize)?;
    let n = args.parse_size("n", 1024)?;
    let shards = args.parse_num("shards", 2usize)?;
    let workers = args.parse_num("workers", 2usize)?;
    let seed = args.parse_num("seed", 42u64)?;
    let max_outstanding = args.parse_num("max-outstanding", 64usize)?;
    let tenant_cap = args.parse_num("tenant-cap", 16usize)?;
    let route = args.parse_num("route", RoutePolicy::RoundRobin)?;
    if clients == 0 || requests == 0 {
        bail!("--clients and --requests must be at least 1");
    }
    let fleet = ShardedSortService::start(ShardedConfig::uniform(
        shards,
        route,
        ServiceConfig { workers, ..Default::default() },
    ))?;
    let fe = Arc::new(Frontend::new(
        fleet,
        FrontendConfig { max_outstanding, tenant_cap, ..Default::default() },
    )?);
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let fe = Arc::clone(&fe);
        handles.push(std::thread::spawn(move || -> Result<(u64, u64, u64)> {
            let class = if c % 2 == 0 { Priority::Interactive } else { Priority::Batch };
            let tag = JobTag::new(format!("client-{c}"), class);
            let (mut ok, mut shed, mut elems) = (0u64, 0u64, 0u64);
            for r in 0..requests {
                let s = seed + (c * requests + r) as u64;
                let data = Dataset::generate32(DatasetKind::MapReduce, n, s).values;
                match fe.sort(&tag, data) {
                    Ok(resp) => {
                        ok += 1;
                        elems += resp.sorted.len() as u64;
                    }
                    // Shed load is the expected outcome under pressure,
                    // not a failure of the run.
                    Err(e) if e.downcast_ref::<AdmitError>().is_some() => shed += 1,
                    Err(e) => return Err(e),
                }
            }
            Ok((ok, shed, elems))
        }));
    }
    let (mut ok, mut shed, mut elems) = (0u64, 0u64, 0u64);
    for h in handles {
        let (o, s, e) = h.join().expect("stress client panicked")?;
        ok += o;
        shed += s;
        elems += e;
    }
    let wall = t0.elapsed();
    let adm = fe.admission();
    let snap = fe.fleet_metrics();
    println!(
        "stress        : {clients} clients x {requests} requests of {n} \
         ({shards} shards, {workers} workers/shard, {})",
        route.name()
    );
    println!("served        : {ok} ok, {shed} shed, {elems} elements");
    println!(
        "admission     : {} admitted, {} shed saturated ({} batch / {} interactive), \
         {} tenant-capped, {} overdraft spends",
        adm.admitted,
        adm.shed_batch + adm.shed_interactive,
        adm.shed_batch,
        adm.shed_interactive,
        adm.shed_tenant_cap,
        adm.overdraft_spent
    );
    println!(
        "fleet         : {} completed, {} errors, imbalance {:.2}, \
         worst p50/p99 {}/{} µs",
        snap.completed, snap.errors, snap.imbalance, snap.p50_us, snap.p99_us
    );
    println!(
        "throughput    : {:.2} Mnum/s over {:.1} ms wall",
        elems as f64 / wall.as_secs_f64() / 1e6,
        wall.as_secs_f64() * 1e3
    );
    if let Ok(fe) = Arc::try_unwrap(fe) {
        fe.shutdown();
    }
    Ok(())
}

/// Out-of-bank scaling sweep: n from 4× capacity up to `--max`.
fn cmd_scale(args: &Args) -> Result<()> {
    let capacity = args.parse_size("capacity", memsort::params::DEFAULT_N)?;
    let fanout = args.parse_num("fanout", 4usize)?;
    let width = args.parse_num("width", 32u32)?;
    let k = args.parse_num("k", 2usize)?;
    let seed = args.parse_num("seed", 42u64)?;
    let max = args.parse_size("max", 1_000_000)?;
    if capacity == 0 {
        bail!("--capacity must be at least 1");
    }
    if fanout < 2 {
        bail!("--fanout must be at least 2");
    }
    if max <= capacity {
        bail!("--max ({max}) must exceed --capacity ({capacity})");
    }
    let streaming = args.flag("streaming");
    let route = args.parse_num("route", RoutePolicy::RoundRobin)?;
    // Shard count before the worker split: a geometry list defines one
    // (possibly heterogeneous) shard per entry.
    let shards_hint = match args.get("shard-geometry") {
        Some(spec) => spec.split(',').count(),
        None => args.parse_num("shards", 1usize)?,
    };
    let services = shard_services(args, &report::sweep_service(width, k, shards_hint))?;
    let shards = services.len();
    // Geometries survive the move of `services` into the sweep: the
    // schedule report below models the fleet from them.
    let geometries: Vec<Geometry> = services.iter().map(|s| s.geometry.clone()).collect();
    let mut ns = Vec::new();
    let mut n = capacity.saturating_mul(4);
    while n < max {
        ns.push(n);
        n = n.saturating_mul(4);
    }
    ns.push(max);
    let (pts, snap) =
        report::scaling_sharded(&ns, capacity, fanout, seed, streaming, services, route);
    let fleet = (shards > 1).then_some(snap);
    if args.flag("json") {
        let points = Json::arr(pts.iter().map(|p| Json::obj([
            ("n", p.n.into()),
            ("capacity", p.capacity.into()),
            ("chunks", p.chunks.into()),
            ("fanout", p.fanout.into()),
            ("streaming", Json::Bool(p.streaming)),
            ("shards", p.shards.into()),
            ("latency_cycles", p.latency_cycles.into()),
            ("barrier_cycles", p.barrier_cycles.into()),
            ("streamed_cycles", p.streamed_cycles.into()),
            ("sharded_cycles", p.sharded_cycles.into()),
            ("overlap_saving", p.overlap_saving.into()),
            ("cycles_per_number", p.cycles_per_number.into()),
            ("merge_fraction", p.merge_fraction.into()),
            ("throughput_mnum_s", p.throughput_mnum_s.into()),
            ("area_kum2", p.area_kum2.into()),
            ("power_mw", p.power_mw.into()),
        ])));
        match &fleet {
            None => println!("{}", points.render()),
            Some(snap) => {
                // Points plus the fleet snapshot: totals, per-shard
                // latency percentiles, imbalance.
                let fleet_json = Json::obj([
                    ("route", route.name().into()),
                    ("completed", snap.completed.into()),
                    ("errors", snap.errors.into()),
                    ("elements", snap.elements.into()),
                    ("rerouted", snap.rerouted.into()),
                    ("recovered", snap.recovered.into()),
                    ("retries", snap.retries.into()),
                    ("hedges_won", snap.hedges_won.into()),
                    ("hedges_lost", snap.hedges_lost.into()),
                    ("budget_exhausted", snap.budget_exhausted.into()),
                    ("retry_tokens", snap.retry_tokens.into()),
                    ("imbalance", snap.imbalance.into()),
                    ("p50_us", snap.p50_us.into()),
                    ("p99_us", snap.p99_us.into()),
                    (
                        "shards",
                        Json::arr(snap.shards.iter().zip(&snap.healthy).map(|(s, &h)| {
                            Json::obj([
                                ("completed", s.completed.into()),
                                ("elements", s.elements.into()),
                                ("p50_us", s.p50_us.into()),
                                ("p99_us", s.p99_us.into()),
                                ("healthy", Json::Bool(h)),
                            ])
                        })),
                    ),
                ]);
                println!(
                    "{}",
                    Json::obj([("points", points), ("fleet", fleet_json)]).render()
                );
            }
        }
    } else {
        let rows: Vec<Vec<String>> = pts
            .iter()
            .map(|p| {
                vec![
                    p.n.to_string(),
                    p.chunks.to_string(),
                    p.latency_cycles.to_string(),
                    p.sharded_cycles.to_string(),
                    format!("{:.2}", p.cycles_per_number),
                    format!("{:.1}%", p.merge_fraction * 100.0),
                    format!("{:.1}%", p.overlap_saving * 100.0),
                    format!("{:.1}", p.throughput_mnum_s),
                    format!("{:.0}", p.area_kum2),
                    format!("{:.0}", p.power_mw),
                ]
            })
            .collect();
        println!(
            "out-of-bank scaling (capacity={capacity}, fanout={fanout}, w={width}, k={k}, \
             MapReduce, {} merge, {} shard{})",
            if streaming { "streaming" } else { "barrier" },
            shards,
            if shards == 1 { "" } else { "s" }
        );
        print!(
            "{}",
            report::render_table(
                &[
                    "n", "chunks", "latency", "fleet", "cyc/num", "merge", "hidden", "Mnum/s",
                    "Kµm²", "mW"
                ],
                &rows
            )
        );
        if let Some(snap) = &fleet {
            println!(
                "fleet ({}): {} jobs, {} errors, imbalance {:.2}, rerouted {}, recovered {}, \
                 {} retries, hedges {}/{}, {} budget-denied",
                route.name(),
                snap.completed,
                snap.errors,
                snap.imbalance,
                snap.rerouted,
                snap.recovered,
                snap.retries,
                snap.hedges_won,
                snap.hedges_lost,
                snap.budget_exhausted
            );
            for (i, (s, h)) in snap.shards.iter().zip(&snap.healthy).enumerate() {
                println!(
                    "  shard {i}: {} jobs, {} elements, p50/p99 {}/{} µs{}",
                    s.completed,
                    s.elements,
                    s.p50_us,
                    s.p99_us,
                    if *h { "" } else { " [DOWN]" }
                );
            }
            // The modelled fleet timeline at the sweep's largest n:
            // the completion-balanced deal the planner routes against,
            // with each shard's merge drain (schedule layer, modelled
            // cycles at the nominal per-element cost — not measured
            // µs).
            let chunks = max.div_ceil(capacity);
            let models: Vec<_> = geometries
                .iter()
                .map(|g| {
                    shard_model(
                        capacity,
                        fanout,
                        g,
                        memsort::params::NOMINAL_COLSKIP_CYC_PER_NUM,
                    )
                })
                .collect();
            let sched = FleetSchedule::completion_balanced(chunks, capacity, &models, fanout);
            println!(
                "  modelled schedule @ n={max}: fleet completion {} cycles \
                 (completion-balanced deal over {chunks} chunks)",
                sched.completion()
            );
            for lane in sched.lanes() {
                println!(
                    "    shard {}: {} chunks, colskip {}, first arrival {}, last ready {}, \
                     merge drain {}",
                    lane.shard,
                    lane.chunks,
                    lane.colskip(),
                    lane.arrival,
                    lane.ready,
                    lane.drain
                );
            }
        }
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let d = dataset_from(args)?;
    for v in &d.values {
        println!("{v}");
    }
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let d = dataset_from(args)?;
    let width = args.parse_num("width", 32u32)?;
    let s = analyze(&d.values, width);
    println!("dataset             : {}", d.kind.name());
    println!("n                   : {}", s.n);
    println!("min / max           : {} / {}", s.min, s.max);
    println!("mean leading zeros  : {:.2} bits", s.mean_leading_zeros);
    println!("unique fraction     : {:.3}", s.unique_fraction);
    println!("mean sorted prefix  : {:.2} bits", s.mean_sorted_prefix);
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    let id = args.get("id").ok_or_else(|| anyhow!("--id <6|7|8a|8b> required"))?;
    let n = args.parse_num("n", 1024usize)?;
    let width = args.parse_num("width", 32u32)?;
    let trials = args.parse_num("trials", 5u64)?;
    let seed = args.parse_num("seed", 42u64)?;
    let kmax = args.parse_num("kmax", 8usize)?;
    let json = args.flag("json");
    match id {
        "6" => {
            let pts = report::fig6(n, width, kmax, trials, seed);
            if json {
                println!(
                    "{}",
                    Json::arr(pts.iter().map(|p| Json::obj([
                        ("dataset", p.dataset.name().into()),
                        ("k", p.k.into()),
                        ("cycles_per_number", p.cycles_per_number.into()),
                        ("speedup", p.speedup.into()),
                    ])))
                    .render()
                );
            } else {
                let rows: Vec<Vec<String>> = pts
                    .iter()
                    .map(|p| {
                        vec![
                            p.dataset.name().to_string(),
                            p.k.to_string(),
                            format!("{:.2}", p.cycles_per_number),
                            format!("{:.2}", p.speedup),
                        ]
                    })
                    .collect();
                println!("Fig. 6 — normalized speedup over baseline (N={n}, w={width})");
                print!("{}", report::render_table(&["dataset", "k", "cyc/num", "speedup"], &rows));
            }
        }
        "7" => {
            let pts = report::fig7(n, width, kmax, trials, seed);
            if json {
                println!(
                    "{}",
                    Json::arr(pts.iter().map(|p| Json::obj([
                        ("k", p.k.into()),
                        ("cycles_per_number", p.cycles_per_number.into()),
                        ("area_kum2", p.area_kum2.into()),
                        ("power_mw", p.power_mw.into()),
                        ("norm_area", p.norm_area.into()),
                        ("norm_power", p.norm_power.into()),
                        ("area_eff_ratio", p.area_eff_ratio.into()),
                        ("energy_eff_ratio", p.energy_eff_ratio.into()),
                    ])))
                    .render()
                );
            } else {
                let rows: Vec<Vec<String>> = pts
                    .iter()
                    .map(|p| {
                        vec![
                            p.k.to_string(),
                            format!("{:.2}", p.cycles_per_number),
                            format!("{:.1}", p.area_kum2),
                            format!("{:.1}", p.power_mw),
                            format!("{:.3}", p.norm_area),
                            format!("{:.3}", p.norm_power),
                            format!("{:.2}", p.area_eff_ratio),
                            format!("{:.2}", p.energy_eff_ratio),
                        ]
                    })
                    .collect();
                println!("Fig. 7 — area/power vs k on MapReduce (N={n}, w={width})");
                print!(
                    "{}",
                    report::render_table(
                        &["k", "cyc/num", "area", "power", "n.area", "n.power", "AE x", "EE x"],
                        &rows
                    )
                );
            }
        }
        "8a" => {
            let rows_data = report::fig8a(n, width, trials, seed);
            if json {
                println!(
                    "{}",
                    Json::arr(rows_data.iter().map(|r| Json::obj([
                        ("name", r.name.into()),
                        ("cycles_per_number", r.cycles_per_number.into()),
                        ("area_kum2", r.area_kum2.into()),
                        ("area_eff", r.area_eff.into()),
                        ("power_mw", r.power_mw.into()),
                        ("energy_eff", r.energy_eff.into()),
                    ])))
                    .render()
                );
            } else {
                let rows: Vec<Vec<String>> = rows_data
                    .iter()
                    .map(|r| {
                        vec![
                            r.name.to_string(),
                            format!("{:.2}", r.cycles_per_number),
                            format!("{:.1} ({:.2})", r.area_kum2, r.area_eff),
                            format!("{:.1} ({:.1})", r.power_mw, r.energy_eff),
                        ]
                    })
                    .collect();
                println!("Fig. 8(a) — implementation summary (MapReduce, N={n}, w={width})");
                print!(
                    "{}",
                    report::render_table(
                        &["sorter", "cyc/num", "area Kµm² (AE)", "power mW (EE)"],
                        &rows
                    )
                );
            }
        }
        "8b" => {
            let pts = report::fig8b(n, width);
            if json {
                println!(
                    "{}",
                    Json::arr(pts.iter().map(|p| Json::obj([
                        ("sub_len", p.sub_len.into()),
                        ("banks", p.banks.into()),
                        ("norm_area", p.norm_area.into()),
                        ("norm_power", p.norm_power.into()),
                    ])))
                    .render()
                );
            } else {
                let rows: Vec<Vec<String>> = pts
                    .iter()
                    .map(|p| {
                        vec![
                            p.sub_len.to_string(),
                            p.banks.to_string(),
                            format!("{:.3}", p.norm_area),
                            format!("{:.3}", p.norm_power),
                        ]
                    })
                    .collect();
                println!("Fig. 8(b) — multibank area/power (N={n}, w={width}, k=2)");
                print!("{}", report::render_table(&["Ns", "banks", "n.area", "n.power"], &rows));
            }
        }
        other => bail!("unknown figure `{other}` (6, 7, 8a, 8b)"),
    }
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let trials = args.parse_num("trials", 5u64)?;
    let seed = args.parse_num("seed", 42u64)?;
    let (n, width) = report::paper_defaults();
    let rows = report::fig8a(n, width, trials, seed);
    let base = &rows[0];
    let cs = &rows[2];
    let model = CostModel::calibrated();
    let speedup = base.cycles_per_number / cs.cycles_per_number;
    let ae = cs.area_eff / base.area_eff;
    let ee = cs.energy_eff / base.energy_eff;
    println!("headline (paper abstract vs measured, MapReduce, N={n}, w={width}, k=2)");
    println!("  speedup           : paper 4.08x | measured {speedup:.2}x");
    println!("  area efficiency   : paper 3.14x | measured {ae:.2}x");
    println!("  energy efficiency : paper 3.39x | measured {ee:.2}x");
    println!(
        "  col-skip cyc/num  : paper 7.84  | measured {:.2}",
        cs.cycles_per_number
    );
    println!(
        "  col-skip area     : paper 101.1 | model {:.1} Kµm²",
        model.area_kum2(SorterArch::ColSkip { n, w: width, k: 2 })
    );
    println!(
        "  col-skip power    : paper 385.2 | model(nominal) {:.1} mW",
        model.power_mw(SorterArch::ColSkip { n, w: width, k: 2 }, Activity::nominal_colskip())
    );
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let kind = DatasetKind::parse(args.get_or("dataset", "clustered"))
        .ok_or_else(|| anyhow!("unknown dataset (see usage)"))?;
    let n = args.parse_num("n", 8usize)?;
    let width = args.parse_num("width", 8u32)?;
    let k = args.parse_num("k", 2usize)?;
    let seed = args.parse_num("seed", 42u64)?;
    let iters = args.parse_num("iters", 6usize)?;
    let d = Dataset::generate(kind, n, width, seed);
    println!("values: {:?}", d.values);
    let (out, run) = memsort::sim::trace_sort(
        &d.values,
        &ColSkipConfig { width, k, ..Default::default() },
    );
    print!("{}", memsort::sim::render_schedule(&run, iters));
    println!(
        "total: {} CRs, {} SLs, {} drains, {} cycles ({:.2} cyc/num)",
        out.stats.crs,
        out.stats.sls,
        out.stats.drains,
        out.stats.cycles(),
        out.stats.cycles_per_number(n)
    );
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    use memsort::cost::energy::EnergyModel;
    use memsort::memory::Bank;
    let d = dataset_from(args)?;
    let width = args.parse_num("width", 32u32)?;
    let k = args.parse_num("k", 2usize)?;
    let n = d.values.len();
    let mut bank = Bank::load(&d.values, width);
    let sorter = ColSkipSorter::new(ColSkipConfig { width, k, ..Default::default() });
    let out = sorter.sort_bank(&mut bank);
    let em = EnergyModel::default();
    let b = em.breakdown(bank.meter(), &out.stats, n, width, k);
    println!("energy breakdown ({} n={n} w={width} k={k}):", d.kind.name());
    println!("  array sensing    : {:.3} nJ", b.array_sense_j * 1e9);
    println!("  circuit CR path  : {:.3} nJ", b.circuit_cr_j * 1e9);
    println!("  wordline updates : {:.3} nJ", b.circuit_re_j * 1e9);
    println!("  state table      : {:.3} nJ", b.state_table_j * 1e9);
    println!("  (array load      : {:.3} nJ, one-time)", b.write_j * 1e9);
    println!("  total / element  : {:.3} pJ", b.per_element_j(n) * 1e12);
    println!(
        "  avg power @500MHz: {:.1} mW over {} cycles",
        b.average_power_w(out.stats.cycles()) * 1e3,
        out.stats.cycles()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let engine = args.parse_num("engine", EngineKind::Native)?;
    let workers = args.parse_num("workers", 4usize)?;
    let requests = args.parse_num("requests", 64usize)?;
    let n = args.parse_num("n", 1024usize)?;
    let seed = args.parse_num("seed", 42u64)?;
    let artifacts = args.get_or("artifacts", "artifacts");
    if args.flag("shard") {
        // A wire shard host: serve the RPC protocol on a TCP socket
        // until a coordinator sends Shutdown. `sort --connect` is the
        // matching client; the frame format is specced in
        // rust/OPERATIONS.md.
        let width = args.parse_num("width", 32u32)?;
        let k = args.parse_num("k", 2usize)?;
        let banks = args.parse_num("banks", 1usize)?;
        let mut cfg = ServiceConfig {
            workers,
            engine,
            banks,
            colskip: ColSkipConfig { width, k, ..Default::default() },
            artifacts_dir: artifacts.into(),
            ..Default::default()
        };
        if let Some(spec) = args.get("geometry") {
            cfg.geometry = Geometry::from_spec(spec)?;
            if cfg.geometry.width != width {
                bail!(
                    "--geometry width {} conflicts with engine --width {width}",
                    cfg.geometry.width
                );
            }
        }
        let host = args.get_or("host", "127.0.0.1");
        let port = args.parse_num("port", 7600u16)?;
        let max_conns = args.parse_num("max-conns", 8usize)?;
        let listener = std::net::TcpListener::bind((host, port))
            .map_err(|e| anyhow!("binding {host}:{port}: {e}"))?;
        println!(
            "shard host on {} ({} workers, geometry {}x{}, engine {}, \
             up to {max_conns} concurrent coordinators)",
            listener.local_addr()?,
            cfg.workers,
            cfg.geometry.largest_bank(),
            cfg.geometry.width,
            engine.name()
        );
        return memsort::coordinator::shard_server::serve_tcp(listener, cfg, max_conns);
    }
    let svc = SortService::start(ServiceConfig {
        workers,
        engine,
        artifacts_dir: artifacts.into(),
        ..Default::default()
    })?;
    let t0 = std::time::Instant::now();
    let batch: Vec<Vec<u32>> = (0..requests)
        .map(|i| Dataset::generate32(DatasetKind::MapReduce, n, seed + i as u64).values)
        .collect();
    let resps = svc.submit_batch(batch)?;
    let wall = t0.elapsed();
    let m = svc.metrics();
    println!("engine          : {}", engine.name());
    println!("workers         : {workers}");
    println!("requests        : {} ok, {} errors", m.completed, m.errors);
    println!("elements sorted : {}", m.elements);
    println!("wall time       : {:.1} ms", wall.as_secs_f64() * 1e3);
    println!(
        "service rate    : {:.2} Mnum/s",
        m.elements as f64 / wall.as_secs_f64() / 1e6
    );
    println!("latency p50/p99 : {} µs / {} µs", m.p50_us, m.p99_us);
    println!("sim cyc/num     : {:.2}", m.cycles_per_number);
    debug_assert_eq!(resps.len(), requests);
    svc.shutdown();
    Ok(())
}

//! Hand-rolled CLI argument parsing (no clap offline): `--key value` /
//! `--flag` pairs with typed accessors and helpful errors.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed arguments: a subcommand plus `--key value` options.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.command = it.next();
            }
        }
        while let Some(tok) = it.next() {
            let Some(key) = tok.strip_prefix("--") else {
                bail!("unexpected positional argument `{tok}` (options are --key value)");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    args.opts.insert(key.to_string(), it.next().expect("peeked"));
                }
                _ => args.flags.push(key.to_string()),
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse::<T>()
                .map_err(|e| anyhow!("--{name} `{s}`: {e}")),
        }
    }

    /// Parse a human-friendly element count: plain digits plus an
    /// optional decimal `k`/`m`/`g` suffix (case-insensitive), so
    /// `--n 1m` and `--n 1000000` are the same request.
    pub fn parse_size(&self, name: &str, default: usize) -> Result<usize> {
        let Some(s) = self.get(name) else { return Ok(default) };
        let (digits, mult) = match s.char_indices().last() {
            Some((i, c)) if c.eq_ignore_ascii_case(&'k') => (&s[..i], 1_000usize),
            Some((i, c)) if c.eq_ignore_ascii_case(&'m') => (&s[..i], 1_000_000),
            Some((i, c)) if c.eq_ignore_ascii_case(&'g') => (&s[..i], 1_000_000_000),
            _ => (s, 1),
        };
        let base: usize = digits
            .parse()
            .map_err(|e| anyhow!("--{name} `{s}`: {e} (use digits with an optional k/m/g)"))?;
        base.checked_mul(mult)
            .ok_or_else(|| anyhow!("--{name} `{s}`: overflows usize"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["fig", "--id", "6", "--trials", "3", "--json"]);
        assert_eq!(a.command.as_deref(), Some("fig"));
        assert_eq!(a.get("id"), Some("6"));
        assert_eq!(a.parse_num::<u64>("trials", 1).unwrap(), 3);
        assert!(a.flag("json"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["sort"]);
        assert_eq!(a.parse_num::<usize>("n", 1024).unwrap(), 1024);
        assert_eq!(a.get_or("dataset", "mapreduce"), "mapreduce");
    }

    #[test]
    fn bad_number_is_an_error() {
        let a = parse(&["sort", "--n", "abc"]);
        assert!(a.parse_num::<usize>("n", 1).is_err());
    }

    #[test]
    fn rejects_positional_garbage() {
        assert!(Args::parse(vec!["sort".into(), "oops".into()]).is_err());
    }

    #[test]
    fn size_suffixes() {
        let a = parse(&["sort", "--n", "1M", "--capacity", "2k", "--x", "3g", "--plain", "77"]);
        assert_eq!(a.parse_size("n", 0).unwrap(), 1_000_000);
        assert_eq!(a.parse_size("capacity", 0).unwrap(), 2_000);
        assert_eq!(a.parse_size("x", 0).unwrap(), 3_000_000_000);
        assert_eq!(a.parse_size("plain", 0).unwrap(), 77);
        assert_eq!(a.parse_size("missing", 42).unwrap(), 42);
        assert!(parse(&["sort", "--n", "q5k"]).parse_size("n", 0).is_err());
        assert!(parse(&["sort", "--n", "k"]).parse_size("n", 0).is_err());
    }

    #[test]
    fn no_command() {
        let a = parse(&["--n", "5"]);
        assert_eq!(a.command, None);
        assert_eq!(a.get("n"), Some("5"));
    }
}

//! Deterministic PRNGs used by every generator in the crate.
//!
//! The vendored registry has no `rand`, so we carry our own: SplitMix64
//! for seeding and Xoshiro256** as the workhorse — both are the reference
//! algorithms from Blackman & Vigna, chosen for reproducibility (every
//! figure harness is seeded, so paper-reproduction runs are bit-stable).

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** 1.0.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the last Box–Muller draw.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (the recommended seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    #[inline]
    fn rotl(x: u64, k: u32) -> u64 {
        x.rotate_left(k)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = Self::rotl(self.s[1].wrapping_mul(5), 7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = Self::rotl(self.s[3], 45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal deviate via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Geometric-ish small value: `floor(-scale * ln(U))`, clamped.
    /// Used for "majority small with frequent repetitions" weight models.
    pub fn exp_small(&mut self, scale: f64, max: u64) -> u64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        ((-(u.ln()) * scale) as u64).min(max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(1);
        for bound in [1u64, 2, 3, 7, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(7);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..1000 {
            let v = r.range_u64(10, 12);
            assert!((10..=12).contains(&v));
            lo_seen |= v == 10;
            hi_seen |= v == 12;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(12345);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn exp_small_clamps() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.exp_small(8.0, 100) <= 100);
        }
    }
}

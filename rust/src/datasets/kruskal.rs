//! Kruskal's-algorithm workload: the edge-weight multiset of a random
//! graph, plus a full MST implementation (union–find) used by the
//! `kruskal_mst` example to demonstrate the sorter inside the real
//! application the paper motivates (§II.A).
//!
//! The paper characterizes these weights as "small numbers with frequent
//! repetitions" — e.g. road-network or grid-like graphs where weights are
//! quantized lengths/costs. We model weights as a quantized exponential:
//! `w = q * floor(Exp(scale))`, which concentrates mass near zero and
//! repeats heavily.

use super::rng::Rng;

/// Generate `n` edge weights with the paper's stated statistics
/// (majority small, frequent repetitions).
pub fn edge_weights(n: usize, rng: &mut Rng) -> Vec<u32> {
    // Weight = quantum * Exp(scale) truncated: exponential mass near zero
    // (majority small), quantized so exact repetitions are frequent but
    // not dominant — tuned so the k=2 column-skipping speedup at N=1024
    // lands in the paper's ~3.5× regime (Fig. 6).
    let quantum = 7u64; // non-power-of-two so low bits are non-trivial
    let scale = 1600.0;
    let max_q = 1u64 << 22; // keep everything well under 2^25
    (0..n).map(|_| (quantum * rng.exp_small(scale, max_q)).min(u32::MAX as u64) as u32).collect()
}

/// An undirected weighted edge.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Edge {
    pub u: u32,
    pub v: u32,
    pub weight: u32,
}

/// Generate a connected random graph with `nodes` vertices and `extra`
/// additional random edges beyond a random spanning tree.
pub fn random_graph(nodes: usize, extra: usize, rng: &mut Rng) -> Vec<Edge> {
    assert!(nodes >= 2);
    let mut edges = Vec::with_capacity(nodes - 1 + extra);
    // Random spanning tree: connect each new vertex to a random earlier one.
    let weights = edge_weights(nodes - 1 + extra, rng);
    let mut wi = 0;
    for v in 1..nodes {
        let u = rng.below(v as u64) as u32;
        edges.push(Edge { u, v: v as u32, weight: weights[wi] });
        wi += 1;
    }
    for _ in 0..extra {
        let u = rng.below(nodes as u64) as u32;
        let mut v = rng.below(nodes as u64) as u32;
        if v == u {
            v = (v + 1) % nodes as u32;
        }
        edges.push(Edge { u, v, weight: weights[wi] });
        wi += 1;
    }
    edges
}

/// Union–find with path halving and union by rank.
pub struct UnionFind {
    parent: Vec<u32>,
    rank: Vec<u8>,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        UnionFind { parent: (0..n as u32).collect(), rank: vec![0; n] }
    }

    pub fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let gp = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = gp;
            x = gp;
        }
        x
    }

    /// Union the sets of `a` and `b`; returns false if already joined.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (hi, lo) = if self.rank[ra as usize] >= self.rank[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[lo as usize] = hi;
        if self.rank[hi as usize] == self.rank[lo as usize] {
            self.rank[hi as usize] += 1;
        }
        true
    }
}

/// Kruskal's MST given edges **already sorted by weight** (the sorter under
/// test provides the order as an argsort permutation).
///
/// Returns (total weight, chosen edge indexes).
pub fn mst_from_sorted(nodes: usize, edges: &[Edge], order: &[usize]) -> (u64, Vec<usize>) {
    let mut uf = UnionFind::new(nodes);
    let mut total = 0u64;
    let mut chosen = Vec::with_capacity(nodes.saturating_sub(1));
    for &i in order {
        let e = edges[i];
        if uf.union(e.u, e.v) {
            total += e.weight as u64;
            chosen.push(i);
            if chosen.len() == nodes - 1 {
                break;
            }
        }
    }
    (total, chosen)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argsort_by_weight(edges: &[Edge]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..edges.len()).collect();
        idx.sort_by_key(|&i| edges[i].weight);
        idx
    }

    #[test]
    fn edge_weights_small_and_repetitive() {
        let mut rng = Rng::new(2);
        let w = edge_weights(2048, &mut rng);
        let mut uniq = w.clone();
        uniq.sort_unstable();
        uniq.dedup();
        // Frequent repetitions (≥15% duplicates at this n), all small.
        assert!(uniq.len() < w.len() * 85 / 100, "{} unique of {}", uniq.len(), w.len());
        assert!(w.iter().all(|&x| x < 1 << 25));
    }

    #[test]
    fn random_graph_is_connected() {
        let mut rng = Rng::new(3);
        let edges = random_graph(100, 50, &mut rng);
        assert_eq!(edges.len(), 149);
        let mut uf = UnionFind::new(100);
        for e in &edges {
            uf.union(e.u, e.v);
        }
        let root = uf.find(0);
        assert!((0..100).all(|v| uf.find(v) == root));
    }

    #[test]
    fn union_find_basics() {
        let mut uf = UnionFind::new(4);
        assert!(uf.union(0, 1));
        assert!(!uf.union(1, 0));
        assert!(uf.union(2, 3));
        assert_ne!(uf.find(0), uf.find(2));
        assert!(uf.union(0, 3));
        assert_eq!(uf.find(1), uf.find(2));
    }

    #[test]
    fn mst_matches_reference_prim_on_small_graph() {
        // Triangle with a cheap path: MST must take the two cheap edges.
        let edges = vec![
            Edge { u: 0, v: 1, weight: 1 },
            Edge { u: 1, v: 2, weight: 2 },
            Edge { u: 0, v: 2, weight: 10 },
        ];
        let (total, chosen) = mst_from_sorted(3, &edges, &argsort_by_weight(&edges));
        assert_eq!(total, 3);
        assert_eq!(chosen, vec![0, 1]);
    }

    #[test]
    fn mst_has_v_minus_1_edges_and_spans() {
        let mut rng = Rng::new(4);
        let edges = random_graph(64, 128, &mut rng);
        let (_, chosen) = mst_from_sorted(64, &edges, &argsort_by_weight(&edges));
        assert_eq!(chosen.len(), 63);
        let mut uf = UnionFind::new(64);
        for &i in &chosen {
            assert!(uf.union(edges[i].u, edges[i].v), "chosen edges must be acyclic");
        }
    }

    #[test]
    fn mst_weight_is_order_invariant_for_equal_weights() {
        // Two different stable orders over tied weights give the same total.
        let mut rng = Rng::new(5);
        let edges = random_graph(32, 64, &mut rng);
        let fwd = argsort_by_weight(&edges);
        let mut rev: Vec<usize> = (0..edges.len()).rev().collect();
        rev.sort_by_key(|&i| edges[i].weight); // stable: reversed tie order
        let (t1, _) = mst_from_sorted(32, &edges, &fwd);
        let (t2, _) = mst_from_sorted(32, &edges, &rev);
        assert_eq!(t1, t2);
    }
}

//! Workload statistics that predict column-skipping performance.
//!
//! The paper's speedups are driven by two dataset properties (§III):
//! leading-zero runs (scenario 1) and shared prefixes / repetitions
//! (scenario 2). This module quantifies both so the figure harnesses can
//! report *why* a dataset speeds up, not just by how much.

/// Summary statistics of a sorting workload.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadStats {
    pub n: usize,
    pub min: u32,
    pub max: u32,
    /// Mean leading-zero count within `width` bits.
    pub mean_leading_zeros: f64,
    /// Unique values / n.
    pub unique_fraction: f64,
    /// Mean shared-prefix length (bits, within `width`) between
    /// *consecutive values of the sorted order* — the quantity state
    /// recording exploits when it resumes below a recorded column.
    pub mean_sorted_prefix: f64,
}

/// Compute [`WorkloadStats`] for `values` at the given bit width.
pub fn analyze(values: &[u32], width: u32) -> WorkloadStats {
    assert!(!values.is_empty());
    assert!((1..=32).contains(&width));
    let n = values.len();
    let mut sorted = values.to_vec();
    sorted.sort_unstable();
    let min = sorted[0];
    let max = sorted[n - 1];
    let mean_leading_zeros = values
        .iter()
        .map(|&v| (v.leading_zeros().min(32) as i64 - (32 - width) as i64).max(0) as f64)
        .sum::<f64>()
        / n as f64;
    let mut uniq = 1usize;
    let mut prefix_sum = 0f64;
    for i in 1..n {
        if sorted[i] != sorted[i - 1] {
            uniq += 1;
        }
        let x = sorted[i] ^ sorted[i - 1];
        let shared = if x == 0 { width } else { x.leading_zeros().saturating_sub(32 - width) };
        prefix_sum += shared as f64;
    }
    WorkloadStats {
        n,
        min,
        max,
        mean_leading_zeros,
        unique_fraction: uniq as f64 / n as f64,
        mean_sorted_prefix: if n > 1 { prefix_sum / (n - 1) as f64 } else { width as f64 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, DatasetKind};

    #[test]
    fn constant_array_stats() {
        let s = analyze(&[5, 5, 5, 5], 8);
        assert_eq!(s.min, 5);
        assert_eq!(s.max, 5);
        assert_eq!(s.unique_fraction, 0.25);
        assert_eq!(s.mean_sorted_prefix, 8.0);
        assert_eq!(s.mean_leading_zeros, 5.0); // 5 = 00000101 in 8 bits
    }

    #[test]
    fn leading_zeros_respects_width() {
        let s = analyze(&[1], 4);
        assert_eq!(s.mean_leading_zeros, 3.0);
        let s32 = analyze(&[1], 32);
        assert_eq!(s32.mean_leading_zeros, 31.0);
    }

    #[test]
    fn prefix_of_adjacent_values() {
        // 8=1000, 9=1001 share 3 bits; 9,10=1010 share 2 bits (width 4).
        let s = analyze(&[8, 9, 10], 4);
        assert!((s.mean_sorted_prefix - 2.5).abs() < 1e-9);
    }

    #[test]
    fn mapreduce_beats_uniform_on_both_axes() {
        let u = Dataset::generate32(DatasetKind::Uniform, 1024, 1);
        let m = Dataset::generate32(DatasetKind::MapReduce, 1024, 1);
        let su = analyze(&u.values, 32);
        let sm = analyze(&m.values, 32);
        assert!(sm.mean_leading_zeros > su.mean_leading_zeros + 8.0);
        assert!(sm.mean_sorted_prefix > su.mean_sorted_prefix + 8.0);
        assert!(sm.unique_fraction < su.unique_fraction);
    }

    #[test]
    fn clustered_has_more_leading_zeros_than_normal() {
        let c = Dataset::generate32(DatasetKind::Clustered, 1024, 2);
        let n = Dataset::generate32(DatasetKind::Normal, 1024, 2);
        let sc = analyze(&c.values, 32);
        let sn = analyze(&n.values, 32);
        assert!(sc.mean_leading_zeros > sn.mean_leading_zeros);
    }
}

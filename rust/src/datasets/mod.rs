//! Sorting-workload generators used in the paper's evaluation (§V).
//!
//! Statistical datasets (exact parameters from the paper):
//! * **Uniform** — u32 over `[0, 2^32 - 1]`.
//! * **Normal** — mean `2^31`, σ = `2^31 / 3`, clamped to u32.
//! * **Clustered** — two clusters centered at `2^15` and `2^25`, both with
//!   σ = `2^13`, 50/50 mixture.
//!
//! Application datasets (paper §II.A — generated, see `DESIGN.md` for the
//! substitution rationale):
//! * **Kruskal** — edge weights of a random graph as consumed by
//!   Kruskal's MST: majority small values with frequent repetitions.
//! * **MapReduce** — shuffle keys clustered in a few groups with heavy
//!   repetition, as between map and reduce stages.

pub mod kruskal;
pub mod mapreduce;
pub mod rng;
pub mod stats;

use rng::Rng;

/// The five dataset families of the paper's evaluation.
#[derive(Copy, Clone, PartialEq, Eq, Debug, Hash)]
pub enum DatasetKind {
    Uniform,
    Normal,
    Clustered,
    Kruskal,
    MapReduce,
}

impl DatasetKind {
    /// All five families, in the paper's presentation order (Fig. 6).
    pub const ALL: [DatasetKind; 5] = [
        DatasetKind::Uniform,
        DatasetKind::Normal,
        DatasetKind::Clustered,
        DatasetKind::Kruskal,
        DatasetKind::MapReduce,
    ];

    /// Display name as used in figure labels.
    pub fn name(self) -> &'static str {
        match self {
            DatasetKind::Uniform => "uniform",
            DatasetKind::Normal => "normal",
            DatasetKind::Clustered => "clustered",
            DatasetKind::Kruskal => "kruskal",
            DatasetKind::MapReduce => "mapreduce",
        }
    }

    /// Parse from a CLI name.
    pub fn parse(s: &str) -> Option<Self> {
        Self::ALL.iter().copied().find(|k| k.name() == s)
    }
}

/// A generated workload: the values plus provenance.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub seed: u64,
    pub values: Vec<u32>,
}

impl Dataset {
    /// Generate `n` values of `kind` from `seed`, for `width`-bit sorters.
    ///
    /// Values are guaranteed to fit in `width` bits (the statistical
    /// families are defined for width 32; for narrower widths they are
    /// right-shifted into range so the *shape* — leading-zero profile,
    /// repetition profile — is preserved).
    pub fn generate(kind: DatasetKind, n: usize, width: u32, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ (kind as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let raw: Vec<u32> = match kind {
            DatasetKind::Uniform => (0..n).map(|_| rng.next_u32()).collect(),
            DatasetKind::Normal => {
                let mean = 2f64.powi(31);
                let std = 2f64.powi(31) / 3.0;
                (0..n).map(|_| clamp_u32(mean + std * rng.normal())).collect()
            }
            DatasetKind::Clustered => {
                let std = 2f64.powi(13);
                (0..n)
                    .map(|_| {
                        let center = if rng.f64() < 0.5 { 2f64.powi(15) } else { 2f64.powi(25) };
                        clamp_u32(center + std * rng.normal())
                    })
                    .collect()
            }
            DatasetKind::Kruskal => kruskal::edge_weights(n, &mut rng),
            DatasetKind::MapReduce => mapreduce::shuffle_keys(n, &mut rng),
        };
        let shift = 32 - width;
        let values = if shift == 0 { raw } else { raw.iter().map(|&v| v >> shift).collect() };
        Dataset { kind, seed, values }
    }

    /// Generate with the paper's default width (32 bits).
    pub fn generate32(kind: DatasetKind, n: usize, seed: u64) -> Dataset {
        Self::generate(kind, n, 32, seed)
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

#[inline]
fn clamp_u32(x: f64) -> u32 {
    if x <= 0.0 {
        0
    } else if x >= u32::MAX as f64 {
        u32::MAX
    } else {
        x as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_is_deterministic_per_seed_and_kind() {
        for kind in DatasetKind::ALL {
            let a = Dataset::generate32(kind, 256, 7);
            let b = Dataset::generate32(kind, 256, 7);
            assert_eq!(a.values, b.values, "{kind:?}");
            let c = Dataset::generate32(kind, 256, 8);
            assert_ne!(a.values, c.values, "{kind:?} should vary with seed");
        }
    }

    #[test]
    fn kinds_have_distinct_streams_for_same_seed() {
        let u = Dataset::generate32(DatasetKind::Uniform, 64, 1);
        let n = Dataset::generate32(DatasetKind::Normal, 64, 1);
        assert_ne!(u.values, n.values);
    }

    #[test]
    fn normal_params_match_paper() {
        let d = Dataset::generate32(DatasetKind::Normal, 100_000, 3);
        let mean: f64 = d.values.iter().map(|&v| v as f64).sum::<f64>() / d.len() as f64;
        let target = 2f64.powi(31);
        // mean within 1% of 2^31
        assert!((mean - target).abs() / target < 0.01, "mean {mean:.3e}");
        let var: f64 =
            d.values.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / d.len() as f64;
        let std = var.sqrt();
        let target_std = target / 3.0;
        assert!((std - target_std).abs() / target_std < 0.02, "std {std:.3e}");
    }

    #[test]
    fn clustered_params_match_paper() {
        let d = Dataset::generate32(DatasetKind::Clustered, 50_000, 3);
        let lo = d.values.iter().filter(|&&v| v < 1 << 20).count();
        let hi = d.len() - lo;
        // 50/50 mixture, +-5%
        assert!((lo as f64 / d.len() as f64 - 0.5).abs() < 0.05, "lo fraction {lo}");
        assert!(hi > 0);
        // low cluster concentrated near 2^15 (σ=2^13 ⇒ nearly all < 2^17)
        let near_lo =
            d.values.iter().filter(|&&v| v < 1 << 17).count() as f64 / lo as f64;
        assert!(near_lo > 0.99, "{near_lo}");
    }

    #[test]
    fn uniform_spans_high_bits() {
        let d = Dataset::generate32(DatasetKind::Uniform, 4096, 11);
        // MSB should be set on roughly half the values.
        let msb = d.values.iter().filter(|&&v| v >> 31 == 1).count() as f64 / 4096.0;
        assert!((msb - 0.5).abs() < 0.05, "{msb}");
    }

    #[test]
    fn narrow_width_fits() {
        for kind in DatasetKind::ALL {
            let d = Dataset::generate(kind, 128, 8, 5);
            assert!(d.values.iter().all(|&v| v < 256), "{kind:?}");
        }
    }

    #[test]
    fn application_datasets_have_repetitions_and_small_values() {
        // Duplicate density grows with n; probe at a realistic 4096.
        for kind in [DatasetKind::Kruskal, DatasetKind::MapReduce] {
            let d = Dataset::generate32(kind, 4096, 11);
            let mut uniq = d.values.clone();
            uniq.sort_unstable();
            uniq.dedup();
            assert!(
                uniq.len() < d.len() * 85 / 100,
                "{kind:?}: expected frequent repetitions, got {} unique of {}",
                uniq.len(),
                d.len()
            );
            // "majority of the elements are small": median far below 2^31.
            let mut s = d.values.clone();
            s.sort_unstable();
            assert!(s[d.len() / 2] < 1 << 26, "{kind:?} median {:#x}", s[d.len() / 2]);
        }
    }

    #[test]
    fn parse_roundtrip() {
        for kind in DatasetKind::ALL {
            assert_eq!(DatasetKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(DatasetKind::parse("nope"), None);
    }
}

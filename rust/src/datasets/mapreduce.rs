//! MapReduce shuffle workload: the key stream that must be sorted between
//! the map and reduce stages (paper §II.A, citing Dean & Ghemawat).
//!
//! "These maps are typically clustered in a few groups": think word-count
//! style jobs where the key space collapses onto a handful of hot groups
//! (partitions / hot keys) with a Zipfian popularity profile and heavy
//! exact repetition. Group centers are kept small (≤ 2^20) — hashed
//! partition ids / counter-like keys — which gives the long leading-zero
//! runs the column-skipping algorithm exploits (paper Fig. 6: MapReduce is
//! its best case, up to 4.16×).

use super::rng::Rng;

/// Tunables for the shuffle-key generator. `Default` reproduces the
/// profile used throughout the figure harnesses.
#[derive(Clone, Debug)]
pub struct MapReduceProfile {
    /// Number of hot key groups.
    pub groups: usize,
    /// Largest group center (exclusive). Small centers ⇒ leading zeros.
    pub center_max: u32,
    /// In-group spread (σ of a rounded normal around the center).
    pub spread: f64,
    /// Zipf exponent over group popularity.
    pub zipf_s: f64,
}

impl Default for MapReduceProfile {
    fn default() -> Self {
        // Tuned so the k=2 column-skipping speedup at N=1024 lands in the
        // paper's ~4× regime (Fig. 6 / Fig. 8a): a few hot groups, small
        // centers (long leading-zero runs), moderate exact repetition.
        MapReduceProfile { groups: 8, center_max: 1 << 20, spread: 1100.0, zipf_s: 1.1 }
    }
}

/// Generate `n` shuffle keys with the default profile.
pub fn shuffle_keys(n: usize, rng: &mut Rng) -> Vec<u32> {
    shuffle_keys_with(n, &MapReduceProfile::default(), rng)
}

/// Generate `n` shuffle keys from an explicit profile.
pub fn shuffle_keys_with(n: usize, p: &MapReduceProfile, rng: &mut Rng) -> Vec<u32> {
    assert!(p.groups >= 1);
    // Group centers: stratified log-uniform small values (stratification
    // keeps the per-seed key entropy stable, so figure trials have low
    // variance while centers still differ across seeds).
    let hi = (p.center_max as f64).ln();
    let lo = 256f64.ln();
    let centers: Vec<u32> = (0..p.groups)
        .map(|g| {
            let u = (g as f64 + rng.f64()) / p.groups as f64;
            (lo + u * (hi - lo)).exp() as u32
        })
        .collect();
    // Zipf CDF over groups.
    let weights: Vec<f64> = (1..=p.groups).map(|r| 1.0 / (r as f64).powf(p.zipf_s)).collect();
    let total: f64 = weights.iter().sum();
    let mut cdf = Vec::with_capacity(p.groups);
    let mut acc = 0.0;
    for w in &weights {
        acc += w / total;
        cdf.push(acc);
    }
    (0..n)
        .map(|_| {
            let u = rng.f64();
            let g = cdf.iter().position(|&c| u <= c).unwrap_or(p.groups - 1);
            let c = centers[g] as f64;
            let v = c + p.spread * rng.normal();
            // Quantize within the group so exact repetitions are frequent,
            // as repeated keys are in a real shuffle.
            let q = 8.0;
            let v = (v / q).round() * q;
            if v <= 0.0 {
                0
            } else if v >= u32::MAX as f64 {
                u32::MAX
            } else {
                v as u32
            }
        })
        .collect()
}

/// A (key, value-size) record stream for the `mapreduce_shuffle` example.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Record {
    pub key: u32,
    pub payload_len: u32,
}

/// Generate a record stream whose keys follow the shuffle profile.
pub fn record_stream(n: usize, p: &MapReduceProfile, rng: &mut Rng) -> Vec<Record> {
    shuffle_keys_with(n, p, rng)
        .into_iter()
        .map(|key| Record { key, payload_len: 64 + rng.below(192) as u32 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_are_clustered_in_few_groups() {
        let mut rng = Rng::new(11);
        let p = MapReduceProfile::default();
        let keys = shuffle_keys_with(4096, &p, &mut rng);
        // Nearly all keys within spread*6 of one of at most `groups` centers:
        // verify by clustering keys greedily with a wide tolerance.
        let mut centers: Vec<u32> = Vec::new();
        let tol = (p.spread * 8.0) as i64;
        let mut outliers = 0;
        for &k in &keys {
            if !centers.iter().any(|&c| (k as i64 - c as i64).abs() <= tol) {
                if centers.len() < p.groups {
                    centers.push(k);
                } else {
                    outliers += 1;
                }
            }
        }
        assert!(outliers < keys.len() / 50, "outliers={outliers}");
    }

    #[test]
    fn keys_have_heavy_repetition() {
        let mut rng = Rng::new(12);
        let keys = shuffle_keys(2048, &mut rng);
        let mut uniq = keys.clone();
        uniq.sort_unstable();
        uniq.dedup();
        // Clustered keys repeat heavily (>35% duplicates at this n).
        assert!(
            uniq.len() < keys.len() * 65 / 100,
            "unique={} of {}",
            uniq.len(),
            keys.len()
        );
    }

    #[test]
    fn keys_are_small_numbers() {
        let mut rng = Rng::new(13);
        let keys = shuffle_keys(2048, &mut rng);
        // center_max = 2^20, spread tiny ⇒ everything below 2^21.
        assert!(keys.iter().all(|&k| k < 1 << 21));
    }

    #[test]
    fn profile_is_tunable() {
        let mut rng = Rng::new(14);
        let p = MapReduceProfile { groups: 2, center_max: 1 << 10, ..Default::default() };
        let keys = shuffle_keys_with(1024, &p, &mut rng);
        assert!(keys.iter().all(|&k| k < 1 << 12));
    }

    #[test]
    fn record_stream_shapes() {
        let mut rng = Rng::new(15);
        let recs = record_stream(100, &MapReduceProfile::default(), &mut rng);
        assert_eq!(recs.len(), 100);
        assert!(recs.iter().all(|r| (64..256).contains(&r.payload_len)));
    }
}

//! Minimal property-testing harness (the vendored registry has no
//! `proptest`, so we carry our own): seeded random case generation with
//! automatic shrinking of failing `Vec<u32>` inputs.
//!
//! Used by `rust/tests/proptests.rs` to check the coordinator/sorter
//! invariants the paper relies on (output sortedness, permutation
//! property, cycle-count bounds, multi-bank equivalence), and by
//! `rust/tests/concurrency.rs` via the deterministic multi-client
//! driver ([`run_interleaved`]) for the concurrent request plane.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::frontend::JobTag;
use crate::coordinator::shard_server::ShardServer;
use crate::coordinator::wire::{duplex, read_frame, write_frame, Frame};
use crate::coordinator::SortResponse;
use crate::datasets::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (each case derives its own stream).
    pub seed: u64,
    /// Max length of generated vectors.
    pub max_len: usize,
    /// Max bit width of generated values.
    pub max_width: u32,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 256, seed: 0xC0FFEE, max_len: 200, max_width: 32 }
    }
}

/// A generated case: values plus the width they fit in.
#[derive(Clone, Debug)]
pub struct Case {
    pub values: Vec<u32>,
    pub width: u32,
}

/// Generate a random case biased toward sorter-hostile shapes: small
/// widths, duplicates, runs, extremes.
pub fn gen_case(rng: &mut Rng, cfg: &PropConfig) -> Case {
    let width = 1 + rng.below(cfg.max_width as u64) as u32;
    let len = rng.below(cfg.max_len as u64 + 1) as usize;
    let max_val = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
    let mode = rng.below(5);
    let values: Vec<u32> = (0..len)
        .map(|i| match mode {
            // Uniform over the full width.
            0 => (rng.next_u64() & max_val as u64) as u32,
            // Heavy duplicates from a tiny pool.
            1 => {
                let pool = 1 + rng.below(4) as u32;
                (rng.below(pool as u64 + 1) as u32).min(max_val)
            }
            // Small values (leading zeros).
            2 => (rng.below(16.min(max_val as u64 + 1)) as u32).min(max_val),
            // Sorted / reverse runs.
            3 => (i as u32).min(max_val),
            _ => (max_val).saturating_sub(i as u32),
        })
        .collect();
    Case { values, width }
}

/// Run `prop` over random cases; on failure, shrink the input and panic
/// with the minimal reproduction.
pub fn check(name: &str, cfg: PropConfig, prop: impl Fn(&Case) -> Result<(), String>) {
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case = gen_case(&mut rng, &cfg);
        if let Err(msg) = prop(&case) {
            let minimal = shrink(&case, &prop);
            panic!(
                "property `{name}` failed (case {case_idx}): {msg}\n\
                 minimal repro: width={} values={:?}",
                minimal.width, minimal.values
            );
        }
    }
}

/// Greedy shrink: try removing chunks, then halving values.
fn shrink(case: &Case, prop: &impl Fn(&Case) -> Result<(), String>) -> Case {
    let mut cur = case.clone();
    // Remove chunks while the property still fails.
    let mut chunk = (cur.values.len() / 2).max(1);
    while chunk >= 1 && !cur.values.is_empty() {
        let mut i = 0;
        let mut progressed = false;
        while i < cur.values.len() {
            let mut cand = cur.clone();
            let hi = (i + chunk).min(cand.values.len());
            cand.values.drain(i..hi);
            if prop(&cand).is_err() {
                cur = cand;
                progressed = true;
            } else {
                i += chunk;
            }
        }
        if chunk == 1 && !progressed {
            break;
        }
        chunk = (chunk / 2).max(1);
        if chunk == 1 && !progressed && cur.values.len() <= 1 {
            break;
        }
    }
    // Shrink individual values toward zero.
    loop {
        let mut progressed = false;
        for i in 0..cur.values.len() {
            while cur.values[i] > 0 {
                let mut cand = cur.clone();
                cand.values[i] /= 2;
                if prop(&cand).is_err() {
                    cur = cand;
                    progressed = true;
                } else {
                    break;
                }
            }
        }
        if !progressed {
            break;
        }
    }
    cur
}

/// One client's scripted workload for [`run_interleaved`]: the jobs it
/// submits, in order, and the tag they travel under (`None` sends plain
/// v1 `SortJob` frames; `Some` sends tagged v2 frames).
#[derive(Clone, Debug)]
pub struct ClientScript {
    pub tag: Option<JobTag>,
    pub jobs: Vec<Vec<u32>>,
}

/// Drive `K` concurrent clients against one [`ShardServer`] over
/// in-memory duplex connections with a **seeded** interleaving, and
/// return each client's replies in its own submission order.
///
/// Determinism without sleeps: a single scheduler thread owns every
/// client handle and repeatedly asks the seeded [`Rng`] which client
/// acts next and whether it *sends* its next job or *collects* one
/// outstanding reply (collecting blocks on the duplex until the
/// server's collector thread writes the reply — a rendezvous, not a
/// timing guess). Replies are keyed by correlation id, so the per-job
/// association is exact even when the shared worker pool completes
/// jobs out of submission order. Every schedule for a given seed sends
/// the same frames in the same global order; the only nondeterminism
/// left is the server's internal completion order, which the
/// correlation ids make invisible to the caller.
///
/// Sessions end as plain disconnects (the host stays up), so callers
/// can inspect the server afterwards or run another wave.
pub fn run_interleaved(
    server: &Arc<ShardServer>,
    clients: &[ClientScript],
    seed: u64,
) -> Result<Vec<Vec<SortResponse>>> {
    let mut rng = Rng::new(seed);
    // Dial every client over its own duplex; each connection is served
    // by its own session thread against the shared host.
    let mut conns = Vec::new();
    let mut sessions = Vec::new();
    for (ci, _) in clients.iter().enumerate() {
        let ((mut r, mut w), (sr, sw)) = duplex();
        let srv = Arc::clone(server);
        sessions.push(std::thread::spawn(move || srv.serve_conn(sr, sw)));
        write_frame(w.as_mut(), 0, &Frame::Hello)?;
        let (_, frame) = read_frame(r.as_mut())?;
        anyhow::ensure!(
            matches!(frame, Frame::HelloAck(_)),
            "client {ci}: handshake answered {frame:?}"
        );
        conns.push((r, w));
    }
    let mut sent = vec![0usize; clients.len()];
    let mut collected = vec![0usize; clients.len()];
    let mut stash: Vec<HashMap<u64, SortResponse>> =
        clients.iter().map(|_| HashMap::new()).collect();
    loop {
        // Legal moves this step: any client with jobs left to send, any
        // client with more sent than collected.
        let mut moves: Vec<(usize, bool)> = Vec::new();
        for ci in 0..clients.len() {
            if sent[ci] < clients[ci].jobs.len() {
                moves.push((ci, true));
            }
            if collected[ci] < sent[ci] {
                moves.push((ci, false));
            }
        }
        let Some(&(ci, send)) = moves.get(rng.below(moves.len().max(1) as u64) as usize)
        else {
            break; // everything sent and collected
        };
        if send {
            let id = sent[ci] as u64 + 1; // 0 was the Hello
            let data = clients[ci].jobs[sent[ci]].clone();
            let frame = match &clients[ci].tag {
                Some(tag) => Frame::SortJobTagged(tag.clone(), data),
                None => Frame::SortJob(data),
            };
            write_frame(conns[ci].1.as_mut(), id, &frame)?;
            sent[ci] += 1;
        } else {
            let (id, frame) = read_frame(conns[ci].0.as_mut())?;
            let Frame::SortOk(resp) = frame else {
                anyhow::bail!("client {ci}, reply {id}: expected SortOk, got {frame:?}")
            };
            stash[ci].insert(id, resp);
            collected[ci] += 1;
        }
    }
    drop(conns); // EOF on every duplex: sessions end as plain disconnects
    for (ci, session) in sessions.into_iter().enumerate() {
        let outcome = session.join().expect("session thread panicked");
        anyhow::ensure!(
            matches!(outcome, Ok(false)),
            "client {ci}: session ended {outcome:?}, expected a plain disconnect"
        );
    }
    Ok(stash
        .into_iter()
        .map(|m| {
            let mut replies: Vec<(u64, SortResponse)> = m.into_iter().collect();
            replies.sort_by_key(|&(id, _)| id);
            replies.into_iter().map(|(_, resp)| resp).collect()
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("tautology", PropConfig { cases: 50, ..Default::default() }, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property `fails-on-nonempty` failed")]
    fn failing_property_panics_with_repro() {
        check(
            "fails-on-nonempty",
            PropConfig { cases: 50, ..Default::default() },
            |c| {
                if c.values.len() > 3 {
                    Err("too long".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn shrink_finds_small_repro() {
        // Property fails iff any value >= 8: minimal repro is one value 8.
        let prop = |c: &Case| -> Result<(), String> {
            if c.values.iter().any(|&v| v >= 8) {
                Err("has big value".into())
            } else {
                Ok(())
            }
        };
        let case = Case { values: vec![3, 100, 5, 64, 9], width: 8 };
        let min = shrink(&case, &prop);
        assert_eq!(min.values.len(), 1, "{min:?}");
        assert!(min.values[0] >= 8 && min.values[0] <= 12, "{min:?}");
    }

    #[test]
    fn interleaved_clients_get_their_own_replies_back() {
        use crate::coordinator::frontend::Priority;
        use crate::coordinator::ServiceConfig;
        let server = Arc::new(
            ShardServer::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap(),
        );
        let scripts = vec![
            ClientScript { tag: None, jobs: vec![vec![3, 1, 2], vec![9, 7]] },
            ClientScript {
                tag: Some(JobTag::new("acme", Priority::Interactive)),
                jobs: vec![vec![5, 5, 0]],
            },
        ];
        let replies = run_interleaved(&server, &scripts, 42).unwrap();
        assert_eq!(replies[0][0].sorted, vec![1, 2, 3]);
        assert_eq!(replies[0][1].sorted, vec![7, 9]);
        assert_eq!(replies[1][0].sorted, vec![0, 5, 5]);
        assert_eq!(server.host().metrics().completed, 3, "one shared host served all");
        server.host().shutdown();
    }

    #[test]
    fn gen_case_respects_width() {
        let mut rng = Rng::new(1);
        let cfg = PropConfig::default();
        for _ in 0..200 {
            let c = gen_case(&mut rng, &cfg);
            if c.width < 32 {
                assert!(c.values.iter().all(|&v| v < (1 << c.width)));
            }
        }
    }
}

//! Minimal property-testing harness (the vendored registry has no
//! `proptest`, so we carry our own): seeded random case generation with
//! automatic shrinking of failing `Vec<u32>` inputs.
//!
//! Used by `rust/tests/proptests.rs` to check the coordinator/sorter
//! invariants the paper relies on (output sortedness, permutation
//! property, cycle-count bounds, multi-bank equivalence).

use crate::datasets::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct PropConfig {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed (each case derives its own stream).
    pub seed: u64,
    /// Max length of generated vectors.
    pub max_len: usize,
    /// Max bit width of generated values.
    pub max_width: u32,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 256, seed: 0xC0FFEE, max_len: 200, max_width: 32 }
    }
}

/// A generated case: values plus the width they fit in.
#[derive(Clone, Debug)]
pub struct Case {
    pub values: Vec<u32>,
    pub width: u32,
}

/// Generate a random case biased toward sorter-hostile shapes: small
/// widths, duplicates, runs, extremes.
pub fn gen_case(rng: &mut Rng, cfg: &PropConfig) -> Case {
    let width = 1 + rng.below(cfg.max_width as u64) as u32;
    let len = rng.below(cfg.max_len as u64 + 1) as usize;
    let max_val = if width == 32 { u32::MAX } else { (1u32 << width) - 1 };
    let mode = rng.below(5);
    let values: Vec<u32> = (0..len)
        .map(|i| match mode {
            // Uniform over the full width.
            0 => (rng.next_u64() & max_val as u64) as u32,
            // Heavy duplicates from a tiny pool.
            1 => {
                let pool = 1 + rng.below(4) as u32;
                (rng.below(pool as u64 + 1) as u32).min(max_val)
            }
            // Small values (leading zeros).
            2 => (rng.below(16.min(max_val as u64 + 1)) as u32).min(max_val),
            // Sorted / reverse runs.
            3 => (i as u32).min(max_val),
            _ => (max_val).saturating_sub(i as u32),
        })
        .collect();
    Case { values, width }
}

/// Run `prop` over random cases; on failure, shrink the input and panic
/// with the minimal reproduction.
pub fn check(name: &str, cfg: PropConfig, prop: impl Fn(&Case) -> Result<(), String>) {
    let mut rng = Rng::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case = gen_case(&mut rng, &cfg);
        if let Err(msg) = prop(&case) {
            let minimal = shrink(&case, &prop);
            panic!(
                "property `{name}` failed (case {case_idx}): {msg}\n\
                 minimal repro: width={} values={:?}",
                minimal.width, minimal.values
            );
        }
    }
}

/// Greedy shrink: try removing chunks, then halving values.
fn shrink(case: &Case, prop: &impl Fn(&Case) -> Result<(), String>) -> Case {
    let mut cur = case.clone();
    // Remove chunks while the property still fails.
    let mut chunk = (cur.values.len() / 2).max(1);
    while chunk >= 1 && !cur.values.is_empty() {
        let mut i = 0;
        let mut progressed = false;
        while i < cur.values.len() {
            let mut cand = cur.clone();
            let hi = (i + chunk).min(cand.values.len());
            cand.values.drain(i..hi);
            if prop(&cand).is_err() {
                cur = cand;
                progressed = true;
            } else {
                i += chunk;
            }
        }
        if chunk == 1 && !progressed {
            break;
        }
        chunk = (chunk / 2).max(1);
        if chunk == 1 && !progressed && cur.values.len() <= 1 {
            break;
        }
    }
    // Shrink individual values toward zero.
    loop {
        let mut progressed = false;
        for i in 0..cur.values.len() {
            while cur.values[i] > 0 {
                let mut cand = cur.clone();
                cand.values[i] /= 2;
                if prop(&cand).is_err() {
                    cur = cand;
                    progressed = true;
                } else {
                    break;
                }
            }
        }
        if !progressed {
            break;
        }
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("tautology", PropConfig { cases: 50, ..Default::default() }, |_| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property `fails-on-nonempty` failed")]
    fn failing_property_panics_with_repro() {
        check(
            "fails-on-nonempty",
            PropConfig { cases: 50, ..Default::default() },
            |c| {
                if c.values.len() > 3 {
                    Err("too long".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn shrink_finds_small_repro() {
        // Property fails iff any value >= 8: minimal repro is one value 8.
        let prop = |c: &Case| -> Result<(), String> {
            if c.values.iter().any(|&v| v >= 8) {
                Err("has big value".into())
            } else {
                Ok(())
            }
        };
        let case = Case { values: vec![3, 100, 5, 64, 9], width: 8 };
        let min = shrink(&case, &prop);
        assert_eq!(min.values.len(), 1, "{min:?}");
        assert!(min.values[0] >= 8 && min.values[0] <= 12, "{min:?}");
    }

    #[test]
    fn gen_case_respects_width() {
        let mut rng = Rng::new(1);
        let cfg = PropConfig::default();
        for _ in 0..200 {
            let c = gen_case(&mut rng, &cfg);
            if c.width < 32 {
                assert!(c.values.iter().all(|&v| v < (1 << c.width)));
            }
        }
    }
}

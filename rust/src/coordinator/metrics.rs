//! Service metrics: latency percentiles, throughput, aggregate simulator
//! stats. Lock-free counters where possible; the latency reservoir is a
//! mutex-guarded ring (sampling beyond the cap).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sorter::SortStats;

const RESERVOIR_CAP: usize = 4096;

/// Aggregated service metrics.
pub struct ServiceMetrics {
    completed: AtomicU64,
    errors: AtomicU64,
    elements: AtomicU64,
    sim_cycles: AtomicU64,
    sim_crs: AtomicU64,
    hier_completed: AtomicU64,
    hier_elements: AtomicU64,
    hier_chunks: AtomicU64,
    merge_cycles: AtomicU64,
    merge_comparisons: AtomicU64,
    latencies_us: Mutex<Vec<u64>>,
}

/// Point-in-time view.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub completed: u64,
    pub errors: u64,
    pub elements: u64,
    /// Total simulated near-memory cycles across requests.
    pub sim_cycles: u64,
    /// Total simulated column reads.
    pub sim_crs: u64,
    /// Hierarchical (out-of-bank) sorts completed.
    pub hier_completed: u64,
    /// Elements that went through the hierarchical pipeline.
    pub hier_elements: u64,
    /// Bank-sized chunks sorted on behalf of hierarchical requests.
    pub hier_chunks: u64,
    /// Modelled merge-network cycles spent by the hierarchical pipeline.
    pub merge_cycles: u64,
    /// Comparator operations performed by the loser-tree merge stage.
    pub merge_comparisons: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Mean simulated cycles per element (the paper's speed metric,
    /// aggregated over served traffic).
    pub cycles_per_number: f64,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        ServiceMetrics {
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            elements: AtomicU64::new(0),
            sim_cycles: AtomicU64::new(0),
            sim_crs: AtomicU64::new(0),
            hier_completed: AtomicU64::new(0),
            hier_elements: AtomicU64::new(0),
            hier_chunks: AtomicU64::new(0),
            merge_cycles: AtomicU64::new(0),
            merge_comparisons: AtomicU64::new(0),
            latencies_us: Mutex::new(Vec::with_capacity(RESERVOIR_CAP)),
        }
    }

    /// Record a completed request.
    pub fn record(&self, latency_us: u64, stats: &SortStats, n: usize) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(n as u64, Ordering::Relaxed);
        self.sim_cycles.fetch_add(stats.cycles(), Ordering::Relaxed);
        self.sim_crs.fetch_add(stats.crs, Ordering::Relaxed);
        let mut lat = self.latencies_us.lock().expect("metrics poisoned");
        if lat.len() < RESERVOIR_CAP {
            lat.push(latency_us);
        } else {
            // Simple overwrite sampling keeps the reservoir fresh.
            let idx = (latency_us as usize ^ lat.len()) % RESERVOIR_CAP;
            lat[idx] = latency_us;
        }
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a completed hierarchical (chunk → sort → merge) request.
    /// The per-chunk simulator work was already recorded by the workers;
    /// this adds the pipeline-level view.
    pub fn record_hierarchical(
        &self,
        elements: usize,
        chunks: usize,
        merge_cycles: u64,
        merge_comparisons: u64,
    ) {
        self.hier_completed.fetch_add(1, Ordering::Relaxed);
        self.hier_elements.fetch_add(elements as u64, Ordering::Relaxed);
        self.hier_chunks.fetch_add(chunks as u64, Ordering::Relaxed);
        self.merge_cycles.fetch_add(merge_cycles, Ordering::Relaxed);
        self.merge_comparisons.fetch_add(merge_comparisons, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut lat = self.latencies_us.lock().expect("metrics poisoned").clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p) as usize]
            }
        };
        let elements = self.elements.load(Ordering::Relaxed);
        let cycles = self.sim_cycles.load(Ordering::Relaxed);
        Snapshot {
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            elements,
            sim_cycles: cycles,
            sim_crs: self.sim_crs.load(Ordering::Relaxed),
            hier_completed: self.hier_completed.load(Ordering::Relaxed),
            hier_elements: self.hier_elements.load(Ordering::Relaxed),
            hier_chunks: self.hier_chunks.load(Ordering::Relaxed),
            merge_cycles: self.merge_cycles.load(Ordering::Relaxed),
            merge_comparisons: self.merge_comparisons.load(Ordering::Relaxed),
            p50_us: pct(0.50),
            p99_us: pct(0.99),
            max_us: lat.last().copied().unwrap_or(0),
            cycles_per_number: if elements == 0 {
                0.0
            } else {
                cycles as f64 / elements as f64
            },
        }
    }
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(cycles: u64) -> SortStats {
        SortStats { crs: cycles, ..Default::default() }
    }

    #[test]
    fn snapshot_percentiles() {
        let m = ServiceMetrics::new();
        for i in 1..=100 {
            m.record(i, &stats(10), 5);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.elements, 500);
        assert_eq!(s.sim_cycles, 1000);
        assert_eq!(s.max_us, 100);
        assert!((49..=51).contains(&s.p50_us), "{}", s.p50_us);
        assert!(s.p99_us >= 98);
        assert!((s.cycles_per_number - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServiceMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.cycles_per_number, 0.0);
    }

    #[test]
    fn errors_counted() {
        let m = ServiceMetrics::new();
        m.record_error();
        m.record_error();
        assert_eq!(m.snapshot().errors, 2);
    }

    #[test]
    fn hierarchical_counters_accumulate() {
        let m = ServiceMetrics::new();
        m.record_hierarchical(5000, 5, 10_000, 60_000);
        m.record_hierarchical(2000, 2, 2_000, 20_000);
        let s = m.snapshot();
        assert_eq!(s.hier_completed, 2);
        assert_eq!(s.hier_elements, 7000);
        assert_eq!(s.hier_chunks, 7);
        assert_eq!(s.merge_cycles, 12_000);
        assert_eq!(s.merge_comparisons, 80_000);
    }

    #[test]
    fn reservoir_caps_memory() {
        let m = ServiceMetrics::new();
        for i in 0..(RESERVOIR_CAP as u64 + 1000) {
            m.record(i, &stats(1), 1);
        }
        assert_eq!(m.snapshot().completed, RESERVOIR_CAP as u64 + 1000);
        assert!(m.latencies_us.lock().unwrap().len() <= RESERVOIR_CAP);
    }
}

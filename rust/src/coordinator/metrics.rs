//! Service metrics: latency percentiles, throughput, aggregate simulator
//! stats. Lock-free counters where possible; the latency reservoir is a
//! mutex-guarded ring (sampling beyond the cap).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::sorter::SortStats;

const RESERVOIR_CAP: usize = 4096;

/// Log2 size-class buckets for per-class cycle accounting: class `i`
/// aggregates requests with `floor(log2(n)) == i` (n = 0 and n = 1
/// share class 0). Bank-sized chunk requests land in the class of
/// their bank, which is what the chunk-size auto-tuner reads.
const SIZE_CLASSES: usize = 64;

/// The size class a request of `n` elements belongs to. Shared with the
/// shard router's size-class-affinity policy
/// ([`super::shard::RoutePolicy::SizeClass`]).
pub(crate) fn size_class(n: usize) -> usize {
    (n.max(1).ilog2() as usize).min(SIZE_CLASSES - 1)
}

/// The latency reservoir: a ring over the last [`RESERVOIR_CAP`]
/// samples, advanced by a monotone insertion counter so every record
/// lands in a fresh slot regardless of its value. (The previous scheme
/// hashed `latency_us` into the slot index, so constant-latency
/// traffic rewrote a single slot forever — neither uniform nor fresh.)
struct Reservoir {
    samples: Vec<u64>,
    /// Total records ever seen (not capped).
    seen: u64,
}

/// Aggregated service metrics.
pub struct ServiceMetrics {
    completed: AtomicU64,
    errors: AtomicU64,
    elements: AtomicU64,
    sim_cycles: AtomicU64,
    sim_crs: AtomicU64,
    hier_completed: AtomicU64,
    hier_elements: AtomicU64,
    hier_chunks: AtomicU64,
    merge_cycles: AtomicU64,
    merge_comparisons: AtomicU64,
    /// Per-size-class simulated cycles / elements (see [`size_class`]).
    class_cycles: Vec<AtomicU64>,
    class_elements: Vec<AtomicU64>,
    latencies_us: Mutex<Reservoir>,
}

/// Point-in-time view.
#[derive(Clone, Debug, PartialEq)]
pub struct Snapshot {
    pub completed: u64,
    pub errors: u64,
    pub elements: u64,
    /// Total simulated near-memory cycles across requests.
    pub sim_cycles: u64,
    /// Total simulated column reads.
    pub sim_crs: u64,
    /// Hierarchical (out-of-bank) sorts completed.
    pub hier_completed: u64,
    /// Elements that went through the hierarchical pipeline.
    pub hier_elements: u64,
    /// Bank-sized chunks sorted on behalf of hierarchical requests.
    pub hier_chunks: u64,
    /// Modelled merge-network cycles spent by the hierarchical pipeline.
    pub merge_cycles: u64,
    /// Comparator operations performed by the loser-tree merge stage.
    pub merge_comparisons: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    /// Mean simulated cycles per element (the paper's speed metric,
    /// aggregated over served traffic).
    pub cycles_per_number: f64,
    /// Mean simulated cycles per element, split by log2 request-size
    /// class (0.0 for classes with no traffic). Indexed by
    /// `floor(log2(n))`; feeds the chunk-size auto-tuner.
    pub class_cyc_per_num: Vec<f64>,
    /// Elements served per size class (same indexing). Lets the fleet
    /// aggregator ([`super::shard::FleetSnapshot`]) weight per-shard
    /// class costs correctly instead of averaging ratios.
    pub class_elements: Vec<u64>,
}

impl Snapshot {
    /// The all-zero snapshot a dead or unreachable host reports — what
    /// [`super::transport::LocalTransport`] returns after shutdown and
    /// what a `RemoteTransport` reports for a dead link, so fleet
    /// aggregation never needs a special case for missing hosts.
    pub fn empty() -> Self {
        ServiceMetrics::new().snapshot()
    }

    /// Observed cycles/number for requests in `n`'s size class,
    /// falling back to the global average over all served traffic,
    /// then to `fallback` (e.g. the paper's nominal
    /// [`crate::params::NOMINAL_COLSKIP_CYC_PER_NUM`]) when the
    /// service has seen nothing yet.
    pub fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        let class = self.class_cyc_per_num[size_class(n)];
        if class > 0.0 {
            class
        } else if self.cycles_per_number > 0.0 {
            self.cycles_per_number
        } else {
            fallback
        }
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        ServiceMetrics {
            completed: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            elements: AtomicU64::new(0),
            sim_cycles: AtomicU64::new(0),
            sim_crs: AtomicU64::new(0),
            hier_completed: AtomicU64::new(0),
            hier_elements: AtomicU64::new(0),
            hier_chunks: AtomicU64::new(0),
            merge_cycles: AtomicU64::new(0),
            merge_comparisons: AtomicU64::new(0),
            class_cycles: (0..SIZE_CLASSES).map(|_| AtomicU64::new(0)).collect(),
            class_elements: (0..SIZE_CLASSES).map(|_| AtomicU64::new(0)).collect(),
            latencies_us: Mutex::new(Reservoir {
                samples: Vec::with_capacity(RESERVOIR_CAP),
                seen: 0,
            }),
        }
    }

    /// Record a completed request.
    pub fn record(&self, latency_us: u64, stats: &SortStats, n: usize) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.elements.fetch_add(n as u64, Ordering::Relaxed);
        self.sim_cycles.fetch_add(stats.cycles(), Ordering::Relaxed);
        self.sim_crs.fetch_add(stats.crs, Ordering::Relaxed);
        let class = size_class(n);
        self.class_cycles[class].fetch_add(stats.cycles(), Ordering::Relaxed);
        self.class_elements[class].fetch_add(n as u64, Ordering::Relaxed);
        let mut lat = self.latencies_us.lock().expect("metrics poisoned");
        if lat.samples.len() < RESERVOIR_CAP {
            lat.samples.push(latency_us);
        } else {
            // Ring overwrite on the monotone insertion counter: the
            // reservoir always holds the freshest RESERVOIR_CAP
            // samples, and the slot never depends on the value.
            let idx = (lat.seen % RESERVOIR_CAP as u64) as usize;
            lat.samples[idx] = latency_us;
        }
        lat.seen += 1;
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    /// Observed cycles/number for `n`'s size class without building a
    /// full [`Snapshot`] — plain atomic reads, no latency-reservoir
    /// lock. The cost-aware shard router calls this once per candidate
    /// shard per routing decision (hundreds of decisions per
    /// hierarchical fan-out), where cloning and sorting the reservoir
    /// would dominate the decision.
    /// Same fallback ladder as [`Snapshot::cyc_per_num_for`]: class
    /// observation, then the global average, then `fallback`.
    pub fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        // Gate every rung on a *positive ratio*, exactly like the
        // snapshot reader: a class (or service) whose recorded cycles
        // are zero — e.g. clamped malformed PJRT traces — must fall
        // through rather than report a free shard to the cost router.
        let class = size_class(n);
        let class_elems = self.class_elements[class].load(Ordering::Relaxed);
        if class_elems > 0 {
            let ratio =
                self.class_cycles[class].load(Ordering::Relaxed) as f64 / class_elems as f64;
            if ratio > 0.0 {
                return ratio;
            }
        }
        let elements = self.elements.load(Ordering::Relaxed);
        if elements > 0 {
            let global = self.sim_cycles.load(Ordering::Relaxed) as f64 / elements as f64;
            if global > 0.0 {
                return global;
            }
        }
        fallback
    }

    /// Record a completed hierarchical (chunk → sort → merge) request.
    /// The per-chunk simulator work was already recorded by the workers;
    /// this adds the pipeline-level view.
    pub fn record_hierarchical(
        &self,
        elements: usize,
        chunks: usize,
        merge_cycles: u64,
        merge_comparisons: u64,
    ) {
        self.hier_completed.fetch_add(1, Ordering::Relaxed);
        self.hier_elements.fetch_add(elements as u64, Ordering::Relaxed);
        self.hier_chunks.fetch_add(chunks as u64, Ordering::Relaxed);
        self.merge_cycles.fetch_add(merge_cycles, Ordering::Relaxed);
        self.merge_comparisons.fetch_add(merge_comparisons, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> Snapshot {
        let mut lat = self.latencies_us.lock().expect("metrics poisoned").samples.clone();
        lat.sort_unstable();
        let pct = |p: f64| -> u64 {
            if lat.is_empty() {
                0
            } else {
                lat[((lat.len() - 1) as f64 * p) as usize]
            }
        };
        let elements = self.elements.load(Ordering::Relaxed);
        let cycles = self.sim_cycles.load(Ordering::Relaxed);
        Snapshot {
            completed: self.completed.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            elements,
            sim_cycles: cycles,
            sim_crs: self.sim_crs.load(Ordering::Relaxed),
            hier_completed: self.hier_completed.load(Ordering::Relaxed),
            hier_elements: self.hier_elements.load(Ordering::Relaxed),
            hier_chunks: self.hier_chunks.load(Ordering::Relaxed),
            merge_cycles: self.merge_cycles.load(Ordering::Relaxed),
            merge_comparisons: self.merge_comparisons.load(Ordering::Relaxed),
            p50_us: pct(0.50),
            p99_us: pct(0.99),
            max_us: lat.last().copied().unwrap_or(0),
            cycles_per_number: if elements == 0 {
                0.0
            } else {
                cycles as f64 / elements as f64
            },
            class_cyc_per_num: self
                .class_cycles
                .iter()
                .zip(&self.class_elements)
                .map(|(c, e)| {
                    let e = e.load(Ordering::Relaxed);
                    if e == 0 { 0.0 } else { c.load(Ordering::Relaxed) as f64 / e as f64 }
                })
                .collect(),
            class_elements: self
                .class_elements
                .iter()
                .map(|e| e.load(Ordering::Relaxed))
                .collect(),
        }
    }
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(cycles: u64) -> SortStats {
        SortStats { crs: cycles, ..Default::default() }
    }

    #[test]
    fn snapshot_percentiles() {
        let m = ServiceMetrics::new();
        for i in 1..=100 {
            m.record(i, &stats(10), 5);
        }
        let s = m.snapshot();
        assert_eq!(s.completed, 100);
        assert_eq!(s.elements, 500);
        assert_eq!(s.sim_cycles, 1000);
        assert_eq!(s.max_us, 100);
        assert!((49..=51).contains(&s.p50_us), "{}", s.p50_us);
        assert!(s.p99_us >= 98);
        assert!((s.cycles_per_number - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_snapshot_is_zero() {
        let s = ServiceMetrics::new().snapshot();
        assert_eq!(s.completed, 0);
        assert_eq!(s.p50_us, 0);
        assert_eq!(s.cycles_per_number, 0.0);
        // The dead-host constructor is exactly the fresh-service view.
        assert_eq!(Snapshot::empty(), s);
    }

    #[test]
    fn errors_counted() {
        let m = ServiceMetrics::new();
        m.record_error();
        m.record_error();
        assert_eq!(m.snapshot().errors, 2);
    }

    #[test]
    fn hierarchical_counters_accumulate() {
        let m = ServiceMetrics::new();
        m.record_hierarchical(5000, 5, 10_000, 60_000);
        m.record_hierarchical(2000, 2, 2_000, 20_000);
        let s = m.snapshot();
        assert_eq!(s.hier_completed, 2);
        assert_eq!(s.hier_elements, 7000);
        assert_eq!(s.hier_chunks, 7);
        assert_eq!(s.merge_cycles, 12_000);
        assert_eq!(s.merge_comparisons, 80_000);
    }

    #[test]
    fn per_class_costs_are_tracked_separately() {
        let m = ServiceMetrics::new();
        // 256-element requests at 8 cyc/num; 1024-element at 30 cyc/num.
        m.record(1, &stats(2048), 256);
        m.record(1, &stats(2048), 256);
        m.record(1, &stats(30_720), 1024);
        let s = m.snapshot();
        assert!((s.cyc_per_num_for(256, 7.84) - 8.0).abs() < 1e-12);
        assert!((s.cyc_per_num_for(300, 7.84) - 8.0).abs() < 1e-12, "same log2 class");
        assert!((s.cyc_per_num_for(1024, 7.84) - 30.0).abs() < 1e-12);
        // Unseen class falls back to the global average, not 7.84.
        let global = (2048.0 + 2048.0 + 30_720.0) / (256.0 + 256.0 + 1024.0);
        assert!((s.cyc_per_num_for(16, 7.84) - global).abs() < 1e-12);
        // Empty service falls back to the nominal constant.
        let empty = ServiceMetrics::new().snapshot();
        assert!((empty.cyc_per_num_for(256, 7.84) - 7.84).abs() < 1e-12);
        // Degenerate n.
        assert_eq!(size_class(0), 0);
        assert_eq!(size_class(1), 0);
        assert_eq!(size_class(usize::MAX), SIZE_CLASSES - 1);
    }

    #[test]
    fn lock_free_cyc_per_num_matches_snapshot() {
        // The router-side reader must agree with the snapshot-side one
        // on every rung of the fallback ladder.
        let m = ServiceMetrics::new();
        assert_eq!(m.cyc_per_num_for(256, 7.84), 7.84, "empty: nominal fallback");
        m.record(1, &stats(2048), 256);
        m.record(1, &stats(30_720), 1024);
        // A zero-cycle class (clamped malformed traces): elements are
        // recorded but the ratio is 0, and both readers must fall
        // through to the global average instead of reporting a free
        // shard.
        m.record(1, &stats(0), 64);
        let s = m.snapshot();
        for n in [16usize, 64, 256, 300, 1024, 50_000] {
            assert!(
                (m.cyc_per_num_for(n, 7.84) - s.cyc_per_num_for(n, 7.84)).abs() < 1e-12,
                "n={n}"
            );
        }
        assert!(m.cyc_per_num_for(64, 7.84) > 0.0, "zero-cycle class falls back");
        // All-zero-cycle service: both rungs exhausted -> nominal.
        let z = ServiceMetrics::new();
        z.record(1, &stats(0), 64);
        assert_eq!(z.cyc_per_num_for(64, 7.84), 7.84);
        assert_eq!(z.cyc_per_num_for(64, 7.84), z.snapshot().cyc_per_num_for(64, 7.84));
    }

    #[test]
    fn reservoir_caps_memory() {
        let m = ServiceMetrics::new();
        for i in 0..(RESERVOIR_CAP as u64 + 1000) {
            m.record(i, &stats(1), 1);
        }
        assert_eq!(m.snapshot().completed, RESERVOIR_CAP as u64 + 1000);
        assert!(m.latencies_us.lock().unwrap().samples.len() <= RESERVOIR_CAP);
    }

    #[test]
    fn full_reservoir_spreads_overwrites_across_slots() {
        // Regression for the biased overwrite: once the reservoir was
        // full, the slot index was derived from `latency_us` itself, so
        // constant-latency traffic rewrote one slot forever and the
        // percentiles stayed frozen on the old samples. The ring must
        // instead retire every stale sample after CAP further records.
        let m = ServiceMetrics::new();
        for _ in 0..RESERVOIR_CAP {
            m.record(1_000_000, &stats(1), 1); // fill with an old regime
        }
        for _ in 0..RESERVOIR_CAP {
            m.record(5, &stats(1), 1); // constant-latency fresh traffic
        }
        let lat = m.latencies_us.lock().unwrap();
        assert_eq!(lat.samples.len(), RESERVOIR_CAP);
        assert!(
            lat.samples.iter().all(|&v| v == 5),
            "every slot must be overwritten by the fresh regime"
        );
        drop(lat);
        let s = m.snapshot();
        assert_eq!((s.p50_us, s.p99_us, s.max_us), (5, 5, 5));
        // Distinct latencies land in distinct slots (insertion order).
        let m = ServiceMetrics::new();
        for _ in 0..RESERVOIR_CAP {
            m.record(0, &stats(1), 1);
        }
        for i in 0..16u64 {
            m.record(100 + i, &stats(1), 1);
        }
        let lat = m.latencies_us.lock().unwrap();
        assert_eq!(&lat.samples[..16], &(100..116).collect::<Vec<u64>>()[..]);
        assert_eq!(lat.seen, RESERVOIR_CAP as u64 + 16);
    }
}

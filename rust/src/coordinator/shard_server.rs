//! The shard host's side of the wire: a blocking server loop that
//! speaks the [`super::wire`] protocol on behalf of one
//! [`super::SortService`].
//!
//! One [`ShardServer`] is one shard host — exactly the thing a
//! [`super::transport::LocalTransport`] is in-process, so it *wraps*
//! one: the wire's `Halt`/`Restart` frames map straight onto the
//! transport's crash/replace machinery, and the coordinator-visible
//! semantics (submits fail fast on a dead host, a restarted host comes
//! back empty) are the same whether the shard sits behind a thread
//! boundary or a socket.
//!
//! Connections are served one at a time ([`ShardServer::serve_conn`]
//! blocks until EOF or `Shutdown`); a shard has one coordinator, and a
//! reconnect — the remote side of
//! [`super::transport::ShardTransport::restart`] — simply starts the
//! next `serve_conn`. Within a connection, sort jobs are fully
//! pipelined: each job is submitted to the service immediately and a
//! per-job collector thread writes the reply whenever the worker pool
//! finishes it, so responses may return out of submission order (the
//! correlation id in the frame header is what keys them, not arrival
//! order).
//!
//! **Dropped replies stay dropped.** When the host dies with a job in
//! flight (submit rejected, or the worker vanished under it), the
//! server answers [`super::wire::Frame::Dropped`] — never an error
//! *reply* — so the coordinator's re-route path observes exactly what
//! an in-process dropped channel looks like. A sort that fails as a
//! *result* (engine mismatch) is a [`super::wire::Frame::ErrReply`],
//! which fails the request on the coordinator without re-routing, same
//! as the local path.

use std::io::{Read, Write};
use std::net::TcpListener;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::transport::{LocalTransport, ShardTransport};
use super::wire::{read_frame, read_hello, write_frame, Frame, WIRE_VERSION};
use super::ServiceConfig;

/// One shard host behind the wire: a restartable in-process service
/// plus the connection loop that exposes it.
pub struct ShardServer {
    host: Arc<LocalTransport>,
}

impl ShardServer {
    /// Start the host's service from `config`.
    pub fn start(config: ServiceConfig) -> Result<Self> {
        Ok(ShardServer { host: Arc::new(LocalTransport::start(config)?) })
    }

    /// The in-process transport this server fronts. Tests use it to
    /// kill the host behind the wire's back (the remote analogue of a
    /// host crashing without telling its coordinator).
    pub fn host(&self) -> &Arc<LocalTransport> {
        &self.host
    }

    /// A [`super::transport::Connector`] that dials this server over a
    /// fresh in-memory duplex per call, each connection served by its
    /// own thread — the deterministic stand-in for re-dialling a TCP
    /// host, shared by the remote-path tests, benches and examples.
    pub fn duplex_connector(server: Arc<Self>) -> super::transport::Connector {
        Box::new(move || {
            let (client, (sr, sw)) = super::wire::duplex();
            let srv = Arc::clone(&server);
            std::thread::spawn(move || {
                let _ = srv.serve_conn(sr, sw);
            });
            Ok(client)
        })
    }

    /// Serve one connection until EOF or a `Shutdown` frame. Returns
    /// `Ok(true)` after `Shutdown` (the host is shut down too — stop
    /// accepting), `Ok(false)` after a plain disconnect (the host keeps
    /// running; the coordinator may reconnect, e.g. on restart).
    pub fn serve_conn(
        &self,
        mut r: Box<dyn Read + Send>,
        w: Box<dyn Write + Send>,
    ) -> Result<bool> {
        // The write half is shared with the per-job collector threads;
        // every frame goes out as one locked write_all, so frames never
        // interleave.
        let w: Arc<Mutex<Box<dyn Write + Send>>> = Arc::new(Mutex::new(w));
        let write = |id: u64, frame: &Frame| {
            let mut g = w.lock().expect("writer poisoned");
            write_frame(g.as_mut(), id, frame)
        };

        // Version negotiation: the connection must open with Hello.
        let (hid, version) = read_hello(r.as_mut())?;
        if version != WIRE_VERSION {
            let msg = format!(
                "unsupported wire version {version} (this host speaks {WIRE_VERSION})"
            );
            let _ = write(hid, &Frame::ErrReply(msg.clone()));
            anyhow::bail!("{msg}");
        }
        write(hid, &Frame::HelloAck(self.host.config()))?;

        loop {
            // EOF or a framing error ends the connection; the host
            // stays up for the next one.
            let Ok((id, frame)) = read_frame(r.as_mut()) else { return Ok(false) };
            match frame {
                // A job whose *reply* would exceed the frame cap is
                // answered with a delivered error — never with an
                // over-cap SortOk that would kill the connection (and
                // every other job in flight on it).
                Frame::SortJob(data) if data.len() > super::wire::MAX_SORT_ELEMS => {
                    let msg = format!(
                        "sort job of {} elements exceeds the wire cap of {}",
                        data.len(),
                        super::wire::MAX_SORT_ELEMS
                    );
                    let _ = write(id, &Frame::ErrReply(msg));
                }
                Frame::SortJob(data) => match self.host.submit(data) {
                    Ok(rx) => {
                        // Collector: one thread per in-flight job, so
                        // replies pipeline in completion order while
                        // the read loop keeps accepting jobs.
                        let w = Arc::clone(&w);
                        std::thread::spawn(move || {
                            let frame = match rx.recv() {
                                Ok(Ok(resp)) => Frame::SortOk(resp),
                                Ok(Err(e)) => Frame::ErrReply(format!("{e:#}")),
                                // The worker vanished under the job —
                                // the wire form of a dropped reply.
                                Err(_) => Frame::Dropped,
                            };
                            let mut g = w.lock().expect("writer poisoned");
                            // The connection may already be gone; the
                            // coordinator then sees the drop anyway.
                            let _ = write_frame(g.as_mut(), id, &frame);
                        });
                    }
                    // Submit rejected: the host is down. Fail "fast"
                    // the only way a reply channel can — by dropping.
                    Err(_) => {
                        let _ = write(id, &Frame::Dropped);
                    }
                },
                Frame::GetMetrics => write(id, &Frame::MetricsReply(self.host.metrics()))?,
                Frame::Halt => self.host.halt(),
                Frame::Restart => {
                    let reply = match self.host.restart() {
                        Ok(()) => Frame::Ack,
                        Err(e) => Frame::ErrReply(format!("restart failed: {e:#}")),
                    };
                    write(id, &reply)?;
                }
                Frame::Shutdown => {
                    self.host.shutdown();
                    return Ok(true);
                }
                // Server-bound streams never carry reply kinds; a
                // coordinator that sends one is broken — drop the link.
                other => anyhow::bail!("unexpected frame {other:?} on a shard server"),
            }
        }
    }
}

impl super::transport::ShardTransport for ShardServer {
    // A ShardServer *is* its LocalTransport with a wire bolted on; the
    // trait pass-through lets operator tooling (and tests) poke the
    // host directly through the same seam the wire serves.
    fn submit(
        &self,
        data: Vec<u32>,
    ) -> Result<std::sync::mpsc::Receiver<Result<super::SortResponse>>> {
        self.host.submit(data)
    }

    fn metrics(&self) -> super::metrics::Snapshot {
        self.host.metrics()
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        self.host.cyc_per_num_for(n, fallback)
    }

    fn config(&self) -> ServiceConfig {
        self.host.config()
    }

    fn halt(&self) {
        self.host.halt();
    }

    fn restart(&self) -> Result<()> {
        self.host.restart()
    }

    fn shutdown(&self) {
        self.host.shutdown();
    }
}

/// Accept loop for a TCP-fronted shard host: serve connections one at a
/// time until a coordinator sends `Shutdown`. This is what
/// `memsort serve --shard --port N` runs; each accepted connection gets
/// the full handshake + job loop, and a dropped coordinator only ends
/// its own connection.
pub fn serve_tcp(listener: TcpListener, config: ServiceConfig) -> Result<()> {
    let server = ShardServer::start(config)?;
    for conn in listener.incoming() {
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_nodelay(true);
        let read = Box::new(stream.try_clone()?) as Box<dyn Read + Send>;
        let write = Box::new(stream) as Box<dyn Write + Send>;
        match server.serve_conn(read, write) {
            Ok(true) => return Ok(()), // coordinator asked for shutdown
            Ok(false) => continue,     // disconnect; await a reconnect
            Err(e) => eprintln!("shard connection error: {e:#}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::transport::ShardTransport;
    use super::super::wire::{duplex, encode_frame, read_frame, write_frame, Frame};
    use super::*;

    fn start() -> (Arc<ShardServer>, std::thread::JoinHandle<Result<bool>>, super::super::wire::WireConn)
    {
        let server = Arc::new(
            ShardServer::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap(),
        );
        let (client, (sr, sw)) = duplex();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve_conn(sr, sw));
        (server, t, client)
    }

    #[test]
    fn handshake_sort_and_shutdown_over_a_duplex_link() {
        let (_server, t, (mut r, mut w)) = start();
        write_frame(w.as_mut(), 1, &Frame::Hello).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!(id, 1);
        let Frame::HelloAck(cfg) = frame else { panic!("expected HelloAck, got {frame:?}") };
        assert_eq!(cfg.workers, 2);
        // Two pipelined jobs; replies come back keyed by id.
        write_frame(w.as_mut(), 10, &Frame::SortJob(vec![3, 1, 2])).unwrap();
        write_frame(w.as_mut(), 11, &Frame::SortJob(vec![9, 7])).unwrap();
        let mut got = std::collections::HashMap::new();
        for _ in 0..2 {
            let (id, frame) = read_frame(r.as_mut()).unwrap();
            let Frame::SortOk(resp) = frame else { panic!("expected SortOk, got {frame:?}") };
            got.insert(id, resp.sorted);
        }
        assert_eq!(got[&10], vec![1, 2, 3]);
        assert_eq!(got[&11], vec![7, 9]);
        write_frame(w.as_mut(), 12, &Frame::Shutdown).unwrap();
        assert!(t.join().unwrap().unwrap(), "Shutdown ends the accept contract");
    }

    #[test]
    fn dead_host_answers_dropped_not_error() {
        let (server, t, (mut r, mut w)) = start();
        write_frame(w.as_mut(), 1, &Frame::Hello).unwrap();
        let _ = read_frame(r.as_mut()).unwrap();
        // Kill the host behind the wire's back and wait for the death
        // to be observable, like the local-transport tests do.
        server.host().halt();
        while server.host().submit(vec![1u32]).is_ok() {
            std::thread::yield_now();
        }
        write_frame(w.as_mut(), 5, &Frame::SortJob(vec![4, 4, 1])).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!((id, frame), (5, Frame::Dropped));
        // Restart over the wire brings the host back empty.
        write_frame(w.as_mut(), 6, &Frame::Restart).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!((id, frame), (6, Frame::Ack));
        write_frame(w.as_mut(), 7, &Frame::SortJob(vec![4, 4, 1])).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!(id, 7);
        let Frame::SortOk(resp) = frame else { panic!("expected SortOk, got {frame:?}") };
        assert_eq!(resp.sorted, vec![1, 4, 4]);
        write_frame(w.as_mut(), 8, &Frame::GetMetrics).unwrap();
        let (_, frame) = read_frame(r.as_mut()).unwrap();
        let Frame::MetricsReply(snap) = frame else { panic!("expected metrics") };
        assert_eq!(snap.completed, 1, "a restarted host reports from zero");
        write_frame(w.as_mut(), 9, &Frame::Shutdown).unwrap();
        t.join().unwrap().unwrap();
    }

    #[test]
    fn version_mismatch_is_rejected_at_hello() {
        let (_server, t, (mut r, mut w)) = start();
        let mut hello = encode_frame(1, &Frame::Hello);
        hello[2] = super::super::wire::WIRE_VERSION + 1;
        w.write_all(&hello).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!(id, 1);
        let Frame::ErrReply(msg) = frame else { panic!("expected ErrReply, got {frame:?}") };
        assert!(msg.contains("version"), "{msg}");
        assert!(t.join().unwrap().is_err(), "the server drops the connection");
    }

    #[test]
    fn plain_disconnect_keeps_the_host_alive_for_a_reconnect() {
        let server = Arc::new(
            ShardServer::start(ServiceConfig { workers: 1, ..Default::default() }).unwrap(),
        );
        for round in 0..2 {
            let ((mut r, mut w), (sr, sw)) = duplex();
            let srv = Arc::clone(&server);
            let t = std::thread::spawn(move || srv.serve_conn(sr, sw));
            write_frame(w.as_mut(), 1, &Frame::Hello).unwrap();
            let _ = read_frame(r.as_mut()).unwrap();
            write_frame(w.as_mut(), 2, &Frame::SortJob(vec![2, 1])).unwrap();
            let (_, frame) = read_frame(r.as_mut()).unwrap();
            assert!(matches!(frame, Frame::SortOk(_)), "round {round}: {frame:?}");
            drop((r, w)); // plain disconnect
            assert!(!t.join().unwrap().unwrap(), "host survives the disconnect");
        }
        // The same host served both connections: its metrics persisted.
        assert_eq!(server.host().metrics().completed, 2);
        server.host().shutdown();
    }
}

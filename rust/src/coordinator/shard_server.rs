//! The shard host's side of the wire: a blocking server loop that
//! speaks the [`super::wire`] protocol on behalf of one
//! [`super::SortService`].
//!
//! One [`ShardServer`] is one shard host — exactly the thing a
//! [`super::transport::LocalTransport`] is in-process, so it *wraps*
//! one: the wire's `Halt`/`Restart` frames map straight onto the
//! transport's crash/replace machinery, and the coordinator-visible
//! semantics (submits fail fast on a dead host, a restarted host comes
//! back empty) are the same whether the shard sits behind a thread
//! boundary or a socket.
//!
//! Connections are served **concurrently**: [`ShardServer::serve_conn`]
//! is one session (blocking until EOF or `Shutdown`) and any number of
//! sessions may run at once against the shared restartable host —
//! [`serve_tcp`] spawns one session thread per accepted connection, up
//! to a cap. A reconnect — the remote side of
//! [`super::transport::ShardTransport::restart`] — is simply a fresh
//! session; sibling sessions never notice, because the host outlives
//! every connection. Within a session, sort jobs are fully pipelined:
//! each job is submitted to the service immediately and a per-job
//! collector thread writes the reply whenever the worker pool finishes
//! it, so responses may return out of submission order (the correlation
//! id in the frame header is what keys them, not arrival order). The
//! ids are scoped per connection, so concurrent coordinators can reuse
//! the same ids without collision.
//!
//! **Dropped replies stay dropped.** When the host dies with a job in
//! flight (submit rejected, or the worker vanished under it), the
//! server answers [`super::wire::Frame::Dropped`] — never an error
//! *reply* — so the coordinator's re-route path observes exactly what
//! an in-process dropped channel looks like. A sort that fails as a
//! *result* (engine mismatch) is a [`super::wire::Frame::ErrReply`],
//! which fails the request on the coordinator without re-routing, same
//! as the local path.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::locks::lock_recover;
use super::transport::{LocalTransport, ShardTransport};
use super::wire::{
    read_frame_view, read_hello, write_frame, Frame, FrameSink, FrameView, MIN_WIRE_VERSION,
    WIRE_VERSION,
};
use super::ServiceConfig;

/// The shared write half of one session: every frame goes out as one
/// locked `write_frame`, so concurrent collector threads never
/// interleave bytes. The [`FrameSink`] owns the session's encode
/// buffer, so steady-state replies reuse one allocation instead of
/// building a fresh `Vec` per frame.
type SharedWriter = Arc<Mutex<FrameSink>>;

/// One shard host behind the wire: a restartable in-process service
/// plus the connection loop that exposes it.
pub struct ShardServer {
    host: Arc<LocalTransport>,
}

impl ShardServer {
    /// Start the host's service from `config`.
    pub fn start(config: ServiceConfig) -> Result<Self> {
        Ok(ShardServer { host: Arc::new(LocalTransport::start(config)?) })
    }

    /// The in-process transport this server fronts. Tests use it to
    /// kill the host behind the wire's back (the remote analogue of a
    /// host crashing without telling its coordinator).
    pub fn host(&self) -> &Arc<LocalTransport> {
        &self.host
    }

    /// A [`super::transport::Connector`] that dials this server over a
    /// fresh in-memory duplex per call, each connection served by its
    /// own thread — the deterministic stand-in for re-dialling a TCP
    /// host, shared by the remote-path tests, benches and examples.
    pub fn duplex_connector(server: Arc<Self>) -> super::transport::Connector {
        Box::new(move || {
            let (client, (sr, sw)) = super::wire::duplex();
            let srv = Arc::clone(&server);
            std::thread::spawn(move || {
                let _ = srv.serve_conn(sr, sw);
            });
            Ok(client)
        })
    }

    /// Serve one connection until EOF or a `Shutdown` frame. Returns
    /// `Ok(true)` after `Shutdown` (the host is shut down too — stop
    /// accepting), `Ok(false)` after a plain disconnect (the host keeps
    /// running; the coordinator may reconnect, e.g. on restart).
    pub fn serve_conn(
        &self,
        mut r: Box<dyn Read + Send>,
        w: Box<dyn Write + Send>,
    ) -> Result<bool> {
        // The write half is shared with the per-job collector threads;
        // every frame goes out as one locked write_all, so frames never
        // interleave. A collector that panicked mid-write must not take
        // its siblings down with it, hence the recovering lock.
        let w: SharedWriter = Arc::new(Mutex::new(FrameSink::new(w)));
        let write = |id: u64, frame: &Frame| {
            let mut g = lock_recover(&w);
            g.write_frame(id, frame)
        };

        // Version negotiation: the connection must open with Hello. Any
        // version the codec can read is served — a v1 coordinator only
        // ever sends v1 kinds, which still decode and answer v1 replies.
        let (hid, version) = read_hello(r.as_mut())?;
        if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version) {
            let msg = format!(
                "unsupported wire version {version} (this host speaks \
                 {MIN_WIRE_VERSION}..={WIRE_VERSION})"
            );
            let _ = write(hid, &Frame::ErrReply(msg.clone()));
            anyhow::bail!("{msg}");
        }
        write(hid, &Frame::HelloAck(self.host.config()))?;

        // One payload scratch for the whole session: every frame is
        // read into it (zero steady-state allocation), and the hot job
        // kinds are decoded as borrowed views so the only copy of the
        // element data is the one `to_vec` below hands to the service.
        let mut scratch = Vec::new();
        loop {
            // EOF or a framing error ends the connection; the host
            // stays up for the next one.
            let Ok((id, view)) = read_frame_view(r.as_mut(), &mut scratch) else {
                return Ok(false);
            };
            match view {
                FrameView::SortJob(data) => self.dispatch_job(id, None, data.to_vec(), &w),
                FrameView::SortJobTagged(tag, data) => {
                    self.dispatch_job(id, Some(tag), data.to_vec(), &w)
                }
                // Server-bound streams never carry reply kinds; a
                // coordinator that sends one is broken — drop the link.
                FrameView::SortOk(_) => {
                    anyhow::bail!("unexpected frame SortOk on a shard server")
                }
                FrameView::Owned(frame) => match frame {
                    Frame::GetMetrics => write(id, &Frame::MetricsReply(self.host.metrics()))?,
                    Frame::Halt => self.host.halt(),
                    Frame::Restart => {
                        let reply = match self.host.restart() {
                            Ok(()) => Frame::Ack,
                            Err(e) => Frame::ErrReply(format!("restart failed: {e:#}")),
                        };
                        write(id, &reply)?;
                    }
                    Frame::Shutdown => {
                        self.host.shutdown();
                        return Ok(true);
                    }
                    other => anyhow::bail!("unexpected frame {other:?} on a shard server"),
                },
            }
        }
    }

    /// Submit one pipelined sort job and arrange its reply.
    ///
    /// A job whose *reply* would exceed the frame cap is answered with
    /// a delivered error — never with an over-cap `SortOk` that would
    /// kill the connection (and every other job in flight on it). A
    /// rejected submit (the host is down) answers [`Frame::Dropped`],
    /// the wire form of a dropped reply channel; so does a worker that
    /// vanishes under the job after submission.
    fn dispatch_job(
        &self,
        id: u64,
        tag: Option<super::frontend::JobTag>,
        data: Vec<u32>,
        w: &SharedWriter,
    ) {
        let write_one = |frame: &Frame| {
            let mut g = lock_recover(w);
            let _ = g.write_frame(id, frame);
        };
        if data.len() > super::wire::MAX_SORT_ELEMS {
            let msg = format!(
                "sort job of {} elements exceeds the wire cap of {}",
                data.len(),
                super::wire::MAX_SORT_ELEMS
            );
            write_one(&Frame::ErrReply(msg));
            return;
        }
        let submitted = match &tag {
            Some(t) => self.host.submit_tagged(t, data),
            None => self.host.submit(data),
        };
        match submitted {
            Ok(rx) => {
                // Collector: one thread per in-flight job, so replies
                // pipeline in completion order while the read loop
                // keeps accepting jobs.
                let w = Arc::clone(w);
                std::thread::spawn(move || {
                    let frame = match rx.recv() {
                        Ok(Ok(resp)) => Frame::SortOk(resp),
                        Ok(Err(e)) => Frame::ErrReply(format!("{e:#}")),
                        // The worker vanished under the job — the wire
                        // form of a dropped reply.
                        Err(_) => Frame::Dropped,
                    };
                    // The connection may already be gone; the
                    // coordinator then sees the drop anyway.
                    let mut g = lock_recover(&w);
                    let _ = g.write_frame(id, &frame);
                });
            }
            // Submit rejected: the host is down. Fail "fast" the only
            // way a reply channel can — by dropping.
            Err(_) => write_one(&Frame::Dropped),
        }
    }
}

impl super::transport::ShardTransport for ShardServer {
    // A ShardServer *is* its LocalTransport with a wire bolted on; the
    // trait pass-through lets operator tooling (and tests) poke the
    // host directly through the same seam the wire serves.
    fn submit(
        &self,
        data: Vec<u32>,
    ) -> Result<std::sync::mpsc::Receiver<Result<super::SortResponse>>> {
        self.host.submit(data)
    }

    fn submit_tagged(
        &self,
        tag: &super::frontend::JobTag,
        data: Vec<u32>,
    ) -> Result<std::sync::mpsc::Receiver<Result<super::SortResponse>>> {
        self.host.submit_tagged(tag, data)
    }

    fn metrics(&self) -> super::metrics::Snapshot {
        self.host.metrics()
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        self.host.cyc_per_num_for(n, fallback)
    }

    fn config(&self) -> ServiceConfig {
        self.host.config()
    }

    fn halt(&self) {
        self.host.halt();
    }

    fn restart(&self) -> Result<()> {
        self.host.restart()
    }

    fn shutdown(&self) {
        self.host.shutdown();
    }
}

/// Accept loop for a TCP-fronted shard host: spawn one session thread
/// per accepted connection (up to `max_conns` concurrent sessions) and
/// run until any coordinator sends `Shutdown`. This is what
/// `memsort serve --shard --port N` runs.
///
/// * Each connection gets the full handshake + pipelined job loop; a
///   dropped coordinator only ends its own session, the host (and every
///   sibling session) keeps running.
/// * At the cap, a new connection is *politely* rejected: its `Hello`
///   is read and answered with an [`Frame::ErrReply`] naming the limit,
///   so the client sees a typed refusal instead of a hung or reset
///   socket. The rejection runs on its own thread so a client that
///   never sends `Hello` cannot wedge the accept loop.
/// * `Shutdown` on any session shuts the host down, closes every
///   sibling connection (their in-flight jobs would only observe
///   [`Frame::Dropped`] from the dead host anyway), unblocks the accept
///   loop with a self-dial, and joins the remaining sessions.
pub fn serve_tcp(listener: TcpListener, config: ServiceConfig, max_conns: usize) -> Result<()> {
    anyhow::ensure!(max_conns >= 1, "a shard server needs at least one connection slot");
    let server = Arc::new(ShardServer::start(config)?);
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let active = Arc::new(AtomicUsize::new(0));
    // Raw handles to every live session's stream, keyed by a session
    // id: Shutdown closes them all to wake sessions parked in a read.
    let peers: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
    let mut sessions = Vec::new();
    let mut next_session = 0u64;
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_nodelay(true);
        if active.load(Ordering::SeqCst) >= max_conns {
            reject_over_cap(stream, max_conns);
            continue;
        }
        let sid = next_session;
        next_session += 1;
        active.fetch_add(1, Ordering::SeqCst);
        lock_recover(&peers).insert(sid, stream.try_clone()?);
        let srv = Arc::clone(&server);
        let stop = Arc::clone(&stop);
        let active = Arc::clone(&active);
        let peers = Arc::clone(&peers);
        sessions.push(std::thread::spawn(move || {
            let read = stream.try_clone().map(|s| Box::new(s) as Box<dyn Read + Send>);
            let outcome = match read {
                Ok(read) => srv.serve_conn(read, Box::new(stream)),
                Err(e) => Err(e.into()),
            };
            lock_recover(&peers).remove(&sid);
            active.fetch_sub(1, Ordering::SeqCst);
            match outcome {
                Ok(true) => {
                    // Orderly shutdown: close the siblings, then dial
                    // ourselves so the accept loop re-checks the flag.
                    stop.store(true, Ordering::SeqCst);
                    for (_, peer) in lock_recover(&peers).drain() {
                        let _ = peer.shutdown(std::net::Shutdown::Both);
                    }
                    let _ = TcpStream::connect(addr);
                }
                Ok(false) => {} // disconnect; the host awaits a reconnect
                Err(e) => eprintln!("shard connection error: {e:#}"),
            }
        }));
    }
    for session in sessions {
        let _ = session.join();
    }
    Ok(())
}

/// Politely refuse a connection over the session cap: read its `Hello`
/// (on a throwaway thread — the client may never send one) and answer
/// with a typed error naming the limit.
fn reject_over_cap(mut stream: TcpStream, max_conns: usize) {
    std::thread::spawn(move || {
        if let Ok((hid, _)) = read_hello(&mut stream) {
            let msg = format!(
                "connection limit reached ({max_conns} active sessions): retry later"
            );
            let _ = write_frame(&mut stream, hid, &Frame::ErrReply(msg));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::super::transport::ShardTransport;
    use super::super::wire::{duplex, encode_frame, read_frame, write_frame, Frame};
    use super::*;

    fn start() -> (Arc<ShardServer>, std::thread::JoinHandle<Result<bool>>, super::super::wire::WireConn)
    {
        let server = Arc::new(
            ShardServer::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap(),
        );
        let (client, (sr, sw)) = duplex();
        let srv = Arc::clone(&server);
        let t = std::thread::spawn(move || srv.serve_conn(sr, sw));
        (server, t, client)
    }

    #[test]
    fn handshake_sort_and_shutdown_over_a_duplex_link() {
        let (_server, t, (mut r, mut w)) = start();
        write_frame(w.as_mut(), 1, &Frame::Hello).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!(id, 1);
        let Frame::HelloAck(cfg) = frame else { panic!("expected HelloAck, got {frame:?}") };
        assert_eq!(cfg.workers, 2);
        // Two pipelined jobs; replies come back keyed by id.
        write_frame(w.as_mut(), 10, &Frame::SortJob(vec![3, 1, 2])).unwrap();
        write_frame(w.as_mut(), 11, &Frame::SortJob(vec![9, 7])).unwrap();
        let mut got = std::collections::HashMap::new();
        for _ in 0..2 {
            let (id, frame) = read_frame(r.as_mut()).unwrap();
            let Frame::SortOk(resp) = frame else { panic!("expected SortOk, got {frame:?}") };
            got.insert(id, resp.sorted);
        }
        assert_eq!(got[&10], vec![1, 2, 3]);
        assert_eq!(got[&11], vec![7, 9]);
        write_frame(w.as_mut(), 12, &Frame::Shutdown).unwrap();
        assert!(t.join().unwrap().unwrap(), "Shutdown ends the accept contract");
    }

    #[test]
    fn malformed_frame_ends_the_session_but_not_the_host() {
        let (server, t, (mut r, mut w)) = start();
        write_frame(w.as_mut(), 1, &Frame::Hello).unwrap();
        let _ = read_frame(r.as_mut()).unwrap();
        // Garbage after the handshake: a header that fails the magic
        // check. The session must end as a plain disconnect (Ok(false),
        // never a panic), leaving the shared host serving.
        w.write_all(&[0xDEu8; 16]).unwrap();
        drop(w);
        assert_eq!(t.join().unwrap().unwrap(), false, "framing error = disconnect");
        // A fresh session against the same host works end to end.
        let (client, (sr, sw)) = duplex();
        let (mut r2, mut w2) = client;
        let srv = Arc::clone(&server);
        let t2 = std::thread::spawn(move || srv.serve_conn(sr, sw));
        write_frame(w2.as_mut(), 1, &Frame::Hello).unwrap();
        let _ = read_frame(r2.as_mut()).unwrap();
        write_frame(w2.as_mut(), 2, &Frame::SortJob(vec![5, 2, 9])).unwrap();
        let (id, frame) = read_frame(r2.as_mut()).unwrap();
        assert_eq!(id, 2);
        let Frame::SortOk(resp) = frame else { panic!("expected SortOk, got {frame:?}") };
        assert_eq!(resp.sorted, vec![2, 5, 9]);
        write_frame(w2.as_mut(), 3, &Frame::Shutdown).unwrap();
        assert!(t2.join().unwrap().unwrap());
    }

    #[test]
    fn dead_host_answers_dropped_not_error() {
        let (server, t, (mut r, mut w)) = start();
        write_frame(w.as_mut(), 1, &Frame::Hello).unwrap();
        let _ = read_frame(r.as_mut()).unwrap();
        // Kill the host behind the wire's back and wait for the death
        // to be observable, like the local-transport tests do.
        server.host().halt();
        while server.host().submit(vec![1u32]).is_ok() {
            std::thread::yield_now();
        }
        write_frame(w.as_mut(), 5, &Frame::SortJob(vec![4, 4, 1])).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!((id, frame), (5, Frame::Dropped));
        // Restart over the wire brings the host back empty.
        write_frame(w.as_mut(), 6, &Frame::Restart).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!((id, frame), (6, Frame::Ack));
        write_frame(w.as_mut(), 7, &Frame::SortJob(vec![4, 4, 1])).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!(id, 7);
        let Frame::SortOk(resp) = frame else { panic!("expected SortOk, got {frame:?}") };
        assert_eq!(resp.sorted, vec![1, 4, 4]);
        write_frame(w.as_mut(), 8, &Frame::GetMetrics).unwrap();
        let (_, frame) = read_frame(r.as_mut()).unwrap();
        let Frame::MetricsReply(snap) = frame else { panic!("expected metrics") };
        assert_eq!(snap.completed, 1, "a restarted host reports from zero");
        write_frame(w.as_mut(), 9, &Frame::Shutdown).unwrap();
        t.join().unwrap().unwrap();
    }

    #[test]
    fn version_mismatch_is_rejected_at_hello() {
        let (_server, t, (mut r, mut w)) = start();
        let mut hello = encode_frame(1, &Frame::Hello);
        hello[2] = super::super::wire::WIRE_VERSION + 1;
        w.write_all(&hello).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!(id, 1);
        let Frame::ErrReply(msg) = frame else { panic!("expected ErrReply, got {frame:?}") };
        assert!(msg.contains("version"), "{msg}");
        assert!(t.join().unwrap().is_err(), "the server drops the connection");
    }

    #[test]
    fn tagged_jobs_sort_like_plain_ones() {
        use super::super::frontend::{JobTag, Priority};
        let (_server, t, (mut r, mut w)) = start();
        write_frame(w.as_mut(), 1, &Frame::Hello).unwrap();
        let _ = read_frame(r.as_mut()).unwrap();
        let tag = JobTag::new("acme", Priority::Interactive);
        write_frame(w.as_mut(), 2, &Frame::SortJobTagged(tag, vec![5, 3, 9, 1])).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!(id, 2);
        let Frame::SortOk(resp) = frame else { panic!("expected SortOk, got {frame:?}") };
        assert_eq!(resp.sorted, vec![1, 3, 5, 9]);
        write_frame(w.as_mut(), 3, &Frame::Shutdown).unwrap();
        t.join().unwrap().unwrap();
    }

    #[test]
    fn v1_coordinators_still_handshake() {
        // A v1 peer stamps its Hello with version 1; the server must
        // serve it (v1 kinds all decode), not slam the door.
        let (_server, t, (mut r, mut w)) = start();
        let mut hello = encode_frame(1, &Frame::Hello);
        hello[2] = super::super::wire::MIN_WIRE_VERSION;
        w.write_all(&hello).unwrap();
        let (id, frame) = read_frame(r.as_mut()).unwrap();
        assert_eq!(id, 1);
        assert!(matches!(frame, Frame::HelloAck(_)), "got {frame:?}");
        write_frame(w.as_mut(), 2, &Frame::SortJob(vec![2, 1])).unwrap();
        let (_, frame) = read_frame(r.as_mut()).unwrap();
        assert!(matches!(frame, Frame::SortOk(_)), "got {frame:?}");
        write_frame(w.as_mut(), 3, &Frame::Shutdown).unwrap();
        t.join().unwrap().unwrap();
    }

    #[test]
    fn restart_drops_only_the_dead_sessions_jobs_and_siblings_recover() {
        // The multi-connection regression: two sessions share one host.
        // The host dies; session A observes Dropped for its job, session
        // B restarts the host over *its* connection — and both sessions
        // keep working on the same (restarted) host. Neither connection
        // is torn down by the host's death.
        let server = Arc::new(
            ShardServer::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap(),
        );
        let mut conns = Vec::new();
        let mut threads = Vec::new();
        for id in 0..2u64 {
            let ((mut r, mut w), (sr, sw)) = duplex();
            let srv = Arc::clone(&server);
            threads.push(std::thread::spawn(move || srv.serve_conn(sr, sw)));
            write_frame(w.as_mut(), id, &Frame::Hello).unwrap();
            let (_, frame) = read_frame(r.as_mut()).unwrap();
            assert!(matches!(frame, Frame::HelloAck(_)));
            conns.push((r, w));
        }
        // Kill the host behind both sessions' backs and wait until the
        // death is observable (no sleeps: submit() rejects when dead).
        server.host().halt();
        while server.host().submit(vec![1u32]).is_ok() {
            std::thread::yield_now();
        }
        // Session A's job lands on the dead host: Dropped, session alive.
        {
            let (r, w) = &mut conns[0];
            write_frame(w.as_mut(), 10, &Frame::SortJob(vec![3, 1])).unwrap();
            assert_eq!(read_frame(r.as_mut()).unwrap(), (10, Frame::Dropped));
        }
        // Session B restarts the host through its own connection.
        {
            let (r, w) = &mut conns[1];
            write_frame(w.as_mut(), 20, &Frame::Restart).unwrap();
            assert_eq!(read_frame(r.as_mut()).unwrap(), (20, Frame::Ack));
        }
        // Both sessions sort on the restarted host — the session that
        // saw the drop did not need to reconnect.
        for (i, (r, w)) in conns.iter_mut().enumerate() {
            let id = 30 + i as u64;
            write_frame(w.as_mut(), id, &Frame::SortJob(vec![9, 4, 6])).unwrap();
            let (rid, frame) = read_frame(r.as_mut()).unwrap();
            assert_eq!(rid, id);
            let Frame::SortOk(resp) = frame else { panic!("conn {i}: {frame:?}") };
            assert_eq!(resp.sorted, vec![4, 6, 9], "conn {i}");
        }
        // One shutdown ends the host; the sibling sees EOF (duplex
        // close) as a plain disconnect when we drop its connection.
        let (_, w0) = &mut conns[0];
        write_frame(w0.as_mut(), 40, &Frame::Shutdown).unwrap();
        let shutdown_outcome = threads.remove(0).join().unwrap().unwrap();
        assert!(shutdown_outcome, "session 0 saw Shutdown");
        drop(conns); // EOF for session 1
        assert!(!threads.remove(0).join().unwrap().unwrap(), "session 1: plain disconnect");
    }

    #[test]
    fn tcp_accept_loop_serves_concurrent_sessions_and_caps_them() {
        use std::net::TcpStream;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let cfg = ServiceConfig { workers: 2, ..Default::default() };
        let server = std::thread::spawn(move || serve_tcp(listener, cfg, 2));
        let dial = || {
            let s = TcpStream::connect(addr).unwrap();
            (s.try_clone().unwrap(), s)
        };
        // Two concurrent sessions, both fully served.
        let mut live = Vec::new();
        for id in 0..2u64 {
            let (mut r, mut w) = dial();
            write_frame(&mut w, id, &Frame::Hello).unwrap();
            let (_, frame) = read_frame(&mut r).unwrap();
            assert!(matches!(frame, Frame::HelloAck(_)), "conn {id}: {frame:?}");
            write_frame(&mut w, 100 + id, &Frame::SortJob(vec![2, 1, 3])).unwrap();
            let (rid, frame) = read_frame(&mut r).unwrap();
            assert_eq!(rid, 100 + id);
            assert!(matches!(frame, Frame::SortOk(_)), "conn {id}: {frame:?}");
            live.push((r, w));
        }
        // A third connection is over the cap: polite typed refusal.
        {
            let (mut r, mut w) = dial();
            write_frame(&mut w, 7, &Frame::Hello).unwrap();
            let (id, frame) = read_frame(&mut r).unwrap();
            assert_eq!(id, 7);
            let Frame::ErrReply(msg) = frame else { panic!("expected ErrReply, got {frame:?}") };
            assert!(msg.contains("connection limit"), "{msg}");
        }
        // Free a slot; the next dial is admitted. (The slot release
        // races the accept of the new dial, so wait for the handshake
        // to prove admission rather than asserting on the first try.)
        live.remove(0);
        let admitted = loop {
            let (mut r, mut w) = dial();
            write_frame(&mut w, 8, &Frame::Hello).unwrap();
            let (_, frame) = read_frame(&mut r).unwrap();
            match frame {
                Frame::HelloAck(_) => break (r, w),
                Frame::ErrReply(_) => std::thread::yield_now(),
                other => panic!("unexpected {other:?}"),
            }
        };
        let (r, mut w) = admitted;
        write_frame(&mut w, 9, &Frame::Shutdown).unwrap();
        drop((r, w));
        drop(live);
        server.join().unwrap().unwrap();
    }

    #[test]
    fn plain_disconnect_keeps_the_host_alive_for_a_reconnect() {
        let server = Arc::new(
            ShardServer::start(ServiceConfig { workers: 1, ..Default::default() }).unwrap(),
        );
        for round in 0..2 {
            let ((mut r, mut w), (sr, sw)) = duplex();
            let srv = Arc::clone(&server);
            let t = std::thread::spawn(move || srv.serve_conn(sr, sw));
            write_frame(w.as_mut(), 1, &Frame::Hello).unwrap();
            let _ = read_frame(r.as_mut()).unwrap();
            write_frame(w.as_mut(), 2, &Frame::SortJob(vec![2, 1])).unwrap();
            let (_, frame) = read_frame(r.as_mut()).unwrap();
            assert!(matches!(frame, Frame::SortOk(_)), "round {round}: {frame:?}");
            drop((r, w)); // plain disconnect
            assert!(!t.join().unwrap().unwrap(), "host survives the disconnect");
        }
        // The same host served both connections: its metrics persisted.
        assert_eq!(server.host().metrics().completed, 2);
        server.host().shutdown();
    }
}

//! Wire protocol for the shard transport: how a coordinator talks to a
//! remote shard host over a byte stream.
//!
//! The [`super::transport::ShardTransport`] seam was built so that "a
//! wire where the `Vec<Box<dyn ShardTransport>>` is" could drop in
//! without touching routing, recovery or the latency models. This
//! module is that wire's codec: a versioned header plus length-prefixed
//! frames, self-contained (encode into any `io::Write`, decode from any
//! `io::Read`) so the same bytes flow over a `TcpStream` in production
//! and over the in-memory [`duplex`] pipe in deterministic tests.
//!
//! ## Frame layout
//!
//! Every frame is a fixed 16-byte header followed by a length-prefixed
//! payload (all integers little-endian):
//!
//! ```text
//! offset  size  field
//! 0       2     magic   0x4D53 ("MS")
//! 2       1     version the minimum version that can carry this kind
//! 3       1     kind    frame discriminant (see Frame)
//! 4       8     id      correlation id (request id; replies echo it)
//! 12      4     len     payload length in bytes (<= MAX_PAYLOAD)
//! 16      len   payload kind-specific encoding
//! ```
//!
//! Version negotiation happens once per connection: the client opens
//! with [`Frame::Hello`] (its build's [`WIRE_VERSION`] is in the
//! header), the server answers [`Frame::HelloAck`] carrying its
//! [`ServiceConfig`] — the coordinator derives the shard's planner
//! geometry and cost reference from it, so a remote fleet cannot
//! disagree with its hosts — or [`Frame::ErrReply`] when the version is
//! unsupported. Every *other* frame is stamped with the **minimum**
//! version able to carry its kind ([`Frame::wire_version`]), and a
//! reader accepts the whole [`MIN_WIRE_VERSION`]`..=`[`WIRE_VERSION`]
//! range — so a v1 coordinator still reads a newer server's replies
//! (all v1 kinds), while the v2-only [`Frame::SortJobTagged`] and the
//! v3-only admission verdicts ([`Frame::ErrTenantCap`],
//! [`Frame::ErrSaturated`]) are rejected by an older peer at the
//! header, before they can misparse the payload. A
//! decoder that sees a wrong magic or an unknown kind fails the
//! connection rather than resynchronising: the stream is
//! trusted-transport framing, not a self-healing radio protocol.
//!
//! Dropped-reply semantics cross the wire intact: a host that dies with
//! a job in flight answers [`Frame::Dropped`] (or simply closes the
//! connection), and the coordinator surfaces both exactly like an
//! in-process worker dropping its reply channel — the re-route path
//! cannot tell the difference. A sort that fails *as a result* (an
//! engine mismatch, a validation error) is a [`Frame::ErrReply`]: an
//! error reply is a delivered answer, not a dropped one, and fails the
//! request instead of re-routing it, same as the local path.
//!
//! The full operator-facing specification (deploy topology, error
//! codes, tuning knobs) lives in `rust/OPERATIONS.md`.

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, bail, Result};

use super::frontend::{JobTag, Priority};
use super::metrics::Snapshot;
use super::planner::Geometry;
use super::{EngineKind, ServiceConfig, SortResponse};
use crate::sorter::colskip::ColSkipConfig;
use crate::sorter::SortStats;

/// Newest protocol version this build speaks. Bumped on any header or
/// payload change; the server rejects a `Hello` outside
/// [`MIN_WIRE_VERSION`]`..=WIRE_VERSION` with an [`Frame::ErrReply`].
/// v2 added [`Frame::SortJobTagged`] (tenant + priority riding on a
/// sort job, for the coordinator frontend's fair-share admission).
/// v3 added the typed admission verdicts [`Frame::ErrTenantCap`] and
/// [`Frame::ErrSaturated`], so a remote caller of the frontend gets
/// the same machine-readable refusal an in-process caller downcasts
/// out of [`super::frontend::AdmitError`] — not a stringly
/// [`Frame::ErrReply`].
pub const WIRE_VERSION: u8 = 3;

/// Oldest protocol version this build still speaks. Every v1 kind
/// encodes byte-identically under v2, so v1 peers interoperate fully —
/// they just cannot send (or be sent) tagged jobs.
pub const MIN_WIRE_VERSION: u8 = 1;

/// `0x4D53` — "MS" (memsort), the frame magic.
pub const WIRE_MAGIC: u16 = 0x4D53;

/// Upper bound on one frame's payload (64 MiB): a corrupt or hostile
/// length prefix must not allocate unbounded memory.
pub const MAX_PAYLOAD: u32 = 64 << 20;

/// Largest sort job the wire carries, in elements — sized so the
/// *response* frame (the fat direction: `112 + 12n` bytes with argsort
/// and stats) fits [`MAX_PAYLOAD`], not just the `24 + 4n` job frame.
/// Both sides enforce it: a `RemoteTransport` rejects a bigger submit
/// before writing anything, and the shard server answers an `ErrReply`
/// instead of producing an over-cap reply that would kill the
/// connection (and every other job in flight on it). Far beyond one
/// bank-sized chunk, which is what actually crosses the wire; only a
/// plain multi-million-element `submit` can reach it.
pub const MAX_SORT_ELEMS: usize = (MAX_PAYLOAD as usize - 112) / 12;

/// One protocol frame. Client→server kinds: `Hello`, `SortJob`,
/// `GetMetrics`, `Halt`, `Restart`, `Shutdown`. Server→client kinds:
/// `HelloAck`, `SortOk`, `ErrReply`, `Dropped`, `MetricsReply`, `Ack`.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Connection opener; the client's version rides in the header.
    Hello,
    /// Handshake answer: the host's service configuration (geometry,
    /// workers, engine — everything the coordinator's planner and cost
    /// router read).
    HelloAck(ServiceConfig),
    /// Sort these values; the header id correlates the reply.
    SortJob(Vec<u32>),
    /// The completed sort for the echoed id.
    SortOk(SortResponse),
    /// A delivered *error answer* for the echoed id (sort failure,
    /// version rejection, restart failure). Fails the request; never
    /// triggers a re-route.
    ErrReply(String),
    /// The host died with the echoed id's job in flight: the wire form
    /// of a dropped reply. The coordinator re-routes, exactly as if an
    /// in-process worker had dropped its channel.
    Dropped,
    /// Request a full metrics snapshot of the host.
    GetMetrics,
    /// The host's metrics snapshot.
    MetricsReply(Snapshot),
    /// Crash the host the way [`super::transport::ShardTransport::halt`]
    /// does: queued work drains, later submits drop. Fire-and-forget.
    Halt,
    /// Restart the host from its configuration (empty queue, empty
    /// metrics). Answered with `Ack` or `ErrReply`.
    Restart,
    /// Positive answer to a control frame (`Restart`).
    Ack,
    /// Graceful connection + host shutdown. Fire-and-forget; the server
    /// closes the connection after draining.
    Shutdown,
    /// v2: a sort job carrying its request-plane tag (tenant +
    /// priority). The host sorts it exactly like a [`Frame::SortJob`] —
    /// the tag is coordination metadata for the frontend's fair-share
    /// admission, not an execution parameter — but carrying it on the
    /// wire lets a remote coordinator's accounting survive the hop.
    SortJobTagged(JobTag, Vec<u32>),
    /// v3: a delivered admission refusal — the wire form of
    /// [`super::frontend::AdmitError::TenantCap`]. Like
    /// [`Frame::ErrReply`] it is an *answer*, never a re-route; unlike
    /// it, the tenant and its cap survive as typed fields, so a remote
    /// caller sheds load programmatically (429-equivalent) exactly as
    /// an in-process one does. Counts cross as `u64` so 32- and 64-bit
    /// peers agree on the encoding.
    ErrTenantCap { tenant: String, cap: u64 },
    /// v3: the wire form of
    /// [`super::frontend::AdmitError::Saturated`] — which priority
    /// class was shed and the outstanding/limit pair behind the
    /// decision.
    ErrSaturated { priority: Priority, outstanding: u64, limit: u64 },
}

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Frame::Hello => 0,
            Frame::HelloAck(_) => 1,
            Frame::SortJob(_) => 2,
            Frame::SortOk(_) => 3,
            Frame::ErrReply(_) => 4,
            Frame::Dropped => 5,
            Frame::GetMetrics => 6,
            Frame::MetricsReply(_) => 7,
            Frame::Halt => 8,
            Frame::Restart => 9,
            Frame::Ack => 10,
            Frame::Shutdown => 11,
            Frame::SortJobTagged(..) => 12,
            Frame::ErrTenantCap { .. } => 13,
            Frame::ErrSaturated { .. } => 14,
        }
    }

    /// The version stamped into this frame's header: the *minimum*
    /// protocol version that can carry the kind, so a v3 build's v1
    /// frames stay readable by v1 peers. `Hello` is the exception — it
    /// advertises the build's newest version, which is the whole point
    /// of the handshake.
    pub fn wire_version(&self) -> u8 {
        match self {
            Frame::Hello => WIRE_VERSION,
            Frame::SortJobTagged(..) => 2,
            Frame::ErrTenantCap { .. } | Frame::ErrSaturated { .. } => 3,
            _ => MIN_WIRE_VERSION,
        }
    }

    /// The wire frame for an admission refusal: typed verdicts cross
    /// as typed kinds, losslessly recoverable via
    /// [`Frame::admit_error`].
    pub fn from_admit_error(e: &super::frontend::AdmitError) -> Frame {
        use super::frontend::AdmitError;
        match e {
            AdmitError::TenantCap { tenant, cap } => {
                Frame::ErrTenantCap { tenant: tenant.clone(), cap: *cap as u64 }
            }
            AdmitError::Saturated { priority, outstanding, limit } => Frame::ErrSaturated {
                priority: *priority,
                outstanding: *outstanding as u64,
                limit: *limit as u64,
            },
        }
    }

    /// Recover the typed [`super::frontend::AdmitError`] from an
    /// admission-verdict frame; `None` for every other kind, or when a
    /// count does not fit this host's `usize` (a 32-bit peer refusing
    /// to truncate).
    pub fn admit_error(&self) -> Option<super::frontend::AdmitError> {
        use super::frontend::AdmitError;
        match self {
            Frame::ErrTenantCap { tenant, cap } => Some(AdmitError::TenantCap {
                tenant: tenant.clone(),
                cap: usize::try_from(*cap).ok()?,
            }),
            Frame::ErrSaturated { priority, outstanding, limit } => {
                Some(AdmitError::Saturated {
                    priority: *priority,
                    outstanding: usize::try_from(*outstanding).ok()?,
                    limit: usize::try_from(*limit).ok()?,
                })
            }
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------
// Primitive encoders: a small buffer-writer / buffer-reader pair. All
// integers are little-endian; usize crosses the wire as u64 (a 32-bit
// peer rejects oversized values at decode time instead of truncating).
// ---------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_usize(buf: &mut Vec<u8>, v: usize) {
    put_u64(buf, v as u64);
}

fn put_f64(buf: &mut Vec<u8>, v: f64) {
    put_u64(buf, v.to_bits());
}

fn put_bool(buf: &mut Vec<u8>, v: bool) {
    buf.push(u8::from(v));
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_usize(buf, s.len());
    buf.extend_from_slice(s.as_bytes());
}

/// Cursor over a received payload; every read is bounds-checked so a
/// truncated payload is an error, never a panic or a silent zero.
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, at: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow!("truncated payload: wanted {n} bytes at {}", self.at))?;
        let s = &self.buf[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => bail!("invalid bool byte {b}"),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?.try_into().map_err(|_| anyhow!("short u32 read"))?;
        Ok(u32::from_le_bytes(b))
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?.try_into().map_err(|_| anyhow!("short u64 read"))?;
        Ok(u64::from_le_bytes(b))
    }

    fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| anyhow!("value exceeds this host's usize"))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A length prefix about to drive a `Vec` allocation: bound it by
    /// what the enclosing payload can actually hold (`elem` bytes per
    /// element) so a corrupt prefix cannot over-allocate.
    fn len_prefix(&mut self, elem: usize) -> Result<usize> {
        let n = self.usize()?;
        let remaining = self.buf.len() - self.at;
        if n.checked_mul(elem.max(1)).is_none_or(|bytes| bytes > remaining) {
            bail!("length prefix {n} exceeds the remaining {remaining}-byte payload");
        }
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.len_prefix(1)?;
        // Validate on the borrowed slice, then copy exactly once into
        // the owned String (`from_utf8` on a `to_vec` would copy twice).
        Ok(std::str::from_utf8(self.take(n)?)?.to_owned())
    }

    fn finish(self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{} trailing bytes after payload", self.buf.len() - self.at);
        }
        Ok(())
    }
}

fn put_priority(buf: &mut Vec<u8>, p: Priority) {
    buf.push(match p {
        Priority::Interactive => 0,
        Priority::Batch => 1,
    });
}

fn get_priority(c: &mut Cursor) -> Result<Priority> {
    match c.u8()? {
        0 => Ok(Priority::Interactive),
        1 => Ok(Priority::Batch),
        b => bail!("unknown priority discriminant {b}"),
    }
}

fn put_tag(buf: &mut Vec<u8>, tag: &JobTag) {
    put_priority(buf, tag.priority);
    put_str(buf, &tag.tenant);
}

fn get_tag(c: &mut Cursor) -> Result<JobTag> {
    let priority = get_priority(c)?;
    Ok(JobTag { tenant: c.str()?, priority })
}

fn put_u32_slice(buf: &mut Vec<u8>, v: &[u32]) {
    put_usize(buf, v.len());
    // One resize + chunked stores instead of n element-wise
    // `extend_from_slice` calls; byte-identical little-endian layout.
    let at = buf.len();
    buf.resize(at + 4 * v.len(), 0);
    if let Some(dst) = buf.get_mut(at..) {
        for (d, &x) in dst.chunks_exact_mut(4).zip(v) {
            d.copy_from_slice(&x.to_le_bytes());
        }
    }
}

/// Borrowed view of a length-prefixed `u32` array still sitting in the
/// receive buffer: decode defers the copy to the consumer, so a payload
/// that is routed (not read) never materialises a `Vec`.
#[derive(Clone, Copy, Debug)]
pub struct U32sLe<'a>(&'a [u8]);

impl U32sLe<'_> {
    /// Element count.
    pub fn len(&self) -> usize {
        self.0.len() / 4
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Copy the elements out — the single copy the borrowed decode
    /// path performs, counted against the wire traffic model.
    pub fn to_vec(&self) -> Vec<u32> {
        crate::traffic::wire_count_alloc();
        crate::traffic::wire_count_copy(self.0.len() as u64);
        let mut out = Vec::with_capacity(self.0.len() / 4);
        for chunk in self.0.chunks_exact(4) {
            let mut b = [0u8; 4];
            b.copy_from_slice(chunk);
            out.push(u32::from_le_bytes(b));
        }
        out
    }
}

/// Borrowed view of a length-prefixed `u64` array (usize-on-the-wire)
/// still sitting in the receive buffer.
#[derive(Clone, Copy, Debug)]
pub struct U64sLe<'a>(&'a [u8]);

impl U64sLe<'_> {
    /// Element count.
    pub fn len(&self) -> usize {
        self.0.len() / 8
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    /// Copy the elements out as host `usize`s, refusing values that do
    /// not fit (the same 32-bit-peer contract as [`Cursor::usize`]).
    pub fn to_usize_vec(&self) -> Result<Vec<usize>> {
        crate::traffic::wire_count_alloc();
        crate::traffic::wire_count_copy(self.0.len() as u64);
        let mut out = Vec::with_capacity(self.0.len() / 8);
        for chunk in self.0.chunks_exact(8) {
            let mut b = [0u8; 8];
            b.copy_from_slice(chunk);
            let v = u64::from_le_bytes(b);
            out.push(
                usize::try_from(v).map_err(|_| anyhow!("value exceeds this host's usize"))?,
            );
        }
        Ok(out)
    }
}

fn take_u32s<'a>(c: &mut Cursor<'a>) -> Result<U32sLe<'a>> {
    let n = c.len_prefix(4)?;
    Ok(U32sLe(c.take(4 * n)?))
}

fn take_u64s<'a>(c: &mut Cursor<'a>) -> Result<U64sLe<'a>> {
    let n = c.len_prefix(8)?;
    Ok(U64sLe(c.take(8 * n)?))
}

fn get_u32_vec(c: &mut Cursor) -> Result<Vec<u32>> {
    Ok(take_u32s(c)?.to_vec())
}

fn put_stats(buf: &mut Vec<u8>, s: &SortStats) {
    for v in [s.crs, s.res, s.srs, s.sls, s.invalidations, s.drains, s.iterations] {
        put_u64(buf, v);
    }
}

fn get_stats(c: &mut Cursor) -> Result<SortStats> {
    Ok(SortStats {
        crs: c.u64()?,
        res: c.u64()?,
        srs: c.u64()?,
        sls: c.u64()?,
        invalidations: c.u64()?,
        drains: c.u64()?,
        iterations: c.u64()?,
    })
}

fn put_response(buf: &mut Vec<u8>, r: &SortResponse) {
    put_u64(buf, r.id);
    put_u32_slice(buf, &r.sorted);
    put_usize(buf, r.order.len());
    for &row in &r.order {
        put_usize(buf, row);
    }
    put_stats(buf, &r.stats);
    put_u64(buf, r.latency_us);
    put_usize(buf, r.worker);
}

/// Borrowed decode of a [`Frame::SortOk`] payload: the two fat arrays
/// (`sorted`, `order`) stay in the receive buffer until
/// [`SortOkView::into_response`] copies them out, once, at the
/// consumer.
#[derive(Clone, Debug)]
pub struct SortOkView<'a> {
    /// Request id echoed inside the payload (same as the header id).
    pub id: u64,
    /// Sorted values, still wire-resident.
    pub sorted: U32sLe<'a>,
    /// Argsort rows, still wire-resident.
    pub order: U64sLe<'a>,
    /// Itemized operation counts.
    pub stats: SortStats,
    /// Host-measured latency in microseconds.
    pub latency_us: u64,
    /// Worker index that ran the job.
    pub worker: usize,
}

impl SortOkView<'_> {
    /// Materialise the owned [`SortResponse`] — one copy per array.
    pub fn into_response(self) -> Result<SortResponse> {
        Ok(SortResponse {
            id: self.id,
            sorted: self.sorted.to_vec(),
            order: self.order.to_usize_vec()?,
            stats: self.stats,
            latency_us: self.latency_us,
            worker: self.worker,
        })
    }
}

fn take_response_view<'a>(c: &mut Cursor<'a>) -> Result<SortOkView<'a>> {
    let id = c.u64()?;
    let sorted = take_u32s(c)?;
    let order = take_u64s(c)?;
    Ok(SortOkView {
        id,
        sorted,
        order,
        stats: get_stats(c)?,
        latency_us: c.u64()?,
        worker: c.usize()?,
    })
}

fn get_response(c: &mut Cursor) -> Result<SortResponse> {
    take_response_view(c)?.into_response()
}

fn put_config(buf: &mut Vec<u8>, cfg: &ServiceConfig) {
    put_usize(buf, cfg.workers);
    put_u32(buf, cfg.colskip.width);
    put_usize(buf, cfg.colskip.k);
    put_bool(buf, cfg.colskip.skip_leading);
    put_bool(buf, cfg.colskip.stall_on_duplicates);
    put_usize(buf, cfg.banks);
    buf.push(match cfg.engine {
        EngineKind::Native => 0,
        EngineKind::Pjrt => 1,
        EngineKind::Hybrid => 2,
    });
    // The artifacts directory is host-local (the coordinator never
    // loads a remote host's AOT artifacts) but is carried so the
    // handshake config round-trips; non-UTF-8 paths degrade lossily.
    put_str(buf, &cfg.artifacts_dir.to_string_lossy());
    put_usize(buf, cfg.queue_depth);
    put_usize(buf, cfg.geometry.bank_sizes.len());
    for &b in &cfg.geometry.bank_sizes {
        put_usize(buf, b);
    }
    put_u32(buf, cfg.geometry.width);
    put_usize(buf, cfg.geometry.merge_fanout);
}

fn get_config(c: &mut Cursor) -> Result<ServiceConfig> {
    let workers = c.usize()?;
    let colskip = ColSkipConfig {
        width: c.u32()?,
        k: c.usize()?,
        skip_leading: c.bool()?,
        stall_on_duplicates: c.bool()?,
    };
    let banks = c.usize()?;
    let engine = match c.u8()? {
        0 => EngineKind::Native,
        1 => EngineKind::Pjrt,
        2 => EngineKind::Hybrid,
        b => bail!("unknown engine discriminant {b}"),
    };
    let artifacts_dir = std::path::PathBuf::from(c.str()?);
    let queue_depth = c.usize()?;
    let n = c.len_prefix(8)?;
    let bank_sizes = (0..n).map(|_| c.usize()).collect::<Result<Vec<_>>>()?;
    let geometry = Geometry { bank_sizes, width: c.u32()?, merge_fanout: c.usize()? };
    Ok(ServiceConfig { workers, colskip, banks, engine, artifacts_dir, queue_depth, geometry })
}

fn put_snapshot(buf: &mut Vec<u8>, s: &Snapshot) {
    for v in [
        s.completed,
        s.errors,
        s.elements,
        s.sim_cycles,
        s.sim_crs,
        s.hier_completed,
        s.hier_elements,
        s.hier_chunks,
        s.merge_cycles,
        s.merge_comparisons,
        s.p50_us,
        s.p99_us,
        s.max_us,
    ] {
        put_u64(buf, v);
    }
    put_f64(buf, s.cycles_per_number);
    put_usize(buf, s.class_cyc_per_num.len());
    for &v in &s.class_cyc_per_num {
        put_f64(buf, v);
    }
    put_usize(buf, s.class_elements.len());
    for &v in &s.class_elements {
        put_u64(buf, v);
    }
}

fn get_snapshot(c: &mut Cursor) -> Result<Snapshot> {
    let mut u = || c.u64();
    let (completed, errors, elements, sim_cycles, sim_crs) = (u()?, u()?, u()?, u()?, u()?);
    let (hier_completed, hier_elements, hier_chunks) = (u()?, u()?, u()?);
    let (merge_cycles, merge_comparisons) = (u()?, u()?);
    let (p50_us, p99_us, max_us) = (u()?, u()?, u()?);
    let cycles_per_number = c.f64()?;
    let n = c.len_prefix(8)?;
    let class_cyc_per_num = (0..n).map(|_| c.f64()).collect::<Result<Vec<_>>>()?;
    let n = c.len_prefix(8)?;
    let class_elements = (0..n).map(|_| c.u64()).collect::<Result<Vec<_>>>()?;
    Ok(Snapshot {
        completed,
        errors,
        elements,
        sim_cycles,
        sim_crs,
        hier_completed,
        hier_elements,
        hier_chunks,
        merge_cycles,
        merge_comparisons,
        p50_us,
        p99_us,
        max_us,
        cycles_per_number,
        class_cyc_per_num,
        class_elements,
    })
}

// ---------------------------------------------------------------------
// Frame I/O
// ---------------------------------------------------------------------

/// Encode `frame` (correlated by `id`) into the caller's reusable
/// buffer: header first (with a length placeholder), payload in place
/// behind it, then the length patched in. One buffer, one pass — no
/// intermediate payload `Vec` and, with a warm `buf`, no allocation.
/// Byte-identical to [`encode_frame`].
pub fn encode_frame_into(buf: &mut Vec<u8>, id: u64, frame: &Frame) {
    buf.clear();
    buf.extend_from_slice(&WIRE_MAGIC.to_le_bytes());
    buf.push(frame.wire_version());
    buf.push(frame.kind());
    buf.extend_from_slice(&id.to_le_bytes());
    buf.extend_from_slice(&0u32.to_le_bytes()); // len, patched below
    match frame {
        Frame::Hello
        | Frame::Dropped
        | Frame::GetMetrics
        | Frame::Halt
        | Frame::Restart
        | Frame::Ack
        | Frame::Shutdown => {}
        Frame::HelloAck(cfg) => put_config(buf, cfg),
        Frame::SortJob(data) => put_u32_slice(buf, data),
        Frame::SortOk(resp) => put_response(buf, resp),
        Frame::ErrReply(msg) => put_str(buf, msg),
        Frame::MetricsReply(snap) => put_snapshot(buf, snap),
        Frame::SortJobTagged(tag, data) => {
            put_tag(buf, tag);
            put_u32_slice(buf, data);
        }
        Frame::ErrTenantCap { tenant, cap } => {
            put_str(buf, tenant);
            put_u64(buf, *cap);
        }
        Frame::ErrSaturated { priority, outstanding, limit } => {
            put_priority(buf, *priority);
            put_u64(buf, *outstanding);
            put_u64(buf, *limit);
        }
    }
    let payload_len = buf.len() - 16;
    debug_assert!(payload_len <= MAX_PAYLOAD as usize, "oversized frame payload");
    if let Some(slot) = buf.get_mut(12..16) {
        slot.copy_from_slice(&(payload_len as u32).to_le_bytes());
    }
    crate::traffic::wire_count_copy(buf.len() as u64);
}

/// Encode `frame` into a fresh buffer. Kept separate from
/// [`write_frame`] so a shared writer can hold its lock for exactly
/// one `write_all`; hot paths reuse a buffer via [`encode_frame_into`]
/// (or [`FrameSink`]) instead.
pub fn encode_frame(id: u64, frame: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    encode_frame_into(&mut buf, id, frame);
    crate::traffic::wire_count_alloc();
    buf
}

/// Write one frame. The whole frame goes out in a single `write_all`,
/// so concurrent writers serialised by a mutex never interleave frames.
pub fn write_frame(w: &mut dyn Write, id: u64, frame: &Frame) -> io::Result<()> {
    w.write_all(&encode_frame(id, frame))?;
    w.flush()
}

/// A write half paired with its reusable encode buffer: every
/// [`FrameSink::write_frame`] encodes in place and goes out in one
/// `write_all`, so a warm sink writes frames with zero allocations.
/// This is what the shard server's shared writer and the remote
/// transport's per-link writer hold behind their mutexes — the guard
/// scopes exactly one frame write, same as the free [`write_frame`].
pub struct FrameSink {
    w: Box<dyn Write + Send>,
    buf: Vec<u8>,
}

impl FrameSink {
    /// Wrap a write half; the encode buffer starts empty and warms up
    /// to the largest frame this sink has carried.
    pub fn new(w: Box<dyn Write + Send>) -> Self {
        FrameSink { w, buf: Vec::new() }
    }

    /// Encode into the reusable buffer and write the whole frame in a
    /// single `write_all`.
    pub fn write_frame(&mut self, id: u64, frame: &Frame) -> io::Result<()> {
        encode_frame_into(&mut self.buf, id, frame);
        self.w.write_all(&self.buf)?;
        self.w.flush()
    }
}

/// Read one frame (blocking). `Err` means the connection is unusable —
/// EOF, a short read, bad magic, an unsupported version on a non-Hello
/// frame, or a malformed payload; framing never resynchronises.
///
/// Any version in [`MIN_WIRE_VERSION`]`..=`[`WIRE_VERSION`] is
/// accepted — frames are stamped with the minimum version carrying
/// their kind, so a v1 peer's whole vocabulary decodes here and this
/// build's v1-kind frames decode there. Use [`read_hello`] for the
/// connection opener, which tolerates *future* versions so the server
/// can reject them politely.
pub fn read_frame(r: &mut dyn Read) -> Result<(u64, Frame)> {
    let (id, version, kind, payload) = read_raw(r)?;
    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version) {
        bail!(
            "unsupported wire version {version} (this build speaks \
             {MIN_WIRE_VERSION}..={WIRE_VERSION})"
        );
    }
    decode(id, kind, &payload)
}

/// Read the connection-opening frame, tolerating a version mismatch so
/// the server can reject it politely: returns `(id, client_version)`
/// when the frame is a structurally-valid `Hello` of *any* version.
pub fn read_hello(r: &mut dyn Read) -> Result<(u64, u8)> {
    let (id, version, kind, payload) = read_raw(r)?;
    if kind != 0 || !payload.is_empty() {
        bail!("connection must open with Hello (got kind {kind})");
    }
    Ok((id, version))
}

/// Read one raw frame into the caller's reusable scratch buffer,
/// returning `(id, version, kind)` with the payload left in `scratch`.
/// A warm scratch (capacity ≥ the payload) is neither reallocated nor
/// zero-filled — the two hidden copies the fresh-`Vec` path pays on
/// every frame. The header is parsed through the bounds-checked
/// [`Cursor`], so a malformed frame is an `Err`, never a panic.
fn read_raw_into(r: &mut dyn Read, scratch: &mut Vec<u8>) -> Result<(u64, u8, u8)> {
    let mut header = [0u8; 16];
    r.read_exact(&mut header)?;
    let mut h = Cursor::new(&header);
    let magic = u16::from_le_bytes([h.u8()?, h.u8()?]);
    if magic != WIRE_MAGIC {
        bail!("bad frame magic {magic:#06x}");
    }
    let version = h.u8()?;
    let kind = h.u8()?;
    let id = h.u64()?;
    let len = h.u32()?;
    if len > MAX_PAYLOAD {
        bail!("frame payload of {len} bytes exceeds the {MAX_PAYLOAD}-byte cap");
    }
    let len = len as usize;
    if len > scratch.capacity() {
        crate::traffic::wire_count_alloc();
    }
    // `resize` zero-fills only the grown tail; count exactly that.
    crate::traffic::wire_count_copy(len.saturating_sub(scratch.len()) as u64);
    scratch.resize(len, 0);
    r.read_exact(scratch.as_mut_slice())?;
    Ok((id, version, kind))
}

fn read_raw(r: &mut dyn Read) -> Result<(u64, u8, u8, Vec<u8>)> {
    let mut payload = Vec::new();
    let (id, version, kind) = read_raw_into(r, &mut payload)?;
    Ok((id, version, kind, payload))
}

fn decode(id: u64, kind: u8, payload: &[u8]) -> Result<(u64, Frame)> {
    let mut c = Cursor::new(payload);
    let frame = match kind {
        0 => Frame::Hello,
        1 => Frame::HelloAck(get_config(&mut c)?),
        2 => Frame::SortJob(get_u32_vec(&mut c)?),
        3 => Frame::SortOk(get_response(&mut c)?),
        4 => Frame::ErrReply(c.str()?),
        5 => Frame::Dropped,
        6 => Frame::GetMetrics,
        7 => Frame::MetricsReply(get_snapshot(&mut c)?),
        8 => Frame::Halt,
        9 => Frame::Restart,
        10 => Frame::Ack,
        11 => Frame::Shutdown,
        12 => {
            let tag = get_tag(&mut c)?;
            Frame::SortJobTagged(tag, get_u32_vec(&mut c)?)
        }
        13 => Frame::ErrTenantCap { tenant: c.str()?, cap: c.u64()? },
        14 => Frame::ErrSaturated {
            priority: get_priority(&mut c)?,
            outstanding: c.u64()?,
            limit: c.u64()?,
        },
        k => bail!("unknown frame kind {k}"),
    };
    c.finish()?;
    Ok((id, frame))
}

/// Borrowed decode of one frame: the hot kinds ([`Frame::SortJob`],
/// [`Frame::SortJobTagged`], [`Frame::SortOk`]) keep their fat arrays
/// in the receive buffer, everything else decodes owned exactly as
/// [`read_frame`] would. The session loops decode through this so the
/// values cross from wire bytes to working memory exactly once.
#[derive(Debug)]
pub enum FrameView<'a> {
    /// A sort job whose data is still wire-resident.
    SortJob(U32sLe<'a>),
    /// A tagged sort job; the small tag is owned, the data borrowed.
    SortJobTagged(JobTag, U32sLe<'a>),
    /// A completed sort whose arrays are still wire-resident.
    SortOk(SortOkView<'a>),
    /// Any other kind, decoded owned (all cold / fixed-size).
    Owned(Frame),
}

impl FrameView<'_> {
    /// Materialise the owned [`Frame`] (one copy per borrowed array) —
    /// the compatibility path for consumers that need ownership.
    pub fn into_frame(self) -> Result<Frame> {
        Ok(match self {
            FrameView::SortJob(data) => Frame::SortJob(data.to_vec()),
            FrameView::SortJobTagged(tag, data) => Frame::SortJobTagged(tag, data.to_vec()),
            FrameView::SortOk(view) => Frame::SortOk(view.into_response()?),
            FrameView::Owned(frame) => frame,
        })
    }
}

/// Decode one payload as a [`FrameView`]; same validation (including
/// the trailing-bytes check) as [`decode`].
pub fn decode_view(id: u64, kind: u8, payload: &[u8]) -> Result<(u64, FrameView<'_>)> {
    let mut c = Cursor::new(payload);
    let view = match kind {
        2 => FrameView::SortJob(take_u32s(&mut c)?),
        3 => FrameView::SortOk(take_response_view(&mut c)?),
        12 => {
            let tag = get_tag(&mut c)?;
            FrameView::SortJobTagged(tag, take_u32s(&mut c)?)
        }
        k => return decode(id, k, payload).map(|(id, f)| (id, FrameView::Owned(f))),
    };
    c.finish()?;
    Ok((id, view))
}

/// Read one frame as a borrowed [`FrameView`] over the caller's
/// reusable scratch buffer. Same version window and error contract as
/// [`read_frame`]; a warm scratch makes the receive path
/// allocation-free for every kind.
pub fn read_frame_view<'a>(
    r: &mut dyn Read,
    scratch: &'a mut Vec<u8>,
) -> Result<(u64, FrameView<'a>)> {
    let (id, version, kind) = read_raw_into(r, scratch)?;
    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version) {
        bail!(
            "unsupported wire version {version} (this build speaks \
             {MIN_WIRE_VERSION}..={WIRE_VERSION})"
        );
    }
    decode_view(id, kind, scratch)
}

// ---------------------------------------------------------------------
// In-memory duplex: the deterministic test stand-in for a TcpStream.
// ---------------------------------------------------------------------

/// One directed byte half of a connection: reader and writer halves of
/// one [`pipe`]. Dropping the writer closes the pipe (EOF at the
/// reader), like a peer closing its socket.
struct PipeState {
    buf: VecDeque<u8>,
    closed: bool,
}

struct Pipe {
    state: Mutex<PipeState>,
    ready: Condvar,
}

/// Read half of a [`pipe`]; dropping it makes later writes fail like
/// `EPIPE` (bytes toward a dead reader must not buffer forever).
pub struct PipeReader(Arc<Pipe>);

/// Write half of a [`pipe`]; dropping it is EOF at the reader.
pub struct PipeWriter(Arc<Pipe>);

/// An in-memory unidirectional byte pipe with blocking reads, EOF on
/// writer drop and broken-pipe write errors on reader drop —
/// `io::Read`/`io::Write` over `Mutex` + `Condvar`, no sockets
/// involved.
pub fn pipe() -> (PipeReader, PipeWriter) {
    let p = Arc::new(Pipe {
        state: Mutex::new(PipeState { buf: VecDeque::new(), closed: false }),
        ready: Condvar::new(),
    });
    (PipeReader(Arc::clone(&p)), PipeWriter(p))
}

impl Read for PipeReader {
    fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
        if out.is_empty() {
            return Ok(0);
        }
        let mut st = self.0.state.lock().expect("pipe poisoned");
        while st.buf.is_empty() && !st.closed {
            st = self.0.ready.wait(st).expect("pipe poisoned");
        }
        if st.buf.is_empty() {
            return Ok(0); // closed: EOF
        }
        // Bulk-copy out of the ring's two contiguous runs — this pipe
        // is the bench's stand-in for a socket, so per-byte pops under
        // the lock would show up as fictitious wire overhead.
        let n = out.len().min(st.buf.len());
        let (a, b) = st.buf.as_slices();
        let from_a = a.len().min(n);
        out[..from_a].copy_from_slice(&a[..from_a]);
        if from_a < n {
            out[from_a..n].copy_from_slice(&b[..n - from_a]);
        }
        st.buf.drain(..n);
        Ok(n)
    }
}

impl Write for PipeWriter {
    fn write(&mut self, data: &[u8]) -> io::Result<usize> {
        let mut st = self.0.state.lock().expect("pipe poisoned");
        if st.closed {
            return Err(io::Error::new(io::ErrorKind::BrokenPipe, "pipe closed"));
        }
        st.buf.extend(data.iter().copied());
        self.0.ready.notify_all();
        Ok(data.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

impl Drop for PipeWriter {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().expect("pipe poisoned");
        st.closed = true;
        self.0.ready.notify_all();
    }
}

impl Drop for PipeReader {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().expect("pipe poisoned");
        st.closed = true;
        self.0.ready.notify_all();
    }
}

/// One side of a bidirectional connection: the read half and the write
/// half handed to a reader thread and a shared writer independently
/// (the same split a `TcpStream::try_clone` pair gives).
pub type WireConn = (Box<dyn Read + Send>, Box<dyn Write + Send>);

/// An in-memory full-duplex connection: returns the client-side and
/// server-side [`WireConn`]s of a fresh link. Deterministic (no
/// sockets, no ports), used by the remote-transport tests and benches.
pub fn duplex() -> (WireConn, WireConn) {
    let (client_read, server_write) = pipe();
    let (server_read, client_write) = pipe();
    (
        (Box::new(client_read), Box::new(client_write)),
        (Box::new(server_read), Box::new(server_write)),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(id: u64, frame: Frame) -> (u64, Frame) {
        let bytes = encode_frame(id, &frame);
        read_frame(&mut &bytes[..]).expect("round trip decodes")
    }

    fn sample_response() -> SortResponse {
        SortResponse {
            id: 77,
            sorted: vec![1, 2, 2, 9, u32::MAX],
            order: vec![4, 0, 3, 1, 2],
            stats: SortStats {
                crs: 40,
                res: 11,
                srs: 3,
                sls: 2,
                invalidations: 1,
                drains: 2,
                iterations: 3,
            },
            latency_us: 123,
            worker: 1,
        }
    }

    #[test]
    fn every_frame_kind_round_trips() {
        let frames = [
            Frame::Hello,
            Frame::HelloAck(ServiceConfig::default()),
            Frame::SortJob(vec![3, 1, 2, u32::MAX, 0]),
            Frame::SortJob(Vec::new()),
            Frame::SortOk(sample_response()),
            Frame::ErrReply("engine mismatch on request 7".into()),
            Frame::ErrReply(String::new()),
            Frame::Dropped,
            Frame::GetMetrics,
            Frame::MetricsReply(super::super::metrics::ServiceMetrics::new().snapshot()),
            Frame::Halt,
            Frame::Restart,
            Frame::Ack,
            Frame::Shutdown,
            Frame::SortJobTagged(
                JobTag { tenant: "acme".into(), priority: Priority::Interactive },
                vec![3, 1, 2],
            ),
            Frame::SortJobTagged(
                JobTag { tenant: String::new(), priority: Priority::Batch },
                Vec::new(),
            ),
            Frame::ErrTenantCap { tenant: "acme".into(), cap: 8 },
            Frame::ErrTenantCap { tenant: String::new(), cap: 0 },
            Frame::ErrSaturated { priority: Priority::Batch, outstanding: 64, limit: 64 },
            Frame::ErrSaturated { priority: Priority::Interactive, outstanding: 70, limit: 64 },
        ];
        for (i, frame) in frames.into_iter().enumerate() {
            let id = 0x1234_5678_9ABC_DEF0 ^ i as u64;
            let (rid, rframe) = roundtrip(id, frame.clone());
            assert_eq!(rid, id);
            assert_eq!(rframe, frame);
        }
    }

    #[test]
    fn frames_are_stamped_with_their_minimum_version() {
        // Every v1 kind keeps the v1 stamp, so a v1 peer reads a v3
        // build's replies; the tagged job keeps v2, the admission
        // verdicts carry v3, and the advertising Hello carries the
        // build's newest version.
        let tag = JobTag { tenant: "t".into(), priority: Priority::Batch };
        assert_eq!(encode_frame(1, &Frame::Hello)[2], WIRE_VERSION);
        assert_eq!(encode_frame(1, &Frame::SortJobTagged(tag, vec![1]))[2], 2);
        assert_eq!(encode_frame(1, &Frame::ErrTenantCap { tenant: "t".into(), cap: 4 })[2], 3);
        let sat = Frame::ErrSaturated { priority: Priority::Batch, outstanding: 9, limit: 8 };
        assert_eq!(encode_frame(1, &sat)[2], 3);
        for frame in [
            Frame::SortJob(vec![1]),
            Frame::SortOk(sample_response()),
            Frame::ErrReply("e".into()),
            Frame::Dropped,
            Frame::GetMetrics,
            Frame::Halt,
            Frame::Restart,
            Frame::Ack,
            Frame::Shutdown,
        ] {
            assert_eq!(encode_frame(1, &frame)[2], MIN_WIRE_VERSION, "{frame:?}");
        }
        // And the whole supported range decodes.
        let mut bytes = encode_frame(7, &Frame::SortJob(vec![9]));
        for v in MIN_WIRE_VERSION..=WIRE_VERSION {
            bytes[2] = v;
            assert_eq!(read_frame(&mut &bytes[..]).unwrap().1, Frame::SortJob(vec![9]));
        }
        // Version 0 (below the floor) is rejected like a future one.
        bytes[2] = 0;
        assert!(read_frame(&mut &bytes[..]).unwrap_err().to_string().contains("version"));
    }

    #[test]
    fn admission_verdicts_convert_losslessly() {
        // The satellite contract: a remote caller of the frontend gets
        // the *same typed error* an in-process caller downcasts — the
        // AdmitError → Frame → wire → Frame → AdmitError loop is the
        // identity, for every variant and priority class.
        use super::super::frontend::AdmitError;
        let verdicts = [
            AdmitError::TenantCap { tenant: "acme".into(), cap: 8 },
            AdmitError::TenantCap { tenant: String::new(), cap: 0 },
            AdmitError::Saturated { priority: Priority::Batch, outstanding: 64, limit: 64 },
            AdmitError::Saturated { priority: Priority::Interactive, outstanding: 70, limit: 64 },
        ];
        for verdict in verdicts {
            let frame = Frame::from_admit_error(&verdict);
            let bytes = encode_frame(42, &frame);
            let (id, decoded) = read_frame(&mut &bytes[..]).expect("verdict decodes");
            assert_eq!(id, 42);
            assert_eq!(decoded, frame);
            assert_eq!(decoded.admit_error(), Some(verdict));
        }
        // Non-verdict kinds recover nothing.
        assert_eq!(Frame::ErrReply("saturated".into()).admit_error(), None);
        assert_eq!(Frame::Dropped.admit_error(), None);
        // A corrupt priority discriminant fails the decode, exactly
        // like the tagged-job path.
        let sat = Frame::ErrSaturated { priority: Priority::Batch, outstanding: 1, limit: 1 };
        let mut bytes = encode_frame(1, &sat);
        bytes[16] = 9; // payload starts at 16 with the priority byte
        assert!(read_frame(&mut &bytes[..]).unwrap_err().to_string().contains("priority"));
    }

    #[test]
    fn response_without_argsort_round_trips() {
        // A pure-PJRT backend returns no row provenance; the empty
        // order must survive the wire as empty, not as len zeros.
        let mut resp = sample_response();
        resp.order = Vec::new();
        let (_, frame) = roundtrip(1, Frame::SortOk(resp.clone()));
        assert_eq!(frame, Frame::SortOk(resp));
    }

    #[test]
    fn config_with_custom_geometry_round_trips() {
        let cfg = ServiceConfig {
            workers: 3,
            banks: 4,
            engine: EngineKind::Hybrid,
            queue_depth: 17,
            colskip: ColSkipConfig {
                width: 16,
                k: 5,
                skip_leading: false,
                stall_on_duplicates: false,
            },
            artifacts_dir: "some/artifacts".into(),
            geometry: Geometry::from_spec("512x16").unwrap(),
        };
        let (_, frame) = roundtrip(9, Frame::HelloAck(cfg.clone()));
        assert_eq!(frame, Frame::HelloAck(cfg));
    }

    #[test]
    fn metrics_snapshot_with_traffic_round_trips() {
        let m = super::super::metrics::ServiceMetrics::new();
        m.record(12, &SortStats { crs: 2048, ..Default::default() }, 256);
        m.record(15, &SortStats { crs: 30_000, drains: 7, ..Default::default() }, 1024);
        m.record_error();
        m.record_hierarchical(5000, 5, 10_000, 60_000);
        let snap = m.snapshot();
        let (_, frame) = roundtrip(2, Frame::MetricsReply(snap.clone()));
        assert_eq!(frame, Frame::MetricsReply(snap));
    }

    #[test]
    fn frame_sizes_match_the_documented_overhead_model() {
        // EXPERIMENTS.md §Remote transport (cross-checked by
        // python/fleet_model.py): a SortJob frame is 24 + 4n bytes, a
        // full SortOk (argsort + stats) 112 + 12n.
        let n = 1024usize;
        assert_eq!(encode_frame(1, &Frame::SortJob(vec![0u32; n])).len(), 24 + 4 * n);
        let resp = SortResponse {
            id: 1,
            sorted: vec![0u32; n],
            order: (0..n).collect(),
            stats: SortStats::default(),
            latency_us: 0,
            worker: 0,
        };
        assert_eq!(encode_frame(1, &Frame::SortOk(resp)).len(), 112 + 12 * n);
        // A tagged job adds the 1-byte priority and the length-prefixed
        // tenant to the v1 job frame: 33 + t + 4n bytes.
        let tag = JobTag { tenant: "tenant-7".into(), priority: Priority::Batch };
        let t = tag.tenant.len();
        assert_eq!(
            encode_frame(1, &Frame::SortJobTagged(tag, vec![0u32; n])).len(),
            33 + t + 4 * n
        );
        // The job cap is derived from the response model: the largest
        // accepted job's reply still fits the payload cap, and one
        // more element would not.
        assert!(112 + 12 * MAX_SORT_ELEMS <= MAX_PAYLOAD as usize);
        assert!(112 + 12 * (MAX_SORT_ELEMS + 1) > MAX_PAYLOAD as usize);
    }

    #[test]
    fn bad_magic_version_kind_and_truncation_are_errors() {
        let good = encode_frame(5, &Frame::SortJob(vec![1, 2, 3]));
        // Magic.
        let mut bad = good.clone();
        bad[0] ^= 0xFF;
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("magic"));
        // Version.
        let mut bad = good.clone();
        bad[2] = WIRE_VERSION + 1;
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("version"));
        // Unknown kind.
        let mut bad = good.clone();
        bad[3] = 200;
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("kind"));
        // Truncated payload (header promises more than the stream has).
        let bad = &good[..good.len() - 2];
        assert!(read_frame(&mut &bad[..]).is_err());
        // Trailing garbage inside the declared payload.
        let mut bad = encode_frame(5, &Frame::Dropped);
        bad[12] = 3; // declare a 3-byte payload on a payload-less kind
        bad.extend_from_slice(&[0, 0, 0]);
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("trailing"));
        // Oversized length prefix.
        let mut bad = encode_frame(5, &Frame::Dropped);
        bad[12..16].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        assert!(read_frame(&mut &bad[..]).unwrap_err().to_string().contains("cap"));
    }

    #[test]
    fn corrupt_inner_length_prefix_cannot_overallocate() {
        // A SortJob whose element-count prefix claims more elements
        // than the payload could hold must error out of the bounded
        // reader, not attempt a huge Vec.
        let mut bytes = encode_frame(1, &Frame::SortJob(vec![1, 2, 3]));
        // Payload starts at 16; its first 8 bytes are the count.
        bytes[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = read_frame(&mut &bytes[..]).unwrap_err().to_string();
        assert!(err.contains("length prefix") || err.contains("usize"), "{err}");
    }

    #[test]
    fn hello_of_a_future_version_is_readable_as_hello() {
        let mut bytes = encode_frame(3, &Frame::Hello);
        bytes[2] = WIRE_VERSION + 9;
        let (id, version) = read_hello(&mut &bytes[..]).unwrap();
        assert_eq!((id, version), (3, WIRE_VERSION + 9));
        // ...while the strict reader refuses it.
        assert!(read_frame(&mut &bytes[..]).is_err());
        // And a non-Hello opener is rejected by the hello reader.
        let bytes = encode_frame(3, &Frame::SortJob(vec![1]));
        assert!(read_hello(&mut &bytes[..]).unwrap_err().to_string().contains("Hello"));
    }

    #[test]
    fn frames_concatenate_on_one_stream() {
        let mut stream = Vec::new();
        stream.extend_from_slice(&encode_frame(1, &Frame::Hello));
        stream.extend_from_slice(&encode_frame(2, &Frame::SortJob(vec![9, 8])));
        stream.extend_from_slice(&encode_frame(3, &Frame::Shutdown));
        let mut r: &[u8] = &stream;
        assert_eq!(read_frame(&mut r).unwrap(), (1, Frame::Hello));
        assert_eq!(read_frame(&mut r).unwrap(), (2, Frame::SortJob(vec![9, 8])));
        assert_eq!(read_frame(&mut r).unwrap(), (3, Frame::Shutdown));
        assert!(read_frame(&mut r).is_err(), "EOF after the last frame");
    }

    fn sample_frames() -> Vec<Frame> {
        vec![
            Frame::Hello,
            Frame::HelloAck(ServiceConfig::default()),
            Frame::SortJob(vec![3, 1, 2, u32::MAX, 0]),
            Frame::SortJob(Vec::new()),
            Frame::SortOk(sample_response()),
            Frame::ErrReply("engine mismatch".into()),
            Frame::Dropped,
            Frame::GetMetrics,
            Frame::MetricsReply(super::super::metrics::ServiceMetrics::new().snapshot()),
            Frame::Halt,
            Frame::Restart,
            Frame::Ack,
            Frame::Shutdown,
            Frame::SortJobTagged(
                JobTag { tenant: "acme".into(), priority: Priority::Interactive },
                vec![9, 9, 1],
            ),
            Frame::ErrTenantCap { tenant: "acme".into(), cap: 8 },
            Frame::ErrSaturated { priority: Priority::Batch, outstanding: 64, limit: 64 },
        ]
    }

    #[test]
    fn encode_into_a_reused_buffer_is_byte_identical_to_encode() {
        // One buffer across every kind, fat frames before small ones,
        // so a stale longer payload would surface as trailing bytes.
        let mut buf = Vec::new();
        for (i, frame) in sample_frames().into_iter().enumerate() {
            let id = 0xA5A5_0000 ^ i as u64;
            encode_frame_into(&mut buf, id, &frame);
            assert_eq!(buf, encode_frame(id, &frame), "{frame:?}");
        }
    }

    #[test]
    fn borrowed_views_decode_identically_to_owned_frames() {
        // Same scratch across every kind: the view decode must agree
        // with the owned decode frame-for-frame, and a previous (fatter)
        // payload must never bleed into the next.
        let mut scratch = Vec::new();
        for (i, frame) in sample_frames().into_iter().enumerate() {
            let id = 0x77 ^ i as u64;
            let bytes = encode_frame(id, &frame);
            let (vid, view) = read_frame_view(&mut &bytes[..], &mut scratch).expect("view");
            assert_eq!(vid, id);
            assert_eq!(view.into_frame().expect("materialise"), frame, "kind {i}");
        }
    }

    #[test]
    fn sort_ok_view_exposes_the_arrays_without_copying() {
        let resp = sample_response();
        let bytes = encode_frame(5, &Frame::SortOk(resp.clone()));
        let mut scratch = Vec::new();
        let (_, view) = read_frame_view(&mut &bytes[..], &mut scratch).expect("view");
        match view {
            FrameView::SortOk(v) => {
                assert_eq!(v.id, resp.id);
                assert_eq!(v.sorted.len(), resp.sorted.len());
                assert_eq!(v.order.len(), resp.order.len());
                assert!(!v.sorted.is_empty() && !v.order.is_empty());
                assert_eq!(v.stats, resp.stats);
                let owned = v.into_response().expect("materialise");
                assert_eq!(owned, resp);
            }
            other => panic!("expected SortOk view, got {other:?}"),
        }
    }

    #[test]
    fn view_reader_rejects_what_the_owned_reader_rejects() {
        let mut scratch = Vec::new();
        // Unsupported version.
        let mut bytes = encode_frame(1, &Frame::SortJob(vec![1]));
        bytes[2] = WIRE_VERSION + 1;
        let err = read_frame_view(&mut &bytes[..], &mut scratch).unwrap_err();
        assert!(err.to_string().contains("version"));
        // Trailing bytes inside a hot-kind payload.
        let mut bytes = encode_frame(1, &Frame::SortJob(vec![1]));
        let len = (bytes.len() - 16 + 1) as u32;
        bytes[12..16].copy_from_slice(&len.to_le_bytes());
        bytes.push(0);
        let err = read_frame_view(&mut &bytes[..], &mut scratch).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
        // Oversized order values refuse to materialise only where usize
        // is too small; the length caps still hold on every host.
        let mut bad = encode_frame(1, &Frame::SortJob(vec![1, 2, 3]));
        bad[16..24].copy_from_slice(&u64::MAX.to_le_bytes());
        assert!(read_frame_view(&mut &bad[..], &mut scratch).is_err());
    }

    #[test]
    fn warm_buffers_land_exactly_on_the_after_model() {
        use crate::traffic::{roundtrip_bytes_after, wire_counters, wire_counters_reset};
        let n = 1024usize;
        let job = Frame::SortJob((0..n as u32).rev().collect());
        let ok = Frame::SortOk(SortResponse {
            id: 9,
            sorted: (0..n as u32).collect(),
            order: (0..n).rev().collect(),
            stats: SortStats::default(),
            latency_us: 3,
            worker: 0,
        });
        let mut wire_buf = Vec::new();
        // One scratch per reader thread, as deployed: the server's
        // session loop reads jobs, the client's reply reader reads oks.
        let mut server_scratch = Vec::new();
        let mut client_scratch = Vec::new();
        // First lap warms the buffers; second lap is the measured
        // steady state and must land on roundtrip_bytes_after to the
        // byte, with exactly the three consumer-side copies allocating.
        for measured in [false, true] {
            wire_counters_reset();
            encode_frame_into(&mut wire_buf, 9, &job);
            {
                let (_, view) =
                    read_frame_view(&mut &wire_buf[..], &mut server_scratch).expect("job");
                match view {
                    FrameView::SortJob(data) => assert_eq!(data.to_vec().len(), n),
                    other => panic!("expected SortJob view, got {other:?}"),
                }
            }
            encode_frame_into(&mut wire_buf, 9, &ok);
            {
                let (_, view) =
                    read_frame_view(&mut &wire_buf[..], &mut client_scratch).expect("ok");
                match view {
                    FrameView::SortOk(v) => {
                        assert_eq!(v.into_response().expect("resp").sorted.len(), n)
                    }
                    other => panic!("expected SortOk view, got {other:?}"),
                }
            }
            if measured {
                let c = wire_counters();
                assert_eq!(c.bytes_copied, roundtrip_bytes_after(n));
                assert_eq!(c.allocs, 3); // job data, sorted, order
            }
        }
    }

    #[test]
    fn frame_sink_writes_decodable_frames_through_a_pipe() {
        let (mut reader, writer) = pipe();
        let mut sink = FrameSink::new(Box::new(writer));
        sink.write_frame(1, &Frame::SortJob(vec![4, 4, 1])).expect("write");
        sink.write_frame(2, &Frame::Ack).expect("write");
        let mut scratch = Vec::new();
        let (id, view) = read_frame_view(&mut reader, &mut scratch).expect("read");
        assert_eq!(id, 1);
        assert_eq!(view.into_frame().expect("own"), Frame::SortJob(vec![4, 4, 1]));
        let (id, view) = read_frame_view(&mut reader, &mut scratch).expect("read");
        assert_eq!(id, 2);
        assert!(matches!(view, FrameView::Owned(Frame::Ack)));
        drop(sink);
        assert!(read_frame_view(&mut reader, &mut scratch).is_err(), "EOF after drop");
    }

    #[test]
    fn duplex_carries_frames_both_ways_and_eofs_on_drop() {
        let ((mut cr, mut cw), (mut sr, mut sw)) = duplex();
        let t = std::thread::spawn(move || {
            let (id, frame) = read_frame(&mut *sr).unwrap();
            assert_eq!((id, frame), (7, Frame::Hello));
            write_frame(&mut *sw, 7, &Frame::HelloAck(ServiceConfig::default())).unwrap();
            drop(sw);
        });
        write_frame(&mut *cw, 7, &Frame::Hello).unwrap();
        let (id, frame) = read_frame(&mut *cr).unwrap();
        assert_eq!(id, 7);
        assert!(matches!(frame, Frame::HelloAck(_)));
        t.join().unwrap();
        // The server write half is dropped: the client sees EOF.
        assert!(read_frame(&mut *cr).is_err());
        // And writing toward a dropped reader fails like EPIPE (the
        // server thread dropped `sr` when it exited).
        assert!(write_frame(&mut *cw, 8, &Frame::Shutdown).is_err());
    }
}

//! Sort planner: serve arrays of *arbitrary* length on fixed-geometry
//! in-memory sorters.
//!
//! A memristive bank is a fixed `N × w` cell grid; the paper evaluates a
//! length-1024 sorter. Real traffic has arbitrary lengths, so the
//! coordinator plans each request onto the hardware:
//!
//! * **Pad** — if the length is within slack of a bank size, pad with
//!   `u32::MAX` sentinels (they sort to the end and are dropped on
//!   output). Cost: the sentinels' rows still participate in CRs.
//! * **Chunk + merge** — split long arrays into bank-sized chunks
//!   ([`partition`]), sort each in its own bank (parallel in hardware, so
//!   chunk latency = max, not sum), then stream the sorted runs through a
//!   fanout-`f` loser-tree merge network
//!   ([`crate::sorter::merge::merge_runs`]).
//!
//! The planner picks the cheaper plan under the paper's cycle model and
//! executes it with any [`InMemorySorter`] factory. The full
//! out-of-bank pipeline — worker-pool chunk sorting plus aggregated
//! stats/cost — lives in [`super::hierarchical`]; this module is the
//! shared planning arithmetic.

use std::ops::Range;

use crate::sorter::merge::{merge_sorted_runs, model_merge_cycles};
use crate::sorter::{InMemorySorter, SortStats};

/// Fixed hardware geometry the planner targets.
#[derive(Clone, Debug)]
pub struct Geometry {
    /// Available bank heights (must be sorted ascending), e.g. AOT
    /// artifact sizes or physical bank heights.
    pub bank_sizes: Vec<usize>,
    /// Bit width of the banks.
    pub width: u32,
    /// Fanout of the digital merge network behind the banks.
    pub merge_fanout: usize,
}

impl Default for Geometry {
    fn default() -> Self {
        Geometry { bank_sizes: vec![16, 64, 256, 1024], width: 32, merge_fanout: 4 }
    }
}

/// Split `[0, n)` into spans of at most `capacity` rows — the bank-sized
/// chunks of the hierarchical pipeline. The last span may be short.
pub fn partition(n: usize, capacity: usize) -> Vec<Range<usize>> {
    assert!(capacity >= 1, "bank capacity must be positive");
    (0..n.div_ceil(capacity))
        .map(|c| c * capacity..((c + 1) * capacity).min(n))
        .collect()
}

/// An execution plan for one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Plan {
    /// Sort in one bank of `bank` rows, padding with sentinels.
    Pad { bank: usize, sentinels: usize },
    /// Sort `chunks` banks of `bank` rows each (last chunk padded), then
    /// merge the sorted runs through the fanout-`fanout` merge network.
    ChunkMerge { bank: usize, chunks: usize, sentinels: usize, fanout: usize },
}

impl Plan {
    /// Estimated latency in cycles under the paper's model, assuming the
    /// per-element cost `cyc_per_num` observed on this traffic class.
    pub fn estimated_cycles(&self, cyc_per_num: f64) -> f64 {
        match *self {
            Plan::Pad { bank, .. } => bank as f64 * cyc_per_num,
            Plan::ChunkMerge { bank, chunks, fanout, .. } => {
                // Banks sort in parallel (multi-bank hardware): latency is
                // one bank sort + the merge passes over all elements.
                bank as f64 * cyc_per_num
                    + model_merge_cycles(bank * chunks, chunks, fanout) as f64
            }
        }
    }
}

/// Plan a request of length `n` onto the geometry.
pub fn plan(n: usize, geo: &Geometry, cyc_per_num: f64) -> Plan {
    assert!(n > 0, "cannot plan an empty sort");
    let largest = *geo.bank_sizes.last().expect("geometry has banks");
    if n <= largest {
        // Smallest bank that fits.
        let bank = *geo
            .bank_sizes
            .iter()
            .find(|&&b| b >= n)
            .expect("largest covers n");
        return Plan::Pad { bank, sentinels: bank - n };
    }
    // Chunk into the largest banks.
    let chunks = n.div_ceil(largest);
    let candidate = Plan::ChunkMerge {
        bank: largest,
        chunks,
        sentinels: chunks * largest - n,
        fanout: geo.merge_fanout.max(2),
    };
    let _ = cyc_per_num; // single candidate today; hook for richer search
    candidate
}

/// Execute a plan with a sorter factory (`make(bank_size)` builds the
/// sorter for one bank). Returns the sorted values and aggregate stats;
/// `stats.crs`/`cycles` follow the plan's latency semantics (parallel
/// banks: max over chunks; merge passes added on top).
pub fn execute<S: InMemorySorter>(
    data: &[u32],
    p: &Plan,
    mut make: impl FnMut(usize) -> S,
) -> (Vec<u32>, SortStats) {
    match *p {
        Plan::Pad { bank, sentinels } => {
            let mut padded = data.to_vec();
            padded.resize(bank, u32::MAX);
            let mut s = make(bank);
            let out = s.sort_with_stats(&padded);
            let mut sorted = out.sorted;
            sorted.truncate(bank - sentinels);
            (sorted, out.stats)
        }
        Plan::ChunkMerge { bank, chunks, fanout, .. } => {
            let mut runs: Vec<Vec<u32>> = Vec::with_capacity(chunks);
            let mut agg = SortStats::default();
            let mut max_cycles = 0u64;
            for span in partition(data.len(), bank) {
                let mut chunk = data[span].to_vec();
                chunk.resize(bank, u32::MAX);
                let mut s = make(bank);
                let out = s.sort_with_stats(&chunk);
                max_cycles = max_cycles.max(out.stats.cycles());
                agg.merge_from(&out.stats);
                runs.push(out.sorted);
            }
            // k-way merge of the sorted runs through the loser tree.
            let mut sorted = merge_sorted_runs(runs, fanout).merged;
            sorted.truncate(data.len());
            // Parallel-bank latency: only the slowest chunk counts, plus
            // the merge network passes. Reflect that in the aggregate by
            // replacing crs with the latency-equivalent count.
            let mut latency_stats = agg.clone();
            latency_stats.crs = max_cycles + model_merge_cycles(bank * chunks, chunks, fanout);
            latency_stats.drains = 0;
            (sorted, latency_stats)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, DatasetKind};
    use crate::sorter::colskip::ColSkipSorter;

    fn geo() -> Geometry {
        Geometry::default()
    }

    #[test]
    fn small_requests_pad_to_smallest_fit() {
        assert_eq!(plan(10, &geo(), 8.0), Plan::Pad { bank: 16, sentinels: 6 });
        assert_eq!(plan(16, &geo(), 8.0), Plan::Pad { bank: 16, sentinels: 0 });
        assert_eq!(plan(17, &geo(), 8.0), Plan::Pad { bank: 64, sentinels: 47 });
        assert_eq!(plan(1024, &geo(), 8.0), Plan::Pad { bank: 1024, sentinels: 0 });
    }

    #[test]
    fn large_requests_chunk() {
        let p = plan(3000, &geo(), 8.0);
        assert_eq!(p, Plan::ChunkMerge { bank: 1024, chunks: 3, sentinels: 72, fanout: 4 });
    }

    #[test]
    fn partition_covers_range_without_overlap() {
        for (n, cap) in [(0usize, 4usize), (1, 4), (4, 4), (5, 4), (1000, 64), (7, 1)] {
            let spans = partition(n, cap);
            assert_eq!(spans.len(), n.div_ceil(cap), "n={n} cap={cap}");
            let mut covered = 0;
            for s in &spans {
                assert_eq!(s.start, covered, "contiguous");
                assert!(s.len() <= cap && !s.is_empty());
                covered = s.end;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn pad_execution_drops_sentinels() {
        let data = vec![9u32, 1, 5];
        let p = plan(data.len(), &geo(), 8.0);
        let (sorted, _) = execute(&data, &p, |_| ColSkipSorter::with_k(2));
        assert_eq!(sorted, vec![1, 5, 9]);
    }

    #[test]
    fn chunk_merge_sorts_arbitrary_lengths() {
        for n in [1025usize, 2048, 2500, 5000] {
            let d = Dataset::generate32(DatasetKind::Kruskal, n, 3);
            let p = plan(n, &geo(), 8.0);
            let (sorted, stats) = execute(&d.values, &p, |_| ColSkipSorter::with_k(2));
            let mut expect = d.values.clone();
            expect.sort_unstable();
            assert_eq!(sorted, expect, "n={n}");
            assert!(stats.cycles() > 0);
        }
    }

    #[test]
    fn chunk_latency_is_max_plus_merge() {
        let n = 2048;
        let d = Dataset::generate32(DatasetKind::Uniform, n, 3);
        let p = plan(n, &geo(), 8.0);
        let (_, stats) = execute(&d.values, &p, |_| ColSkipSorter::with_k(2));
        // Latency must be far below 2 sequential bank sorts (parallel
        // banks) + merge: bounded by one worst bank (≤ 32*1024) + one
        // merge pass over the stream (2 runs at fanout 4).
        assert!(
            stats.cycles() <= 32 * 1024 + model_merge_cycles(2048, 2, 4),
            "{}",
            stats.cycles()
        );
    }

    #[test]
    fn sentinel_values_survive_real_max_entries() {
        // Data containing u32::MAX must not be truncated away.
        let data = vec![u32::MAX, 5, u32::MAX];
        let p = plan(data.len(), &geo(), 8.0);
        let (sorted, _) = execute(&data, &p, |_| ColSkipSorter::with_k(2));
        assert_eq!(sorted, vec![5, u32::MAX, u32::MAX]);
    }

    #[test]
    fn estimated_cycles_orders_plans() {
        let pad = Plan::Pad { bank: 1024, sentinels: 0 };
        let cm = Plan::ChunkMerge { bank: 1024, chunks: 4, sentinels: 0, fanout: 4 };
        assert!(pad.estimated_cycles(8.0) < cm.estimated_cycles(8.0));
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_plan_panics() {
        plan(0, &geo(), 8.0);
    }
}

//! Sort planner: serve arrays of *arbitrary* length on fixed-geometry
//! in-memory sorters.
//!
//! A memristive bank is a fixed `N × w` cell grid; the paper evaluates a
//! length-1024 sorter. Real traffic has arbitrary lengths, so the
//! coordinator plans each request onto the hardware:
//!
//! * **Pad** — if the length is within slack of a bank size, pad with
//!   `u32::MAX` sentinels (they sort to the end and are dropped on
//!   output). Cost: the sentinels' rows still participate in CRs.
//! * **Chunk + merge** — split long arrays into bank-sized chunks,
//!   sort each in its own bank (parallel in hardware, so chunk latency =
//!   max, not sum), then stream through the digital merge network the
//!   merge-sorter comparison point already models.
//!
//! The planner picks the cheaper plan under the paper's cycle model and
//! executes it with any [`InMemorySorter`] factory.

use crate::sorter::merge::MergeSorter;
use crate::sorter::{InMemorySorter, SortStats};

/// Fixed hardware geometry the planner targets.
#[derive(Clone, Debug)]
pub struct Geometry {
    /// Available bank heights (must be sorted ascending), e.g. AOT
    /// artifact sizes or physical bank heights.
    pub bank_sizes: Vec<usize>,
    /// Bit width of the banks.
    pub width: u32,
}

impl Default for Geometry {
    fn default() -> Self {
        Geometry { bank_sizes: vec![16, 64, 256, 1024], width: 32 }
    }
}

/// An execution plan for one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Plan {
    /// Sort in one bank of `bank` rows, padding with sentinels.
    Pad { bank: usize, sentinels: usize },
    /// Sort `chunks` banks of `bank` rows each (last chunk padded), then
    /// merge the sorted runs through the digital merge tree.
    ChunkMerge { bank: usize, chunks: usize, sentinels: usize },
}

impl Plan {
    /// Estimated latency in cycles under the paper's model, assuming the
    /// per-element cost `cyc_per_num` observed on this traffic class.
    pub fn estimated_cycles(&self, cyc_per_num: f64) -> f64 {
        match *self {
            Plan::Pad { bank, .. } => bank as f64 * cyc_per_num,
            Plan::ChunkMerge { bank, chunks, .. } => {
                // Banks sort in parallel (multi-bank hardware): latency is
                // one bank sort + the merge pass over all elements.
                bank as f64 * cyc_per_num
                    + MergeSorter::model_cycles(bank * chunks) as f64
            }
        }
    }
}

/// Plan a request of length `n` onto the geometry.
pub fn plan(n: usize, geo: &Geometry, cyc_per_num: f64) -> Plan {
    assert!(n > 0, "cannot plan an empty sort");
    let largest = *geo.bank_sizes.last().expect("geometry has banks");
    if n <= largest {
        // Smallest bank that fits.
        let bank = *geo
            .bank_sizes
            .iter()
            .find(|&&b| b >= n)
            .expect("largest covers n");
        return Plan::Pad { bank, sentinels: bank - n };
    }
    // Chunk into the largest banks.
    let chunks = n.div_ceil(largest);
    let candidate = Plan::ChunkMerge {
        bank: largest,
        chunks,
        sentinels: chunks * largest - n,
    };
    let _ = cyc_per_num; // single candidate today; hook for richer search
    candidate
}

/// Execute a plan with a sorter factory (`make(bank_size)` builds the
/// sorter for one bank). Returns the sorted values and aggregate stats;
/// `stats.crs`/`cycles` follow the plan's latency semantics (parallel
/// banks: max over chunks; merge pass added on top).
pub fn execute<S: InMemorySorter>(
    data: &[u32],
    p: &Plan,
    mut make: impl FnMut(usize) -> S,
) -> (Vec<u32>, SortStats) {
    match *p {
        Plan::Pad { bank, sentinels } => {
            let mut padded = data.to_vec();
            padded.resize(bank, u32::MAX);
            let mut s = make(bank);
            let out = s.sort_with_stats(&padded);
            let mut sorted = out.sorted;
            sorted.truncate(bank - sentinels);
            (sorted, out.stats)
        }
        Plan::ChunkMerge { bank, chunks, .. } => {
            let mut runs: Vec<Vec<u32>> = Vec::with_capacity(chunks);
            let mut agg = SortStats::default();
            let mut max_cycles = 0u64;
            for c in 0..chunks {
                let lo = c * bank;
                let hi = ((c + 1) * bank).min(data.len());
                let mut chunk = data[lo..hi].to_vec();
                chunk.resize(bank, u32::MAX);
                let mut s = make(bank);
                let out = s.sort_with_stats(&chunk);
                max_cycles = max_cycles.max(out.stats.cycles());
                agg.merge_from(&out.stats);
                runs.push(out.sorted);
            }
            // Parallel-bank latency: only the slowest chunk counts, plus
            // the merge network pass. Reflect that in the aggregate by
            // replacing crs with the latency-equivalent count.
            let merge_cycles = MergeSorter::model_cycles(bank * chunks);
            let mut latency_stats = agg.clone();
            latency_stats.crs = max_cycles + merge_cycles;
            latency_stats.drains = 0;
            // k-way merge of the sorted runs (binary merge tree).
            let mut merged = runs;
            while merged.len() > 1 {
                let mut next = Vec::with_capacity(merged.len().div_ceil(2));
                let mut it = merged.into_iter();
                while let Some(a) = it.next() {
                    match it.next() {
                        Some(b) => next.push(merge2(&a, &b)),
                        None => next.push(a),
                    }
                }
                merged = next;
            }
            let mut sorted = merged.pop().unwrap_or_default();
            sorted.truncate(data.len());
            (sorted, latency_stats)
        }
    }
}

fn merge2(a: &[u32], b: &[u32]) -> Vec<u32> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        if a[i] <= b[j] {
            out.push(a[i]);
            i += 1;
        } else {
            out.push(b[j]);
            j += 1;
        }
    }
    out.extend_from_slice(&a[i..]);
    out.extend_from_slice(&b[j..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, DatasetKind};
    use crate::sorter::colskip::ColSkipSorter;

    fn geo() -> Geometry {
        Geometry::default()
    }

    #[test]
    fn small_requests_pad_to_smallest_fit() {
        assert_eq!(plan(10, &geo(), 8.0), Plan::Pad { bank: 16, sentinels: 6 });
        assert_eq!(plan(16, &geo(), 8.0), Plan::Pad { bank: 16, sentinels: 0 });
        assert_eq!(plan(17, &geo(), 8.0), Plan::Pad { bank: 64, sentinels: 47 });
        assert_eq!(plan(1024, &geo(), 8.0), Plan::Pad { bank: 1024, sentinels: 0 });
    }

    #[test]
    fn large_requests_chunk() {
        let p = plan(3000, &geo(), 8.0);
        assert_eq!(p, Plan::ChunkMerge { bank: 1024, chunks: 3, sentinels: 72 });
    }

    #[test]
    fn pad_execution_drops_sentinels() {
        let data = vec![9u32, 1, 5];
        let p = plan(data.len(), &geo(), 8.0);
        let (sorted, _) = execute(&data, &p, |_| ColSkipSorter::with_k(2));
        assert_eq!(sorted, vec![1, 5, 9]);
    }

    #[test]
    fn chunk_merge_sorts_arbitrary_lengths() {
        for n in [1025usize, 2048, 2500, 5000] {
            let d = Dataset::generate32(DatasetKind::Kruskal, n, 3);
            let p = plan(n, &geo(), 8.0);
            let (sorted, stats) = execute(&d.values, &p, |_| ColSkipSorter::with_k(2));
            let mut expect = d.values.clone();
            expect.sort_unstable();
            assert_eq!(sorted, expect, "n={n}");
            assert!(stats.cycles() > 0);
        }
    }

    #[test]
    fn chunk_latency_is_max_plus_merge() {
        let n = 2048;
        let d = Dataset::generate32(DatasetKind::Uniform, n, 3);
        let p = plan(n, &geo(), 8.0);
        let (_, stats) = execute(&d.values, &p, |_| ColSkipSorter::with_k(2));
        // Latency must be far below 2 sequential bank sorts (parallel
        // banks) + merge: bounded by one worst bank (≤ 32*1024) + merge.
        assert!(
            stats.cycles() <= 32 * 1024 + MergeSorter::model_cycles(2048),
            "{}",
            stats.cycles()
        );
    }

    #[test]
    fn sentinel_values_survive_real_max_entries() {
        // Data containing u32::MAX must not be truncated away.
        let data = vec![u32::MAX, 5, u32::MAX];
        let p = plan(data.len(), &geo(), 8.0);
        let (sorted, _) = execute(&data, &p, |_| ColSkipSorter::with_k(2));
        assert_eq!(sorted, vec![5, u32::MAX, u32::MAX]);
    }

    #[test]
    fn estimated_cycles_orders_plans() {
        let pad = Plan::Pad { bank: 1024, sentinels: 0 };
        let cm = Plan::ChunkMerge { bank: 1024, chunks: 4, sentinels: 0 };
        assert!(pad.estimated_cycles(8.0) < cm.estimated_cycles(8.0));
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_plan_panics() {
        plan(0, &geo(), 8.0);
    }
}

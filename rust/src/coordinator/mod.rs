//! L3 sort service: a multi-worker coordinator that owns process
//! topology, request routing, batching and metrics.
//!
//! The paper's contribution is the near-memory circuit, so the service
//! layer is deliberately thin (per the architecture: "if the paper's
//! contribution lives at L1/L2, L3 is a driver") — but it is a *real*
//! driver: a worker pool where each worker owns a sorting engine (the
//! bit-accurate native simulator, the PJRT-compiled AOT artifact, or a
//! hybrid that runs both and cross-checks), an mpsc request queue,
//! bounded backpressure, and latency/throughput metrics.
//!
//! No tokio in the offline registry — workers are `std::thread` with
//! `std::sync::mpsc`, which for a CPU-bound service is the right tool
//! anyway (the PJRT client is not `Send`, so each worker constructs its
//! own engine).
//!
//! Requests longer than one bank go through the [`hierarchical`] pipeline
//! ([`SortService::sort_hierarchical`]): partition into bank-sized chunks
//! ([`planner::partition`]), sort the chunks on this worker pool, and
//! combine the runs in a k-way loser-tree merge network.

pub mod frontend;
pub mod hierarchical;
pub(crate) mod locks;
pub mod metrics;
pub mod planner;
pub mod shard;
pub mod shard_server;
pub mod transport;
pub mod wire;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::multibank::{MultiBankConfig, MultiBankSorter};
use crate::runtime::PjrtEngine;
use crate::sorter::colskip::{ColSkipConfig, ColSkipSorter};
use crate::sorter::{InMemorySorter, SortOutput, SortStats};
use metrics::ServiceMetrics;

/// Which compute backend workers use.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Bit-accurate near-memory-circuit simulator (full cycle stats).
    Native,
    /// AOT-compiled rank pass on the PJRT CPU client (functional result +
    /// per-iteration traces; cycle stats estimated from traces).
    Pjrt,
    /// PJRT compute cross-checked against the native simulator — the
    /// configuration used in the end-to-end example.
    Hybrid,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "native" => Some(EngineKind::Native),
            "pjrt" => Some(EngineKind::Pjrt),
            "hybrid" => Some(EngineKind::Hybrid),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Native => "native",
            EngineKind::Pjrt => "pjrt",
            EngineKind::Hybrid => "hybrid",
        }
    }

    /// Every engine kind, for sweeps and the parse round-trip test.
    pub const ALL: [EngineKind; 3] = [EngineKind::Native, EngineKind::Pjrt, EngineKind::Hybrid];
}

impl std::str::FromStr for EngineKind {
    type Err = String;

    /// [`EngineKind::parse`] as the standard trait, so CLI flags go
    /// through the same typed accessors as every numeric option.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        EngineKind::parse(s).ok_or_else(|| format!("unknown engine `{s}` (native|pjrt|hybrid)"))
    }
}

/// Service configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceConfig {
    /// Worker threads (each with its own engine instance).
    pub workers: usize,
    /// Column-skipping configuration for the native engine.
    pub colskip: ColSkipConfig,
    /// Sub-banks per native sorter: 1 uses a single-bank [`ColSkipSorter`];
    /// >1 uses a [`MultiBankSorter`] striped over this many banks (§IV).
    pub banks: usize,
    /// Compute backend.
    pub engine: EngineKind,
    /// Artifacts directory for PJRT engines.
    pub artifacts_dir: std::path::PathBuf,
    /// Bounded queue depth (backpressure): `submit` blocks beyond this.
    pub queue_depth: usize,
    /// Bank geometry the chunk-size auto-tuner plans against
    /// ([`hierarchical::Capacity::Auto`]).
    pub geometry: planner::Geometry,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            colskip: ColSkipConfig::default(),
            banks: 1,
            engine: EngineKind::Native,
            artifacts_dir: PjrtEngine::default_dir(),
            queue_depth: 256,
            geometry: planner::Geometry::default(),
        }
    }
}

/// A sort job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SortRequest {
    pub id: u64,
    pub data: Vec<u32>,
}

/// A completed job.
#[derive(Clone, Debug, PartialEq)]
pub struct SortResponse {
    pub id: u64,
    pub sorted: Vec<u32>,
    /// `order[i]` = original index of `sorted[i]` (argsort). Empty when
    /// the backend cannot provide it (pure PJRT executes only the rank
    /// pass, which returns values and traces, not row provenance).
    pub order: Vec<usize>,
    /// Simulated near-memory-circuit stats (native/hybrid; estimated for
    /// pure PJRT from the iteration traces).
    pub stats: SortStats,
    /// Wall-clock service latency in microseconds.
    pub latency_us: u64,
    /// Worker that served the request.
    pub worker: usize,
}

enum Job {
    Sort(SortRequest, mpsc::Sender<Result<SortResponse>>),
    Shutdown,
}

/// Handle to a running sort service.
pub struct SortService {
    tx: mpsc::SyncSender<Job>,
    workers: Vec<JoinHandle<()>>,
    metrics: Arc<ServiceMetrics>,
    next_id: AtomicU64,
    config: ServiceConfig,
}

impl SortService {
    /// Start the worker pool. Misconfiguration is an error, not a
    /// panic: these values come straight from CLI flags and fleet
    /// configs, and a bad flag must not take the process down.
    pub fn start(config: ServiceConfig) -> Result<Self> {
        if config.workers < 1 {
            return Err(anyhow!("a service needs at least one worker"));
        }
        if config.banks < 1 {
            return Err(anyhow!("a service engine needs at least one bank"));
        }
        let (tx, rx) = mpsc::sync_channel::<Job>(config.queue_depth);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(ServiceMetrics::new());
        let mut workers = Vec::with_capacity(config.workers);
        for wid in 0..config.workers {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let cfg = config.clone();
            workers.push(std::thread::spawn(move || worker_loop(wid, cfg, rx, metrics)));
        }
        Ok(SortService { tx, workers, metrics, next_id: AtomicU64::new(0), config })
    }

    /// The configuration this service was started with.
    pub fn config(&self) -> &ServiceConfig {
        &self.config
    }

    /// Submit a job; returns a receiver for the response. Blocks when the
    /// queue is full (backpressure).
    pub fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Job::Sort(SortRequest { id, data }, rtx))
            .map_err(|_| anyhow!("service is shut down"))?;
        Ok(rrx)
    }

    /// Submit and wait for the response.
    pub fn submit_wait(&self, data: Vec<u32>) -> Result<SortResponse> {
        let rx = self.submit(data)?;
        rx.recv().map_err(|_| anyhow!("worker dropped the response"))?
    }

    /// Submit a batch and wait for all responses (in submission order).
    pub fn submit_batch(&self, batch: Vec<Vec<u32>>) -> Result<Vec<SortResponse>> {
        let rxs: Vec<_> =
            batch.into_iter().map(|d| self.submit(d)).collect::<Result<_>>()?;
        rxs.into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow!("worker dropped the response"))?)
            .collect()
    }

    /// Live metrics snapshot.
    pub fn metrics(&self) -> metrics::Snapshot {
        self.metrics.snapshot()
    }

    /// Observed cycles/number for `n`'s size class without snapshot
    /// overhead (no reservoir lock) — the cost-aware shard router's
    /// per-decision read. Falls back like [`metrics::Snapshot::cyc_per_num_for`].
    pub fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        self.metrics.cyc_per_num_for(n, fallback)
    }

    /// Graceful shutdown: drain queued jobs, then join workers.
    pub fn shutdown(mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Initiate shutdown without consuming the handle or joining the
    /// workers: queued jobs still drain, every worker exits after its
    /// shutdown marker, and once the last one is gone the request
    /// channel closes — `submit` fails and in-flight receivers observe
    /// a dropped reply. This is the fleet layer's failure-injection /
    /// shard-retirement hook ([`shard::ShardedSortService::fail_shard`]):
    /// the shard dies the way a crashed host would, asynchronously,
    /// while the coordinator keeps the handle for accounting.
    pub fn halt(&self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Job::Shutdown);
        }
    }
}

/// Build the native simulation engine a worker owns: a single-bank
/// column-skipping sorter, or the §IV multi-bank ensemble when the
/// service is configured with `banks > 1`.
fn native_engine(cfg: &ServiceConfig) -> Box<dyn InMemorySorter> {
    if cfg.banks > 1 {
        Box::new(MultiBankSorter::new(MultiBankConfig {
            width: cfg.colskip.width,
            k: cfg.colskip.k,
            banks: cfg.banks,
            skip_leading: cfg.colskip.skip_leading,
            stall_on_duplicates: cfg.colskip.stall_on_duplicates,
        }))
    } else {
        Box::new(ColSkipSorter::new(cfg.colskip.clone()))
    }
}

fn worker_loop(
    wid: usize,
    cfg: ServiceConfig,
    rx: Arc<Mutex<mpsc::Receiver<Job>>>,
    metrics: Arc<ServiceMetrics>,
) {
    // Engines are constructed per worker: the PJRT client is not Send.
    let mut native = native_engine(&cfg);
    let mut pjrt: Option<PjrtEngine> = match cfg.engine {
        EngineKind::Native => None,
        _ => match PjrtEngine::new(&cfg.artifacts_dir) {
            Ok(e) => Some(e),
            Err(e) => {
                eprintln!("worker {wid}: PJRT engine unavailable ({e}); using native");
                None
            }
        },
    };

    loop {
        let job = {
            // A sibling worker that panicked mid-recv poisons the
            // shared receiver; the pool must keep draining jobs.
            let guard = locks::lock_recover(&rx);
            guard.recv()
        };
        let Ok(job) = job else { return };
        match job {
            Job::Shutdown => return,
            Job::Sort(req, reply) => {
                let t0 = Instant::now();
                let result = serve_one(&cfg, native.as_mut(), pjrt.as_mut(), &req);
                let latency_us = t0.elapsed().as_micros() as u64;
                let resp = result.map(|out| {
                    metrics.record(latency_us, &out.stats, out.sorted.len());
                    SortResponse {
                        id: req.id,
                        sorted: out.sorted,
                        order: out.order,
                        stats: out.stats,
                        latency_us,
                        worker: wid,
                    }
                });
                if resp.is_err() {
                    metrics.record_error();
                }
                let _ = reply.send(resp);
            }
        }
    }
}

fn serve_one(
    cfg: &ServiceConfig,
    native: &mut dyn InMemorySorter,
    pjrt: Option<&mut PjrtEngine>,
    req: &SortRequest,
) -> Result<SortOutput> {
    match (cfg.engine, pjrt) {
        (EngineKind::Native, _) | (_, None) => Ok(native.sort_with_stats(&req.data)),
        (EngineKind::Pjrt, Some(engine)) => {
            let pass = engine.rank(&req.data)?;
            // Estimate near-memory cycles from the iteration traces: a
            // column-skipping sorter re-reads at most (top_col+1) columns
            // per iteration; iterations with no informative column are
            // duplicate drains (1 cycle).
            let stats = estimate_stats_from_traces(&pass.top_cols, &pass.infos);
            Ok(SortOutput {
                sorted: pass.sorted,
                order: Vec::new(),
                stats,
                counters: Default::default(),
            })
        }
        (EngineKind::Hybrid, Some(engine)) => {
            let pass = engine.rank(&req.data)?;
            let out = native.sort_with_stats(&req.data);
            if pass.sorted != out.sorted {
                return Err(anyhow!(
                    "engine mismatch on request {}: PJRT and native sorters disagree",
                    req.id
                ));
            }
            Ok(out)
        }
    }
}

/// Upper-bound cycle estimate from AOT traces (documented approximation:
/// the traces carry per-iteration informative-column structure, not the
/// state-table hit pattern, so this brackets the native simulator from
/// above).
pub fn estimate_stats_from_traces(top_cols: &[i32], infos: &[i32]) -> SortStats {
    let mut stats = SortStats::default();
    for (&top, &info) in top_cols.iter().zip(infos) {
        stats.iterations += 1;
        // A malformed trace can carry a negative entry (the AOT scan
        // encodes "no informative column" as -1 in `top_cols`, and a
        // corrupted artifact could put it in `infos` too). Clamp before
        // the u64 casts: `(top + 1) as u64` on `top < -1` would wrap to
        // ~2^64 column reads and poison every aggregate downstream.
        if info <= 0 {
            stats.drains += 1;
        } else {
            stats.crs += (top.max(-1) as i64 + 1) as u64;
            stats.res += info as u64;
            stats.sls += 1;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, DatasetKind};

    #[test]
    fn native_service_sorts_and_reports() {
        let svc = SortService::start(ServiceConfig {
            workers: 2,
            ..Default::default()
        })
        .unwrap();
        let d = Dataset::generate32(DatasetKind::Clustered, 128, 3);
        let resp = svc.submit_wait(d.values.clone()).unwrap();
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        assert!(resp.stats.cycles() > 0);
        let m = svc.metrics();
        assert_eq!(m.completed, 1);
        assert_eq!(m.errors, 0);
        assert!(m.p50_us <= m.p99_us);
        svc.shutdown();
    }

    #[test]
    fn batch_responses_preserve_order() {
        let svc = SortService::start(ServiceConfig::default()).unwrap();
        let batch: Vec<Vec<u32>> = (0..16u32)
            .map(|i| Dataset::generate32(DatasetKind::Uniform, 64, i as u64).values)
            .collect();
        let expect: Vec<Vec<u32>> = batch
            .iter()
            .map(|d| {
                let mut v = d.clone();
                v.sort_unstable();
                v
            })
            .collect();
        let resps = svc.submit_batch(batch).unwrap();
        assert_eq!(resps.len(), 16);
        for (r, e) in resps.iter().zip(&expect) {
            assert_eq!(&r.sorted, e);
        }
        // ids are in submission order
        assert!(resps.windows(2).all(|w| w[0].id < w[1].id));
        assert_eq!(svc.metrics().completed, 16);
        svc.shutdown();
    }

    #[test]
    fn work_spreads_across_workers() {
        let svc =
            SortService::start(ServiceConfig { workers: 4, ..Default::default() }).unwrap();
        let resps = svc
            .submit_batch(
                (0..64u32)
                    .map(|i| Dataset::generate32(DatasetKind::Uniform, 64, i as u64).values)
                    .collect(),
            )
            .unwrap();
        let mut seen: Vec<usize> = resps.iter().map(|r| r.worker).collect();
        seen.sort_unstable();
        seen.dedup();
        assert!(seen.len() >= 2, "expected >=2 workers to serve: {seen:?}");
        svc.shutdown();
    }

    #[test]
    fn responses_carry_a_valid_argsort() {
        let svc = SortService::start(ServiceConfig::default()).unwrap();
        let d = Dataset::generate32(DatasetKind::Kruskal, 96, 11);
        let resp = svc.submit_wait(d.values.clone()).unwrap();
        assert_eq!(resp.order.len(), d.values.len());
        for (i, &row) in resp.order.iter().enumerate() {
            assert_eq!(d.values[row], resp.sorted[i]);
        }
        svc.shutdown();
    }

    #[test]
    fn multibank_engine_serves_uneven_lengths() {
        // banks=4 with n % 4 != 0 exercises the sorter's internal padding.
        let svc = SortService::start(ServiceConfig {
            workers: 2,
            banks: 4,
            ..Default::default()
        })
        .unwrap();
        let d = Dataset::generate32(DatasetKind::MapReduce, 130, 7);
        let resp = svc.submit_wait(d.values.clone()).unwrap();
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        assert_eq!(resp.order.len(), d.values.len());
        svc.shutdown();
    }

    #[test]
    fn shutdown_rejects_new_work() {
        let svc = SortService::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap();
        // Work in flight *before* shutdown is still served: shutdown
        // drains the queue (the shutdown markers sit behind it).
        let rx = svc.submit(vec![3u32, 1, 2]).unwrap();
        let tx = svc.tx.clone();
        svc.shutdown();
        let resp = rx
            .recv()
            .expect("in-flight job must be served before the workers exit")
            .expect("sort succeeds");
        assert_eq!(resp.sorted, vec![1, 2, 3]);
        // After shutdown every worker has joined and the receiver side
        // of the job channel is gone, so new work is observably
        // rejected — exactly what `submit` maps to its error.
        let (reply_tx, reply_rx) = mpsc::channel();
        let rejected = tx.send(Job::Sort(SortRequest { id: 99, data: vec![7] }, reply_tx));
        assert!(rejected.is_err(), "submitting after shutdown must fail");
        assert!(reply_rx.recv().is_err(), "no worker may answer after shutdown");
    }

    #[test]
    fn halt_closes_the_service_asynchronously() {
        let svc = SortService::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap();
        svc.halt();
        // The workers exit on their own; once the last one is gone the
        // channel closes and submission fails. Poll unbounded rather
        // than sleep or count iterations — the exit is guaranteed (the
        // shutdown markers are already queued), only its timing is not,
        // and an iteration cap would just turn scheduler jitter into a
        // flake. At worst the queue fills and `submit` blocks until the
        // disconnect, which still ends the loop.
        while svc.submit(vec![1u32]).is_ok() {
            std::thread::yield_now();
        }
        svc.shutdown(); // idempotent: joins the already-exited workers
    }

    #[test]
    fn bad_service_config_is_an_error_not_a_panic() {
        assert!(SortService::start(ServiceConfig { workers: 0, ..Default::default() }).is_err());
        assert!(SortService::start(ServiceConfig { banks: 0, ..Default::default() }).is_err());
    }

    #[test]
    fn engine_kind_parse_round_trips() {
        // `ALL`, `name` and `FromStr` must stay in sync: every kind
        // round-trips through its canonical name, and `from_str`
        // delegates to `parse`.
        for kind in EngineKind::ALL {
            assert_eq!(kind.name().parse::<EngineKind>(), Ok(kind));
            assert_eq!(EngineKind::parse(kind.name()), Some(kind));
        }
        assert!("xla".parse::<EngineKind>().is_err());
    }

    #[test]
    fn estimate_from_traces_brackets_native() {
        let d = Dataset::generate32(DatasetKind::MapReduce, 128, 9);
        let mut native = ColSkipSorter::with_k(2);
        let nat = native.sort_with_stats(&d.values).stats;
        // Build traces from the reference model semantics via the native
        // sorter's own run is not available here; approximate with the
        // jnp-equivalent: top informative col per iteration == what the
        // estimate consumes. We reconstruct from a second native run in
        // trace mode once available; here, sanity: estimator on a
        // synthetic trace is monotone in top_col.
        let a = estimate_stats_from_traces(&[5, 3, -1], &[2, 1, 0]);
        assert_eq!(a.crs, 6 + 4);
        assert_eq!(a.drains, 1);
        assert!(a.cycles() >= nat.cycles().min(1)); // trivial lower bound
    }

    #[test]
    fn estimate_from_traces_clamps_malformed_negatives() {
        // Regression: a trace with `top < -1` but `info != 0` used to
        // wrap `(top + 1) as u64` to ~2^64 column reads. Negative
        // entries must clamp, and negative `infos` (never emitted by a
        // healthy artifact) count as drains rather than wrapping `res`.
        let s = estimate_stats_from_traces(&[-5, -1, 3, i32::MIN], &[2, 4, -7, 1]);
        assert_eq!(s.iterations, 4);
        // (-5, 2): top clamps to -1 -> 0 CRs, but the informative count
        // is honoured; (-1, 4): 0 CRs + 4 REs; (3, -7): drain;
        // (i32::MIN, 1): clamps to 0 CRs without overflow.
        assert_eq!(s.crs, 0);
        assert_eq!(s.res, 2 + 4 + 1);
        assert_eq!(s.sls, 3);
        assert_eq!(s.drains, 1);
        // Every count stays finite/sane: total cycles is bounded by the
        // trace length times the clamped per-iteration maximum.
        assert!(s.cycles() < 1_000);
    }
}

//! Poison-recovering lock acquisition for request-serving threads.
//!
//! `Mutex::lock()` returns `Err` only when another thread panicked
//! while holding the guard. On the coordinator's serving paths —
//! session loops, reader threads, admission — propagating that poison
//! with `expect` turns *one* thread's panic into a process-wide
//! cascade: every sibling session that touches the same lock dies
//! too, which is exactly the failure mode the multi-connection server
//! exists to prevent (one bad frame degrades one session, never the
//! process).
//!
//! These helpers recover the guard instead. That is sound here
//! because every structure the coordinator shares behind a lock is
//! *panic-consistent*: writers either make a single atomic assignment
//! (`*slot = None`, `*cfg = config`) or use std collections, whose
//! operations leave the collection valid (if possibly missing the
//! in-flight element) when they unwind. The worst post-panic outcome
//! is a dropped in-flight entry, which the wire protocol already
//! treats as a dropped reply.
//!
//! memlint (`python/memlint`, rule family `lock-order`) recognises
//! these helpers as lock acquisitions, so sites converted to them
//! stay inside the ordering and guard-across-I/O analysis.

use std::sync::{Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

/// `m.lock()`, recovering the guard from a poisoned mutex.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `l.read()`, recovering the guard from a poisoned rwlock.
pub(crate) fn read_recover<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// `l.write()`, recovering the guard from a poisoned rwlock.
pub(crate) fn write_recover<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn recovers_a_mutex_poisoned_by_a_panicking_thread() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned(), "the panicking holder must poison the mutex");
        // A plain lock() would Err here; the recovering helper returns
        // the guard and the data is still the last consistent value.
        assert_eq!(*lock_recover(&m), 7);
        *lock_recover(&m) = 8;
        assert_eq!(*lock_recover(&m), 8);
    }

    #[test]
    fn recovers_both_halves_of_a_poisoned_rwlock() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _guard = l2.write().unwrap();
            panic!("poison the rwlock");
        })
        .join();
        assert!(l.is_poisoned());
        assert_eq!(read_recover(&l).len(), 3);
        write_recover(&l).push(4);
        assert_eq!(read_recover(&l).len(), 4);
    }
}

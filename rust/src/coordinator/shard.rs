//! Fleet layer: one coordinator over N independent
//! [`super::SortService`] shards — the "multiple services/hosts" step
//! of the roadmap.
//!
//! The paper's §IV multi-bank management scales column-skipping *within*
//! one simulated host; a [`ShardedSortService`] scales it *across*
//! hosts. Every shard owns its own worker pool, engine geometry and
//! metrics (a [`super::SortService`] is exactly one simulated host),
//! and the fleet routes work over them:
//!
//! * **Routing** — [`RoutePolicy`]: round-robin, least-outstanding
//!   (live per-shard in-flight accounting), size-class affinity
//!   (requests of one log2 size class stick to one shard, which keeps
//!   that shard's per-class cost observations dense — the auto-tuner's
//!   food), or cost-aware (see **Heterogeneity**).
//! * **Error isolation** — a shard whose service has died (its channel
//!   closed, its workers gone) is marked unhealthy and its work is
//!   re-routed to the surviving shards instead of failing the request.
//!   [`ShardedSortService::fail_shard`] retires a shard the way a
//!   crashed host would (through its transport's halt).
//! * **Hierarchical sorting** — [`ShardedSortService::sort_hierarchical`]
//!   routes bank-sized chunks across the fleet and drives the *same*
//!   `ChunkAssembly` as the single-service path, so the output is
//!   byte-identical by construction (the streaming merge frontier
//!   consumes run arrivals in chunk order, indifferent to which host
//!   sorted each chunk). On top it reports the fleet latency model:
//!   every shard drains its chunks through its own merge engine in
//!   parallel and a top-level merge combines the shard streams
//!   ([`crate::sorter::merge::model_sharded_completion`] is the
//!   planner-side closed form of the same topology).
//! * **Fleet metrics** — [`FleetSnapshot`] aggregates the per-shard
//!   [`Snapshot`]s: totals, per-shard latency percentiles, and the
//!   shard imbalance ratio (max/mean elements served).
//! * **Heterogeneity** — shards are no longer clones of one template:
//!   [`ShardedConfig`] carries one [`ServiceConfig`] *per shard*
//!   (different bank geometries, worker pools, engines per host), the
//!   cost-aware [`RoutePolicy::Cost`] weighs each shard's observed
//!   per-size-class cycles/number and its geometry (an undersized host
//!   pays the oversize-assembly penalty of
//!   [`super::planner::shard_model`]), and auto-tuning scores
//!   candidates with the heterogeneous fleet model
//!   ([`super::planner::auto_tune_hetero`]), which reduces exactly to
//!   the uniform PR-3 model when every shard matches.
//! * **Recovery** — [`ShardedSortService::recover_shard`] restarts a
//!   retired host through its transport and re-admits it to routing
//!   (it comes back empty, like a real restarted process; the router
//!   warms it back in — zero outstanding work and cost fallbacks make
//!   it immediately attractive to every policy).
//! * **Resilience** — a lossy link to the hosts is survivable *and
//!   bounded*: a per-fleet [`RetryBudgetConfig`] token bucket caps how
//!   many failover hops (and hedges) the fleet will spend, so retries
//!   cannot storm a degraded fleet; and with hedging enabled
//!   ([`HedgeConfig`]) a reply outstanding past the
//!   latency-model-derived straggler deadline
//!   ([`crate::sorter::merge::model_hedge_deadline`]) is re-issued to
//!   the next-best shard by the cost route — first delivered reply
//!   wins, the loser is abandoned (hedging never changes the output:
//!   the simulated response is a deterministic function of the data).
//!   All of it is observable in [`FleetSnapshot`] (`retries`,
//!   `hedges_won`/`hedges_lost`, `budget_exhausted`, `retry_tokens`).
//!
//! The coordinator does not know where its hosts run: each shard is a
//! [`ShardTransport`] ([`super::transport`]) — the in-process
//! [`LocalTransport`], the fault-injecting `FlakyTransport`, or the
//! wire-speaking `RemoteTransport` against a
//! [`super::shard_server::ShardServer`] (TCP in production, the
//! in-memory duplex in tests). Routing, recovery and the models are
//! written against the trait alone; in-process hosts remain what makes
//! the byte-identity property testable, and the remote fleet is pinned
//! byte-identical to them in the integration sweep.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

use super::frontend::JobTag;
use super::hierarchical::{Capacity, ChunkAssembly, HierarchicalConfig, HierarchicalOutput};
use super::metrics::{size_class, ServiceMetrics, Snapshot};
use super::planner::{auto_tune_hetero, partition, schedule, shard_model, Geometry};
use super::transport::{LocalTransport, ShardTransport};
use super::{ServiceConfig, SortResponse};
use crate::sorter::merge::{model_merge_cycles, model_streamed_completion};
use crate::sorter::spill::{resident_merge_bytes, RunStore, TempDirRunStore};

/// How the fleet routes a request (or a hierarchical chunk) to a shard.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through the healthy shards in order.
    RoundRobin,
    /// Pick the healthy shard with the fewest in-flight jobs (ties:
    /// lowest shard id) — the classic join-shortest-queue heuristic.
    LeastOutstanding,
    /// Pin each log2 size class to a home shard, so a shard keeps
    /// seeing the classes it has already calibrated per-class costs
    /// for. Applies per *request*; a hierarchical sort's chunk fan-out
    /// additionally offsets by chunk index (all chunks of one sort
    /// share a size class, and affinity must not serialize the fleet's
    /// parallel drains onto one host).
    SizeClass,
    /// Cost-aware: pick the shard with the cheapest modelled completion
    /// for this request — the shard's observed per-size-class
    /// cycles/number (nominal before traffic) times its geometry-aware
    /// arrival ([`super::planner::shard_model`]: an undersized host
    /// pays the oversize-assembly merge), scaled by its live queue
    /// depth. On a heterogeneous fleet this skews work towards fast,
    /// adequately-sized hosts; on a uniform idle fleet every score
    /// ties and the lowest shard id wins (like
    /// [`RoutePolicy::LeastOutstanding`], a hierarchical fan-out still
    /// spreads because each submission bumps the chosen shard's
    /// queue-depth factor).
    Cost,
}

impl RoutePolicy {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "round" | "round-robin" | "rr" => Some(RoutePolicy::RoundRobin),
            "least" | "least-outstanding" => Some(RoutePolicy::LeastOutstanding),
            "class" | "size-class" => Some(RoutePolicy::SizeClass),
            "cost" | "cost-aware" => Some(RoutePolicy::Cost),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastOutstanding => "least-outstanding",
            RoutePolicy::SizeClass => "size-class",
            RoutePolicy::Cost => "cost",
        }
    }

    /// Every policy, for sweeps and property tests.
    pub const ALL: [RoutePolicy; 4] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastOutstanding,
        RoutePolicy::SizeClass,
        RoutePolicy::Cost,
    ];
}

impl std::str::FromStr for RoutePolicy {
    type Err = String;

    /// [`RoutePolicy::parse`] as the standard trait, so CLI flags go
    /// through the same typed accessors as every numeric option.
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        RoutePolicy::parse(s)
            .ok_or_else(|| format!("unknown route policy `{s}` (round|least|class|cost)"))
    }
}

/// The fleet's retry budget: a deterministic token bucket that bounds
/// how many failover hops (and hedges) the fleet will spend, so a
/// degraded fleet degrades instead of amplifying its own load with a
/// retry storm. The bucket starts at `capacity` tokens; every failover
/// hop or hedge costs one; every *successful* submit deposits
/// `deposit` back (capped at `capacity`) — the classic
/// retries-as-a-fraction-of-traffic budget, with `capacity` as the
/// burst allowance. Deliberately clockless: the budget refills with
/// served work, not wall time, so tests and replays are deterministic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryBudgetConfig {
    /// Token capacity (and the initial balance). 0 disables retries
    /// entirely: any failover hop errors with "retry budget exhausted".
    pub capacity: f64,
    /// Tokens deposited per successful submit (`0.1` ≈ the classic
    /// "retries may add 10% load" budget).
    pub deposit: f64,
}

impl Default for RetryBudgetConfig {
    fn default() -> Self {
        RetryBudgetConfig { capacity: 10.0, deposit: 0.1 }
    }
}

/// Hedged-request configuration. A reply still outstanding past the
/// straggler deadline — [`crate::sorter::merge::model_hedge_deadline`]
/// (`straggler_mult ×` the modelled arrival at the shard's observed
/// cycles/number), converted to host time with the fleet's observed
/// µs-per-simulated-cycle calibration and floored at `floor_us` — is
/// re-issued once to the next-best healthy shard by the cost route.
/// First delivered reply wins; the loser is abandoned (settled and its
/// late reply discarded). Hedges draw from the retry budget, so a
/// degraded fleet hedges less, not more.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HedgeConfig {
    /// How many times the modelled arrival a reply may be outstanding
    /// before it counts as a straggler.
    pub straggler_mult: f64,
    /// Lower bound on the hedge deadline in host µs, so tiny chunks
    /// (and the cold start before any µs-per-cycle observation) don't
    /// hedge on scheduling noise.
    pub floor_us: u64,
}

impl Default for HedgeConfig {
    fn default() -> Self {
        HedgeConfig { straggler_mult: 4.0, floor_us: 20_000 }
    }
}

/// Fleet-level resilience: the retry budget is always on (set
/// `capacity` high to effectively disable the bound); hedging is
/// opt-in — it re-routes straggling work *speculatively*, which an
/// operator should choose, not inherit.
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct ResilienceConfig {
    /// The failover/hedge token bucket.
    pub retry_budget: RetryBudgetConfig,
    /// Hedged requests; `None` (the default) waits indefinitely on the
    /// serving shard like PR 4 did.
    pub hedge: Option<HedgeConfig>,
}

impl ResilienceConfig {
    fn validate(&self) -> Result<()> {
        let b = &self.retry_budget;
        if !b.capacity.is_finite() || b.capacity < 0.0 || !b.deposit.is_finite() || b.deposit < 0.0
        {
            return Err(anyhow!(
                "retry budget must be finite and non-negative (capacity {}, deposit {})",
                b.capacity,
                b.deposit
            ));
        }
        if let Some(h) = &self.hedge {
            if !h.straggler_mult.is_finite() || h.straggler_mult < 0.0 {
                return Err(anyhow!(
                    "hedge straggler multiplier must be finite and non-negative, got {}",
                    h.straggler_mult
                ));
            }
        }
        Ok(())
    }
}

/// Fleet configuration: one independent host per entry of `services`
/// (hosts may differ in geometry, workers, engine — a heterogeneous
/// fleet), routed by `route`.
#[derive(Clone, Debug)]
pub struct ShardedConfig {
    /// Routing policy.
    pub route: RoutePolicy,
    /// Per-shard service configurations; `services.len()` is the shard
    /// count.
    pub services: Vec<ServiceConfig>,
    /// Retry-budget / hedging behaviour.
    pub resilience: ResilienceConfig,
}

impl ShardedConfig {
    /// The classic uniform fleet: `shards` identical hosts cloned from
    /// one `service` template.
    pub fn uniform(shards: usize, route: RoutePolicy, service: ServiceConfig) -> Self {
        ShardedConfig {
            route,
            services: vec![service; shards],
            resilience: ResilienceConfig::default(),
        }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.services.len()
    }
}

impl Default for ShardedConfig {
    fn default() -> Self {
        ShardedConfig::uniform(2, RoutePolicy::RoundRobin, ServiceConfig::default())
    }
}

/// One shard: a transport to its host plus the fleet-side accounting
/// around it.
struct Shard {
    /// How the coordinator reaches the host — in-process today
    /// ([`LocalTransport`]), a wire later.
    transport: Box<dyn ShardTransport>,
    /// The host's planner geometry, cached at fleet start so the
    /// cost-aware router does not clone a [`ServiceConfig`] per
    /// decision.
    geometry: Geometry,
    /// Jobs submitted to this shard and not yet answered.
    outstanding: AtomicU64,
    /// Cleared when the shard's service is observed dead (submit or
    /// reply channel closed); routing skips unhealthy shards.
    healthy: AtomicBool,
    /// Requests/chunks this fleet re-routed *away* from this shard.
    rerouted_from: AtomicU64,
}

/// Aggregated view over the fleet: totals across shards, the per-shard
/// snapshots (each carrying its own p50/p99), and the imbalance ratio.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    /// Per-shard metric snapshots, indexed by shard id.
    pub shards: Vec<Snapshot>,
    /// Per-shard health at snapshot time.
    pub healthy: Vec<bool>,
    /// Completed requests across the fleet.
    pub completed: u64,
    /// Errors across the fleet.
    pub errors: u64,
    /// Elements sorted across the fleet.
    pub elements: u64,
    /// Simulated near-memory cycles across the fleet.
    pub sim_cycles: u64,
    /// Hierarchical sorts completed at the fleet level.
    pub hier_completed: u64,
    /// Elements through the fleet's hierarchical pipeline.
    pub hier_elements: u64,
    /// Chunks the fleet's hierarchical sorts fanned out.
    pub hier_chunks: u64,
    /// Modelled merge-network cycles of fleet hierarchical sorts.
    pub merge_cycles: u64,
    /// Comparator ops of fleet hierarchical sorts.
    pub merge_comparisons: u64,
    /// Times the router observed a dead shard and moved work off it
    /// since the fleet started.
    pub rerouted: u64,
    /// Shards re-admitted to routing by
    /// [`ShardedSortService::recover_shard`] since the fleet started.
    pub recovered: u64,
    /// Failover hops actually paid for from the retry budget (every
    /// `rerouted` hop spends one token; a hop denied by an empty
    /// bucket shows up in `budget_exhausted` instead).
    pub retries: u64,
    /// Hedged requests whose speculative copy delivered first.
    pub hedges_won: u64,
    /// Hedged requests whose original delivered first (the hedge was
    /// abandoned).
    pub hedges_lost: u64,
    /// Retry/hedge attempts denied because the token bucket was empty.
    pub budget_exhausted: u64,
    /// Current retry-budget balance, in tokens.
    pub retry_tokens: f64,
    /// Requests admitted by the frontend's request plane. 0 in a
    /// snapshot taken straight from the fleet — only
    /// [`super::frontend::Frontend::fleet_metrics`] knows the
    /// admission plane and fills these three in.
    pub admitted: u64,
    /// Requests shed at saturation (both priority classes).
    pub shed_saturated: u64,
    /// Requests refused at a per-tenant outstanding cap.
    pub shed_tenant_cap: u64,
    /// Worst per-shard p50 (µs) — the fleet's slow-median shard.
    pub p50_us: u64,
    /// Worst per-shard p99 (µs).
    pub p99_us: u64,
    /// Shard imbalance: max elements served by one shard over the
    /// per-shard mean. 1.0 = perfectly balanced; grows as routing
    /// skews. 1.0 when the fleet has served nothing.
    pub imbalance: f64,
    /// Mean simulated cycles per element across the fleet.
    pub cycles_per_number: f64,
}

impl FleetSnapshot {
    /// Observed cycles/number for `n`'s size class, element-weighted
    /// across every shard's per-class observations, falling back to the
    /// fleet-wide average and then to `fallback` — the fleet analogue
    /// of [`Snapshot::cyc_per_num_for`], feeding the sharded
    /// auto-tuner.
    pub fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        let class = size_class(n);
        let (mut cycles, mut elems) = (0.0f64, 0u64);
        for s in &self.shards {
            let e = s.class_elements[class];
            cycles += s.class_cyc_per_num[class] * e as f64;
            elems += e;
        }
        if elems > 0 {
            cycles / elems as f64
        } else if self.elements > 0 {
            self.sim_cycles as f64 / self.elements as f64
        } else {
            fallback
        }
    }
}

/// Result of one fleet hierarchical sort: the single-service-identical
/// pipeline output plus the shard-level view.
#[derive(Clone, Debug)]
pub struct ShardedOutput {
    /// The assembled pipeline result — byte-identical (values, argsort,
    /// per-chunk stats, merge accounting) to
    /// [`super::SortService::sort_hierarchical`] on one host.
    pub hier: HierarchicalOutput,
    /// Which shard served each chunk (after any re-routing).
    pub assignments: Vec<usize>,
    /// Chunks served per shard.
    pub shard_chunks: Vec<usize>,
    /// Chunks re-routed off a failed shard during this sort.
    pub rerouted: u64,
    /// Fleet latency model over the *actual* per-chunk cycles, under
    /// the schedule that ran: each shard drains its chunks through its
    /// own merge engine (streaming: [`model_streamed_completion`] per
    /// shard; barrier: slowest arrival + that shard's merge passes),
    /// and a top-level merge combines the shard streams the same way.
    /// With one shard this equals `hier.latency_cycles` exactly.
    pub sharded_latency_cycles: u64,
}

impl ShardedOutput {
    /// Cycles the fleet topology saves over the single-engine schedule
    /// of the mode that ran, as a fraction of the latter (0 with one
    /// shard; can be negative when the cross-shard merge pass costs
    /// more than the parallel drains save, e.g. many shards at a small
    /// fanout).
    pub fn fleet_saving(&self) -> f64 {
        if self.hier.latency_cycles == 0 {
            0.0
        } else {
            1.0 - self.sharded_latency_cycles as f64 / self.hier.latency_cycles as f64
        }
    }
}

/// Handle to a running fleet.
pub struct ShardedSortService {
    shards: Vec<Shard>,
    route: RoutePolicy,
    rr: AtomicU64,
    /// Fleet-level pipeline counters (per-shard chunk work lives in the
    /// shards' own metrics).
    fleet: ServiceMetrics,
    /// Shards re-admitted by [`Self::recover_shard`].
    recovered: AtomicU64,
    resilience: ResilienceConfig,
    /// Retry-budget token balance (see [`RetryBudgetConfig`]).
    tokens: Mutex<f64>,
    retries: AtomicU64,
    hedges_won: AtomicU64,
    hedges_lost: AtomicU64,
    budget_exhausted: AtomicU64,
    /// Observed host-µs per simulated cycle (EWMA over delivered
    /// replies): the calibration that converts the model-derived hedge
    /// deadline from cycles to wall time. `None` before any reply.
    us_per_cycle: Mutex<Option<f64>>,
    config: ShardedConfig,
}

impl ShardedSortService {
    /// Start one independent in-process host per `config.services`
    /// entry ([`LocalTransport`]). An empty fleet is an error, not a
    /// panic — the shard count comes straight from a CLI flag.
    pub fn start(config: ShardedConfig) -> Result<Self> {
        if config.services.is_empty() {
            return Err(anyhow!("a fleet has at least one shard (got --shards 0?)"));
        }
        let transports = config
            .services
            .iter()
            .map(|svc| {
                Ok(Box::new(LocalTransport::start(svc.clone())?) as Box<dyn ShardTransport>)
            })
            .collect::<Result<Vec<_>>>()?;
        Self::with_transports_resilient(config.route, config.resilience, transports)
    }

    /// [`Self::with_transports`] with default resilience (the classic
    /// retry budget, no hedging).
    pub fn with_transports(
        route: RoutePolicy,
        transports: Vec<Box<dyn ShardTransport>>,
    ) -> Result<Self> {
        Self::with_transports_resilient(route, ResilienceConfig::default(), transports)
    }

    /// Assemble a fleet over caller-provided transports — the RPC /
    /// fault-injection entry point. The per-shard [`ServiceConfig`]s
    /// that feed the planner, the cost model and [`Self::config`] are
    /// derived from the transports themselves
    /// ([`ShardTransport::config`]), so a caller cannot hand the
    /// coordinator a config list that disagrees with the hosts.
    pub fn with_transports_resilient(
        route: RoutePolicy,
        resilience: ResilienceConfig,
        transports: Vec<Box<dyn ShardTransport>>,
    ) -> Result<Self> {
        if transports.is_empty() {
            return Err(anyhow!("a fleet has at least one shard (got --shards 0?)"));
        }
        resilience.validate()?;
        // One `config()` call per transport, reused for both the fleet
        // config and the cached routing geometry — an RPC transport
        // whose config is fetched remotely must not be able to hand
        // the two readers different answers.
        let mut services = Vec::with_capacity(transports.len());
        let shards: Vec<Shard> = transports
            .into_iter()
            .map(|transport| {
                let svc = transport.config();
                let geometry = svc.geometry.clone();
                services.push(svc);
                Shard {
                    geometry,
                    transport,
                    outstanding: AtomicU64::new(0),
                    healthy: AtomicBool::new(true),
                    rerouted_from: AtomicU64::new(0),
                }
            })
            .collect();
        Ok(ShardedSortService {
            shards,
            route,
            rr: AtomicU64::new(0),
            fleet: ServiceMetrics::new(),
            recovered: AtomicU64::new(0),
            resilience,
            tokens: Mutex::new(resilience.retry_budget.capacity),
            retries: AtomicU64::new(0),
            hedges_won: AtomicU64::new(0),
            hedges_lost: AtomicU64::new(0),
            budget_exhausted: AtomicU64::new(0),
            us_per_cycle: Mutex::new(None),
            config: ShardedConfig { route, services, resilience },
        })
    }

    /// The fleet configuration.
    pub fn config(&self) -> &ShardedConfig {
        &self.config
    }

    /// Number of shards (healthy or not).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shards currently accepting work.
    pub fn healthy_count(&self) -> usize {
        self.shards.iter().filter(|s| s.healthy.load(Ordering::Relaxed)).count()
    }

    /// Retire shard `i` the way a crashed host would: its workers are
    /// told to exit (the transport's halt) and routing stops offering
    /// it work immediately. In-flight jobs on it either drain (they
    /// were queued ahead of the halt) or surface as dropped replies,
    /// which the fleet re-routes. An out-of-range index is an error,
    /// not a panic — it can come from a CLI flag or an operator tool.
    pub fn fail_shard(&self, i: usize) -> Result<()> {
        let shard = self
            .shards
            .get(i)
            .ok_or_else(|| anyhow!("shard {i} out of range (fleet has {})", self.shards.len()))?;
        shard.healthy.store(false, Ordering::Relaxed);
        shard.transport.halt();
        Ok(())
    }

    /// Re-admit shard `i`: restart the host through its transport and
    /// put it back into routing. The host comes back *empty* (no queued
    /// work, no metric history — like a real restarted process), which
    /// is exactly what warms it back in: its jobs all settled when they
    /// were re-routed off the dead host, so it is the least-outstanding
    /// pick, and its cost falls back to the nominal constant — every
    /// policy starts offering it work immediately (pinned by
    /// `recovered_shard_receives_new_work_under_every_policy`). The
    /// outstanding counter is deliberately *not* reset: every submit
    /// settles exactly once on every path, so the counter already
    /// tracks genuinely in-flight fleet jobs, and zeroing it would let
    /// late settles from the old host eat decrements belonging to new
    /// post-recovery work. Recovering a healthy shard is allowed and
    /// restarts it (an operator-driven host replacement).
    pub fn recover_shard(&self, i: usize) -> Result<()> {
        let shard = self
            .shards
            .get(i)
            .ok_or_else(|| anyhow!("shard {i} out of range (fleet has {})", self.shards.len()))?;
        shard.transport.restart()?;
        shard.healthy.store(true, Ordering::Relaxed);
        self.recovered.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// The cost-aware routing score for serving `len` elements on shard
    /// `sid`: the schedule-derived *completion* of the chunk behind the
    /// shard's live queue. The host is modelled as a lane already
    /// owning its `q` outstanding chunks ([`shard_model`]: observed
    /// per-class cyc/num, plus the oversize-assembly merge when the
    /// request exceeds the host's tallest bank), and the score is when
    /// a `q+1`-chunk lane *drains*
    /// ([`schedule::uniform_completion`]). At an empty queue this
    /// reduces exactly to the modelled arrival the pre-schedule score
    /// used, and it grows strictly with queue depth, so the old score's
    /// orderings are preserved — but a deep queue is now charged its
    /// superlinear merge serialization instead of a linear proxy.
    /// Lower is better.
    fn route_cost(&self, sid: usize, len: usize) -> f64 {
        let shard = &self.shards[sid];
        let n = len.max(1);
        let cyc = shard
            .transport
            .cyc_per_num_for(n, crate::params::NOMINAL_COLSKIP_CYC_PER_NUM);
        let fanout = shard.geometry.merge_fanout.max(2);
        let m = shard_model(n, fanout, &shard.geometry, cyc);
        let q = shard.outstanding.load(Ordering::Relaxed);
        schedule::uniform_completion(q as usize + 1, n, m.arrival + q * m.oversize, fanout) as f64
    }

    /// Pick a shard for a request of `len` elements under the policy,
    /// skipping unhealthy shards. `offset` distinguishes the chunks of
    /// one hierarchical fan-out (0 for plain requests): round-robin,
    /// least-outstanding and cost ignore it (the latter two spread via
    /// the outstanding counts the fan-out itself builds up), size-class
    /// affinity adds it to the class's home shard so one sort's
    /// same-class chunks still spread. `None` when the whole fleet is
    /// down.
    fn route_for(&self, len: usize, offset: usize) -> Option<usize> {
        let healthy: Vec<usize> = (0..self.shards.len())
            .filter(|&i| self.shards[i].healthy.load(Ordering::Relaxed))
            .collect();
        if healthy.is_empty() {
            return None;
        }
        let pick = match self.route {
            RoutePolicy::RoundRobin => {
                healthy[(self.rr.fetch_add(1, Ordering::Relaxed) % healthy.len() as u64) as usize]
            }
            RoutePolicy::LeastOutstanding => *healthy
                .iter()
                .min_by_key(|&&i| (self.shards[i].outstanding.load(Ordering::Relaxed), i))
                .expect("non-empty"),
            RoutePolicy::SizeClass => healthy[(size_class(len) + offset) % healthy.len()],
            RoutePolicy::Cost => {
                // Score each shard once, then take the minimum —
                // `min_by` comparators re-evaluate their keys, and a
                // 977-chunk fan-out pays the cost model per decision.
                let scores: Vec<(f64, usize)> =
                    healthy.iter().map(|&i| (self.route_cost(i, len), i)).collect();
                scores
                    .into_iter()
                    .min_by(|a, b| {
                        a.0.partial_cmp(&b.0)
                            .unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.1.cmp(&b.1))
                    })
                    .expect("non-empty")
                    .1
            }
        };
        Some(pick)
    }

    /// Route and submit one job, failing over to surviving shards when
    /// a submit hits a dead service (each failover bumps `rerouted`
    /// and spends one retry token). A tagged job keeps its tag across
    /// every hop — attribution survives failover. Returns the serving
    /// shard id and the response receiver; the caller owns the
    /// outstanding decrement (via [`Self::settle`]).
    fn submit_routed(
        &self,
        tag: Option<&JobTag>,
        data: &[u32],
        offset: usize,
        rerouted: &mut u64,
    ) -> Result<(usize, mpsc::Receiver<Result<SortResponse>>)> {
        let mut tries = 0u64;
        loop {
            let Some(sid) = self.route_for(data.len(), offset) else {
                return Err(anyhow!("every shard is down"));
            };
            match self.shard_submit(sid, tag, data) {
                Ok(rx) => {
                    self.shards[sid].outstanding.fetch_add(1, Ordering::Relaxed);
                    *rerouted += tries;
                    self.deposit_budget();
                    return Ok((sid, rx));
                }
                Err(_) => {
                    // The shard's channel is closed: a dead host.
                    // Isolate it and — budget permitting — try the
                    // next healthy shard.
                    self.mark_dead(sid);
                    tries += 1;
                    self.charge_retry()?;
                }
            }
        }
    }

    /// One shard submit, tagged or plain — the single spot where the
    /// optional tag meets the transport seam.
    fn shard_submit(
        &self,
        sid: usize,
        tag: Option<&JobTag>,
        data: &[u32],
    ) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        match tag {
            Some(t) => self.shards[sid].transport.submit_tagged(t, data.to_vec()),
            None => self.shards[sid].transport.submit(data.to_vec()),
        }
    }

    fn mark_dead(&self, sid: usize) {
        self.shards[sid].healthy.store(false, Ordering::Relaxed);
        self.shards[sid].rerouted_from.fetch_add(1, Ordering::Relaxed);
    }

    /// Deposit the per-success refill into the retry bucket (capped).
    fn deposit_budget(&self) {
        let b = self.resilience.retry_budget;
        if b.deposit > 0.0 {
            let mut tokens = self.tokens.lock().expect("budget poisoned");
            *tokens = (*tokens + b.deposit).min(b.capacity);
        }
    }

    /// Take one token if the bucket has it; an empty bucket counts a
    /// `budget_exhausted` and denies.
    fn try_spend_budget(&self) -> bool {
        let mut tokens = self.tokens.lock().expect("budget poisoned");
        if *tokens >= 1.0 {
            *tokens -= 1.0;
            true
        } else {
            self.budget_exhausted.fetch_add(1, Ordering::Relaxed);
            false
        }
    }

    /// A failover hop is about to happen: pay for it or refuse it. The
    /// refusal is an *error*, not a silent wait — a fleet that has
    /// burnt its budget must shed load visibly rather than amplify it.
    fn charge_retry(&self) -> Result<()> {
        if self.try_spend_budget() {
            self.retries.fetch_add(1, Ordering::Relaxed);
            Ok(())
        } else {
            Err(anyhow!(
                "retry budget exhausted ({} denied so far): the fleet is shedding failovers",
                self.budget_exhausted.load(Ordering::Relaxed)
            ))
        }
    }

    /// Fold a delivered reply into the µs-per-simulated-cycle EWMA —
    /// the calibration that turns the cycle-domain hedge deadline into
    /// host time.
    fn observe_reply(&self, resp: &Result<SortResponse>) {
        if let Ok(r) = resp {
            let cycles = r.stats.cycles();
            if cycles > 0 {
                let sample = r.latency_us as f64 / cycles as f64;
                let mut g = self.us_per_cycle.lock().expect("calibration poisoned");
                *g = Some(match *g {
                    Some(prev) => 0.8 * prev + 0.2 * sample,
                    None => sample,
                });
            }
        }
    }

    /// The hedge deadline for a job of `len` elements outstanding on
    /// shard `sid`, in host time: the schedule layer's straggler bound
    /// in modelled cycles ([`schedule::hedge_deadline`] at the shard's
    /// observed cycles/number — the same timeline arrival every other
    /// completion number derives from), converted through the observed
    /// µs-per-cycle calibration, floored at the config's `floor_us`.
    /// `None` when hedging is off.
    fn hedge_deadline(&self, sid: usize, len: usize) -> Option<Duration> {
        let h = self.resilience.hedge.as_ref()?;
        let n = len.max(1);
        let cyc = self.shards[sid]
            .transport
            .cyc_per_num_for(n, crate::params::NOMINAL_COLSKIP_CYC_PER_NUM);
        let cycles = schedule::hedge_deadline(n, cyc, h.straggler_mult, 0);
        let us = match *self.us_per_cycle.lock().expect("calibration poisoned") {
            Some(ratio) => (cycles as f64 * ratio) as u64,
            None => 0, // cold start: the floor carries the deadline
        };
        Some(Duration::from_micros(us.max(h.floor_us)))
    }

    /// Try to issue a hedge for a straggling job: pick the next-best
    /// healthy shard by the cost route (excluding the straggler),
    /// spend a budget token, and submit the same data there. `None`
    /// when no other shard is healthy, the budget denies, or the
    /// chosen shard turns out dead at submit (it is isolated, and the
    /// hedge is simply not placed — the original stays the only lane).
    fn issue_hedge(
        &self,
        primary: usize,
        tag: Option<&JobTag>,
        data: &[u32],
    ) -> Option<(usize, mpsc::Receiver<Result<SortResponse>>)> {
        let scores: Vec<(f64, usize)> = (0..self.shards.len())
            .filter(|&i| i != primary && self.shards[i].healthy.load(Ordering::Relaxed))
            .map(|i| (self.route_cost(i, data.len()), i))
            .collect();
        let hsid = scores
            .into_iter()
            .min_by(|a, b| {
                a.0.partial_cmp(&b.0).unwrap_or(std::cmp::Ordering::Equal).then(a.1.cmp(&b.1))
            })?
            .1;
        if !self.try_spend_budget() {
            return None;
        }
        match self.shard_submit(hsid, tag, data) {
            Ok(rx) => {
                self.shards[hsid].outstanding.fetch_add(1, Ordering::Relaxed);
                Some((hsid, rx))
            }
            Err(_) => {
                self.mark_dead(hsid);
                None
            }
        }
    }

    fn settle(&self, sid: usize) {
        // Every submit settles exactly once on every path, so the
        // counter cannot genuinely underflow; saturate anyway — a wrap
        // to u64::MAX would permanently starve the shard under
        // least-outstanding routing, far worse than a transiently low
        // count.
        let _ = self.shards[sid].outstanding.fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |v| Some(v.saturating_sub(1)),
        );
    }

    /// Wait for one routed job, re-routing off every shard that dies
    /// with the job in flight (`rerouted` counts the hops, each paid
    /// from the retry budget) and — when hedging is enabled — racing a
    /// straggler against one speculative copy on the next-best shard.
    /// Settles the outstanding count of each shard tried, on every
    /// exit path; an abandoned hedge loser is settled when abandoned
    /// and its late reply discarded.
    fn recv_rerouted(
        &self,
        sid: usize,
        rx: mpsc::Receiver<Result<SortResponse>>,
        tag: Option<&JobTag>,
        data: &[u32],
        offset: usize,
        rerouted: &mut u64,
    ) -> Result<(usize, SortResponse)> {
        use mpsc::RecvTimeoutError::{Disconnected, Timeout};
        let mut primary = (sid, rx);
        let mut hedge: Option<(usize, mpsc::Receiver<Result<SortResponse>>)> = None;
        // One hedge per job: armed while hedging is configured and the
        // attempt has not been spent (issued, denied, or the hedge lane
        // died — in every case the job is back to a single lane).
        let mut hedge_armed = self.resilience.hedge.is_some();
        loop {
            if let Some((hsid, hrx)) = hedge.take() {
                // Two lanes in flight: race them in short slices.
                // First *delivered* reply wins (identical content
                // either way — the simulated response is a function of
                // the data); the loser is abandoned: settled now, its
                // late reply discarded by the dropped receiver.
                let slice = Duration::from_millis(1);
                match primary.1.recv_timeout(slice) {
                    Ok(resp) => {
                        self.settle(primary.0);
                        self.settle(hsid);
                        self.hedges_lost.fetch_add(1, Ordering::Relaxed);
                        self.observe_reply(&resp);
                        return resp.map(|r| (primary.0, r));
                    }
                    Err(Disconnected) => {
                        // The straggler turned out dead: the hedge is
                        // promoted to the only lane.
                        self.settle(primary.0);
                        self.mark_dead(primary.0);
                        *rerouted += 1;
                        primary = (hsid, hrx);
                        continue;
                    }
                    Err(Timeout) => {}
                }
                match hrx.recv_timeout(slice) {
                    Ok(resp) => {
                        self.settle(hsid);
                        self.settle(primary.0);
                        self.hedges_won.fetch_add(1, Ordering::Relaxed);
                        self.observe_reply(&resp);
                        return resp.map(|r| (hsid, r));
                    }
                    Err(Disconnected) => {
                        // The hedge lane died; the original carries on
                        // alone (no second hedge for this job).
                        self.settle(hsid);
                        self.mark_dead(hsid);
                        *rerouted += 1;
                    }
                    Err(Timeout) => hedge = Some((hsid, hrx)),
                }
                continue;
            }
            // Single lane: wait outright, or up to the straggler
            // deadline while a hedge is still available.
            let deadline =
                if hedge_armed { self.hedge_deadline(primary.0, data.len()) } else { None };
            let outcome = match deadline {
                Some(t) => primary.1.recv_timeout(t),
                None => primary.1.recv().map_err(|_| Disconnected),
            };
            match outcome {
                Ok(resp) => {
                    self.settle(primary.0);
                    self.observe_reply(&resp);
                    return resp.map(|r| (primary.0, r));
                }
                Err(Disconnected) => {
                    // The worker vanished under the job: dead host.
                    self.settle(primary.0);
                    self.mark_dead(primary.0);
                    *rerouted += 1;
                    self.charge_retry()?;
                    primary = self.submit_routed(tag, data, offset, rerouted)?;
                }
                Err(Timeout) => {
                    // Straggler: hedge once if the fleet and the
                    // budget allow; either way the attempt is spent.
                    hedge = self.issue_hedge(primary.0, tag, data);
                    hedge_armed = false;
                }
            }
        }
    }

    /// Submit one request and wait, re-routing off a shard that dies
    /// with the job in flight.
    pub fn submit_wait(&self, data: Vec<u32>) -> Result<SortResponse> {
        let mut rerouted = 0;
        let (sid, rx) = self.submit_routed(None, &data, 0, &mut rerouted)?;
        self.recv_rerouted(sid, rx, None, &data, 0, &mut rerouted).map(|(_, resp)| resp)
    }

    /// [`Self::submit_wait`] with the request-plane tag riding along:
    /// same routing, same failover and hedging (the tag survives every
    /// hop), and on wire transports the tag crosses to the host
    /// ([`super::wire::Frame::SortJobTagged`]). The frontend's sort
    /// path ([`super::frontend::Frontend::sort`]) comes through here.
    pub fn submit_wait_tagged(&self, tag: &JobTag, data: Vec<u32>) -> Result<SortResponse> {
        let mut rerouted = 0;
        let (sid, rx) = self.submit_routed(Some(tag), &data, 0, &mut rerouted)?;
        self.recv_rerouted(sid, rx, Some(tag), &data, 0, &mut rerouted).map(|(_, resp)| resp)
    }

    /// Current retry-budget balance — the saturation signal the
    /// frontend's admission plane reads (cheap: one mutex, no
    /// per-shard RPC).
    pub fn retry_tokens(&self) -> f64 {
        *self.tokens.lock().expect("budget poisoned")
    }

    /// Jobs submitted to shards and not yet settled, across the fleet.
    pub fn outstanding_total(&self) -> u64 {
        self.shards.iter().map(|s| s.outstanding.load(Ordering::Relaxed)).sum()
    }

    /// Sort through the hierarchical pipeline across the fleet: route
    /// bank-sized chunks over the shards, absorb the responses into the
    /// shared `ChunkAssembly` (byte-identical to the single-service
    /// path), re-routing chunks off any shard that dies mid-flight.
    pub fn sort_hierarchical(
        &self,
        data: &[u32],
        cfg: &HierarchicalConfig,
    ) -> Result<ShardedOutput> {
        if cfg.fanout < 2 {
            return Err(anyhow!("merge fanout must be at least 2, got {}", cfg.fanout));
        }
        let n = data.len();
        let (capacity, fanout) = self.resolve_chunking(n, cfg);
        if capacity < 1 {
            return Err(anyhow!("bank capacity must be positive"));
        }
        // Same spill rule as the single-service path: the hierarchical
        // assembly (and its merge working set) lives on this
        // coordinator regardless of where the chunks sort, so the
        // budget governs it identically.
        let store = if cfg.budget.fits(resident_merge_bytes(n)) {
            None
        } else {
            Some(TempDirRunStore::new()?)
        };
        let mut asm = match &store {
            Some(s) => ChunkAssembly::new_spilling(
                partition(n, capacity),
                fanout,
                cfg.streaming,
                s as &dyn RunStore,
            ),
            None => ChunkAssembly::new(partition(n, capacity), fanout, cfg.streaming),
        };
        let chunks = asm.spans().len();

        // Fan every chunk out across the fleet up front (parallel
        // hosts), recording the routed shard per chunk. On any error —
        // here or while collecting — the outstanding count of every
        // still-pending chunk is settled before returning, so a failed
        // sort can never skew LeastOutstanding routing for later work.
        let spans: Vec<std::ops::Range<usize>> = asm.spans().to_vec();
        let mut pending = Vec::with_capacity(chunks);
        let mut assignments = Vec::with_capacity(chunks);
        let mut rerouted = 0u64;
        let fanned: Result<()> = spans.iter().enumerate().try_for_each(|(i, span)| {
            pending.push(Some(self.submit_routed(None, &data[span.clone()], i, &mut rerouted)?));
            Ok(())
        });
        // Collect in chunk order; a dropped reply means the serving
        // shard died — `recv_rerouted` moves that chunk to a survivor
        // instead of failing the sort.
        let collected: Result<()> = fanned.and_then(|()| {
            for (i, slot) in pending.iter_mut().enumerate() {
                let (sid, rx) = slot.take().expect("fan-out filled every slot");
                let (served, resp) =
                    self.recv_rerouted(sid, rx, None, &data[spans[i].clone()], i, &mut rerouted)?;
                assignments.push(served);
                asm.absorb(i, &resp)?;
            }
            Ok(())
        });
        if let Err(e) = collected {
            for (sid, _rx) in pending.into_iter().flatten() {
                self.settle(sid);
            }
            return Err(e);
        }

        // Fleet latency model over the actual per-chunk cycles, under
        // the schedule that ran: each shard's own merge engine drains
        // its chunks (in assignment order), then the top-level merge
        // combines the shard streams the same way.
        let mut per_shard: Vec<Vec<(u64, usize)>> = vec![Vec::new(); self.shards.len()];
        for (leaf, &sid) in asm.arrivals().iter().zip(&assignments) {
            per_shard[sid].push(*leaf);
        }
        let shard_chunks: Vec<usize> = per_shard.iter().map(Vec::len).collect();
        let active: Vec<&Vec<(u64, usize)>> =
            per_shard.iter().filter(|leaves| !leaves.is_empty()).collect();
        let sharded_latency_cycles = if cfg.streaming {
            let shard_streams: Vec<(u64, usize)> = active
                .iter()
                .map(|leaves| {
                    (
                        model_streamed_completion(leaves, fanout),
                        leaves.iter().map(|&(_, l)| l).sum(),
                    )
                })
                .collect();
            model_streamed_completion(&shard_streams, fanout)
        } else {
            // Barrier fleet: every shard barriers on its own chunks,
            // then the cross-shard merge barriers on the shard streams.
            // Reduces to `hier.barrier_latency_cycles` at one shard
            // (the cross-shard stage has a single run: zero passes).
            let worst = active
                .iter()
                .map(|leaves| {
                    let arrival = leaves.iter().map(|&(a, _)| a).max().unwrap_or(0);
                    let len: usize = leaves.iter().map(|&(_, l)| l).sum();
                    arrival + model_merge_cycles(len, leaves.len(), fanout)
                })
                .max()
                .unwrap_or(0);
            worst + model_merge_cycles(n, active.len(), fanout)
        };

        // Cost totals are referenced to shard 0's engine configuration;
        // a heterogeneous fleet's silicon differs per host, but the
        // pipeline output needs one deterministic reference ensemble.
        let out = asm.finish(&self.config.services[0], capacity)?;
        self.fleet.record_hierarchical(n, chunks, out.merge.cycles, out.merge.comparisons);

        Ok(ShardedOutput {
            hier: out,
            assignments,
            shard_chunks,
            rerouted,
            sharded_latency_cycles,
        })
    }

    /// Resolve the `(bank capacity, merge fanout)` a fleet hierarchical
    /// sort will use: fixed from the config, or auto-tuned with the
    /// heterogeneous fleet model ([`auto_tune_hetero`]) over the
    /// *healthy* shards' geometries and each shard's own observed
    /// per-class costs — a degraded fleet must not pick a plan whose
    /// parallelism (or geometry) retired with its dead shards. On a
    /// uniform fleet this is exactly the PR-3
    /// [`super::planner::auto_tune_sharded`] pick (the hetero tuner
    /// reduces to it; pinned by `auto_capacity_uses_the_shard_dimension`).
    pub fn resolve_chunking(&self, n: usize, cfg: &HierarchicalConfig) -> (usize, usize) {
        match cfg.capacity {
            Capacity::Fixed(c) => (c, cfg.fanout),
            Capacity::Auto => {
                let healthy: Vec<&Shard> = self
                    .shards
                    .iter()
                    .filter(|s| s.healthy.load(Ordering::Relaxed))
                    .collect();
                // A fully-degraded fleet still resolves a plan (the
                // sort itself will fail on routing): score shard 0.
                let healthy = if healthy.is_empty() {
                    vec![&self.shards[0]]
                } else {
                    healthy
                };
                let geos: Vec<Geometry> =
                    healthy.iter().map(|s| s.geometry.clone()).collect();
                auto_tune_hetero(n, &geos, cfg.streaming, |s, bank| {
                    healthy[s]
                        .transport
                        .cyc_per_num_for(bank, crate::params::NOMINAL_COLSKIP_CYC_PER_NUM)
                })
            }
        }
    }

    /// Aggregate fleet metrics: totals, per-shard snapshots, imbalance.
    /// A recovered shard reports from zero (its host restarted), so
    /// fleet totals can step down across a recovery — like a real
    /// fleet's gauge after losing a host's counters.
    pub fn fleet_metrics(&self) -> FleetSnapshot {
        let snaps: Vec<Snapshot> = self.shards.iter().map(|s| s.transport.metrics()).collect();
        let healthy: Vec<bool> =
            self.shards.iter().map(|s| s.healthy.load(Ordering::Relaxed)).collect();
        let fleet = self.fleet.snapshot();
        let completed = snaps.iter().map(|s| s.completed).sum();
        let errors = snaps.iter().map(|s| s.errors).sum();
        let elements: u64 = snaps.iter().map(|s| s.elements).sum();
        let sim_cycles: u64 = snaps.iter().map(|s| s.sim_cycles).sum();
        let max_elements = snaps.iter().map(|s| s.elements).max().unwrap_or(0);
        // Clamp the imbalance denominator: a fleet whose serving shards
        // all just recovered reports zero elements everywhere (restarted
        // hosts lose their counters), and max/mean must degrade to the
        // balanced 1.0, never to a 0/0 NaN or a division by zero.
        let mean_elements = elements as f64 / self.shards.len() as f64;
        let imbalance =
            if mean_elements > 0.0 { max_elements as f64 / mean_elements } else { 1.0 };
        FleetSnapshot {
            healthy,
            completed,
            errors,
            elements,
            sim_cycles,
            hier_completed: fleet.hier_completed,
            hier_elements: fleet.hier_elements,
            hier_chunks: fleet.hier_chunks,
            merge_cycles: fleet.merge_cycles,
            merge_comparisons: fleet.merge_comparisons,
            rerouted: self
                .shards
                .iter()
                .map(|s| s.rerouted_from.load(Ordering::Relaxed))
                .sum(),
            recovered: self.recovered.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            hedges_won: self.hedges_won.load(Ordering::Relaxed),
            hedges_lost: self.hedges_lost.load(Ordering::Relaxed),
            budget_exhausted: self.budget_exhausted.load(Ordering::Relaxed),
            retry_tokens: *self.tokens.lock().expect("budget poisoned"),
            admitted: 0,
            shed_saturated: 0,
            shed_tenant_cap: 0,
            p50_us: snaps.iter().map(|s| s.p50_us).max().unwrap_or(0),
            p99_us: snaps.iter().map(|s| s.p99_us).max().unwrap_or(0),
            imbalance,
            cycles_per_number: if elements == 0 {
                0.0
            } else {
                sim_cycles as f64 / elements as f64
            },
            shards: snaps,
        }
    }

    /// Graceful shutdown of every shard — for remote shards this sends
    /// the wire `Shutdown` and *terminates the host processes*. A
    /// coordinator that merely wants to end its session with long-lived
    /// hosts should [`Self::disconnect`] instead.
    pub fn shutdown(self) {
        for shard in self.shards {
            shard.transport.shutdown();
        }
    }

    /// End the coordinator's session without touching the hosts: every
    /// shard link simply drops (a remote host sees the connection close
    /// and serves its next coordinator; `memsort sort --connect` uses
    /// this so operator-started `serve --shard` processes outlive the
    /// sort). In-process hosts are torn down with the handles — there
    /// is no one left to reach them.
    pub fn disconnect(self) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SortService;
    use crate::datasets::{Dataset, DatasetKind};

    fn fleet(shards: usize, route: RoutePolicy) -> ShardedSortService {
        ShardedSortService::start(ShardedConfig::uniform(
            shards,
            route,
            ServiceConfig { workers: 2, ..Default::default() },
        ))
        .unwrap()
    }

    /// Block until shard `i`'s host observably rejects work (halt
    /// drains asynchronously).
    fn wait_dead(f: &ShardedSortService, i: usize) {
        while f.shards[i].transport.submit(vec![1u32]).is_ok() {
            std::thread::yield_now();
        }
    }

    #[test]
    fn routes_and_sorts_across_shards() {
        for route in RoutePolicy::ALL {
            let f = fleet(3, route);
            for seed in 0..6u64 {
                let d = Dataset::generate32(DatasetKind::Uniform, 64, seed);
                let resp = f.submit_wait(d.values.clone()).unwrap();
                let mut expect = d.values;
                expect.sort_unstable();
                assert_eq!(resp.sorted, expect, "{route:?}");
            }
            let m = f.fleet_metrics();
            assert_eq!(m.completed, 6, "{route:?}");
            assert_eq!(m.errors, 0);
            if route == RoutePolicy::RoundRobin {
                // 6 equal requests over 3 shards: perfectly balanced.
                assert!(m.shards.iter().all(|s| s.completed == 2), "{route:?}");
                assert!((m.imbalance - 1.0).abs() < 1e-12, "{}", m.imbalance);
            }
            if route == RoutePolicy::SizeClass {
                // One size class: everything pins to one shard.
                assert_eq!(m.shards.iter().filter(|s| s.completed > 0).count(), 1);
                assert!((m.imbalance - 3.0).abs() < 1e-12, "{}", m.imbalance);
            }
            f.shutdown();
        }
    }

    #[test]
    fn sharded_hierarchical_matches_single_service() {
        let single =
            SortService::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap();
        let d = Dataset::generate32(DatasetKind::MapReduce, 3000, 17);
        let cfg = HierarchicalConfig::fixed(256, 4);
        let reference = single.sort_hierarchical(&d.values, &cfg).unwrap();
        for shards in [1usize, 2, 4] {
            for route in RoutePolicy::ALL {
                let f = fleet(shards, route);
                let out = f.sort_hierarchical(&d.values, &cfg).unwrap();
                assert_eq!(out.hier.output.sorted, reference.output.sorted);
                assert_eq!(out.hier.output.order, reference.output.order);
                assert_eq!(out.hier.output.stats, reference.output.stats);
                assert_eq!(out.hier.chunk_stats, reference.chunk_stats);
                assert_eq!(out.hier.merge.comparisons, reference.merge.comparisons);
                assert_eq!(out.hier.merge.passes, reference.merge.passes);
                assert_eq!(out.hier.streamed_latency_cycles, reference.streamed_latency_cycles);
                assert_eq!(out.hier.barrier_latency_cycles, reference.barrier_latency_cycles);
                assert_eq!(out.assignments.len(), reference.chunks());
                assert_eq!(out.shard_chunks.iter().sum::<usize>(), reference.chunks());
                assert_eq!(out.rerouted, 0);
                if shards == 1 {
                    // One shard is one host: the fleet model degenerates
                    // to the single-engine streamed schedule exactly.
                    assert_eq!(out.sharded_latency_cycles, reference.streamed_latency_cycles);
                    assert_eq!(out.fleet_saving(), 0.0);
                }
                f.shutdown();
            }
        }
        single.shutdown();
    }

    #[test]
    fn failed_shard_reroutes_chunks() {
        let f = fleet(2, RoutePolicy::RoundRobin);
        // Kill shard 1 and wait until its service observably rejects
        // work (the halt drains asynchronously).
        f.fail_shard(1).unwrap();
        wait_dead(&f, 1);
        assert_eq!(f.healthy_count(), 1);
        let d = Dataset::generate32(DatasetKind::Clustered, 1500, 5);
        let out = f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(128, 4)).unwrap();
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(out.hier.output.sorted, expect);
        // Every chunk landed on the survivor.
        assert!(out.assignments.iter().all(|&s| s == 0), "{:?}", out.assignments);
        assert_eq!(out.shard_chunks, vec![12, 0]);
        // Plain requests keep working too.
        let resp = f.submit_wait(d.values.clone()).unwrap();
        assert_eq!(resp.sorted, expect);
        f.shutdown();
    }

    #[test]
    fn inflight_shard_death_is_rerouted_not_fatal() {
        // Submit directly to a shard that is about to die, then let the
        // fleet's recv path observe the dropped reply and re-route.
        let f = fleet(2, RoutePolicy::LeastOutstanding);
        f.fail_shard(0).unwrap();
        wait_dead(&f, 0);
        // Undo the health mark so the router *tries* the dead shard:
        // this simulates a host that died without telling anyone.
        f.shards[0].healthy.store(true, Ordering::Relaxed);
        let d = Dataset::generate32(DatasetKind::Kruskal, 600, 9);
        let out = f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(64, 2)).unwrap();
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(out.hier.output.sorted, expect);
        // The dead shard was tried (submit fails fast now, so chunks
        // fail over at submit time) and everything ran on shard 1.
        assert!(out.assignments.iter().all(|&s| s == 1), "{:?}", out.assignments);
        assert_eq!(f.healthy_count(), 1, "the dead shard must be re-isolated");
        assert!(out.rerouted >= 1, "submit-time failovers count in the per-sort view");
        let m = f.fleet_metrics();
        assert!(m.rerouted >= 1, "the failover must be accounted fleet-wide");
        f.shutdown();
    }

    #[test]
    fn whole_fleet_down_is_an_error() {
        let f = fleet(2, RoutePolicy::RoundRobin);
        f.fail_shard(0).unwrap();
        f.fail_shard(1).unwrap();
        assert_eq!(f.healthy_count(), 0);
        assert!(f.submit_wait(vec![1, 2, 3]).is_err());
        assert!(f
            .sort_hierarchical(&[5, 4, 3, 2, 1], &HierarchicalConfig::fixed(2, 2))
            .is_err());
        f.shutdown();
    }

    #[test]
    fn fleet_metrics_aggregate_across_shards() {
        let f = fleet(2, RoutePolicy::RoundRobin);
        // Four plain requests round-robin across both shards.
        for seed in 0..4u64 {
            let d = Dataset::generate32(DatasetKind::MapReduce, 256, seed);
            f.submit_wait(d.values).unwrap();
        }
        // One hierarchical sort on top.
        let d = Dataset::generate32(DatasetKind::MapReduce, 1000, 7);
        f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(128, 4)).unwrap();
        let m = f.fleet_metrics();
        // Totals are the per-shard sums.
        assert_eq!(m.completed, m.shards.iter().map(|s| s.completed).sum::<u64>());
        assert_eq!(m.elements, m.shards.iter().map(|s| s.elements).sum::<u64>());
        assert_eq!(m.sim_cycles, m.shards.iter().map(|s| s.sim_cycles).sum::<u64>());
        assert_eq!(m.completed, 4 + 8, "4 requests + 8 chunks");
        assert_eq!(m.elements, 4 * 256 + 1000);
        // Fleet-level pipeline counters.
        assert_eq!(m.hier_completed, 1);
        assert_eq!(m.hier_elements, 1000);
        assert_eq!(m.hier_chunks, 8);
        assert!(m.merge_cycles > 0 && m.merge_comparisons > 0);
        // Percentiles are the worst shard's.
        assert_eq!(m.p99_us, m.shards.iter().map(|s| s.p99_us).max().unwrap());
        // Both shards served work and the ratio is sane.
        assert!(m.shards.iter().all(|s| s.completed > 0));
        assert!(m.imbalance >= 1.0 && m.imbalance <= 2.0, "{}", m.imbalance);
        // The weighted per-class cost equals what one service observing
        // the same traffic would compute: both shards saw 256-element
        // requests, so the class estimate is their element-weighted mean.
        let fleet_cyc = m.cyc_per_num_for(256, 7.84);
        let (mut c, mut e) = (0.0, 0u64);
        for s in &m.shards {
            let cls = crate::coordinator::metrics::size_class(256);
            c += s.class_cyc_per_num[cls] * s.class_elements[cls] as f64;
            e += s.class_elements[cls];
        }
        assert!((fleet_cyc - c / e as f64).abs() < 1e-12);
        assert!(fleet_cyc > 0.0);
        f.shutdown();
    }

    #[test]
    fn least_outstanding_balances_like_round_robin_on_uniform_load() {
        // With synchronous submit_wait the outstanding counts are zero
        // at every routing decision, so the tie-break applies: ties go
        // to the lowest shard id and a sequential stream pins to shard
        // 0.
        let f = fleet(3, RoutePolicy::LeastOutstanding);
        for seed in 0..3u64 {
            let d = Dataset::generate32(DatasetKind::Uniform, 32, seed);
            f.submit_wait(d.values).unwrap();
        }
        let m = f.fleet_metrics();
        assert_eq!(m.shards[0].completed, 3, "sequential ties pin to shard 0");
        // A hierarchical sort fans out *before* collecting, so the
        // outstanding counts differentiate and spread the chunks.
        let d = Dataset::generate32(DatasetKind::MapReduce, 900, 3);
        let out = f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(100, 4)).unwrap();
        let served: Vec<usize> =
            out.shard_chunks.iter().filter(|&&c| c > 0).copied().collect();
        assert_eq!(served.iter().sum::<usize>(), 9);
        assert_eq!(out.shard_chunks, vec![3, 3, 3], "9 chunks spread 3/3/3");
        f.shutdown();
    }

    #[test]
    fn barrier_mode_fleet_model_follows_the_barrier_schedule() {
        // `sharded_latency_cycles` must model the schedule that ran:
        // under barrier configs, per-shard barrier + cross-shard
        // barrier — not the streaming overlap.
        let d = Dataset::generate32(DatasetKind::Uniform, 1000, 11);
        let cfg = HierarchicalConfig::barrier(128, 4);
        // One shard degenerates to the flat barrier latency exactly.
        let f1 = fleet(1, RoutePolicy::RoundRobin);
        let o1 = f1.sort_hierarchical(&d.values, &cfg).unwrap();
        assert!(!o1.hier.streaming);
        assert_eq!(o1.sharded_latency_cycles, o1.hier.barrier_latency_cycles);
        assert_eq!(o1.fleet_saving(), 0.0);
        f1.shutdown();
        // Two shards: recompute the two-tier barrier model by hand
        // from the per-chunk stats and assignments.
        let f = fleet(2, RoutePolicy::RoundRobin);
        let out = f.sort_hierarchical(&d.values, &cfg).unwrap();
        let lens: Vec<usize> = (0..out.hier.chunks()).map(|i| (1000 - i * 128).min(128)).collect();
        let mut leaves = vec![Vec::new(); 2];
        for (i, (s, &sid)) in out.hier.chunk_stats.iter().zip(&out.assignments).enumerate() {
            leaves[sid].push((s.cycles(), lens[i]));
        }
        let worst = leaves
            .iter()
            .filter(|l| !l.is_empty())
            .map(|l| {
                let arrival = l.iter().map(|&(a, _)| a).max().unwrap();
                let len: usize = l.iter().map(|&(_, x)| x).sum();
                arrival + crate::sorter::merge::model_merge_cycles(len, l.len(), 4)
            })
            .max()
            .unwrap();
        let expect = worst + crate::sorter::merge::model_merge_cycles(1000, 2, 4);
        assert_eq!(out.sharded_latency_cycles, expect);
        f.shutdown();
    }

    #[test]
    fn size_class_affinity_still_spreads_chunk_fanout() {
        // All chunks of one hierarchical sort share a size class; the
        // chunk-index offset must keep the fan-out parallel instead of
        // serializing the whole sort onto the class's home shard.
        let f = fleet(4, RoutePolicy::SizeClass);
        let d = Dataset::generate32(DatasetKind::MapReduce, 1024 * 8, 3);
        let out = f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(1024, 4)).unwrap();
        assert_eq!(out.shard_chunks, vec![2, 2, 2, 2], "8 equal chunks spread 2/2/2/2");
        // Plain requests keep pure affinity: one class, one shard.
        for seed in 0..3u64 {
            f.submit_wait(Dataset::generate32(DatasetKind::Uniform, 64, seed).values).unwrap();
        }
        let m = f.fleet_metrics();
        let plain: Vec<u64> = m.shards.iter().map(|s| s.completed).collect();
        // 8 chunk jobs spread evenly + 3 same-class requests pinned to
        // one shard.
        assert_eq!(plain.iter().sum::<u64>(), 8 + 3);
        assert_eq!(plain.iter().filter(|&&c| c >= 5).count(), 1, "{plain:?}");
        f.shutdown();
    }

    #[test]
    fn auto_capacity_uses_the_shard_dimension() {
        use crate::coordinator::planner::auto_tune_sharded;
        use crate::params::NOMINAL_COLSKIP_CYC_PER_NUM;
        let f = fleet(4, RoutePolicy::RoundRobin);
        let cfg = HierarchicalConfig::auto();
        let n = 50_000usize;
        let (bank, fanout) = f.resolve_chunking(n, &cfg);
        // A fresh uniform fleet costs every shard at the nominal
        // constant, so the hetero tuner reduces to the PR-3 uniform
        // pick exactly.
        let expect = auto_tune_sharded(
            n,
            &f.config().services[0].geometry,
            4,
            true,
            |_| NOMINAL_COLSKIP_CYC_PER_NUM,
        );
        assert_eq!((bank, fanout), expect);
        let d = Dataset::generate32(DatasetKind::MapReduce, n, 3);
        let out = f.sort_hierarchical(&d.values, &cfg).unwrap();
        assert_eq!(out.hier.capacity, bank);
        assert_eq!(out.hier.merge.fanout, fanout);
        f.shutdown();
    }

    #[test]
    fn fleet_misconfiguration_is_an_error_not_a_panic() {
        // Empty fleet.
        assert!(ShardedSortService::start(ShardedConfig {
            route: RoutePolicy::RoundRobin,
            services: vec![],
            ..Default::default()
        })
        .is_err());
        // A bad per-shard config surfaces as the start error.
        assert!(ShardedSortService::start(ShardedConfig::uniform(
            2,
            RoutePolicy::RoundRobin,
            ServiceConfig { workers: 0, ..Default::default() },
        ))
        .is_err());
        // An empty transport list is equally rejected, and a fleet
        // assembled from transports reports the hosts' own configs —
        // there is no parallel config list to get wrong.
        assert!(ShardedSortService::with_transports(RoutePolicy::RoundRobin, vec![]).is_err());
        let t = LocalTransport::start(ServiceConfig { workers: 1, ..Default::default() }).unwrap();
        let f1 = ShardedSortService::with_transports(
            RoutePolicy::RoundRobin,
            vec![Box::new(t) as Box<dyn ShardTransport>],
        )
        .unwrap();
        assert_eq!(f1.config().shards(), 1);
        assert_eq!(f1.config().services[0].workers, 1, "config derives from the transport");
        f1.shutdown();
        // Out-of-range shard operations.
        let f = fleet(2, RoutePolicy::RoundRobin);
        assert!(f.fail_shard(2).is_err());
        assert!(f.recover_shard(7).is_err());
        // A degenerate fanout is an error, not a panic.
        assert!(f
            .sort_hierarchical(&[3, 1, 2], &HierarchicalConfig::fixed(2, 1))
            .is_err());
        f.shutdown();
    }

    #[test]
    fn route_policy_parse_round_trips() {
        // `ALL`, `name` and `FromStr` must stay in sync: every policy
        // round-trips through its canonical name, and `from_str`
        // delegates to `parse`.
        for route in RoutePolicy::ALL {
            assert_eq!(route.name().parse::<RoutePolicy>(), Ok(route));
            assert_eq!(RoutePolicy::parse(route.name()), Some(route));
        }
        assert!("chaos".parse::<RoutePolicy>().is_err());
    }

    #[test]
    fn recovered_shard_receives_new_work_under_every_policy() {
        for route in RoutePolicy::ALL {
            let f = fleet(2, route);
            f.fail_shard(0).unwrap();
            wait_dead(&f, 0);
            assert_eq!(f.healthy_count(), 1, "{route:?}");
            // The degraded fleet still serves (all on shard 1).
            let d = Dataset::generate32(DatasetKind::MapReduce, 600, 4);
            let out = f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(64, 4)).unwrap();
            assert!(out.assignments.iter().all(|&s| s == 1), "{route:?}");
            // Recover shard 0 and sort again: the router must resume
            // offering it work under *every* policy (round-robin and
            // size-class by rotation/offset, least-outstanding and
            // cost because the empty host scores best-or-tied).
            f.recover_shard(0).unwrap();
            assert_eq!(f.healthy_count(), 2, "{route:?}");
            let out = f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(64, 4)).unwrap();
            let mut expect = d.values.clone();
            expect.sort_unstable();
            assert_eq!(out.hier.output.sorted, expect, "{route:?}");
            assert!(
                out.shard_chunks[0] > 0,
                "{route:?}: recovered shard got no chunks: {:?}",
                out.shard_chunks
            );
            let m = f.fleet_metrics();
            assert_eq!(m.recovered, 1, "{route:?}");
            assert!(m.healthy.iter().all(|&h| h), "{route:?}");
            // Plain requests reach it too where the pick is fully
            // deterministic (round-robin rotates onto it; least ties
            // to the lowest id). Size-class pins by class and cost by
            // whichever shard's observed chunk costs came out lower —
            // the chunk assertion above already covers those.
            if matches!(route, RoutePolicy::RoundRobin | RoutePolicy::LeastOutstanding) {
                let before = f.shards[0].transport.metrics().completed;
                for seed in 0..2u64 {
                    let d = Dataset::generate32(DatasetKind::Uniform, 64, seed);
                    f.submit_wait(d.values).unwrap();
                }
                assert!(
                    f.shards[0].transport.metrics().completed > before,
                    "{route:?}: no plain request reached the recovered shard"
                );
            }
            f.shutdown();
        }
    }

    #[test]
    fn late_settle_after_recovery_cannot_underflow_outstanding() {
        let f = fleet(2, RoutePolicy::LeastOutstanding);
        // A spurious settle at 0 must saturate, not wrap to u64::MAX
        // (which would permanently starve the shard under
        // least-outstanding routing and overflow the cost score).
        f.settle(0);
        assert_eq!(f.shards[0].outstanding.load(Ordering::Relaxed), 0);
        let d = Dataset::generate32(DatasetKind::Uniform, 32, 1);
        f.submit_wait(d.values).unwrap();
        assert_eq!(f.shards[0].transport.metrics().completed, 1, "ties still pin to shard 0");
        f.shutdown();
    }

    #[test]
    fn recovery_restarts_a_dead_host_with_empty_metrics() {
        let f = fleet(2, RoutePolicy::RoundRobin);
        let d = Dataset::generate32(DatasetKind::MapReduce, 256, 9);
        for _ in 0..4 {
            f.submit_wait(d.values.clone()).unwrap();
        }
        assert_eq!(f.shards[1].transport.metrics().completed, 2);
        f.fail_shard(1).unwrap();
        wait_dead(&f, 1);
        f.recover_shard(1).unwrap();
        // The restarted host starts from zero — like a real process
        // that came back from a crash.
        assert_eq!(f.shards[1].transport.metrics().completed, 0);
        let resp = f.shards[1].transport.submit(d.values.clone()).unwrap();
        assert!(resp.recv().unwrap().is_ok());
        f.shutdown();
    }

    #[test]
    fn cost_routing_prefers_the_cheap_shard_on_observed_traffic() {
        // Train shard 0 with expensive uniform traffic and shard 1 with
        // cheap MapReduce traffic in the same size class, by talking to
        // the hosts directly; then the fleet's cost router must send a
        // same-class request to shard 1 (uniform ~28-30 cyc/num vs
        // MapReduce ~7-8 — robustly apart).
        let f = fleet(2, RoutePolicy::Cost);
        let expensive = Dataset::generate32(DatasetKind::Uniform, 256, 3);
        let cheap = Dataset::generate32(DatasetKind::MapReduce, 256, 3);
        f.shards[0].transport.submit(expensive.values.clone()).unwrap().recv().unwrap().unwrap();
        f.shards[1].transport.submit(cheap.values.clone()).unwrap().recv().unwrap().unwrap();
        assert!(
            f.route_cost(0, 256) > f.route_cost(1, 256),
            "{} vs {}",
            f.route_cost(0, 256),
            f.route_cost(1, 256)
        );
        let before = f.shards[1].transport.metrics().completed;
        let resp = f.submit_wait(Dataset::generate32(DatasetKind::Kruskal, 300, 8).values);
        assert!(resp.is_ok());
        assert_eq!(
            f.shards[1].transport.metrics().completed,
            before + 1,
            "same size class must route to the observed-cheap shard"
        );
        f.shutdown();
    }

    #[test]
    fn cost_routing_penalizes_undersized_geometry() {
        // Shard 0's tallest bank is 256, shard 1's is 1024: a 1024-row
        // request pays the oversize-assembly merge on shard 0, so an
        // idle fresh fleet (both at the nominal cost) must route it to
        // shard 1. A 256-row request ties and takes shard 0.
        let services = vec![
            ServiceConfig {
                workers: 1,
                geometry: Geometry::from_spec("256x32").unwrap(),
                ..Default::default()
            },
            ServiceConfig {
                workers: 1,
                geometry: Geometry::from_spec("1024x32").unwrap(),
                ..Default::default()
            },
        ];
        // Each decision on a *fresh* fleet: observed traffic would move
        // the costs off the deterministic nominal fallback.
        let f = ShardedSortService::start(ShardedConfig {
            route: RoutePolicy::Cost,
            services: services.clone(),
            ..Default::default()
        })
        .unwrap();
        assert!(f.route_cost(0, 1024) > f.route_cost(1, 1024));
        let d = Dataset::generate32(DatasetKind::MapReduce, 1024, 5);
        f.submit_wait(d.values).unwrap();
        assert_eq!(f.shards[1].transport.metrics().completed, 1);
        f.shutdown();
        let f = ShardedSortService::start(ShardedConfig {
            route: RoutePolicy::Cost,
            services,
            ..Default::default()
        })
        .unwrap();
        let d = Dataset::generate32(DatasetKind::MapReduce, 256, 5);
        f.submit_wait(d.values).unwrap();
        assert_eq!(f.shards[0].transport.metrics().completed, 1, "in-geometry tie -> shard 0");
        f.shutdown();
    }

    #[test]
    fn heterogeneous_fleet_is_byte_identical_and_tunes_heterogeneously() {
        use crate::coordinator::planner::auto_tune_hetero;
        use crate::params::NOMINAL_COLSKIP_CYC_PER_NUM;
        // Mixed geometries *and* mixed worker pools: the pipeline output
        // must still be byte-identical to one service, for every policy.
        let services = vec![
            ServiceConfig {
                workers: 2,
                geometry: Geometry::from_spec("1024x32").unwrap(),
                ..Default::default()
            },
            ServiceConfig {
                workers: 1,
                geometry: Geometry::from_spec("512x32").unwrap(),
                ..Default::default()
            },
            ServiceConfig {
                workers: 3,
                geometry: Geometry::from_spec("256x32").unwrap(),
                ..Default::default()
            },
        ];
        let single =
            SortService::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap();
        let d = Dataset::generate32(DatasetKind::Kruskal, 3000, 21);
        let cfg = HierarchicalConfig::fixed(256, 4);
        let reference = single.sort_hierarchical(&d.values, &cfg).unwrap();
        for route in RoutePolicy::ALL {
            let f = ShardedSortService::start(ShardedConfig {
                route,
                services: services.clone(),
                ..Default::default()
            })
            .unwrap();
            let out = f.sort_hierarchical(&d.values, &cfg).unwrap();
            assert_eq!(out.hier.output.sorted, reference.output.sorted, "{route:?}");
            assert_eq!(out.hier.output.order, reference.output.order, "{route:?}");
            assert_eq!(out.hier.output.stats, reference.output.stats, "{route:?}");
            // Auto capacity resolves through the heterogeneous tuner
            // over the healthy geometries at per-shard observed costs.
            let resolved = f.resolve_chunking(50_000, &HierarchicalConfig::auto());
            let geos: Vec<Geometry> =
                f.shards.iter().map(|s| s.geometry.clone()).collect();
            let expect = auto_tune_hetero(50_000, &geos, true, |s, bank| {
                f.shards[s]
                    .transport
                    .cyc_per_num_for(bank, NOMINAL_COLSKIP_CYC_PER_NUM)
            });
            assert_eq!(resolved, expect, "{route:?}");
            f.shutdown();
        }
        single.shutdown();
    }

    #[test]
    fn flaky_transport_failover_and_recovery() {
        use crate::coordinator::transport::FlakyTransport;
        // A fleet over fault-injecting transports: break shard 1's
        // link, watch the router fail over at submit time, then recover
        // through the same transport seam.
        let svc = ServiceConfig { workers: 1, ..Default::default() };
        let handles: Vec<std::sync::Arc<FlakyTransport>> = (0..2)
            .map(|_| std::sync::Arc::new(FlakyTransport::start(svc.clone()).unwrap()))
            .collect();
        let f = ShardedSortService::with_transports(
            RoutePolicy::RoundRobin,
            handles
                .iter()
                .map(|t| Box::new(std::sync::Arc::clone(t)) as Box<dyn ShardTransport>)
                .collect(),
        )
        .unwrap();
        handles[1].break_link();
        let d = Dataset::generate32(DatasetKind::Clustered, 900, 13);
        let out = f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(128, 4)).unwrap();
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(out.hier.output.sorted, expect);
        assert!(out.assignments.iter().all(|&s| s == 0), "broken link serves nothing");
        assert!(out.rerouted >= 1, "the submit-time failover must be counted");
        assert_eq!(f.healthy_count(), 1, "the flaky shard is isolated");
        // Recover through the transport: the link heals, the host
        // restarts, routing resumes.
        f.recover_shard(1).unwrap();
        assert!(!handles[1].is_down());
        let out = f.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(128, 4)).unwrap();
        assert_eq!(out.hier.output.sorted, expect);
        assert!(out.shard_chunks[1] > 0, "{:?}", out.shard_chunks);
        f.shutdown();
    }

    type FlakyHandles = Vec<std::sync::Arc<crate::coordinator::transport::FlakyTransport>>;

    /// A fleet of flaky hosts under explicit resilience settings.
    fn flaky_fleet(
        shards: usize,
        route: RoutePolicy,
        resilience: ResilienceConfig,
    ) -> (FlakyHandles, ShardedSortService) {
        use crate::coordinator::transport::FlakyTransport;
        let svc = ServiceConfig { workers: 2, ..Default::default() };
        let handles: FlakyHandles = (0..shards)
            .map(|_| std::sync::Arc::new(FlakyTransport::start(svc.clone()).unwrap()))
            .collect();
        let f = ShardedSortService::with_transports_resilient(
            route,
            resilience,
            handles
                .iter()
                .map(|t| Box::new(std::sync::Arc::clone(t)) as Box<dyn ShardTransport>)
                .collect(),
        )
        .unwrap();
        (handles, f)
    }

    #[test]
    fn retry_budget_denies_failover_when_exhausted() {
        // Capacity 0: the fleet isolates dead shards but refuses to
        // *pay* for failover hops — the hop errors instead of storming
        // the survivors.
        let resilience = ResilienceConfig {
            retry_budget: RetryBudgetConfig { capacity: 0.0, deposit: 0.0 },
            hedge: None,
        };
        let (_, f) = flaky_fleet(2, RoutePolicy::LeastOutstanding, resilience);
        // Kill shard 0 behind the router's back (ties route to it).
        f.shards[0].transport.halt();
        wait_dead(&f, 0);
        let d = Dataset::generate32(DatasetKind::Uniform, 64, 1);
        let err = f.submit_wait(d.values.clone()).unwrap_err().to_string();
        assert!(err.contains("retry budget"), "{err}");
        let m = f.fleet_metrics();
        assert!(m.budget_exhausted >= 1);
        assert_eq!(m.retries, 0, "no hop was paid for");
        assert_eq!(m.retry_tokens, 0.0);
        // The denied hop still isolated the dead shard, so the next
        // submit routes straight to the survivor — no retry needed.
        let resp = f.submit_wait(d.values.clone()).unwrap();
        let mut expect = d.values;
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        f.shutdown();
    }

    #[test]
    fn retry_budget_spends_and_refills_on_successful_traffic() {
        let resilience = ResilienceConfig {
            retry_budget: RetryBudgetConfig { capacity: 2.0, deposit: 0.5 },
            hedge: None,
        };
        let (_, f) = flaky_fleet(2, RoutePolicy::RoundRobin, resilience);
        assert!((f.fleet_metrics().retry_tokens - 2.0).abs() < 1e-12, "starts full");
        assert!(f.try_spend_budget());
        assert!(f.try_spend_budget());
        assert!(!f.try_spend_budget(), "an empty bucket denies");
        assert_eq!(f.fleet_metrics().budget_exhausted, 1);
        // Successful traffic deposits back, capped at capacity.
        for seed in 0..6u64 {
            f.submit_wait(Dataset::generate32(DatasetKind::Uniform, 32, seed).values).unwrap();
        }
        let tokens = f.fleet_metrics().retry_tokens;
        assert!((tokens - 2.0).abs() < 1e-9, "refilled to the cap, got {tokens}");
        assert!(f.try_spend_budget());
        f.shutdown();
    }

    #[test]
    fn bad_resilience_config_is_an_error_not_a_panic() {
        for resilience in [
            ResilienceConfig {
                retry_budget: RetryBudgetConfig { capacity: f64::NAN, deposit: 0.1 },
                hedge: None,
            },
            ResilienceConfig {
                retry_budget: RetryBudgetConfig { capacity: 1.0, deposit: -0.5 },
                hedge: None,
            },
            ResilienceConfig {
                retry_budget: RetryBudgetConfig::default(),
                hedge: Some(HedgeConfig { straggler_mult: f64::INFINITY, floor_us: 0 }),
            },
        ] {
            let t = LocalTransport::start(ServiceConfig { workers: 1, ..Default::default() })
                .unwrap();
            assert!(
                ShardedSortService::with_transports_resilient(
                    RoutePolicy::RoundRobin,
                    resilience,
                    vec![Box::new(t) as Box<dyn ShardTransport>],
                )
                .is_err(),
                "{resilience:?}"
            );
        }
    }

    #[test]
    fn hedged_request_wins_over_a_stalled_shard() {
        // Shard 0 accepts the job and never answers (a hung host, not
        // a dead one — the reply channel stays open). The straggler
        // deadline fires, the hedge lands on shard 1, and the first
        // delivered reply wins.
        let resilience = ResilienceConfig {
            retry_budget: RetryBudgetConfig::default(),
            hedge: Some(HedgeConfig { straggler_mult: 4.0, floor_us: 2_000 }),
        };
        let (handles, f) = flaky_fleet(2, RoutePolicy::LeastOutstanding, resilience);
        handles[0].stall(); // ties pin the primary to shard 0
        let d = Dataset::generate32(DatasetKind::MapReduce, 256, 3);
        let resp = f.submit_wait(d.values.clone()).unwrap();
        let mut expect = d.values;
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        let m = f.fleet_metrics();
        assert_eq!((m.hedges_won, m.hedges_lost), (1, 0));
        // Both lanes settled when the race ended: the abandoned
        // straggler cannot skew least-outstanding routing forever.
        assert_eq!(f.shards[0].outstanding.load(Ordering::Relaxed), 0);
        assert_eq!(f.shards[1].outstanding.load(Ordering::Relaxed), 0);
        assert!(m.retry_tokens < resilience.retry_budget.capacity, "the hedge cost a token");
        f.shutdown();
    }

    #[test]
    fn hedge_loses_when_the_primary_answers_first() {
        // Zero floor + no calibration yet = a zero deadline: the hedge
        // fires immediately — at the *stalled* shard 1, so the healthy
        // primary always delivers first and the hedge is abandoned.
        let resilience = ResilienceConfig {
            retry_budget: RetryBudgetConfig::default(),
            hedge: Some(HedgeConfig { straggler_mult: 4.0, floor_us: 0 }),
        };
        let (handles, f) = flaky_fleet(2, RoutePolicy::LeastOutstanding, resilience);
        handles[1].stall();
        let d = Dataset::generate32(DatasetKind::Uniform, 4096, 3);
        let resp = f.submit_wait(d.values.clone()).unwrap();
        let mut expect = d.values;
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        let m = f.fleet_metrics();
        assert_eq!((m.hedges_won, m.hedges_lost), (0, 1));
        assert_eq!(f.shards[0].outstanding.load(Ordering::Relaxed), 0);
        assert_eq!(f.shards[1].outstanding.load(Ordering::Relaxed), 0);
        f.shutdown();
    }

    #[test]
    fn hedge_denied_on_empty_budget_still_serves() {
        // A zero-capacity budget turns hedging off in practice: the
        // straggler deadline fires, the hedge is denied (counted), and
        // the job simply waits out its primary like PR 4 did.
        let resilience = ResilienceConfig {
            retry_budget: RetryBudgetConfig { capacity: 0.0, deposit: 0.0 },
            hedge: Some(HedgeConfig { straggler_mult: 4.0, floor_us: 0 }),
        };
        let (_, f) = flaky_fleet(2, RoutePolicy::LeastOutstanding, resilience);
        let d = Dataset::generate32(DatasetKind::MapReduce, 1024, 5);
        let resp = f.submit_wait(d.values.clone()).unwrap();
        let mut expect = d.values;
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        let m = f.fleet_metrics();
        assert_eq!((m.hedges_won, m.hedges_lost), (0, 0));
        assert!(m.budget_exhausted >= 1, "the denied hedge must be visible");
        f.shutdown();
    }

    #[test]
    fn hedging_sweep_is_byte_identical_under_stall_faults() {
        // The fault-injection sweep: one stalled shard in a 3-shard
        // round-robin fleet, hedging on. Every chunk the stalled host
        // sits on is hedged to a survivor, the output stays
        // byte-identical to the single-service pipeline (the simulated
        // response is a deterministic function of the data), and the
        // wins are visible in the fleet snapshot.
        let single =
            SortService::start(ServiceConfig { workers: 2, ..Default::default() }).unwrap();
        let cfg = HierarchicalConfig::fixed(128, 4);
        let resilience = ResilienceConfig {
            retry_budget: RetryBudgetConfig { capacity: 64.0, deposit: 0.1 },
            hedge: Some(HedgeConfig { straggler_mult: 4.0, floor_us: 2_000 }),
        };
        for kind in DatasetKind::ALL {
            let d = Dataset::generate32(kind, 1200, 9);
            let reference = single.sort_hierarchical(&d.values, &cfg).unwrap();
            let (handles, f) = flaky_fleet(3, RoutePolicy::RoundRobin, resilience);
            handles[2].stall();
            let out = f.sort_hierarchical(&d.values, &cfg).unwrap();
            let tag = format!("{kind:?}");
            assert_eq!(out.hier.output.sorted, reference.output.sorted, "{tag}");
            assert_eq!(out.hier.output.order, reference.output.order, "{tag}");
            assert_eq!(out.hier.output.stats, reference.output.stats, "{tag}");
            assert_eq!(out.hier.chunk_stats, reference.chunk_stats, "{tag}");
            let m = f.fleet_metrics();
            assert!(m.hedges_won >= 1, "{tag}: the stalled shard's chunks must be hedged");
            assert_eq!(m.errors, 0, "{tag}");
            // No chunk may be *assigned* to the stalled shard in the
            // final accounting — every one of its jobs was won by a
            // survivor's hedge.
            assert_eq!(out.shard_chunks[2], 0, "{tag}: {:?}", out.shard_chunks);
            f.shutdown();
        }
        single.shutdown();
    }

    #[test]
    fn imbalance_clamps_when_every_counter_reset_on_recovery() {
        // The regression: per-shard element counters restart from zero
        // across a recovery, and a fleet whose serving shards all just
        // recovered must report the balanced 1.0 — never NaN or a
        // division by zero — while the totals honestly read 0.
        let f = fleet(2, RoutePolicy::RoundRobin);
        for seed in 0..4u64 {
            f.submit_wait(Dataset::generate32(DatasetKind::Uniform, 64, seed).values).unwrap();
        }
        let m = f.fleet_metrics();
        assert!(m.imbalance >= 1.0 && m.imbalance.is_finite());
        // Operator-driven replacement of *every* host.
        f.recover_shard(0).unwrap();
        f.recover_shard(1).unwrap();
        let m = f.fleet_metrics();
        assert_eq!(m.elements, 0, "restarted hosts lost their counters");
        assert!((m.imbalance - 1.0).abs() < 1e-12, "clamped, got {}", m.imbalance);
        assert!(m.imbalance.is_finite());
        f.shutdown();
    }
}

//! The coordinator's request plane: fair-share admission over a
//! [`super::shard::ShardedSortService`] fleet.
//!
//! The fleet layer (PRs 4–5) routes *one caller's* work well; this
//! module is what stands in front of it when there are many callers.
//! A [`Frontend`] admits concurrent sort requests tagged with a tenant
//! and a [`Priority`] class, enforcing three deterministic rules:
//!
//! 1. **Per-tenant caps** — a tenant may hold at most
//!    [`FrontendConfig::tenant_cap`] outstanding requests; a breach is
//!    the typed [`AdmitError::TenantCap`], never a queue (a misbehaving
//!    tenant must not grow an invisible backlog inside the
//!    coordinator).
//! 2. **Saturation shedding, lowest class first** — once the frontend
//!    is saturated (outstanding at [`FrontendConfig::max_outstanding`],
//!    or the fleet's retry budget has burnt to empty — the same
//!    token-bucket signal the failover path sheds on), `Batch` work is
//!    shed immediately with [`AdmitError::Saturated`]. `Interactive`
//!    work rides an *overdraft* token bucket
//!    ([`FrontendConfig::overdraft`], the same clockless machinery as
//!    [`super::shard::RetryBudgetConfig`]): each admission past
//!    saturation spends a token, and tokens refill as admitted work
//!    *releases* — so a saturated frontend keeps absorbing a bounded
//!    burst of interactive traffic while batch traffic sheds, and the
//!    bound regenerates with served work, not wall time. Deterministic
//!    by construction: tests replay exact shed orderings.
//! 3. **Cross-request coalescing** — [`Frontend::sort_batch`] packs
//!    small same-class requests into one bank-sized carrier job before
//!    routing and splits the result back per request via the argsort
//!    (`order[i]` = the original index of `sorted[i]`, and the
//!    single-bank sorter drains duplicates in ascending original index,
//!    so each request's slice of the carrier's output is exactly its
//!    solo stable sort). One wire frame and one routing decision
//!    amortise over the whole pack —
//!    [`super::planner::model_coalescing`] quantifies the saving, and
//!    `python/fleet_model.py` §coalescing mirrors it.
//!
//! Admission state is one mutex-guarded scoreboard (outstanding total,
//! per-tenant counts, overdraft balance); a [`Permit`] decrements it on
//! drop, so every admitted request releases exactly once on every exit
//! path — success, sort error, or panic unwind.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use super::hierarchical::HierarchicalConfig;
use super::locks::lock_recover;
use super::shard::{FleetSnapshot, RetryBudgetConfig, ShardedOutput, ShardedSortService};
use super::SortResponse;
use crate::sorter::spill::{resident_merge_bytes, spill_working_bytes};

/// Request priority class. Two classes are deliberate: the admission
/// contract is "who sheds first", and a total order over many levels
/// invites starvation games; interactive-over-batch is the whole
/// policy.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Hash)]
pub enum Priority {
    /// Latency-sensitive foreground work: admitted past saturation
    /// while the overdraft bucket holds tokens.
    Interactive,
    /// Throughput work: the first class shed at saturation.
    Batch,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Every class, for sweeps and the parse round-trip test.
    pub const ALL: [Priority; 2] = [Priority::Interactive, Priority::Batch];
}

impl std::str::FromStr for Priority {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Priority::parse(s).ok_or_else(|| format!("unknown priority `{s}` (interactive|batch)"))
    }
}

/// The request-plane tag riding on a sort job: who is asking and how
/// urgently. Crosses the wire on v2 links
/// ([`super::wire::Frame::SortJobTagged`]); the host sorts tagged and
/// untagged jobs identically — the tag is coordination metadata, not an
/// execution parameter.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct JobTag {
    /// Accounting identity for the per-tenant outstanding cap.
    pub tenant: String,
    /// Shed class under saturation.
    pub priority: Priority,
}

impl JobTag {
    pub fn new(tenant: impl Into<String>, priority: Priority) -> Self {
        JobTag { tenant: tenant.into(), priority }
    }
}

impl Default for JobTag {
    /// Untagged traffic: an anonymous batch-class tenant, so work that
    /// never asked for priority is the first to shed.
    fn default() -> Self {
        JobTag { tenant: "anon".into(), priority: Priority::Batch }
    }
}

/// Why admission refused a request. A typed error, deliberately not an
/// `anyhow` string: callers shed load *programmatically* (retry later,
/// downshift priority, surface a 429-equivalent), so the variant and
/// its numbers must survive the boundary. Convertible into
/// `anyhow::Error` (it is a `std::error::Error`), and recoverable from
/// one via `downcast_ref::<AdmitError>()`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// The tenant is at its outstanding cap. Not a hang: the caller
    /// decides whether to wait, not the coordinator.
    TenantCap {
        tenant: String,
        cap: usize,
    },
    /// The frontend is saturated and this class is being shed —
    /// `Batch` always, `Interactive` once the overdraft bucket is dry.
    Saturated {
        priority: Priority,
        outstanding: usize,
        limit: usize,
    },
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::TenantCap { tenant, cap } => {
                write!(f, "tenant `{tenant}` is at its cap of {cap} outstanding requests")
            }
            AdmitError::Saturated { priority, outstanding, limit } => write!(
                f,
                "frontend saturated ({outstanding}/{limit} outstanding): shedding {} work",
                priority.name()
            ),
        }
    }
}

impl std::error::Error for AdmitError {}

/// Frontend admission configuration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FrontendConfig {
    /// Outstanding requests across all tenants before the frontend
    /// counts as saturated.
    pub max_outstanding: usize,
    /// Outstanding requests one tenant may hold.
    pub tenant_cap: usize,
    /// The interactive overdraft past saturation: `capacity` is the
    /// burst bound, `deposit` refills per *released* request — the
    /// fleet's retry-budget machinery, reused for admission.
    pub overdraft: RetryBudgetConfig,
    /// Coalescing cap for [`Frontend::sort_batch`], in elements per
    /// carrier job. `0` = auto: the fleet's largest bank, so a carrier
    /// is exactly one bank-sized chunk and never triggers hierarchical
    /// splitting.
    pub coalesce_elems: usize,
}

impl Default for FrontendConfig {
    fn default() -> Self {
        FrontendConfig {
            max_outstanding: 64,
            tenant_cap: 16,
            overdraft: RetryBudgetConfig { capacity: 4.0, deposit: 0.25 },
            coalesce_elems: 0,
        }
    }
}

impl FrontendConfig {
    fn validate(&self) -> Result<()> {
        if self.max_outstanding == 0 || self.tenant_cap == 0 {
            return Err(anyhow!(
                "admission caps must be positive (max_outstanding {}, tenant_cap {})",
                self.max_outstanding,
                self.tenant_cap
            ));
        }
        let b = &self.overdraft;
        if !b.capacity.is_finite() || b.capacity < 0.0 || !b.deposit.is_finite() || b.deposit < 0.0
        {
            return Err(anyhow!(
                "overdraft must be finite and non-negative (capacity {}, deposit {})",
                b.capacity,
                b.deposit
            ));
        }
        Ok(())
    }
}

/// The mutex-guarded admission scoreboard.
struct AdmitState {
    /// Admitted and not yet released, across all tenants.
    outstanding: usize,
    /// Coordinator-memory bytes charged by admitted-and-unreleased
    /// requests ([`Frontend::try_admit_sized`]). A spilling
    /// hierarchical sort charges its bounded spill working set, not
    /// its resident merge footprint — see
    /// [`hierarchical_admission_bytes`].
    outstanding_bytes: u64,
    /// Admitted and not yet released, per tenant. Entries are removed
    /// at zero so an idle tenant costs nothing.
    per_tenant: HashMap<String, usize>,
    /// Interactive overdraft balance, in tokens.
    overdraft_tokens: f64,
}

/// An admitted request's slot. Dropping it releases the admission —
/// decrements the scoreboard (count and bytes) and deposits the
/// overdraft refill — so release happens exactly once on every exit
/// path.
pub struct Permit<'a> {
    frontend: &'a Frontend,
    tenant: String,
    /// Bytes charged at admission, returned on release.
    bytes: u64,
}

impl Drop for Permit<'_> {
    fn drop(&mut self) {
        self.frontend.release(&self.tenant, self.bytes);
    }
}

/// The coordinator-memory bytes one hierarchical sort of `n` elements
/// holds while it runs — the quantity admission accounts. A request
/// the budget keeps resident holds the full merge working set
/// ([`resident_merge_bytes`]); a request the budget forces to spill
/// holds only the bounded reader/writer blocks of the external merge
/// ([`spill_working_bytes`]), *not* the resident footprint — spilled
/// bytes live in the run store, not in coordinator memory, and
/// charging them as resident would let one over-budget sort falsely
/// saturate the plane.
pub fn hierarchical_admission_bytes(n: usize, cfg: &HierarchicalConfig) -> u64 {
    let resident = resident_merge_bytes(n);
    if cfg.budget.fits(resident) {
        resident as u64
    } else {
        spill_working_bytes(cfg.fanout.max(2)) as u64
    }
}

/// Point-in-time view of the admission plane (the frontend's own
/// counters; fleet counters live in [`FleetSnapshot`]).
#[derive(Clone, Debug, PartialEq)]
pub struct AdmissionSnapshot {
    /// Requests admitted since start (including overdraft admissions).
    pub admitted: u64,
    /// Batch requests shed at saturation.
    pub shed_batch: u64,
    /// Interactive requests shed (saturation with a dry overdraft).
    pub shed_interactive: u64,
    /// Requests refused at a tenant cap.
    pub shed_tenant_cap: u64,
    /// Interactive admissions that spent an overdraft token.
    pub overdraft_spent: u64,
    /// Carrier jobs [`Frontend::sort_batch`] submitted on behalf of
    /// coalesced requests.
    pub coalesced_batches: u64,
    /// Requests that rode a carrier (≥ 2 per carrier).
    pub coalesced_requests: u64,
    /// Currently admitted and unreleased.
    pub outstanding: usize,
    /// Coordinator-memory bytes currently charged by admitted work
    /// ([`hierarchical_admission_bytes`]: spill working set for
    /// spilling sorts, resident merge footprint otherwise).
    pub outstanding_bytes: u64,
    /// Current overdraft balance, in tokens.
    pub overdraft_tokens: f64,
}

/// The concurrent request plane over one fleet: admission (caps,
/// priorities, shedding) in front, [`ShardedSortService`] routing
/// behind. All methods take `&self`; wrap it in an `Arc` to serve many
/// client threads.
pub struct Frontend {
    fleet: ShardedSortService,
    cfg: FrontendConfig,
    /// Resolved coalescing cap (cfg value, or the fleet's largest bank).
    coalesce_elems: usize,
    state: Mutex<AdmitState>,
    admitted: AtomicU64,
    shed_batch: AtomicU64,
    shed_interactive: AtomicU64,
    shed_tenant_cap: AtomicU64,
    overdraft_spent: AtomicU64,
    coalesced_batches: AtomicU64,
    coalesced_requests: AtomicU64,
}

impl Frontend {
    /// Put an admission plane in front of `fleet`.
    pub fn new(fleet: ShardedSortService, cfg: FrontendConfig) -> Result<Self> {
        cfg.validate()?;
        let coalesce_elems = if cfg.coalesce_elems > 0 {
            cfg.coalesce_elems
        } else {
            fleet.config().services[0].geometry.largest_bank()
        };
        Ok(Frontend {
            fleet,
            coalesce_elems,
            state: Mutex::new(AdmitState {
                outstanding: 0,
                outstanding_bytes: 0,
                per_tenant: HashMap::new(),
                overdraft_tokens: cfg.overdraft.capacity,
            }),
            cfg,
            admitted: AtomicU64::new(0),
            shed_batch: AtomicU64::new(0),
            shed_interactive: AtomicU64::new(0),
            shed_tenant_cap: AtomicU64::new(0),
            overdraft_spent: AtomicU64::new(0),
            coalesced_batches: AtomicU64::new(0),
            coalesced_requests: AtomicU64::new(0),
        })
    }

    /// The fleet behind the admission plane.
    pub fn fleet(&self) -> &ShardedSortService {
        &self.fleet
    }

    /// The admission configuration.
    pub fn config(&self) -> &FrontendConfig {
        &self.cfg
    }

    /// The resolved coalescing cap ([`FrontendConfig::coalesce_elems`],
    /// or the fleet's largest bank when that was 0).
    pub fn coalesce_elems(&self) -> usize {
        self.coalesce_elems
    }

    /// Whether the frontend is shedding: the outstanding count is at
    /// the cap, or the *fleet's* retry budget has burnt to empty (a
    /// degraded fleet paying for failovers must not also absorb new
    /// load). A fleet configured with a sub-token budget capacity never
    /// trips the second signal — it never had tokens to burn.
    fn saturated(&self, outstanding: usize) -> bool {
        outstanding >= self.cfg.max_outstanding
            || (self.fleet.config().resilience.retry_budget.capacity >= 1.0
                && self.fleet.retry_tokens() < 1.0)
    }

    /// Admit one request, or say exactly why not. Never blocks beyond
    /// the scoreboard mutex; a refusal is a typed [`AdmitError`].
    ///
    /// Decision order is the contract (pinned by the admission tests):
    /// tenant cap first — a capped tenant is refused even when the
    /// frontend is idle — then saturation, where `Batch` sheds
    /// outright and `Interactive` spends the overdraft while it lasts.
    pub fn try_admit(&self, tag: &JobTag) -> std::result::Result<Permit<'_>, AdmitError> {
        self.try_admit_sized(tag, 0)
    }

    /// [`Frontend::try_admit`] with a coordinator-memory byte charge
    /// riding the permit: the bytes are added to the scoreboard's
    /// [`AdmissionSnapshot::outstanding_bytes`] on admission and
    /// returned when the permit drops. The byte charge is accounting
    /// (operator visibility of the plane's memory pressure), not a
    /// shed signal — the count caps and the overdraft stay the
    /// admission contract.
    pub fn try_admit_sized(
        &self,
        tag: &JobTag,
        bytes: u64,
    ) -> std::result::Result<Permit<'_>, AdmitError> {
        let mut st = lock_recover(&self.state);
        let used = st.per_tenant.get(&tag.tenant).copied().unwrap_or(0);
        if used >= self.cfg.tenant_cap {
            self.shed_tenant_cap.fetch_add(1, Ordering::Relaxed);
            return Err(AdmitError::TenantCap {
                tenant: tag.tenant.clone(),
                cap: self.cfg.tenant_cap,
            });
        }
        if self.saturated(st.outstanding) {
            match tag.priority {
                Priority::Batch => {
                    self.shed_batch.fetch_add(1, Ordering::Relaxed);
                    return Err(AdmitError::Saturated {
                        priority: Priority::Batch,
                        outstanding: st.outstanding,
                        limit: self.cfg.max_outstanding,
                    });
                }
                Priority::Interactive => {
                    if st.overdraft_tokens >= 1.0 {
                        st.overdraft_tokens -= 1.0;
                        self.overdraft_spent.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.shed_interactive.fetch_add(1, Ordering::Relaxed);
                        return Err(AdmitError::Saturated {
                            priority: Priority::Interactive,
                            outstanding: st.outstanding,
                            limit: self.cfg.max_outstanding,
                        });
                    }
                }
            }
        }
        st.outstanding += 1;
        st.outstanding_bytes = st.outstanding_bytes.saturating_add(bytes);
        *st.per_tenant.entry(tag.tenant.clone()).or_insert(0) += 1;
        self.admitted.fetch_add(1, Ordering::Relaxed);
        Ok(Permit { frontend: self, tenant: tag.tenant.clone(), bytes })
    }

    /// Release one admission (the [`Permit`] drop path).
    fn release(&self, tenant: &str, bytes: u64) {
        let mut st = lock_recover(&self.state);
        st.outstanding = st.outstanding.saturating_sub(1);
        st.outstanding_bytes = st.outstanding_bytes.saturating_sub(bytes);
        if let Some(n) = st.per_tenant.get_mut(tenant) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                st.per_tenant.remove(tenant);
            }
        }
        let b = self.cfg.overdraft;
        if b.deposit > 0.0 {
            st.overdraft_tokens = (st.overdraft_tokens + b.deposit).min(b.capacity);
        }
    }

    /// Admit and sort one request, releasing the admission on every
    /// exit path. A shed request surfaces its [`AdmitError`] inside the
    /// `anyhow` error (recover it with `downcast_ref::<AdmitError>()`).
    pub fn sort(&self, tag: &JobTag, data: Vec<u32>) -> Result<SortResponse> {
        let _permit = self.try_admit(tag).map_err(anyhow::Error::new)?;
        self.fleet.submit_wait_tagged(tag, data)
    }

    /// Admit and run one hierarchical (out-of-bank) sort through the
    /// fleet, charging the admission scoreboard the bytes the request
    /// actually holds on this coordinator
    /// ([`hierarchical_admission_bytes`]): the resident merge working
    /// set when the [`HierarchicalConfig::budget`] keeps it in memory,
    /// the bounded spill working set when the budget forces the
    /// external merge — spilled bytes, not resident bytes. The charge
    /// releases with the permit on every exit path.
    pub fn sort_hierarchical(
        &self,
        tag: &JobTag,
        data: &[u32],
        cfg: &HierarchicalConfig,
    ) -> Result<ShardedOutput> {
        let bytes = hierarchical_admission_bytes(data.len(), cfg);
        let _permit = self.try_admit_sized(tag, bytes).map_err(anyhow::Error::new)?;
        self.fleet.sort_hierarchical(data, cfg)
    }

    /// Admit and sort a batch of requests, coalescing small same-class
    /// jobs into bank-sized carrier jobs before routing. Per-request
    /// outcomes: a shed request carries its [`AdmitError`]; admitted
    /// requests return responses **byte-identical in `(sorted, order)`
    /// to their solo runs** — the split-back walks the carrier's
    /// argsort, and the sorter's stable duplicate order makes each
    /// request's slice exactly its own stable sort. `stats`,
    /// `latency_us` and `worker` on a coalesced response describe the
    /// *carrier* run, shared by every rider (the simulator cost of the
    /// pack is a property of the pack, not divisible per rider).
    ///
    /// Requests bigger than the coalescing cap, and packs that end up
    /// with a single admitted rider, are submitted plain. A carrier
    /// whose engine returns no argsort (a provenance-free PJRT host)
    /// falls back to plain per-request submits — identity over
    /// amortisation.
    pub fn sort_batch(
        &self,
        jobs: Vec<(JobTag, Vec<u32>)>,
    ) -> Vec<Result<SortResponse>> {
        let mut results: Vec<Option<Result<SortResponse>>> =
            (0..jobs.len()).map(|_| None).collect();
        for class in Priority::ALL {
            // Pack same-class requests greedily, preserving submission
            // order: a pack closes when the next job would overflow the
            // carrier cap. An oversized job gets a singleton pack
            // (submitted plain below).
            let idxs: Vec<usize> =
                (0..jobs.len()).filter(|&i| jobs[i].0.priority == class).collect();
            let mut packs: Vec<Vec<usize>> = Vec::new();
            let mut cur: Vec<usize> = Vec::new();
            let mut cur_len = 0usize;
            for &i in &idxs {
                let n = jobs[i].1.len();
                if !cur.is_empty() && cur_len + n > self.coalesce_elems {
                    packs.push(std::mem::take(&mut cur));
                    cur_len = 0;
                }
                cur.push(i);
                cur_len += n;
                if cur_len >= self.coalesce_elems {
                    packs.push(std::mem::take(&mut cur));
                    cur_len = 0;
                }
            }
            if !cur.is_empty() {
                packs.push(cur);
            }
            for pack in packs {
                // Admit every rider individually — coalescing must not
                // let a capped tenant smuggle work in under a sibling's
                // admission.
                let mut riders: Vec<(usize, Permit<'_>)> = Vec::new();
                for &i in &pack {
                    match self.try_admit(&jobs[i].0) {
                        Ok(permit) => riders.push((i, permit)),
                        Err(e) => results[i] = Some(Err(anyhow::Error::new(e))),
                    }
                }
                if riders.is_empty() {
                    continue;
                }
                if riders.len() == 1 || riders.iter().map(|&(i, _)| jobs[i].1.len()).sum::<usize>()
                    > self.coalesce_elems
                {
                    for (i, _permit) in riders {
                        results[i] =
                            Some(self.fleet.submit_wait_tagged(&jobs[i].0, jobs[i].1.clone()));
                    }
                    continue;
                }
                let rider_idx: Vec<usize> = riders.iter().map(|&(i, _)| i).collect();
                match self.sort_coalesced(&jobs, &rider_idx) {
                    Ok(split) => {
                        for (i, resp) in rider_idx.iter().zip(split) {
                            results[*i] = Some(Ok(resp));
                        }
                    }
                    Err(e) => {
                        // The carrier failed as a unit: every rider
                        // sees the same delivered error.
                        for &i in &rider_idx {
                            results[i] = Some(Err(anyhow!("coalesced carrier failed: {e:#}")));
                        }
                    }
                }
                drop(riders);
            }
        }
        // Every slot was filled above (solo paths and both coalesced
        // arms); a hole would be a frontend bug, and a serving path
        // answers bugs with a delivered error, not a panic.
        results
            .into_iter()
            .map(|r| r.unwrap_or_else(|| Err(anyhow!("internal error: job got no outcome"))))
            .collect()
    }

    /// Submit one carrier for the (already admitted) riders and split
    /// the result back per rider via the argsort. Falls back to plain
    /// per-rider submits when the carrier's engine returned no
    /// provenance.
    fn sort_coalesced(
        &self,
        jobs: &[(JobTag, Vec<u32>)],
        riders: &[usize],
    ) -> Result<Vec<SortResponse>> {
        // Spans of each rider inside the concatenated carrier.
        let mut spans = Vec::with_capacity(riders.len());
        let mut carrier = Vec::new();
        for &i in riders {
            let start = carrier.len();
            carrier.extend_from_slice(&jobs[i].1);
            spans.push(start..carrier.len());
        }
        let n = carrier.len();
        // The carrier rides the first rider's tag: one frame, one tag —
        // per-rider accounting already happened at admission.
        let tag = &jobs[riders[0]].0;
        let resp = self.fleet.submit_wait_tagged(tag, carrier)?;
        if resp.order.len() != n {
            // No argsort to split by (a provenance-free engine):
            // identity over amortisation — run every rider plain.
            return riders
                .iter()
                .map(|&i| self.fleet.submit_wait_tagged(&jobs[i].0, jobs[i].1.clone()))
                .collect();
        }
        self.coalesced_batches.fetch_add(1, Ordering::Relaxed);
        self.coalesced_requests.fetch_add(riders.len() as u64, Ordering::Relaxed);
        // Walk the carrier's output once: `order[k]` says which
        // original index produced `sorted[k]`, and the span containing
        // it says which rider. Within a rider the walk preserves the
        // carrier's stable (value, original index) order, which is the
        // rider's own stable sort.
        let mut outs: Vec<(Vec<u32>, Vec<usize>)> =
            spans.iter().map(|s| (Vec::with_capacity(s.len()), Vec::with_capacity(s.len()))).collect();
        for (k, &src) in resp.order.iter().enumerate() {
            // First span whose end is past `src`; empty spans have
            // `end <= src` whenever a non-empty successor holds it, so
            // the walk never lands on one.
            let r = spans.partition_point(|s| s.end <= src);
            debug_assert!(spans[r].contains(&src));
            outs[r].0.push(resp.sorted[k]);
            outs[r].1.push(src - spans[r].start);
        }
        Ok(outs
            .into_iter()
            .map(|(sorted, order)| SortResponse {
                id: resp.id,
                sorted,
                order,
                stats: resp.stats.clone(),
                latency_us: resp.latency_us,
                worker: resp.worker,
            })
            .collect())
    }

    /// The frontend's own counters.
    pub fn admission(&self) -> AdmissionSnapshot {
        let st = lock_recover(&self.state);
        AdmissionSnapshot {
            admitted: self.admitted.load(Ordering::Relaxed),
            shed_batch: self.shed_batch.load(Ordering::Relaxed),
            shed_interactive: self.shed_interactive.load(Ordering::Relaxed),
            shed_tenant_cap: self.shed_tenant_cap.load(Ordering::Relaxed),
            overdraft_spent: self.overdraft_spent.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            coalesced_requests: self.coalesced_requests.load(Ordering::Relaxed),
            outstanding: st.outstanding,
            outstanding_bytes: st.outstanding_bytes,
            overdraft_tokens: st.overdraft_tokens,
        }
    }

    /// The fleet snapshot with the admission-plane counters filled in
    /// ([`FleetSnapshot::admitted`] and the shed counters are 0 when
    /// the snapshot comes straight from the fleet — only the frontend
    /// knows them).
    pub fn fleet_metrics(&self) -> FleetSnapshot {
        let mut snap = self.fleet.fleet_metrics();
        let adm = self.admission();
        snap.admitted = adm.admitted;
        snap.shed_saturated = adm.shed_batch + adm.shed_interactive;
        snap.shed_tenant_cap = adm.shed_tenant_cap;
        snap
    }

    /// Graceful shutdown of the fleet behind the plane.
    pub fn shutdown(self) {
        self.fleet.shutdown();
    }

    /// Dismantle the admission plane and hand the fleet back — for
    /// callers that must [`ShardedSortService::disconnect`] from
    /// operator-owned remote hosts instead of shutting them down.
    pub fn into_fleet(self) -> ShardedSortService {
        self.fleet
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::{RoutePolicy, ShardedConfig};
    use crate::coordinator::ServiceConfig;

    fn frontend(cfg: FrontendConfig) -> Frontend {
        let fleet = ShardedSortService::start(ShardedConfig::uniform(
            2,
            RoutePolicy::RoundRobin,
            ServiceConfig { workers: 2, ..Default::default() },
        ))
        .unwrap();
        Frontend::new(fleet, cfg).unwrap()
    }

    fn tag(tenant: &str, priority: Priority) -> JobTag {
        JobTag::new(tenant, priority)
    }

    #[test]
    fn priority_parse_round_trips() {
        for p in Priority::ALL {
            assert_eq!(Priority::parse(p.name()), Some(p));
            assert_eq!(p.name().parse::<Priority>().unwrap(), p);
        }
        assert!("realtime".parse::<Priority>().is_err());
        assert_eq!(JobTag::default().priority, Priority::Batch);
    }

    #[test]
    fn sorts_through_admission() {
        let fe = frontend(FrontendConfig::default());
        let resp = fe.sort(&tag("acme", Priority::Interactive), vec![3, 1, 2]).unwrap();
        assert_eq!(resp.sorted, vec![1, 2, 3]);
        let adm = fe.admission();
        assert_eq!((adm.admitted, adm.outstanding), (1, 0), "the permit released");
        let snap = fe.fleet_metrics();
        assert_eq!(snap.admitted, 1);
        assert_eq!(snap.shed_saturated + snap.shed_tenant_cap, 0);
        fe.shutdown();
    }

    #[test]
    fn permit_releases_on_drop_even_without_a_sort() {
        let fe = frontend(FrontendConfig::default());
        let t = tag("acme", Priority::Batch);
        {
            let _p = fe.try_admit(&t).unwrap();
            assert_eq!(fe.admission().outstanding, 1);
        }
        assert_eq!(fe.admission().outstanding, 0);
        fe.shutdown();
    }

    #[test]
    fn invalid_configs_are_rejected() {
        let fleet = ShardedSortService::start(ShardedConfig::uniform(
            1,
            RoutePolicy::RoundRobin,
            ServiceConfig { workers: 1, ..Default::default() },
        ))
        .unwrap();
        let bad = FrontendConfig { max_outstanding: 0, ..Default::default() };
        assert!(Frontend::new(fleet, bad).is_err());
    }

    #[test]
    fn sized_admission_charges_and_releases_bytes() {
        let fe = frontend(FrontendConfig::default());
        let t = tag("acme", Priority::Batch);
        {
            let _a = fe.try_admit_sized(&t, 4096).unwrap();
            assert_eq!(fe.admission().outstanding_bytes, 4096);
            let _b = fe.try_admit_sized(&t, 1000).unwrap();
            assert_eq!(fe.admission().outstanding_bytes, 5096);
            // Plain admission charges nothing.
            let _c = fe.try_admit(&t).unwrap();
            assert_eq!(fe.admission().outstanding_bytes, 5096);
        }
        let adm = fe.admission();
        assert_eq!((adm.outstanding, adm.outstanding_bytes), (0, 0));
        fe.shutdown();
    }

    #[test]
    fn hierarchical_admission_accounts_spill_not_resident_bytes() {
        use crate::sorter::spill::{resident_merge_bytes, spill_working_bytes, MemoryBudget};
        let n = 100_000;
        let resident = HierarchicalConfig::fixed(256, 4);
        assert_eq!(hierarchical_admission_bytes(n, &resident), resident_merge_bytes(n) as u64);
        // A budget at exactly the resident footprint stays resident.
        let exact = resident.clone().with_budget(MemoryBudget::Bytes(resident_merge_bytes(n)));
        assert_eq!(hierarchical_admission_bytes(n, &exact), resident_merge_bytes(n) as u64);
        // One byte under: the sort spills, and admission charges the
        // bounded working set of the external merge, not the resident
        // footprint it no longer holds.
        let spilling =
            resident.clone().with_budget(MemoryBudget::Bytes(resident_merge_bytes(n) - 1));
        let charged = hierarchical_admission_bytes(n, &spilling);
        assert_eq!(charged, spill_working_bytes(4) as u64);
        assert!(charged < resident_merge_bytes(n) as u64);
    }

    #[test]
    fn hierarchical_sorts_through_admission_and_releases() {
        use crate::sorter::spill::MemoryBudget;
        let fe = frontend(FrontendConfig::default());
        let data: Vec<u32> = (0..2000u32).rev().collect();
        let mut want = data.clone();
        want.sort_unstable();
        let resident = fe
            .sort_hierarchical(&tag("acme", Priority::Batch), &data, &HierarchicalConfig::fixed(128, 4))
            .unwrap();
        assert_eq!(resident.hier.output.sorted, want);
        assert!(!resident.hier.spilled);
        let cfg = HierarchicalConfig::fixed(128, 4).with_budget(MemoryBudget::Bytes(4 << 10));
        let spilled = fe.sort_hierarchical(&tag("acme", Priority::Batch), &data, &cfg).unwrap();
        assert_eq!(spilled.hier.output.sorted, want);
        assert!(spilled.hier.spilled);
        assert!(spilled.hier.spilled_bytes > 0);
        let adm = fe.admission();
        assert_eq!(adm.admitted, 2);
        assert_eq!((adm.outstanding, adm.outstanding_bytes), (0, 0), "permits released");
        fe.shutdown();
    }

    #[test]
    fn coalesce_cap_defaults_to_the_fleet_bank() {
        let fe = frontend(FrontendConfig::default());
        assert_eq!(
            fe.coalesce_elems(),
            fe.fleet().config().services[0].geometry.largest_bank()
        );
        let fe = frontend(FrontendConfig { coalesce_elems: 128, ..Default::default() });
        assert_eq!(fe.coalesce_elems(), 128);
        fe.shutdown();
    }
}

//! Hierarchical out-of-bank sorting: chunk → column-skip → k-way merge.
//!
//! The paper's sorters (and the §IV multi-bank ensemble) operate on one
//! logical memristive array; the evaluation tops out at N = 1024. This
//! module opens the "array larger than the hardware" dimension: a
//! capacity-aware partitioner ([`super::planner::partition`]) splits a
//! request of arbitrary length into bank-sized chunks, the service's
//! worker pool sorts the chunks concurrently (each worker owns a
//! [`crate::sorter::colskip::ColSkipSorter`] or a
//! [`crate::multibank::MultiBankSorter`]), and a loser-tree merge network
//! ([`crate::sorter::merge::merge_runs`]) combines the per-chunk runs
//! into the global order — the standard sort-then-merge recipe for
//! scaling in-memory sorters past array capacity (cf. arXiv:2012.09918,
//! arXiv:2310.07903).
//!
//! ## Accounting
//!
//! Two views are reported and must not be conflated:
//!
//! * **Work** — `output.stats` is the *sum* of the per-chunk simulator
//!   stats (every CR/RE/SR/SL/drain issued anywhere). The integration
//!   tests pin `output.stats == Σ chunk_stats`.
//! * **Latency** — `latency_cycles` is the critical path: chunks sort in
//!   parallel banks (max over chunks), then the merge network streams
//!   the whole dataset once per merge pass.
//!
//! Cost totals (area/power) come from the calibrated model's
//! [`crate::cost::SorterArch::Hierarchical`] arch, using the service's
//! engine configuration (width, k, sub-banks).

use anyhow::{anyhow, Result};

use super::planner::partition;
use super::{SortResponse, SortService};
use crate::cost::{Activity, CostModel, SorterArch};
use crate::sorter::merge::merge_runs;
use crate::sorter::{SortOutput, SortStats};

/// Configuration of one hierarchical sort. Engine parameters (width, k,
/// sub-banks per chunk) come from the [`super::ServiceConfig`] the
/// service was started with.
#[derive(Clone, Debug)]
pub struct HierarchicalConfig {
    /// Bank capacity: rows per chunk (the hardware's array length).
    pub capacity: usize,
    /// Fanout of the merge network combining the sorted runs.
    pub fanout: usize,
}

impl Default for HierarchicalConfig {
    fn default() -> Self {
        HierarchicalConfig { capacity: crate::params::DEFAULT_N, fanout: 4 }
    }
}

/// Merge-stage accounting of one hierarchical sort.
#[derive(Clone, Debug)]
pub struct MergeMetrics {
    /// Comparator operations performed by the loser trees (all passes).
    pub comparisons: u64,
    /// Merge passes (`ceil(log_fanout(chunks))`).
    pub passes: u32,
    /// Modelled merge-network latency in cycles.
    pub cycles: u64,
    /// Fanout the merge ran with.
    pub fanout: usize,
}

/// Result of one hierarchical sort.
#[derive(Clone, Debug)]
pub struct HierarchicalOutput {
    /// Global sorted values + argsort; `stats` is the summed per-chunk
    /// work (see the module docs for work vs latency).
    pub output: SortOutput,
    /// Per-chunk simulator stats, in chunk order.
    pub chunk_stats: Vec<SortStats>,
    /// Bank capacity the partitioner used.
    pub capacity: usize,
    /// Merge-stage accounting.
    pub merge: MergeMetrics,
    /// Critical-path latency: max chunk cycles + merge cycles.
    pub latency_cycles: u64,
    /// Calibrated silicon area of the modelled hardware (Kµm²).
    pub area_kum2: f64,
    /// Calibrated power under the measured switching activity (mW).
    pub power_mw: f64,
}

impl HierarchicalOutput {
    /// Number of chunks the request was split into.
    pub fn chunks(&self) -> usize {
        self.chunk_stats.len()
    }

    /// Critical-path latency in seconds at the paper's 500 MHz clock.
    pub fn latency_seconds(&self) -> f64 {
        self.latency_cycles as f64 / crate::params::CLOCK_HZ
    }

    /// Sorted elements per second at the paper's clock (latency view).
    pub fn throughput(&self) -> f64 {
        if self.latency_cycles == 0 {
            0.0
        } else {
            self.output.sorted.len() as f64 * crate::params::CLOCK_HZ / self.latency_cycles as f64
        }
    }

    /// Fraction of the critical path spent in the merge network.
    pub fn merge_fraction(&self) -> f64 {
        if self.latency_cycles == 0 {
            0.0
        } else {
            self.merge.cycles as f64 / self.latency_cycles as f64
        }
    }
}

impl SortService {
    /// Sort a dataset of arbitrary length through the hierarchical
    /// pipeline: partition into `cfg.capacity`-row chunks, sort every
    /// chunk on the worker pool, merge the runs through a
    /// `cfg.fanout`-way loser-tree network.
    pub fn sort_hierarchical(
        &self,
        data: &[u32],
        cfg: &HierarchicalConfig,
    ) -> Result<HierarchicalOutput> {
        assert!(cfg.capacity >= 1, "bank capacity must be positive");
        assert!(cfg.fanout >= 2, "merge fanout must be at least 2");
        let n = data.len();
        let spans = partition(n, cfg.capacity);
        let chunks = spans.len();

        // Fan the chunks out to the worker pool (parallel banks), then
        // collect in chunk order.
        let rxs: Vec<_> = spans
            .iter()
            .map(|s| self.submit(data[s.clone()].to_vec()))
            .collect::<Result<_>>()?;
        let resps: Vec<SortResponse> = rxs
            .into_iter()
            .map(|rx| rx.recv().map_err(|_| anyhow!("worker dropped a chunk response"))?)
            .collect::<Result<_>>()?;

        let mut chunk_stats = Vec::with_capacity(chunks);
        let mut total = SortStats::default();
        let mut max_chunk_cycles = 0u64;
        let mut have_order = true;
        let mut runs: Vec<Vec<(u32, usize)>> = Vec::with_capacity(chunks);
        for (span, resp) in spans.iter().zip(&resps) {
            if resp.sorted.len() != span.len() {
                return Err(anyhow!(
                    "chunk [{}, {}) returned {} elements",
                    span.start,
                    span.end,
                    resp.sorted.len()
                ));
            }
            max_chunk_cycles = max_chunk_cycles.max(resp.stats.cycles());
            total.merge_from(&resp.stats);
            chunk_stats.push(resp.stats.clone());
            // Rebase chunk-local argsort rows to global indices. A
            // backend without row provenance (pure PJRT) degrades the
            // global order to empty rather than inventing one.
            if resp.order.len() == resp.sorted.len() {
                runs.push(
                    resp.sorted
                        .iter()
                        .zip(&resp.order)
                        .map(|(&v, &r)| (v, span.start + r))
                        .collect(),
                );
            } else {
                have_order = false;
                runs.push(resp.sorted.iter().map(|&v| (v, 0)).collect());
            }
        }

        let merge = merge_runs(runs, cfg.fanout);
        debug_assert_eq!(merge.merged.len(), n);
        let sorted = merge.values();
        let order = if have_order { merge.order() } else { Vec::new() };

        let latency_cycles = max_chunk_cycles + merge.cycles;
        let metrics = MergeMetrics {
            comparisons: merge.comparisons,
            passes: merge.passes,
            cycles: merge.cycles,
            fanout: cfg.fanout,
        };
        self.metrics.record_hierarchical(n, chunks, metrics.cycles, metrics.comparisons);

        // Cost totals for the modelled hardware ensemble, under the
        // activity the chunks actually exhibited.
        let svc = self.config();
        let arch = SorterArch::Hierarchical {
            bank_n: cfg.capacity,
            w: svc.colskip.width,
            k: svc.colskip.k,
            chunks: chunks.max(1),
            banks_per_chunk: svc.banks,
            fanout: cfg.fanout,
        };
        let model = CostModel::calibrated();
        let act = if total.cycles() > 0 {
            Activity::from_stats(&total)
        } else {
            Activity::nominal_colskip()
        };

        Ok(HierarchicalOutput {
            output: SortOutput { sorted, order, stats: total },
            chunk_stats,
            capacity: cfg.capacity,
            merge: metrics,
            latency_cycles,
            area_kum2: model.area_kum2(arch),
            power_mw: model.power_mw(arch, act),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServiceConfig;
    use crate::datasets::{Dataset, DatasetKind};
    use crate::sorter::merge::{model_merge_cycles, model_merge_passes};

    fn service(workers: usize) -> SortService {
        SortService::start(ServiceConfig { workers, ..Default::default() }).unwrap()
    }

    #[test]
    fn sorts_past_bank_capacity() {
        let svc = service(4);
        let cfg = HierarchicalConfig { capacity: 256, fanout: 4 };
        for n in [1usize, 255, 256, 257, 1000, 5000] {
            let d = Dataset::generate32(DatasetKind::MapReduce, n, 13);
            let out = svc.sort_hierarchical(&d.values, &cfg).unwrap();
            let mut expect = d.values.clone();
            expect.sort_unstable();
            assert_eq!(out.output.sorted, expect, "n={n}");
            assert_eq!(out.chunks(), n.div_ceil(256), "n={n}");
            // Global argsort maps original rows to sorted values.
            assert_eq!(out.output.order.len(), n);
            for (i, &row) in out.output.order.iter().enumerate() {
                assert_eq!(d.values[row], out.output.sorted[i], "n={n}");
            }
        }
        svc.shutdown();
    }

    #[test]
    fn work_is_sum_latency_is_critical_path() {
        let svc = service(2);
        let cfg = HierarchicalConfig { capacity: 128, fanout: 2 };
        let d = Dataset::generate32(DatasetKind::Clustered, 1000, 3);
        let out = svc.sort_hierarchical(&d.values, &cfg).unwrap();
        let mut summed = SortStats::default();
        let mut max_cycles = 0;
        for s in &out.chunk_stats {
            summed.merge_from(s);
            max_cycles = max_cycles.max(s.cycles());
        }
        assert_eq!(out.output.stats, summed, "stats must be the summed chunk work");
        assert_eq!(out.latency_cycles, max_cycles + out.merge.cycles);
        assert_eq!(out.merge.cycles, model_merge_cycles(1000, 8, 2));
        assert_eq!(out.merge.passes, model_merge_passes(8, 2));
        assert!(out.merge.comparisons > 0);
        assert!(out.merge_fraction() > 0.0 && out.merge_fraction() < 1.0);
        svc.shutdown();
    }

    #[test]
    fn empty_input_is_trivial() {
        let svc = service(1);
        let out = svc
            .sort_hierarchical(&[], &HierarchicalConfig::default())
            .unwrap();
        assert!(out.output.sorted.is_empty());
        assert_eq!(out.chunks(), 0);
        assert_eq!(out.latency_cycles, 0);
        assert_eq!(out.throughput(), 0.0);
        svc.shutdown();
    }

    #[test]
    fn service_metrics_see_the_pipeline() {
        let svc = service(2);
        let cfg = HierarchicalConfig { capacity: 64, fanout: 4 };
        let d = Dataset::generate32(DatasetKind::Uniform, 300, 5);
        svc.sort_hierarchical(&d.values, &cfg).unwrap();
        let m = svc.metrics();
        assert_eq!(m.hier_completed, 1);
        assert_eq!(m.hier_elements, 300);
        assert_eq!(m.hier_chunks, 5);
        assert!(m.merge_cycles > 0);
        assert!(m.merge_comparisons > 0);
        // Chunk jobs flowed through the normal request path too.
        assert_eq!(m.completed, 5);
        svc.shutdown();
    }

    #[test]
    fn saturated_max_values_sort_exactly() {
        // A dataset saturated with *real* `u32::MAX` values through the
        // hierarchical path. Unlike `planner::execute` (which pads every
        // chunk to the full bank with MAX sentinels and meters them —
        // see `chunk_merge_meters_sentinel_work`), the pipeline sorts
        // the short last chunk unpadded: the output, the argsort and
        // the summed work stats cover exactly the n real rows.
        let svc = service(2);
        let cfg = HierarchicalConfig { capacity: 64, fanout: 4 };
        let mut data = vec![u32::MAX; 150];
        for (i, v) in data.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = i as u32;
            }
        }
        let out = svc.sort_hierarchical(&data, &cfg).unwrap();
        let mut expect = data.clone();
        expect.sort_unstable();
        assert_eq!(out.output.sorted, expect);
        assert_eq!(out.chunks(), 3, "64 + 64 + 22 rows");
        // The argsort is a permutation over the real rows only.
        let mut seen = vec![false; data.len()];
        for (&row, &val) in out.output.order.iter().zip(&out.output.sorted) {
            assert!(!seen[row], "row {row} emitted twice");
            seen[row] = true;
            assert_eq!(data[row], val);
        }
        assert!(seen.iter().all(|&s| s));
        // Work covers exactly n emissions — no sentinel rows anywhere.
        let mut summed = SortStats::default();
        for s in &out.chunk_stats {
            summed.merge_from(s);
        }
        assert_eq!(summed.iterations + summed.drains, 150);
        assert_eq!(out.output.stats, summed);
        svc.shutdown();
    }

    #[test]
    fn finer_chunking_is_cheaper_silicon() {
        // Fig. 8(b) carried to the chunk dimension: the row processor
        // scales as Ns·log2(Ns), so 16 banks of 256 rows undercut 2 banks
        // of 2048 rows even with the larger merge tree.
        let svc = service(2);
        let d = Dataset::generate32(DatasetKind::MapReduce, 4096, 9);
        let coarse = svc
            .sort_hierarchical(&d.values, &HierarchicalConfig { capacity: 2048, fanout: 4 })
            .unwrap();
        let fine = svc
            .sort_hierarchical(&d.values, &HierarchicalConfig { capacity: 256, fanout: 4 })
            .unwrap();
        assert!(fine.area_kum2 < coarse.area_kum2, "{} vs {}", fine.area_kum2, coarse.area_kum2);
        assert!(fine.power_mw < coarse.power_mw, "{} vs {}", fine.power_mw, coarse.power_mw);
        assert!(fine.area_kum2 > 0.0 && fine.power_mw > 0.0);
        svc.shutdown();
    }
}

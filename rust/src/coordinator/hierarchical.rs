//! Hierarchical out-of-bank sorting: chunk → column-skip → k-way merge.
//!
//! The paper's sorters (and the §IV multi-bank ensemble) operate on one
//! logical memristive array; the evaluation tops out at N = 1024. This
//! module opens the "array larger than the hardware" dimension: a
//! capacity-aware partitioner ([`super::planner::partition`]) splits a
//! request of arbitrary length into bank-sized chunks, the service's
//! worker pool sorts the chunks concurrently (each worker owns a
//! [`crate::sorter::colskip::ColSkipSorter`] or a
//! [`crate::multibank::MultiBankSorter`]), and a loser-tree merge network
//! ([`crate::sorter::merge::merge_runs`]) combines the per-chunk runs
//! into the global order — the standard sort-then-merge recipe for
//! scaling in-memory sorters past array capacity (cf. arXiv:2012.09918,
//! arXiv:2310.07903).
//!
//! ## Streaming vs barrier
//!
//! The PR-1 pipeline barriered: every chunk response was collected
//! before the first merge cycle, so the merge latency sat entirely on
//! the critical path. The pipeline now *streams* by default
//! ([`HierarchicalConfig::streaming`]): a [`StreamingMerge`] frontier
//! owns the fixed merge tree and reduces each group of runs the moment
//! its last member arrives, so merge cycles overlap the chunk sorts
//! still in flight — the near-memory manager behaviour the paper's
//! multi-bank coordination implies, and the standard sort-then-stream
//! overlap of scaled memristive sorting designs (arXiv:2012.09918,
//! arXiv:2310.07903). Both modes produce byte-identical output; only
//! the schedule (and therefore the latency model) differs.
//!
//! ## Accounting
//!
//! Three views are reported and must not be conflated:
//!
//! * **Work** — `output.stats` is the *sum* of the per-chunk simulator
//!   stats (every CR/RE/SR/SL/drain issued anywhere). The integration
//!   tests pin `output.stats == Σ chunk_stats`.
//! * **Barrier latency** — `barrier_latency_cycles`: chunks sort in
//!   parallel banks (max over chunks), then the merge network streams
//!   the whole dataset once per merge pass.
//! * **Streamed latency** — `streamed_latency_cycles`: the
//!   deterministic overlap schedule of
//!   [`crate::sorter::merge::model_streamed_completion`] over the
//!   actual per-chunk arrival cycles; never above the barrier number,
//!   never below the slowest chunk.
//!
//! Cost totals (area/power) come from the calibrated model's
//! [`crate::cost::SorterArch::Hierarchical`] arch, using the service's
//! engine configuration (width, k, sub-banks).

use std::ops::Range;

use anyhow::{anyhow, Result};

use super::planner::{auto_tune_budgeted, partition, schedule};
use super::{ServiceConfig, SortResponse, SortService};
use crate::cost::{Activity, CostModel, SorterArch};
use crate::sorter::merge::{merge_runs, model_streamed_completion, StreamingMerge};
use crate::sorter::spill::{
    resident_merge_bytes, spill_merge, write_run, MemoryBudget, RunStore, TempDirRunStore,
};
use crate::sorter::{SortOutput, SortStats};

/// How the partitioner picks the bank capacity (rows per chunk).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Capacity {
    /// Auto-tune: enumerate `(bank, fanout)` candidates over the
    /// service's [`super::planner::Geometry`] and pick the cheapest
    /// under the latency model ([`super::planner::auto_tune`]), fed by
    /// the per-size-class cycles/number the service has observed
    /// (falling back to the paper's nominal
    /// [`crate::params::NOMINAL_COLSKIP_CYC_PER_NUM`] before any
    /// traffic).
    Auto,
    /// Use exactly this many rows per chunk.
    Fixed(usize),
}

/// Configuration of one hierarchical sort. Engine parameters (width, k,
/// sub-banks per chunk) come from the [`super::ServiceConfig`] the
/// service was started with.
#[derive(Clone, Debug)]
pub struct HierarchicalConfig {
    /// Bank capacity: rows per chunk (the hardware's array length),
    /// fixed or auto-tuned.
    pub capacity: Capacity,
    /// Fanout of the merge network combining the sorted runs.
    /// [`Capacity::Auto`] may pick a different fanout when the model
    /// scores it cheaper.
    pub fanout: usize,
    /// Stream the merge (overlap chunk sorting with merge passes —
    /// the default) instead of barriering on every chunk response
    /// before the first merge cycle. Both modes produce byte-identical
    /// output; they differ in the latency model and in when the host
    /// does the merge work.
    pub streaming: bool,
    /// Byte budget for the merge working set. When the resident
    /// footprint ([`resident_merge_bytes`]) exceeds it, chunk runs
    /// spill to a temp-dir [`RunStore`] and the merge runs out of core
    /// — byte-identical output (values, argsort, stats; pinned by
    /// `tests/spill.rs`), with the spill I/O priced into
    /// `latency_cycles`. Defaults to [`MemoryBudget::Unbounded`]: never
    /// spill.
    pub budget: MemoryBudget,
}

impl HierarchicalConfig {
    /// Streaming pipeline at a fixed bank capacity.
    pub fn fixed(capacity: usize, fanout: usize) -> Self {
        HierarchicalConfig {
            capacity: Capacity::Fixed(capacity),
            fanout,
            streaming: true,
            budget: MemoryBudget::Unbounded,
        }
    }

    /// The PR-1 barrier pipeline at a fixed bank capacity: collect all
    /// chunk responses, then merge.
    pub fn barrier(capacity: usize, fanout: usize) -> Self {
        HierarchicalConfig {
            capacity: Capacity::Fixed(capacity),
            fanout,
            streaming: false,
            budget: MemoryBudget::Unbounded,
        }
    }

    /// Streaming pipeline with auto-tuned chunking.
    pub fn auto() -> Self {
        HierarchicalConfig {
            capacity: Capacity::Auto,
            fanout: 4,
            streaming: true,
            budget: MemoryBudget::Unbounded,
        }
    }

    /// Same config under a [`MemoryBudget`] (builder style, used by the
    /// CLI's `--memory-budget` flag and the spill tests).
    pub fn with_budget(mut self, budget: MemoryBudget) -> Self {
        self.budget = budget;
        self
    }
}

impl Default for HierarchicalConfig {
    fn default() -> Self {
        Self::fixed(crate::params::DEFAULT_N, 4)
    }
}

/// Merge-stage accounting of one hierarchical sort.
#[derive(Clone, Debug)]
pub struct MergeMetrics {
    /// Comparator operations performed by the loser trees (all passes).
    pub comparisons: u64,
    /// Merge passes (`ceil(log_fanout(chunks))`).
    pub passes: u32,
    /// Modelled merge-network latency in cycles.
    pub cycles: u64,
    /// Fanout the merge ran with.
    pub fanout: usize,
}

/// Result of one hierarchical sort.
#[derive(Clone, Debug)]
pub struct HierarchicalOutput {
    /// Global sorted values + argsort; `stats` is the summed per-chunk
    /// work (see the module docs for work vs latency).
    pub output: SortOutput,
    /// Per-chunk simulator stats, in chunk order.
    pub chunk_stats: Vec<SortStats>,
    /// Bank capacity the partitioner used (resolved, for `Auto`).
    pub capacity: usize,
    /// Merge-stage accounting.
    pub merge: MergeMetrics,
    /// Whether this sort ran the streaming pipeline.
    pub streaming: bool,
    /// Whether the merge ran out of core (chunk runs spilled to a
    /// [`RunStore`] under the config's [`MemoryBudget`]).
    pub spilled: bool,
    /// Total bytes written to the spill store (header + block framing
    /// included, intermediate merge passes too); 0 when resident. This
    /// — not the resident working set — is what frontend admission
    /// accounts for a spilled sort.
    pub spilled_bytes: u64,
    /// Critical-path latency of the mode that ran: the streamed
    /// completion under streaming, `max_chunk + merge` under barrier —
    /// plus the modelled spill I/O surcharge
    /// ([`schedule::spill_io_cycles`]) when the sort spilled.
    pub latency_cycles: u64,
    /// Barrier-model latency (`max_chunk_cycles + merge.cycles`),
    /// reported in both modes for comparison.
    pub barrier_latency_cycles: u64,
    /// Overlap-model latency ([`model_streamed_completion`] over the
    /// chunk arrivals), reported in both modes for comparison. Never
    /// exceeds `barrier_latency_cycles`.
    pub streamed_latency_cycles: u64,
    /// Cycles of the slowest chunk sort (parallel banks).
    pub max_chunk_cycles: u64,
    /// Calibrated silicon area of the modelled hardware (Kµm²).
    pub area_kum2: f64,
    /// Calibrated power under the measured switching activity (mW).
    pub power_mw: f64,
}

impl HierarchicalOutput {
    /// Number of chunks the request was split into.
    pub fn chunks(&self) -> usize {
        self.chunk_stats.len()
    }

    /// Critical-path latency in seconds at the paper's 500 MHz clock.
    pub fn latency_seconds(&self) -> f64 {
        self.latency_cycles as f64 / crate::params::CLOCK_HZ
    }

    /// Sorted elements per second at the paper's clock (latency view).
    pub fn throughput(&self) -> f64 {
        if self.latency_cycles == 0 {
            0.0
        } else {
            self.output.sorted.len() as f64 * crate::params::CLOCK_HZ / self.latency_cycles as f64
        }
    }

    /// Fraction of the critical path *not* hidden behind chunk sorting
    /// — the exposed merge share. Under the barrier model this is
    /// exactly `merge.cycles / latency_cycles`; under streaming it is
    /// the merge tail the overlap failed to hide.
    pub fn merge_fraction(&self) -> f64 {
        if self.latency_cycles == 0 {
            0.0
        } else {
            (self.latency_cycles - self.max_chunk_cycles) as f64 / self.latency_cycles as f64
        }
    }

    /// Cycles the streaming frontier hides relative to the barrier
    /// model, as a fraction of the barrier latency.
    pub fn overlap_saving(&self) -> f64 {
        if self.barrier_latency_cycles == 0 {
            0.0
        } else {
            1.0 - self.streamed_latency_cycles as f64 / self.barrier_latency_cycles as f64
        }
    }
}

/// The shared assembly half of the hierarchical pipeline: per-chunk
/// responses go in (chunk-index order), the [`HierarchicalOutput`]
/// comes out. [`SortService::sort_hierarchical`] drives it over one
/// worker pool; [`super::shard::ShardedSortService::sort_hierarchical`]
/// drives the *same* assembler over chunks routed across shard
/// transports ([`super::transport::ShardTransport`]) — which is why
/// the two paths are byte-identical by construction (the frontier
/// consumes run arrivals in chunk order regardless of which host — or
/// host geometry — sorted each chunk, and a [`SortResponse`] looks the
/// same whether it crossed a thread boundary or the
/// [`super::wire`] protocol — pinned by the remote-vs-local
/// integration sweep).
pub(crate) struct ChunkAssembly<'s> {
    spans: Vec<Range<usize>>,
    streaming: bool,
    fanout: usize,
    frontier: StreamingMerge<(u32, usize)>,
    parked: Vec<Vec<(u32, usize)>>,
    /// Out-of-core mode: absorbed runs are written to this store (run
    /// id = chunk index) instead of the frontier/park, and `finish`
    /// merges them externally ([`spill_merge`]).
    spill: Option<&'s dyn RunStore>,
    chunk_stats: Vec<SortStats>,
    total: SortStats,
    max_chunk_cycles: u64,
    have_order: bool,
    arrivals: Vec<(u64, usize)>,
}

impl<'s> ChunkAssembly<'s> {
    pub(crate) fn new(spans: Vec<Range<usize>>, fanout: usize, streaming: bool) -> Self {
        Self::build(spans, fanout, streaming, None)
    }

    /// Out-of-core assembly: every absorbed run spills to `store`, the
    /// merge runs externally. Output stays byte-identical to [`new`]'s
    /// resident pipeline (`Self::new`).
    pub(crate) fn new_spilling(
        spans: Vec<Range<usize>>,
        fanout: usize,
        streaming: bool,
        store: &'s dyn RunStore,
    ) -> Self {
        Self::build(spans, fanout, streaming, Some(store))
    }

    fn build(
        spans: Vec<Range<usize>>,
        fanout: usize,
        streaming: bool,
        spill: Option<&'s dyn RunStore>,
    ) -> Self {
        let chunks = spans.len();
        ChunkAssembly {
            spans,
            streaming,
            fanout,
            // Streaming mode feeds the merge frontier as responses are
            // collected (in chunk-index order — std mpsc has no
            // select, so a slow early chunk delays later,
            // already-finished ones), so host merge work overlaps the
            // chunk sorts still queued behind it; barrier mode (PR 1)
            // parks every run and merges after all of them. The
            // *modelled* latency is unaffected either way: it is
            // computed from the recorded per-chunk arrival cycles, not
            // from host timing. Spill mode bypasses the frontier
            // entirely (runs go to the store), so it gets an empty one.
            frontier: StreamingMerge::new(
                if streaming && spill.is_none() { chunks } else { 0 },
                fanout,
            ),
            parked: Vec::new(),
            spill,
            chunk_stats: Vec::with_capacity(chunks),
            total: SortStats::default(),
            max_chunk_cycles: 0,
            have_order: true,
            arrivals: Vec::with_capacity(chunks),
        }
    }

    pub(crate) fn spans(&self) -> &[Range<usize>] {
        &self.spans
    }

    /// Absorb chunk `i`'s response: validate the span, aggregate the
    /// stats, rebase the argsort and feed the merge (frontier or park).
    pub(crate) fn absorb(&mut self, i: usize, resp: &SortResponse) -> Result<()> {
        let span = self.spans[i].clone();
        if resp.sorted.len() != span.len() {
            return Err(anyhow!(
                "chunk [{}, {}) returned {} elements",
                span.start,
                span.end,
                resp.sorted.len()
            ));
        }
        self.max_chunk_cycles = self.max_chunk_cycles.max(resp.stats.cycles());
        self.arrivals.push((resp.stats.cycles(), span.len()));
        self.total.merge_from(&resp.stats);
        self.chunk_stats.push(resp.stats.clone());
        // Rebase chunk-local argsort rows to global indices. A backend
        // without row provenance (pure PJRT) degrades the global order
        // to empty rather than inventing one.
        let run: Vec<(u32, usize)> = if resp.order.len() == resp.sorted.len() {
            resp.sorted
                .iter()
                .zip(&resp.order)
                .map(|(&v, &r)| (v, span.start + r))
                .collect()
        } else {
            self.have_order = false;
            resp.sorted.iter().map(|&v| (v, 0)).collect()
        };
        if let Some(store) = self.spill {
            // Out of core: the run leaves memory now; the budget's
            // whole point is that at most one chunk run is resident at
            // a time on this path. Any store failure propagates — never
            // a silent fall-back to the resident merge.
            write_run(store, i, &run)?;
        } else if self.streaming {
            self.frontier.push(i, run, resp.stats.cycles());
        } else {
            self.parked.push(run);
        }
        Ok(())
    }

    /// Close the pipeline: run (or finish) the merge stage and assemble
    /// the output, costing the ensemble with `svc`'s engine geometry.
    /// Errors only on the spill path (store I/O / decode faults) —
    /// resident merges are infallible.
    pub(crate) fn finish(self, svc: &ServiceConfig, capacity: usize) -> Result<HierarchicalOutput> {
        let n = self.spans.last().map_or(0, |s| s.end);
        let chunks = self.spans.len();
        debug_assert_eq!(self.chunk_stats.len(), chunks, "every chunk must be absorbed");
        // Merge-stage result: identical output in all three modes (the
        // external merge ports the loser tree and pass grouping
        // verbatim — see `spill.rs`); only the schedule differs.
        let (merged, comparisons, passes, merge_cycles, streamed_latency_cycles) =
            if let Some(store) = self.spill {
                let m = spill_merge(store, chunks, self.fanout)?;
                let streamed = model_streamed_completion(&self.arrivals, self.fanout);
                (m.merged, m.comparisons, m.passes, m.cycles, streamed)
            } else if self.streaming {
                let s = self.frontier.finish();
                (s.merged, s.comparisons, s.passes, s.cycles, s.completion_cycles)
            } else {
                let m = merge_runs(self.parked, self.fanout);
                let streamed = model_streamed_completion(&self.arrivals, self.fanout);
                (m.merged, m.comparisons, m.passes, m.cycles, streamed)
            };
        debug_assert_eq!(merged.len(), n);
        let sorted: Vec<u32> = merged.iter().map(|&(v, _)| v).collect();
        let order: Vec<usize> =
            if self.have_order { merged.iter().map(|&(_, r)| r).collect() } else { Vec::new() };

        let barrier_latency_cycles = self.max_chunk_cycles + merge_cycles;
        debug_assert!(streamed_latency_cycles <= barrier_latency_cycles);
        debug_assert!(streamed_latency_cycles >= self.max_chunk_cycles);
        // The barrier/streamed fields stay pure in-memory models (so
        // spill-vs-resident comparisons read them directly); the
        // critical path of a spilled sort adds the device crossings.
        let spill_io_cycles = if self.spill.is_some() {
            schedule::spill_io_cycles(n, chunks, self.fanout)
        } else {
            0
        };
        let latency_cycles = spill_io_cycles
            + if self.streaming { streamed_latency_cycles } else { barrier_latency_cycles };
        let metrics =
            MergeMetrics { comparisons, passes, cycles: merge_cycles, fanout: self.fanout };

        // Cost totals for the modelled hardware ensemble, under the
        // activity the chunks actually exhibited.
        let arch = SorterArch::Hierarchical {
            bank_n: capacity,
            w: svc.colskip.width,
            k: svc.colskip.k,
            chunks: chunks.max(1),
            banks_per_chunk: svc.banks,
            fanout: self.fanout,
        };
        let model = CostModel::calibrated();
        let act = if self.total.cycles() > 0 {
            Activity::from_stats(&self.total)
        } else {
            Activity::nominal_colskip()
        };

        Ok(HierarchicalOutput {
            output: SortOutput { sorted, order, stats: self.total, counters: Default::default() },
            chunk_stats: self.chunk_stats,
            capacity,
            merge: metrics,
            streaming: self.streaming,
            spilled: self.spill.is_some(),
            spilled_bytes: self.spill.map_or(0, |s| s.spilled_bytes()),
            latency_cycles,
            barrier_latency_cycles,
            streamed_latency_cycles,
            max_chunk_cycles: self.max_chunk_cycles,
            area_kum2: model.area_kum2(arch),
            power_mw: model.power_mw(arch, act),
        })
    }

    /// The recorded `(arrival_cycles, len)` leaves, in chunk order —
    /// the sharded pipeline re-scores them per shard.
    pub(crate) fn arrivals(&self) -> &[(u64, usize)] {
        &self.arrivals
    }
}

impl SortService {
    /// Sort a dataset of arbitrary length through the hierarchical
    /// pipeline: partition into `cfg.capacity`-row chunks, sort every
    /// chunk on the worker pool, merge the runs through a
    /// `cfg.fanout`-way loser-tree network.
    pub fn sort_hierarchical(
        &self,
        data: &[u32],
        cfg: &HierarchicalConfig,
    ) -> Result<HierarchicalOutput> {
        // Misconfiguration is an error, not a panic — same contract as
        // the fleet path (`ShardedSortService::sort_hierarchical`);
        // these values come straight from CLI flags.
        if cfg.fanout < 2 {
            return Err(anyhow!("merge fanout must be at least 2, got {}", cfg.fanout));
        }
        let n = data.len();
        let (capacity, fanout, spilling) = self.resolve_chunking_budgeted(n, cfg);
        if capacity < 1 {
            return Err(anyhow!("bank capacity must be positive"));
        }
        let store = if spilling { Some(TempDirRunStore::new()?) } else { None };
        self.run_hierarchical(
            data,
            cfg.streaming,
            capacity,
            fanout,
            store.as_ref().map(|s| s as &dyn RunStore),
        )
    }

    /// [`Self::sort_hierarchical`] forced through the given spill
    /// store, regardless of the budget — the deterministic, disk-free
    /// test entry (an in-memory [`crate::sorter::spill::MemoryRunStore`]
    /// makes the whole spill path reproducible and fault-injectable).
    pub fn sort_hierarchical_with_store(
        &self,
        data: &[u32],
        cfg: &HierarchicalConfig,
        store: &dyn RunStore,
    ) -> Result<HierarchicalOutput> {
        if cfg.fanout < 2 {
            return Err(anyhow!("merge fanout must be at least 2, got {}", cfg.fanout));
        }
        let n = data.len();
        let (capacity, fanout, _) = self.resolve_chunking_budgeted(n, cfg);
        if capacity < 1 {
            return Err(anyhow!("bank capacity must be positive"));
        }
        self.run_hierarchical(data, cfg.streaming, capacity, fanout, Some(store))
    }

    /// The shared pipeline body: fan out, absorb, finish. `store` picks
    /// resident vs out-of-core assembly.
    fn run_hierarchical(
        &self,
        data: &[u32],
        streaming: bool,
        capacity: usize,
        fanout: usize,
        store: Option<&dyn RunStore>,
    ) -> Result<HierarchicalOutput> {
        let n = data.len();
        let spans = partition(n, capacity);
        let mut asm = match store {
            Some(s) => ChunkAssembly::new_spilling(spans, fanout, streaming, s),
            None => ChunkAssembly::new(spans, fanout, streaming),
        };
        let chunks = asm.spans().len();

        // Fan the chunks out to the worker pool (parallel banks).
        let rxs: Vec<_> = asm
            .spans()
            .iter()
            .map(|s| self.submit(data[s.clone()].to_vec()))
            .collect::<Result<_>>()?;

        for (i, rx) in rxs.into_iter().enumerate() {
            let resp: SortResponse =
                rx.recv().map_err(|_| anyhow!("worker dropped a chunk response"))??;
            asm.absorb(i, &resp)?;
        }

        let out = asm.finish(self.config(), capacity)?;
        self.metrics.record_hierarchical(n, chunks, out.merge.cycles, out.merge.comparisons);
        Ok(out)
    }

    /// Resolve the `(bank capacity, merge fanout)` a hierarchical sort
    /// will use: fixed from the config, or auto-tuned over the service
    /// geometry with the per-size-class cycles/number observed on
    /// served traffic ([`super::planner::auto_tune`]). Ignores the
    /// spill decision — [`Self::resolve_chunking_budgeted`] adds it.
    pub fn resolve_chunking(&self, n: usize, cfg: &HierarchicalConfig) -> (usize, usize) {
        let (capacity, fanout, _) = self.resolve_chunking_budgeted(n, cfg);
        (capacity, fanout)
    }

    /// [`Self::resolve_chunking`] plus the spill decision: `(capacity,
    /// fanout, spill)`. One rule everywhere — spill iff the resident
    /// merge working set exceeds `cfg.budget` — and under
    /// [`Capacity::Auto`] the tuner re-scores candidates with the spill
    /// I/O surcharge ([`auto_tune_budgeted`]), since the surcharge
    /// shifts the bank/fanout trade-off.
    pub fn resolve_chunking_budgeted(
        &self,
        n: usize,
        cfg: &HierarchicalConfig,
    ) -> (usize, usize, bool) {
        match cfg.capacity {
            Capacity::Fixed(c) => (c, cfg.fanout, !cfg.budget.fits(resident_merge_bytes(n))),
            Capacity::Auto => {
                let snap = self.metrics.snapshot();
                auto_tune_budgeted(n, &self.config().geometry, cfg.streaming, cfg.budget, |bank| {
                    snap.cyc_per_num_for(bank, crate::params::NOMINAL_COLSKIP_CYC_PER_NUM)
                })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ServiceConfig;
    use crate::datasets::{Dataset, DatasetKind};
    use crate::sorter::merge::{model_merge_cycles, model_merge_passes};

    fn service(workers: usize) -> SortService {
        SortService::start(ServiceConfig { workers, ..Default::default() }).unwrap()
    }

    #[test]
    fn sorts_past_bank_capacity() {
        let svc = service(4);
        let cfg = HierarchicalConfig::fixed(256, 4);
        for n in [1usize, 255, 256, 257, 1000, 5000] {
            let d = Dataset::generate32(DatasetKind::MapReduce, n, 13);
            let out = svc.sort_hierarchical(&d.values, &cfg).unwrap();
            let mut expect = d.values.clone();
            expect.sort_unstable();
            assert_eq!(out.output.sorted, expect, "n={n}");
            assert_eq!(out.chunks(), n.div_ceil(256), "n={n}");
            // Global argsort maps original rows to sorted values.
            assert_eq!(out.output.order.len(), n);
            for (i, &row) in out.output.order.iter().enumerate() {
                assert_eq!(d.values[row], out.output.sorted[i], "n={n}");
            }
        }
        svc.shutdown();
    }

    #[test]
    fn work_is_sum_latency_is_critical_path() {
        let svc = service(2);
        let cfg = HierarchicalConfig::barrier(128, 2);
        let d = Dataset::generate32(DatasetKind::Clustered, 1000, 3);
        let out = svc.sort_hierarchical(&d.values, &cfg).unwrap();
        let mut summed = SortStats::default();
        let mut max_cycles = 0;
        for s in &out.chunk_stats {
            summed.merge_from(s);
            max_cycles = max_cycles.max(s.cycles());
        }
        assert_eq!(out.output.stats, summed, "stats must be the summed chunk work");
        assert!(!out.streaming);
        assert_eq!(out.latency_cycles, max_cycles + out.merge.cycles);
        assert_eq!(out.latency_cycles, out.barrier_latency_cycles);
        assert_eq!(out.max_chunk_cycles, max_cycles);
        assert_eq!(out.merge.cycles, model_merge_cycles(1000, 8, 2));
        assert_eq!(out.merge.passes, model_merge_passes(8, 2));
        assert!(out.merge.comparisons > 0);
        assert!(out.merge_fraction() > 0.0 && out.merge_fraction() < 1.0);
        // The overlap model is reported alongside and can only help.
        assert!(out.streamed_latency_cycles <= out.barrier_latency_cycles);
        assert!(out.streamed_latency_cycles >= out.max_chunk_cycles);
        svc.shutdown();
    }

    #[test]
    fn streamed_output_is_byte_identical_to_barrier() {
        let svc = service(3);
        for kind in DatasetKind::ALL {
            let d = Dataset::generate32(kind, 1500, 7);
            for (capacity, fanout) in [(64usize, 2usize), (256, 4), (2048, 4)] {
                let s = svc
                    .sort_hierarchical(&d.values, &HierarchicalConfig::fixed(capacity, fanout))
                    .unwrap();
                let b = svc
                    .sort_hierarchical(&d.values, &HierarchicalConfig::barrier(capacity, fanout))
                    .unwrap();
                assert!(s.streaming && !b.streaming);
                assert_eq!(s.output.sorted, b.output.sorted, "{kind:?} cap={capacity}");
                assert_eq!(s.output.order, b.output.order, "{kind:?} cap={capacity}");
                assert_eq!(s.output.stats, b.output.stats, "{kind:?} cap={capacity}");
                assert_eq!(s.chunk_stats, b.chunk_stats, "{kind:?} cap={capacity}");
                assert_eq!(s.merge.comparisons, b.merge.comparisons);
                assert_eq!(s.merge.passes, b.merge.passes);
                assert_eq!(s.merge.cycles, b.merge.cycles);
                // Same model numbers on both sides; streaming's critical
                // path is the overlapped one and never loses.
                assert_eq!(s.barrier_latency_cycles, b.barrier_latency_cycles);
                assert_eq!(s.streamed_latency_cycles, b.streamed_latency_cycles);
                assert_eq!(s.latency_cycles, s.streamed_latency_cycles);
                assert_eq!(b.latency_cycles, b.barrier_latency_cycles);
                assert!(s.latency_cycles <= b.latency_cycles, "{kind:?} cap={capacity}");
            }
        }
        svc.shutdown();
    }

    #[test]
    fn streaming_hides_merge_cycles_on_uneven_chunks() {
        // The last chunk of 1000 % 128 = 104 rows finishes well before
        // the full 128-row chunks, and chunk cycle counts vary with the
        // data — the frontier merges early groups inside that slack, so
        // the streamed critical path must beat the barrier by a
        // non-trivial margin on a multi-pass merge.
        let svc = service(2);
        let d = Dataset::generate32(DatasetKind::MapReduce, 1000, 3);
        let out = svc.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(128, 2)).unwrap();
        assert!(out.streaming);
        assert!(
            out.streamed_latency_cycles < out.barrier_latency_cycles,
            "{} vs {}",
            out.streamed_latency_cycles,
            out.barrier_latency_cycles
        );
        assert!(out.overlap_saving() > 0.0);
        assert!(out.merge_fraction() < 1.0);
        svc.shutdown();
    }

    #[test]
    fn bad_hierarchical_config_is_an_error_not_a_panic() {
        // Same contract as the fleet path: a bad CLI flag surfaces as
        // an Err from either entry point, never a process abort.
        let svc = service(1);
        assert!(svc.sort_hierarchical(&[3, 1, 2], &HierarchicalConfig::fixed(2, 1)).is_err());
        assert!(svc.sort_hierarchical(&[3, 1, 2], &HierarchicalConfig::fixed(0, 4)).is_err());
        svc.shutdown();
    }

    #[test]
    fn empty_input_is_trivial() {
        let svc = service(1);
        let out = svc
            .sort_hierarchical(&[], &HierarchicalConfig::default())
            .unwrap();
        assert!(out.output.sorted.is_empty());
        assert_eq!(out.chunks(), 0);
        assert_eq!(out.latency_cycles, 0);
        assert_eq!(out.throughput(), 0.0);
        svc.shutdown();
    }

    #[test]
    fn service_metrics_see_the_pipeline() {
        let svc = service(2);
        let cfg = HierarchicalConfig::fixed(64, 4);
        let d = Dataset::generate32(DatasetKind::Uniform, 300, 5);
        svc.sort_hierarchical(&d.values, &cfg).unwrap();
        let m = svc.metrics();
        assert_eq!(m.hier_completed, 1);
        assert_eq!(m.hier_elements, 300);
        assert_eq!(m.hier_chunks, 5);
        assert!(m.merge_cycles > 0);
        assert!(m.merge_comparisons > 0);
        // Chunk jobs flowed through the normal request path too.
        assert_eq!(m.completed, 5);
        svc.shutdown();
    }

    #[test]
    fn saturated_max_values_sort_exactly() {
        // A dataset saturated with *real* `u32::MAX` values through the
        // hierarchical path. Unlike `planner::execute` (which pads every
        // chunk to the full bank with MAX sentinels and meters them —
        // see `chunk_merge_meters_sentinel_work`), the pipeline sorts
        // the short last chunk unpadded: the output, the argsort and
        // the summed work stats cover exactly the n real rows.
        let svc = service(2);
        let cfg = HierarchicalConfig::fixed(64, 4);
        let mut data = vec![u32::MAX; 150];
        for (i, v) in data.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = i as u32;
            }
        }
        let out = svc.sort_hierarchical(&data, &cfg).unwrap();
        let mut expect = data.clone();
        expect.sort_unstable();
        assert_eq!(out.output.sorted, expect);
        assert_eq!(out.chunks(), 3, "64 + 64 + 22 rows");
        // The argsort is a permutation over the real rows only.
        let mut seen = vec![false; data.len()];
        for (&row, &val) in out.output.order.iter().zip(&out.output.sorted) {
            assert!(!seen[row], "row {row} emitted twice");
            seen[row] = true;
            assert_eq!(data[row], val);
        }
        assert!(seen.iter().all(|&s| s));
        // Work covers exactly n emissions — no sentinel rows anywhere.
        let mut summed = SortStats::default();
        for s in &out.chunk_stats {
            summed.merge_from(s);
        }
        assert_eq!(summed.iterations + summed.drains, 150);
        assert_eq!(out.output.stats, summed);
        svc.shutdown();
    }

    #[test]
    fn auto_capacity_matches_planner_and_beats_the_largest_bank() {
        use crate::coordinator::planner::{auto_tune, candidate};
        use crate::params::NOMINAL_COLSKIP_CYC_PER_NUM;

        let svc = service(2);
        let geo = svc.config().geometry.clone();
        let n = 3000usize;
        let d = Dataset::generate32(DatasetKind::MapReduce, n, 9);
        for streaming in [true, false] {
            let cfg = HierarchicalConfig {
                capacity: Capacity::Auto,
                fanout: 4,
                streaming,
                budget: MemoryBudget::Unbounded,
            };
            // A fresh service has served no traffic, so the tuner runs
            // on the nominal cycles/number — fully deterministic.
            let fresh = service(2);
            let (bank, fanout) = fresh.resolve_chunking(n, &cfg);
            let expect = auto_tune(n, &geo, streaming, |_| NOMINAL_COLSKIP_CYC_PER_NUM);
            assert_eq!((bank, fanout), expect, "streaming={streaming}");
            let out = fresh.sort_hierarchical(&d.values, &cfg).unwrap();
            assert_eq!(out.capacity, bank);
            assert_eq!(out.merge.fanout, fanout);
            let mut check = d.values.clone();
            check.sort_unstable();
            assert_eq!(out.output.sorted, check);
            // Regression: the largest bank must NOT win here — finer
            // chunking sorts in parallel and the merge passes are
            // cheaper than the saved in-bank cycles.
            let largest = *geo.bank_sizes.last().unwrap();
            assert_ne!(bank, largest, "streaming={streaming}");
            // And the pick really is the cheapest candidate under the
            // scoring model the mode uses.
            let score = |b: usize, f: usize| {
                let c = candidate(n, b, f);
                if streaming {
                    c.estimated_cycles_overlap(NOMINAL_COLSKIP_CYC_PER_NUM)
                } else {
                    c.estimated_cycles(NOMINAL_COLSKIP_CYC_PER_NUM)
                }
            };
            let picked = score(bank, fanout);
            for &b in &geo.bank_sizes {
                for f in [2usize, 4, 8, 16] {
                    assert!(
                        picked <= score(b, f),
                        "streaming={streaming}: ({bank},{fanout}) lost to ({b},{f})"
                    );
                }
            }
            fresh.shutdown();
        }
        svc.shutdown();
    }

    #[test]
    fn auto_capacity_uses_observed_traffic_class_costs() {
        // After serving traffic, the tuner must read the observed
        // per-class cycles/number instead of the nominal constant.
        let svc = service(2);
        let d = Dataset::generate32(DatasetKind::Uniform, 256, 4);
        svc.submit_wait(d.values.clone()).unwrap();
        let snap = svc.metrics();
        let observed = snap.cyc_per_num_for(256, crate::params::NOMINAL_COLSKIP_CYC_PER_NUM);
        assert!(observed > 0.0);
        // Uniform data is far more expensive than the nominal MapReduce
        // 7.84 — the class observation must differ from the fallback.
        assert!(
            (observed - crate::params::NOMINAL_COLSKIP_CYC_PER_NUM).abs() > 1.0,
            "{observed}"
        );
        let cfg = HierarchicalConfig {
            capacity: Capacity::Auto,
            fanout: 4,
            streaming: true,
            budget: MemoryBudget::Unbounded,
        };
        let (bank, fanout) = svc.resolve_chunking(3000, &cfg);
        let expect = crate::coordinator::planner::auto_tune(
            3000,
            &svc.config().geometry,
            true,
            |b| snap.cyc_per_num_for(b, crate::params::NOMINAL_COLSKIP_CYC_PER_NUM),
        );
        assert_eq!((bank, fanout), expect);
        svc.shutdown();
    }

    #[test]
    fn bounded_budget_spills_with_identical_output() {
        // A 5000-element sort needs 80 kB of resident merge working
        // set; a 4 KiB budget forces it out of core. Output must be
        // byte-identical to the unbounded run, with the spill visible
        // in the flags, the accounted bytes and the latency surcharge.
        // (The full DatasetKind × budget × fanout sweep lives in
        // tests/spill.rs.)
        let svc = service(2);
        let d = Dataset::generate32(DatasetKind::MapReduce, 5000, 17);
        let resident =
            svc.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(256, 4)).unwrap();
        let spilled = svc
            .sort_hierarchical(
                &d.values,
                &HierarchicalConfig::fixed(256, 4).with_budget(MemoryBudget::Bytes(4 << 10)),
            )
            .unwrap();
        assert!(!resident.spilled && spilled.spilled);
        assert_eq!(resident.spilled_bytes, 0);
        assert!(spilled.spilled_bytes > 0);
        assert_eq!(spilled.output.sorted, resident.output.sorted);
        assert_eq!(spilled.output.order, resident.output.order);
        assert_eq!(spilled.output.stats, resident.output.stats);
        assert_eq!(spilled.chunk_stats, resident.chunk_stats);
        assert_eq!(spilled.merge.comparisons, resident.merge.comparisons);
        assert_eq!(spilled.merge.passes, resident.merge.passes);
        assert_eq!(spilled.merge.cycles, resident.merge.cycles);
        // The resident latency models agree; only the critical path
        // carries the I/O surcharge.
        assert_eq!(spilled.streamed_latency_cycles, resident.streamed_latency_cycles);
        assert_eq!(spilled.barrier_latency_cycles, resident.barrier_latency_cycles);
        assert!(spilled.latency_cycles > resident.latency_cycles);
        // A budget the working set fits must stay resident.
        let roomy = svc
            .sort_hierarchical(
                &d.values,
                &HierarchicalConfig::fixed(256, 4)
                    .with_budget(MemoryBudget::Bytes(crate::sorter::spill::resident_merge_bytes(
                        5000,
                    ))),
            )
            .unwrap();
        assert!(!roomy.spilled);
        svc.shutdown();
    }

    #[test]
    fn finer_chunking_is_cheaper_silicon() {
        // Fig. 8(b) carried to the chunk dimension: the row processor
        // scales as Ns·log2(Ns), so 16 banks of 256 rows undercut 2 banks
        // of 2048 rows even with the larger merge tree.
        let svc = service(2);
        let d = Dataset::generate32(DatasetKind::MapReduce, 4096, 9);
        let coarse =
            svc.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(2048, 4)).unwrap();
        let fine = svc.sort_hierarchical(&d.values, &HierarchicalConfig::fixed(256, 4)).unwrap();
        assert!(fine.area_kum2 < coarse.area_kum2, "{} vs {}", fine.area_kum2, coarse.area_kum2);
        assert!(fine.power_mw < coarse.power_mw, "{} vs {}", fine.power_mw, coarse.power_mw);
        assert!(fine.area_kum2 > 0.0 && fine.power_mw > 0.0);
        svc.shutdown();
    }
}

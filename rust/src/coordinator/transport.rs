//! The shard transport boundary: how the fleet coordinator talks to one
//! shard host.
//!
//! [`super::shard::ShardedSortService`] used to hold a `Vec<SortService>`
//! directly, which welded the routing layer to in-process hosts. The
//! [`ShardTransport`] trait is the seam at exactly that spot: everything
//! the router needs from a host — submit a job, read its cost/metric
//! observations, crash it, restart it — expressed without naming the
//! host's implementation. The fleet code is written against the trait,
//! so a future RPC transport (a wire where the `Vec<Box<dyn
//! ShardTransport>>` is) drops in without touching routing, recovery or
//! the latency models.
//!
//! Three implementations ship today:
//!
//! * [`LocalTransport`] — the in-process host: owns a [`SortService`]
//!   behind an `RwLock` so [`ShardTransport::restart`] can replace a
//!   halted service with a fresh one from the same config (the shard
//!   *recovery* primitive; a real deployment would restart the remote
//!   process instead).
//! * [`RemoteTransport`] — the wire: speaks the [`super::wire`] frame
//!   protocol over any byte stream (a `TcpStream` against
//!   `memsort serve --shard`, or the in-memory [`super::wire::duplex`]
//!   against a [`super::shard_server::ShardServer`] in deterministic
//!   tests), preserving the dropped-reply semantics across the link.
//! * [`FlakyTransport`] — a fault-injecting wrapper for tests: a local
//!   host whose submissions can be made to fail on demand (a partition)
//!   or stall forever (a straggling host — the hedging tests' food),
//!   simulating failures the router must observe, isolate and — after
//!   [`ShardTransport::restart`] — re-admit.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};

use anyhow::{anyhow, Result};

use super::frontend::JobTag;
use super::locks::{lock_recover, read_recover, write_recover};
use super::metrics::{ServiceMetrics, Snapshot};
use super::wire::{self, Frame};
use super::{ServiceConfig, SortResponse, SortService};

/// Everything the fleet coordinator needs from one shard host. The
/// contract mirrors a crashed-host reality: [`ShardTransport::submit`]
/// fails fast when the host is down, an in-flight job on a dying host
/// surfaces as a dropped reply (the receiver's `recv` errors), and
/// [`ShardTransport::restart`] brings the host back *empty* — a
/// restarted host has lost its metric observations, exactly like a real
/// process that came back from a crash.
pub trait ShardTransport: Send + Sync {
    /// Submit one sort job; returns the response receiver. Errors when
    /// the host is down (closed channel / dead process).
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>>;

    /// Submit a sort job carrying its request-plane tag (tenant +
    /// priority). The tag is coordination metadata — the host sorts
    /// tagged and untagged jobs identically — so the default simply
    /// forwards to [`ShardTransport::submit`]; a wire transport
    /// overrides it to carry the tag in the frame
    /// ([`wire::Frame::SortJobTagged`]) so the remote host's operator
    /// view keeps the attribution.
    fn submit_tagged(
        &self,
        tag: &JobTag,
        data: Vec<u32>,
    ) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        let _ = tag;
        self.submit(data)
    }

    /// Full metrics snapshot of the host.
    fn metrics(&self) -> Snapshot;

    /// The host's observed cycles/number for `n`'s size class, with
    /// `fallback` before any traffic — the cost-aware router's input.
    /// Must be cheap: it is called once per routing decision.
    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64;

    /// The service configuration the host runs (geometry, workers, …).
    fn config(&self) -> ServiceConfig;

    /// Kill the host the way a crash would: asynchronously, leaving the
    /// handle valid for accounting. Queued work drains; later submits
    /// fail.
    fn halt(&self);

    /// Restart a halted host from its configuration. The returned host
    /// is empty: no queued work, no metric history.
    fn restart(&self) -> Result<()>;

    /// Graceful shutdown (drain, then stop). Idempotent.
    fn shutdown(&self);
}

/// Shared-ownership pass-through: a fleet can own `Arc`s of transports
/// that a test (or an operator tool) also holds, to crash or inspect a
/// host behind the router's back — exactly what a real host failure
/// looks like from the coordinator's side.
impl<T: ShardTransport + ?Sized> ShardTransport for std::sync::Arc<T> {
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        (**self).submit(data)
    }

    // Forwarded explicitly — the trait default would call *this* Arc's
    // `submit` and silently bypass an inner override (the remote
    // transport's tagged frame).
    fn submit_tagged(
        &self,
        tag: &JobTag,
        data: Vec<u32>,
    ) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        (**self).submit_tagged(tag, data)
    }

    fn metrics(&self) -> Snapshot {
        (**self).metrics()
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        (**self).cyc_per_num_for(n, fallback)
    }

    fn config(&self) -> ServiceConfig {
        (**self).config()
    }

    fn halt(&self) {
        (**self).halt();
    }

    fn restart(&self) -> Result<()> {
        (**self).restart()
    }

    fn shutdown(&self) {
        (**self).shutdown();
    }
}

/// The in-process shard host: one [`SortService`] plus the restart
/// machinery. `None` in the slot means the host is shut down; only an
/// explicit [`ShardTransport::restart`] (a host replacement) revives it.
pub struct LocalTransport {
    config: ServiceConfig,
    service: RwLock<Option<SortService>>,
}

impl LocalTransport {
    /// Start an in-process host from `config`.
    pub fn start(config: ServiceConfig) -> Result<Self> {
        let service = SortService::start(config.clone())?;
        Ok(LocalTransport { config, service: RwLock::new(Some(service)) })
    }

    fn with_service<T>(&self, f: impl FnOnce(&SortService) -> T) -> Result<T> {
        let guard = read_recover(&self.service);
        guard.as_ref().map(f).ok_or_else(|| anyhow!("shard host is shut down"))
    }
}

impl ShardTransport for LocalTransport {
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        self.with_service(|svc| svc.submit(data))?
    }

    fn metrics(&self) -> Snapshot {
        self.with_service(SortService::metrics).unwrap_or_else(|_| Snapshot::empty())
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        self.with_service(|svc| svc.cyc_per_num_for(n, fallback)).unwrap_or(fallback)
    }

    fn config(&self) -> ServiceConfig {
        self.config.clone()
    }

    fn halt(&self) {
        if let Ok(guard) = self.service.read() {
            if let Some(svc) = guard.as_ref() {
                svc.halt();
            }
        }
    }

    fn restart(&self) -> Result<()> {
        // Build the replacement before taking the write lock so a
        // failed start leaves the old (halted) host in place.
        let fresh = SortService::start(self.config.clone())?;
        let old = write_recover(&self.service).replace(fresh);
        if let Some(old) = old {
            // The halted workers exit on their own; join them off the
            // routing path so the restart does not leak threads.
            old.shutdown();
        }
        Ok(())
    }

    fn shutdown(&self) {
        let old = write_recover(&self.service).take();
        if let Some(svc) = old {
            svc.shutdown();
        }
    }
}

// ---------------------------------------------------------------------
// RemoteTransport: the wire implementation of the seam.
// ---------------------------------------------------------------------

/// How a [`RemoteTransport`] (re-)establishes its connection: a factory
/// producing a fresh [`wire::WireConn`] per call. For TCP this dials
/// the shard server's address ([`RemoteTransport::connect_tcp`]); in
/// tests it hands out [`wire::duplex`] ends served by an in-process
/// [`super::shard_server::ShardServer`]. Re-invoked on
/// [`ShardTransport::restart`], which is what makes recovery work over
/// a link that died.
pub type Connector = Box<dyn Fn() -> Result<wire::WireConn> + Send + Sync>;

enum PendingReply {
    Sort(mpsc::Sender<Result<SortResponse>>),
    Metrics(mpsc::Sender<Snapshot>),
    Control(mpsc::Sender<Result<()>>),
}

type PendingMap = Arc<Mutex<HashMap<u64, PendingReply>>>;

/// One live connection: the shared write half, the reply routing table
/// its reader thread dispatches into, and the liveness flag the reader
/// clears on exit. Dropping the link drops the write half (the peer
/// sees EOF), which unblocks the reader, which flips `alive` and
/// drains `pending` — every in-flight request observes a dropped
/// reply, exactly like an in-process worker pool dying. `alive` is
/// what keeps a *later* submit from parking a sender in a map nobody
/// will ever drain again (a TCP write into a dead peer's socket buffer
/// can succeed long before the OS reports the connection gone).
struct Link {
    writer: Arc<Mutex<wire::FrameSink>>,
    pending: PendingMap,
    alive: Arc<AtomicBool>,
}

/// The RPC shard host: a [`ShardTransport`] that reaches its
/// [`SortService`] through the [`super::wire`] protocol instead of a
/// thread boundary.
///
/// * **Pipelined** — `submit` writes one `SortJob` frame and returns
///   immediately with a receiver; a per-link reader thread routes
///   replies back by correlation id, so any number of jobs are in
///   flight at once and replies arrive in completion order.
/// * **Fail-fast** — once the link is observed dead (a write error, a
///   read error, EOF), later submits error immediately and every
///   pending receiver sees a dropped reply; the fleet's re-route path
///   cannot tell this host from a crashed in-process one.
/// * **Restart = reconnect + host restart** — [`ShardTransport::restart`]
///   closes any existing connection (a shard host accepts one
///   connection at a time, so the old link must go before a new
///   handshake can start), dials afresh through the [`Connector`],
///   re-handshakes, and sends `Restart`; only after the host
///   acknowledges is the new link installed. A failed restart leaves
///   the shard link down and known-down — the same observable state a
///   crashed host has.
/// * **Cost reads stay cheap** — [`ShardTransport::cyc_per_num_for`] is
///   called once per routing decision and must not cross the wire.
///   The transport keeps a local [`ServiceMetrics`] *mirror*, recorded
///   from every response's stats as it arrives: for the traffic this
///   coordinator routed since (re)connect, the mirror's per-class
///   cycles/number is identical to the host's own observation (the
///   stats are deterministic functions of the data), and it resets on
///   restart exactly when the host's history does.
///   [`ShardTransport::metrics`], by contrast, is a real `GetMetrics`
///   RPC — fleet snapshots report the host's own counters.
pub struct RemoteTransport {
    connector: Connector,
    link: RwLock<Option<Link>>,
    config: RwLock<ServiceConfig>,
    mirror: RwLock<Arc<ServiceMetrics>>,
    next_id: AtomicU64,
}

impl RemoteTransport {
    /// Dial the host through `connector`, handshake, and return the
    /// connected transport. Errors when the connection cannot be
    /// established or the host rejects the protocol version.
    pub fn connect(connector: Connector) -> Result<Self> {
        let mirror = Arc::new(ServiceMetrics::new());
        let (link, config) = Self::dial(&connector, Arc::clone(&mirror))?;
        Ok(RemoteTransport {
            connector,
            link: RwLock::new(Some(link)),
            config: RwLock::new(config),
            mirror: RwLock::new(mirror),
            next_id: AtomicU64::new(1),
        })
    }

    /// [`RemoteTransport::connect`] over TCP to a
    /// `memsort serve --shard` host at `addr` (`host:port`).
    pub fn connect_tcp(addr: &str) -> Result<Self> {
        let addr = addr.to_string();
        Self::connect(Box::new(move || {
            let stream = std::net::TcpStream::connect(&addr)
                .map_err(|e| anyhow!("connecting to shard {addr}: {e}"))?;
            let _ = stream.set_nodelay(true);
            let read = Box::new(stream.try_clone()?) as Box<dyn Read + Send>;
            let write = Box::new(TcpWriteHalf(stream)) as Box<dyn Write + Send>;
            Ok((read, write))
        }))
    }

    /// Establish one connection: handshake on the calling thread, then
    /// hand the read half to a reader thread that routes replies into
    /// `mirror` and the link's pending map until the connection dies.
    fn dial(connector: &Connector, mirror: Arc<ServiceMetrics>) -> Result<(Link, ServiceConfig)> {
        let (mut read, write) = connector()?;
        // The link's sink owns the encode buffer every outgoing frame
        // reuses; the handshake warms it.
        let mut write = wire::FrameSink::new(write);
        write.write_frame(0, &Frame::Hello)?;
        let (_, frame) = wire::read_frame(read.as_mut())?;
        let config = match frame {
            Frame::HelloAck(cfg) => cfg,
            Frame::ErrReply(msg) => return Err(anyhow!("shard handshake rejected: {msg}")),
            other => return Err(anyhow!("unexpected handshake frame {other:?}")),
        };
        let pending: PendingMap = Arc::new(Mutex::new(HashMap::new()));
        let alive = Arc::new(AtomicBool::new(true));
        let (routing, liveness) = (Arc::clone(&pending), Arc::clone(&alive));
        std::thread::spawn(move || reader_loop(read, routing, liveness, mirror));
        Ok((Link { writer: Arc::new(Mutex::new(write)), pending, alive }, config))
    }

    /// Send `frame` with a fresh id, registering `reply` for the
    /// answer. Fails fast when the link is down — including a link
    /// whose reader thread has exited (a dead peer can accept TCP
    /// writes into its socket buffer long after it stopped answering)
    /// — and a write error tears that same link down, never a fresh
    /// one a concurrent restart just installed.
    fn send(&self, frame: &Frame, reply: PendingReply) -> Result<u64> {
        let guard = read_recover(&self.link);
        let Some(link) = guard.as_ref() else {
            return Err(anyhow!("remote shard link is down"));
        };
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        lock_recover(&link.pending).insert(id, reply);
        // Check liveness *after* inserting: the reader flips `alive`
        // before its final drain, so either the drain removes this
        // entry (a dropped reply) or this check observes the death —
        // an entry can never outlive its reader unnoticed.
        if !link.alive.load(Ordering::Acquire) {
            lock_recover(&link.pending).remove(&id);
            return Err(anyhow!("remote shard link is down (reader exited)"));
        }
        let wrote = {
            let mut w = lock_recover(&link.writer);
            w.write_frame(id, frame)
        };
        if let Err(e) = wrote {
            lock_recover(&link.pending).remove(&id);
            let failed = Arc::clone(&link.writer);
            drop(guard);
            // Tear down the link that failed — and only that one: a
            // concurrent restart may already have installed a fresh,
            // healthy link, which this write failure says nothing
            // about.
            let mut slot = write_recover(&self.link);
            if slot.as_ref().is_some_and(|l| Arc::ptr_eq(&l.writer, &failed)) {
                *slot = None;
            }
            return Err(anyhow!("remote shard link failed: {e}"));
        }
        Ok(id)
    }

    /// Fire-and-forget control frame (`Halt`, `Shutdown`): best-effort,
    /// link errors are swallowed — the host is unreachable, which for
    /// these frames is indistinguishable from already-dead.
    fn send_control(&self, frame: &Frame) {
        if let Some(link) = read_recover(&self.link).as_ref() {
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let mut w = lock_recover(&link.writer);
            let _ = w.write_frame(id, frame);
        }
    }
}

/// The write half of a TCP wire connection. Dropping it shuts the
/// socket down both ways: a `try_clone`'d fd is only *closed* once
/// every clone drops, and the transport's reader thread keeps one —
/// without an explicit shutdown, tearing down a link would never send
/// a FIN, the server's session thread would stay parked on the dead
/// connection forever, and the transport's own reader would never see
/// the EOF that drains its pending replies. (The in-memory duplex gets
/// the same semantics from `PipeWriter::drop`.)
struct TcpWriteHalf(std::net::TcpStream);

impl Write for TcpWriteHalf {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.0.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.0.flush()
    }
}

impl Drop for TcpWriteHalf {
    fn drop(&mut self) {
        let _ = self.0.shutdown(std::net::Shutdown::Both);
    }
}

fn reader_loop(
    mut read: Box<dyn Read + Send>,
    pending: PendingMap,
    alive: Arc<AtomicBool>,
    mirror: Arc<ServiceMetrics>,
) {
    // One payload scratch for the link's lifetime: every reply frame
    // is read into it, and the fat `SortOk` arrays are decoded as
    // borrowed views so their only copy is the one handed to the
    // waiting receiver below.
    let mut scratch = Vec::new();
    loop {
        let Ok((id, view)) = wire::read_frame_view(read.as_mut(), &mut scratch) else { break };
        let slot = lock_recover(&pending).remove(&id);
        match (slot, view) {
            (Some(PendingReply::Sort(tx)), wire::FrameView::SortOk(ok)) => {
                // The single copy out of the scratch happens here, at
                // the consumer. A view whose arrays cannot materialize
                // (an order index beyond this host's usize) is a
                // broken peer: fail the connection; the drain below
                // turns the removed sender into a dropped reply.
                let Ok(resp) = ok.into_response() else { break };
                // The coordinator-side mirror of the host's cost
                // observations: same stats, same element count, so the
                // per-class cycles/number agrees with the host's own.
                mirror.record(resp.latency_us, &resp.stats, resp.sorted.len());
                let _ = tx.send(Ok(resp));
            }
            (Some(PendingReply::Sort(tx)), wire::FrameView::Owned(Frame::ErrReply(msg))) => {
                let _ = tx.send(Err(anyhow!(msg)));
            }
            // A dropped reply crosses the wire as Frame::Dropped: drop
            // the sender without sending, and the receiver's recv()
            // errors exactly like a vanished in-process worker.
            (Some(PendingReply::Sort(_)), wire::FrameView::Owned(Frame::Dropped)) => {}
            (Some(PendingReply::Metrics(tx)), wire::FrameView::Owned(Frame::MetricsReply(snap))) => {
                let _ = tx.send(snap);
            }
            (Some(PendingReply::Control(tx)), wire::FrameView::Owned(Frame::Ack)) => {
                let _ = tx.send(Ok(()));
            }
            (Some(PendingReply::Control(tx)), wire::FrameView::Owned(Frame::ErrReply(msg))) => {
                let _ = tx.send(Err(anyhow!(msg)));
            }
            // A reply for an id nobody is waiting on: an abandoned
            // request (e.g. a hedge loser whose receiver was dropped).
            // Late answers are discarded, not errors.
            (None, _) => {}
            // A reply of the wrong shape is a broken peer: fail the
            // connection rather than guess.
            (Some(_), _) => break,
        }
    }
    // Connection over. Flip liveness *before* the final drain: a
    // concurrent submit either loses its entry to the drain (a dropped
    // reply) or sees `alive == false` right after inserting and fails
    // fast — there is no window in which a sender parks forever.
    alive.store(false, Ordering::Release);
    // Every still-pending request observes a dropped reply (senders
    // drop with the map entries).
    lock_recover(&pending).clear();
}

/// Enforce the wire's job cap before writing anything: the *response*
/// frame (12 B/element with argsort) is the fat direction, and letting
/// it exceed MAX_PAYLOAD would kill the connection — and every other
/// job in flight on it.
fn check_wire_cap(len: usize) -> Result<()> {
    if len > wire::MAX_SORT_ELEMS {
        return Err(anyhow!(
            "sort job of {len} elements exceeds the wire cap of {} (submit it through \
             the hierarchical pipeline, which chunks to bank size)",
            wire::MAX_SORT_ELEMS
        ));
    }
    Ok(())
}

impl ShardTransport for RemoteTransport {
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        check_wire_cap(data.len())?;
        let (tx, rx) = mpsc::channel();
        self.send(&Frame::SortJob(data), PendingReply::Sort(tx))?;
        Ok(rx)
    }

    fn submit_tagged(
        &self,
        tag: &JobTag,
        data: Vec<u32>,
    ) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        check_wire_cap(data.len())?;
        let (tx, rx) = mpsc::channel();
        self.send(&Frame::SortJobTagged(tag.clone(), data), PendingReply::Sort(tx))?;
        Ok(rx)
    }

    fn metrics(&self) -> Snapshot {
        // A real RPC: the host's own counters. A dead link reports the
        // empty snapshot, like a dead LocalTransport; a half-dead one
        // (TCP partition with no RST yet) is bounded by a timeout so a
        // fleet snapshot can never hang on one unreachable shard.
        let (tx, rx) = mpsc::channel();
        if self.send(&Frame::GetMetrics, PendingReply::Metrics(tx)).is_err() {
            return Snapshot::empty();
        }
        rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap_or_else(|_| Snapshot::empty())
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        read_recover(&self.mirror).cyc_per_num_for(n, fallback)
    }

    fn config(&self) -> ServiceConfig {
        read_recover(&self.config).clone()
    }

    fn halt(&self) {
        self.send_control(&Frame::Halt);
    }

    fn restart(&self) -> Result<()> {
        // Close any existing connection *first*. The shard server
        // accepts concurrent connections now, so the old link would no
        // longer block a new handshake — but restart is a host
        // replacement either way: in-flight work on the old link was
        // dead, keeping the stale session around would only let its
        // late replies race the fresh ones, and a failed re-dial must
        // leave the shard down and known-down, which routing already
        // handles.
        *write_recover(&self.link) = None;
        // Dial a fresh connection and restart the host through it;
        // only a fully-acknowledged restart installs the new link (and
        // the cost mirror — the host's history is gone, so is ours).
        let mirror = Arc::new(ServiceMetrics::new());
        let (link, config) = Self::dial(&self.connector, Arc::clone(&mirror))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = mpsc::channel();
        lock_recover(&link.pending).insert(id, PendingReply::Control(tx));
        {
            let mut w = lock_recover(&link.writer);
            w.write_frame(id, &Frame::Restart)?;
        }
        rx.recv().map_err(|_| anyhow!("shard link dropped during restart"))??;
        *write_recover(&self.config) = config;
        *write_recover(&self.mirror) = mirror;
        *write_recover(&self.link) = Some(link);
        Ok(())
    }

    fn shutdown(&self) {
        self.send_control(&Frame::Shutdown);
        *write_recover(&self.link) = None;
    }
}

/// Fault-injecting transport for tests: a [`LocalTransport`] whose
/// submissions fail while the injected fault is armed — the shape of a
/// network partition (the host itself may be healthy, but the fleet
/// cannot reach it) — or stall forever while the straggler fault is
/// armed (submits are accepted and never answered: a hung host, the
/// hedging path's trigger). [`ShardTransport::restart`] clears both
/// faults *and* restarts the inner host, modelling a full host
/// replacement; stalled jobs surface as dropped replies then.
pub struct FlakyTransport {
    inner: LocalTransport,
    down: AtomicBool,
    stalled: AtomicBool,
    /// Senders of stalled jobs, kept alive so their receivers block
    /// (a reply that never comes, rather than a dropped one). Drained
    /// on restart: a replaced host drops what it was sitting on.
    parked: Mutex<Vec<mpsc::Sender<Result<SortResponse>>>>,
}

impl FlakyTransport {
    /// A healthy flaky host (faults disarmed).
    pub fn start(config: ServiceConfig) -> Result<Self> {
        Ok(FlakyTransport {
            inner: LocalTransport::start(config)?,
            down: AtomicBool::new(false),
            stalled: AtomicBool::new(false),
            parked: Mutex::new(Vec::new()),
        })
    }

    /// Arm the fault: every submit fails until [`ShardTransport::restart`].
    pub fn break_link(&self) {
        self.down.store(true, Ordering::Relaxed);
    }

    /// Whether the partition fault is armed.
    pub fn is_down(&self) -> bool {
        self.down.load(Ordering::Relaxed)
    }

    /// Arm the straggler fault: submits are accepted but never
    /// answered, until [`ShardTransport::restart`].
    pub fn stall(&self) {
        self.stalled.store(true, Ordering::Relaxed);
    }

    /// Whether the straggler fault is armed.
    pub fn is_stalled(&self) -> bool {
        self.stalled.load(Ordering::Relaxed)
    }
}

impl ShardTransport for FlakyTransport {
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        if self.is_down() {
            return Err(anyhow!("injected fault: shard link is down"));
        }
        if self.is_stalled() {
            // Accept the job and never answer: park the sender so the
            // receiver blocks like a hung host's caller would.
            let (tx, rx) = mpsc::channel();
            lock_recover(&self.parked).push(tx);
            return Ok(rx);
        }
        self.inner.submit(data)
    }

    fn metrics(&self) -> Snapshot {
        self.inner.metrics()
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        self.inner.cyc_per_num_for(n, fallback)
    }

    fn config(&self) -> ServiceConfig {
        self.inner.config()
    }

    fn halt(&self) {
        self.inner.halt();
        // Halt's contract: in-flight jobs surface as dropped replies —
        // including the ones the straggler fault was sitting on.
        lock_recover(&self.parked).clear();
    }

    fn restart(&self) -> Result<()> {
        self.inner.restart()?;
        self.down.store(false, Ordering::Relaxed);
        self.stalled.store(false, Ordering::Relaxed);
        // The replaced host drops the jobs it was sitting on: their
        // receivers observe dropped replies and the fleet re-routes.
        lock_recover(&self.parked).clear();
        Ok(())
    }

    fn shutdown(&self) {
        self.inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, DatasetKind};

    fn config() -> ServiceConfig {
        ServiceConfig { workers: 2, ..Default::default() }
    }

    #[test]
    fn local_transport_serves_and_restarts() {
        let t = LocalTransport::start(config()).unwrap();
        let d = Dataset::generate32(DatasetKind::Uniform, 64, 3);
        let rx = t.submit(d.values.clone()).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        assert_eq!(t.metrics().completed, 1);
        // Crash the host; once the workers are gone, submits fail.
        t.halt();
        while t.submit(vec![1u32]).is_ok() {
            std::thread::yield_now();
        }
        // Restart: a fresh host with *empty* metrics serves again.
        t.restart().unwrap();
        let resp = t.submit(d.values.clone()).unwrap().recv().unwrap().unwrap();
        assert_eq!(resp.sorted, expect);
        assert_eq!(t.metrics().completed, 1, "a restarted host starts from zero");
        t.shutdown();
        assert!(t.submit(vec![1u32]).is_err(), "shutdown is final");
        assert!(t.restart().is_ok(), "but an explicit restart still revives the slot");
        t.shutdown();
    }

    #[test]
    fn local_transport_cost_reader_matches_snapshot() {
        let t = LocalTransport::start(config()).unwrap();
        let d = Dataset::generate32(DatasetKind::MapReduce, 256, 5);
        t.submit(d.values).unwrap().recv().unwrap().unwrap();
        let snap = t.metrics();
        for n in [16usize, 256, 4096] {
            assert!((t.cyc_per_num_for(n, 7.84) - snap.cyc_per_num_for(n, 7.84)).abs() < 1e-12);
        }
        t.shutdown();
        assert_eq!(t.cyc_per_num_for(256, 7.84), 7.84, "a dead host falls back");
    }

    #[test]
    fn flaky_transport_fails_and_recovers_on_demand() {
        let t = FlakyTransport::start(config()).unwrap();
        assert!(t.submit(vec![3u32, 1, 2]).is_ok());
        t.break_link();
        assert!(t.is_down());
        assert!(t.submit(vec![3u32, 1, 2]).is_err(), "armed fault fails fast");
        t.restart().unwrap();
        assert!(!t.is_down());
        let resp = t.submit(vec![3u32, 1, 2]).unwrap().recv().unwrap().unwrap();
        assert_eq!(resp.sorted, vec![1, 2, 3]);
        t.shutdown();
    }

    #[test]
    fn stalled_transport_accepts_but_never_answers_until_restart() {
        let t = FlakyTransport::start(config()).unwrap();
        t.stall();
        assert!(t.is_stalled());
        let rx = t.submit(vec![3u32, 1, 2]).unwrap();
        assert!(
            rx.recv_timeout(std::time::Duration::from_millis(20)).is_err(),
            "a stalled host never answers"
        );
        // Restart replaces the host: the parked job surfaces as a
        // dropped reply and fresh submits serve normally.
        t.restart().unwrap();
        assert!(!t.is_stalled());
        assert!(
            matches!(rx.recv(), Err(mpsc::RecvError)),
            "the replaced host drops its stalled jobs"
        );
        let resp = t.submit(vec![3u32, 1, 2]).unwrap().recv().unwrap().unwrap();
        assert_eq!(resp.sorted, vec![1, 2, 3]);
        t.shutdown();
    }

    use crate::coordinator::shard_server::ShardServer;

    fn remote_pair() -> (RemoteTransport, Arc<ShardServer>) {
        let server = Arc::new(ShardServer::start(config()).unwrap());
        let connector = ShardServer::duplex_connector(Arc::clone(&server));
        let t = RemoteTransport::connect(connector).unwrap();
        (t, server)
    }

    #[test]
    fn remote_transport_sorts_and_reports_host_metrics() {
        let (t, server) = remote_pair();
        assert_eq!(t.config().workers, 2, "config comes from the handshake");
        let d = Dataset::generate32(DatasetKind::MapReduce, 256, 5);
        let resp = t.submit(d.values.clone()).unwrap().recv().unwrap().unwrap();
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        assert_eq!(resp.order.len(), d.values.len(), "the argsort crosses the wire");
        // metrics() is a real RPC: it reports the host's own counters.
        let snap = t.metrics();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.elements, 256);
        // The cost mirror agrees with the host's per-class observation.
        for n in [16usize, 256, 4096] {
            assert!(
                (t.cyc_per_num_for(n, 7.84) - server.host().cyc_per_num_for(n, 7.84)).abs()
                    < 1e-12,
                "n={n}"
            );
        }
        t.shutdown();
        assert!(t.submit(vec![1u32]).is_err(), "shutdown closes the link");
        assert_eq!(t.metrics().completed, 0, "a dead link reports the empty snapshot");
    }

    #[test]
    fn remote_transport_drops_replies_when_the_host_dies_and_restarts_empty() {
        let (t, server) = remote_pair();
        t.submit(vec![5u32, 2]).unwrap().recv().unwrap().unwrap();
        // Kill the host behind the wire's back and wait until the death
        // is observable server-side.
        server.host().halt();
        while server.host().submit(vec![1u32]).is_ok() {
            std::thread::yield_now();
        }
        // The link is still up, so submit succeeds — and the reply is
        // *dropped*, not an error: exactly the in-process semantics.
        let rx = t.submit(vec![4u32, 3]).unwrap();
        assert!(matches!(rx.recv(), Err(mpsc::RecvError)), "dropped reply crosses the wire");
        // Restart: a fresh connection, a fresh host, empty history.
        t.restart().unwrap();
        let resp = t.submit(vec![4u32, 3]).unwrap().recv().unwrap().unwrap();
        assert_eq!(resp.sorted, vec![3, 4]);
        assert_eq!(t.metrics().completed, 1, "a restarted host starts from zero");
        let (mine, hosts) = (t.cyc_per_num_for(2, 7.84), server.host().cyc_per_num_for(2, 7.84));
        assert!((mine - hosts).abs() < 1e-12, "the cost mirror reset with the host");
        t.shutdown();
    }

    #[test]
    fn tagged_submit_crosses_the_wire_and_sorts_identically() {
        use crate::coordinator::frontend::Priority;
        let (t, server) = remote_pair();
        let tag = JobTag::new("acme", Priority::Interactive);
        let d = Dataset::generate32(DatasetKind::Clustered, 128, 9);
        let tagged = t.submit_tagged(&tag, d.values.clone()).unwrap().recv().unwrap().unwrap();
        let plain = server.host().submit(d.values.clone()).unwrap().recv().unwrap().unwrap();
        assert_eq!(tagged.sorted, plain.sorted, "the tag is metadata, not execution");
        assert_eq!(tagged.order, plain.order);
        assert_eq!(t.metrics().completed, 2);
        t.shutdown();
    }

    #[test]
    fn remote_transport_pipelines_concurrent_jobs() {
        let (t, _server) = remote_pair();
        let datasets: Vec<Vec<u32>> = (0..8u64)
            .map(|seed| Dataset::generate32(DatasetKind::Uniform, 64, seed).values)
            .collect();
        let rxs: Vec<_> = datasets.iter().map(|d| t.submit(d.clone()).unwrap()).collect();
        for (d, rx) in datasets.iter().zip(rxs) {
            let resp = rx.recv().unwrap().unwrap();
            let mut expect = d.clone();
            expect.sort_unstable();
            assert_eq!(resp.sorted, expect);
        }
        assert_eq!(t.metrics().completed, 8);
        t.shutdown();
    }
}

//! The shard transport boundary: how the fleet coordinator talks to one
//! shard host.
//!
//! [`super::shard::ShardedSortService`] used to hold a `Vec<SortService>`
//! directly, which welded the routing layer to in-process hosts. The
//! [`ShardTransport`] trait is the seam at exactly that spot: everything
//! the router needs from a host — submit a job, read its cost/metric
//! observations, crash it, restart it — expressed without naming the
//! host's implementation. The fleet code is written against the trait,
//! so a future RPC transport (a wire where the `Vec<Box<dyn
//! ShardTransport>>` is) drops in without touching routing, recovery or
//! the latency models.
//!
//! Two implementations ship today:
//!
//! * [`LocalTransport`] — the in-process host: owns a [`SortService`]
//!   behind an `RwLock` so [`ShardTransport::restart`] can replace a
//!   halted service with a fresh one from the same config (the shard
//!   *recovery* primitive; a real deployment would restart the remote
//!   process instead).
//! * [`FlakyTransport`] — a fault-injecting wrapper for tests: a local
//!   host whose submissions can be made to fail on demand, simulating a
//!   network partition or a crashed host that the router must observe,
//!   isolate and — after [`ShardTransport::restart`] — re-admit.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, RwLock};

use anyhow::{anyhow, Result};

use super::metrics::Snapshot;
use super::{ServiceConfig, SortResponse, SortService};

/// Everything the fleet coordinator needs from one shard host. The
/// contract mirrors a crashed-host reality: [`ShardTransport::submit`]
/// fails fast when the host is down, an in-flight job on a dying host
/// surfaces as a dropped reply (the receiver's `recv` errors), and
/// [`ShardTransport::restart`] brings the host back *empty* — a
/// restarted host has lost its metric observations, exactly like a real
/// process that came back from a crash.
pub trait ShardTransport: Send + Sync {
    /// Submit one sort job; returns the response receiver. Errors when
    /// the host is down (closed channel / dead process).
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>>;

    /// Full metrics snapshot of the host.
    fn metrics(&self) -> Snapshot;

    /// The host's observed cycles/number for `n`'s size class, with
    /// `fallback` before any traffic — the cost-aware router's input.
    /// Must be cheap: it is called once per routing decision.
    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64;

    /// The service configuration the host runs (geometry, workers, …).
    fn config(&self) -> ServiceConfig;

    /// Kill the host the way a crash would: asynchronously, leaving the
    /// handle valid for accounting. Queued work drains; later submits
    /// fail.
    fn halt(&self);

    /// Restart a halted host from its configuration. The returned host
    /// is empty: no queued work, no metric history.
    fn restart(&self) -> Result<()>;

    /// Graceful shutdown (drain, then stop). Idempotent.
    fn shutdown(&self);
}

/// Shared-ownership pass-through: a fleet can own `Arc`s of transports
/// that a test (or an operator tool) also holds, to crash or inspect a
/// host behind the router's back — exactly what a real host failure
/// looks like from the coordinator's side.
impl<T: ShardTransport + ?Sized> ShardTransport for std::sync::Arc<T> {
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        (**self).submit(data)
    }

    fn metrics(&self) -> Snapshot {
        (**self).metrics()
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        (**self).cyc_per_num_for(n, fallback)
    }

    fn config(&self) -> ServiceConfig {
        (**self).config()
    }

    fn halt(&self) {
        (**self).halt();
    }

    fn restart(&self) -> Result<()> {
        (**self).restart()
    }

    fn shutdown(&self) {
        (**self).shutdown();
    }
}

/// The in-process shard host: one [`SortService`] plus the restart
/// machinery. `None` in the slot means the host is shut down; only an
/// explicit [`ShardTransport::restart`] (a host replacement) revives it.
pub struct LocalTransport {
    config: ServiceConfig,
    service: RwLock<Option<SortService>>,
}

impl LocalTransport {
    /// Start an in-process host from `config`.
    pub fn start(config: ServiceConfig) -> Result<Self> {
        let service = SortService::start(config.clone())?;
        Ok(LocalTransport { config, service: RwLock::new(Some(service)) })
    }

    fn with_service<T>(&self, f: impl FnOnce(&SortService) -> T) -> Result<T> {
        let guard = self.service.read().expect("transport poisoned");
        guard.as_ref().map(f).ok_or_else(|| anyhow!("shard host is shut down"))
    }
}

impl ShardTransport for LocalTransport {
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        self.with_service(|svc| svc.submit(data))?
    }

    fn metrics(&self) -> Snapshot {
        self.with_service(SortService::metrics)
            .unwrap_or_else(|_| super::metrics::ServiceMetrics::new().snapshot())
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        self.with_service(|svc| svc.cyc_per_num_for(n, fallback)).unwrap_or(fallback)
    }

    fn config(&self) -> ServiceConfig {
        self.config.clone()
    }

    fn halt(&self) {
        if let Ok(guard) = self.service.read() {
            if let Some(svc) = guard.as_ref() {
                svc.halt();
            }
        }
    }

    fn restart(&self) -> Result<()> {
        // Build the replacement before taking the write lock so a
        // failed start leaves the old (halted) host in place.
        let fresh = SortService::start(self.config.clone())?;
        let old = self
            .service
            .write()
            .expect("transport poisoned")
            .replace(fresh);
        if let Some(old) = old {
            // The halted workers exit on their own; join them off the
            // routing path so the restart does not leak threads.
            old.shutdown();
        }
        Ok(())
    }

    fn shutdown(&self) {
        let old = self.service.write().expect("transport poisoned").take();
        if let Some(svc) = old {
            svc.shutdown();
        }
    }
}

/// Fault-injecting transport for tests: a [`LocalTransport`] whose
/// submissions fail while the injected fault is armed — the shape of a
/// network partition (the host itself may be healthy, but the fleet
/// cannot reach it). [`ShardTransport::restart`] clears the fault *and*
/// restarts the inner host, modelling a full host replacement.
pub struct FlakyTransport {
    inner: LocalTransport,
    down: AtomicBool,
}

impl FlakyTransport {
    /// A healthy flaky host (fault disarmed).
    pub fn start(config: ServiceConfig) -> Result<Self> {
        Ok(FlakyTransport { inner: LocalTransport::start(config)?, down: AtomicBool::new(false) })
    }

    /// Arm the fault: every submit fails until [`ShardTransport::restart`].
    pub fn break_link(&self) {
        self.down.store(true, Ordering::Relaxed);
    }

    /// Whether the fault is armed.
    pub fn is_down(&self) -> bool {
        self.down.load(Ordering::Relaxed)
    }
}

impl ShardTransport for FlakyTransport {
    fn submit(&self, data: Vec<u32>) -> Result<mpsc::Receiver<Result<SortResponse>>> {
        if self.is_down() {
            return Err(anyhow!("injected fault: shard link is down"));
        }
        self.inner.submit(data)
    }

    fn metrics(&self) -> Snapshot {
        self.inner.metrics()
    }

    fn cyc_per_num_for(&self, n: usize, fallback: f64) -> f64 {
        self.inner.cyc_per_num_for(n, fallback)
    }

    fn config(&self) -> ServiceConfig {
        self.inner.config()
    }

    fn halt(&self) {
        self.inner.halt();
    }

    fn restart(&self) -> Result<()> {
        self.inner.restart()?;
        self.down.store(false, Ordering::Relaxed);
        Ok(())
    }

    fn shutdown(&self) {
        self.inner.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, DatasetKind};

    fn config() -> ServiceConfig {
        ServiceConfig { workers: 2, ..Default::default() }
    }

    #[test]
    fn local_transport_serves_and_restarts() {
        let t = LocalTransport::start(config()).unwrap();
        let d = Dataset::generate32(DatasetKind::Uniform, 64, 3);
        let rx = t.submit(d.values.clone()).unwrap();
        let resp = rx.recv().unwrap().unwrap();
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(resp.sorted, expect);
        assert_eq!(t.metrics().completed, 1);
        // Crash the host; once the workers are gone, submits fail.
        t.halt();
        while t.submit(vec![1u32]).is_ok() {
            std::thread::yield_now();
        }
        // Restart: a fresh host with *empty* metrics serves again.
        t.restart().unwrap();
        let resp = t.submit(d.values.clone()).unwrap().recv().unwrap().unwrap();
        assert_eq!(resp.sorted, expect);
        assert_eq!(t.metrics().completed, 1, "a restarted host starts from zero");
        t.shutdown();
        assert!(t.submit(vec![1u32]).is_err(), "shutdown is final");
        assert!(t.restart().is_ok(), "but an explicit restart still revives the slot");
        t.shutdown();
    }

    #[test]
    fn local_transport_cost_reader_matches_snapshot() {
        let t = LocalTransport::start(config()).unwrap();
        let d = Dataset::generate32(DatasetKind::MapReduce, 256, 5);
        t.submit(d.values).unwrap().recv().unwrap().unwrap();
        let snap = t.metrics();
        for n in [16usize, 256, 4096] {
            assert!((t.cyc_per_num_for(n, 7.84) - snap.cyc_per_num_for(n, 7.84)).abs() < 1e-12);
        }
        t.shutdown();
        assert_eq!(t.cyc_per_num_for(256, 7.84), 7.84, "a dead host falls back");
    }

    #[test]
    fn flaky_transport_fails_and_recovers_on_demand() {
        let t = FlakyTransport::start(config()).unwrap();
        assert!(t.submit(vec![3u32, 1, 2]).is_ok());
        t.break_link();
        assert!(t.is_down());
        assert!(t.submit(vec![3u32, 1, 2]).is_err(), "armed fault fails fast");
        t.restart().unwrap();
        assert!(!t.is_down());
        let resp = t.submit(vec![3u32, 1, 2]).unwrap().recv().unwrap().unwrap();
        assert_eq!(resp.sorted, vec![1, 2, 3]);
        t.shutdown();
    }
}

//! Sort planner: serve arrays of *arbitrary* length on fixed-geometry
//! in-memory sorters.
//!
//! A memristive bank is a fixed `N × w` cell grid; the paper evaluates a
//! length-1024 sorter. Real traffic has arbitrary lengths, so the
//! coordinator plans each request onto the hardware:
//!
//! * **Pad** — if the length is within slack of a bank size, pad with
//!   `u32::MAX` sentinels (they sort to the end and are dropped on
//!   output). Cost: the sentinels' rows still participate in CRs.
//! * **Chunk + merge** — split long arrays into bank-sized chunks
//!   ([`partition`]), sort each in its own bank (parallel in hardware, so
//!   chunk latency = max, not sum), then stream the sorted runs through a
//!   fanout-`f` loser-tree merge network
//!   ([`crate::sorter::merge::merge_runs`]).
//!
//! The planner picks the cheaper plan under the paper's cycle model and
//! executes it with any [`InMemorySorter`] factory. The full
//! out-of-bank pipeline — worker-pool chunk sorting plus aggregated
//! stats/cost — lives in [`super::hierarchical`]; this module is the
//! shared planning arithmetic. The latency arithmetic itself — the
//! event timeline every completion/deadline/makespan number derives
//! from — lives in the [`schedule`] submodule.

pub mod schedule;

use std::ops::Range;

use anyhow::{anyhow, Result};

use crate::sorter::merge::{
    apportion_chunks, merge_sorted_runs, model_merge_cycles, model_sharded_completion,
    model_streamed_completion_uniform,
};
use crate::sorter::spill::{resident_merge_bytes, MemoryBudget};
use crate::sorter::{InMemorySorter, SortStats};

use schedule::FleetSchedule;

/// Fixed hardware geometry the planner targets.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Geometry {
    /// Available bank heights (must be sorted ascending), e.g. AOT
    /// artifact sizes or physical bank heights.
    pub bank_sizes: Vec<usize>,
    /// Bit width of the banks.
    pub width: u32,
    /// Fanout of the digital merge network behind the banks.
    pub merge_fanout: usize,
}

impl Default for Geometry {
    fn default() -> Self {
        Geometry { bank_sizes: vec![16, 64, 256, 1024], width: 32, merge_fanout: 4 }
    }
}

impl Geometry {
    /// The tallest bank this geometry offers.
    pub fn largest_bank(&self) -> usize {
        self.bank_sizes.last().copied().unwrap_or(1).max(1)
    }

    /// Parse a `HEIGHTxWIDTH` shard-geometry spec (the CLI's
    /// `--shard-geometry 1024x32,512x32` entries): `HEIGHT` is the
    /// shard's tallest physical bank, `WIDTH` its cell bit width. The
    /// planner ladder keeps every default sub-bank size up to the
    /// height (plus the height itself), so auto-tuning can still pick
    /// finer chunking on that host.
    pub fn from_spec(spec: &str) -> Result<Geometry> {
        let (h, w) = spec
            .split_once(['x', 'X'])
            .ok_or_else(|| anyhow!("shard geometry `{spec}`: expected HEIGHTxWIDTH"))?;
        let height: usize =
            h.parse().map_err(|e| anyhow!("shard geometry `{spec}`: height: {e}"))?;
        let width: u32 =
            w.parse().map_err(|e| anyhow!("shard geometry `{spec}`: width: {e}"))?;
        if height == 0 {
            return Err(anyhow!("shard geometry `{spec}`: height must be at least 1"));
        }
        if width == 0 || width > 32 {
            return Err(anyhow!("shard geometry `{spec}`: width must be in 1..=32"));
        }
        let mut bank_sizes: Vec<usize> = Geometry::default()
            .bank_sizes
            .into_iter()
            .filter(|&b| b < height)
            .collect();
        bank_sizes.push(height);
        Ok(Geometry { bank_sizes, width, merge_fanout: Geometry::default().merge_fanout })
    }
}

/// Split `[0, n)` into spans of at most `capacity` rows — the bank-sized
/// chunks of the hierarchical pipeline. The last span may be short.
pub fn partition(n: usize, capacity: usize) -> Vec<Range<usize>> {
    assert!(capacity >= 1, "bank capacity must be positive");
    (0..n.div_ceil(capacity))
        .map(|c| c * capacity..((c + 1) * capacity).min(n))
        .collect()
}

/// An execution plan for one request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Plan {
    /// Sort in one bank of `bank` rows, padding with sentinels.
    Pad { bank: usize, sentinels: usize },
    /// Sort `chunks` banks of `bank` rows each (last chunk padded), then
    /// merge the sorted runs through the fanout-`fanout` merge network.
    ChunkMerge { bank: usize, chunks: usize, sentinels: usize, fanout: usize },
}

impl Plan {
    /// Estimated latency in cycles under the paper's model, assuming the
    /// per-element cost `cyc_per_num` observed on this traffic class.
    pub fn estimated_cycles(&self, cyc_per_num: f64) -> f64 {
        match *self {
            Plan::Pad { bank, .. } => bank as f64 * cyc_per_num,
            Plan::ChunkMerge { bank, chunks, fanout, .. } => {
                // Banks sort in parallel (multi-bank hardware): latency is
                // one bank sort + the merge passes over all elements.
                bank as f64 * cyc_per_num
                    + model_merge_cycles(bank * chunks, chunks, fanout) as f64
            }
        }
    }

    /// Estimated latency under the *streaming* pipeline: chunk runs
    /// arrive at `bank · cyc_per_num` (parallel banks, padded model)
    /// and the merge engine starts the moment a group of runs exists
    /// instead of barriering on every chunk. Uses the closed-form
    /// uniform-arrival model
    /// ([`model_streamed_completion_uniform`]), so scoring a candidate
    /// is O(chunks) even at millions of elements. Pads have no merge
    /// stage, so both models coincide there. Never exceeds
    /// [`Plan::estimated_cycles`].
    pub fn estimated_cycles_overlap(&self, cyc_per_num: f64) -> f64 {
        match *self {
            Plan::Pad { bank, .. } => bank as f64 * cyc_per_num,
            Plan::ChunkMerge { bank, chunks, fanout, .. } => {
                let arrival = (bank as f64 * cyc_per_num).round() as u64;
                model_streamed_completion_uniform(chunks, bank, arrival, fanout) as f64
            }
        }
    }

    /// Estimated latency of this plan executed *out of core*: the
    /// resident score (overlap or barrier per `streaming`) plus the
    /// spill I/O surcharge ([`schedule::spill_io_cycles`]) for pushing
    /// the padded stream through the spill device on every merge pass.
    /// A pad has one run (write + read-back, no merge passes). Always
    /// exceeds the resident score, so the budgeted tuner
    /// ([`auto_tune_budgeted`]) selects spill only when the memory
    /// budget forces it — never on merit.
    pub fn estimated_cycles_spill(&self, cyc_per_num: f64, streaming: bool) -> f64 {
        let resident = if streaming {
            self.estimated_cycles_overlap(cyc_per_num)
        } else {
            self.estimated_cycles(cyc_per_num)
        };
        let io = match *self {
            Plan::Pad { bank, .. } => schedule::spill_io_cycles(bank, 1, 2),
            Plan::ChunkMerge { bank, chunks, fanout, .. } => {
                schedule::spill_io_cycles(bank * chunks, chunks, fanout)
            }
        };
        resident + io as f64
    }

    /// Estimated latency on an `shards`-host fleet under the streaming
    /// pipeline: chunks are dealt round-robin, every shard drains its
    /// share through its *own* merge engine in parallel, and one
    /// top-level merge combines the shard streams
    /// ([`model_sharded_completion`]). Equals
    /// [`Plan::estimated_cycles_overlap`] exactly at `shards = 1`; a
    /// pad fits one bank on one shard, so sharding never changes it.
    pub fn estimated_cycles_sharded(&self, cyc_per_num: f64, shards: usize) -> f64 {
        match *self {
            Plan::Pad { bank, .. } => bank as f64 * cyc_per_num,
            Plan::ChunkMerge { bank, chunks, fanout, .. } => {
                let arrival = (bank as f64 * cyc_per_num).round() as u64;
                model_sharded_completion(chunks, bank, arrival, shards, fanout) as f64
            }
        }
    }

    /// Estimated latency on an `shards`-host fleet under the *barrier*
    /// schedule: one bank sort (parallel banks), the heaviest shard's
    /// local merge passes, then the cross-shard merge passes over the
    /// whole stream. Equals [`Plan::estimated_cycles`] exactly at
    /// `shards = 1` (the cross-shard stage has a single run: zero
    /// passes).
    pub fn estimated_cycles_sharded_barrier(&self, cyc_per_num: f64, shards: usize) -> f64 {
        assert!(shards >= 1, "a fleet has at least one shard");
        match *self {
            Plan::Pad { bank, .. } => bank as f64 * cyc_per_num,
            Plan::ChunkMerge { bank, chunks, fanout, .. } => {
                let shards = shards.min(chunks);
                let heaviest = chunks.div_ceil(shards);
                bank as f64 * cyc_per_num
                    + model_merge_cycles(bank * heaviest, heaviest, fanout) as f64
                    + model_merge_cycles(bank * chunks, shards, fanout) as f64
            }
        }
    }

    /// Estimated latency on a *heterogeneous* fleet, one [`ShardModel`]
    /// per healthy shard: the streamed schedule deals chunks
    /// **completion-balanced**
    /// ([`schedule::completion_balanced_deal`]) — per-shard merge
    /// serialization is folded into the deal, so the fleet is scored by
    /// when the last shard *drains*, not when its chunks arrive — every
    /// shard drains its share through its own merge engine from its own
    /// arrival cycle, and a cross-shard merge combines the streams. A
    /// pad is one bank on one host, so the cheapest shard serves it.
    /// With identical shard models this reduces exactly to
    /// [`Plan::estimated_cycles_sharded`] (`streaming = true`) /
    /// [`Plan::estimated_cycles_sharded_barrier`] (`false`) — pinned by
    /// `prop_hetero_scoring_reduces_to_uniform` and
    /// `hetero_scoring_reduces_to_uniform_models`. The legacy
    /// arrival-balanced streamed score stays callable as
    /// [`Plan::estimated_cycles_hetero_arrival_balanced`].
    pub fn estimated_cycles_hetero(&self, shards: &[ShardModel], streaming: bool) -> f64 {
        assert!(!shards.is_empty(), "a fleet has at least one shard");
        match *self {
            Plan::Pad { bank, .. } => shards
                .iter()
                .map(|s| bank as f64 * s.cyc_per_num + s.oversize as f64)
                .fold(f64::INFINITY, f64::min),
            Plan::ChunkMerge { bank, chunks, fanout, .. } => {
                if streaming {
                    FleetSchedule::completion_balanced(chunks, bank, shards, fanout).completion()
                        as f64
                } else {
                    let weights: Vec<f64> = shards.iter().map(|s| s.weight).collect();
                    let counts = apportion_chunks(chunks, &weights);
                    // Barrier fleet: every active shard barriers on its
                    // own chunks (sort + per-chunk assembly + local
                    // merge passes), then the cross-shard merge
                    // barriers on the shard streams.
                    let active = counts.iter().filter(|&&c| c > 0).count();
                    let worst = counts
                        .iter()
                        .zip(shards)
                        .filter(|(&c, _)| c > 0)
                        .map(|(&c, s)| {
                            bank as f64 * s.cyc_per_num
                                + (c as u64 * s.oversize
                                    + model_merge_cycles(bank * c, c, fanout))
                                    as f64
                        })
                        .fold(0.0f64, f64::max);
                    worst + model_merge_cycles(bank * chunks, active, fanout) as f64
                }
            }
        }
    }

    /// The pre-schedule-layer streamed hetero score: chunks dealt by
    /// reciprocal-arrival weights only
    /// ([`schedule::arrival_balanced_deal`]), merge drain ignored by
    /// the deal. Kept callable so the old EXPERIMENTS table stays
    /// reproducible and the arrival-vs-completion comparison stays
    /// pinned (`hetero_fleet_table_is_pinned`); everything that routes
    /// traffic uses [`Plan::estimated_cycles_hetero`].
    pub fn estimated_cycles_hetero_arrival_balanced(&self, shards: &[ShardModel]) -> f64 {
        assert!(!shards.is_empty(), "a fleet has at least one shard");
        match *self {
            Plan::Pad { bank, .. } => shards
                .iter()
                .map(|s| bank as f64 * s.cyc_per_num + s.oversize as f64)
                .fold(f64::INFINITY, f64::min),
            Plan::ChunkMerge { bank, chunks, fanout, .. } => {
                FleetSchedule::arrival_balanced(chunks, bank, shards, fanout).completion() as f64
            }
        }
    }
}

/// One shard's inputs to the heterogeneous fleet scoring, built per
/// `(bank, fanout)` candidate by [`shard_model`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ShardModel {
    /// Cycle at which one bank-sized chunk run exists on this shard.
    pub arrival: u64,
    /// Per-element sort cost this shard has observed for the bank's
    /// size class (pads are costed from it directly, unrounded).
    pub cyc_per_num: f64,
    /// Extra merge cycles the host pays per chunk when the candidate
    /// bank exceeds its tallest physical bank (it must assemble the
    /// oversized chunk from its own banks). 0 when the chunk fits.
    pub oversize: u64,
    /// Apportionment weight: faster shards absorb more chunks.
    pub weight: f64,
}

/// Build a shard's [`ShardModel`] for a candidate `(bank, fanout)`:
/// the arrival is `bank · cyc` rounded, plus — when the bank exceeds
/// the shard's tallest physical bank — the merge passes that host needs
/// to assemble an oversized chunk out of its own banks. `arrival`
/// covers the *first* chunk; the schedule layer
/// ([`schedule::FleetSchedule`]) charges one further `oversize` per
/// additional dealt chunk, because the assembly shares the shard's
/// serialized merge engine. The weight is
/// the reciprocal arrival, so [`apportion_chunks`] deals chunks in
/// proportion to how fast each shard produces them. With one shared
/// geometry and cost this is the uniform model's arrival exactly.
pub fn shard_model(bank: usize, fanout: usize, geo: &Geometry, cyc: f64) -> ShardModel {
    assert!(
        cyc.is_finite() && cyc >= 0.0,
        "shard cyc/num must be finite and non-negative, got {cyc}"
    );
    let largest = geo.largest_bank();
    let oversize = if bank > largest {
        model_merge_cycles(bank, bank.div_ceil(largest), fanout)
    } else {
        0
    };
    let arrival = (bank as f64 * cyc).round() as u64 + oversize;
    ShardModel { arrival, cyc_per_num: cyc, oversize, weight: 1.0 / arrival.max(1) as f64 }
}

/// Wire-byte outcome of coalescing small same-class requests into one
/// carrier sort, built by [`model_coalescing`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoalescingModel {
    /// Total wire bytes when every request travels solo (one tagged job
    /// frame plus one provenance reply each).
    pub solo_bytes: u64,
    /// Total wire bytes through one shared carrier frame pair.
    pub coalesced_bytes: u64,
    /// Number of requests folded together.
    pub requests: usize,
}

impl CoalescingModel {
    /// Bytes the carrier saves over solo submission — always
    /// `(requests − 1) · (145 + tenant_len)`: the payload bytes are
    /// conserved, only the per-request frame envelopes are folded.
    pub fn saved_bytes(&self) -> u64 {
        self.solo_bytes - self.coalesced_bytes
    }

    /// Solo-to-coalesced byte ratio (> 1 whenever two or more requests
    /// fold); the amortization factor quoted in EXPERIMENTS.md.
    pub fn amortization(&self) -> f64 {
        if self.coalesced_bytes == 0 {
            1.0
        } else {
            self.solo_bytes as f64 / self.coalesced_bytes as f64
        }
    }
}

/// Model the wire cost of the frontend's cross-request coalescing
/// ([`super::frontend::Frontend::sort_batch`]): `lens` are the element
/// counts of small same-class requests from a tenant whose name is
/// `tenant_len` bytes. Frame sizes are the pinned wire sizes
/// (`wire::tests` size pins): a tagged job frame is `33 + t + 4n`
/// bytes, a provenance `SortOk` reply is `112 + 12n`, so each request
/// costs a fixed `145 + t` envelope plus `16` bytes per element. Solo,
/// every request pays its own envelope; coalesced, one carrier pays it
/// once over the concatenated payload. Mirrored independently by
/// `python/fleet_model.py` (`§ coalescing amortization`) and quoted in
/// EXPERIMENTS.md §Concurrent request plane.
pub fn model_coalescing(lens: &[usize], tenant_len: usize) -> CoalescingModel {
    let fixed = 145 + tenant_len as u64;
    let total: u64 = lens.iter().map(|&n| n as u64).sum();
    let solo: u64 = lens.iter().map(|&n| fixed + 16 * n as u64).sum();
    let coalesced = if lens.is_empty() { 0 } else { fixed + 16 * total };
    CoalescingModel { solo_bytes: solo, coalesced_bytes: coalesced, requests: lens.len() }
}

/// Merge fanouts the auto-tuner enumerates (a hardware fanout-f merge
/// unit is an `f·log2 f` comparator tree; past 16 the silicon cost of a
/// unit outgrows the pass savings on realistic chunk counts).
pub const FANOUT_CANDIDATES: [usize; 4] = [2, 4, 8, 16];

/// Auto-tune the hierarchical pipeline's chunking: enumerate every
/// `(bank, fanout)` candidate over the geometry's bank sizes and
/// [`FANOUT_CANDIDATES`], score each with the barrier or overlap
/// latency model at the per-bank-class observed cost `cyc_for(bank)`,
/// and return the cheapest `(bank, fanout)` pair. Ties prefer larger
/// banks (fewer chunks, less merge silicon) and smaller fanouts.
pub fn auto_tune(
    n: usize,
    geo: &Geometry,
    streaming: bool,
    cyc_for: impl FnMut(usize) -> f64,
) -> (usize, usize) {
    auto_tune_sharded(n, geo, 1, streaming, cyc_for)
}

/// [`auto_tune`] with a shard dimension: score every `(bank, fanout)`
/// candidate for an `shards`-host fleet
/// ([`Plan::estimated_cycles_sharded`] /
/// [`Plan::estimated_cycles_sharded_barrier`]) and return the cheapest
/// pair. At `shards = 1` the scoring models reduce exactly to the
/// unsharded ones, so this *is* [`auto_tune`] then — the shard count
/// only reshapes the merge side of the objective (per-shard engines
/// drain in parallel; the cross-shard tree adds passes past
/// `shards > fanout`).
pub fn auto_tune_sharded(
    n: usize,
    geo: &Geometry,
    shards: usize,
    streaming: bool,
    mut cyc_for: impl FnMut(usize) -> f64,
) -> (usize, usize) {
    assert!(shards >= 1, "a fleet has at least one shard");
    let fallback_fanout = geo.merge_fanout.max(2);
    let largest = *geo.bank_sizes.last().expect("geometry has banks");
    if n == 0 {
        return (largest, fallback_fanout);
    }
    let mut fanouts: Vec<usize> = FANOUT_CANDIDATES.to_vec();
    if !fanouts.contains(&fallback_fanout) {
        fanouts.push(fallback_fanout);
    }
    let mut best: Option<(usize, usize, f64)> = None;
    for &bank in geo.bank_sizes.iter().rev() {
        let cyc = cyc_for(bank);
        assert!(
            cyc.is_finite() && cyc >= 0.0,
            "cyc_for({bank}) must be finite and non-negative, got {cyc}"
        );
        for &fanout in &fanouts {
            let cand = candidate(n, bank, fanout);
            let cost = if streaming {
                cand.estimated_cycles_sharded(cyc, shards)
            } else {
                cand.estimated_cycles_sharded_barrier(cyc, shards)
            };
            if best.is_none_or(|(.., c)| cost < c) {
                best = Some((bank, fanout, cost));
            }
            if bank >= n {
                break; // a pad has no merge stage: fanout is irrelevant
            }
        }
    }
    let (bank, fanout, _) = best.expect("geometry has banks");
    (bank, fanout)
}

/// Streamed completion of the *spilled* uniform merge — the planner's
/// public face of [`schedule::spill_completion`]: the resident uniform
/// closed form plus the serialize/deserialize surcharge of pushing
/// every run through the spill device on each pass. Mirrored with hard
/// pins by `fleet_model.model_spill_completion` (the EXPERIMENTS
/// §Out-of-core spill crossover table).
pub fn model_spill_completion(chunks: usize, bank: usize, arrival: u64, fanout: usize) -> u64 {
    schedule::spill_completion(chunks, bank, arrival, fanout)
}

/// [`auto_tune`] under a [`MemoryBudget`]: returns `(bank, fanout,
/// spill)`. The spill decision is the one rule used everywhere — spill
/// iff the resident merge working set ([`resident_merge_bytes`])
/// exceeds the budget — and is *not* part of the enumeration: spill
/// always costs extra I/O ([`Plan::estimated_cycles_spill`] > the
/// resident score), so enumerating it would never pick it and a
/// bounded budget must force it instead. Within the forced-spill
/// regime the usual `(bank, fanout)` enumeration re-runs against the
/// spilled scores, because the surcharge shifts the trade-off (higher
/// fanout ⇒ fewer passes ⇒ fewer device crossings).
pub fn auto_tune_budgeted(
    n: usize,
    geo: &Geometry,
    streaming: bool,
    budget: MemoryBudget,
    mut cyc_for: impl FnMut(usize) -> f64,
) -> (usize, usize, bool) {
    if budget.fits(resident_merge_bytes(n)) {
        let (bank, fanout) = auto_tune(n, geo, streaming, cyc_for);
        return (bank, fanout, false);
    }
    // Forced spill: same candidate set, iteration order and tie-breaks
    // as auto_tune, scored with the spill surcharge.
    let fallback_fanout = geo.merge_fanout.max(2);
    let mut fanouts: Vec<usize> = FANOUT_CANDIDATES.to_vec();
    if !fanouts.contains(&fallback_fanout) {
        fanouts.push(fallback_fanout);
    }
    let mut best: Option<(usize, usize, f64)> = None;
    for &bank in geo.bank_sizes.iter().rev() {
        let cyc = cyc_for(bank);
        assert!(
            cyc.is_finite() && cyc >= 0.0,
            "cyc_for({bank}) must be finite and non-negative, got {cyc}"
        );
        for &fanout in &fanouts {
            let cost = candidate(n, bank, fanout).estimated_cycles_spill(cyc, streaming);
            if best.is_none_or(|(.., c)| cost < c) {
                best = Some((bank, fanout, cost));
            }
            if bank >= n {
                break; // a pad has no merge stage: fanout is irrelevant
            }
        }
    }
    let (bank, fanout, _) = best.expect("geometry has banks");
    (bank, fanout, true)
}

/// [`auto_tune_sharded`] for a *heterogeneous* fleet: one [`Geometry`]
/// per healthy shard, and `cyc_for(shard, bank)` the per-shard observed
/// cost for the bank's size class. Candidates are enumerated over the
/// union of every shard's bank ladder and scored with
/// [`Plan::estimated_cycles_hetero`] over the per-shard models
/// ([`shard_model`]), so geometry diversity shapes both where chunks go
/// (completion-balanced deal — merge silicon is in the objective, per
/// [`schedule::completion_balanced_deal`]) and what chunk size wins
/// (oversize penalty on undersized hosts). When every shard shares one geometry and cost
/// function, the candidate set, scores, iteration order and tie-breaks
/// all coincide with the uniform tuner, so the pick is *identical* to
/// `auto_tune_sharded(n, geo, geos.len(), …)` — pinned by
/// `auto_tune_hetero_reduces_to_uniform`.
pub fn auto_tune_hetero(
    n: usize,
    geos: &[Geometry],
    streaming: bool,
    mut cyc_for: impl FnMut(usize, usize) -> f64,
) -> (usize, usize) {
    assert!(!geos.is_empty(), "a fleet has at least one shard");
    let fallback_fanout = geos.iter().map(|g| g.merge_fanout).max().unwrap_or(2).max(2);
    // Candidate banks: the union of every shard's ladder.
    let mut banks: Vec<usize> = geos.iter().flat_map(|g| g.bank_sizes.iter().copied()).collect();
    banks.sort_unstable();
    banks.dedup();
    let largest = *banks.last().expect("geometry has banks");
    if n == 0 {
        return (largest, fallback_fanout);
    }
    let mut fanouts: Vec<usize> = FANOUT_CANDIDATES.to_vec();
    if !fanouts.contains(&fallback_fanout) {
        fanouts.push(fallback_fanout);
    }
    let mut best: Option<(usize, usize, f64)> = None;
    for &bank in banks.iter().rev() {
        let cycs: Vec<f64> = (0..geos.len()).map(|s| cyc_for(s, bank)).collect();
        for &fanout in &fanouts {
            let models: Vec<ShardModel> =
                geos.iter().zip(&cycs).map(|(g, &c)| shard_model(bank, fanout, g, c)).collect();
            let cost = candidate(n, bank, fanout).estimated_cycles_hetero(&models, streaming);
            if best.is_none_or(|(.., c)| cost < c) {
                best = Some((bank, fanout, cost));
            }
            if bank >= n {
                break; // a pad has no merge stage: fanout is irrelevant
            }
        }
    }
    let (bank, fanout, _) = best.expect("geometry has banks");
    (bank, fanout)
}

/// The candidate plan a request of length `n` gets on a bank of `bank`
/// rows: pad into one bank when it fits, otherwise chunk-and-merge.
pub fn candidate(n: usize, bank: usize, fanout: usize) -> Plan {
    assert!(n > 0 && bank > 0);
    if bank >= n {
        Plan::Pad { bank, sentinels: bank - n }
    } else {
        let chunks = n.div_ceil(bank);
        Plan::ChunkMerge { bank, chunks, sentinels: chunks * bank - n, fanout }
    }
}

/// Plan a request of length `n` onto the geometry: every bank size is a
/// candidate (pad if it fits, chunk + merge otherwise) and the cheapest
/// under [`Plan::estimated_cycles`] at the observed `cyc_per_num` wins.
/// Banks sort in parallel, so on cheap-per-element traffic a *smaller*
/// bank often beats the largest one: more chunks cost only merge passes,
/// while the per-bank sort latency shrinks linearly.
pub fn plan(n: usize, geo: &Geometry, cyc_per_num: f64) -> Plan {
    assert!(n > 0, "cannot plan an empty sort");
    assert!(
        cyc_per_num.is_finite() && cyc_per_num >= 0.0,
        "cyc_per_num must be finite and non-negative, got {cyc_per_num}"
    );
    let fanout = geo.merge_fanout.max(2);
    // Chunked candidates largest bank first, so a cost tie prefers fewer
    // chunks (less merge silicon).
    let mut best: Option<(Plan, f64)> = None;
    for &bank in geo.bank_sizes.iter().rev().filter(|&&b| b < n) {
        let cand = candidate(n, bank, fanout);
        let cost = cand.estimated_cycles(cyc_per_num);
        if best.as_ref().is_none_or(|(_, c)| cost < *c) {
            best = Some((cand, cost));
        }
    }
    // Only the smallest fitting bank can be the best pad (cost and
    // silicon both grow with the bank); scored with `<=` so a cost tie
    // prefers the simplest hardware (one bank, no merge network).
    if let Some(&bank) = geo.bank_sizes.iter().find(|&&b| b >= n) {
        let cand = candidate(n, bank, fanout);
        let cost = cand.estimated_cycles(cyc_per_num);
        if best.as_ref().is_none_or(|(_, c)| cost <= *c) {
            best = Some((cand, cost));
        }
    }
    best.expect("geometry has banks").0
}

/// Execute a plan with a sorter factory (`make(bank_size)` builds the
/// sorter for one bank). Returns the sorted values and aggregate stats;
/// `stats.crs`/`cycles` follow the plan's latency semantics (parallel
/// banks: max over chunks; merge passes added on top).
///
/// ## Sentinel accounting (vs the hierarchical pipeline)
///
/// This models *fixed-geometry hardware*: every chunk is padded to the
/// full `bank` rows with `u32::MAX` sentinels, and the sentinel rows
/// participate in (and are metered by) the traversal — exactly what a
/// physical bank would do. `SortService::sort_hierarchical` instead
/// sorts the short last chunk *unpadded* (its worker receives only the
/// real elements), so its summed work stats carry no sentinel work.
/// The two paths therefore agree on the sorted output but deliberately
/// differ in summed work: `execute`'s iterations + drains equal
/// `chunks · bank`, the hierarchical pipeline's equal `n`. Both
/// behaviors are pinned by tests (`chunk_merge_meters_sentinel_work`
/// here, `saturated_max_values_sort_exactly` in `hierarchical`).
pub fn execute<S: InMemorySorter>(
    data: &[u32],
    p: &Plan,
    mut make: impl FnMut(usize) -> S,
) -> (Vec<u32>, SortStats) {
    match *p {
        Plan::Pad { bank, sentinels } => {
            let mut padded = data.to_vec();
            padded.resize(bank, u32::MAX);
            let mut s = make(bank);
            let out = s.sort_with_stats(&padded);
            let mut sorted = out.sorted;
            sorted.truncate(bank - sentinels);
            (sorted, out.stats)
        }
        Plan::ChunkMerge { bank, chunks, fanout, .. } => {
            let mut runs: Vec<Vec<u32>> = Vec::with_capacity(chunks);
            let mut agg = SortStats::default();
            let mut max_cycles = 0u64;
            for span in partition(data.len(), bank) {
                let mut chunk = data[span].to_vec();
                chunk.resize(bank, u32::MAX);
                let mut s = make(bank);
                let out = s.sort_with_stats(&chunk);
                max_cycles = max_cycles.max(out.stats.cycles());
                agg.merge_from(&out.stats);
                runs.push(out.sorted);
            }
            // k-way merge of the sorted runs through the loser tree.
            let mut sorted = merge_sorted_runs(runs, fanout).merged;
            sorted.truncate(data.len());
            // Parallel-bank latency: only the slowest chunk counts, plus
            // the merge network passes. Reflect that in the aggregate by
            // replacing crs with the latency-equivalent count.
            let mut latency_stats = agg.clone();
            latency_stats.crs = max_cycles + model_merge_cycles(bank * chunks, chunks, fanout);
            latency_stats.drains = 0;
            (sorted, latency_stats)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{Dataset, DatasetKind};
    use crate::sorter::colskip::ColSkipSorter;

    fn geo() -> Geometry {
        Geometry::default()
    }

    #[test]
    fn smallest_fitting_bank_wins_when_chunking_cannot() {
        // No bank is smaller than n=10, so the only candidates are pads;
        // the smallest fitting bank costs least.
        assert_eq!(plan(10, &geo(), 8.0), Plan::Pad { bank: 16, sentinels: 6 });
        assert_eq!(plan(16, &geo(), 8.0), Plan::Pad { bank: 16, sentinels: 0 });
    }

    #[test]
    fn chunking_into_a_smaller_bank_beats_padding_up() {
        // n=17 at 8 cyc/num: Pad{64} = 512 cycles, but two 16-row banks
        // sort in parallel (128) plus one merge pass over 32 padded
        // elements = 160 cycles. The planner must pick the cheap one.
        let p = plan(17, &geo(), 8.0);
        assert_eq!(p, Plan::ChunkMerge { bank: 16, chunks: 2, sentinels: 15, fanout: 4 });
        assert!(
            p.estimated_cycles(8.0) < Plan::Pad { bank: 64, sentinels: 47 }.estimated_cycles(8.0)
        );
    }

    #[test]
    fn smaller_bank_wins_past_the_largest_bank() {
        // Regression for the dead cost hook: n=3000 at 8 cyc/num. The old
        // planner always chunked into the largest bank (1024: 8192 sort +
        // 3072 merge = 11264); 12 chunks of 256 cost 2048 + 6144 = 8192.
        let p = plan(3000, &geo(), 8.0);
        assert_eq!(p, Plan::ChunkMerge { bank: 256, chunks: 12, sentinels: 72, fanout: 4 });
        let largest = Plan::ChunkMerge { bank: 1024, chunks: 3, sentinels: 72, fanout: 4 };
        assert!(p.estimated_cycles(8.0) < largest.estimated_cycles(8.0));
    }

    #[test]
    fn cheap_traffic_prefers_the_largest_bank() {
        // When the per-element sort cost is tiny, merge passes dominate
        // and the largest bank (fewest chunks, fewest passes) wins.
        let p = plan(3000, &geo(), 0.1);
        assert_eq!(p, Plan::ChunkMerge { bank: 1024, chunks: 3, sentinels: 72, fanout: 4 });
    }

    #[test]
    fn zero_cost_traffic_still_pads_into_the_smallest_fit() {
        // Degenerate cyc_per_num = 0: every pad candidate ties at zero
        // cost; the tie-break must pick the smallest fitting bank, and
        // padding (no merge network) must beat zero-sort-cost chunking.
        assert_eq!(plan(10, &geo(), 0.0), Plan::Pad { bank: 16, sentinels: 6 });
        assert_eq!(plan(17, &geo(), 0.0), Plan::Pad { bank: 64, sentinels: 47 });
    }

    #[test]
    fn plan_always_picks_the_cheapest_candidate() {
        // Exhaustive cross-check of plan() against brute-force scoring.
        for n in [1usize, 10, 17, 100, 1000, 3000, 10_000] {
            for cyc in [0.5, 2.0, 8.0, 32.0] {
                let picked = plan(n, &geo(), cyc).estimated_cycles(cyc);
                for &bank in &geo().bank_sizes {
                    let cand = candidate(n, bank, 4).estimated_cycles(cyc);
                    assert!(picked <= cand, "n={n} cyc={cyc} bank={bank}");
                }
            }
        }
    }

    #[test]
    fn partition_covers_range_without_overlap() {
        for (n, cap) in [(0usize, 4usize), (1, 4), (4, 4), (5, 4), (1000, 64), (7, 1)] {
            let spans = partition(n, cap);
            assert_eq!(spans.len(), n.div_ceil(cap), "n={n} cap={cap}");
            let mut covered = 0;
            for s in &spans {
                assert_eq!(s.start, covered, "contiguous");
                assert!(s.len() <= cap && !s.is_empty());
                covered = s.end;
            }
            assert_eq!(covered, n);
        }
    }

    #[test]
    fn pad_execution_drops_sentinels() {
        let data = vec![9u32, 1, 5];
        let p = plan(data.len(), &geo(), 8.0);
        let (sorted, _) = execute(&data, &p, |_| ColSkipSorter::with_k(2));
        assert_eq!(sorted, vec![1, 5, 9]);
    }

    #[test]
    fn chunk_merge_sorts_arbitrary_lengths() {
        for n in [1025usize, 2048, 2500, 5000] {
            let d = Dataset::generate32(DatasetKind::Kruskal, n, 3);
            let p = plan(n, &geo(), 8.0);
            let (sorted, stats) = execute(&d.values, &p, |_| ColSkipSorter::with_k(2));
            let mut expect = d.values.clone();
            expect.sort_unstable();
            assert_eq!(sorted, expect, "n={n}");
            assert!(stats.cycles() > 0);
        }
    }

    #[test]
    fn chunk_latency_is_max_plus_merge() {
        let n = 2048;
        let d = Dataset::generate32(DatasetKind::Uniform, n, 3);
        let p = plan(n, &geo(), 8.0);
        let Plan::ChunkMerge { bank, chunks, fanout, .. } = p else {
            panic!("2048 elements cannot pad into one bank: {p:?}");
        };
        let (_, stats) = execute(&d.values, &p, |_| ColSkipSorter::with_k(2));
        // Latency must be far below `chunks` sequential bank sorts
        // (banks are parallel): bounded by one worst bank (≤ 32·bank)
        // plus the merge passes over the padded stream.
        assert!(
            stats.cycles() <= 32 * bank as u64 + model_merge_cycles(bank * chunks, chunks, fanout),
            "{}",
            stats.cycles()
        );
    }

    #[test]
    fn chunk_merge_meters_sentinel_work() {
        // Fixed-geometry honesty: execute() pads every chunk to the full
        // bank, so sentinel rows are metered — iterations + drains equal
        // the padded `chunks · bank`, not n. (The hierarchical pipeline
        // sorts the short chunk unpadded and reports exactly n; see
        // `hierarchical::tests::saturated_max_values_sort_exactly`.)
        use crate::sorter::InMemorySorter;
        let n = 1025usize;
        let d = Dataset::generate32(DatasetKind::MapReduce, n, 21);
        let p = Plan::ChunkMerge { bank: 1024, chunks: 2, sentinels: 1023, fanout: 4 };
        let (sorted, stats) = execute(&d.values, &p, |_| ColSkipSorter::with_k(2));
        let mut expect = d.values.clone();
        expect.sort_unstable();
        assert_eq!(sorted, expect);
        // Reference: sort the two padded chunks by hand. Every padded
        // chunk emits the full bank (real rows + sentinels).
        let mut manual = SortStats::default();
        for span in partition(n, 1024) {
            let mut chunk = d.values[span].to_vec();
            chunk.resize(1024, u32::MAX);
            manual.merge_from(&ColSkipSorter::with_k(2).sort_with_stats(&chunk).stats);
        }
        assert_eq!(manual.iterations + manual.drains, 2048, "sentinel rows are metered");
        // execute() rewrites crs/drains into the latency view but keeps
        // the itemized work fields — they must carry the sentinel work.
        assert_eq!(stats.iterations, manual.iterations);
        assert_eq!(stats.res, manual.res);
        assert_eq!(stats.sls, manual.sls);
        assert_eq!(stats.srs, manual.srs);
    }

    #[test]
    fn sentinel_values_survive_real_max_entries() {
        // Data containing u32::MAX must not be truncated away.
        let data = vec![u32::MAX, 5, u32::MAX];
        let p = plan(data.len(), &geo(), 8.0);
        let (sorted, _) = execute(&data, &p, |_| ColSkipSorter::with_k(2));
        assert_eq!(sorted, vec![5, u32::MAX, u32::MAX]);
    }

    #[test]
    fn overlap_model_never_exceeds_barrier_model() {
        for n in [100usize, 1025, 3000, 50_000] {
            for bank in [16usize, 64, 256, 1024] {
                for fanout in [2usize, 4, 16] {
                    let c = candidate(n, bank, fanout);
                    for cyc in [0.5, 7.84, 32.0] {
                        // +0.5 covers the overlap model's integer
                        // rounding of the arrival time.
                        assert!(
                            c.estimated_cycles_overlap(cyc) <= c.estimated_cycles(cyc) + 0.5,
                            "n={n} bank={bank} fanout={fanout} cyc={cyc}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn auto_tune_picks_the_cheapest_bank_fanout_pair() {
        let geo = Geometry::default();
        // At the nominal 7.84 cyc/num, 12 chunks of 256 through one
        // fanout-16 pass beat every other pair — including every plan
        // on the largest bank (the PR-1 behavior).
        assert_eq!(auto_tune(3000, &geo, false, |_| 7.84), (256, 16));
        assert_eq!(auto_tune(3000, &geo, true, |_| 7.84), (256, 16));
        // Degenerate sizes.
        assert_eq!(auto_tune(0, &geo, true, |_| 7.84), (1024, 4));
        let (bank, _) = auto_tune(10, &geo, true, |_| 7.84);
        assert_eq!(bank, 16, "smallest fitting pad wins for tiny requests");
        // Per-class observed costs steer the pick: when small banks are
        // expensive on this traffic class, the largest bank wins.
        let (bank, _) = auto_tune(3000, &geo, false, |b| if b <= 256 { 1000.0 } else { 0.1 });
        assert_eq!(bank, 1024);
    }

    #[test]
    fn sharded_scoring_reduces_to_unsharded_at_one_shard() {
        for n in [10usize, 17, 1025, 3000, 50_000] {
            for bank in [16usize, 256, 1024] {
                for fanout in [2usize, 4, 16] {
                    let c = candidate(n.max(1), bank, fanout);
                    for cyc in [0.5, 7.84, 32.0] {
                        assert_eq!(
                            c.estimated_cycles_sharded(cyc, 1),
                            c.estimated_cycles_overlap(cyc),
                            "n={n} bank={bank} fanout={fanout} cyc={cyc}"
                        );
                        assert_eq!(
                            c.estimated_cycles_sharded_barrier(cyc, 1),
                            c.estimated_cycles(cyc),
                            "n={n} bank={bank} fanout={fanout} cyc={cyc}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sharded_overlap_latency_strictly_decreases_at_1m() {
        // The acceptance criterion: at n = 1M the planner's overlap
        // scoring must strictly improve from 1 to 4 shards (bank 1024,
        // fanout 4, nominal 7.84 cyc/num).
        let c = candidate(1_000_000, 1024, 4);
        let lat: Vec<f64> = (1..=4).map(|s| c.estimated_cycles_sharded(7.84, s)).collect();
        assert!(
            lat.windows(2).all(|w| w[1] < w[0]),
            "sharded latency must strictly decrease 1 -> 4 shards: {lat:?}"
        );
        // Pads are one-bank plans: sharding cannot change their score.
        let pad = candidate(10, 16, 4);
        assert_eq!(pad.estimated_cycles_sharded(7.84, 4), pad.estimated_cycles_overlap(7.84));
        assert_eq!(
            pad.estimated_cycles_sharded_barrier(7.84, 4),
            pad.estimated_cycles(7.84)
        );
    }

    #[test]
    fn auto_tune_sharded_matches_brute_force() {
        let geo = Geometry::default();
        for shards in [1usize, 2, 4, 8] {
            for streaming in [true, false] {
                let (bank, fanout) = auto_tune_sharded(50_000, &geo, shards, streaming, |_| 7.84);
                let score = |b: usize, f: usize| {
                    let c = candidate(50_000, b, f);
                    if streaming {
                        c.estimated_cycles_sharded(7.84, shards)
                    } else {
                        c.estimated_cycles_sharded_barrier(7.84, shards)
                    }
                };
                let picked = score(bank, fanout);
                for &b in &geo.bank_sizes {
                    for f in FANOUT_CANDIDATES {
                        assert!(
                            picked <= score(b, f),
                            "shards={shards} streaming={streaming}: \
                             ({bank},{fanout}) lost to ({b},{f})"
                        );
                    }
                }
            }
        }
        // shards = 1 is auto_tune itself.
        assert_eq!(
            auto_tune_sharded(3000, &geo, 1, true, |_| 7.84),
            auto_tune(3000, &geo, true, |_| 7.84)
        );
    }

    #[test]
    fn budgeted_tuner_spills_only_when_the_budget_is_exceeded() {
        // The acceptance criterion: auto_tune selects spill only when
        // the modelled budget is exceeded. The working set is 16 B per
        // element, so the threshold is exact.
        let geo = Geometry::default();
        let n = 3000usize;
        let threshold = resident_merge_bytes(n); // 48_000
        assert_eq!(threshold, 48_000);
        for streaming in [true, false] {
            // Unbounded and at-threshold budgets stay resident and pick
            // exactly what auto_tune picks.
            for budget in [MemoryBudget::Unbounded, MemoryBudget::Bytes(threshold)] {
                let (bank, fanout, spill) = auto_tune_budgeted(n, &geo, streaming, budget, |_| 7.84);
                assert!(!spill, "budget {budget} fits: must not spill");
                assert_eq!((bank, fanout), auto_tune(n, &geo, streaming, |_| 7.84));
            }
            // One byte under the working set forces spill.
            let (.., spill) = auto_tune_budgeted(
                n,
                &geo,
                streaming,
                MemoryBudget::Bytes(threshold - 1),
                |_| 7.84,
            );
            assert!(spill, "budget below the working set must spill");
        }
    }

    #[test]
    fn budgeted_tuner_matches_brute_force_under_spill() {
        let geo = Geometry::default();
        for streaming in [true, false] {
            for n in [1025usize, 3000, 50_000] {
                let (bank, fanout, spill) =
                    auto_tune_budgeted(n, &geo, streaming, MemoryBudget::Bytes(64 << 10), |_| 7.84);
                if !spill {
                    assert!(resident_merge_bytes(n) <= 64 << 10);
                    continue;
                }
                let picked = candidate(n, bank, fanout).estimated_cycles_spill(7.84, streaming);
                for &b in &geo.bank_sizes {
                    for f in FANOUT_CANDIDATES {
                        assert!(
                            picked <= candidate(n, b, f).estimated_cycles_spill(7.84, streaming),
                            "n={n} streaming={streaming}: ({bank},{fanout}) lost to ({b},{f})"
                        );
                    }
                }
            }
        }
        // Degenerate n: resident (an empty working set fits any budget).
        let (bank, fanout, spill) =
            auto_tune_budgeted(0, &geo, true, MemoryBudget::Bytes(0), |_| 7.84);
        assert_eq!((bank, fanout, spill), (1024, 4, false));
    }

    #[test]
    fn spill_scoring_always_exceeds_resident_scoring() {
        // Spill is never selected on merit: its score strictly exceeds
        // the matching resident score for every candidate shape.
        for n in [10usize, 1025, 3000, 50_000] {
            for bank in [16usize, 256, 1024] {
                for fanout in [2usize, 4, 16] {
                    let c = candidate(n, bank, fanout);
                    for streaming in [true, false] {
                        let resident = if streaming {
                            c.estimated_cycles_overlap(7.84)
                        } else {
                            c.estimated_cycles(7.84)
                        };
                        assert!(
                            c.estimated_cycles_spill(7.84, streaming) > resident,
                            "n={n} bank={bank} fanout={fanout} streaming={streaming}"
                        );
                    }
                }
            }
        }
        // The wrapper is the schedule-layer model, verbatim.
        assert_eq!(model_spill_completion(977, 1024, 8028, 4), 20_014_940);
        assert_eq!(
            model_spill_completion(977, 1024, 8028, 4),
            schedule::spill_completion(977, 1024, 8028, 4)
        );
    }

    #[test]
    fn hetero_scoring_reduces_to_uniform_models() {
        // Identical shard models = the uniform fleet scoring, exactly,
        // for both schedules, across shapes (incl. shards > chunks).
        for n in [10usize, 17, 1025, 3000, 50_000] {
            for bank in [16usize, 256, 1024] {
                for fanout in [2usize, 4, 16] {
                    let c = candidate(n, bank, fanout);
                    for cyc in [0.5, 7.84, 32.0] {
                        for shards in [1usize, 2, 4, 8] {
                            let models =
                                vec![shard_model(bank, fanout, &Geometry::default(), cyc); shards];
                            assert_eq!(
                                c.estimated_cycles_hetero(&models, true),
                                c.estimated_cycles_sharded(cyc, shards),
                                "n={n} bank={bank} fanout={fanout} cyc={cyc} shards={shards}"
                            );
                            assert_eq!(
                                c.estimated_cycles_hetero(&models, false),
                                c.estimated_cycles_sharded_barrier(cyc, shards),
                                "n={n} bank={bank} fanout={fanout} cyc={cyc} shards={shards}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn shard_model_prices_oversized_chunks() {
        // A shard whose tallest bank is 256 must pay the assembly merge
        // for 1024-row chunks; a 1024-bank shard must not.
        let small = Geometry::from_spec("256x32").unwrap();
        let tall = Geometry::from_spec("1024x32").unwrap();
        let m_small = shard_model(1024, 4, &small, 7.84);
        let m_tall = shard_model(1024, 4, &tall, 7.84);
        assert_eq!(m_tall.oversize, 0);
        assert_eq!(m_tall.arrival, (1024.0f64 * 7.84).round() as u64);
        // 1024 rows from 4 banks of 256: one fanout-4 pass over 1024.
        assert_eq!(m_small.oversize, 1024);
        assert_eq!(m_small.arrival, m_tall.arrival + 1024);
        assert!(m_small.weight < m_tall.weight, "slower arrival, smaller share");
        // The weighted deal follows: the tall shard absorbs more chunks.
        let deal = crate::sorter::merge::apportion_chunks(
            10,
            &[m_small.weight, m_tall.weight],
        );
        assert!(deal[1] > deal[0], "{deal:?}");
        assert_eq!(deal.iter().sum::<usize>(), 10);
    }

    #[test]
    fn hetero_fleet_scores_worse_with_a_slow_shard() {
        // Replacing one of two nominal shards with a half-speed host
        // must never improve the streamed score — and under the
        // completion-balanced deal a mixed fleet must also beat an
        // all-slow one (it has strictly faster silicon available). The
        // legacy arrival-balanced deal inverted that ordering: weights
        // model chunk production rates, not the superlinear per-shard
        // merge work, so it overloaded the fast host's serialized
        // engine ([33, 16] → 157,532 > all-slow's 142,008). The
        // schedule layer's deal ([26, 23]) restores uniform < mixed <
        // all_slow; both generations stay pinned (mirrored in
        // python/fleet_model.py).
        let c = candidate(50_000, 1024, 4);
        let geo = Geometry::default();
        let fast = shard_model(1024, 4, &geo, 7.84);
        let slow = shard_model(1024, 4, &geo, 15.68);
        let uniform = c.estimated_cycles_hetero(&[fast, fast], true);
        let mixed = c.estimated_cycles_hetero(&[fast, slow], true);
        let all_slow = c.estimated_cycles_hetero(&[slow, slow], true);
        assert_eq!(uniform, 133_980.0);
        assert_eq!(mixed, 138_076.0);
        assert_eq!(all_slow, 142_008.0);
        assert!(uniform < mixed && mixed < all_slow);
        // The legacy deal's inversion, pinned via the arrival-balanced
        // path (the regression the refactor exists to fix).
        let legacy_mixed = c.estimated_cycles_hetero_arrival_balanced(&[fast, slow]);
        assert_eq!(legacy_mixed, 157_532.0);
        assert!(legacy_mixed > all_slow, "the old deal lost to an all-slow fleet");
    }

    #[test]
    fn hetero_fleet_table_is_pinned() {
        // EXPERIMENTS.md §Heterogeneous shard scaling: n = 1M over 977
        // banks of 1024 at fanout 4, both deal generations. Values
        // cross-checked against the independent mirror in
        // python/fleet_model.py (run in CI).
        let models = |shards: &[(&str, f64)]| -> Vec<ShardModel> {
            shards
                .iter()
                .map(|&(spec, cyc)| {
                    shard_model(1024, 4, &Geometry::from_spec(spec).unwrap(), cyc)
                })
                .collect()
        };
        let score = |shards: &[(&str, f64)]| -> f64 {
            candidate(1_000_000, 1024, 4).estimated_cycles_hetero(&models(shards), true)
        };
        let legacy = |shards: &[(&str, f64)]| -> f64 {
            candidate(1_000_000, 1024, 4).estimated_cycles_hetero_arrival_balanced(&models(shards))
        };
        let nominal = ("1024x32", 7.84);
        let slow = ("1024x32", 15.68);
        let short = ("512x32", 7.84);
        // Uniform fleets: both generations coincide (the deal guard).
        assert_eq!(score(&[nominal; 4]), 2_010_972.0, "= the PR-3 uniform 4-shard row");
        assert_eq!(legacy(&[nominal; 4]), 2_010_972.0);
        assert_eq!(score(&[slow; 4]), 2_019_000.0);
        assert_eq!(legacy(&[slow; 4]), 2_019_000.0);
        // Mixed fleets: completion-balanced strictly improves on every
        // row (24.7%, 5.4% and 33.0%).
        assert_eq!(score(&[nominal, nominal, slow, slow]), 2_011_832.0);
        assert_eq!(legacy(&[nominal, nominal, slow, slow]), 2_671_452.0);
        assert_eq!(score(&[nominal, nominal, short, short]), 2_200_412.0);
        assert_eq!(legacy(&[nominal, nominal, short, short]), 2_325_340.0);
        assert_eq!(score(&[nominal, slow, slow, slow]), 2_011_832.0);
        assert_eq!(legacy(&[nominal, slow, slow, slow]), 3_003_228.0);
    }

    #[test]
    fn auto_tune_hetero_reduces_to_uniform() {
        let geo = Geometry::default();
        for n in [10usize, 3000, 50_000] {
            for shards in [1usize, 2, 4, 8] {
                for streaming in [true, false] {
                    let geos = vec![geo.clone(); shards];
                    assert_eq!(
                        auto_tune_hetero(n, &geos, streaming, |_, _| 7.84),
                        auto_tune_sharded(n, &geo, shards, streaming, |_| 7.84),
                        "n={n} shards={shards} streaming={streaming}"
                    );
                }
            }
        }
        // Degenerate n.
        assert_eq!(auto_tune_hetero(0, &[geo], true, |_, _| 7.84), (1024, 4));
    }

    #[test]
    fn auto_tune_hetero_sees_geometry_diversity() {
        // Fleet of one 1024-bank host and one 256-max host: candidates
        // include both ladders' banks, and the pick is the cheapest
        // under the hetero scoring (cross-checked by brute force).
        let geos = vec![
            Geometry::from_spec("1024x32").unwrap(),
            Geometry::from_spec("256x32").unwrap(),
        ];
        let n = 50_000usize;
        for streaming in [true, false] {
            let (bank, fanout) = auto_tune_hetero(n, &geos, streaming, |_, _| 7.84);
            let score = |b: usize, f: usize| {
                let models: Vec<ShardModel> =
                    geos.iter().map(|g| shard_model(b, f, g, 7.84)).collect();
                candidate(n, b, f).estimated_cycles_hetero(&models, streaming)
            };
            let picked = score(bank, fanout);
            let mut banks: Vec<usize> =
                geos.iter().flat_map(|g| g.bank_sizes.iter().copied()).collect();
            banks.sort_unstable();
            banks.dedup();
            for &b in &banks {
                for f in FANOUT_CANDIDATES {
                    assert!(
                        picked <= score(b, f),
                        "streaming={streaming}: ({bank},{fanout}) lost to ({b},{f})"
                    );
                }
            }
        }
    }

    #[test]
    fn geometry_spec_parses() {
        let g = Geometry::from_spec("1024x32").unwrap();
        assert_eq!(g.bank_sizes, vec![16, 64, 256, 1024]);
        assert_eq!(g.width, 32);
        assert_eq!(g.largest_bank(), 1024);
        let g = Geometry::from_spec("512x32").unwrap();
        assert_eq!(g.bank_sizes, vec![16, 64, 256, 512], "height joins the ladder");
        let g = Geometry::from_spec("2048x16").unwrap();
        assert_eq!(g.bank_sizes, vec![16, 64, 256, 1024, 2048]);
        assert_eq!(g.width, 16);
        // Height already on the ladder is not duplicated.
        assert_eq!(Geometry::from_spec("256x32").unwrap().bank_sizes, vec![16, 64, 256]);
        for bad in ["1024", "x32", "1024x", "0x32", "1024x0", "1024x33", "ax32", "1024xb"] {
            assert!(Geometry::from_spec(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn coalescing_saves_exactly_the_folded_envelopes() {
        // 8 requests of 64 elements from tenant "acme" (4 bytes):
        // envelope = 145 + 4 = 149 bytes, payload 16·64 = 1024 per
        // request. Solo: 8·1173 = 9384; carrier: 149 + 16·512 = 8341.
        let m = model_coalescing(&[64; 8], 4);
        assert_eq!(m.solo_bytes, 9384);
        assert_eq!(m.coalesced_bytes, 8341);
        assert_eq!(m.saved_bytes(), 7 * 149, "(k-1) envelopes folded");
        assert!(m.amortization() > 1.0);
        // The invariant across shapes: savings are exactly the folded
        // envelopes, never a byte of payload.
        for lens in [vec![1usize], vec![3, 5, 7], vec![100, 1, 100, 1]] {
            for t in [0usize, 4, 32] {
                let m = model_coalescing(&lens, t);
                assert_eq!(
                    m.saved_bytes(),
                    (lens.len() as u64 - 1) * (145 + t as u64),
                    "lens={lens:?} t={t}"
                );
            }
        }
        // Degenerate shapes.
        let empty = model_coalescing(&[], 4);
        assert_eq!((empty.solo_bytes, empty.coalesced_bytes), (0, 0));
        assert_eq!(empty.amortization(), 1.0);
        let single = model_coalescing(&[64], 4);
        assert_eq!(single.saved_bytes(), 0, "a lone request gains nothing");
    }

    #[test]
    fn estimated_cycles_orders_plans() {
        let pad = Plan::Pad { bank: 1024, sentinels: 0 };
        let cm = Plan::ChunkMerge { bank: 1024, chunks: 4, sentinels: 0, fanout: 4 };
        assert!(pad.estimated_cycles(8.0) < cm.estimated_cycles(8.0));
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_plan_panics() {
        plan(0, &geo(), 8.0);
    }
}

//! The fleet-schedule layer: ONE deterministic event timeline that every
//! completion / deadline / makespan number in the repo is derived from.
//!
//! Before this module, six generations of latency models
//! (`model_streamed_completion`, its uniform closed form,
//! `model_sharded_completion[_hetero]`, `model_hedge_deadline`, the
//! request plane's makespan) had accreted as loose functions across
//! `sorter/merge.rs`, the planner and the Python mirror, each
//! re-implementing the same overlap timeline. They now live here as one
//! family with shared primitives, and the legacy `merge::model_*`
//! functions are thin wrappers pinned byte-identical to their
//! pre-refactor values (see `merge.rs` tests and
//! `prop_hetero_scoring_reduces_to_uniform`).
//!
//! The timeline ([`FleetSchedule`]) maps `(shard, chunk)` to four
//! events, all in modelled cycles from the instant the parallel bank
//! sorts start:
//!
//! ```text
//! dispatch ──► colskip ──► arrival ──► merge-drain
//!    0          bank·cyc    + assembly   lane ready + W(c)·len
//!               (per-shard) (oversize    (that shard's serialized
//!                            hosts)       merge engine drains its deal)
//! ```
//!
//! and the fleet completion is the top-level cross-shard merge over the
//! lane drains, scheduled by the same greedy single-engine event model
//! as every streamed latency in the repo ([`event_completion`]).
//!
//! On top of the timeline sits **completion-balanced apportionment**
//! ([`completion_balanced_deal`]): the legacy deal
//! (`merge::apportion_chunks` on reciprocal-arrival weights) balances
//! chunk *arrival* only, ignoring that each shard's merge engine then
//! drains its share serially — so a mixed fleet could score worse than a
//! uniformly slow one (EXPERIMENTS §Heterogeneous shard scaling, the old
//! table). The completion-balanced deal starts from the arrival-balanced
//! seed and descends on the full schedule score, so mixed fleets now
//! route against predicted *completion*. Mirrored line-for-line by
//! `python/fleet_model.py` (run in CI), which pins both the old and the
//! new tables.

use std::collections::HashMap;

use super::ShardModel;

/// Deterministic overlap model of the streaming merge network — the
/// single shared event scheduler every streamed completion reduces to.
///
/// `leaves` are sorted input streams as `(ready_cycles, len)` in fixed
/// tree order. One fully-pipelined merge engine executes the fixed
/// fanout-`fanout` merge tree (the same index grouping as
/// `merge::merge_sorted_runs`): a non-trivial merge op streams its
/// inputs at one element per cycle and starts as soon as its inputs
/// exist and the engine is free; ops are scheduled greedily
/// earliest-ready first (ties: lower level, then lower group).
/// Single-run groups pass through for free. Returns the cycle the final
/// merged stream drains.
pub fn event_completion(leaves: &[(u64, usize)], fanout: usize) -> u64 {
    assert!(fanout >= 2, "merge fanout must be at least 2");
    if leaves.is_empty() {
        return 0;
    }
    // Node (level, group): stream length and the cycle it is fully
    // available (None until produced). Level 0 = the chunk runs.
    let mut lens: Vec<Vec<usize>> = vec![leaves.iter().map(|&(_, l)| l).collect()];
    let mut ready: Vec<Vec<Option<u64>>> = vec![leaves.iter().map(|&(a, _)| Some(a)).collect()];
    while lens.last().expect("at least one level").len() > 1 {
        let prev = lens.last().expect("at least one level");
        let next: Vec<usize> = prev.chunks(fanout).map(|g| g.iter().sum()).collect();
        ready.push(vec![None; next.len()]);
        lens.push(next);
    }
    let depth = lens.len();
    let mut engine_free = 0u64;
    loop {
        // Single-run groups pass through the tree for free.
        let mut changed = true;
        while changed {
            changed = false;
            for l in 1..depth {
                for g in 0..lens[l].len() {
                    let lo = g * fanout;
                    let hi = (lo + fanout).min(lens[l - 1].len());
                    if ready[l][g].is_none() && hi - lo == 1 {
                        if let Some(r) = ready[l - 1][lo] {
                            ready[l][g] = Some(r);
                            changed = true;
                        }
                    }
                }
            }
        }
        if let Some(done) = ready[depth - 1][0] {
            return done;
        }
        // Among unproduced real merges whose inputs all exist, run the
        // earliest-ready one on the shared engine.
        let mut pick: Option<(u64, usize, usize)> = None;
        for l in 1..depth {
            for g in 0..lens[l].len() {
                if ready[l][g].is_some() {
                    continue;
                }
                let lo = g * fanout;
                let hi = (lo + fanout).min(lens[l - 1].len());
                let inputs_ready = ready[l - 1][lo..hi]
                    .iter()
                    .copied()
                    .try_fold(0u64, |m, r| r.map(|v| m.max(v)));
                let Some(inputs_ready) = inputs_ready else { continue };
                if pick.is_none_or(|p| (inputs_ready, l, g) < p) {
                    pick = Some((inputs_ready, l, g));
                }
            }
        }
        let (inputs_ready, l, g) =
            pick.expect("an op with ready inputs must exist before the root is produced");
        let start = engine_free.max(inputs_ready);
        let done = start + lens[l][g] as u64;
        ready[l][g] = Some(done);
        engine_free = done;
    }
}

/// `W(c, f)`: the real-merge stream work of the fixed fanout-`f` tree
/// over `c` equal runs, in units of one run's length. The uniform
/// closed form is `arrival + W(c, f)·len` — factoring `W` out of
/// [`uniform_completion`] is what lets the completion-balanced deal
/// search memoize it per chunk count and stay O(shards²) per candidate
/// move instead of O(chunks).
pub fn uniform_merge_work(chunks: usize, fanout: usize) -> u64 {
    assert!(fanout >= 2, "merge fanout must be at least 2");
    if chunks == 0 {
        return 0;
    }
    // counts[i] = original runs under node i of the current level.
    let mut counts: Vec<usize> = vec![1; chunks];
    let mut work = 0u64;
    while counts.len() > 1 {
        let mut next = Vec::with_capacity(counts.len().div_ceil(fanout));
        for g in counts.chunks(fanout) {
            let c: usize = g.iter().sum();
            if g.len() > 1 {
                work += c as u64;
            }
            next.push(c);
        }
        counts = next;
    }
    work
}

/// Streamed completion when every chunk run arrives at the same cycle
/// with the same length — the closed form of [`event_completion`] for
/// this case: with equal arrivals the engine starts at `arrival` and
/// never idles, so the completion is `arrival` plus the total
/// real-merge work (single-run groups pass through for free).
/// O(chunks), which is what lets the auto-tuner score million-element
/// candidates without simulating them.
pub fn uniform_completion(chunks: usize, len: usize, arrival: u64, fanout: usize) -> u64 {
    assert!(fanout >= 2, "merge fanout must be at least 2");
    if chunks == 0 {
        return 0;
    }
    arrival + uniform_merge_work(chunks, fanout) * len as u64
}

/// Serialized bytes per spilled element: a `u32` value plus a `u64`
/// row (the spill run format's chunked-LE payload, header and block
/// framing amortized away). Mirrored by `fleet_model.SPILL_BYTES_PER_ELEM`.
pub const SPILL_BYTES_PER_ELEM: u64 = 12;

/// Spill-device bandwidth in bytes per modelled cycle: a 64-bit
/// channel at the paper's 500 MHz clock (4 GB/s — commodity NVMe
/// territory, deliberately conservative so the tuner never
/// underestimates spill cost). Mirrored by
/// `fleet_model.SPILL_BYTES_PER_CYC`.
pub const SPILL_BYTES_PER_CYC: u64 = 8;

/// Extra I/O cycles the out-of-core merge pays over the resident merge
/// for `n` total elements arriving as `chunks` runs at fanout `fanout`:
/// every element crosses the spill device once on the initial chunk
/// spill, once per merge-pass read, and once per non-final-pass write —
/// `2·passes` crossings for `passes ≥ 1`, and `2` (write + read-back)
/// for the degenerate single-run case. Ceil-divided by the device
/// bandwidth, so the model never rounds the cost to zero.
pub fn spill_io_cycles(n: usize, chunks: usize, fanout: usize) -> u64 {
    assert!(fanout >= 2, "merge fanout must be at least 2");
    if n == 0 {
        return 0;
    }
    let mut passes = 0u64;
    let mut r = chunks;
    while r > 1 {
        passes += 1;
        r = r.div_ceil(fanout);
    }
    let crossings = 2 * passes.max(1);
    (n as u64 * SPILL_BYTES_PER_ELEM * crossings).div_ceil(SPILL_BYTES_PER_CYC)
}

/// Streamed completion of the *spilled* merge: the resident uniform
/// closed form ([`uniform_completion`]) plus the spill I/O surcharge
/// ([`spill_io_cycles`]) for pushing every run through the spill device
/// on each pass. Always ≥ the resident completion, so the budgeted
/// auto-tuner picks spill only when the memory budget forces it.
pub fn spill_completion(chunks: usize, len: usize, arrival: u64, fanout: usize) -> u64 {
    if chunks == 0 {
        assert!(fanout >= 2, "merge fanout must be at least 2");
        return 0;
    }
    uniform_completion(chunks, len, arrival, fanout) + spill_io_cycles(chunks * len, chunks, fanout)
}

/// Streamed completion of a `shards`-host fleet draining `chunks`
/// uniform runs dealt round-robin — the uniform-fleet special case of
/// [`hetero_completion`]. See `merge::model_sharded_completion` (the
/// pinned wrapper) for the full topology contract.
pub fn sharded_completion(
    chunks: usize,
    len: usize,
    arrival: u64,
    shards: usize,
    fanout: usize,
) -> u64 {
    assert!(shards >= 1, "a fleet has at least one shard");
    if chunks == 0 {
        assert!(fanout >= 2, "merge fanout must be at least 2");
        return 0;
    }
    let shards = shards.min(chunks);
    let (base, extra) = (chunks / shards, chunks % shards);
    let deal: Vec<(usize, u64)> =
        (0..shards).map(|s| (base + usize::from(s < extra), arrival)).collect();
    hetero_completion(len, &deal, fanout)
}

/// Streamed completion of a heterogeneous fleet: shard `s` owns
/// `deal[s].0` uniform runs of `len` rows, each lane becoming ready at
/// its own `deal[s].1` cycle. Every shard drains its share through its
/// own merge engine under the uniform closed form, and one top-level
/// fanout-`fanout` merge combines the shard streams; shards dealt zero
/// chunks contribute nothing.
pub fn hetero_completion(len: usize, deal: &[(usize, u64)], fanout: usize) -> u64 {
    assert!(fanout >= 2, "merge fanout must be at least 2");
    let leaves: Vec<(u64, usize)> = deal
        .iter()
        .filter(|&&(c, _)| c > 0)
        .map(|&(c, a)| (uniform_completion(c, len, a, fanout), c * len))
        .collect();
    event_completion(&leaves, fanout)
}

/// Deal `chunks` chunks over shards in proportion to `weights`
/// (largest-remainder apportionment; ties go to the lower shard id).
/// Degenerate weights are guarded: a NaN, infinite, zero or negative
/// entry is clamped to zero weight, and if *every* entry is degenerate
/// the deal falls back to equal shares — either way every chunk is
/// accounted for (`Σ deal == chunks`, pinned). Observed-cost feedback
/// can produce all of these shapes, so the guard is load-bearing, not
/// defensive decoration.
pub fn apportion(chunks: usize, weights: &[f64]) -> Vec<usize> {
    assert!(!weights.is_empty(), "apportionment needs at least one shard");
    let sane: Vec<f64> =
        weights.iter().map(|&w| if w.is_finite() && w > 0.0 { w } else { 0.0 }).collect();
    let total: f64 = sane.iter().sum();
    let sane = if total > 0.0 { sane } else { vec![1.0; weights.len()] };
    let total: f64 = sane.iter().sum();
    let quotas: Vec<f64> = sane.iter().map(|w| chunks as f64 * w / total).collect();
    let mut deal: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let dealt: usize = deal.iter().sum();
    // Distribute the remainder by descending fractional part, ties to
    // the lower shard id (sort_by is stable, so equal keys keep index
    // order).
    let mut order: Vec<usize> = (0..sane.len()).collect();
    order.sort_by(|&a, &b| {
        let (fa, fb) = (quotas[a] - quotas[a].floor(), quotas[b] - quotas[b].floor());
        fb.partial_cmp(&fa).expect("fractional parts are finite")
    });
    for &s in order.iter().take(chunks.saturating_sub(dealt)) {
        deal[s] += 1;
    }
    debug_assert_eq!(deal.iter().sum::<usize>(), chunks);
    deal
}

/// The hedging straggler bound, in modelled cycles: a chunk of `len`
/// rows on a host observed at `cyc` cycles/number is *expected* to
/// arrive at `round(len·cyc)` — the timeline's leaf arrival — so a
/// reply still outstanding past `mult` times that is a straggler worth
/// hedging. `floor` bounds the deadline from below so tiny chunks don't
/// hedge on scheduling noise.
pub fn hedge_deadline(len: usize, cyc: f64, mult: f64, floor: u64) -> u64 {
    assert!(
        cyc.is_finite() && cyc >= 0.0 && mult.is_finite() && mult >= 0.0,
        "hedge deadline inputs must be finite and non-negative (cyc={cyc}, mult={mult})"
    );
    ((len as f64 * cyc * mult).round() as u64).max(floor)
}

/// Makespan of `clients` connections each pipelining `jobs` bank-sized
/// sorts of `n` elements into one shard host with `workers` workers:
/// the sessions share the worker pool (not a per-connection lock), so
/// every job is in flight up front and the pool drains
/// `ceil(total/workers)` rounds of `round(n·cyc)` cycles. Aggregate
/// throughput is flat in the client count at `workers/cyc` elem/cycle;
/// per-client latency grows linearly — the EXPERIMENTS §Concurrent
/// request plane table, previously derived only in the Python mirror
/// (`fleet_model.concurrent_makespan`), now pinned on both sides.
pub fn concurrent_makespan(clients: usize, jobs: usize, n: usize, workers: usize, cyc: f64) -> u64 {
    assert!(workers >= 1, "a host has at least one worker");
    assert!(cyc.is_finite() && cyc >= 0.0, "cyc/num must be finite and non-negative");
    let total = clients * jobs;
    total.div_ceil(workers) as u64 * (n as f64 * cyc).round() as u64
}

/// The arrival-balanced deal: largest-remainder apportionment on the
/// models' reciprocal-arrival weights — the legacy (pre-schedule-layer)
/// heterogeneous deal, kept callable so the old EXPERIMENTS table stays
/// reproducible and the old-vs-new comparison stays pinned.
pub fn arrival_balanced_deal(chunks: usize, models: &[ShardModel]) -> Vec<usize> {
    let weights: Vec<f64> = models.iter().map(|m| m.weight).collect();
    apportion(chunks, &weights)
}

/// The completion-balanced deal: start from the arrival-balanced seed,
/// then steepest-descent on single-chunk moves scored by the *full
/// schedule* — fleet completion first, then the per-lane drains sorted
/// descending. The deal that wins is the one whose slowest merge drain
/// (not slowest chunk arrival) is lowest.
///
/// Two design points are load-bearing:
///
/// * **Identical fleets return the seed untouched.** On identical
///   shards an unconstrained search can beat the balanced deal by
///   consolidating lanes to save a top-level pass (e.g. 5 identical
///   shards × 5 chunks at fanout 4: deal `[2,1,1,1,0]` completes at
///   15,196 vs the balanced deal's 17,244), which would break the
///   pinned invariant that the hetero model reduces *exactly* to the
///   uniform round-robin model. The guard compares the
///   schedule-relevant fields (arrival, oversize, weight); when all
///   shards match, the arrival-balanced seed IS the uniform deal and
///   is returned as-is.
/// * **The secondary score key walks plateaus.** With two tied fast
///   lanes, moving a chunk off one leaves the fleet completion pinned
///   on its twin, so no single move strictly improves completion alone
///   and descent stalls ~25% above the optimum (the 2-fast+2-slow
///   EXPERIMENTS row). Comparing the sorted drain vector
///   lexicographically after completion accepts completion-neutral
///   moves that lower a runner-up drain, and the next round improves
///   the twin. Every accepted move strictly decreases the (completion,
///   drains) tuple, so the search terminates; the explicit round cap
///   only bounds the worst case.
///
/// Deterministic by construction (steepest descent, ties to the lowest
/// `(from, to)` move), never worse than the arrival-balanced deal
/// (descent starts there and only accepts improvements), and mirrored
/// move-for-move by `fleet_model.completion_balanced_deal`.
pub fn completion_balanced_deal(
    chunks: usize,
    len: usize,
    models: &[ShardModel],
    fanout: usize,
) -> Vec<usize> {
    let mut deal = arrival_balanced_deal(chunks, models);
    let uniform_fleet = models.iter().all(|m| {
        m.arrival == models[0].arrival
            && m.oversize == models[0].oversize
            && m.weight == models[0].weight
    });
    if chunks == 0 || uniform_fleet {
        return deal;
    }
    let mut search = DealSearch::new(len, fanout, models);
    let mut best = search.score(&deal);
    let shards = models.len();
    for _ in 0..2 * chunks * shards {
        let mut mv: Option<(DealScore, usize, usize)> = None;
        for i in 0..shards {
            if deal[i] == 0 {
                continue;
            }
            for j in 0..shards {
                if i == j {
                    continue;
                }
                deal[i] -= 1;
                deal[j] += 1;
                let s = search.score(&deal);
                deal[i] += 1;
                deal[j] -= 1;
                if s < best && mv.as_ref().is_none_or(|m| s < m.0) {
                    mv = Some((s, i, j));
                }
            }
        }
        let Some((score, i, j)) = mv else { break };
        best = score;
        deal[i] -= 1;
        deal[j] += 1;
    }
    deal
}

/// Score of one candidate deal: `(fleet completion, per-lane drains
/// sorted descending)`, compared lexicographically.
type DealScore = (u64, Vec<u64>);

/// Memoized scorer for the deal search: `W(c, fanout)` is cached per
/// chunk count, so re-scoring a neighbour deal costs O(shards²) (the
/// top-level event schedule over ≤ shards leaves), not O(chunks).
struct DealSearch<'a> {
    len: usize,
    fanout: usize,
    models: &'a [ShardModel],
    work: HashMap<usize, u64>,
}

impl<'a> DealSearch<'a> {
    fn new(len: usize, fanout: usize, models: &'a [ShardModel]) -> Self {
        DealSearch { len, fanout, models, work: HashMap::new() }
    }

    fn score(&mut self, deal: &[usize]) -> DealScore {
        let fanout = self.fanout;
        let mut drains: Vec<u64> = Vec::with_capacity(deal.len());
        let mut leaves: Vec<(u64, usize)> = Vec::new();
        for (&c, m) in deal.iter().zip(self.models) {
            if c == 0 {
                // An idle lane drains nothing; it still occupies a slot
                // in the secondary key so vectors compare positionally.
                drains.push(0);
                continue;
            }
            let w = *self.work.entry(c).or_insert_with(|| uniform_merge_work(c, fanout));
            let ready = m.arrival + (c as u64 - 1) * m.oversize;
            let drain = ready + w * self.len as u64;
            drains.push(drain);
            leaves.push((drain, c * self.len));
        }
        let completion = event_completion(&leaves, self.fanout);
        drains.sort_unstable_by(|a, b| b.cmp(a));
        (completion, drains)
    }
}

/// One `(shard, chunk)` row of the event timeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChunkEvent {
    /// Owning shard (index into the model slice the schedule was built
    /// from).
    pub shard: usize,
    /// Chunk index within the shard's lane.
    pub chunk: usize,
    /// When the chunk is dispatched: all banks start together at 0.
    pub dispatch: u64,
    /// When the bank's column-skipping sort finishes (`round(bank·cyc)`
    /// for the lane's host).
    pub colskip: u64,
    /// When the sorted run exists on the shard: colskip plus this
    /// chunk's share of the oversize-assembly serialization.
    pub arrival: u64,
    /// When the shard's merge engine has drained the whole lane this
    /// chunk belongs to (lane-level: the engine emits one stream).
    pub drain: u64,
}

/// One shard's slice of the schedule.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lane {
    /// Shard index.
    pub shard: usize,
    /// Chunks dealt to this shard.
    pub chunks: usize,
    /// First-chunk arrival (colskip + one assembly pass on oversize
    /// hosts).
    pub arrival: u64,
    /// Serialization charge per additional dealt chunk (oversize
    /// assembly on the shard's own merge engine; 0 for right-sized
    /// hosts).
    pub oversize: u64,
    /// When the last chunk's run exists: `arrival + (chunks-1)·oversize`.
    pub ready: u64,
    /// When the shard's merge engine has drained its lane into one
    /// stream: `ready + W(chunks)·len` (0 for an idle lane).
    pub drain: u64,
}

impl Lane {
    /// Arrival of chunk `j` of this lane.
    pub fn chunk_arrival(&self, j: usize) -> u64 {
        self.arrival + j as u64 * self.oversize
    }

    /// When the lane's bank sort finishes (arrival minus the first
    /// chunk's assembly charge).
    pub fn colskip(&self) -> u64 {
        self.arrival.saturating_sub(self.oversize)
    }
}

/// The deterministic fleet timeline: per-shard lanes plus the
/// cross-shard completion, computed once and queried everywhere —
/// planner scoring, cost routing, hedge deadlines and the `scale`
/// CLI's per-shard drain report all read this one struct instead of
/// re-deriving the arithmetic.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FleetSchedule {
    len: usize,
    fanout: usize,
    lanes: Vec<Lane>,
    completion: u64,
}

impl FleetSchedule {
    /// Build the timeline for an explicit deal over shard models.
    pub fn from_deal(len: usize, fanout: usize, models: &[ShardModel], deal: &[usize]) -> Self {
        assert_eq!(models.len(), deal.len(), "one deal entry per shard model");
        let lanes: Vec<Lane> = deal
            .iter()
            .zip(models)
            .enumerate()
            .map(|(shard, (&chunks, m))| {
                let ready = m.arrival + (chunks as u64).saturating_sub(1) * m.oversize;
                let drain = if chunks == 0 {
                    0
                } else {
                    uniform_completion(chunks, len, ready, fanout)
                };
                Lane { shard, chunks, arrival: m.arrival, oversize: m.oversize, ready, drain }
            })
            .collect();
        let leaves: Vec<(u64, usize)> = lanes
            .iter()
            .filter(|l| l.chunks > 0)
            .map(|l| (l.drain, l.chunks * len))
            .collect();
        let completion = event_completion(&leaves, fanout);
        FleetSchedule { len, fanout, lanes, completion }
    }

    /// The legacy schedule: chunks dealt by reciprocal-arrival weights.
    pub fn arrival_balanced(
        chunks: usize,
        len: usize,
        models: &[ShardModel],
        fanout: usize,
    ) -> Self {
        let deal = arrival_balanced_deal(chunks, models);
        Self::from_deal(len, fanout, models, &deal)
    }

    /// The completion-balanced schedule ([`completion_balanced_deal`]).
    pub fn completion_balanced(
        chunks: usize,
        len: usize,
        models: &[ShardModel],
        fanout: usize,
    ) -> Self {
        let deal = completion_balanced_deal(chunks, len, models, fanout);
        Self::from_deal(len, fanout, models, &deal)
    }

    /// The cycle the cross-shard merge drains the final stream.
    pub fn completion(&self) -> u64 {
        self.completion
    }

    /// Chunks per shard under this schedule.
    pub fn deal(&self) -> Vec<usize> {
        self.lanes.iter().map(|l| l.chunks).collect()
    }

    /// Per-shard lanes, in shard order.
    pub fn lanes(&self) -> &[Lane] {
        &self.lanes
    }

    /// Chunk length the schedule was built for.
    pub fn chunk_len(&self) -> usize {
        self.len
    }

    /// Merge fanout the schedule was built for.
    pub fn fanout(&self) -> usize {
        self.fanout
    }

    /// The full `(shard, chunk)` event timeline, shard-major.
    pub fn events(&self) -> Vec<ChunkEvent> {
        self.lanes
            .iter()
            .flat_map(|l| {
                (0..l.chunks).map(move |j| ChunkEvent {
                    shard: l.shard,
                    chunk: j,
                    dispatch: 0,
                    colskip: l.colskip(),
                    arrival: l.chunk_arrival(j),
                    drain: l.drain,
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::{shard_model, Geometry};

    fn models(specs: &[(&str, f64)], bank: usize, fanout: usize) -> Vec<ShardModel> {
        specs
            .iter()
            .map(|&(spec, cyc)| {
                shard_model(bank, fanout, &Geometry::from_spec(spec).unwrap(), cyc)
            })
            .collect()
    }

    /// The EXPERIMENTS §Heterogeneous shard scaling fleets (n=1M,
    /// bank=1024, fanout=4), as (spec, cyc) rows.
    fn experiments_fleets() -> Vec<(&'static str, Vec<(&'static str, f64)>)> {
        vec![
            ("4x nominal", vec![("1024x32", 7.84); 4]),
            (
                "2x nominal + 2x half",
                vec![("1024x32", 7.84), ("1024x32", 7.84), ("1024x32", 15.68), ("1024x32", 15.68)],
            ),
            ("4x half-speed", vec![("1024x32", 15.68); 4]),
            (
                "2x nominal + 2x 512-max",
                vec![("1024x32", 7.84), ("1024x32", 7.84), ("512x32", 7.84), ("512x32", 7.84)],
            ),
            (
                "1x nominal + 3x half",
                vec![("1024x32", 7.84), ("1024x32", 15.68), ("1024x32", 15.68), ("1024x32", 15.68)],
            ),
        ]
    }

    #[test]
    fn completion_balanced_beats_or_ties_arrival_balanced_on_every_experiments_row() {
        // The acceptance table, pinned value-for-value (mirrored by
        // python/fleet_model.py, which CI runs): completion-balanced ≤
        // arrival-balanced on every row, equality exactly on the
        // uniform fleets, and the big wins where arrival weights
        // over-skew the deal (the 5-level/4-level merge-tree cliff at
        // 256 chunks is what the search walks across).
        let chunks = 1_000_000usize.div_ceil(1024);
        let expect: Vec<(u64, u64, Vec<usize>)> = vec![
            (2_010_972, 2_010_972, vec![245, 244, 244, 244]),
            (2_671_452, 2_011_832, vec![245, 245, 244, 243]),
            (2_019_000, 2_019_000, vec![245, 244, 244, 244]),
            (2_325_340, 2_200_412, vec![256, 256, 233, 232]),
            (3_003_228, 2_011_832, vec![245, 244, 244, 244]),
        ];
        for ((name, fleet), (arrival_pin, completion_pin, deal_pin)) in
            experiments_fleets().into_iter().zip(expect)
        {
            let ms = models(&fleet, 1024, 4);
            let old = FleetSchedule::arrival_balanced(chunks, 1024, &ms, 4);
            let new = FleetSchedule::completion_balanced(chunks, 1024, &ms, 4);
            assert_eq!(old.completion(), arrival_pin, "{name}: arrival-balanced");
            assert_eq!(new.completion(), completion_pin, "{name}: completion-balanced");
            assert_eq!(new.deal(), deal_pin, "{name}: deal");
            assert!(new.completion() <= old.completion(), "{name}: regression");
        }
    }

    #[test]
    fn identical_fleets_keep_the_round_robin_deal() {
        // The guard that preserves the uniform reduction: on identical
        // shards the search must NOT consolidate lanes (which would
        // beat the round-robin deal by saving a top-level pass — 5
        // shards × 5 chunks at fanout 4: [2,1,1,1,0] completes at
        // 15,196 < 17,244) because the uniform models are the pinned
        // contract. The counterexample itself is pinned so the guard
        // can't silently become dead code.
        let ms = vec![shard_model(1024, 4, &Geometry::default(), 7.84); 5];
        let deal = completion_balanced_deal(5, 1024, &ms, 4);
        assert_eq!(deal, vec![1, 1, 1, 1, 1], "guarded: seed returned untouched");
        let consolidated = FleetSchedule::from_deal(1024, 4, &ms, &[2, 1, 1, 1, 0]);
        let balanced = FleetSchedule::from_deal(1024, 4, &ms, &[1, 1, 1, 1, 1]);
        assert_eq!(consolidated.completion(), 15_196);
        assert_eq!(balanced.completion(), 17_244);
        assert!(
            consolidated.completion() < balanced.completion(),
            "the guard is load-bearing: unguarded search would take the consolidated deal"
        );
    }

    #[test]
    fn schedule_timeline_events_are_consistent() {
        // 2 nominal + 2 undersized hosts: the 512-max lanes charge one
        // oversize assembly pass (1024 cycles) per chunk, visible in
        // the per-chunk arrivals; drains cover every arrival; the
        // fleet completion is the top-level merge over the drains.
        let ms = models(
            &[("1024x32", 7.84), ("1024x32", 7.84), ("512x32", 7.84), ("512x32", 7.84)],
            1024,
            4,
        );
        let sched = FleetSchedule::completion_balanced(977, 1024, &ms, 4);
        let events = sched.events();
        assert_eq!(events.len(), 977, "every chunk appears exactly once");
        for e in &events {
            assert_eq!(e.dispatch, 0, "all banks start together");
            assert!(e.colskip <= e.arrival, "assembly cannot precede the sort");
            assert!(e.arrival <= e.drain, "a run drains after it exists");
        }
        let lanes = sched.lanes();
        assert_eq!(lanes[0].oversize, 0);
        assert_eq!(lanes[2].oversize, 1024, "512-max host pays one assembly pass per chunk");
        assert_eq!(lanes[2].chunk_arrival(1) - lanes[2].chunk_arrival(0), 1024);
        assert!(sched.completion() >= lanes.iter().map(|l| l.drain).max().unwrap());
    }

    #[test]
    fn concurrent_makespan_matches_the_experiments_table() {
        // EXPERIMENTS §Concurrent request plane: C clients × 8 jobs of
        // one 1024-bank each into a 1-worker host at nominal cyc —
        // makespan doubles with C, aggregate throughput flat.
        for (clients, pin) in [(1usize, 64_224u64), (2, 128_448), (4, 256_896), (8, 513_792)] {
            assert_eq!(concurrent_makespan(clients, 8, 1024, 1, 7.84), pin, "C={clients}");
        }
        // Work that doesn't divide the pool rounds up to a whole round.
        assert_eq!(concurrent_makespan(1, 3, 1024, 2, 7.84), 2 * 8028);
    }

    #[test]
    fn spill_io_surcharge_matches_the_experiments_table() {
        // EXPERIMENTS §Out-of-core spill (mirrored and pinned by
        // python/fleet_model.py): 12 B/elem over an 8 B/cycle device,
        // 2·passes crossings (write + read-back for a single run).
        assert_eq!(spill_io_cycles(0, 0, 4), 0);
        assert_eq!(spill_io_cycles(1024, 1, 4), 3_072, "single run: write + read back");
        assert_eq!(spill_io_cycles(4 * 1024, 4, 4), 12_288, "one pass");
        assert_eq!(spill_io_cycles(16 * 1024, 16, 4), 98_304, "two passes");
        // The 1M-element fleet shape: 977 chunks of 1024, 5 passes.
        assert_eq!(spill_io_cycles(977 * 1024, 977, 4), 15_006_720);
        // Rounds up, never to zero.
        assert_eq!(spill_io_cycles(1, 1, 2), 3);
    }

    #[test]
    fn spill_completion_is_resident_plus_io_and_never_cheaper() {
        // Pinned crossover points for the budgeted tuner (bank 1024,
        // nominal arrival 8028 = round(1024·7.84), fanout 4).
        assert_eq!(spill_completion(0, 1024, 8028, 4), 0);
        assert_eq!(spill_completion(1, 1024, 8028, 4), 8_028 + 3_072);
        assert_eq!(spill_completion(4, 1024, 8028, 4), 12_124 + 12_288);
        assert_eq!(spill_completion(977, 1024, 8028, 4), 5_008_220 + 15_006_720);
        for chunks in [1usize, 3, 16, 200, 977] {
            for fanout in [2usize, 4, 8] {
                let resident = uniform_completion(chunks, 1024, 8028, fanout);
                let spilled = spill_completion(chunks, 1024, 8028, fanout);
                assert!(
                    spilled > resident,
                    "spill must always cost extra (chunks={chunks} fanout={fanout})"
                );
            }
        }
    }

    #[test]
    fn degenerate_weights_clamp_to_a_uniform_deal() {
        // The observed-cost feedback path can hand apportionment NaN
        // (0/0 on a fresh class), +inf (cyc overflow), zero and
        // negative weights. Each is dealt nothing while any sane weight
        // exists; all-degenerate falls back to equal shares. Every
        // chunk is accounted for in all cases.
        assert_eq!(apportion(4, &[f64::NAN, 2.0]), vec![0, 4]);
        assert_eq!(apportion(4, &[f64::INFINITY, 2.0]), vec![0, 4]);
        assert_eq!(apportion(4, &[-3.0, 2.0]), vec![0, 4]);
        assert_eq!(apportion(4, &[0.0, 0.0]), vec![2, 2]);
        assert_eq!(apportion(5, &[f64::NAN, f64::INFINITY, -1.0]), vec![2, 2, 1]);
        assert_eq!(apportion(0, &[f64::NAN]), vec![0]);
        for weights in
            [vec![f64::NAN; 3], vec![f64::NEG_INFINITY, 0.0, -0.0], vec![1.0, f64::NAN, 3.0]]
        {
            let deal = apportion(7, &weights);
            assert_eq!(deal.iter().sum::<usize>(), 7, "{weights:?}: every chunk dealt");
        }
    }

    #[test]
    fn completion_balanced_never_loses_to_arrival_balanced() {
        // Deterministic sweep across mixed shapes (beyond the pinned
        // EXPERIMENTS rows): descent starts at the arrival-balanced
        // seed and only accepts improvements, so ≤ must hold
        // everywhere, with the chunk count conserved.
        let shapes: Vec<Vec<(&str, f64)>> = vec![
            vec![("1024x32", 7.84), ("1024x32", 31.36)],
            vec![("1024x32", 7.84), ("512x32", 15.68), ("256x32", 7.84)],
            vec![("64x32", 3.92), ("1024x32", 7.84), ("1024x32", 7.84), ("512x32", 15.68)],
        ];
        for fleet in shapes {
            for bank in [256usize, 1024] {
                for chunks in [1usize, 7, 49, 200] {
                    let ms = models(&fleet, bank, 4);
                    let old = FleetSchedule::arrival_balanced(chunks, bank, &ms, 4);
                    let new = FleetSchedule::completion_balanced(chunks, bank, &ms, 4);
                    assert!(
                        new.completion() <= old.completion(),
                        "{fleet:?} bank={bank} chunks={chunks}: {} > {}",
                        new.completion(),
                        old.completion()
                    );
                    assert_eq!(new.deal().iter().sum::<usize>(), chunks);
                }
            }
        }
    }
}

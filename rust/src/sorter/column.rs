//! The **column processor** of the near-memory circuit (paper Fig. 4): it
//! owns the column-address register, decides where a traversal starts, and
//! implements the two skip mechanisms of §III.A —
//!
//! 1. *leading-zero skipping*: full traversals start at the highest column
//!    that can still be informative (tracked in the lead register — the
//!    highest informative column ever observed can only move toward the
//!    LSB as rows retire, so starting there is always sound);
//! 2. *stalling*: when several rows stay active at the end of an iteration
//!    (duplicates), the column processor stalls (`cen` deasserted) while
//!    the row processor drains them, issuing zero CRs.

/// Column-address control for one sorter.
#[derive(Clone, Debug)]
pub struct ColumnProcessor {
    width: u32,
    /// Highest column observed to be informative (lead register).
    /// `None` until the first full traversal has run.
    lead: Option<u32>,
    /// Enable leading-zero skipping (scenario 1 of §III.A).
    skip_leading: bool,
}

impl ColumnProcessor {
    pub fn new(width: u32, skip_leading: bool) -> Self {
        assert!((1..=32).contains(&width));
        ColumnProcessor { width, lead: None, skip_leading }
    }

    pub fn width(&self) -> u32 {
        self.width
    }

    /// Column where a *full* (from-MSB) traversal starts.
    pub fn full_start(&self) -> u32 {
        match (self.skip_leading, self.lead) {
            (true, Some(l)) => l,
            _ => self.width - 1,
        }
    }

    /// Observe the first informative column of a full traversal; the lead
    /// register latches it (it is non-increasing over the sort).
    pub fn observe_first_informative(&mut self, col: u32) {
        debug_assert!(self.lead.is_none_or(|l| col <= l));
        self.lead = Some(col);
    }

    /// Reset for a new array.
    pub fn reset(&mut self) {
        self.lead = None;
    }

    /// Current lead register (tests/debug).
    pub fn lead(&self) -> Option<u32> {
        self.lead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_msb_before_any_observation() {
        let cp = ColumnProcessor::new(32, true);
        assert_eq!(cp.full_start(), 31);
    }

    #[test]
    fn lead_register_latches_and_lowers_start() {
        let mut cp = ColumnProcessor::new(32, true);
        cp.observe_first_informative(19);
        assert_eq!(cp.full_start(), 19);
        cp.observe_first_informative(12);
        assert_eq!(cp.full_start(), 12);
    }

    #[test]
    fn disabled_skipping_always_starts_at_msb() {
        let mut cp = ColumnProcessor::new(32, false);
        cp.observe_first_informative(5);
        assert_eq!(cp.full_start(), 31);
    }

    #[test]
    fn reset_clears_lead() {
        let mut cp = ColumnProcessor::new(16, true);
        cp.observe_first_informative(3);
        cp.reset();
        assert_eq!(cp.full_start(), 15);
        assert_eq!(cp.lead(), None);
    }
}

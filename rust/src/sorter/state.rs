//! The k-entry **state controller** of the column-skipping near-memory
//! circuit (paper §III.B, Fig. 4).
//!
//! Each entry holds a wordline (RE-state) snapshot and the bit-column
//! index it belongs to. Semantics (derived from Fig. 2/3 and validated
//! against the paper's worked example — see `colskip::tests`):
//!
//! * **SR (state recording)** — during an iteration that started from the
//!   MSB, every informative column records the RE state *with which the
//!   column was entered* plus its index. Only the `k` most recent
//!   recordings are kept (the table is a shift register; older entries
//!   fall off).
//! * **SL (state loading)** — a new min search peeks the most recent
//!   entry. If its snapshot still contains an unsorted row, the wordline
//!   register is loaded from it and the traversal resumes at that entry's
//!   column (every column above it is provably redundant). Entries whose
//!   snapshots contain only already-sorted rows are permanently discarded
//!   (their rows can never come back).

use crate::bits::RowMask;

/// One recorded (RE state, column index) pair.
#[derive(Clone, Debug)]
pub struct StateEntry {
    /// Wordline snapshot: the active-row set entering column `col`.
    pub snapshot: RowMask,
    /// The bit column the snapshot belongs to.
    pub col: u32,
}

/// The k-entry recording table.
#[derive(Clone, Debug)]
pub struct StateTable {
    entries: Vec<StateEntry>,
    k: usize,
    /// Spare snapshot buffers recycled from evicted/invalidated entries so
    /// steady-state recording never allocates.
    pool: Vec<RowMask>,
}

impl StateTable {
    /// A table with capacity `k` (k = 0 disables recording entirely).
    pub fn new(k: usize) -> Self {
        StateTable { entries: Vec::with_capacity(k), k, pool: Vec::with_capacity(k + 1) }
    }

    /// Capacity (the paper's parameter k).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Current number of stored entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record the state `active` entering informative column `col`
    /// (the SR operation). Evicts the oldest entry when full.
    pub fn record(&mut self, active: &RowMask, col: u32) {
        if self.k == 0 {
            return;
        }
        let mut snapshot = if self.entries.len() == self.k {
            // Shift register full: oldest entry's buffer is recycled.
            self.entries.remove(0).snapshot
        } else {
            self.pool.pop().unwrap_or_else(|| RowMask::new_empty(active.len()))
        };
        snapshot.copy_from(active);
        self.entries.push(StateEntry { snapshot, col });
    }

    /// [`StateTable::record`], but *swap* the snapshot in instead of
    /// copying it: `src` (the pre-exclusion active set staged by
    /// `Bank::column_step`) becomes the stored snapshot by pointer
    /// exchange, and `src` is left holding a recycled buffer of the
    /// same length — stale content, about to be overwritten by the next
    /// column step. Zero mask words move. Falls back to the same
    /// eviction/pool discipline as `record`, so table contents are
    /// identical to the copying path.
    pub fn record_swapped(&mut self, src: &mut RowMask, col: u32) {
        if self.k == 0 {
            return;
        }
        let mut snapshot = if self.entries.len() == self.k {
            self.entries.remove(0).snapshot
        } else {
            self.pool.pop().unwrap_or_else(|| RowMask::new_empty(src.len()))
        };
        std::mem::swap(&mut snapshot, src);
        self.entries.push(StateEntry { snapshot, col });
    }

    /// The SL operation: discard dead entries (snapshot disjoint from
    /// `alive`), then return the most recent live one. Returns the number
    /// of entries invalidated alongside the entry.
    pub fn load_most_recent(&mut self, alive: &RowMask) -> (Option<&StateEntry>, u64) {
        let mut invalidated = 0;
        while let Some(last) = self.entries.last() {
            if last.snapshot.intersects(alive) {
                return (self.entries.last(), invalidated);
            }
            let dead = self.entries.pop().expect("last() was Some");
            self.pool.push(dead.snapshot);
            invalidated += 1;
        }
        (None, invalidated)
    }

    /// Pop the most recent entry unconditionally (multi-bank manager use:
    /// an entry that is dead *globally* is popped in every bank even if
    /// some local snapshot is empty). Returns whether an entry was popped.
    pub fn pop_most_recent(&mut self) -> bool {
        match self.entries.pop() {
            Some(e) => {
                self.pool.push(e.snapshot);
                true
            }
            None => false,
        }
    }

    /// Drop all entries (used when switching arrays).
    pub fn clear(&mut self) {
        while let Some(e) = self.entries.pop() {
            self.pool.push(e.snapshot);
        }
    }

    /// Read-only view of the entries, oldest first (for tests/debug).
    pub fn entries(&self) -> &[StateEntry] {
        &self.entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mask(n: usize, rows: &[usize]) -> RowMask {
        RowMask::from_rows(n, rows.iter().copied())
    }

    #[test]
    fn k_zero_records_nothing() {
        let mut t = StateTable::new(0);
        t.record(&mask(8, &[0, 1]), 3);
        assert!(t.is_empty());
        let alive = mask(8, &[0]);
        let (e, inv) = t.load_most_recent(&alive);
        assert!(e.is_none());
        assert_eq!(inv, 0);
    }

    #[test]
    fn keeps_k_most_recent() {
        let mut t = StateTable::new(2);
        t.record(&mask(8, &[0, 1, 2]), 5);
        t.record(&mask(8, &[0, 1]), 4);
        t.record(&mask(8, &[0]), 3);
        assert_eq!(t.len(), 2);
        // Oldest (col 5) evicted.
        assert_eq!(t.entries()[0].col, 4);
        assert_eq!(t.entries()[1].col, 3);
    }

    #[test]
    fn load_returns_most_recent_live() {
        let mut t = StateTable::new(3);
        t.record(&mask(8, &[0, 1, 2]), 5);
        t.record(&mask(8, &[1, 2]), 4);
        let alive = mask(8, &[1, 2, 7]);
        let (e, inv) = t.load_most_recent(&alive);
        assert_eq!(e.unwrap().col, 4);
        assert_eq!(inv, 0);
    }

    #[test]
    fn dead_entries_are_discarded_permanently() {
        let mut t = StateTable::new(3);
        t.record(&mask(8, &[0, 1, 2]), 5);
        t.record(&mask(8, &[1]), 4);
        // Row 1 got sorted: entry at col 4 is dead.
        let alive = mask(8, &[0, 2]);
        let (e, inv) = t.load_most_recent(&alive);
        assert_eq!(e.unwrap().col, 5);
        assert_eq!(inv, 1);
        assert_eq!(t.len(), 1);
    }

    #[test]
    fn all_dead_empties_table() {
        let mut t = StateTable::new(2);
        t.record(&mask(8, &[0]), 5);
        t.record(&mask(8, &[1]), 4);
        let alive = mask(8, &[6, 7]);
        let (e, inv) = t.load_most_recent(&alive);
        assert!(e.is_none());
        assert_eq!(inv, 2);
        assert!(t.is_empty());
    }

    #[test]
    fn snapshot_is_a_copy_not_a_reference() {
        let mut t = StateTable::new(1);
        let mut m = mask(8, &[0, 1]);
        t.record(&m, 3);
        m.clear(0);
        m.clear(1);
        assert_eq!(t.entries()[0].snapshot.count(), 2);
    }

    #[test]
    fn record_swapped_builds_the_same_table_as_record() {
        let mut copied = StateTable::new(2);
        let mut swapped = StateTable::new(2);
        for (rows, col) in
            [(vec![0usize, 1, 2], 5u32), (vec![1, 2], 4), (vec![2], 3)]
        {
            let m = mask(8, &rows);
            copied.record(&m, col);
            let mut src = m.clone();
            swapped.record_swapped(&mut src, col);
            // A same-geometry buffer is handed back for reuse.
            assert_eq!(src.len(), 8);
        }
        assert_eq!(copied.len(), swapped.len());
        for (a, b) in copied.entries().iter().zip(swapped.entries()) {
            assert_eq!(a.col, b.col);
            assert_eq!(a.snapshot, b.snapshot);
        }
        // k = 0 is still a no-op and must not disturb the source mask.
        let mut t0 = StateTable::new(0);
        let mut src = mask(8, &[3]);
        t0.record_swapped(&mut src, 1);
        assert!(t0.is_empty());
        assert_eq!(src, mask(8, &[3]));
    }

    #[test]
    fn clear_resets() {
        let mut t = StateTable::new(2);
        t.record(&mask(8, &[0]), 1);
        t.clear();
        assert!(t.is_empty());
        // Buffers recycle through the pool: record again without growth.
        t.record(&mask(8, &[1]), 2);
        assert_eq!(t.len(), 1);
    }
}

//! Out-of-core spill tier: budgeted file-backed runs and a k-way
//! external merge over bounded-buffer run readers.
//!
//! Every other path in the repo keeps all chunk results resident, so
//! the largest sortable dataset is bounded by coordinator memory. This
//! module removes that bound: when a request's merge working set
//! exceeds the configured [`MemoryBudget`], the hierarchical assembly
//! writes each sorted chunk run to a [`RunStore`] instead of parking it
//! in memory, and [`spill_merge`] reduces the stored runs through the
//! same fixed fanout-`f` merge tree as the resident path — reading each
//! run back one bounded block at a time, writing intermediate passes
//! back to the store, and streaming only the final pass into memory.
//!
//! ## Byte-identity with the resident path
//!
//! The merge items are `(value, global_row)` pairs with globally unique
//! rows, totally ordered with ties broken by within-group run index —
//! exactly the key order of [`super::merge::LoserTree`]. The internal
//! [`SourceTree`] ports that loser tree verbatim (same construction
//! replay order, same Some/Some-only comparison metering) and
//! [`spill_merge`] reproduces `merge_sorted_runs`' pass structure (same
//! fanout grouping in run order, singleton groups pass through free,
//! empty runs dropped up front, `cycles = total · passes`), so the
//! merged values, the argsort, the comparison count and the modelled
//! merge cycles are byte-identical to the resident pipeline — pinned by
//! `tests/spill.rs` across datasets, budgets and fanouts.
//!
//! ## Run format
//!
//! Length-prefixed and checksummed, using the wire codec's chunked-LE
//! slice encoding (`coordinator::wire` idiom), framed in bounded blocks
//! so a reader never holds more than one block per run in memory:
//!
//! ```text
//! header : magic u32 LE | version u32 LE | total elements u64 LE
//! block  : count u32 LE (1..=SPILL_BLOCK_ELEMS)
//!          count × value u32 LE
//!          count × row   u64 LE
//!          fnv1a-64 checksum u64 LE  (over count + values + rows bytes)
//! ```
//!
//! Every decode failure — short file, bad magic, bad count, checksum
//! mismatch, trailing bytes — surfaces as a typed [`SpillError`]
//! (downcastable through `anyhow`), never as partial output: the merge
//! either returns the complete byte-identical result or an `Err`.

use std::collections::HashMap;
use std::fmt;
use std::fs::{self, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};

use anyhow::{anyhow, Result};

/// Sentinel for an empty loser-tree slot (pre-initialization) — the
/// same convention as [`super::merge::LoserTree`].
const EMPTY: usize = usize::MAX;

/// Elements per run-format block. Bounds every reader/writer buffer:
/// a fanout-`f` merge holds at most `f + 1` blocks resident
/// ([`spill_working_bytes`]), ~64 KiB of tuples at fanout 4.
pub const SPILL_BLOCK_ELEMS: usize = 1024;

/// Run-format magic (`b"MSRN"`, memsort run) and version.
const RUN_MAGIC: u32 = 0x4e52_534d;
const RUN_VERSION: u32 = 1;

/// Header bytes: magic + version + total.
const HEADER_BYTES: u64 = 16;

/// Serialized bytes per element: a `u32` value plus a `u64` row.
const ELEM_BYTES: usize = 12;

// --- budget ---------------------------------------------------------------

/// Byte budget for a sort's merge working set. `Unbounded` (the
/// default) keeps every run resident — the pre-spill behaviour,
/// byte-for-byte. A bounded budget spills the runs to a [`RunStore`]
/// whenever the resident merge footprint ([`resident_merge_bytes`])
/// would exceed it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MemoryBudget {
    /// No limit: never spill.
    #[default]
    Unbounded,
    /// Spill when the resident merge working set exceeds this many
    /// bytes.
    Bytes(usize),
}

impl MemoryBudget {
    /// Does a working set of `bytes` fit without spilling?
    pub fn fits(self, bytes: usize) -> bool {
        match self {
            MemoryBudget::Unbounded => true,
            MemoryBudget::Bytes(limit) => bytes <= limit,
        }
    }

    /// Is this a real (finite) budget?
    pub fn is_bounded(self) -> bool {
        matches!(self, MemoryBudget::Bytes(_))
    }
}

impl fmt::Display for MemoryBudget {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            MemoryBudget::Unbounded => write!(f, "unbounded"),
            MemoryBudget::Bytes(b) => write!(f, "{b} B"),
        }
    }
}

/// Resident merge working set of an `n`-element hierarchical sort: one
/// `(u32, usize)` tuple per element held across the merge stage. This
/// is the number a [`MemoryBudget`] is compared against — both here and
/// in the planner's budgeted tuner, so the spill decision is one rule
/// everywhere.
pub fn resident_merge_bytes(n: usize) -> usize {
    n.saturating_mul(std::mem::size_of::<(u32, usize)>())
}

/// Peak resident footprint of the *spilling* merge at fanout `fanout`:
/// one decoded block per open reader plus one encode buffer on the
/// writer. This is what frontend admission charges for a spilled sort
/// instead of [`resident_merge_bytes`].
pub fn spill_working_bytes(fanout: usize) -> usize {
    (fanout + 1) * SPILL_BLOCK_ELEMS * std::mem::size_of::<(u32, usize)>()
}

// --- typed errors ---------------------------------------------------------

/// Typed spill-tier failure. Carried inside [`anyhow::Error`] so
/// callers can `downcast_ref::<SpillError>()` (the `AdmitError`
/// convention): a fault anywhere in the spill path surfaces as one of
/// these, never as partial or silently-resident output.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpillError {
    /// The backing device failed (write quota exhausted, reader died,
    /// filesystem error).
    Io {
        /// Run id the operation targeted.
        run: usize,
        /// Backend-specific description.
        detail: String,
    },
    /// The run ended before the declared payload (`need` bytes wanted
    /// at a point where only `have` existed).
    Truncated {
        /// Run id.
        run: usize,
        /// Bytes the decoder needed.
        need: u64,
        /// Bytes the run actually holds.
        have: u64,
    },
    /// A block's FNV-1a checksum did not match its payload.
    Checksum {
        /// Run id.
        run: usize,
        /// Checksum stored in the run.
        want: u64,
        /// Checksum recomputed from the payload.
        got: u64,
    },
    /// The run violates the format contract (bad magic/version/count,
    /// trailing bytes, element-count mismatch).
    Malformed {
        /// Run id.
        run: usize,
        /// What was wrong.
        detail: String,
    },
}

impl fmt::Display for SpillError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpillError::Io { run, detail } => write!(f, "spill run {run}: I/O failure: {detail}"),
            SpillError::Truncated { run, need, have } => {
                write!(f, "spill run {run}: truncated: need {need} bytes, have {have}")
            }
            SpillError::Checksum { run, want, got } => write!(
                f,
                "spill run {run}: checksum mismatch: stored {want:#018x}, computed {got:#018x}"
            ),
            SpillError::Malformed { run, detail } => {
                write!(f, "spill run {run}: malformed: {detail}")
            }
        }
    }
}

impl std::error::Error for SpillError {}

fn spill_err(e: SpillError) -> anyhow::Error {
    anyhow::Error::new(e)
}

// --- RunStore -------------------------------------------------------------

/// Backend for spilled runs: an append-only byte store addressed by run
/// id, with random-access reads. `&self` methods (interior mutability)
/// so one store serves a writer and several block readers at once;
/// `Send + Sync` so the fleet path can share it across shard
/// collection.
pub trait RunStore: Send + Sync {
    /// Append `bytes` to run `id`, creating the run on first append.
    fn append(&self, id: usize, bytes: &[u8]) -> Result<()>;

    /// Read exactly `buf.len()` bytes of run `id` starting at `offset`.
    /// Reading past the end of the run is a typed
    /// [`SpillError::Truncated`].
    fn read_at(&self, id: usize, offset: u64, buf: &mut [u8]) -> Result<()>;

    /// Current byte length of run `id` (0 for a run never appended to).
    fn run_len(&self, id: usize) -> Result<u64>;

    /// Total bytes ever appended across all runs — what frontend
    /// admission and the CLI report as the spilled footprint.
    fn spilled_bytes(&self) -> u64;
}

/// In-memory [`RunStore`] for deterministic, disk-free tests, with
/// `FlakyTransport`-style fault hooks: a write quota (ENOSPC
/// mid-spill), a read fuse (reader death mid-merge), and direct
/// truncate/corrupt mutators for format-fault tests.
#[derive(Default)]
pub struct MemoryRunStore {
    spill_runs: Mutex<HashMap<usize, Vec<u8>>>,
    total: AtomicU64,
    /// Bytes of append the store still accepts; `u64::MAX` = no quota.
    write_quota: AtomicU64,
    /// `read_at` calls before the injected reader death; `u64::MAX` =
    /// no fuse.
    read_fuse: AtomicU64,
}

impl MemoryRunStore {
    pub fn new() -> Self {
        MemoryRunStore {
            spill_runs: Mutex::new(HashMap::new()),
            total: AtomicU64::new(0),
            write_quota: AtomicU64::new(u64::MAX),
            read_fuse: AtomicU64::new(u64::MAX),
        }
    }

    /// Arm the ENOSPC fault: appends beyond `bytes` further bytes fail
    /// with a typed [`SpillError::Io`].
    pub fn set_write_quota(&self, bytes: u64) {
        self.write_quota.store(bytes, Ordering::SeqCst);
    }

    /// Arm the reader-death fault: the `calls + 1`-th `read_at` from
    /// now fails with a typed [`SpillError::Io`].
    pub fn fail_reads_after(&self, calls: u64) {
        self.read_fuse.store(calls, Ordering::SeqCst);
    }

    /// Truncate run `id` to `len` bytes (format-fault injection).
    pub fn truncate_run(&self, id: usize, len: usize) {
        let mut runs = self.spill_runs.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(run) = runs.get_mut(&id) {
            run.truncate(len);
        }
    }

    /// Flip one byte of run `id` at `at` (checksum-fault injection).
    pub fn corrupt_run(&self, id: usize, at: usize) {
        let mut runs = self.spill_runs.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(b) = runs.get_mut(&id).and_then(|run| run.get_mut(at)) {
            *b ^= 0xFF;
        }
    }
}

impl RunStore for MemoryRunStore {
    fn append(&self, id: usize, bytes: &[u8]) -> Result<()> {
        let want = bytes.len() as u64;
        // Quota check-and-debit; single fetch_update keeps concurrent
        // writers from double-spending the last bytes.
        let debited = self
            .write_quota
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |q| {
                if q == u64::MAX {
                    Some(q) // no quota armed
                } else {
                    q.checked_sub(want)
                }
            })
            .is_ok();
        if !debited {
            return Err(spill_err(SpillError::Io {
                run: id,
                detail: "injected fault: spill device full (ENOSPC)".into(),
            }));
        }
        let mut runs = self.spill_runs.lock().unwrap_or_else(PoisonError::into_inner);
        runs.entry(id).or_default().extend_from_slice(bytes);
        drop(runs);
        self.total.fetch_add(want, Ordering::Relaxed);
        Ok(())
    }

    fn read_at(&self, id: usize, offset: u64, buf: &mut [u8]) -> Result<()> {
        let blown = self
            .read_fuse
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |f| {
                if f == u64::MAX {
                    Some(f)
                } else {
                    f.checked_sub(1)
                }
            })
            .is_err();
        if blown {
            return Err(spill_err(SpillError::Io {
                run: id,
                detail: "injected fault: spill reader died mid-merge".into(),
            }));
        }
        let runs = self.spill_runs.lock().unwrap_or_else(PoisonError::into_inner);
        let run = runs.get(&id).map(Vec::as_slice).unwrap_or(&[]);
        let end = offset.saturating_add(buf.len() as u64);
        let src = usize::try_from(offset)
            .ok()
            .and_then(|start| run.get(start..start + buf.len()))
            .ok_or_else(|| {
                spill_err(SpillError::Truncated { run: id, need: end, have: run.len() as u64 })
            })?;
        buf.copy_from_slice(src);
        Ok(())
    }

    fn run_len(&self, id: usize) -> Result<u64> {
        let runs = self.spill_runs.lock().unwrap_or_else(PoisonError::into_inner);
        Ok(runs.get(&id).map_or(0, |r| r.len() as u64))
    }

    fn spilled_bytes(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }
}

/// Monotone suffix so two stores in one process never share a
/// directory (no clock or RNG involved: deterministic under test).
static SPILL_DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Temp-directory [`RunStore`]: one `run-<id>` file per run under a
/// process-unique directory in [`std::env::temp_dir`], removed on drop.
/// Appends open in append mode and reads open/seek/read per call, so no
/// file handle (and no lock) is held across calls — several readers and
/// a writer can interleave freely.
pub struct TempDirRunStore {
    dir: PathBuf,
    total: AtomicU64,
}

impl TempDirRunStore {
    /// Create the backing directory
    /// (`memsort-spill-<pid>-<seq>` under the OS temp dir).
    pub fn new() -> Result<Self> {
        let seq = SPILL_DIR_SEQ.fetch_add(1, Ordering::Relaxed);
        let dir = std::env::temp_dir().join(format!("memsort-spill-{}-{seq}", std::process::id()));
        fs::create_dir_all(&dir)
            .map_err(|e| anyhow!("creating spill dir {}: {e}", dir.display()))?;
        Ok(TempDirRunStore { dir, total: AtomicU64::new(0) })
    }

    fn run_path(&self, id: usize) -> PathBuf {
        self.dir.join(format!("run-{id}"))
    }

    /// Where the runs live (surfaced by the CLI's spill report).
    pub fn dir(&self) -> &std::path::Path {
        &self.dir
    }
}

impl RunStore for TempDirRunStore {
    fn append(&self, id: usize, bytes: &[u8]) -> Result<()> {
        let path = self.run_path(id);
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| spill_err(SpillError::Io { run: id, detail: format!("open: {e}") }))?;
        f.write_all(bytes)
            .map_err(|e| spill_err(SpillError::Io { run: id, detail: format!("append: {e}") }))?;
        self.total.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(())
    }

    fn read_at(&self, id: usize, offset: u64, buf: &mut [u8]) -> Result<()> {
        let path = self.run_path(id);
        let mut f = std::fs::File::open(&path)
            .map_err(|e| spill_err(SpillError::Io { run: id, detail: format!("open: {e}") }))?;
        let have = f
            .metadata()
            .map_err(|e| spill_err(SpillError::Io { run: id, detail: format!("stat: {e}") }))?
            .len();
        f.seek(SeekFrom::Start(offset))
            .map_err(|e| spill_err(SpillError::Io { run: id, detail: format!("seek: {e}") }))?;
        f.read_exact(buf).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                spill_err(SpillError::Truncated {
                    run: id,
                    need: offset.saturating_add(buf.len() as u64),
                    have,
                })
            } else {
                spill_err(SpillError::Io { run: id, detail: format!("read: {e}") })
            }
        })
    }

    fn run_len(&self, id: usize) -> Result<u64> {
        match fs::metadata(self.run_path(id)) {
            Ok(m) => Ok(m.len()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(0),
            Err(e) => Err(spill_err(SpillError::Io { run: id, detail: format!("stat: {e}") })),
        }
    }

    fn spilled_bytes(&self) -> u64 {
        self.total.load(Ordering::Relaxed)
    }
}

impl Drop for TempDirRunStore {
    fn drop(&mut self) {
        // Best-effort cleanup; a leaked temp dir must not fail a sort.
        let _ = fs::remove_dir_all(&self.dir);
    }
}

// --- codec (the wire chunked-LE idiom, local to the run format) -----------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Chunked-LE slice encode (the `wire::put_u32_slice` shape): resize
/// once, then blit each element into its 4-byte window.
fn put_u32_slice(buf: &mut Vec<u8>, v: &[u32]) {
    let at = buf.len();
    buf.resize(at + 4 * v.len(), 0);
    if let Some(dst) = buf.get_mut(at..) {
        for (d, &x) in dst.chunks_exact_mut(4).zip(v) {
            d.copy_from_slice(&x.to_le_bytes());
        }
    }
}

/// Chunked-LE encode of rows as `u64` (lossless from `usize`).
fn put_u64_slice(buf: &mut Vec<u8>, v: &[u64]) {
    let at = buf.len();
    buf.resize(at + 8 * v.len(), 0);
    if let Some(dst) = buf.get_mut(at..) {
        for (d, &x) in dst.chunks_exact_mut(8).zip(v) {
            d.copy_from_slice(&x.to_le_bytes());
        }
    }
}

fn read_u32_le(bytes: &[u8]) -> u32 {
    let mut arr = [0u8; 4];
    if let Some(src) = bytes.get(..4) {
        arr.copy_from_slice(src);
    }
    u32::from_le_bytes(arr)
}

fn read_u64_le(bytes: &[u8]) -> u64 {
    let mut arr = [0u8; 8];
    if let Some(src) = bytes.get(..8) {
        arr.copy_from_slice(src);
    }
    u64::from_le_bytes(arr)
}

/// FNV-1a 64-bit over `bytes` — the run format's block checksum.
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

// --- RunWriter ------------------------------------------------------------

/// Streaming encoder for one run: buffers at most one block
/// ([`SPILL_BLOCK_ELEMS`] elements), appending each completed block —
/// count, chunked-LE values, chunked-LE rows, FNV-1a checksum — to the
/// store. [`RunWriter::finish`] flushes the tail block and enforces the
/// header's declared element count.
pub struct RunWriter<'s> {
    store: &'s dyn RunStore,
    id: usize,
    declared: u64,
    written: u64,
    vals: Vec<u32>,
    rows: Vec<u64>,
}

impl<'s> RunWriter<'s> {
    /// Start run `id`, writing the header that declares `total`
    /// elements.
    pub fn create(store: &'s dyn RunStore, id: usize, total: u64) -> Result<Self> {
        let mut header = Vec::with_capacity(HEADER_BYTES as usize);
        put_u32(&mut header, RUN_MAGIC);
        put_u32(&mut header, RUN_VERSION);
        put_u64(&mut header, total);
        store.append(id, &header)?;
        Ok(RunWriter {
            store,
            id,
            declared: total,
            written: 0,
            vals: Vec::with_capacity(SPILL_BLOCK_ELEMS),
            rows: Vec::with_capacity(SPILL_BLOCK_ELEMS),
        })
    }

    /// Append one `(value, row)` element, flushing a block when full.
    pub fn push(&mut self, item: (u32, usize)) -> Result<()> {
        self.vals.push(item.0);
        self.rows.push(item.1 as u64);
        self.written += 1;
        if self.vals.len() == SPILL_BLOCK_ELEMS {
            self.flush_block()?;
        }
        Ok(())
    }

    fn flush_block(&mut self) -> Result<()> {
        if self.vals.is_empty() {
            return Ok(());
        }
        let count = self.vals.len();
        let mut block = Vec::with_capacity(4 + count * ELEM_BYTES + 8);
        put_u32(&mut block, count as u32);
        put_u32_slice(&mut block, &self.vals);
        put_u64_slice(&mut block, &self.rows);
        let sum = fnv1a64(&block);
        put_u64(&mut block, sum);
        self.store.append(self.id, &block)?;
        self.vals.clear();
        self.rows.clear();
        Ok(())
    }

    /// Flush the tail block and close the run, returning the element
    /// count. Writing a different count than the header declared is a
    /// typed [`SpillError::Malformed`].
    pub fn finish(mut self) -> Result<u64> {
        self.flush_block()?;
        if self.written != self.declared {
            return Err(spill_err(SpillError::Malformed {
                run: self.id,
                detail: format!("header declared {} elements, wrote {}", self.declared, self.written),
            }));
        }
        Ok(self.written)
    }
}

/// Encode a whole in-memory run into the store (the chunk-spill path of
/// the hierarchical assembly).
pub fn write_run(store: &dyn RunStore, id: usize, items: &[(u32, usize)]) -> Result<u64> {
    let mut w = RunWriter::create(store, id, items.len() as u64)?;
    for &item in items {
        w.push(item)?;
    }
    w.finish()
}

// --- RunReader ------------------------------------------------------------

/// Read and validate run `id`'s header, returning the declared element
/// count. Shared by [`RunReader::open`] and the merge's run census.
fn read_header(store: &dyn RunStore, id: usize) -> Result<u64> {
    let len = store.run_len(id)?;
    if len < HEADER_BYTES {
        return Err(spill_err(SpillError::Truncated { run: id, need: HEADER_BYTES, have: len }));
    }
    let mut header = [0u8; HEADER_BYTES as usize];
    store.read_at(id, 0, &mut header)?;
    let magic = read_u32_le(&header);
    if magic != RUN_MAGIC {
        return Err(spill_err(SpillError::Malformed {
            run: id,
            detail: format!("bad magic {magic:#010x}"),
        }));
    }
    let version = read_u32_le(header.get(4..).unwrap_or(&[]));
    if version != RUN_VERSION {
        return Err(spill_err(SpillError::Malformed {
            run: id,
            detail: format!("unsupported version {version}"),
        }));
    }
    Ok(read_u64_le(header.get(8..).unwrap_or(&[])))
}

/// Bounded-buffer decoder for one run: holds exactly one decoded block
/// in memory, verifying each block's checksum as it is refilled and the
/// absence of trailing bytes at exhaustion.
pub struct RunReader<'s> {
    store: &'s dyn RunStore,
    id: usize,
    total: u64,
    consumed: u64,
    offset: u64,
    len: u64,
    block: Vec<(u32, usize)>,
    at: usize,
}

impl<'s> RunReader<'s> {
    /// Open run `id`: validate the header and decode the first block.
    pub fn open(store: &'s dyn RunStore, id: usize) -> Result<Self> {
        let total = read_header(store, id)?;
        let len = store.run_len(id)?;
        let mut r = RunReader {
            store,
            id,
            total,
            consumed: 0,
            offset: HEADER_BYTES,
            len,
            block: Vec::new(),
            at: 0,
        };
        r.refill()?;
        Ok(r)
    }

    /// Elements the header declared.
    pub fn total(&self) -> u64 {
        self.total
    }

    /// The current head element, or `None` when the run is exhausted.
    pub fn head(&self) -> Option<(u32, usize)> {
        self.block.get(self.at).copied()
    }

    /// Consume the current head, decoding the next block when this one
    /// drains. A no-op on an exhausted run.
    pub fn advance(&mut self) -> Result<()> {
        if self.at < self.block.len() {
            self.at += 1;
            self.consumed += 1;
        }
        if self.at >= self.block.len() {
            self.refill()?;
        }
        Ok(())
    }

    /// Decode the next block into the buffer (empty at exhaustion,
    /// after checking for trailing bytes).
    fn refill(&mut self) -> Result<()> {
        self.block.clear();
        self.at = 0;
        let remaining = self.total - self.consumed;
        if remaining == 0 {
            if self.offset != self.len {
                return Err(spill_err(SpillError::Malformed {
                    run: self.id,
                    detail: format!("{} trailing bytes after payload", self.len - self.offset),
                }));
            }
            return Ok(());
        }
        let need_count = self.offset.saturating_add(4);
        if need_count > self.len {
            return Err(spill_err(SpillError::Truncated {
                run: self.id,
                need: need_count,
                have: self.len,
            }));
        }
        let mut count_bytes = [0u8; 4];
        self.store.read_at(self.id, self.offset, &mut count_bytes)?;
        let count = read_u32_le(&count_bytes) as usize;
        if count == 0 || count > SPILL_BLOCK_ELEMS || count as u64 > remaining {
            return Err(spill_err(SpillError::Malformed {
                run: self.id,
                detail: format!("block count {count} (remaining {remaining})"),
            }));
        }
        let payload_len = count * ELEM_BYTES + 8;
        let need = need_count.saturating_add(payload_len as u64);
        if need > self.len {
            return Err(spill_err(SpillError::Truncated {
                run: self.id,
                need,
                have: self.len,
            }));
        }
        let mut payload = vec![0u8; payload_len];
        self.store.read_at(self.id, self.offset + 4, &mut payload)?;
        let body_len = count * ELEM_BYTES;
        let mut sum_input = Vec::with_capacity(4 + body_len);
        sum_input.extend_from_slice(&count_bytes);
        sum_input.extend_from_slice(payload.get(..body_len).unwrap_or(&[]));
        let got = fnv1a64(&sum_input);
        let want = read_u64_le(payload.get(body_len..).unwrap_or(&[]));
        if got != want {
            return Err(spill_err(SpillError::Checksum { run: self.id, want, got }));
        }
        let vals = payload.get(..count * 4).unwrap_or(&[]);
        let rows = payload.get(count * 4..body_len).unwrap_or(&[]);
        for (v, r) in vals.chunks_exact(4).zip(rows.chunks_exact(8)) {
            let value = read_u32_le(v);
            let row64 = read_u64_le(r);
            let row = usize::try_from(row64).map_err(|_| {
                spill_err(SpillError::Malformed {
                    run: self.id,
                    detail: format!("row {row64} exceeds this host's usize"),
                })
            })?;
            self.block.push((value, row));
        }
        self.offset = need;
        Ok(())
    }
}

// --- SourceTree: the loser tree over run readers --------------------------

/// [`super::merge::LoserTree`] ported verbatim over [`RunReader`]
/// sources: same construction replay order (`(0..k).rev()`), same
/// first-empty-slot parking, same Some/Some-only comparison metering,
/// same `(item, run_index)` tie-break — so the emitted sequence AND the
/// comparison count match the resident tree exactly. The only
/// difference is that advancing a source performs block I/O, so
/// [`SourceTree::pop`] is fallible.
struct SourceTree<'a, 's> {
    readers: &'a mut [RunReader<'s>],
    tree: Vec<usize>,
    comparisons: u64,
}

impl<'a, 's> SourceTree<'a, 's> {
    fn new(readers: &'a mut [RunReader<'s>]) -> Self {
        let k = readers.len();
        let mut st = SourceTree { readers, tree: vec![EMPTY; k.max(1)], comparisons: 0 };
        for leaf in (0..k).rev() {
            st.replay(leaf);
        }
        st
    }

    fn comparisons(&self) -> u64 {
        self.comparisons
    }

    /// Emit the next element of the merged order, or `Ok(None)` when
    /// every source is exhausted.
    fn pop(&mut self) -> Result<Option<(u32, usize)>> {
        let w = self.tree.first().copied().unwrap_or(EMPTY);
        let Some(reader) = self.readers.get_mut(w) else {
            return Ok(None);
        };
        let Some(item) = reader.head() else {
            return Ok(None);
        };
        reader.advance()?;
        self.replay(w);
        Ok(Some(item))
    }

    /// Head of source `i` as a tie-broken key; `None` = exhausted.
    fn key(&self, i: usize) -> Option<((u32, usize), usize)> {
        self.readers.get(i).and_then(RunReader::head).map(|v| (v, i))
    }

    fn beats(&mut self, a: usize, b: usize) -> bool {
        match (self.key(a), self.key(b)) {
            (None, _) => false,
            (Some(_), None) => true,
            (Some(x), Some(y)) => {
                self.comparisons += 1;
                x < y
            }
        }
    }

    fn replay(&mut self, leaf: usize) {
        let k = self.readers.len();
        let mut winner = leaf;
        let mut node = (leaf + k) / 2;
        while node > 0 {
            let held = self.tree.get(node).copied().unwrap_or(EMPTY);
            if held == EMPTY {
                if let Some(slot) = self.tree.get_mut(node) {
                    *slot = winner;
                }
                return;
            }
            if self.beats(held, winner) {
                if let Some(slot) = self.tree.get_mut(node) {
                    *slot = winner;
                }
                winner = held;
            }
            node /= 2;
        }
        if let Some(slot) = self.tree.first_mut() {
            *slot = winner;
        }
    }
}

// --- SpillMerge -----------------------------------------------------------

/// Result of the external k-way merge — the spill tier's counterpart of
/// `merge::KWayMerged`, with identical semantics for every field.
#[derive(Clone, Debug)]
pub struct SpillMerged {
    /// Globally merged `(value, row)` stream.
    pub merged: Vec<(u32, usize)>,
    /// Comparator operations actually performed (all passes) — equal to
    /// the resident tree's count by construction.
    pub comparisons: u64,
    /// Merge passes executed (`ceil(log_fanout(runs))` over non-empty
    /// runs).
    pub passes: u32,
    /// Modelled merge-network latency: one element per cycle per pass
    /// (`total · passes`, the resident model).
    pub cycles: u64,
}

/// Merge runs `0..runs` of `store` through the fixed fanout-`fanout`
/// tree, multi-pass and out of core: every non-final pass streams each
/// group through a [`RunWriter`] into a fresh run id (`runs`,
/// `runs + 1`, …), the final pass streams into memory. Grouping, pass
/// structure, tie-breaks and comparison metering replicate
/// `merge::merge_sorted_runs` exactly (empty runs dropped up front,
/// singleton groups pass through free), so the output is byte-identical
/// to the resident merge of the same runs.
pub fn spill_merge(store: &dyn RunStore, runs: usize, fanout: usize) -> Result<SpillMerged> {
    if fanout < 2 {
        return Err(anyhow!("merge fanout must be at least 2, got {fanout}"));
    }
    let mut ids: Vec<usize> = Vec::with_capacity(runs);
    let mut total: u64 = 0;
    for id in 0..runs {
        let t = read_header(store, id)?;
        total += t;
        if t > 0 {
            ids.push(id);
        }
    }
    let mut merged: Vec<(u32, usize)> = Vec::new();
    let mut comparisons = 0u64;
    let mut passes = 0u32;
    let mut next_id = runs;
    while ids.len() > 1 {
        passes += 1;
        if ids.len() <= fanout {
            // Final pass: one group, streamed straight into memory.
            let mut readers = open_group(store, &ids)?;
            let mut tree = SourceTree::new(&mut readers);
            merged.reserve(total as usize);
            while let Some(item) = tree.pop()? {
                merged.push(item);
            }
            comparisons += tree.comparisons();
            ids.clear();
            break;
        }
        let mut next_ids = Vec::with_capacity(ids.len().div_ceil(fanout));
        for group in ids.chunks(fanout) {
            if group.len() == 1 {
                // Singleton groups pass through for free (no I/O),
                // exactly like the resident pass structure.
                next_ids.extend_from_slice(group);
                continue;
            }
            let mut readers = open_group(store, group)?;
            let group_total: u64 = readers.iter().map(RunReader::total).sum();
            let mut writer = RunWriter::create(store, next_id, group_total)?;
            let mut tree = SourceTree::new(&mut readers);
            while let Some(item) = tree.pop()? {
                writer.push(item)?;
            }
            comparisons += tree.comparisons();
            writer.finish()?;
            next_ids.push(next_id);
            next_id += 1;
        }
        ids = next_ids;
    }
    if let Some(&last) = ids.first() {
        // Zero passes (a single non-empty run): read it back verbatim.
        let mut r = RunReader::open(store, last)?;
        merged.reserve(total as usize);
        while let Some(item) = r.head() {
            merged.push(item);
            r.advance()?;
        }
    }
    if merged.len() as u64 != total {
        return Err(spill_err(SpillError::Malformed {
            run: next_id.saturating_sub(1),
            detail: format!("merged {} elements, expected {total}", merged.len()),
        }));
    }
    Ok(SpillMerged { merged, comparisons, passes, cycles: total * passes as u64 })
}

fn open_group<'s>(store: &'s dyn RunStore, ids: &[usize]) -> Result<Vec<RunReader<'s>>> {
    ids.iter().map(|&id| RunReader::open(store, id)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sorter::merge::merge_sorted_runs;

    /// Deterministic pseudo-random runs: a tiny LCG, no RNG dependency.
    fn gen_runs(seed: u64, runs: usize, max_len: usize) -> Vec<Vec<(u32, usize)>> {
        let mut x = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        let mut next = move || {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            x >> 33
        };
        let mut row = 0usize;
        (0..runs)
            .map(|_| {
                let len = (next() as usize) % (max_len + 1);
                let mut run: Vec<(u32, usize)> = (0..len)
                    .map(|_| {
                        row += 1;
                        (next() as u32, row - 1)
                    })
                    .collect();
                run.sort_unstable();
                run
            })
            .collect()
    }

    fn store_with(runs: &[Vec<(u32, usize)>]) -> MemoryRunStore {
        let store = MemoryRunStore::new();
        for (id, run) in runs.iter().enumerate() {
            write_run(&store, id, run).unwrap();
        }
        store
    }

    #[test]
    fn roundtrip_preserves_every_element() {
        let store = MemoryRunStore::new();
        for (id, len) in [(0usize, 0usize), (1, 1), (2, SPILL_BLOCK_ELEMS), (3, 2500)] {
            let run: Vec<(u32, usize)> = (0..len).map(|i| (i as u32, 7 * i + 1)).collect();
            assert_eq!(write_run(&store, id, &run).unwrap(), len as u64);
            let mut r = RunReader::open(&store, id).unwrap();
            assert_eq!(r.total(), len as u64);
            let mut back = Vec::new();
            while let Some(item) = r.head() {
                back.push(item);
                r.advance().unwrap();
            }
            assert_eq!(back, run, "len={len}");
        }
        assert!(store.spilled_bytes() > 0);
    }

    #[test]
    fn tempdir_backend_roundtrips_and_cleans_up() {
        let dir;
        {
            let store = TempDirRunStore::new().unwrap();
            dir = store.dir().to_path_buf();
            assert!(dir.exists());
            let run: Vec<(u32, usize)> = (0..3000).map(|i| (i as u32 / 3, i)).collect();
            write_run(&store, 0, &run).unwrap();
            assert_eq!(store.run_len(0).unwrap(), store.spilled_bytes());
            let mut r = RunReader::open(&store, 0).unwrap();
            let mut back = Vec::new();
            while let Some(item) = r.head() {
                back.push(item);
                r.advance().unwrap();
            }
            assert_eq!(back, run);
        }
        assert!(!dir.exists(), "drop removes the spill dir");
    }

    #[test]
    fn spill_merge_is_byte_identical_to_resident_merge() {
        for seed in 1..6u64 {
            for fanout in [2usize, 4, 8] {
                let runs = gen_runs(seed, 11, 300);
                let store = store_with(&runs);
                let resident = merge_sorted_runs(runs.clone(), fanout);
                let spilled = spill_merge(&store, runs.len(), fanout).unwrap();
                assert_eq!(spilled.merged, resident.merged, "seed={seed} fanout={fanout}");
                assert_eq!(spilled.comparisons, resident.comparisons, "seed={seed} f={fanout}");
                assert_eq!(spilled.passes, resident.passes);
                assert_eq!(spilled.cycles, resident.cycles);
            }
        }
    }

    #[test]
    fn degenerate_merges_are_exact() {
        // No runs at all.
        let store = MemoryRunStore::new();
        let out = spill_merge(&store, 0, 4).unwrap();
        assert!(out.merged.is_empty());
        assert_eq!((out.comparisons, out.passes, out.cycles), (0, 0, 0));
        // One run: zero passes, read back verbatim.
        let run: Vec<(u32, usize)> = (0..10).map(|i| (i as u32, i)).collect();
        let store = store_with(std::slice::from_ref(&run));
        let out = spill_merge(&store, 1, 4).unwrap();
        assert_eq!(out.merged, run);
        assert_eq!((out.passes, out.cycles), (0, 0));
        // All-empty runs.
        let store = store_with(&[Vec::new(), Vec::new()]);
        let out = spill_merge(&store, 2, 2).unwrap();
        assert!(out.merged.is_empty());
        assert_eq!(out.passes, 0);
        // Bad fanout is an error, not a panic.
        assert!(spill_merge(&store, 2, 1).is_err());
    }

    #[test]
    fn truncated_run_is_a_typed_error() {
        let run: Vec<(u32, usize)> = (0..100).map(|i| (i as u32, i)).collect();
        let store = store_with(std::slice::from_ref(&run));
        let full = store.run_len(0).unwrap() as usize;
        store.truncate_run(0, full - 5);
        let err = spill_merge(&store, 1, 2).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Truncated { .. })),
            "{err}"
        );
        // Header-level truncation too.
        store.truncate_run(0, 7);
        let err = RunReader::open(&store, 0).unwrap_err();
        assert!(matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Truncated { .. })));
    }

    #[test]
    fn corrupted_block_is_a_checksum_error() {
        let run: Vec<(u32, usize)> = (0..100).map(|i| (i as u32, i)).collect();
        let store = store_with(std::slice::from_ref(&run));
        store.corrupt_run(0, HEADER_BYTES as usize + 10);
        let err = spill_merge(&store, 1, 2).unwrap_err();
        assert!(
            matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Checksum { .. })),
            "{err}"
        );
    }

    #[test]
    fn enospc_mid_spill_is_a_typed_error() {
        let store = MemoryRunStore::new();
        store.set_write_quota(100);
        let run: Vec<(u32, usize)> = (0..2000).map(|i| (i as u32, i)).collect();
        let err = write_run(&store, 0, &run).unwrap_err();
        match err.downcast_ref::<SpillError>() {
            Some(SpillError::Io { detail, .. }) => assert!(detail.contains("ENOSPC"), "{detail}"),
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn reader_death_mid_merge_is_a_typed_error() {
        let runs = gen_runs(3, 6, 200);
        let store = store_with(&runs);
        store.fail_reads_after(4);
        let err = spill_merge(&store, runs.len(), 2).unwrap_err();
        match err.downcast_ref::<SpillError>() {
            Some(SpillError::Io { detail, .. }) => {
                assert!(detail.contains("reader died"), "{detail}")
            }
            other => panic!("expected Io, got {other:?}"),
        }
    }

    #[test]
    fn trailing_bytes_are_rejected() {
        let run: Vec<(u32, usize)> = (0..5).map(|i| (i as u32, i)).collect();
        let store = store_with(std::slice::from_ref(&run));
        store.append(0, &[0xAB, 0xCD]).unwrap();
        let mut r = RunReader::open(&store, 0).unwrap();
        let err = loop {
            match r.advance() {
                Ok(()) if r.head().is_none() => panic!("trailing bytes accepted"),
                Ok(()) => {}
                Err(e) => break e,
            }
        };
        assert!(
            matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Malformed { .. })),
            "{err}"
        );
    }

    #[test]
    fn budget_and_footprints() {
        assert!(MemoryBudget::Unbounded.fits(usize::MAX));
        assert!(!MemoryBudget::Unbounded.is_bounded());
        let b = MemoryBudget::Bytes(1024);
        assert!(b.fits(1024) && !b.fits(1025) && b.is_bounded());
        assert_eq!(resident_merge_bytes(1000), 16_000);
        assert_eq!(spill_working_bytes(4), 5 * SPILL_BLOCK_ELEMS * 16);
        assert_eq!(format!("{b}"), "1024 B");
        assert_eq!(format!("{}", MemoryBudget::Unbounded), "unbounded");
    }

    #[test]
    fn fnv_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}

//! Digital merge hardware: the conventional merge *sorter* used as the
//! non-in-memory comparison point (§V: 246.1 Kµm², 825.9 mW, 3.2× the
//! baseline's speed at N=1024), plus the k-way **merge stage** of the
//! hierarchical out-of-bank pipeline (a loser-tree merge network that
//! combines per-bank sorted runs into the global order).
//!
//! Hardware model shared by both: a fully pipelined merge tree streams
//! one element per cycle per pass. The sorter does `ceil(log2 N)` binary
//! passes over a length-N block — `N · ceil(log2 N)` cycles, exactly
//! 10 cycles/number at N=1024, reproducing the paper's 3.2× speed over
//! the 32-cycle baseline. The k-way stage does `ceil(log_f R)` passes to
//! reduce R runs through fanout-f merge units ([`model_merge_cycles`]).
//! Functionally we run real merges and meter comparator activity, so the
//! cycle models are backed by actual sorts.

use super::{InMemorySorter, SortOutput, SortStats};
use crate::coordinator::planner::schedule;

/// Sentinel for an empty loser-tree slot (pre-initialization).
const EMPTY: usize = usize::MAX;

/// Cycle-modelled digital merge sorter.
#[derive(Clone, Debug, Default)]
pub struct MergeSorter {
    /// Comparator operations performed by the last sort (metered).
    pub comparisons: u64,
}

impl MergeSorter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Latency of a length-`n` block in cycles under the pipeline model.
    pub fn model_cycles(n: usize) -> u64 {
        if n <= 1 {
            return n as u64;
        }
        let passes = usize::BITS - (n - 1).leading_zeros(); // ceil(log2 n)
        n as u64 * passes as u64
    }

    /// Bottom-up merge sort over (value, original index) pairs, metering
    /// comparator activity. Stable, so `order` breaks ties by row index.
    fn merge_sort(&mut self, data: &[u32]) -> Vec<(u32, usize)> {
        let mut cur: Vec<(u32, usize)> = data.iter().copied().zip(0..).collect();
        let mut buf = cur.clone();
        let n = cur.len();
        let mut width = 1;
        while width < n {
            let mut lo = 0;
            while lo < n {
                let mid = (lo + width).min(n);
                let hi = (lo + 2 * width).min(n);
                let (mut i, mut j, mut o) = (lo, mid, lo);
                while i < mid && j < hi {
                    self.comparisons += 1;
                    if cur[i].0 <= cur[j].0 {
                        buf[o] = cur[i];
                        i += 1;
                    } else {
                        buf[o] = cur[j];
                        j += 1;
                    }
                    o += 1;
                }
                buf[o..o + (mid - i)].copy_from_slice(&cur[i..mid]);
                let o2 = o + (mid - i);
                buf[o2..o2 + (hi - j)].copy_from_slice(&cur[j..hi]);
                lo = hi;
            }
            std::mem::swap(&mut cur, &mut buf);
            width *= 2;
        }
        cur
    }
}

impl InMemorySorter for MergeSorter {
    fn sort_with_stats(&mut self, data: &[u32]) -> SortOutput {
        self.comparisons = 0;
        let pairs = self.merge_sort(data);
        let stats = SortStats {
            // The cycle model is surfaced through `crs` so that
            // `SortStats::cycles()` reports the modelled latency uniformly
            // across sorter kinds (a merge sorter has no actual CRs).
            crs: Self::model_cycles(data.len()),
            iterations: data.len() as u64,
            ..Default::default()
        };
        SortOutput {
            sorted: pairs.iter().map(|&(v, _)| v).collect(),
            order: pairs.iter().map(|&(_, i)| i).collect(),
            stats,
        }
    }

    fn name(&self) -> &'static str {
        "merge-digital"
    }
}

/// Streaming `k`-way merger over sorted runs, implemented as a classic
/// array loser tree: `k` leaves (one per run), `k` internal slots
/// holding match losers, winner at slot 0. Each [`LoserTree::pop`]
/// emits the global minimum and replays exactly one leaf-to-root path
/// (`ceil(log2 k)` comparisons), which is what a hardware fanout-`k`
/// merge unit does per output cycle.
///
/// Items only need `Copy + Ord`: the hierarchical pipeline merges
/// `(value, original_index)` runs (so ties break by original position,
/// keeping the global argsort stable), the planner merges plain `u32`
/// runs. Remaining ties break by run index.
pub struct LoserTree<'a, T> {
    runs: &'a [Vec<T>],
    /// Cursor into each run.
    pos: Vec<usize>,
    /// Internal nodes (losers); `tree[0]` is the current overall winner.
    tree: Vec<usize>,
    comparisons: u64,
}

impl<'a, T: Copy + Ord> LoserTree<'a, T> {
    /// Build the tournament over `runs` (each must be sorted ascending).
    pub fn new(runs: &'a [Vec<T>]) -> Self {
        let k = runs.len();
        let mut lt = LoserTree {
            runs,
            pos: vec![0; k],
            tree: vec![EMPTY; k.max(1)],
            comparisons: 0,
        };
        for leaf in (0..k).rev() {
            lt.replay(leaf);
        }
        lt
    }

    /// Comparator operations performed so far.
    pub fn comparisons(&self) -> u64 {
        self.comparisons
    }

    /// Emit the next element of the merged order, or `None` when every
    /// run is exhausted.
    pub fn pop(&mut self) -> Option<T> {
        let w = self.tree[0];
        let item = *self.runs.get(w)?.get(self.pos[w])?;
        self.pos[w] += 1;
        self.replay(w);
        Some(item)
    }

    /// Current head of run `i` as a tie-broken key; `None` = exhausted
    /// (which compares greater than every real key).
    fn key(&self, i: usize) -> Option<(T, usize)> {
        self.runs.get(i)?.get(self.pos[i]).map(|&v| (v, i))
    }

    /// Does run `a`'s head sort strictly before run `b`'s head?
    fn beats(&mut self, a: usize, b: usize) -> bool {
        match (self.key(a), self.key(b)) {
            (None, _) => false,
            (Some(_), None) => true,
            (Some(x), Some(y)) => {
                self.comparisons += 1;
                x < y
            }
        }
    }

    /// Replay the matches on `leaf`'s path to the root. During
    /// construction a contestant parks in the first empty slot it meets
    /// (its first match is pending until the opponent arrives); once the
    /// tree is full this is the standard loser-tree update.
    fn replay(&mut self, leaf: usize) {
        let k = self.runs.len();
        let mut winner = leaf;
        let mut node = (leaf + k) / 2;
        while node > 0 {
            let held = self.tree[node];
            if held == EMPTY {
                self.tree[node] = winner;
                return;
            }
            if self.beats(held, winner) {
                self.tree[node] = winner;
                winner = held;
            }
            node /= 2;
        }
        self.tree[0] = winner;
    }
}

/// Result of merging sorted runs through the k-way merge network.
#[derive(Clone, Debug)]
pub struct KWayMerged<T> {
    /// Globally merged stream.
    pub merged: Vec<T>,
    /// Comparator operations actually performed (all passes).
    pub comparisons: u64,
    /// Merge passes executed (`ceil(log_fanout(runs))`).
    pub passes: u32,
    /// Modelled merge-network latency: one element per cycle per pass.
    pub cycles: u64,
}

/// The merge result of `(value, original_index)` runs — the hierarchical
/// pipeline's merge-stage output.
pub type KWayMergeOutput = KWayMerged<(u32, usize)>;

impl KWayMergeOutput {
    /// The merged values alone.
    pub fn values(&self) -> Vec<u32> {
        self.merged.iter().map(|&(v, _)| v).collect()
    }

    /// The merged original indices alone (the global argsort).
    pub fn order(&self) -> Vec<usize> {
        self.merged.iter().map(|&(_, i)| i).collect()
    }
}

/// Merge passes needed to reduce `runs` sorted runs with fanout-`fanout`
/// merge units: `ceil(log_fanout(runs))` (0 when nothing to merge).
pub fn model_merge_passes(runs: usize, fanout: usize) -> u32 {
    assert!(fanout >= 2, "merge fanout must be at least 2");
    let mut passes = 0;
    let mut r = runs;
    while r > 1 {
        r = r.div_ceil(fanout);
        passes += 1;
    }
    passes
}

/// Merge-network latency in cycles for `n` total elements in `runs` runs:
/// every pass streams the whole stream at one element per cycle. With
/// `runs = n` singleton runs and `fanout = 2` this reduces to the binary
/// merge sorter's `N · ceil(log2 N)` model.
pub fn model_merge_cycles(n: usize, runs: usize, fanout: usize) -> u64 {
    n as u64 * model_merge_passes(runs, fanout) as u64
}

/// Merge already-sorted runs of any `Copy + Ord` item through a
/// fanout-`fanout` loser-tree merge network, in as many passes as the
/// fanout requires.
pub fn merge_sorted_runs<T: Copy + Ord>(runs: Vec<Vec<T>>, fanout: usize) -> KWayMerged<T> {
    assert!(fanout >= 2, "merge fanout must be at least 2");
    let total: usize = runs.iter().map(Vec::len).sum();
    let mut runs = runs;
    runs.retain(|r| !r.is_empty());
    let mut comparisons = 0u64;
    let mut passes = 0u32;
    while runs.len() > 1 {
        passes += 1;
        let mut next = Vec::with_capacity(runs.len().div_ceil(fanout));
        let mut it = runs.into_iter();
        loop {
            let group: Vec<Vec<T>> = it.by_ref().take(fanout).collect();
            match group.len() {
                0 => break,
                1 => next.push(group.into_iter().next().expect("one run")),
                _ => {
                    let mut lt = LoserTree::new(&group);
                    let mut out = Vec::with_capacity(group.iter().map(Vec::len).sum());
                    while let Some(x) = lt.pop() {
                        out.push(x);
                    }
                    comparisons += lt.comparisons();
                    next.push(out);
                }
            }
        }
        runs = next;
    }
    KWayMerged {
        merged: runs.pop().unwrap_or_default(),
        comparisons,
        passes,
        cycles: total as u64 * passes as u64,
    }
}

/// Merge already-sorted `(value, original_index)` runs — the merge stage
/// of the hierarchical pipeline: the runs are per-bank sort results and
/// the output is the global order plus the global argsort.
pub fn merge_runs(runs: Vec<Vec<(u32, usize)>>, fanout: usize) -> KWayMergeOutput {
    merge_sorted_runs(runs, fanout)
}

/// Deterministic overlap model of the streaming merge network.
///
/// `leaves` are the per-chunk sorted runs as `(arrival_cycles, len)` in
/// chunk order: chunks sort in parallel banks starting at cycle 0, so
/// chunk i's run exists from its own cycle count on. One fully-pipelined
/// merge engine executes the fixed fanout-`fanout` merge tree (the same
/// index grouping as [`merge_sorted_runs`]): a non-trivial merge op
/// streams its inputs at one element per cycle and starts as soon as
/// its inputs exist and the engine is free; ops are scheduled greedily
/// earliest-ready first (ties: lower level, then lower group).
/// Single-run groups pass through for free.
///
/// Returns the cycle the final merged stream drains. The result never
/// exceeds the barrier model `max(arrival) + model_merge_cycles(n,
/// runs, fanout)` — the engine idles only while the slowest chunks are
/// still sorting, and the tree's total stream work is at most one full
/// stream per pass — and it beats the barrier whenever early groups
/// complete before the slowest chunk arrives.
///
/// Thin wrapper over the schedule layer's
/// [`schedule::event_completion`] — the moved body, pinned
/// byte-identical by this module's tests.
pub fn model_streamed_completion(leaves: &[(u64, usize)], fanout: usize) -> u64 {
    schedule::event_completion(leaves, fanout)
}

/// Streamed completion when every chunk run arrives at the same cycle
/// with the same length — the planner's uniform scoring model. Closed
/// form of [`model_streamed_completion`] for this case: with equal
/// arrivals the engine starts at `arrival` and never idles, so the
/// completion is `arrival` plus the total real-merge work (single-run
/// groups pass through for free). O(chunks), unlike the general
/// event-driven scheduler — this is what lets the auto-tuner score
/// million-element candidates without simulating them.
///
/// Thin wrapper over [`schedule::uniform_completion`] (`arrival +
/// W(chunks, fanout)·len`, with the per-unit work factored out as
/// [`schedule::uniform_merge_work`]) — pinned byte-identical by
/// `uniform_closed_form_matches_event_scheduler`.
pub fn model_streamed_completion_uniform(
    chunks: usize,
    len: usize,
    arrival: u64,
    fanout: usize,
) -> u64 {
    schedule::uniform_completion(chunks, len, arrival, fanout)
}

/// Streamed completion of an `shards`-host fleet draining `chunks`
/// uniform runs of `len` rows that each become available `arrival`
/// cycles after the parallel bank sorts start — the planner's sharded
/// scoring model.
///
/// Topology: chunks are dealt round-robin, so shard `s` owns
/// `chunks/shards` (+1 for the first `chunks % shards` shards) of them.
/// Every shard is an independent host with its *own* merge engine, so
/// each drains its share under the uniform closed form
/// ([`model_streamed_completion_uniform`]) in parallel, and one
/// top-level fanout-`fanout` merge combines the shard streams
/// ([`model_streamed_completion`] over ≤ `shards` leaves, so scoring
/// stays O(chunks) even at millions of elements).
///
/// Reduces *exactly* to [`model_streamed_completion_uniform`] at
/// `shards = 1` (a single leaf passes through the top merge for free),
/// which is what keeps the unsharded planner scoring unchanged. More
/// shards shrink the per-shard merge work that a single engine would
/// serialize; the gain is not monotone past `shards > fanout`, where
/// the cross-shard tree grows an extra pass over the full stream.
///
/// Thin wrapper over [`schedule::sharded_completion`] — pinned
/// byte-identical by `sharded_completion_strictly_decreases_to_fanout_shards`.
pub fn model_sharded_completion(
    chunks: usize,
    len: usize,
    arrival: u64,
    shards: usize,
    fanout: usize,
) -> u64 {
    schedule::sharded_completion(chunks, len, arrival, shards, fanout)
}

/// Streamed completion of a *heterogeneous* fleet: shard `s` owns
/// `deal[s].0` uniform runs of `len` rows, each becoming available at
/// that shard's own `deal[s].1` arrival cycle (a slower host — worse
/// cyc/num, or a bank too small for the chunk — simply arrives later).
/// Every shard drains its share through its own merge engine under the
/// uniform closed form and one top-level fanout-`fanout` merge combines
/// the shard streams; shards dealt zero chunks contribute nothing.
///
/// [`model_sharded_completion`] is exactly this model with an equal
/// deal (round-robin counts, one shared arrival) — the uniform-fleet
/// special case, pinned by `hetero_model_reduces_to_uniform_deal`.
///
/// Thin wrapper over [`schedule::hetero_completion`].
pub fn model_sharded_completion_hetero(
    len: usize,
    deal: &[(usize, u64)],
    fanout: usize,
) -> u64 {
    schedule::hetero_completion(len, deal, fanout)
}

/// Deal `chunks` chunks over shards in proportion to `weights`
/// (largest-remainder apportionment; ties go to the lower shard id).
/// With equal positive weights this reduces exactly to the round-robin
/// deal of [`model_sharded_completion`]: `chunks / shards` each, the
/// first `chunks % shards` shards taking one extra. A shard with zero
/// (or non-finite) weight is dealt nothing unless every weight is
/// degenerate, in which case the deal falls back to equal shares —
/// either way every chunk is accounted for
/// (`degenerate_weight_deals_account_for_every_chunk`).
///
/// Thin wrapper over [`schedule::apportion`].
pub fn apportion_chunks(chunks: usize, weights: &[f64]) -> Vec<usize> {
    schedule::apportion(chunks, weights)
}

/// The hedging straggler bound, in modelled cycles: a chunk of `len`
/// rows on a host observed at `cyc` cycles/number is *expected* to
/// arrive at `round(len·cyc)` cycles (the completion models' leaf
/// arrival — [`model_streamed_completion`] consumes exactly these), so
/// a reply still outstanding past `mult` times that is a straggler and
/// worth hedging to another shard. `floor` bounds the deadline from
/// below so tiny chunks (whose expected arrival is a handful of cycles)
/// don't hedge on scheduling noise. The fleet layer converts this cycle
/// budget to host time with its observed µs-per-cycle calibration; the
/// model itself is deterministic and mirrored by
/// `python/fleet_model.py::model_hedge_deadline`.
///
/// Thin wrapper over [`schedule::hedge_deadline`].
pub fn model_hedge_deadline(len: usize, cyc: f64, mult: f64, floor: u64) -> u64 {
    schedule::hedge_deadline(len, cyc, mult, floor)
}

/// Result of a completed [`StreamingMerge`].
#[derive(Clone, Debug)]
pub struct StreamedMerge<T> {
    /// Globally merged stream (byte-identical to [`merge_sorted_runs`]
    /// over the same runs in chunk order).
    pub merged: Vec<T>,
    /// Comparator operations actually performed (all passes).
    pub comparisons: u64,
    /// Merge passes of the fixed tree (`ceil(log_fanout(runs))`).
    pub passes: u32,
    /// Barrier-model merge-network cycles (whole stream, once per pass).
    pub cycles: u64,
    /// Overlap-model completion: the cycle the final merged stream
    /// drains, counted from when the parallel chunk sorts started
    /// ([`model_streamed_completion`] over the pushed arrivals).
    pub completion_cycles: u64,
}

impl StreamedMerge<(u32, usize)> {
    /// The merged values alone.
    pub fn values(&self) -> Vec<u32> {
        self.merged.iter().map(|&(v, _)| v).collect()
    }

    /// The merged original indices alone (the global argsort).
    pub fn order(&self) -> Vec<usize> {
        self.merged.iter().map(|&(_, i)| i).collect()
    }
}

/// Incremental merge frontier for the streaming hierarchical pipeline.
///
/// Runs are pushed as their chunks finish sorting (any arrival order)
/// and the fixed fanout-`fanout` merge tree advances eagerly: a group is
/// merged the moment its last member arrives, so host-side merge work
/// overlaps the chunk sorts still in flight instead of barriering on
/// all of them. The tree grouping is by chunk index — identical to
/// [`merge_sorted_runs`] over the same runs in chunk order — so for
/// **non-empty** runs (all the hierarchical pipeline ever produces:
/// partition spans are never empty) the merged output, comparison
/// count and pass count match the barrier path exactly (pinned by
/// tests and the streamed-vs-barrier proptest). Empty runs still merge
/// correctly, but the accounting diverges from `merge_sorted_runs`,
/// which prunes them before building its tree while this fixed tree
/// cannot (`streaming_merge_counts_empty_runs_in_its_tree`).
///
/// The latency model is decoupled from host arrival order: `finish`
/// scores the recorded `(arrival_cycles, len)` leaves with the
/// deterministic [`model_streamed_completion`] scheduler, so the
/// modelled cycles are reproducible run-to-run.
pub struct StreamingMerge<T> {
    fanout: usize,
    /// `levels[l][slot]`: a produced run waiting for its group to fill.
    levels: Vec<Vec<Option<Vec<T>>>>,
    /// `(arrival_cycles, len)` per leaf, for the latency model.
    leaves: Vec<Option<(u64, usize)>>,
    received: usize,
    comparisons: u64,
}

impl<T: Copy + Ord> StreamingMerge<T> {
    /// A frontier expecting exactly `expected` runs (chunk count).
    pub fn new(expected: usize, fanout: usize) -> Self {
        assert!(fanout >= 2, "merge fanout must be at least 2");
        let mut levels: Vec<Vec<Option<Vec<T>>>> = vec![(0..expected).map(|_| None).collect()];
        while levels.last().expect("at least one level").len() > 1 {
            let next = levels.last().expect("at least one level").len().div_ceil(fanout);
            levels.push((0..next).map(|_| None).collect());
        }
        StreamingMerge {
            fanout,
            levels,
            leaves: vec![None; expected],
            received: 0,
            comparisons: 0,
        }
    }

    /// Runs received so far.
    pub fn received(&self) -> usize {
        self.received
    }

    /// Feed chunk `idx`'s sorted run, which became available at
    /// `arrival_cycles` in the parallel-bank model. Merges every group
    /// the arrival completes, cascading up the tree.
    pub fn push(&mut self, idx: usize, run: Vec<T>, arrival_cycles: u64) {
        assert!(idx < self.leaves.len(), "run index {idx} out of range");
        assert!(self.leaves[idx].is_none(), "run {idx} pushed twice");
        self.leaves[idx] = Some((arrival_cycles, run.len()));
        self.received += 1;
        self.place(0, idx, run);
    }

    fn place(&mut self, level: usize, slot: usize, run: Vec<T>) {
        self.levels[level][slot] = Some(run);
        if level + 1 == self.levels.len() {
            return; // the root
        }
        let group = slot / self.fanout;
        let lo = group * self.fanout;
        let hi = (lo + self.fanout).min(self.levels[level].len());
        if self.levels[level][lo..hi].iter().any(Option::is_none) {
            return;
        }
        let members: Vec<Vec<T>> = self.levels[level][lo..hi]
            .iter_mut()
            .map(|s| s.take().expect("group checked complete"))
            .collect();
        let merged = if members.len() == 1 {
            members.into_iter().next().expect("one run")
        } else {
            let mut lt = LoserTree::new(&members);
            let mut out = Vec::with_capacity(members.iter().map(Vec::len).sum());
            while let Some(x) = lt.pop() {
                out.push(x);
            }
            self.comparisons += lt.comparisons();
            out
        };
        self.place(level + 1, group, merged);
    }

    /// Close the frontier after every expected run was pushed; returns
    /// the merged stream plus barrier- and overlap-model accounting.
    pub fn finish(mut self) -> StreamedMerge<T> {
        assert_eq!(
            self.received,
            self.leaves.len(),
            "finish() before every expected run was pushed"
        );
        let merged = match self.levels.last_mut() {
            Some(root) if !root.is_empty() => {
                root[0].take().expect("root is produced once all runs arrived")
            }
            _ => Vec::new(),
        };
        let leaves: Vec<(u64, usize)> = self.leaves.iter().map(|l| l.expect("leaf")).collect();
        let total: usize = leaves.iter().map(|&(_, l)| l).sum();
        StreamedMerge {
            merged,
            comparisons: self.comparisons,
            passes: (self.levels.len() - 1) as u32,
            cycles: model_merge_cycles(total, leaves.len(), self.fanout),
            completion_cycles: model_streamed_completion(&leaves, self.fanout),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_reproduces_paper_speed() {
        // N=1024 ⇒ 10 cycles/number ⇒ 3.2× over the 32-cycle baseline.
        let c = MergeSorter::model_cycles(1024);
        assert_eq!(c, 10240);
        assert!((32.0 / (c as f64 / 1024.0) - 3.2).abs() < 1e-12);
    }

    #[test]
    fn model_edge_sizes() {
        assert_eq!(MergeSorter::model_cycles(0), 0);
        assert_eq!(MergeSorter::model_cycles(1), 1);
        assert_eq!(MergeSorter::model_cycles(2), 2);
        assert_eq!(MergeSorter::model_cycles(3), 6); // 2 passes
        assert_eq!(MergeSorter::model_cycles(1000), 10_000); // non-power-of-2
    }

    #[test]
    fn sorts_correctly() {
        let data = vec![5u32, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5];
        let mut m = MergeSorter::new();
        let out = m.sort_with_stats(&data);
        let mut expect = data.clone();
        expect.sort_unstable();
        assert_eq!(out.sorted, expect);
        assert!(m.comparisons > 0);
    }

    #[test]
    fn stable_argsort_on_ties() {
        let data = vec![7u32, 7, 7];
        let mut m = MergeSorter::new();
        let out = m.sort_with_stats(&data);
        assert_eq!(out.order, vec![0, 1, 2], "stability: tie order = row order");
    }

    #[test]
    fn comparison_count_is_n_log_n_ish() {
        let data: Vec<u32> = (0..1024u32).rev().collect();
        let mut m = MergeSorter::new();
        m.sort_with_stats(&data);
        // Reverse order is the worst case-ish: between n/2·log n and n·log n.
        assert!(m.comparisons >= 512 * 10);
        assert!(m.comparisons <= 1024 * 10);
    }

    #[test]
    fn empty_and_single() {
        let mut m = MergeSorter::new();
        assert_eq!(m.sort(&[]), Vec::<u32>::new());
        assert_eq!(m.sort(&[3]), vec![3]);
    }

    fn indexed_runs(chunks: &[&[u32]]) -> Vec<Vec<(u32, usize)>> {
        let mut base = 0usize;
        chunks
            .iter()
            .map(|c| {
                let mut run: Vec<(u32, usize)> =
                    c.iter().enumerate().map(|(i, &v)| (v, base + i)).collect();
                run.sort_unstable();
                base += c.len();
                run
            })
            .collect()
    }

    #[test]
    fn loser_tree_merges_to_global_order() {
        let runs = indexed_runs(&[&[5u32, 1, 9][..], &[2, 2, 8, 30], &[0], &[7, 7]]);
        let mut flat: Vec<u32> = runs.iter().flatten().map(|&(v, _)| v).collect();
        flat.sort_unstable();
        let mut lt = LoserTree::new(&runs);
        let mut got = Vec::new();
        while let Some((v, _)) = lt.pop() {
            got.push(v);
        }
        assert_eq!(got, flat);
        assert!(lt.comparisons() > 0);
    }

    #[test]
    fn loser_tree_edge_shapes() {
        // No runs at all.
        let empty: Vec<Vec<(u32, usize)>> = vec![];
        assert_eq!(LoserTree::new(&empty).pop(), None);
        // One run passes through unchanged.
        let one = indexed_runs(&[&[3u32, 1, 2][..]]);
        let mut lt = LoserTree::new(&one);
        let mut got = Vec::new();
        while let Some(x) = lt.pop() {
            got.push(x.0);
        }
        assert_eq!(got, vec![1, 2, 3]);
        // Empty runs mixed in.
        let mixed = indexed_runs(&[&[][..], &[4u32, 2][..], &[][..], &[3][..]]);
        let mut lt = LoserTree::new(&mixed);
        let mut got = Vec::new();
        while let Some(x) = lt.pop() {
            got.push(x.0);
        }
        assert_eq!(got, vec![2, 3, 4]);
    }

    #[test]
    fn loser_tree_ties_break_by_run_order() {
        let runs = indexed_runs(&[&[7u32, 7][..], &[7], &[5, 7]]);
        let lt_order: Vec<usize> = {
            let mut lt = LoserTree::new(&runs);
            let mut got = Vec::new();
            while let Some((_, i)) = lt.pop() {
                got.push(i);
            }
            got
        };
        // 5 first (run 2), then all the 7s run-by-run: run 0, run 1, run 2.
        assert_eq!(lt_order, vec![3, 0, 1, 2, 4]);
    }

    #[test]
    fn merge_runs_matches_std_sort_across_fanouts() {
        let chunks: Vec<Vec<u32>> = (0..13u32)
            .map(|c| {
                (0..17u32)
                    .map(|i| i.wrapping_mul(2654435761).wrapping_add(c * 40503) >> 7)
                    .collect()
            })
            .collect();
        let refs: Vec<&[u32]> = chunks.iter().map(|c| c.as_slice()).collect();
        let mut expect: Vec<u32> = chunks.iter().flatten().copied().collect();
        expect.sort_unstable();
        for fanout in [2usize, 3, 4, 8, 16] {
            let out = merge_runs(indexed_runs(&refs), fanout);
            assert_eq!(out.values(), expect, "fanout={fanout}");
            assert_eq!(out.passes, model_merge_passes(13, fanout), "fanout={fanout}");
            assert_eq!(out.cycles, model_merge_cycles(expect.len(), 13, fanout));
            // The order is a permutation mapping original indices to values.
            let flat: Vec<u32> = chunks.iter().flatten().copied().collect();
            for (&val, &idx) in out.values().iter().zip(out.order().iter()) {
                assert_eq!(flat[idx], val);
            }
        }
    }

    #[test]
    fn streaming_merge_matches_barrier_merge() {
        let chunks: Vec<Vec<u32>> = (0..13u32)
            .map(|c| {
                (0..17u32)
                    .map(|i| i.wrapping_mul(2654435761).wrapping_add(c * 40503) >> 7)
                    .collect()
            })
            .collect();
        let refs: Vec<&[u32]> = chunks.iter().map(|c| c.as_slice()).collect();
        for fanout in [2usize, 3, 4, 8, 16] {
            let runs = indexed_runs(&refs);
            let barrier = merge_runs(runs.clone(), fanout);
            let mut sm = StreamingMerge::new(runs.len(), fanout);
            // Push in a scrambled arrival order: the tree is fixed by
            // chunk index, so the result must not depend on it.
            let mut order: Vec<usize> = (0..runs.len()).collect();
            order.reverse();
            order.swap(0, 5);
            for &i in &order {
                sm.push(i, runs[i].clone(), (i as u64 + 1) * 100);
            }
            let s = sm.finish();
            assert_eq!(s.merged, barrier.merged, "fanout={fanout}");
            assert_eq!(s.comparisons, barrier.comparisons, "fanout={fanout}");
            assert_eq!(s.passes, barrier.passes, "fanout={fanout}");
            assert_eq!(s.cycles, barrier.cycles, "fanout={fanout}");
            // Streamed completion never exceeds the barrier model.
            let max_arrival = runs.len() as u64 * 100;
            assert!(s.completion_cycles <= max_arrival + barrier.cycles, "fanout={fanout}");
            assert!(s.completion_cycles >= max_arrival, "fanout={fanout}");
        }
    }

    #[test]
    fn streaming_merge_degenerate_shapes() {
        // Zero expected runs.
        let sm: StreamingMerge<(u32, usize)> = StreamingMerge::new(0, 4);
        let s = sm.finish();
        assert!(s.merged.is_empty());
        assert_eq!(s.passes, 0);
        assert_eq!(s.cycles, 0);
        assert_eq!(s.completion_cycles, 0);
        // A single run passes through untouched with zero merge work.
        let mut sm = StreamingMerge::new(1, 4);
        sm.push(0, vec![(1u32, 0usize), (2, 1), (9, 2)], 77);
        let s = sm.finish();
        assert_eq!(s.values(), vec![1, 2, 9]);
        assert_eq!(s.order(), vec![0, 1, 2]);
        assert_eq!(s.comparisons, 0);
        assert_eq!(s.passes, 0);
        assert_eq!(s.completion_cycles, 77, "one run: latency is its own arrival");
        // Empty runs mixed in still merge correctly.
        let mut sm = StreamingMerge::new(3, 2);
        sm.push(1, vec![], 5);
        sm.push(0, vec![(4u32, 0usize), (7, 1)], 9);
        sm.push(2, vec![(5, 2)], 1);
        let s = sm.finish();
        assert_eq!(s.values(), vec![4, 5, 7]);
    }

    #[test]
    fn streaming_merge_counts_empty_runs_in_its_tree() {
        // Accounting divergence on empty runs, pinned: the fixed index
        // tree cannot prune an empty leaf, so it counts a pass the
        // barrier path (which retains non-empty runs first) does not.
        // Values remain identical; the hierarchical pipeline never
        // produces empty runs, so this is API-edge behavior only.
        let runs = vec![vec![], vec![(4u32, 0usize)], vec![(2, 1)]];
        let barrier = merge_runs(runs.clone(), 2);
        let mut sm = StreamingMerge::new(3, 2);
        for (i, r) in runs.into_iter().enumerate() {
            sm.push(i, r, 0);
        }
        let s = sm.finish();
        assert_eq!(s.values(), barrier.values());
        assert_eq!(barrier.passes, 1, "barrier prunes the empty run");
        assert_eq!(s.passes, 2, "the fixed tree counts it");
    }

    #[test]
    #[should_panic(expected = "pushed twice")]
    fn streaming_merge_rejects_duplicate_runs() {
        let mut sm = StreamingMerge::new(2, 2);
        sm.push(0, vec![(1u32, 0usize)], 1);
        sm.push(0, vec![(2, 1)], 2);
    }

    #[test]
    fn streamed_completion_overlaps_early_arrivals() {
        // 4 runs of 10, fanout 2: tree is (0,1) -> a, (2,3) -> b, (a,b)
        // -> root. Runs 0..3 arrive at 10/20/100/100: the (0,1) merge
        // (20 cycles) hides entirely behind the slow chunks, so
        // completion is 100 + 20 + 40 = 160 < barrier 100 + 80.
        let leaves = [(10u64, 10usize), (20, 10), (100, 10), (100, 10)];
        let c = model_streamed_completion(&leaves, 2);
        assert_eq!(c, 160);
        let barrier = 100 + model_merge_cycles(40, 4, 2);
        assert!(c < barrier, "{c} vs {barrier}");
        // Equal arrivals: no overlap to exploit, engine runs the whole
        // tree after the barrier — completion = A + total tree work.
        let eq = [(50u64, 10usize); 4];
        assert_eq!(model_streamed_completion(&eq, 2), 50 + 80);
        // Degenerates.
        assert_eq!(model_streamed_completion(&[], 4), 0);
        assert_eq!(model_streamed_completion(&[(33, 5)], 4), 33);
    }

    #[test]
    fn uniform_closed_form_matches_event_scheduler() {
        for chunks in [0usize, 1, 2, 3, 12, 47, 188, 977] {
            for fanout in [2usize, 4, 16] {
                for arrival in [0u64, 125, 8028] {
                    let closed = model_streamed_completion_uniform(chunks, 64, arrival, fanout);
                    let leaves = vec![(arrival, 64usize); chunks];
                    let sim = model_streamed_completion(&leaves, fanout);
                    assert_eq!(closed, sim, "chunks={chunks} fanout={fanout} a={arrival}");
                }
            }
        }
    }

    #[test]
    fn streamed_completion_never_exceeds_barrier() {
        // Randomized-ish arrivals across shapes and fanouts.
        for runs in [1usize, 2, 3, 7, 16, 61] {
            for fanout in [2usize, 4, 16] {
                let leaves: Vec<(u64, usize)> = (0..runs)
                    .map(|i| ((i as u64).wrapping_mul(2654435761) % 5000, 64 + (i % 7)))
                    .collect();
                let n: usize = leaves.iter().map(|&(_, l)| l).sum();
                let max_a = leaves.iter().map(|&(a, _)| a).max().unwrap_or(0);
                let c = model_streamed_completion(&leaves, fanout);
                let barrier = max_a + model_merge_cycles(n, runs, fanout);
                assert!(c <= barrier, "runs={runs} fanout={fanout}: {c} > {barrier}");
                assert!(c >= max_a, "runs={runs} fanout={fanout}: {c} < {max_a}");
            }
        }
    }

    #[test]
    fn sharded_completion_reduces_to_uniform_at_one_shard() {
        for chunks in [1usize, 2, 5, 61, 977] {
            for fanout in [2usize, 4, 16] {
                assert_eq!(
                    model_sharded_completion(chunks, 1024, 8028, 1, fanout),
                    model_streamed_completion_uniform(chunks, 1024, 8028, fanout),
                    "chunks={chunks} fanout={fanout}"
                );
            }
        }
        // Degenerates: no chunks, and more shards than chunks (each
        // shard holds at most one run, so only the cross-shard merge
        // remains — the fully parallel limit).
        assert_eq!(model_sharded_completion(0, 64, 5, 4, 4), 0);
        assert_eq!(
            model_sharded_completion(3, 64, 5, 16, 4),
            model_streamed_completion(&[(5, 64); 3], 4),
            "shards >= chunks collapses to one run per shard"
        );
    }

    #[test]
    fn sharded_completion_strictly_decreases_to_fanout_shards() {
        // The acceptance shape: n = 1M over 977 banks of 1024 at the
        // paper's nominal 7.84 cyc/num, fanout 4. Values cross-checked
        // against an independent model implementation.
        let chunks = 1_000_000usize.div_ceil(1024);
        let arrival = (1024.0f64 * 7.84).round() as u64;
        let lat: Vec<u64> = (1..=4)
            .map(|s| model_sharded_completion(chunks, 1024, arrival, s, 4))
            .collect();
        assert_eq!(lat, vec![5_008_220, 3_511_132, 2_671_452, 2_010_972]);
        assert!(lat.windows(2).all(|w| w[1] < w[0]), "{lat:?}");
        // Past shards = fanout the cross-shard tree gains a pass over
        // the full stream: 8 shards regress against 4 (documented in
        // EXPERIMENTS.md §Shard scaling).
        let eight = model_sharded_completion(chunks, 1024, arrival, 8, 4);
        assert!(eight > lat[3], "{eight} vs {}", lat[3]);
        // Every fleet still beats the single-engine flat schedule.
        let flat = model_streamed_completion_uniform(chunks, 1024, arrival, 4);
        for (s, &l) in lat.iter().enumerate().skip(1) {
            assert!(l < flat, "shards={} {l} vs flat {flat}", s + 1);
        }
    }

    #[test]
    fn hetero_model_reduces_to_uniform_deal() {
        // The uniform fleet model IS the heterogeneous model with an
        // equal deal — across chunk counts, shard counts and fanouts,
        // including shards > chunks (zero-chunk shards drop out).
        for chunks in [1usize, 2, 3, 5, 61, 977] {
            for shards in [1usize, 2, 3, 4, 8, 16] {
                for fanout in [2usize, 4, 16] {
                    let s = shards.min(chunks);
                    let (base, extra) = (chunks / s, chunks % s);
                    // Equal deal padded with zero-chunk shards: they
                    // must not change the result.
                    let mut deal: Vec<(usize, u64)> =
                        (0..s).map(|i| (base + usize::from(i < extra), 8028)).collect();
                    deal.resize(shards, (0, 8028));
                    assert_eq!(
                        model_sharded_completion_hetero(1024, &deal, fanout),
                        model_sharded_completion(chunks, 1024, 8028, shards, fanout),
                        "chunks={chunks} shards={shards} fanout={fanout}"
                    );
                }
            }
        }
    }

    #[test]
    fn hetero_model_penalizes_slow_shards() {
        // 8 chunks over 2 shards, fanout 4. A fleet with one shard at
        // twice the arrival cost must complete strictly later than the
        // uniform fleet at the fast arrival, and a cost-aware deal that
        // shifts chunks onto the fast shard must beat the even deal.
        let fast = model_sharded_completion(8, 1024, 8028, 2, 4);
        let even = model_sharded_completion_hetero(1024, &[(4, 8028), (4, 16056)], 4);
        let skewed = model_sharded_completion_hetero(1024, &[(5, 8028), (3, 16056)], 4);
        // Hand-computed under the scheduler: 20316 < 27320 < 28344.
        assert_eq!(fast, 20_316);
        assert_eq!(even, 28_344);
        assert_eq!(skewed, 27_320);
        assert!(even > fast, "{even} vs {fast}");
        assert!(skewed < even, "{skewed} vs {even}");
    }

    #[test]
    fn apportionment_follows_weights_and_reduces_round_robin() {
        // Equal weights = the uniform round-robin deal.
        assert_eq!(apportion_chunks(9, &[1.0, 1.0, 1.0]), vec![3, 3, 3]);
        assert_eq!(apportion_chunks(5, &[1.0, 1.0, 1.0]), vec![2, 2, 1]);
        assert_eq!(apportion_chunks(3, &[2.0; 16])[..4], [1, 1, 1, 0]);
        // Proportional split, remainders to the largest fractional part.
        assert_eq!(apportion_chunks(9, &[2.0, 1.0]), vec![6, 3]);
        assert_eq!(apportion_chunks(10, &[3.0, 1.0]), vec![8, 2]);
        assert_eq!(apportion_chunks(7, &[2.0, 1.0]), vec![5, 2], "4.67 -> 5, 2.33 -> 2");
        // Zero / non-finite weights are dealt nothing...
        assert_eq!(apportion_chunks(6, &[1.0, 0.0, 1.0]), vec![3, 0, 3]);
        assert_eq!(apportion_chunks(4, &[f64::NAN, 2.0]), vec![0, 4]);
        // ...unless every weight is degenerate (fallback: equal).
        assert_eq!(apportion_chunks(4, &[0.0, 0.0]), vec![2, 2]);
        // Every deal covers exactly the chunk count.
        for chunks in [0usize, 1, 7, 977] {
            let deal = apportion_chunks(chunks, &[5.0, 0.5, 1.0, 3.25]);
            assert_eq!(deal.iter().sum::<usize>(), chunks, "chunks={chunks}");
        }
    }

    #[test]
    fn degenerate_weight_deals_account_for_every_chunk() {
        // Observed-cost feedback can hand apportionment NaN (0/0 on a
        // fresh class), ±inf (cyc overflow), zero and negative weights
        // — in any combination. Pinned guard behavior: a degenerate
        // entry is clamped to zero weight while any sane weight exists;
        // all-degenerate clamps to the uniform deal; every chunk is
        // accounted for in all cases (never a panic, never a lost or
        // invented chunk).
        assert_eq!(apportion_chunks(4, &[f64::INFINITY, 2.0]), vec![0, 4]);
        assert_eq!(apportion_chunks(4, &[-3.0, 2.0]), vec![0, 4]);
        assert_eq!(apportion_chunks(5, &[f64::NAN, f64::INFINITY, -1.0]), vec![2, 2, 1]);
        assert_eq!(apportion_chunks(6, &[f64::NEG_INFINITY, -0.0, 0.0]), vec![2, 2, 2]);
        assert_eq!(apportion_chunks(0, &[f64::NAN, f64::NAN]), vec![0, 0]);
        let shapes: [&[f64]; 4] = [
            &[f64::NAN, f64::NAN, f64::NAN],
            &[f64::INFINITY; 2],
            &[1.0, f64::NAN, 3.0, -2.0],
            &[0.0, f64::MIN_POSITIVE, 4.0],
        ];
        for weights in shapes {
            for chunks in [0usize, 1, 7, 977] {
                let deal = apportion_chunks(chunks, weights);
                assert_eq!(
                    deal.iter().sum::<usize>(),
                    chunks,
                    "weights={weights:?} chunks={chunks}"
                );
            }
        }
    }

    #[test]
    fn hedge_deadline_scales_with_the_arrival_model_and_floors() {
        // The deadline is `mult` times the modelled leaf arrival
        // (`round(len·cyc)` — the quantity the completion models
        // consume), floored. Values pinned against the Python mirror
        // (`python/fleet_model.py::model_hedge_deadline`).
        assert_eq!(model_hedge_deadline(1024, 7.84, 4.0, 0), 32_113);
        assert_eq!(model_hedge_deadline(1024, 7.84, 1.0, 0), 8_028);
        assert_eq!(model_hedge_deadline(512, 15.68, 2.0, 0), 16_056);
        // The floor wins for tiny chunks.
        assert_eq!(model_hedge_deadline(4, 7.84, 4.0, 10_000), 10_000);
        assert_eq!(model_hedge_deadline(0, 7.84, 4.0, 77), 77);
        // Degenerate-but-legal inputs stay sane.
        assert_eq!(model_hedge_deadline(1024, 0.0, 4.0, 5), 5);
        assert_eq!(model_hedge_deadline(1024, 7.84, 0.0, 0), 0);
    }

    #[test]
    fn merge_pass_model_reduces_to_binary_sorter() {
        // Merging N singleton runs pairwise is exactly the merge sorter.
        for n in [2usize, 3, 7, 1000, 1024] {
            assert_eq!(model_merge_cycles(n, n, 2), MergeSorter::model_cycles(n), "n={n}");
        }
        // Fanout cuts passes logarithmically.
        assert_eq!(model_merge_passes(16, 2), 4);
        assert_eq!(model_merge_passes(16, 4), 2);
        assert_eq!(model_merge_passes(16, 16), 1);
        assert_eq!(model_merge_passes(17, 16), 2);
        assert_eq!(model_merge_passes(1, 4), 0);
        assert_eq!(model_merge_passes(0, 4), 0);
    }
}
